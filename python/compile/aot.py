"""AOT export: lower the L2 computations to HLO text for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the `xla` crate) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts [--profile mnist-small]
Python runs ONCE, at build time; the Rust binary is self-contained after
`make artifacts`.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Profiles mirrored from rust/src/config/schema.rs (kept small: these fix the
# *artifact* shapes; the Rust coordinator pads requests to the batch size).
PROFILES = {
    "mnist-small": dict(layers=[784, 256, 128, 64, 10], ranks=[13, 7, 4], batch=64),
    "mnist-tiny": dict(layers=[784, 64, 48, 32, 10], ranks=[8, 6, 4], batch=16),
    "svhn-small": dict(layers=[1024, 300, 180, 100, 60, 10], ranks=[15, 9, 6, 5], batch=64),
    "mnist-paper": dict(layers=[784, 1000, 600, 400, 10], ranks=[50, 35, 25], batch=100),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _arg_entry(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def export_profile(profile_name, out_dir, train_cfg=None):
    cfg = PROFILES[profile_name]
    layers, ranks, batch = cfg["layers"], cfg["ranks"], cfg["batch"]
    n_weight = len(layers) - 1
    tag = profile_name.replace("-", "_")
    manifest_entries = []

    param_specs, param_args = [], []
    for l in range(n_weight):
        param_specs += [_spec(layers[l], layers[l + 1]), _spec(layers[l + 1])]
        param_args += [
            _arg_entry(f"w{l}", (layers[l], layers[l + 1])),
            _arg_entry(f"b{l}", (layers[l + 1],)),
        ]

    x_spec = _spec(batch, layers[0])

    # ---- forward_control ------------------------------------------------
    def fwd_control(params, x):
        return (model.forward_control(list(params), x, use_pallas=True),)

    lowered = jax.jit(fwd_control).lower(tuple(param_specs), x_spec)
    path = f"{tag}_fwd.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest_entries.append(
        {
            "name": f"{tag}_fwd",
            "file": path,
            "inputs": param_args + [_arg_entry("x", (batch, layers[0]))],
            "outputs": [_arg_entry("logits", (batch, layers[-1]))],
            "batch": batch,
            "layers": layers,
        }
    )

    # ---- forward_ae ------------------------------------------------------
    factor_specs, factor_args = [], []
    for l in range(n_weight - 1):
        k = ranks[l]
        factor_specs += [_spec(layers[l], k), _spec(k, layers[l + 1])]
        factor_args += [
            _arg_entry(f"u{l}", (layers[l], k)),
            _arg_entry(f"v{l}", (k, layers[l + 1])),
        ]

    def fwd_ae(params, factors, x):
        return (model.forward_ae(list(params), list(factors), x, use_pallas=True),)

    lowered = jax.jit(fwd_ae).lower(tuple(param_specs), tuple(factor_specs), x_spec)
    path = f"{tag}_fwd_ae.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest_entries.append(
        {
            "name": f"{tag}_fwd_ae",
            "file": path,
            "inputs": param_args + factor_args + [_arg_entry("x", (batch, layers[0]))],
            "outputs": [_arg_entry("logits", (batch, layers[-1]))],
            "batch": batch,
            "layers": layers,
            "ranks": ranks,
        }
    )

    # ---- train_step ------------------------------------------------------
    tc = train_cfg or dict(dropout_p=0.5, l1_activation=1e-5, l2_weight=5e-5, max_norm=25.0)

    def step(params, velocity, x, y, key, lr, momentum):
        new_p, new_v, loss = model.train_step(
            list(params), list(velocity), x, y, key, lr, momentum,
            dropout_p=tc["dropout_p"], l1_activation=tc["l1_activation"],
            l2_weight=tc["l2_weight"], max_norm=tc["max_norm"],
        )
        return tuple(new_p) + tuple(new_v) + (loss,)

    y_spec = jax.ShapeDtypeStruct((batch,), jnp.int32)
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(step).lower(
        tuple(param_specs), tuple(param_specs), x_spec, y_spec, key_spec, scalar, scalar
    )
    path = f"{tag}_train_step.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    velo_args = [dict(a, name="v_" + a["name"]) for a in param_args]
    manifest_entries.append(
        {
            "name": f"{tag}_train_step",
            "file": path,
            "inputs": param_args
            + velo_args
            + [
                _arg_entry("x", (batch, layers[0])),
                _arg_entry("y", (batch,), "i32"),
                _arg_entry("key", (2,), "u32"),
                _arg_entry("lr", (), "f32"),
                _arg_entry("momentum", (), "f32"),
            ],
            "outputs": param_args + velo_args + [_arg_entry("loss", ())],
            "batch": batch,
            "layers": layers,
            "train_cfg": tc,
        }
    )
    return manifest_entries


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--profile",
        action="append",
        default=None,
        help="profile(s) to export; default: mnist-small + mnist-tiny",
    )
    args = ap.parse_args()
    profiles = args.profile or ["mnist-small", "mnist-tiny"]
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"profiles": {}, "format": "hlo-text", "version": 1}
    for p in profiles:
        entries = export_profile(p, args.out_dir)
        manifest["profiles"][p] = entries
        for e in entries:
            print(f"wrote {e['file']}")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['profiles'])} profiles)")


if __name__ == "__main__":
    main()
