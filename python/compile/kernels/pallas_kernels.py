"""Layer-1 Pallas kernels for the conditional-computation hot path.

Three kernels:

- ``dense_relu``          — fused tiled matmul + bias + ReLU (control path).
- ``lowrank_sign``        — the activation-sign estimator sgn(x.U.V + b - t):
                            U and V are small enough to be VMEM-resident, so
                            the whole estimator runs out of scratchpad.
- ``masked_dense_relu``   — the conditional layer: a tile is *computed* only
                            when the estimator marked any unit in it live
                            (tile-granular conditionality — the TPU adaptation
                            of the paper's per-dot-product skipping, see
                            DESIGN.md §Hardware-Adaptation); within a live
                            tile the element mask zeroes skipped units.

All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls; real-TPU performance is *estimated* from the
BlockSpec VMEM footprint + MXU utilization in DESIGN.md §Perf.

Tiling: inputs are zero-padded up to (BM, BN) multiples inside the wrappers,
so arbitrary layer shapes work; padding is sliced off on the way out.
ReLU(0)=0 and sign masks on padded columns are discarded by the slice.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes. On a real TPU these would be 128x128 (MXU-aligned); we keep the
# same structure but smaller tiles so interpret-mode tests stay fast.
BM = 32
BN = 32

_INTERPRET = True


def _pad_to(x, m, axis):
    """Zero-pad `axis` of x up to a multiple of m."""
    n = x.shape[axis]
    pad = (-n) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# --------------------------------------------------------------------------
# dense_relu
# --------------------------------------------------------------------------

def _dense_relu_kernel(x_ref, w_ref, b_ref, o_ref):
    # One (BM, BN) output tile: full-K matmul + bias + ReLU.
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.maximum(acc + b_ref[...], 0.0)


def dense_relu(x, w, b):
    """Fused sigma(x @ w + b); tiled over (M, N), K kept whole per tile.

    VMEM per grid step: BM*K + K*BN + BM*BN floats. For the paper's largest
    layer (K = 1500) that is 32*1500 + 1500*32 + 32*32 ~ 0.4 MB — comfortably
    inside a 16 MB VMEM budget, so no K-loop is needed.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    xp = _pad_to(x, BM, 0)
    wp = _pad_to(w, BN, 1)
    bp = _pad_to(b.reshape(1, -1), BN, 1)
    mp, np_ = xp.shape[0], wp.shape[1]
    out = pl.pallas_call(
        _dense_relu_kernel,
        grid=(mp // BM, np_ // BN),
        in_specs=[
            pl.BlockSpec((BM, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BN), lambda i, j: (0, j)),
            pl.BlockSpec((1, BN), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=_INTERPRET,
    )(xp, wp, bp)
    return out[:m, :n]


# --------------------------------------------------------------------------
# lowrank_sign
# --------------------------------------------------------------------------

def _lowrank_sign_kernel(t_ref, v_ref, b_ref, bias_ref, o_ref):
    # t = x @ U was computed by the first stage; this tile finishes t @ V.
    z = jnp.dot(t_ref[...], v_ref[...], preferred_element_type=jnp.float32)
    z = z + b_ref[...] - bias_ref[0]
    o_ref[...] = (z > 0.0).astype(o_ref.dtype)


def lowrank_sign(x, u, v, b, decision_bias=0.0):
    """The estimator mask S = [x@U@V + b - t > 0] (paper Eq. 5).

    Stage 1 (x @ U) reuses the dense pipeline without ReLU via jnp.dot — it
    is a skinny matmul (k <= ~200) whose result is tiny; stage 2 runs as a
    Pallas kernel with V held entirely in VMEM (k x BN per tile).
    """
    m, d = x.shape
    k = u.shape[1]
    n = v.shape[1]
    assert u.shape == (d, k) and b.shape == (n,)
    t = x @ u  # (m, k): skinny; XLA fuses this into the surrounding HLO.
    tp = _pad_to(t, BM, 0)
    vp = _pad_to(v, BN, 1)
    bp = _pad_to(b.reshape(1, -1), BN, 1)
    bias_arr = jnp.full((1,), decision_bias, dtype=x.dtype)
    mp, np_ = tp.shape[0], vp.shape[1]
    out = pl.pallas_call(
        _lowrank_sign_kernel,
        grid=(mp // BM, np_ // BN),
        in_specs=[
            pl.BlockSpec((BM, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BN), lambda i, j: (0, j)),
            pl.BlockSpec((1, BN), lambda i, j: (0, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=_INTERPRET,
    )(tp, vp, bp, bias_arr)
    return out[:m, :n]


# --------------------------------------------------------------------------
# masked_dense_relu (tile-granular conditional layer)
# --------------------------------------------------------------------------

def _masked_dense_relu_kernel(x_ref, w_ref, b_ref, m_ref, occ_ref, o_ref):
    @pl.when(occ_ref[0, 0] > 0)
    def _compute():
        acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        o_ref[...] = jnp.maximum(acc + b_ref[...], 0.0) * m_ref[...]

    @pl.when(occ_ref[0, 0] == 0)
    def _skip():
        # Dead tile: write zeros without reading the W tile from HBM.
        o_ref[...] = jnp.zeros_like(o_ref)


def masked_dense_relu(x, w, b, mask):
    """sigma(x @ w + b) * S with whole (BM, BN) tiles skipped when S is all
    zero there — the estimator's prediction turned into saved HBM traffic and
    MXU issue slots (DESIGN.md §Hardware-Adaptation).
    """
    m, k = x.shape
    n = w.shape[1]
    assert mask.shape == (m, n)
    xp = _pad_to(x, BM, 0)
    wp = _pad_to(w, BN, 1)
    bp = _pad_to(b.reshape(1, -1), BN, 1)
    maskp = _pad_to(_pad_to(mask, BM, 0), BN, 1)
    mp, np_ = xp.shape[0], wp.shape[1]
    # Per-tile occupancy: 1 where any unit in the (BM, BN) tile is live.
    occ = (
        maskp.reshape(mp // BM, BM, np_ // BN, BN)
        .transpose(0, 2, 1, 3)
        .max(axis=(2, 3))
        .astype(jnp.int32)
    )
    out = pl.pallas_call(
        _masked_dense_relu_kernel,
        grid=(mp // BM, np_ // BN),
        in_specs=[
            pl.BlockSpec((BM, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BN), lambda i, j: (0, j)),
            pl.BlockSpec((1, BN), lambda i, j: (0, j)),
            pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=_INTERPRET,
    )(xp, wp, bp, maskp, occ)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("decision_bias",))
def cond_layer(x, w, b, u, v, decision_bias=0.0):
    """Fused estimator + conditional layer (the per-layer hot path)."""
    mask = lowrank_sign(x, u, v, b, decision_bias)
    return masked_dense_relu(x, w, b, mask)
