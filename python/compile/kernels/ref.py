"""Pure-jnp reference oracle for the Pallas kernels.

Every kernel in this package has its semantics pinned here; pytest sweeps
shapes (via hypothesis) and asserts `assert_allclose(kernel(...), ref(...))`.
The reference is also what `model.forward_*` would compute if the kernels
were replaced by stock jnp ops, so kernel == ref implies model-level parity.
"""

import jax.numpy as jnp


def dense_relu(x, w, b):
    """sigma(x @ w + b) with sigma = ReLU (paper Eq. 1 + Eq. 3)."""
    return jnp.maximum(x @ w + b, 0.0)


def lowrank_sign_mask(x, u, v, b, decision_bias=0.0):
    """The paper's S matrix (Eq. 5) from the low-rank factors.

    S[i, j] = 1 where (x @ U @ V + b)[i, j] - decision_bias > 0 else 0.
    The cheap association order (x @ U) @ V is semantically irrelevant here
    but is what the kernel implements.
    """
    z = (x @ u) @ v + b
    return (z - decision_bias > 0.0).astype(x.dtype)


def masked_dense_relu(x, w, b, mask):
    """sigma(x @ w + b) * S — the conditional layer (paper §3.1)."""
    return dense_relu(x, w, b) * mask


def cond_layer(x, w, b, u, v, decision_bias=0.0):
    """Estimator + conditional layer fused: the per-layer hot path."""
    mask = lowrank_sign_mask(x, u, v, b, decision_bias)
    return masked_dense_relu(x, w, b, mask)
