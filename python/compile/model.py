"""Layer-2: the paper's MLP in JAX, built on the Layer-1 Pallas kernels.

Three exported computations (all AOT-lowered to HLO text by `aot.py`):

- ``forward_control``  — the dense network sigma(a.W + b) per layer.
- ``forward_ae``       — the estimator-augmented network: per hidden layer,
  the Pallas ``lowrank_sign`` estimator produces S and the Pallas
  ``masked_dense_relu`` computes only predicted-live units (paper Eq. 5).
- ``train_step``       — one SGD+momentum minibatch step with dropout,
  l1 activation penalty (Eq. 7), l2 weight penalty, and max-norm projection
  (Table 1 / §3.5), matching the Rust reference trainer semantically.

Parameters travel as a flat list [w0, b0, w1, b1, ...] so the Rust runtime
can marshal them positionally (see artifacts/manifest.json).
"""

import jax
import jax.numpy as jnp

from .kernels import pallas_kernels as K
from .kernels import ref


def init_params(layers, weight_sigma, bias_init, key):
    """w ~ N(0, sigma^2), b = bias_init (paper §3.5)."""
    params = []
    for i in range(len(layers) - 1):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (layers[i], layers[i + 1]), jnp.float32) * weight_sigma
        b = jnp.full((layers[i + 1],), bias_init, jnp.float32)
        params += [w, b]
    return params


def forward_control(params, x, use_pallas=True):
    """Dense forward; returns logits."""
    layer = K.dense_relu if use_pallas else ref.dense_relu
    n_layers = len(params) // 2
    a = x
    for l in range(n_layers - 1):
        a = layer(a, params[2 * l], params[2 * l + 1])
    return a @ params[-2] + params[-1]


def forward_ae(params, factors, x, use_pallas=True, decision_bias=0.0):
    """Estimator-augmented forward (factors = flat [u0, v0, u1, v1, ...]).

    The output layer is never estimated (§4.1).
    """
    n_layers = len(params) // 2
    assert len(factors) == 2 * (n_layers - 1)
    a = x
    for l in range(n_layers - 1):
        w, b = params[2 * l], params[2 * l + 1]
        u, v = factors[2 * l], factors[2 * l + 1]
        if use_pallas:
            mask = K.lowrank_sign(a, u, v, b, decision_bias)
            a = K.masked_dense_relu(a, w, b, mask)
        else:
            a = ref.cond_layer(a, w, b, u, v, decision_bias)
    return a @ params[-2] + params[-1]


def _split_params(params):
    return params[0::2], params[1::2]


def loss_fn(params, x, y, key, dropout_p, l1_activation):
    """Mean NLL + l1 activation penalty, with inverted dropout on hidden
    activations. `y` is int32 labels. Returns (loss, logits)."""
    ws, bs = _split_params(params)
    n_layers = len(ws)
    a = x
    penalty = 0.0
    for l in range(n_layers - 1):
        a = ref.dense_relu(a, ws[l], bs[l])
        penalty = penalty + l1_activation * jnp.abs(a).sum()
        if dropout_p > 0.0:
            key, sub = jax.random.split(key)
            keep = jax.random.bernoulli(sub, 1.0 - dropout_p, a.shape)
            a = jnp.where(keep, a / (1.0 - dropout_p), 0.0)
    logits = a @ ws[-1] + bs[-1]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    return nll + penalty, logits


def train_step(params, velocity, x, y, key, lr, momentum,
               dropout_p=0.5, l1_activation=0.0, l2_weight=0.0, max_norm=25.0):
    """One minibatch of SGD with momentum + the paper's regularizers.

    v <- mu v - lr (grad + l2 w); w <- w + v; then max-norm column clamp.
    Returns (new_params, new_velocity, loss).
    """
    (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, x, y, key, dropout_p, l1_activation
    )
    new_params, new_velocity = [], []
    for i, (p, v, g) in enumerate(zip(params, velocity, grads)):
        is_weight = i % 2 == 0
        reg = l2_weight * p if is_weight else 0.0
        nv = momentum * v - lr * (g + reg)
        np_ = p + nv
        if is_weight and max_norm > 0.0:
            norms = jnp.linalg.norm(np_, axis=0, keepdims=True)
            np_ = np_ * jnp.minimum(1.0, max_norm / jnp.maximum(norms, 1e-12))
        new_params.append(np_)
        new_velocity.append(nv)
    return new_params, new_velocity, loss


def truncated_svd_factors(w, rank):
    """The paper's U = U_r, V = Sigma_r V_r^T factors (§3.2) — build-time
    helper for exporting estimator-augmented artifacts with concrete ranks."""
    u, s, vt = jnp.linalg.svd(w, full_matrices=False)
    r = int(rank)
    return u[:, :r], s[:r, None] * vt[:r, :]
