"""L1 correctness: Pallas kernels vs. the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (tile-aligned and ragged) and dtypes' value ranges;
assert_allclose against the reference pins kernel semantics exactly.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import pallas_kernels as K
from compile.kernels import ref

DIMS = st.integers(min_value=1, max_value=70)
RANKS = st.integers(min_value=1, max_value=12)
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


def _keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, d=DIMS, h=DIMS, seed=SEEDS)
def test_dense_relu_matches_ref(m, d, h, seed):
    kx, kw, kb = _keys(seed, 3)
    x, w, b = _rand(kx, m, d), _rand(kw, d, h), _rand(kb, h)
    np.testing.assert_allclose(
        np.asarray(K.dense_relu(x, w, b)),
        np.asarray(ref.dense_relu(x, w, b)),
        rtol=1e-5, atol=1e-5,
    )


@settings(max_examples=25, deadline=None)
@given(m=DIMS, d=DIMS, h=DIMS, k=RANKS, seed=SEEDS)
def test_lowrank_sign_matches_ref(m, d, h, k, seed):
    kx, ku, kv, kb = _keys(seed, 4)
    x, u, v, b = _rand(kx, m, d), _rand(ku, d, k), _rand(kv, k, h), _rand(kb, h)
    got = np.asarray(K.lowrank_sign(x, u, v, b))
    want = np.asarray(ref.lowrank_sign_mask(x, u, v, b))
    # Masks are exactly 0/1; equality is required except at |z| ~ 0 ties.
    z = np.asarray((x @ u) @ v + b)
    stable = np.abs(z) > 1e-5
    np.testing.assert_array_equal(got[stable], want[stable])
    assert set(np.unique(got)).issubset({0.0, 1.0})


@settings(max_examples=25, deadline=None)
@given(m=DIMS, d=DIMS, h=DIMS, seed=SEEDS, p=st.floats(0.0, 1.0))
def test_masked_dense_relu_matches_ref(m, d, h, seed, p):
    kx, kw, kb, km = _keys(seed, 4)
    x, w, b = _rand(kx, m, d), _rand(kw, d, h), _rand(kb, h)
    mask = (jax.random.uniform(km, (m, h)) < p).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(K.masked_dense_relu(x, w, b, mask)),
        np.asarray(ref.masked_dense_relu(x, w, b, mask)),
        rtol=1e-5, atol=1e-5,
    )


@settings(max_examples=15, deadline=None)
@given(m=DIMS, d=DIMS, h=DIMS, k=RANKS, seed=SEEDS)
def test_cond_layer_fused_matches_ref(m, d, h, k, seed):
    kx, kw, kb, ku, kv = _keys(seed, 5)
    x, w, b = _rand(kx, m, d), _rand(kw, d, h), _rand(kb, h)
    u, v = _rand(ku, d, k), _rand(kv, k, h)
    got = np.asarray(K.cond_layer(x, w, b, u, v))
    want = np.asarray(ref.cond_layer(x, w, b, u, v))
    # Boundary sign flips (|z| ~ 0) may differ; compare where stable.
    z = np.asarray((x @ u) @ v + b)
    stable = np.abs(z) > 1e-5
    np.testing.assert_allclose(got[stable], want[stable], rtol=1e-5, atol=1e-5)


def test_dense_relu_tile_boundary_shapes():
    # Exactly one tile, tile-multiple, and off-by-one shapes.
    for (m, d, h) in [(32, 32, 32), (64, 32, 64), (33, 17, 65), (1, 1, 1)]:
        kx, kw, kb = _keys(m * 1000 + d * 10 + h, 3)
        x, w, b = _rand(kx, m, d), _rand(kw, d, h), _rand(kb, h)
        np.testing.assert_allclose(
            np.asarray(K.dense_relu(x, w, b)),
            np.asarray(ref.dense_relu(x, w, b)),
            rtol=1e-5, atol=1e-5,
        )


def test_masked_kernel_zero_mask_returns_zeros():
    kx, kw, kb = _keys(7, 3)
    x, w, b = _rand(kx, 40, 20), _rand(kw, 20, 50), _rand(kb, 50)
    out = K.masked_dense_relu(x, w, b, jnp.zeros((40, 50), jnp.float32))
    assert np.all(np.asarray(out) == 0.0)


def test_full_rank_estimator_is_output_preserving():
    # With k = min(d, h) and exact SVD factors, the conditional layer must
    # reproduce the dense layer exactly (true zeros stay zero under ReLU).
    from compile.model import truncated_svd_factors

    kx, kw, kb = _keys(13, 3)
    x, w, b = _rand(kx, 24, 16), _rand(kw, 16, 20), _rand(kb, 20)
    u, v = truncated_svd_factors(w, 16)
    got = np.asarray(K.cond_layer(x, w, b, u, v))
    want = np.asarray(ref.dense_relu(x, w, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_decision_bias_monotone_sparsity():
    kx, kw, kb, ku, kv = _keys(3, 5)
    x, w, b = _rand(kx, 30, 12), _rand(kw, 12, 18), _rand(kb, 18)
    u, v = _rand(ku, 12, 4), _rand(kv, 4, 18)
    d0 = float(np.asarray(K.lowrank_sign(x, u, v, b, 0.0)).mean())
    d1 = float(np.asarray(K.lowrank_sign(x, u, v, b, 0.8)).mean())
    assert d1 <= d0
