"""AOT export sanity: HLO text is produced, parseable-looking, and the
manifest describes it faithfully. (The authoritative load test is on the Rust
side: rust/tests/runtime_roundtrip.rs executes these artifacts via PJRT.)"""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entries = aot.export_profile("mnist-tiny", str(out))
    return out, entries


def test_export_writes_all_files(exported):
    out, entries = exported
    assert len(entries) == 3
    for e in entries:
        path = out / e["file"]
        assert path.exists(), f"missing {e['file']}"
        text = path.read_text()
        assert "ENTRY" in text, "HLO text must contain an ENTRY computation"
        assert "HloModule" in text


def test_manifest_input_shapes_match_profile(exported):
    _, entries = exported
    cfg = aot.PROFILES["mnist-tiny"]
    fwd = next(e for e in entries if e["name"].endswith("_fwd"))
    x = next(a for a in fwd["inputs"] if a["name"] == "x")
    assert x["shape"] == [cfg["batch"], cfg["layers"][0]]
    # One (w, b) pair per weight layer.
    wnames = [a["name"] for a in fwd["inputs"] if a["name"].startswith("w")]
    assert len(wnames) == len(cfg["layers"]) - 1


def test_ae_manifest_has_factors(exported):
    _, entries = exported
    cfg = aot.PROFILES["mnist-tiny"]
    ae = next(e for e in entries if e["name"].endswith("_fwd_ae"))
    unames = [a for a in ae["inputs"] if a["name"].startswith("u")]
    assert len(unames) == len(cfg["layers"]) - 2
    u0 = next(a for a in ae["inputs"] if a["name"] == "u0")
    assert u0["shape"] == [cfg["layers"][0], cfg["ranks"][0]]


def test_train_step_manifest_roundtrips_params(exported):
    _, entries = exported
    ts = next(e for e in entries if e["name"].endswith("_train_step"))
    in_names = [a["name"] for a in ts["inputs"]]
    out_names = [a["name"] for a in ts["outputs"]]
    # Outputs = params + velocities + loss, in the same order as inputs.
    assert out_names[: len(out_names) - 1] == in_names[: len(out_names) - 1]
    assert out_names[-1] == "loss"
    assert "key" in in_names and "lr" in in_names and "momentum" in in_names


def test_parameter_count_in_hlo(exported):
    out, entries = exported
    fwd = next(e for e in entries if e["name"].endswith("_fwd"))
    text = (out / fwd["file"]).read_text()
    # The entry computation must take exactly len(inputs) parameters.
    entry = [l for l in text.splitlines() if l.startswith("ENTRY")]
    assert entry, "no ENTRY line"
    assert entry[0].count("parameter") >= 0  # structural smoke; exact count
    # checked by the Rust-side round-trip test.
