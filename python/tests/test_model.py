"""L2 correctness: model forward shapes/parity and train_step behaviour."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model

LAYERS = [20, 16, 12, 5]


def _init(seed=0):
    return model.init_params(LAYERS, 0.3, 0.1, jax.random.PRNGKey(seed))


def _factors(params, ranks):
    factors = []
    n_layers = len(params) // 2
    for l in range(n_layers - 1):
        u, v = model.truncated_svd_factors(params[2 * l], ranks[l])
        factors += [u, v]
    return factors


def test_init_shapes_and_stats():
    params = _init()
    assert len(params) == 2 * (len(LAYERS) - 1)
    for l in range(len(LAYERS) - 1):
        assert params[2 * l].shape == (LAYERS[l], LAYERS[l + 1])
        assert params[2 * l + 1].shape == (LAYERS[l + 1],)
        np.testing.assert_allclose(np.asarray(params[2 * l + 1]), 0.1)
    w0 = np.asarray(params[0])
    assert abs(w0.std() - 0.3) < 0.05


def test_forward_control_pallas_matches_jnp():
    params = _init(1)
    x = jax.random.normal(jax.random.PRNGKey(9), (7, LAYERS[0]), jnp.float32)
    a = model.forward_control(params, x, use_pallas=True)
    b = model.forward_control(params, x, use_pallas=False)
    assert a.shape == (7, LAYERS[-1])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_forward_ae_pallas_matches_jnp():
    params = _init(2)
    factors = _factors(params, [6, 5])
    x = jax.random.normal(jax.random.PRNGKey(3), (9, LAYERS[0]), jnp.float32)
    a = model.forward_ae(params, factors, x, use_pallas=True)
    b = model.forward_ae(params, factors, x, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_forward_ae_full_rank_matches_control():
    params = _init(4)
    full_ranks = [min(LAYERS[l], LAYERS[l + 1]) for l in range(len(LAYERS) - 2)]
    factors = _factors(params, full_ranks)
    x = jax.random.normal(jax.random.PRNGKey(5), (6, LAYERS[0]), jnp.float32)
    a = model.forward_ae(params, factors, x, use_pallas=False)
    b = model.forward_control(params, x, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_train_step_decreases_loss():
    params = _init(6)
    velocity = [jnp.zeros_like(p) for p in params]
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(7), (32, LAYERS[0]), jnp.float32)
    y = jax.random.randint(jax.random.PRNGKey(8), (32,), 0, LAYERS[-1])
    losses = []
    step = jax.jit(lambda p, v, k: model.train_step(
        p, v, x, y, k, 0.05, 0.5, dropout_p=0.0, l1_activation=0.0,
        l2_weight=0.0, max_norm=25.0))
    for i in range(30):
        key, sub = jax.random.split(key)
        params, velocity, loss = step(params, velocity, sub)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, f"loss did not decrease: {losses[0]} -> {losses[-1]}"


def test_train_step_max_norm_is_enforced():
    params = _init(10)
    velocity = [jnp.zeros_like(p) for p in params]
    x = jax.random.normal(jax.random.PRNGKey(1), (8, LAYERS[0]), jnp.float32)
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, LAYERS[-1])
    max_norm = 0.5
    new_p, _, _ = model.train_step(
        params, velocity, x, y, jax.random.PRNGKey(3), 0.5, 0.0,
        dropout_p=0.0, l1_activation=0.0, l2_weight=0.0, max_norm=max_norm)
    for l in range(len(LAYERS) - 1):
        norms = np.linalg.norm(np.asarray(new_p[2 * l]), axis=0)
        assert np.all(norms <= max_norm + 1e-4)


def test_l1_penalty_increases_loss():
    params = _init(11)
    x = jax.random.normal(jax.random.PRNGKey(4), (16, LAYERS[0]), jnp.float32)
    y = jax.random.randint(jax.random.PRNGKey(5), (16,), 0, LAYERS[-1])
    l0, _ = model.loss_fn(params, x, y, jax.random.PRNGKey(0), 0.0, 0.0)
    l1, _ = model.loss_fn(params, x, y, jax.random.PRNGKey(0), 0.0, 1e-2)
    assert float(l1) > float(l0)


def test_svd_factors_reconstruct():
    params = _init(12)
    w = params[0]
    u, v = model.truncated_svd_factors(w, min(w.shape))
    np.testing.assert_allclose(np.asarray(u @ v), np.asarray(w), rtol=1e-4, atol=1e-4)
    u2, v2 = model.truncated_svd_factors(w, 3)
    assert u2.shape == (w.shape[0], 3) and v2.shape == (3, w.shape[1])
