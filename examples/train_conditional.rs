//! End-to-end training driver (EXPERIMENTS.md §End-to-end): trains the
//! mnist-small network for the full schedule twice — once as the control,
//! once with the activation estimator *in the training loop* (the paper's
//! §3.5 setup with once-per-epoch SVD refresh) — logging the loss curve and
//! validation error per epoch, then reports final test errors and the FLOP
//! accounting of the deployed conditional engine.
//!
//! Run: `cargo run --release --example train_conditional [-- --epochs N]`

use condcomp::condcomp::CondMlp;
use condcomp::config::{EstimatorConfig, ExperimentProfile};
use condcomp::data::synth::build_dataset;
use condcomp::estimator::SignEstimatorSet;
use condcomp::nn::mlp::NoGater;
use condcomp::nn::trainer::evaluate_error;
use condcomp::nn::{Mlp, Trainer};
use condcomp::util::Pcg32;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let epochs = args
        .iter()
        .position(|a| a == "--epochs")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);

    let mut profile = ExperimentProfile::mnist_small();
    profile.train.epochs = epochs;
    let paper = ExperimentProfile::mnist_paper();
    let ranks = profile.scale_ranks(&[50, 35, 25], &paper);
    println!(
        "== end-to-end driver: {} {:?}, {} epochs, estimator ranks {ranks:?} ==",
        profile.name, profile.net.layers, epochs
    );

    // --- control run -----------------------------------------------------
    println!("\n-- control (dense) --");
    let mut data = build_dataset(&profile, profile.train.seed ^ 0xDA7A);
    let mut rng = Pcg32::new(profile.train.seed, 1);
    let mut control = Mlp::init(&profile.net, &mut rng);
    let mut trainer = Trainer::new(profile.train.clone());
    trainer.options.quiet = false;
    let control_hist = trainer.train(&mut control, &mut data, &mut NoGater);
    let control_test = evaluate_error(&control, &NoGater, &data.test);

    // --- estimator-in-the-loop run ----------------------------------------
    println!("\n-- conditional (estimator in the training loop) --");
    let mut data2 = build_dataset(&profile, profile.train.seed ^ 0xDA7A);
    let mut rng2 = Pcg32::new(profile.train.seed, 1);
    let mut net = Mlp::init(&profile.net, &mut rng2);
    let est_cfg = EstimatorConfig::fixed(&ranks);
    let mut gater = SignEstimatorSet::fit(&net, &est_cfg, 7);
    let ae_hist = trainer.train(&mut net, &mut data2, &mut gater);
    gater.refresh(&net);
    let ae_test = evaluate_error(&net, &gater, &data2.test);

    // --- loss curves -------------------------------------------------------
    println!("\nepoch   control-loss  control-valid   ae-loss  ae-valid");
    for e in 0..epochs {
        let c = &control_hist[e];
        let a = &ae_hist[e];
        println!(
            "{:>5}   {:>12.4}  {:>12.2}%  {:>8.4}  {:>7.2}%",
            e,
            c.train_loss,
            c.valid_error * 100.0,
            a.train_loss,
            a.valid_error * 100.0
        );
    }

    // --- deployment accounting ---------------------------------------------
    let cond = CondMlp::compile(&net, &gater);
    let x = data2.test.x.rows_slice(0, 128.min(data2.test.len()));
    let (_, flops) = cond.forward(&x);
    println!("\n== summary ==");
    println!("control test error:      {:.2}%", control_test * 100.0);
    println!("conditional test error:  {:.2}%  (ranks {ranks:?})", ae_test * 100.0);
    println!(
        "deployed FLOP speedup:   {:.2}×  (refresh count {}, SVD refreshes per epoch: 1)",
        flops.speedup(),
        gater.refresh_count
    );
    println!(
        "hidden-layer densities:  {:?}",
        flops.layers[..flops.layers.len() - 1]
            .iter()
            .map(|l| (l.density() * 1000.0).round() / 1000.0)
            .collect::<Vec<_>>()
    );
}
