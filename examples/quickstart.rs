//! Quickstart: train a small ReLU MLP on the synthetic digit corpus, attach
//! a low-rank activation-sign estimator, and compare the dense and
//! conditional forward paths — accuracy, agreement, and FLOPs saved.
//!
//! Run: `cargo run --release --example quickstart`

use condcomp::condcomp::CondMlp;
use condcomp::config::{EstimatorConfig, ExperimentProfile};
use condcomp::data::synth::build_dataset;
use condcomp::estimator::SignEstimatorSet;
use condcomp::nn::mlp::NoGater;
use condcomp::nn::trainer::evaluate_error;
use condcomp::nn::{Mlp, Trainer};
use condcomp::util::Pcg32;

fn main() {
    // 1. A profile: architecture + paper hyperparameters, at tiny scale.
    let mut profile = ExperimentProfile::mnist_tiny();
    profile.train.epochs = 5;
    println!("profile: {} {:?}", profile.name, profile.net.layers);

    // 2. Synthetic MNIST-like data (set MNIST_DIR to use real IDX files).
    let mut data = build_dataset(&profile, 42);
    println!(
        "data: {} train / {} valid / {} test",
        data.train.len(),
        data.valid.len(),
        data.test.len()
    );

    // 3. Train the control network.
    let mut rng = Pcg32::new(profile.train.seed, 1);
    let mut net = Mlp::init(&profile.net, &mut rng);
    let mut trainer = Trainer::new(profile.train.clone());
    trainer.options.quiet = false;
    trainer.train(&mut net, &mut data, &mut NoGater);
    let control_err = evaluate_error(&net, &NoGater, &data.test);
    println!("control test error: {:.2}%", control_err * 100.0);

    // 4. Fit the paper's estimator (rank-k truncated SVD per hidden layer)
    //    and compile the conditional engine.
    let ranks = vec![8, 6, 4];
    let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&ranks), 7);
    let cond = CondMlp::compile(&net, &est);

    // 5. Compare paths on the test set.
    let x = data.test.x.rows_slice(0, 64.min(data.test.len()));
    let (logits, flops) = cond.forward(&x);
    let dense_pred = net.predict(&x, &NoGater);
    let cond_pred = condcomp::nn::activations::argmax_rows(&logits);
    let agree = dense_pred.iter().zip(&cond_pred).filter(|(a, b)| a == b).count();
    println!(
        "conditional vs dense: {}/{} class agreement at ranks {ranks:?}",
        agree,
        x.rows()
    );
    println!(
        "FLOPs: dense {} vs conditional {:.0} → speedup {:.2}× (α = {:.3})",
        flops.total_dense(),
        flops.total_augmented(),
        flops.speedup(),
        flops.layers[0].density(),
    );
}
