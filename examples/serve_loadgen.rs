//! Serving demo + load generator: starts the coordinator on an ephemeral
//! port with a freshly trained model (span tracing on), then drives it with
//! concurrent clients issuing single-example predict requests in both
//! modes, and prints client-side latency percentiles, the server's own
//! p50/p99, and the top span costs per shard from the tracing plane.
//!
//! Run: `cargo run --release --example serve_loadgen`

use condcomp::config::{EstimatorConfig, ExperimentProfile};
use condcomp::coordinator::protocol::Mode;
use condcomp::coordinator::server::Client;
use condcomp::coordinator::{Backend, NativeBackend, RemoteBackend, RemoteOpts, Server, ServerConfig};
use condcomp::data::synth::build_dataset;
use condcomp::estimator::SignEstimatorSet;
use condcomp::nn::mlp::NoGater;
use condcomp::nn::{Mlp, Trainer};
use condcomp::util::stats::Summary;
use condcomp::util::Pcg32;
use std::sync::Arc;
use std::time::Instant;

const CLIENTS: usize = 4;
const REQUESTS_PER_CLIENT: usize = 50;

fn main() {
    // Train a quick model.
    let mut profile = ExperimentProfile::mnist_tiny();
    profile.train.epochs = 3;
    let mut data = build_dataset(&profile, 42);
    let mut rng = Pcg32::new(profile.train.seed, 1);
    let mut net = Mlp::init(&profile.net, &mut rng);
    Trainer::new(profile.train.clone()).train(&mut net, &mut data, &mut NoGater);

    let ranks = vec![8, 6, 4];
    let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&ranks), 7);
    let backend = Arc::new(NativeBackend::new(net, est, 64));
    let server = Server::start(
        backend.clone(),
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_wait: std::time::Duration::from_millis(2),
            trace: true, // span tracing + flight recorder for the demo
            ..ServerConfig::default() // shards: 0 → derived from the thread budget
        },
    )
    .expect("server start");
    let addr = server.local_addr;
    println!(
        "server on {addr} ({} batcher shard(s)); {CLIENTS} clients × {REQUESTS_PER_CLIENT} requests per mode",
        server.num_shards()
    );

    for mode in [Mode::Control, Mode::ConditionalAe] {
        let t0 = Instant::now();
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                std::thread::spawn(move || {
                    let mut client = Client::connect(&addr).expect("connect");
                    let mut rng = Pcg32::new(c as u64, 9);
                    let mut lat_us = Vec::new();
                    for _ in 0..REQUESTS_PER_CLIENT {
                        let x = condcomp::linalg::Mat::randn(1, 784, 0.5, &mut rng);
                        let t = Instant::now();
                        let resp = client.predict(x, mode).expect("predict");
                        assert!(resp.ok, "{:?}", resp.error);
                        lat_us.push(t.elapsed().as_secs_f64() * 1e6);
                    }
                    lat_us
                })
            })
            .collect();
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().unwrap());
        }
        let wall = t0.elapsed().as_secs_f64();
        let s = Summary::of(&all);
        println!(
            "mode {:<8}  {:>6.0} req/s   p50 {:>7.0}us  p95 {:>7.0}us  max {:>7.0}us",
            mode.as_str(),
            (CLIENTS * REQUESTS_PER_CLIENT) as f64 / wall,
            s.median,
            s.p95,
            s.max
        );
    }

    // Server-side metrics, with the thread-accounting gauges pulled out:
    // `threads_total` is the shared pool's size and `threads_leased` how
    // much of it the shard executors hold — with pool slicing the two are
    // equal, i.e. the server runs on exactly the configured budget with no
    // private pools and no parked threads.
    let mut client = Client::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    let payload = stats.payload.unwrap();
    // Kernel routing in production: one `layer<i>_kernel_<id>_batches`
    // counter per hidden layer per registered kernel, so the cost router's
    // decisions are observable from the wire (not just at startup).
    if let Some(counters) = payload.get("counters").and_then(|c| c.as_obj()) {
        println!("\nkernel routing (batches per layer per kernel):");
        for (name, v) in counters {
            if name.starts_with("layer") && name.contains("_kernel_") {
                println!("  {name}: {:.0}", v.as_f64().unwrap_or(0.0));
            }
        }
    }
    // Server-side latency distribution: the batcher's own predict series,
    // bucketed histograms with real percentiles (not just a mean).
    if let Some(lat) = payload.get("latency") {
        for series in ["predict", "predict_control", "predict_ae"] {
            if let Some(s) = lat.get(series) {
                let g = |k: &str| s.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
                println!(
                    "server {series:<16} count {:>5.0}  p50 {:>7.0}us  p99 {:>7.0}us  max {:>7.0}us",
                    g("count"),
                    g("p50_us"),
                    g("p99_us"),
                    g("max_us")
                );
            }
        }
    }
    // Span breakdown: tracing records one `shard<i>_span_<label>` series
    // per pipeline stage; rank each shard's spans by total time spent
    // (count × mean) and show the top 3.
    if let Some(lat) = payload.get("latency").and_then(|l| l.as_obj()) {
        for shard in 0..server.num_shards() {
            let prefix = format!("shard{shard}_span_");
            let mut spans: Vec<(&str, f64, f64)> = lat
                .iter()
                .filter(|(name, _)| name.starts_with(&prefix))
                .map(|(name, v)| {
                    let count = v.get("count").and_then(|x| x.as_f64()).unwrap_or(0.0);
                    let mean = v.get("mean_us").and_then(|x| x.as_f64()).unwrap_or(0.0);
                    (&name[prefix.len()..], count, count * mean)
                })
                .collect();
            spans.sort_by(|a, b| b.2.total_cmp(&a.2));
            print!("shard {shard} top spans:");
            for (label, count, total_us) in spans.iter().take(3) {
                print!("  {label} {:.0}us×{count:.0}", total_us / count.max(1.0));
            }
            println!();
        }
    }
    if let Some(gauges) = payload.get("gauges") {
        let total = gauges.get("threads_total").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let leased = gauges.get("threads_leased").and_then(|v| v.as_f64()).unwrap_or(0.0);
        println!("\nthreads: total {total:.0}, leased by shard executors {leased:.0}");
        for shard in 0..server.num_shards() {
            let width = gauges
                .get(&format!("shard{shard}_pool_threads"))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0);
            let lease = gauges
                .get(&format!("shard{shard}_lease_threads"))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0);
            println!("  shard {shard}: lease {lease:.0} (width {width:.0})");
        }
    }
    println!("\nserver metrics: {}", payload.to_string());
    let _ = client.shutdown();
    server.shutdown();

    // --- multi-process phase: coordinator over two worker replicas --------
    // The same deterministic backend serves behind two single-shard worker
    // servers; a coordinator verifies each through the `hello` handshake
    // (protocol version + model fingerprint) and routes batches by queue
    // depth × per-replica cost. The `replica<i>_` metric stripe mirrors the
    // `shard<i>_` scheme on the coordinator's registry.
    let workers: Vec<Server> = (0..2)
        .map(|_| {
            Server::start(
                backend.clone(),
                ServerConfig {
                    addr: "127.0.0.1:0".into(),
                    max_wait: std::time::Duration::from_millis(2),
                    shards: 1,
                    ..ServerConfig::default()
                },
            )
            .expect("worker start")
        })
        .collect();
    let addrs: Vec<String> = workers.iter().map(|w| w.local_addr.to_string()).collect();
    let expected = backend.model_fingerprint().unwrap_or_default();
    let remote = Arc::new(
        RemoteBackend::connect(&addrs, &expected, RemoteOpts::default())
            .expect("coordinator connect"),
    );
    let coord = Server::start(
        remote.clone() as Arc<dyn Backend>,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_wait: std::time::Duration::from_millis(2),
            shards: 2,
            ..ServerConfig::default()
        },
    )
    .expect("coordinator start");
    remote.attach_metrics(coord.metrics.clone());
    let caddr = coord.local_addr;
    println!(
        "\ncoordinator on {caddr} over {} worker replica(s) (model {expected})",
        remote.num_replicas()
    );
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let mut client = Client::connect(&caddr).expect("connect");
                let mut rng = Pcg32::new(c as u64, 11);
                for i in 0..REQUESTS_PER_CLIENT {
                    let mode = if i % 2 == 0 { Mode::ConditionalAe } else { Mode::Control };
                    let x = condcomp::linalg::Mat::randn(1, 784, 0.5, &mut rng);
                    let resp = client.predict(x, mode).expect("predict");
                    assert!(resp.ok, "{:?}", resp.error);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // One health interval so the exported replica gauges are fresh.
    std::thread::sleep(std::time::Duration::from_millis(600));
    let mut client = Client::connect(&caddr).unwrap();
    let stats = client.stats().unwrap();
    let payload = stats.payload.unwrap();
    if let Some(gauges) = payload.get("gauges").and_then(|g| g.as_obj()) {
        let g = |k: &str| gauges.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        println!("replicas: {:.0} total, {:.0} healthy", g("replicas"), g("replicas_healthy"));
        for i in 0..remote.num_replicas() {
            println!(
                "  replica {i}: healthy {:.0}  depth {:.0}  cost {:.3}",
                g(&format!("replica{i}_healthy")),
                g(&format!("replica{i}_depth")),
                g(&format!("replica{i}_cost")),
            );
        }
    }
    if let Some(counters) = payload.get("counters").and_then(|c| c.as_obj()) {
        println!("replica routing (batches per replica):");
        for (name, v) in counters {
            if name.starts_with("replica") {
                println!("  {name}: {:.0}", v.as_f64().unwrap_or(0.0));
            }
        }
    }
    let _ = client.shutdown();
    coord.shutdown();
    drop(remote);
    for w in workers {
        w.shutdown();
    }
}
