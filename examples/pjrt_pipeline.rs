//! The full three-layer pipeline: Rust coordinator (L3) drives the
//! AOT-compiled JAX train_step (L2) containing the Pallas kernels (L1),
//! with the once-per-epoch SVD refresh computed in Rust.
//!
//! Requires `make artifacts` to have been run (the only Python step).
//!
//! Run: `cargo run --release --example pjrt_pipeline`

use condcomp::config::ExperimentProfile;
use condcomp::coordinator::TrainingScheduler;
use condcomp::data::synth::build_dataset;
use condcomp::nn::Mlp;
use condcomp::runtime::{Engine, ModelRuntime};
use condcomp::util::Pcg32;
use std::path::Path;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let engine = Arc::new(Engine::load(dir)?);
    println!("pjrt platform: {}", engine.platform());

    // mnist-tiny profile must match the artifact shapes exactly.
    let mut profile = ExperimentProfile::mnist_tiny();
    profile.net.layers = vec![784, 64, 48, 32, 10];
    profile.train.epochs = 3;
    profile.train.batch_size = 16; // artifact batch
    profile.n_train = 480;
    profile.n_valid = 120;
    profile.n_test = 120;

    let mut data = build_dataset(&profile, 42);
    let mut rng = Pcg32::new(profile.train.seed, 1);
    let net = Mlp::init(&profile.net, &mut rng);
    let mut rt = ModelRuntime::from_mlp(engine, "mnist-tiny", &net)?;
    println!(
        "bound profile mnist-tiny: layers {:?}, batch {}, estimator ranks {:?}",
        rt.layers, rt.batch, rt.ranks
    );

    let mut sched = TrainingScheduler::new(profile.train.clone());
    sched.quiet = false;
    let history = sched.train(&mut rt, &mut data)?;

    println!("\nepoch  loss     valid(control)  valid(estimator)");
    for h in &history {
        println!(
            "{:>5}  {:>7.4}  {:>13.2}%  {:>15.2}%",
            h.epoch,
            h.train_loss,
            h.valid_error * 100.0,
            h.valid_error_ae * 100.0
        );
    }
    println!(
        "\ntrained {} steps through the L2 train_step artifact; \
         SVD refresh ran in Rust at every epoch boundary.",
        rt.step_count
    );
    Ok(())
}
