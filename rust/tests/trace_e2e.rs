//! End-to-end test for the serving observability plane: a real `Server`
//! started with tracing on, real TCP clients, then assertions over the
//! `stats` snapshot (histogram percentiles + per-layer estimator gauges)
//! and the `trace` op's flight-recorder dump.
//!
//! The acceptance criterion pinned here: every latency series exposes
//! p50/p95/p99, the per-layer `alpha_predicted` / `alpha_achieved` /
//! `sign_agreement` gauges are live, and each flight record's span timings
//! sum (within slack) to the observed batch latency.

use condcomp::config::{EstimatorConfig, ExperimentProfile};
use condcomp::coordinator::protocol::Mode;
use condcomp::coordinator::{Client, NativeBackend, RouterKind, Server, ServerConfig};
use condcomp::data::synth::build_dataset;
use condcomp::estimator::SignEstimatorSet;
use condcomp::linalg::Mat;
use condcomp::nn::mlp::NoGater;
use condcomp::nn::{Mlp, Trainer};
use condcomp::util::Pcg32;
use std::sync::Arc;

fn trained_backend() -> NativeBackend {
    let mut profile = ExperimentProfile::mnist_tiny();
    profile.net.layers = vec![784, 32, 24, 10];
    profile.train.epochs = 1;
    profile.n_train = 200;
    profile.n_valid = 50;
    profile.n_test = 50;
    let mut data = build_dataset(&profile, 42);
    let mut rng = Pcg32::new(profile.train.seed, 1);
    let mut net = Mlp::init(&profile.net, &mut rng);
    let mut trainer = Trainer::new(profile.train.clone());
    trainer.options.quiet = true;
    trainer.train(&mut net, &mut data, &mut NoGater);
    let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&[8, 6]), 7);
    NativeBackend::new(net, est, 32)
}

#[test]
fn traced_server_exports_percentiles_gauges_and_flight_records() {
    let server = Server::start(
        Arc::new(trained_backend()),
        ServerConfig {
            shards: 2,
            router: RouterKind::RoundRobin,
            trace: true,
            trace_ring: 32,
            ..ServerConfig::default()
        },
    )
    .expect("server start");
    assert!(condcomp::trace::enabled(), "--trace turns the flag on process-wide");

    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut rng = Pcg32::seeded(0x7ACE);
    for i in 0..12usize {
        let mode = if i % 3 == 0 { Mode::Control } else { Mode::ConditionalAe };
        let rows = 1 + (i % 2);
        let x = Mat::randn(rows, 784, 0.5, &mut rng);
        let resp = client.predict(x, mode).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.classes.len(), rows);
    }

    // --- stats: histogram percentiles on every latency series -----------
    let stats = client.stats().unwrap();
    assert!(stats.ok);
    let payload = stats.payload.expect("stats payload");
    let latency = payload.get("latency").and_then(|l| l.as_obj()).expect("latency map");
    assert!(!latency.is_empty());
    for (name, series) in latency {
        for key in ["count", "mean_us", "min_us", "max_us", "p50_us", "p95_us", "p99_us"] {
            assert!(series.get(key).is_some(), "series {name} missing {key}");
        }
        let p50 = series.get("p50_us").unwrap().as_f64().unwrap();
        let p99 = series.get("p99_us").unwrap().as_f64().unwrap();
        let max = series.get("max_us").unwrap().as_f64().unwrap();
        assert!(p50 <= p99 && p99 <= max, "series {name}: {p50} / {p99} / {max}");
    }
    assert!(latency.contains_key("predict"), "batcher predict series exported");
    assert!(
        latency.keys().any(|k| k.starts_with("span_") || k.contains("_span_")),
        "span series exported when tracing is on: {:?}",
        latency.keys().collect::<Vec<_>>()
    );

    // --- stats: per-layer estimator gauges -------------------------------
    let gauges = payload.get("gauges").and_then(|g| g.as_obj()).expect("gauges map");
    assert_eq!(gauges.get("trace_enabled").and_then(|v| v.as_f64()), Some(1.0));
    assert_eq!(gauges.get("trace_ring").and_then(|v| v.as_f64()), Some(32.0));
    // Two conditional layers in the 784-32-24-10 net.
    for layer in 0..2 {
        for gauge in ["alpha_predicted", "alpha_achieved", "sign_agreement"] {
            let key = format!("layer{layer}_{gauge}");
            let v = gauges
                .get(&key)
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| panic!("gauge {key} missing"));
            assert!((0.0..=1.0).contains(&v), "{key} = {v}");
        }
    }
    let skipped = gauges
        .get("flops_skipped_frac")
        .and_then(|v| v.as_f64())
        .expect("flops_skipped_frac gauge");
    assert!((0.0..=1.0).contains(&skipped), "flops_skipped_frac = {skipped}");

    // --- trace op: flight-recorder dump ----------------------------------
    let dump = client.trace().unwrap();
    assert!(dump.ok, "{:?}", dump.error);
    let payload = dump.payload.expect("trace payload");
    assert_eq!(payload.get("ring_capacity").and_then(|v| v.as_f64()), Some(32.0));
    let recorded = payload.get("recorded").and_then(|v| v.as_f64()).unwrap();
    assert!(recorded >= 1.0, "at least one batch traced");
    let records = payload.get("records").and_then(|r| r.as_arr()).expect("records");
    assert!(!records.is_empty() && records.len() <= 32);

    // Seq numbers are claimed just before the ring insert, so two shards
    // can interleave; distinctness (not strict order) is the invariant.
    let mut seqs: Vec<u64> =
        records.iter().map(|r| r.get("seq").and_then(|v| v.as_f64()).unwrap() as u64).collect();
    seqs.sort_unstable();
    seqs.dedup();
    assert_eq!(seqs.len(), records.len(), "record seq numbers are unique");
    let mut saw_ae = false;
    for r in records {
        let shard = r.get("shard").and_then(|v| v.as_f64()).unwrap();
        assert!(shard < 2.0, "shard id within --shards 2");
        assert!(r.get("rows").and_then(|v| v.as_f64()).unwrap() >= 1.0);
        assert!(r.get("items").and_then(|v| v.as_f64()).unwrap() >= 1.0);
        let total_us = r.get("total_us").and_then(|v| v.as_f64()).unwrap();
        assert!(total_us > 0.0);

        let spans = r.get("spans").and_then(|s| s.as_arr()).expect("spans");
        assert!(!spans.is_empty(), "traced batch carries spans");
        // The top-level pipeline spans (prep → predict → reply) are
        // disjoint sub-intervals of the batch window: their sum must not
        // exceed the observed batch latency (small slack for clock
        // granularity) and must account for the bulk of it (estimator and
        // kernel spans nest *inside* predict, so they are excluded).
        let mut top_sum = 0.0;
        for s in spans {
            let name = s.get("name").and_then(|v| v.as_str()).unwrap();
            let us = s.get("us").and_then(|v| v.as_f64()).unwrap();
            assert!(us >= 0.0);
            if matches!(name, "prep" | "predict" | "reply") {
                top_sum += us;
            }
        }
        assert!(
            top_sum <= total_us * 1.05 + 50.0,
            "span sum {top_sum}us exceeds batch total {total_us}us"
        );
        assert!(
            top_sum >= total_us * 0.3 - 100.0,
            "span sum {top_sum}us does not account for batch total {total_us}us"
        );

        let mode = r.get("mode").and_then(|v| v.as_str()).unwrap();
        if mode == "ae" {
            saw_ae = true;
            let names: Vec<&str> =
                spans.iter().filter_map(|s| s.get("name").and_then(|v| v.as_str())).collect();
            assert!(names.contains(&"estimator"), "ae batch spans {names:?}");
            assert!(
                names.iter().any(|n| n.starts_with("kernel_")),
                "ae batch records its kernel spans: {names:?}"
            );
            let kernels = r.get("kernels").and_then(|k| k.as_arr()).unwrap();
            assert!(!kernels.is_empty(), "ae batch records the kernels routed");
        }
    }
    assert!(saw_ae, "conditional batches reached the recorder");

    server.shutdown();
}
