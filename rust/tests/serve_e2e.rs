//! End-to-end loopback tests for the sharded serving coordinator: real
//! `Server` on an ephemeral port, real TCP `Client`s, a small MLP trained
//! in-test.
//!
//! What is pinned here and nowhere else:
//!
//! - per-request outputs are **bit-identical** between a 1-shard server and
//!   N-shard servers (both routers), in both Exact (control) and
//!   Conditional modes, through the wire — the serving-level counterpart of
//!   the kernels' thread-count invariance;
//! - `shutdown` drains in-flight requests: every request accepted before
//!   the shutdown op gets its response (no dropped replies), and requests
//!   arriving after close get an explicit rejection, not silence;
//! - a synthetic-cost-model `PolicyTable` installs identical per-layer
//!   dispatch thresholds on every shard (regression guard against
//!   per-shard policy drift);
//! - an N-shard server's executors lease exactly the configured thread
//!   budget from the shared pool (no private pools, no parked threads),
//!   observable from the wire via `threads_total` / `threads_leased`.

use condcomp::autotune::{
    model_fingerprint, Autotuner, CostModel, MachineProfile, PROFILE_SCHEMA_VERSION,
};
use condcomp::condcomp::KernelId;
use condcomp::config::{EstimatorConfig, ExperimentProfile, NetConfig};
use condcomp::coordinator::protocol::{Mode, Request, Response};
use condcomp::coordinator::server::Client;
use condcomp::coordinator::{Backend, NativeBackend, RouterKind, Server, ServerConfig};
use condcomp::data::synth::build_dataset;
use condcomp::estimator::SignEstimatorSet;
use condcomp::exec::ExecCtx;
use condcomp::linalg::Mat;
use condcomp::nn::mlp::NoGater;
use condcomp::nn::{Mlp, Trainer};
use condcomp::parallel::ThreadPool;
use condcomp::util::Pcg32;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

/// Train a small MLP in-test (1 epoch over a shrunken synthetic corpus) and
/// fit its estimators. Deterministic: every call returns bit-identical
/// weights and factors, so two servers built from two calls serve the same
/// function.
fn trained_backend() -> NativeBackend {
    let mut profile = ExperimentProfile::mnist_tiny();
    profile.net.layers = vec![784, 32, 24, 10];
    profile.train.epochs = 1;
    profile.n_train = 200;
    profile.n_valid = 50;
    profile.n_test = 50;
    let mut data = build_dataset(&profile, 42);
    let mut rng = Pcg32::new(profile.train.seed, 1);
    let mut net = Mlp::init(&profile.net, &mut rng);
    let mut trainer = Trainer::new(profile.train.clone());
    trainer.options.quiet = true;
    trainer.train(&mut net, &mut data, &mut NoGater);
    let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&[8, 6]), 7);
    NativeBackend::new(net, est, 32)
}

fn start_trained(shards: usize, router: RouterKind) -> Server {
    Server::start(
        Arc::new(trained_backend()),
        ServerConfig { shards, router, ..ServerConfig::default() },
    )
    .expect("server start")
}

fn logits_bits(resp: &Response) -> Vec<u32> {
    resp.logits
        .as_ref()
        .expect("predict response carries logits")
        .as_slice()
        .iter()
        .map(|v| v.to_bits())
        .collect()
}

/// The acceptance criterion: outputs bit-identical between `--shards 1` and
/// `--shards N`, both modes, both routers, through the wire.
#[test]
fn sharded_outputs_bit_identical_to_single_shard() {
    let single = start_trained(1, RouterKind::RoundRobin);
    let rr3 = start_trained(3, RouterKind::RoundRobin);
    let ld2 = start_trained(2, RouterKind::LeastDepth);
    assert_eq!(single.num_shards(), 1);
    assert_eq!(rr3.num_shards(), 3);
    assert_eq!(ld2.num_shards(), 2);

    let mut c_single = Client::connect(&single.local_addr).unwrap();
    let mut c_rr3 = Client::connect(&rr3.local_addr).unwrap();
    let mut c_ld2 = Client::connect(&ld2.local_addr).unwrap();

    let mut rng = Pcg32::seeded(0xE2E);
    for mode in [Mode::Control, Mode::ConditionalAe] {
        // 8 sequential requests: round-robin walks every shard of the
        // 3-shard server at least twice; each request is its own batch on
        // every server (lockstep client), so batch composition is equal.
        for req in 0..8 {
            let rows = 1 + (req % 2);
            let x = Mat::randn(rows, 784, 0.5, &mut rng);
            let a = c_single.predict(x.clone(), mode).unwrap();
            let b = c_rr3.predict(x.clone(), mode).unwrap();
            let c = c_ld2.predict(x, mode).unwrap();
            assert!(a.ok && b.ok && c.ok, "{:?} / {:?} / {:?}", a.error, b.error, c.error);
            assert_eq!(a.classes, b.classes, "mode {mode:?} req {req}: classes drifted");
            assert_eq!(a.classes, c.classes);
            assert_eq!(a.classes.len(), rows);
            let bits = logits_bits(&a);
            assert_eq!(
                bits,
                logits_bits(&b),
                "mode {mode:?} req {req}: 3-shard logits differ from single-shard"
            );
            assert_eq!(
                bits,
                logits_bits(&c),
                "mode {mode:?} req {req}: least-depth logits differ from single-shard"
            );
        }
    }

    // Every shard of the 3-shard server actually executed work.
    for shard in 0..3 {
        assert!(
            rr3.metrics.shard_counter(shard, "batches") > 0,
            "shard {shard} never drained a batch"
        );
    }
    single.shutdown();
    rr3.shutdown();
    ld2.shutdown();
}

#[test]
fn ping_stats_and_concurrent_predicts_across_shards() {
    let server = start_trained(3, RouterKind::RoundRobin);
    let addr = server.local_addr;

    let mut client = Client::connect(&addr).unwrap();
    let pong = client.ping().unwrap();
    assert!(pong.ok);

    // Concurrent clients in both modes: everything answered, nothing
    // miscounted.
    let handles: Vec<_> = (0..6)
        .map(|c| {
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                let mut rng = Pcg32::new(c as u64, 5);
                for i in 0..5 {
                    let mode = if i % 2 == 0 { Mode::ConditionalAe } else { Mode::Control };
                    let x = Mat::randn(1, 784, 0.5, &mut rng);
                    let resp = client.predict(x, mode).unwrap();
                    assert!(resp.ok, "{:?}", resp.error);
                    assert_eq!(resp.classes.len(), 1);
                    assert!(resp.classes[0] < 10);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(server.metrics.counter("predictions"), 30);

    // Stats over the wire expose the shard topology and per-shard activity.
    let stats = client.stats().unwrap();
    assert!(stats.ok);
    let payload = stats.payload.unwrap();
    let gauges = payload.get("gauges").expect("gauges in snapshot");
    assert_eq!(gauges.get("shards").and_then(|v| v.as_f64()), Some(3.0));
    for shard in 0..3 {
        assert!(
            gauges.get(&format!("shard{shard}_pool_threads")).is_some(),
            "missing shard {shard} pool gauge"
        );
    }
    let shard_batches: u64 = (0..3).map(|s| server.metrics.shard_counter(s, "batches")).sum();
    assert_eq!(shard_batches, server.metrics.counter("batches"));
    server.shutdown();
}

/// Pipelined predicts followed by a shutdown op on the same connection:
/// every request accepted before the shutdown must be answered (the drain
/// guarantee), and a request pushed after close gets an explicit rejection.
#[test]
fn shutdown_drains_in_flight_requests_without_dropping_responses() {
    let mut rng = Pcg32::seeded(0xD12A);
    let net = Mlp::init(
        &NetConfig { layers: vec![24, 32, 24, 8], weight_sigma: 0.3, bias_init: 0.1 },
        &mut rng,
    );
    let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&[8, 6]), 3);
    let server = Server::start(
        Arc::new(NativeBackend::new(net, est, 32)),
        ServerConfig {
            // A long window so pipelined items are still queued when the
            // shutdown op lands — the drain path, not the fast path.
            max_wait: Duration::from_millis(250),
            shards: 2,
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let metrics = server.metrics.clone();
    let addr = server.local_addr;

    // A second connection, opened before shutdown, to probe post-close
    // rejection afterwards.
    let mut late_client = Client::connect(&addr).unwrap();

    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    const IN_FLIGHT: u64 = 8;
    let mut lines = String::new();
    for id in 1..=IN_FLIGHT {
        let x = Mat::randn(1, 24, 0.5, &mut rng);
        lines.push_str(&Request::Predict { id, mode: Mode::ConditionalAe, x }.to_json_line());
        lines.push('\n');
    }
    lines.push_str(&Request::Shutdown { id: 99 }.to_json_line());
    lines.push('\n');
    // One write: all 8 predicts are queued before the handler reaches the
    // shutdown op (lines are processed in order on the connection).
    writer.write_all(lines.as_bytes()).unwrap();
    writer.flush().unwrap();

    let mut got_ids = Vec::new();
    for _ in 0..=IN_FLIGHT {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(!line.trim().is_empty(), "connection closed before all responses arrived");
        let resp = Response::parse(&line).unwrap();
        assert!(resp.ok, "id {}: {:?}", resp.id, resp.error);
        if resp.id != 99 {
            assert_eq!(resp.classes.len(), 1, "predict response fanned back out");
        }
        got_ids.push(resp.id);
    }
    got_ids.sort_unstable();
    let mut want: Vec<u64> = (1..=IN_FLIGHT).collect();
    want.push(99);
    assert_eq!(got_ids, want, "every in-flight request answered exactly once");

    // Join the server: executors drained, acceptor stopped.
    server.shutdown();
    assert_eq!(metrics.counter("predictions"), IN_FLIGHT);
    assert_eq!(metrics.counter("errors"), 0);

    // The batcher is now definitively closed; a straggler on a still-open
    // connection gets a rejection response, not silence.
    let x = Mat::randn(1, 24, 0.5, &mut rng);
    let resp = late_client.predict(x, Mode::ConditionalAe).unwrap();
    assert!(!resp.ok, "post-shutdown predict must be rejected");
    assert!(
        resp.error.as_deref().unwrap_or("").contains("shutting down"),
        "unexpected rejection: {:?}",
        resp.error
    );
    assert_eq!(metrics.counter("rejected"), 1);
}

// ---------------------------------------------------------------------------
// PolicyTable × sharding: dispatch thresholds identical on every shard
// ---------------------------------------------------------------------------

/// Synthetic cost surface, exactly linear in α: wide-input layers pay 8×
/// per masked FLOP (α* = 0.125), others 2× (α* = 0.5).
struct SyntheticCost;

fn synthetic_ratio(d: usize, h: usize) -> f64 {
    if d > h {
        8.0
    } else {
        2.0
    }
}

impl CostModel for SyntheticCost {
    fn seconds(&mut self, kernel: KernelId, n: usize, d: usize, h: usize, alpha: f64) -> f64 {
        let dense = 2.0 * (n * d * h) as f64 * 1e-10;
        if kernel == KernelId::MASKED {
            alpha * synthetic_ratio(d, h) * dense
        } else {
            // dense and dense_packed at parity: ties route to plain dense,
            // so the classic α* values (1/2, 1/8) hold exactly.
            dense
        }
    }
}

fn synthetic_backend() -> (NativeBackend, [f64; 2]) {
    let layer_sizes = [16usize, 32, 16, 6];
    let mut rng = Pcg32::seeded(0x90CA);
    let net = Mlp::init(
        &NetConfig { layers: layer_sizes.to_vec(), weight_sigma: 0.4, bias_init: 0.1 },
        &mut rng,
    );
    let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&[6, 5]), 3);
    let backend = NativeBackend::new(net, est, 32);

    // Fit the per-layer table from the synthetic surface and install it the
    // same way `serve` installs a persisted machine profile.
    let shapes = Autotuner::hidden_shapes(&layer_sizes);
    let fitted = Autotuner::default().fit_shapes(&shapes, &mut SyntheticCost, None);
    let profile = MachineProfile {
        version: PROFILE_SCHEMA_VERSION,
        fingerprint: model_fingerprint(&layer_sizes),
        hardware: "unknown".into(),
        threads: 0,
        budget_ms: 0,
        kernels: vec!["dense".into(), "dense_packed".into(), "masked".into()],
        layers: fitted,
    };
    backend.apply_profile(&profile, "<synthetic>").expect("profile installs");
    (backend, [0.5, 0.125])
}

/// Backend-level drift guard: with the synthetic table installed, the
/// shard-executor entry point must make the same per-layer dispatch
/// decisions on any pool slice — any thread count, any lease width, cold
/// or warm arena. Logit bits AND the reported FLOP speedup must match —
/// the speedup counts computed dot products, so it flips if any shard
/// picks the other kernel.
#[test]
fn synthetic_policy_table_dispatches_identically_on_every_pool_slice() {
    let (backend, want_alpha) = synthetic_backend();
    let thresholds = backend.dispatch_thresholds().expect("table installed");
    assert!((thresholds[0] - want_alpha[0]).abs() < 1e-9, "{thresholds:?}");
    assert!((thresholds[1] - want_alpha[1]).abs() < 1e-9, "{thresholds:?}");

    let mut rng = Pcg32::seeded(0x51AB);
    let x = Mat::randn(6, 16, 1.0, &mut rng);
    let (want_logits, want_speedup) = backend.predict(&x, Mode::ConditionalAe).unwrap();
    let want_speedup = want_speedup.unwrap();
    for threads in [1usize, 2, 5] {
        let pool = ThreadPool::new(threads);
        for grant in [0usize, 1, 2, 5] {
            let mut ctx = ExecCtx::over(pool.lease(grant));
            for round in 0..2 {
                let (logits, speedup) =
                    backend.predict_ctx(&x, Mode::ConditionalAe, &mut ctx).unwrap();
                assert_eq!(
                    logits.as_slice(),
                    want_logits.as_slice(),
                    "threads {threads} lease {grant} round {round}: logits drifted"
                );
                assert_eq!(
                    speedup.unwrap().to_bits(),
                    want_speedup.to_bits(),
                    "threads {threads} lease {grant} round {round}: speedup (≡ kernel choice) drifted"
                );
                ctx.put_buf(logits.into_vec());
            }
        }
        assert_eq!(pool.leased(), 0, "every ctx returned its lease");
    }
}

/// The acceptance criterion for pool slicing: with `--shards N > 1`, the
/// server's worker threads are exactly the configured budget — every shard
/// executor holds a lease carved from the shared pool, the leases cover the
/// budget, and nothing else spawns. Checkable from the wire through the new
/// `threads_total` / `threads_leased` / `shard<i>_lease_threads` stats.
#[test]
fn leased_server_spawns_exactly_the_thread_budget() {
    // A pool this test owns (leaked: executor threads hold leases on it for
    // the server's lifetime), so lease accounting cannot race concurrent
    // tests that lease from the process-global pool.
    let pool: &'static ThreadPool = Box::leak(Box::new(ThreadPool::new(7)));
    let server = Server::start_on(
        Arc::new(trained_backend()),
        ServerConfig { shards: 3, ..ServerConfig::default() },
        pool,
    )
    .expect("server start");
    assert_eq!(server.num_shards(), 3);
    assert_eq!(server.metrics.gauge("threads_total"), Some(7.0));
    assert_eq!(
        server.metrics.gauge("threads_leased"),
        Some(7.0),
        "executor leases must cover the whole budget"
    );
    let per_shard: Vec<usize> = (0..3)
        .map(|s| server.metrics.shard_gauge(s, "lease_threads").expect("lease gauge") as usize)
        .collect();
    assert_eq!(per_shard.iter().sum::<usize>(), 7, "leases sum to the budget: {per_shard:?}");
    assert!(per_shard.iter().all(|&g| g >= 1), "every shard got a slice: {per_shard:?}");
    assert_eq!(pool.leased(), 7, "pool-side accounting agrees");

    // The accounting is visible over the wire, and traffic still flows.
    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut rng = Pcg32::seeded(0xB4D6);
    for mode in [Mode::Control, Mode::ConditionalAe] {
        for _ in 0..3 {
            let x = Mat::randn(1, 784, 0.5, &mut rng);
            assert!(client.predict(x, mode).unwrap().ok);
        }
    }
    let stats = client.stats().unwrap();
    let gauges = stats.payload.unwrap();
    let gauges = gauges.get("gauges").expect("gauges in snapshot");
    assert_eq!(gauges.get("threads_total").and_then(|v| v.as_f64()), Some(7.0));
    assert_eq!(gauges.get("threads_leased").and_then(|v| v.as_f64()), Some(7.0));
    for shard in 0..3 {
        assert!(
            gauges.get(&format!("shard{shard}_lease_threads")).is_some(),
            "shard {shard} lease gauge missing from the wire"
        );
    }
    server.shutdown();
    assert_eq!(pool.leased(), 0, "shutdown returns every lease to the pool");
}

/// The kernel-registry acceptance criterion, end to end through the wire:
/// serve outputs are reproducible for any `--kernels` allow-list, any
/// shard count, and any lease width — scoped to each kernel's declared
/// [`EquivalenceTier`]. Three parts:
///
/// - allow-lists that swap `dense` ↔ `dense_packed` are bit-identical
///   *unconditionally* (both declare `BitExact`; packing is a memory-layout
///   change);
/// - for any fixed allow-list, outputs are bit-identical across shard
///   counts (each server pins the same policy table, so routing is
///   deterministic wherever a batch lands) — this holds for tolerance-tier
///   kernels too, because every kernel is individually deterministic;
/// - a tolerance-tier allow-list (`dense_simd`) forms its own equivalence
///   class: bitwise self-consistent across shard counts, and numerically
///   close to the bit-exact dense class without promising cross-kernel bit
///   identity.
#[test]
fn kernel_allowlists_preserve_bit_identity_end_to_end() {
    use condcomp::condcomp::DispatchPolicy;

    // Pin a dense-regime policy so the cost router deterministically picks
    // the (only) dense-work kernel in each server's allow-list.
    let dense_regime = DispatchPolicy::with_cost_ratio(1e9);
    let make = |allow: &[KernelId], shards: usize| {
        let backend = trained_backend();
        backend.set_allowed_kernels(allow).expect("allow-list installs");
        backend.set_policy_table(condcomp::condcomp::PolicyTable::uniform(
            dense_regime.clone(),
            2,
        ));
        Server::start(
            Arc::new(backend),
            ServerConfig { shards, ..ServerConfig::default() },
        )
        .expect("server start")
    };

    // dense-only vs packed-only vs both, at different shard counts. All
    // five must agree bitwise in both modes: the dense-work kernels are
    // bit-identical, and the masked kernel never wins under the pinned
    // dense-regime table.
    let servers = vec![
        make(&[KernelId::DENSE, KernelId::MASKED], 1),
        make(&[KernelId::DENSE], 2),
        make(&[KernelId::DENSE_PACKED], 1),
        make(&[KernelId::DENSE_PACKED, KernelId::MASKED], 3),
        make(&[KernelId::DENSE, KernelId::DENSE_PACKED, KernelId::MASKED], 2),
    ];
    let mut clients: Vec<Client> =
        servers.iter().map(|s| Client::connect(&s.local_addr).unwrap()).collect();
    let mut rng = Pcg32::seeded(0xA110);
    for mode in [Mode::Control, Mode::ConditionalAe] {
        for req in 0..4 {
            let x = Mat::randn(1 + (req % 2), 784, 0.5, &mut rng);
            let mut first: Option<Vec<u32>> = None;
            for (i, client) in clients.iter_mut().enumerate() {
                let resp = client.predict(x.clone(), mode).unwrap();
                assert!(resp.ok, "server {i}: {:?}", resp.error);
                let bits = logits_bits(&resp);
                match &first {
                    None => first = Some(bits),
                    Some(want) => assert_eq!(
                        &bits, want,
                        "mode {mode:?} req {req}: allow-list variant {i} diverged"
                    ),
                }
            }
        }
    }

    // The masked regime is its own equivalence class: masked-only equals a
    // full allow-list pinned to always-masked, across shard counts.
    let masked_regime = DispatchPolicy::with_cost_ratio(1e-9);
    let make_masked = |allow: &[KernelId], shards: usize| {
        let backend = trained_backend();
        backend.set_allowed_kernels(allow).expect("allow-list installs");
        backend.set_policy_table(condcomp::condcomp::PolicyTable::uniform(
            masked_regime.clone(),
            2,
        ));
        Server::start(
            Arc::new(backend),
            ServerConfig { shards, ..ServerConfig::default() },
        )
        .expect("server start")
    };
    let masked_servers = vec![
        make_masked(&[KernelId::MASKED], 1),
        make_masked(&[KernelId::DENSE, KernelId::DENSE_PACKED, KernelId::MASKED], 3),
    ];
    let mut masked_clients: Vec<Client> = masked_servers
        .iter()
        .map(|s| Client::connect(&s.local_addr).unwrap())
        .collect();
    for req in 0..4 {
        let x = Mat::randn(1, 784, 0.5, &mut rng);
        let a = masked_clients[0].predict(x.clone(), Mode::ConditionalAe).unwrap();
        let b = masked_clients[1].predict(x, Mode::ConditionalAe).unwrap();
        assert!(a.ok && b.ok);
        assert_eq!(logits_bits(&a), logits_bits(&b), "masked regime req {req} diverged");
    }

    // The SIMD dense kernel is its own *tolerance-tier* class: two
    // `dense_simd`-only servers at different shard counts agree bitwise
    // (the kernel is deterministic and its results are independent of row
    // sharding), and both stay numerically close to the bit-exact dense
    // class — without any claim of cross-kernel bit identity.
    let simd_servers = vec![make(&[KernelId::DENSE_SIMD], 1), make(&[KernelId::DENSE_SIMD], 3)];
    let mut simd_clients: Vec<Client> = simd_servers
        .iter()
        .map(|s| Client::connect(&s.local_addr).unwrap())
        .collect();
    for mode in [Mode::Control, Mode::ConditionalAe] {
        for req in 0..3 {
            let x = Mat::randn(1 + (req % 2), 784, 0.5, &mut rng);
            let reference = clients[0].predict(x.clone(), mode).unwrap();
            let a = simd_clients[0].predict(x.clone(), mode).unwrap();
            let b = simd_clients[1].predict(x, mode).unwrap();
            assert!(reference.ok && a.ok && b.ok);
            assert_eq!(
                logits_bits(&a),
                logits_bits(&b),
                "mode {mode:?} req {req}: simd class diverged across shard counts"
            );
            // Numeric closeness vs the dense class is only asserted in
            // Control mode: under ConditionalAe a pre-activation sitting
            // inside the tolerance band can flip an estimator mask bit,
            // which the tier explicitly licenses but which makes the
            // downstream drift unbounded in principle.
            if mode == Mode::Control {
                let want = reference.logits.as_ref().expect("reference logits");
                let got = a.logits.as_ref().expect("simd logits");
                let drift = got.max_abs_diff(want);
                assert!(
                    drift < 1e-3,
                    "req {req}: simd class drifted {drift} from the dense class"
                );
            }
        }
    }

    for s in servers {
        s.shutdown();
    }
    for s in masked_servers {
        s.shutdown();
    }
    for s in simd_servers {
        s.shutdown();
    }
}

/// Server-level drift guard: a 3-shard server built on the synthetic table
/// exports the fitted α* gauges once (not per shard), and identical inputs
/// produce bit-identical responses whichever shard executes them.
#[test]
fn synthetic_policy_table_is_shared_by_every_shard() {
    let (backend, want_alpha) = synthetic_backend();
    let server = Server::start(
        Arc::new(backend),
        ServerConfig { shards: 3, ..ServerConfig::default() },
    )
    .unwrap();
    assert_eq!(server.metrics.gauge("dispatch_layers"), Some(2.0));
    let l0 = server.metrics.gauge("dispatch_alpha_star_l0").unwrap();
    let l1 = server.metrics.gauge("dispatch_alpha_star_l1").unwrap();
    assert!((l0 - want_alpha[0]).abs() < 1e-9);
    assert!((l1 - want_alpha[1]).abs() < 1e-9);

    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut rng = Pcg32::seeded(0x3A2D);
    let x = Mat::randn(2, 16, 1.0, &mut rng);
    // Six sequential sends of the same input: round-robin lands the request
    // on every shard twice; identical table ⇒ identical kernel choice ⇒
    // identical bits.
    let mut first: Option<Vec<u32>> = None;
    for send in 0..6 {
        let resp = client.predict(x.clone(), Mode::ConditionalAe).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        let bits = logits_bits(&resp);
        match &first {
            None => first = Some(bits),
            Some(want) => assert_eq!(&bits, want, "send {send} diverged across shards"),
        }
    }
    for shard in 0..3 {
        assert!(
            server.metrics.shard_counter(shard, "batches") > 0,
            "shard {shard} saw no traffic"
        );
    }
    server.shutdown();
}
