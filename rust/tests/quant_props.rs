//! Property suite for the int8 kernel class (`dense_i8`, `masked_i8`) and
//! the quantized sign estimator — the issue's test-coverage satellite:
//!
//! - symmetric per-row quantization round-trips within half a quantization
//!   step everywhere (and exactly reproduces all-zero rows);
//! - the int8 forward is bit-identical across ISA paths (native caps vs
//!   forced scalar), thread counts {1, 2, 7}, and lease widths — the i32
//!   accumulator is exact, so there is no tier to tolerate, only equality;
//! - the full-rank quantized estimator's mask agrees with the float
//!   estimator's at or above the sign-agreement floor outside the near-zero
//!   band (the contract the `sign-agree` tier enforces at dispatch time).

use condcomp::condcomp::{MaskedLayer, QUANT_SIGN_BAND_REL, QUANT_TIER_AGREEMENT_BP};
use condcomp::estimator::SignEstimator;
use condcomp::exec::ExecCtx;
use condcomp::linalg::{quantize_row_into, Mat, QuantizedLayer, QuantizedMat, SimdCaps};
use condcomp::parallel::ThreadPool;
use condcomp::util::proptest::property;
use condcomp::util::Pcg32;

/// Quantize → dequantize lands within half a step of the original: the
/// symmetric per-row scheme's defining bound, `|x − q·s| ≤ s/2` with
/// `s = max_abs/127`, held by every entry of every row.
#[test]
fn quantize_round_trip_stays_within_half_a_step() {
    property("per-row round-trip bound", 64, |rng| {
        let cols = rng.index(200) + 1;
        let scale_mag = rng.uniform_in(0.01, 10.0);
        let src: Vec<f32> = (0..cols).map(|_| rng.uniform_in(-scale_mag, scale_mag)).collect();
        let mut q = vec![0i8; cols];
        let s = quantize_row_into(&src, &mut q);
        let max_abs = src.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!((s - max_abs / 127.0).abs() <= max_abs * 1e-6, "scale {s} vs {max_abs}/127");
        for (&x, &code) in src.iter().zip(&q) {
            let err = (x - code as f32 * s).abs();
            assert!(
                err <= s * 0.5 + max_abs * 1e-6,
                "round-trip error {err} exceeds half-step {s}/2 (x={x} code={code})"
            );
        }
        // The row's extreme hits a full-scale code exactly.
        assert!(q.iter().any(|&c| c == 127 || c == -127), "{q:?}");
    });
    // All-zero (and empty) rows round-trip exactly with scale 0.
    let mut q = vec![7i8; 5];
    assert_eq!(quantize_row_into(&[0.0; 5], &mut q), 0.0);
    assert!(q.iter().all(|&c| c == 0));
    let mut empty: [i8; 0] = [];
    assert_eq!(quantize_row_into(&[], &mut empty), 0.0);
}

/// The matrix-level round-trip: every row of `dequantize()` is within half
/// that row's step of the original, and all-zero rows come back exact.
#[test]
fn quantized_mat_dequantizes_within_per_row_bounds() {
    property("matrix round-trip bound", 24, |rng| {
        let rows = rng.index(12) + 1;
        let cols = rng.index(40) + 1;
        let zero_row = rng.index(rows);
        let m = Mat::from_fn(rows, cols, |r, _| {
            if r == zero_row {
                0.0
            } else {
                rng.uniform_in(-2.0, 2.0)
            }
        });
        let q = QuantizedMat::quantize(&m);
        assert_eq!(q.shape(), m.shape());
        assert_eq!(q.scale(zero_row), 0.0, "all-zero row has scale 0");
        let back = q.dequantize();
        for r in 0..rows {
            let bound = q.scale(r) * 0.5 + 1e-6;
            for c in 0..cols {
                let err = (m.row(r)[c] - back.row(r)[c]).abs();
                assert!(err <= bound, "[{r},{c}] err {err} > {bound}");
            }
        }
    });
}

/// The int8 forward's cross-ISA / cross-parallelism contract: exact i32
/// accumulation makes every path — native caps vs forced scalar, serial vs
/// any thread count {1, 2, 7} × lease width — produce identical bits and
/// identical dot-product counts, for both the dense_i8 (`compute_all`) and
/// masked_i8 gating modes.
#[test]
fn i8_forward_is_bit_identical_across_isa_threads_and_leases() {
    let mut rng = Pcg32::seeded(0x18B1);
    let (n, d, h) = (19, 133, 23);
    let x = Mat::randn(n, d, 0.6, &mut rng);
    let w = Mat::randn(d, h, 0.4, &mut rng);
    let bias: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.3, 0.3)).collect();
    let layer = MaskedLayer::new(&w, &bias);
    let quant = QuantizedLayer::new(&layer.wt, &layer.bias);
    let mask = Mat::from_fn(n, h, |_, _| if rng.bernoulli(0.4) { 1.0 } else { 0.0 });
    for compute_all in [true, false] {
        // Serial native-caps run: the reference bits.
        let mut want = Mat::full(n, h, f32::NAN);
        let want_count = quant.forward_i8_into(SimdCaps::get(), &x, &mask, &mut want, compute_all);
        let want_bits: Vec<u32> = want.as_slice().iter().map(|v| v.to_bits()).collect();
        for caps in [SimdCaps::get(), SimdCaps::scalar()] {
            for threads in [1usize, 2, 7] {
                let pool = ThreadPool::new(threads);
                for lease_width in [1usize, threads] {
                    let mut ctx = ExecCtx::over(pool.lease(lease_width));
                    let mut out = Mat::full(n, h, f32::NAN);
                    let count =
                        quant.forward_i8_ctx(caps, &x, &mask, &mut out, compute_all, &mut ctx);
                    assert_eq!(count, want_count, "compute_all={compute_all}");
                    let bits: Vec<u32> = out.as_slice().iter().map(|v| v.to_bits()).collect();
                    assert_eq!(
                        bits, want_bits,
                        "int8 path diverged: caps={caps:?} threads={threads} \
                         lease={lease_width} compute_all={compute_all}"
                    );
                }
                assert_eq!(pool.leased(), 0);
            }
        }
    }
}

/// The sign-agreement contract the `sign-agree` tier promises: at full
/// estimator rank, the quantized estimator's mask agrees with the float
/// estimator's on at least `QUANT_TIER_AGREEMENT_BP` basis points of the
/// units whose float pre-activation clears the near-zero band (inside the
/// band a sign flip costs a near-zero activation — exactly the error class
/// quantization is licensed to make).
#[test]
fn quantized_estimator_holds_the_sign_agreement_floor_outside_the_band() {
    let floor = QUANT_TIER_AGREEMENT_BP as f64 / 10_000.0;
    property("quantized estimator sign agreement", 12, |rng| {
        let n = rng.index(24) + 4;
        let d = rng.index(60) + 8;
        let h = rng.index(40) + 8;
        let rank = d.min(h);
        let x = Mat::randn(n, d, 0.8, rng);
        let w = Mat::randn(d, h, 0.5, rng);
        let layer_bias: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.2, 0.2)).collect();
        let mut est = SignEstimator::fit(&w, &layer_bias, rank, 0.0);
        let z_float = est.estimate_preact(&x);
        let mask_float = est.mask(&x);
        est.quantize_factors();
        let mask_quant = est.mask(&x);
        let band = z_float.as_slice().iter().fold(0.0f32, |m, &v| m.max(v.abs()))
            * QUANT_SIGN_BAND_REL;
        let (mut eligible, mut agree) = (0usize, 0usize);
        for ((&z, &mf), &mq) in z_float
            .as_slice()
            .iter()
            .zip(mask_float.as_slice())
            .zip(mask_quant.as_slice())
        {
            if (z - est.bias).abs() <= band {
                continue;
            }
            eligible += 1;
            if mf == mq {
                agree += 1;
            }
        }
        if eligible > 0 {
            let fraction = agree as f64 / eligible as f64;
            assert!(
                fraction >= floor,
                "sign agreement {fraction:.4} below floor {floor} \
                 ({agree}/{eligible} outside band {band})"
            );
        }
    });
}
