//! Equivalence-tier property suite for the SIMD kernels (`dense_simd`,
//! `masked_simd`) — the issue's test-coverage satellite:
//!
//! - both SIMD registry kernels match their serial oracles within the
//!   declared ULP bound at thread counts {1, 2, 7} × lease widths {1, N},
//!   under both ISA paths (native caps and the forced-scalar fallback);
//! - the two caps paths are bit-identical to each other (so
//!   `CONDCOMP_FORCE_SCALAR=1` can change speed, never results);
//! - sign agreement for the estimator path: a mask thresholded from
//!   SIMD-computed low-rank pre-activations agrees with the scalar
//!   estimator's mask everywhere the pre-activation clears the
//!   tolerance-tier boundary band.

use condcomp::condcomp::registry::{
    ComputeKernel, DenseSimdKernel, LayerOperands, MaskedSimdKernel, SIMD_TIER_ULPS,
};
use condcomp::condcomp::{relu_gate, EquivalenceTier, MaskedLayer};
use condcomp::estimator::SignEstimator;
use condcomp::exec::ExecCtx;
use condcomp::linalg::{matmul_into_simd, Mat, SimdCaps};
use condcomp::nn::mlp::add_bias;
use condcomp::parallel::ThreadPool;
use condcomp::util::proptest::property;
use condcomp::util::ulp::within_tolerance;
use condcomp::util::Pcg32;

/// The serial oracle for dense-work kernels: blocked scalar GEMM + bias +
/// ReLU + mask gate.
fn dense_oracle(x: &Mat, w: &Mat, bias: &[f32], mask: &Mat) -> Mat {
    let mut out = Mat::zeros(x.rows(), w.cols());
    condcomp::linalg::matmul_into(x, w, &mut out);
    add_bias(&mut out, bias);
    relu_gate(&mut out, mask);
    out
}

/// Both SIMD kernels, against their serial oracles, within the declared ULP
/// bound — at threads {1, 2, 7} × lease widths {1, N}, under the native and
/// the forced-scalar caps (the "both ISA paths" acceptance criterion; on
/// AVX2/NEON hardware the native arm exercises the vector path, and the CI
/// `CONDCOMP_FORCE_SCALAR=1` run pins the scalar arm for the whole suite).
#[test]
fn simd_kernels_match_serial_oracles_within_declared_tier() {
    for caps in [SimdCaps::get(), SimdCaps::scalar()] {
        let kernels: Vec<Box<dyn ComputeKernel>> = vec![
            Box::new(DenseSimdKernel::new(caps)),
            Box::new(MaskedSimdKernel::new(caps)),
        ];
        for kernel in &kernels {
            assert_eq!(kernel.tier(), EquivalenceTier::Tolerance(SIMD_TIER_ULPS));
        }
        for threads in [1usize, 2, 7] {
            let pool = ThreadPool::new(threads);
            property("simd kernels within tier of oracles", 6, |rng| {
                let n = rng.index(30) + 1;
                let d = rng.index(150) + 1;
                let h = rng.index(30) + 1;
                let x = Mat::randn(n, d, 0.6, rng);
                let w = Mat::randn(d, h, 0.4, rng);
                let bias: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.3, 0.3)).collect();
                let layer = MaskedLayer::new(&w, &bias);
                let alpha = rng.uniform();
                let mask =
                    Mat::from_fn(n, h, |_, _| if rng.bernoulli(alpha) { 1.0 } else { 0.0 });
                let ops = LayerOperands::new(&w, &layer);
                let dense_want = dense_oracle(&x, &w, &bias, &mask);
                let (masked_want, masked_count) = layer.forward_masked(&x, &mask);
                for lease_width in [1usize, threads] {
                    for kernel in &kernels {
                        let mut ctx = ExecCtx::over(pool.lease(lease_width));
                        let mut out = Mat::full(n, h, f32::NAN);
                        let computed = kernel.run(&ops, &x, &mask, &mut ctx, &mut out);
                        // Only float-class SIMD kernels run here (the int8
                        // kernels have their own suite in `quant_props.rs`).
                        let (want, want_count) = match kernel.id().work() {
                            condcomp::condcomp::WorkModel::Dense => (&dense_want, n * h),
                            condcomp::condcomp::WorkModel::AlphaScaled => {
                                (&masked_want, masked_count)
                            }
                            other => panic!("unexpected work model {other:?} in SIMD suite"),
                        };
                        assert_eq!(computed, want_count, "kernel {}", kernel.id());
                        if let Err(msg) = kernel.tier().check(out.as_slice(), want.as_slice())
                        {
                            panic!(
                                "kernel {} threads {threads} lease {lease_width} \
                                 ({n}x{d}x{h}): {msg}",
                                kernel.id()
                            );
                        }
                    }
                }
            });
            assert_eq!(pool.leased(), 0);
        }
    }
}

/// The cross-ISA contract behind the `CONDCOMP_FORCE_SCALAR` escape hatch:
/// a SIMD kernel's native-caps run and forced-scalar run produce identical
/// bits (the scalar mirror reproduces the vector paths' fused accumulator
/// structure exactly), for every thread count.
#[test]
fn forced_scalar_path_reproduces_native_path_bitwise() {
    let mut rng = Pcg32::seeded(0x51AD7);
    let (n, d, h) = (23, 130, 17);
    let x = Mat::randn(n, d, 0.6, &mut rng);
    let w = Mat::randn(d, h, 0.4, &mut rng);
    let bias: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.3, 0.3)).collect();
    let layer = MaskedLayer::new(&w, &bias);
    let mask = Mat::from_fn(n, h, |_, _| if rng.bernoulli(0.4) { 1.0 } else { 0.0 });
    let ops = LayerOperands::new(&w, &layer);
    for threads in [1usize, 3] {
        let pool = ThreadPool::new(threads);
        for make in [
            (|caps| Box::new(DenseSimdKernel::new(caps)) as Box<dyn ComputeKernel>)
                as fn(SimdCaps) -> Box<dyn ComputeKernel>,
            |caps| Box::new(MaskedSimdKernel::new(caps)) as Box<dyn ComputeKernel>,
        ] {
            let native = make(SimdCaps::get());
            let scalar = make(SimdCaps::scalar());
            let mut out_native = Mat::full(n, h, f32::NAN);
            let mut out_scalar = Mat::full(n, h, f32::NAN);
            let mut ctx = ExecCtx::full(&pool);
            let count_native = native.run(&ops, &x, &mask, &mut ctx, &mut out_native);
            let count_scalar = scalar.run(&ops, &x, &mask, &mut ctx, &mut out_scalar);
            assert_eq!(count_native, count_scalar);
            let native_bits: Vec<u32> =
                out_native.as_slice().iter().map(|v| v.to_bits()).collect();
            let scalar_bits: Vec<u32> =
                out_scalar.as_slice().iter().map(|v| v.to_bits()).collect();
            assert_eq!(
                native_bits,
                scalar_bits,
                "kernel {} threads {threads}: ISA paths diverged",
                native.id()
            );
        }
    }
}

/// Sign agreement for the estimator path (the paper's actual requirement —
/// the estimator only needs the *sign* of the low-rank pre-activation):
/// computing `x·U·V + b_layer` through the SIMD GEMM and thresholding at
/// the decision bias produces the same mask as the scalar estimator at
/// every unit whose pre-activation clears the tolerance-tier boundary band.
/// Inside the band (|z − bias| below the SIMD tier's absolute floor) the
/// two may legitimately disagree — that is exactly what `Tolerance(..)`
/// licenses — and the test asserts such units are the *only* disagreements.
#[test]
fn simd_estimated_masks_agree_with_scalar_masks_outside_the_tier_band() {
    // The band matches the tolerance check's absolute floor: values this
    // close to the threshold can land on either side under a reordered
    // accumulation that is still within the declared tier.
    let band = SIMD_TIER_ULPS as f32 * f32::EPSILON;
    for caps in [SimdCaps::get(), SimdCaps::scalar()] {
        property("SIMD estimator masks agree outside the band", 24, |rng| {
            let n = rng.index(12) + 1;
            let d = rng.index(60) + 4;
            let h = rng.index(40) + 4;
            let rank = rng.index(d.min(h).min(8)) + 1;
            let x = Mat::randn(n, d, 0.8, rng);
            let w = Mat::randn(d, h, 0.5, rng);
            let layer_bias: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.2, 0.2)).collect();
            let est = SignEstimator::fit(&w, &layer_bias, rank, 0.0);
            // Scalar reference: the estimator's own pre-activation + mask.
            let z_scalar = est.estimate_preact(&x);
            let mask_scalar = est.mask(&x);
            // SIMD path: the same two low-rank GEMMs through the vectorized
            // kernel, then the same bias add and threshold.
            let mut xu = Mat::full(n, est.factors.u.cols(), f32::NAN);
            matmul_into_simd(caps, &x, &est.factors.u, &mut xu);
            let mut z_simd = Mat::full(n, h, f32::NAN);
            matmul_into_simd(caps, &xu, &est.factors.v, &mut z_simd);
            add_bias(&mut z_simd, &layer_bias);
            let bias = est.bias;
            for (i, (&zs, &zv)) in z_scalar
                .as_slice()
                .iter()
                .zip(z_simd.as_slice())
                .enumerate()
            {
                assert!(
                    within_tolerance(zv, zs, SIMD_TIER_ULPS),
                    "pre-activation [{i}] outside tier: simd={zv} scalar={zs}"
                );
                let mask_simd = if zv - bias > 0.0 { 1.0 } else { 0.0 };
                let agrees = mask_simd == mask_scalar.as_slice()[i];
                if (zs - bias).abs() > band {
                    assert!(
                        agrees,
                        "sign flip outside the boundary band at [{i}]: \
                         z_scalar={zs} z_simd={zv} bias={bias}"
                    );
                }
            }
        });
    }
}
