//! Property tests for the pool-slicing API (`ThreadPool::lease`).
//!
//! What the serving coordinator leans on, pinned under randomized
//! concurrent schedules:
//!
//! - concurrent `lease(k)` grants never exceed the pool size, from any
//!   number of racing threads;
//! - leases release on scope exit — including when a job panics inside the
//!   leased scope (the reservation is returned during unwind, never
//!   leaked);
//! - nested lease requests (from inside a pool job) degrade to inline
//!   execution instead of deadlocking;
//! - `partition_threads`-driven leases cover the compute budget exactly at
//!   shard counts {1, 2, 7} — the arithmetic the N-shard server relies on
//!   to spawn precisely the configured thread budget.

use condcomp::parallel::{partition_threads, ThreadPool};
use condcomp::util::proptest::property;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Shard counts every property runs at (mirrors the thread-count grid the
/// parallel kernels are pinned at).
const SHARD_GRID: [usize; 3] = [1, 2, 7];

#[test]
fn concurrent_grants_never_exceed_the_pool_size() {
    for &pool_size in &[1usize, 2, 5, 8] {
        let pool = ThreadPool::new(pool_size);
        let over_granted = AtomicBool::new(false);
        let grants_seen = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for t in 0..6usize {
                let pool = &pool;
                let over_granted = &over_granted;
                let grants_seen = &grants_seen;
                s.spawn(move || {
                    for i in 0..40usize {
                        let want = (t + i) % (pool_size + 2);
                        let lease = pool.lease(want);
                        // Each grant is bounded by the request, and the
                        // pool-wide outstanding total is bounded by the
                        // pool size at every observable instant.
                        if lease.granted() > want || pool.leased() > pool_size {
                            over_granted.store(true, Ordering::Relaxed);
                        }
                        grants_seen.fetch_add(lease.granted(), Ordering::Relaxed);
                        // Use the lease so the reservation is held across
                        // real work, not just instantaneous.
                        let mut data = vec![0u32; 64];
                        lease.scope(|sc| {
                            for chunk in data.chunks_mut(16) {
                                sc.spawn(move || {
                                    for v in chunk.iter_mut() {
                                        *v += 1;
                                    }
                                });
                            }
                        });
                        assert!(data.iter().all(|&v| v == 1));
                    }
                });
            }
        });
        assert!(
            !over_granted.load(Ordering::Relaxed),
            "a grant exceeded the request or the pool size ({pool_size})"
        );
        assert!(grants_seen.load(Ordering::Relaxed) > 0, "some leases were granted");
        assert_eq!(pool.leased(), 0, "all leases returned after the race");
    }
}

#[test]
fn leases_release_on_scope_exit_including_panic_in_job() {
    let pool = ThreadPool::new(4);
    // Normal exit.
    {
        let lease = pool.lease(3);
        assert_eq!(lease.granted(), 3);
        assert_eq!(pool.leased(), 3);
        lease.scope(|s| s.spawn(|| {}));
        assert_eq!(pool.leased(), 3, "still held until the lease drops");
    }
    assert_eq!(pool.leased(), 0);

    // Panic inside a leased job: the scope re-raises, the unwind drops the
    // lease, and the reservation is returned — not leaked.
    for round in 0..3 {
        let result = catch_unwind(AssertUnwindSafe(|| {
            let lease = pool.lease(2);
            assert_eq!(lease.granted(), 2);
            lease.scope(|s| {
                s.spawn(|| panic!("leased job panic"));
                s.spawn(|| { /* sibling job still runs */ });
            });
        }));
        assert!(result.is_err(), "round {round}: job panic must surface");
        assert_eq!(pool.leased(), 0, "round {round}: reservation leaked");
    }
    // Capacity is fully recovered.
    assert_eq!(pool.lease(4).granted(), 4);
}

#[test]
fn nested_lease_requests_degrade_inline_not_deadlock() {
    let pool = ThreadPool::new(2);
    let checked = AtomicBool::new(false);
    pool.scope(|s| {
        let pool = &pool;
        let checked = &checked;
        s.spawn(move || {
            let worker = std::thread::current().id();
            let lease = pool.lease(2);
            assert_eq!(lease.granted(), 0, "nested lease must not reserve");
            assert_eq!(lease.threads(), 1);
            assert!(lease.is_inline());
            // The nested scope completes inline on this worker — if it
            // enqueued instead, this single-job spawn could deadlock the
            // 2-worker pool under load.
            let mut ran_on = None;
            lease.scope(|s2| {
                let slot = &mut ran_on;
                s2.spawn(move || *slot = Some(std::thread::current().id()));
            });
            assert_eq!(ran_on, Some(worker), "nested scope escaped the worker");
            checked.store(true, Ordering::Release);
        });
    });
    assert!(checked.load(Ordering::Acquire));
    assert_eq!(pool.leased(), 0);
}

/// The server's startup arithmetic: partition the budget, lease each slice
/// — the grants must cover the budget exactly (no slice short-changed, no
/// over-grant) for any budget at shard counts {1, 2, 7}.
#[test]
fn partition_driven_leases_cover_the_budget_exactly() {
    for &shards in &SHARD_GRID {
        property(&format!("partition leases cover budget at {shards} shards"), 12, |rng| {
            let budget = rng.index(9) + 1; // 1..=9
            let pool = ThreadPool::new(budget);
            let slices = partition_threads(budget, shards);
            assert_eq!(slices.len(), shards);
            let leases: Vec<_> = slices.iter().map(|&k| pool.lease(k)).collect();
            let granted: usize = leases.iter().map(|l| l.granted()).sum();
            assert_eq!(
                granted, budget,
                "budget {budget}, shards {shards}, slices {slices:?}"
            );
            assert_eq!(pool.leased(), budget);
            // Exhausted: one more request degrades inline instead of
            // oversubscribing.
            let extra = pool.lease(budget);
            assert_eq!(extra.granted(), 0);
            assert_eq!(extra.threads(), 1);
            drop(extra);
            // Releasing one slice frees exactly that slice; releasing the
            // rest empties the counter.
            let mut leases = leases;
            let first = slices[0];
            drop(leases.remove(0));
            assert_eq!(pool.leased(), budget - first);
            drop(leases);
            assert_eq!(pool.leased(), 0);
        });
    }
}
