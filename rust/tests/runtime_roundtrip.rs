//! Integration: the AOT bridge end to end.
//!
//! Loads the real artifacts (built by `make artifacts`), executes them via
//! PJRT, and checks the numerics against the pure-Rust engine on identical
//! weights: same logits for the control path, same logits for the
//! estimator-augmented path (Rust masked-GEMM vs Pallas-in-HLO), and a
//! decreasing loss for the train-step artifact.
//!
//! Every test is `#[ignore]`d by default: they are environment-bound (the
//! artifacts come from a Python/JAX build step, and execution needs the real
//! `xla` crate swapped in for the vendored API stub). Run with
//! `cargo test --test runtime_roundtrip -- --ignored` in a full environment.

use condcomp::config::NetConfig;
use condcomp::coordinator::scheduler::TrainingScheduler;
use condcomp::config::ExperimentProfile;
use condcomp::data::synth::build_dataset;
use condcomp::estimator::SignEstimatorSet;
use condcomp::linalg::Mat;
use condcomp::nn::mlp::NoGater;
use condcomp::nn::Mlp;
use condcomp::runtime::{Engine, ModelRuntime};
use condcomp::util::Pcg32;
use std::path::Path;
use std::sync::Arc;

const PROFILE: &str = "mnist-tiny";
const LAYERS: &[usize] = &[784, 64, 48, 32, 10];
const RANKS: &[usize] = &[8, 6, 4];
const BATCH: usize = 16;

fn artifacts_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine() -> Arc<Engine> {
    let dir = artifacts_dir();
    assert!(
        dir.join("manifest.json").exists(),
        "artifacts not built — run `make artifacts` first"
    );
    Arc::new(Engine::load(&dir).expect("engine load"))
}

fn tiny_net(seed: u64) -> Mlp {
    let mut rng = Pcg32::seeded(seed);
    Mlp::init(
        &NetConfig { layers: LAYERS.to_vec(), weight_sigma: 0.05, bias_init: 0.5 },
        &mut rng,
    )
}

#[test]
#[ignore = "environment-bound: requires PJRT artifacts (`make artifacts`, a Python/JAX build step) and the real xla crate in place of the vendored stub"]
fn control_forward_matches_native_engine() {
    let engine = engine();
    let net = tiny_net(11);
    let rt = ModelRuntime::from_mlp(engine, PROFILE, &net).expect("bind runtime");
    let mut rng = Pcg32::seeded(3);
    // Full batch and partial batch (exercises padding).
    for rows in [BATCH, 5] {
        let x = Mat::randn(rows, LAYERS[0], 0.5, &mut rng);
        let pjrt = rt.forward(&x).expect("pjrt forward");
        let native = net.logits(&x, &NoGater);
        let diff = pjrt.max_abs_diff(&native);
        assert!(diff < 2e-3, "rows={rows}: PJRT vs native logits diff {diff}");
    }
}

#[test]
#[ignore = "environment-bound: requires PJRT artifacts (`make artifacts`, a Python/JAX build step) and the real xla crate in place of the vendored stub"]
fn ae_forward_matches_native_masked_gemm() {
    let engine = engine();
    let net = tiny_net(13);
    let mut rt = ModelRuntime::from_mlp(engine, PROFILE, &net).expect("bind runtime");
    rt.refresh_factors().expect("refresh");

    // Native path with the *same* factorization ranks.
    let cfg = condcomp::config::EstimatorConfig::fixed(RANKS);
    let est = SignEstimatorSet::fit(&net, &cfg, 5);
    let cond = condcomp::condcomp::CondMlp::compile(&net, &est);

    let mut rng = Pcg32::seeded(5);
    let x = Mat::randn(BATCH, LAYERS[0], 0.5, &mut rng);
    let pjrt = rt.forward_ae(&x).expect("pjrt ae forward");
    let (native, _flops) = cond.forward(&x);
    // Two SVD implementations (Jacobi vs LAPACK) can disagree on near-zero
    // pre-activations; compare with a modest tolerance plus a sign check on
    // the big entries.
    let diff = pjrt.max_abs_diff(&native);
    assert!(
        diff < 5e-2,
        "PJRT(ae) vs native masked-GEMM logits diff {diff}"
    );
    // Class decisions must agree on a strong-margin batch.
    let pa = condcomp::nn::activations::argmax_rows(&pjrt);
    let pb = condcomp::nn::activations::argmax_rows(&native);
    let agree = pa.iter().zip(&pb).filter(|(a, b)| a == b).count();
    assert!(agree >= BATCH - 1, "class agreement {agree}/{BATCH}");
}

#[test]
#[ignore = "environment-bound: requires PJRT artifacts (`make artifacts`, a Python/JAX build step) and the real xla crate in place of the vendored stub"]
fn train_step_reduces_loss_via_pjrt() {
    let engine = engine();
    let net = tiny_net(17);
    let mut rt = ModelRuntime::from_mlp(engine, PROFILE, &net).expect("bind runtime");

    let mut rng = Pcg32::seeded(23);
    let x = Mat::randn(BATCH, LAYERS[0], 0.5, &mut rng);
    let y: Vec<usize> = (0..BATCH).map(|_| rng.index(10)).collect();
    let mut losses = Vec::new();
    for _ in 0..20 {
        let loss = rt.train_step(&x, &y, 0.05, 0.5).expect("train step");
        assert!(loss.is_finite());
        losses.push(loss);
    }
    assert!(
        losses[19] < losses[0],
        "loss should fall when overfitting one batch: {losses:?}"
    );
    // Weights must actually move on the host copy too.
    let moved = rt.weights[0].max_abs_diff(&net.weights[0]);
    assert!(moved > 0.0, "host weights not updated");
}

#[test]
#[ignore = "environment-bound: requires PJRT artifacts (`make artifacts`, a Python/JAX build step) and the real xla crate in place of the vendored stub"]
fn scheduler_trains_end_to_end_via_pjrt() {
    let engine = engine();
    let mut profile = ExperimentProfile::mnist_tiny();
    profile.net.layers = LAYERS.to_vec();
    profile.train.epochs = 2;
    profile.train.batch_size = BATCH;
    profile.n_train = 320;
    profile.n_valid = 80;
    profile.n_test = 80;
    let mut data = build_dataset(&profile, 31);

    let mut rng = Pcg32::seeded(profile.train.seed);
    let net = Mlp::init(&profile.net, &mut rng);
    let mut rt = ModelRuntime::from_mlp(engine, PROFILE, &net).expect("bind runtime");
    let sched = TrainingScheduler::new(profile.train.clone());
    let history = sched.train(&mut rt, &mut data).expect("train");
    assert_eq!(history.len(), 2);
    let last = history.last().unwrap();
    assert!(last.train_loss.is_finite());
    // Both artifact eval paths produce sane error rates.
    assert!(last.valid_error <= 0.95 && last.valid_error >= 0.0);
    assert!(last.valid_error_ae <= 0.95 && last.valid_error_ae >= 0.0);
}

#[test]
#[ignore = "environment-bound: requires PJRT artifacts (`make artifacts`, a Python/JAX build step) and the real xla crate in place of the vendored stub"]
fn engine_caches_executables() {
    let engine = engine();
    let net = tiny_net(29);
    let rt = ModelRuntime::from_mlp(engine.clone(), PROFILE, &net).expect("bind");
    let mut rng = Pcg32::seeded(1);
    let x = Mat::randn(2, LAYERS[0], 0.5, &mut rng);
    let _ = rt.forward(&x).unwrap();
    let before = engine.cached_count();
    let _ = rt.forward(&x).unwrap();
    assert_eq!(engine.cached_count(), before, "no recompilation on 2nd call");
}
