//! Property tests for the sharded dynamic batcher.
//!
//! The serving invariants the coordinator leans on, pinned under randomized
//! concurrent schedules at shard counts {1, 2, 7}:
//!
//! - no request is lost or duplicated across shards, even when pushes race
//!   with `close` (rejected pushes hand the item back — the
//!   close-then-push fix);
//! - `max_batch` / `max_wait` hold per shard;
//! - depth accounting stays consistent with what was pushed and drained.

use condcomp::coordinator::protocol::{Mode, Response};
use condcomp::coordinator::sharded::{RouterKind, ShardedBatcher};
use condcomp::coordinator::{BatchItem, PushRejection};
use condcomp::linalg::Mat;
use condcomp::util::proptest::property;
use std::collections::BTreeSet;
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Shard counts every property runs at (mirrors the thread-count grid the
/// parallel kernels are pinned at).
const SHARD_GRID: [usize; 3] = [1, 2, 7];

fn item(id: u64, rows: usize) -> BatchItem {
    // Reply receivers are dropped: these properties exercise queueing, not
    // response fan-out, and `send` on a closed channel is already ignored
    // by the server.
    let (tx, _rx) = channel::<Response>();
    BatchItem {
        id,
        mode: Mode::Control,
        x: Mat::zeros(rows, 2),
        enqueued: Instant::now(),
        reply: tx,
    }
}

/// Like [`item`] but keeping the reply receiver — for properties that
/// assert the batcher *answers* (deadline sheds), not just queues.
fn item_with_rx(id: u64, rows: usize) -> (BatchItem, Receiver<Response>) {
    let (tx, rx) = channel::<Response>();
    (
        BatchItem {
            id,
            mode: Mode::Control,
            x: Mat::zeros(rows, 2),
            enqueued: Instant::now(),
            reply: tx,
        },
        rx,
    )
}

/// Drain every shard until it reports done, collecting item ids. Must be
/// called with the batcher closed or about to close.
fn spawn_drainers(
    b: &Arc<ShardedBatcher>,
    drained: &Arc<Mutex<Vec<u64>>>,
) -> Vec<std::thread::JoinHandle<()>> {
    (0..b.num_shards())
        .map(|shard| {
            let b = b.clone();
            let drained = drained.clone();
            std::thread::spawn(move || {
                while let Some(batch) = b.next_batch(shard) {
                    let mut sink = drained.lock().unwrap();
                    for it in batch {
                        sink.push(it.id);
                    }
                }
            })
        })
        .collect()
}

#[test]
fn no_request_lost_or_duplicated_under_concurrent_push_and_close() {
    for &shards in &SHARD_GRID {
        property(
            &format!("push/close race loses nothing at {shards} shards"),
            8,
            |rng| {
                let kind = if rng.bernoulli(0.5) {
                    RouterKind::RoundRobin
                } else {
                    RouterKind::LeastDepth
                };
                let b = Arc::new(ShardedBatcher::new(
                    shards,
                    4,
                    Duration::from_millis(1),
                    kind,
                ));
                let drained = Arc::new(Mutex::new(Vec::new()));
                let rejected = Arc::new(Mutex::new(Vec::new()));
                let drainers = spawn_drainers(&b, &drained);

                let pushers: Vec<_> = (0..4u64)
                    .map(|p| {
                        let b = b.clone();
                        let rejected = rejected.clone();
                        std::thread::spawn(move || {
                            for i in 0..25u64 {
                                let id = p * 1000 + i;
                                if let Err(back) = b.push(item(id, 1)) {
                                    assert_eq!(
                                        back.item().id,
                                        id,
                                        "rejection returns the same item"
                                    );
                                    rejected.lock().unwrap().push(id);
                                }
                            }
                        })
                    })
                    .collect();

                // Close at a random point while pushers are racing.
                std::thread::sleep(Duration::from_millis(rng.index(4) as u64));
                b.close();
                for h in pushers {
                    h.join().unwrap();
                }
                for h in drainers {
                    h.join().unwrap();
                }

                let drained = drained.lock().unwrap();
                let rejected = rejected.lock().unwrap();
                let drained_set: BTreeSet<u64> = drained.iter().copied().collect();
                let rejected_set: BTreeSet<u64> = rejected.iter().copied().collect();
                assert_eq!(drained_set.len(), drained.len(), "no id drained twice");
                assert_eq!(rejected_set.len(), rejected.len(), "no id rejected twice");
                assert!(
                    drained_set.is_disjoint(&rejected_set),
                    "an item was both accepted and rejected"
                );
                let mut all: BTreeSet<u64> = drained_set;
                all.extend(&rejected_set);
                assert_eq!(all.len(), 100, "every pushed id accounted for exactly once");
            },
        );
    }
}

#[test]
fn max_batch_is_respected_per_shard_for_any_row_mix() {
    for &shards in &SHARD_GRID {
        property(
            &format!("batch rows ≤ max_batch at {shards} shards"),
            10,
            |rng| {
                let max_batch = 4 + rng.index(5); // 4..=8 rows
                let b = ShardedBatcher::new(
                    shards,
                    max_batch,
                    Duration::from_millis(1),
                    RouterKind::RoundRobin,
                );
                let n_items = 10 + rng.index(20);
                for id in 0..n_items as u64 {
                    // Mostly small items; occasionally one wider than the
                    // whole batch budget (an oversized head must ship alone).
                    let rows = if rng.bernoulli(0.1) { max_batch + 2 } else { 1 + rng.index(3) };
                    b.push(item(id, rows)).unwrap();
                }
                b.close();
                let mut seen = 0usize;
                for shard in 0..b.num_shards() {
                    while let Some(batch) = b.next_batch(shard) {
                        let rows: usize = batch.iter().map(|i| i.x.rows()).sum();
                        if batch.len() == 1 {
                            // A single item may exceed max_batch (oversized
                            // requests still ship) — no bound to check.
                        } else {
                            assert!(
                                rows <= max_batch,
                                "shard {shard}: {rows} rows in a {}-item batch > max {max_batch}",
                                batch.len()
                            );
                        }
                        seen += batch.len();
                    }
                }
                assert_eq!(seen, n_items, "drain sees every item exactly once");
            },
        );
    }
}

#[test]
fn max_wait_ships_partial_batches_per_shard() {
    // One under-filled item per shard: each shard's executor-facing
    // `next_batch` must return it within the batching window (plus
    // scheduling slack), not hold it for a full batch.
    let max_wait = Duration::from_millis(40);
    let b = Arc::new(ShardedBatcher::new(2, 64, max_wait, RouterKind::RoundRobin));
    // Anchor the clock at push time: the batching deadline is
    // `enqueued + max_wait`, so measuring from each drain thread's own
    // start would flake whenever thread spawn is slow on a loaded runner.
    let t0 = Instant::now();
    b.push(item(0, 1)).unwrap();
    b.push(item(1, 1)).unwrap();
    assert_eq!(b.depths(), vec![1, 1], "round-robin placed one item per shard");
    let handles: Vec<_> = (0..2)
        .map(|shard| {
            let b = b.clone();
            std::thread::spawn(move || {
                let batch = b.next_batch(shard).expect("partial batch ships");
                (batch.len(), t0.elapsed())
            })
        })
        .collect();
    for h in handles {
        let (len, waited) = h.join().unwrap();
        assert_eq!(len, 1);
        assert!(
            waited >= Duration::from_millis(25),
            "batch shipped before the window: {waited:?}"
        );
        assert!(
            waited < Duration::from_millis(2000),
            "batch held far past max_wait: {waited:?}"
        );
    }
}

#[test]
fn depth_accounting_is_consistent_across_shard_counts() {
    for &shards in &SHARD_GRID {
        property(
            &format!("depths sum to pushed−drained at {shards} shards"),
            10,
            |rng| {
                let b = ShardedBatcher::new(
                    shards,
                    8,
                    Duration::from_millis(1),
                    RouterKind::RoundRobin,
                );
                let n = 1 + rng.index(40);
                for id in 0..n as u64 {
                    b.push(item(id, 1)).unwrap();
                }
                let depths = b.depths();
                assert_eq!(depths.len(), shards);
                assert_eq!(depths.iter().sum::<usize>(), n);
                assert_eq!(b.depth(), n);
                // Round-robin keeps shard depths within one of each other.
                let (min, max) =
                    (depths.iter().min().unwrap(), depths.iter().max().unwrap());
                assert!(max - min <= 1, "round-robin imbalance: {depths:?}");

                b.close();
                let mut drained = 0usize;
                for shard in 0..shards {
                    while let Some(batch) = b.next_batch(shard) {
                        drained += batch.len();
                        assert_eq!(
                            b.depth(),
                            n - drained,
                            "total depth tracks the drain step by step"
                        );
                    }
                }
                assert_eq!(drained, n);
                assert_eq!(b.depth(), 0);
                assert_eq!(b.depths(), vec![0; shards]);
            },
        );
    }
}

#[test]
fn least_depth_router_keeps_undrained_shards_balanced() {
    property("least-depth imbalance ≤ 1 without drain", 10, |rng| {
        let shards = 2 + rng.index(6);
        let b = ShardedBatcher::new(shards, 8, Duration::from_millis(1), RouterKind::LeastDepth);
        let n = 1 + rng.index(50);
        for id in 0..n as u64 {
            b.push(item(id, 1)).unwrap();
        }
        let depths = b.depths();
        let (min, max) = (depths.iter().min().unwrap(), depths.iter().max().unwrap());
        assert!(max - min <= 1, "least-depth imbalance: {depths:?}");
    });
}

#[test]
fn close_then_push_rejects_on_every_shard_count() {
    for &shards in &SHARD_GRID {
        let b = ShardedBatcher::new(shards, 4, Duration::from_millis(1), RouterKind::RoundRobin);
        b.push(item(1, 1)).unwrap();
        b.close();
        assert!(b.is_closed());
        // The fix under test: a closed batcher must hand items back, not
        // silently accept them into a queue nothing will ever drain.
        for id in 10..13u64 {
            let back = b.push(item(id, 1)).expect_err("push after close must reject");
            assert!(!back.is_overloaded(), "close rejection, not a shed");
            assert_eq!(back.into_item().id, id);
        }
        let mut drained = 0usize;
        for shard in 0..shards {
            while let Some(batch) = b.next_batch(shard) {
                drained += batch.len();
            }
        }
        assert_eq!(drained, 1, "only the pre-close item drains");
    }
}

#[test]
fn bounded_depth_never_exceeded_and_every_push_accounted_for() {
    for &shards in &SHARD_GRID {
        property(
            &format!("depth ≤ cap, shed+served+closed == pushes at {shards} shards"),
            6,
            |rng| {
                let cap = 1 + rng.index(4); // 1..=4 items per shard
                let b = Arc::new(ShardedBatcher::with_limits(
                    shards,
                    2,
                    Duration::from_millis(1),
                    cap,
                    None,
                    RouterKind::RoundRobin,
                ));
                let drained = Arc::new(Mutex::new(Vec::new()));
                let accepted = Arc::new(Mutex::new(Vec::new()));
                let shed = Arc::new(Mutex::new(Vec::new()));
                let closed = Arc::new(Mutex::new(Vec::new()));
                let drainers = spawn_drainers(&b, &drained);

                let pushers: Vec<_> = (0..3u64)
                    .map(|p| {
                        let b = b.clone();
                        let accepted = accepted.clone();
                        let shed = shed.clone();
                        let closed = closed.clone();
                        std::thread::spawn(move || {
                            for i in 0..30u64 {
                                let id = p * 1000 + i;
                                match b.push(item(id, 1)) {
                                    Ok(_shard) => accepted.lock().unwrap().push(id),
                                    Err(PushRejection::Overloaded(it)) => {
                                        assert_eq!(it.id, id, "shed hands the same item back");
                                        shed.lock().unwrap().push(id);
                                    }
                                    Err(PushRejection::Closed(it)) => {
                                        assert_eq!(it.id, id, "close hands the same item back");
                                        closed.lock().unwrap().push(id);
                                    }
                                }
                                // The admission bound is checked under the
                                // queue lock, so no sample — however racy —
                                // may ever see a shard above its cap.
                                for d in b.depths() {
                                    assert!(d <= cap, "shard depth {d} exceeds cap {cap}");
                                }
                            }
                        })
                    })
                    .collect();

                std::thread::sleep(Duration::from_millis(rng.index(4) as u64));
                b.close();
                for h in pushers {
                    h.join().unwrap();
                }
                for h in drainers {
                    h.join().unwrap();
                }

                let drained: BTreeSet<u64> = drained.lock().unwrap().iter().copied().collect();
                let accepted: BTreeSet<u64> = accepted.lock().unwrap().iter().copied().collect();
                let shed = shed.lock().unwrap();
                let closed = closed.lock().unwrap();
                assert_eq!(drained, accepted, "exactly the accepted items drain");
                assert_eq!(
                    accepted.len() + shed.len() + closed.len(),
                    90,
                    "every push resolves to served, shed, or rejected-after-close"
                );
                assert_eq!(b.shed_count(), shed.len() as u64, "shed counter matches rejections");
            },
        );
    }
}

#[test]
fn deadline_expired_items_are_replied_to_not_dropped() {
    for &shards in &SHARD_GRID {
        property(
            &format!("expired items get an overloaded reply at {shards} shards"),
            6,
            |rng| {
                let deadline = Duration::from_millis(5);
                let b = ShardedBatcher::with_limits(
                    shards,
                    64,
                    Duration::from_millis(1),
                    0,
                    Some(deadline),
                    RouterKind::RoundRobin,
                );
                let n = 1 + rng.index(20);
                let mut receivers = Vec::new();
                for id in 0..n as u64 {
                    let (it, rx) = item_with_rx(id, 1);
                    b.push(it).unwrap();
                    receivers.push((id, rx));
                }
                // Let every queued item blow past its deadline before any
                // executor reaches it.
                std::thread::sleep(deadline + Duration::from_millis(20));
                b.close();
                let mut drained = BTreeSet::new();
                for shard in 0..shards {
                    while let Some(batch) = b.next_batch(shard) {
                        for it in batch {
                            drained.insert(it.id);
                        }
                    }
                }
                let mut replied = 0usize;
                for (id, rx) in receivers {
                    match rx.try_recv() {
                        Ok(resp) => {
                            assert!(
                                resp.overloaded && !resp.ok,
                                "expiry must reply with the overload marker"
                            );
                            assert_eq!(resp.id, id);
                            assert!(
                                !drained.contains(&id),
                                "item {id} both expired and served"
                            );
                            replied += 1;
                        }
                        Err(_) => assert!(
                            drained.contains(&id),
                            "item {id} neither answered nor served — dropped"
                        ),
                    }
                }
                assert_eq!(
                    replied + drained.len(),
                    n,
                    "every request answered or served exactly once"
                );
                assert_eq!(b.expired_count(), replied as u64);
            },
        );
    }
}

#[test]
fn pressure_tracks_depth_over_cap_and_full_queues_shed() {
    let b = ShardedBatcher::with_limits(
        2,
        8,
        Duration::from_millis(1),
        4,
        None,
        RouterKind::RoundRobin,
    );
    assert_eq!(b.shard(0).pressure(), 0.0, "empty bounded queue is unpressured");
    for id in 0..8u64 {
        b.push(item(id, 1)).unwrap();
    }
    for s in 0..2 {
        assert_eq!(b.shard(s).depth(), 4);
        assert_eq!(b.shard(s).pressure(), 1.0, "full queue reports unit pressure");
    }
    // The next push finds its shard full: admission sheds, handing the
    // item back tagged as an overload (not a close).
    let rej = b.push(item(99, 1)).expect_err("full queues shed");
    assert!(rej.is_overloaded());
    assert_eq!(rej.into_item().id, 99);
    assert_eq!(b.shed_count(), 1);
    b.close();
    let mut drained = 0usize;
    for shard in 0..2 {
        while let Some(batch) = b.next_batch(shard) {
            drained += batch.len();
        }
    }
    assert_eq!(drained, 8, "shed item never entered a queue");
    // Unbounded queues always report zero pressure regardless of depth.
    let ub = ShardedBatcher::new(1, 8, Duration::from_millis(1), RouterKind::RoundRobin);
    ub.push(item(1, 1)).unwrap();
    assert_eq!(ub.shard(0).pressure(), 0.0);
}
