//! Saturation end-to-end tests: a pipelining loadgen drives a live server
//! well past its measured saturation point and pins the overload contract,
//! at shard counts {1, 2, 7} × elastic {on, off}:
//!
//! - **exactly one reply per request** — every pipelined request comes back
//!   as either logits or an explicit `overloaded` shed; nothing is lost,
//!   nothing is answered twice;
//! - **bounded latency for accepted work** — the admission cap keeps queue
//!   wait finite, so accepted p99 stays bounded even while the offered rate
//!   is a multiple of what the server can serve;
//! - **bit-identity of accepted outputs** vs an unloaded reference server
//!   under a bit-exact kernel allow-list ({dense, dense_packed}): overload
//!   may change *when* a request runs and *whether* it runs, never what an
//!   accepted request computes. Control-mode identity is asserted with
//!   elastic dispatch both off and on (pressure never touches the exact
//!   path); ConditionalAe identity is asserted with elastic off (elastic
//!   rank truncation deliberately trades mask fidelity for throughput, so
//!   no cross-load identity is claimed there — the elastic-on conditional
//!   arm still pins liveness and the exactly-one-reply accounting).
//!
//! Saturation is measured, not assumed: a calibration pass blasts the same
//! pipelined load at an uncapped server and takes its accepted throughput
//! as the saturation rate; overload arms then pace the loadgen at 3× that.

use condcomp::condcomp::KernelId;
use condcomp::config::{EstimatorConfig, NetConfig};
use condcomp::coordinator::protocol::{Mode, Request, Response};
use condcomp::coordinator::server::Client;
use condcomp::coordinator::{NativeBackend, Server, ServerConfig};
use condcomp::estimator::SignEstimatorSet;
use condcomp::linalg::Mat;
use condcomp::nn::Mlp;
use condcomp::util::Pcg32;
use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

const CLIENTS: u64 = 4;
const PER_CLIENT: u64 = 30;
const TOTAL: u64 = CLIENTS * PER_CLIENT;

/// Compute-heavy deterministic backend: big enough that serving a request
/// costs far more than parsing one, so a pipelined burst genuinely outruns
/// the executors. No training needed — seeded init weights serve a fixed
/// function, and two calls build bit-identical backends. The allow-list is
/// pinned to the bit-exact dense class so kernel choice can never move
/// accepted outputs off the reference bits, whatever batch shapes or
/// pressure the overload produces.
fn overload_backend() -> NativeBackend {
    let mut rng = Pcg32::seeded(0x0E71);
    let net = Mlp::init(
        &NetConfig { layers: vec![128, 256, 192, 16], weight_sigma: 0.3, bias_init: 0.1 },
        &mut rng,
    );
    let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&[8, 6]), 3);
    let backend = NativeBackend::new(net, est, 32);
    backend
        .set_allowed_kernels(&[KernelId::DENSE, KernelId::DENSE_PACKED])
        .expect("bit-exact allow-list installs");
    backend
}

/// The request payload for a given id — its own seeded stream, so loadgen
/// threads and the reference pass reproduce identical inputs independently.
fn input_for(id: u64) -> Mat {
    let mut rng = Pcg32::new(id, 0x10AD);
    Mat::randn(1, 128, 0.5, &mut rng)
}

fn logits_bits(resp: &Response) -> Vec<u32> {
    resp.logits
        .as_ref()
        .expect("accepted response carries logits")
        .as_slice()
        .iter()
        .map(|v| v.to_bits())
        .collect()
}

/// Ground truth through the wire: an unloaded single-shard server answers
/// every request id sequentially. Going over TCP (rather than calling the
/// backend directly) keeps the reference on the same serialization path as
/// the loadgen, so the comparison is bits-in-equals-bits-out end to end.
fn reference_bits(mode: Mode) -> BTreeMap<u64, Vec<u32>> {
    let server = Server::start(
        Arc::new(overload_backend()),
        ServerConfig {
            shards: 1,
            max_wait: Duration::from_millis(1),
            ..ServerConfig::default()
        },
    )
    .expect("reference server start");
    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut map = BTreeMap::new();
    for id in 0..TOTAL {
        let resp = client.predict(input_for(id), mode).unwrap();
        assert!(resp.ok, "reference id {id}: {:?}", resp.error);
        map.insert(id, logits_bits(&resp));
    }
    server.shutdown();
    map
}

struct LoadgenResult {
    /// (id, logit bits, latency µs) for every non-shed reply.
    accepted: Vec<(u64, Vec<u32>, u64)>,
    /// ids that came back with the explicit overload marker.
    shed: Vec<u64>,
}

/// Drive `addr` with `CLIENTS` pipelining connections, each sending
/// `PER_CLIENT` requests paced at `interval` (zero = blast). Requests are
/// written by a dedicated sender thread per connection while the reader
/// collects replies, so a full socket never deadlocks the loadgen. Pacing
/// uses absolute target times, so oversleep on a loaded runner self-corrects
/// instead of silently lowering the offered rate.
fn run_loadgen(addr: std::net::SocketAddr, mode: Mode, interval: Duration) -> LoadgenResult {
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                stream.set_nodelay(true).unwrap();
                // A lost reply must fail loudly, not hang the suite.
                stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                let sender = std::thread::spawn(move || {
                    let start = Instant::now();
                    for i in 0..PER_CLIENT {
                        let due = start + interval * i as u32;
                        let wait = due.saturating_duration_since(Instant::now());
                        if !wait.is_zero() {
                            std::thread::sleep(wait);
                        }
                        let id = c * PER_CLIENT + i;
                        let mut line =
                            Request::Predict { id, mode, x: input_for(id) }.to_json_line();
                        line.push('\n');
                        writer.write_all(line.as_bytes()).unwrap();
                    }
                    writer.flush().unwrap();
                    writer
                });
                let mut accepted = Vec::new();
                let mut shed = Vec::new();
                for k in 0..PER_CLIENT {
                    let mut line = String::new();
                    reader
                        .read_line(&mut line)
                        .unwrap_or_else(|e| panic!("client {c}: reply {k} never arrived: {e}"));
                    assert!(
                        !line.trim().is_empty(),
                        "client {c}: connection closed after {k} replies"
                    );
                    let resp = Response::parse(&line).unwrap();
                    if resp.overloaded {
                        assert!(!resp.ok, "id {}: shed reply claims success", resp.id);
                        shed.push(resp.id);
                    } else {
                        assert!(resp.ok, "id {}: {:?}", resp.id, resp.error);
                        accepted.push((resp.id, logits_bits(&resp), resp.latency_us));
                    }
                }
                drop(sender.join().unwrap());
                (accepted, shed)
            })
        })
        .collect();
    let mut accepted = Vec::new();
    let mut shed = Vec::new();
    for h in handles {
        let (a, s) = h.join().unwrap();
        accepted.extend(a);
        shed.extend(s);
    }
    LoadgenResult { accepted, shed }
}

/// Measure the saturation rate: blast an uncapped server and take its
/// accepted throughput. Everything is admitted (no queue bound), so the
/// elapsed wall clock is service-bound — req/s out of this run is what the
/// serving stack can actually sustain on this machine.
fn measured_saturation_rps() -> f64 {
    let server = Server::start(
        Arc::new(overload_backend()),
        ServerConfig {
            shards: 2,
            max_wait: Duration::from_millis(1),
            ..ServerConfig::default()
        },
    )
    .expect("calibration server start");
    let t0 = Instant::now();
    let got = run_loadgen(server.local_addr, Mode::Control, Duration::ZERO);
    let elapsed = t0.elapsed().as_secs_f64().max(1e-6);
    assert_eq!(got.accepted.len() as u64, TOTAL, "uncapped server accepts everything");
    assert!(got.shed.is_empty(), "uncapped server must not shed");
    server.shutdown();
    TOTAL as f64 / elapsed
}

/// Every-reply-exactly-once accounting plus the bounded-p99 check, shared
/// by all arms.
fn check_conservation(got: &LoadgenResult, arm: &str) {
    let mut ids: BTreeSet<u64> = got.accepted.iter().map(|(id, _, _)| *id).collect();
    assert_eq!(ids.len(), got.accepted.len(), "{arm}: duplicate accepted ids");
    for id in &got.shed {
        assert!(ids.insert(*id), "{arm}: id {id} both served and shed");
    }
    assert_eq!(
        ids.len() as u64,
        TOTAL,
        "{arm}: {} accepted + {} shed != {TOTAL} sent",
        got.accepted.len(),
        got.shed.len()
    );
    assert_eq!(ids, (0..TOTAL).collect::<BTreeSet<u64>>(), "{arm}: reply ids drifted");

    let mut lat: Vec<u64> = got.accepted.iter().map(|(_, _, us)| *us).collect();
    lat.sort_unstable();
    if !lat.is_empty() {
        let p99 = lat[(lat.len() - 1) * 99 / 100];
        // Generous but finite: the admission cap bounds queue wait, so even
        // a slow CI runner stays far under this. An unbounded queue under
        // 3× overload would blow through it.
        assert!(p99 < 10_000_000, "{arm}: accepted p99 {p99}µs is unbounded");
    }
}

#[test]
fn overload_sheds_explicitly_and_preserves_accepted_bits() {
    let control_ref = reference_bits(Mode::Control);
    let ae_ref = reference_bits(Mode::ConditionalAe);
    let sat_rps = measured_saturation_rps();
    // 3× past measured saturation, spread over the client pool.
    let interval = Duration::from_secs_f64(CLIENTS as f64 / (3.0 * sat_rps).max(1.0));

    for shards in [1usize, 2, 7] {
        for elastic in [false, true] {
            let arm = format!("shards={shards} elastic={elastic}");
            let server = Server::start(
                Arc::new(overload_backend()),
                ServerConfig {
                    shards,
                    max_wait: Duration::from_millis(1),
                    max_queue_depth: 4,
                    elastic,
                    ..ServerConfig::default()
                },
            )
            .unwrap_or_else(|e| panic!("{arm}: server start: {e}"));
            assert_eq!(
                server.metrics.gauge("max_queue_depth"),
                Some(4.0),
                "{arm}: admission bound not exported"
            );
            assert_eq!(
                server.metrics.gauge("elastic_enabled"),
                Some(if elastic { 1.0 } else { 0.0 }),
                "{arm}: elastic flag not exported"
            );

            // Control pass: exact path, so accepted bits must match the
            // unloaded reference whether or not elastic dispatch is on.
            let control = run_loadgen(server.local_addr, Mode::Control, interval);
            check_conservation(&control, &arm);
            assert!(
                !control.shed.is_empty(),
                "{arm}: 3× overload produced no sheds — not saturated"
            );
            for (id, bits, _) in &control.accepted {
                assert_eq!(
                    bits, &control_ref[id],
                    "{arm}: accepted control id {id} drifted from unloaded reference"
                );
            }

            // Conditional pass: same conservation contract; bit-identity is
            // additionally pinned when elastic is off (with it on, rank
            // truncation under pressure is allowed to move conditional
            // outputs — that is the feature, not a corruption).
            let cond = run_loadgen(server.local_addr, Mode::ConditionalAe, interval);
            check_conservation(&cond, &arm);
            if !elastic {
                for (id, bits, _) in &cond.accepted {
                    assert_eq!(
                        bits, &ae_ref[id],
                        "{arm}: accepted conditional id {id} drifted from unloaded reference"
                    );
                }
            }

            // Shed accounting: every overloaded reply the clients saw was
            // counted (admission sheds increment before the reply is sent,
            // and no deadline is configured, so the counter is exact).
            let total_shed = (control.shed.len() + cond.shed.len()) as u64;
            assert_eq!(
                server.metrics.counter("shed_total"),
                total_shed,
                "{arm}: shed_total disagrees with observed overloaded replies"
            );
            // The pressure signal reached the exporter on every shard.
            for s in 0..shards {
                let p = server
                    .metrics
                    .shard_gauge(s, "queue_pressure")
                    .unwrap_or_else(|| panic!("{arm}: shard {s} exported no queue_pressure"));
                assert!((0.0..=1.0).contains(&p), "{arm}: shard {s} pressure {p} out of range");
            }
            server.shutdown();
        }
    }
}
