//! End-to-end tests for multi-process serving: real `condcomp worker`
//! processes (spawned from the built binary), a coordinator routing batches
//! to them over the TCP protocol, real clients on the front door.
//!
//! What is pinned here and nowhere else:
//!
//! - **bit-identity across process counts**: a coordinator over three
//!   worker processes answers bit-identically to a direct client of one
//!   worker, in both modes — under the bit-exact kernel allow-list
//!   (`dense,dense_packed`), since each worker calibrates its own dispatch
//!   table and only that class guarantees identical bits whichever kernel
//!   the table picks;
//! - **exactly-one-reply conservation under worker death**: killing one of
//!   three workers mid-load loses no request — every predict gets exactly
//!   one reply (ok or explicit overloaded), zero hard errors, because the
//!   coordinator re-routes the in-flight batch to a surviving replica;
//! - **recovery**: a worker restarted on the same port is re-admitted by
//!   the health thread after a fresh `hello` handshake, and the
//!   `replica<i>_healthy` gauge reflects it.

use condcomp::coordinator::protocol::Mode;
use condcomp::coordinator::{
    Backend, Client, ConnectOpts, RemoteBackend, RemoteOpts, Server, ServerConfig,
};
use condcomp::linalg::Mat;
use condcomp::util::Pcg32;
use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A spawned worker process plus the address/fingerprint scraped from its
/// startup line.
struct Worker {
    child: Child,
    addr: String,
    fingerprint: String,
}

impl Worker {
    fn kill(mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn `condcomp worker` bound to `addr` (use 127.0.0.1:0 for an
/// ephemeral port) and scrape the bound address + model fingerprint from
/// its stdout line. The model prep is deterministic, so every worker from
/// this helper serves bit-identical weights; the kernel allow-list is
/// pinned to the bit-exact class so per-worker calibration cannot introduce
/// tier drift.
fn spawn_worker(addr: &str) -> Worker {
    let mut child = Command::new(env!("CARGO_BIN_EXE_condcomp"))
        .args([
            "worker",
            "--profile",
            "mnist-tiny",
            "--train-epochs",
            "1",
            "--addr",
            addr,
            "--kernels",
            "dense,dense_packed",
            "--set",
            "data.n_train=200",
            "--set",
            "data.n_valid=50",
            "--set",
            "data.n_test=50",
            "--set",
            "autotune.budget_ms=200",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn condcomp worker");
    let stdout = child.stdout.take().expect("worker stdout piped");
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).expect("read worker startup line");
    // "worker listening on 127.0.0.1:PORT (model mlp:…, ranks […])"
    let bound = line
        .split_whitespace()
        .nth(3)
        .unwrap_or_else(|| panic!("worker exited before binding (stdout: {line:?})"))
        .to_string();
    let fingerprint = line
        .split("(model ")
        .nth(1)
        .and_then(|s| s.split(',').next())
        .unwrap_or_else(|| panic!("no fingerprint in startup line {line:?}"))
        .to_string();
    Worker { child, addr: bound, fingerprint }
}

/// Spawn a worker on a *fixed* port, retrying briefly: right after a kill
/// the old socket may still be tearing down (SO_REUSEADDR makes this rare,
/// but the retry keeps the test unflaky).
fn spawn_worker_at_port(addr: &str) -> Worker {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let mut child = Command::new(env!("CARGO_BIN_EXE_condcomp"))
            .args([
                "worker",
                "--profile",
                "mnist-tiny",
                "--train-epochs",
                "1",
                "--addr",
                addr,
                "--kernels",
                "dense,dense_packed",
                "--set",
                "data.n_train=200",
                "--set",
                "data.n_valid=50",
                "--set",
                "data.n_test=50",
                "--set",
                "autotune.budget_ms=200",
            ])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn condcomp worker");
        let stdout = child.stdout.take().expect("worker stdout piped");
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).expect("read worker startup line");
        if line.contains("worker listening on") {
            let fingerprint = line
                .split("(model ")
                .nth(1)
                .and_then(|s| s.split(',').next())
                .unwrap_or("")
                .to_string();
            return Worker { child, addr: addr.to_string(), fingerprint };
        }
        // Bind failed (the process printed nothing and exited): reap, wait,
        // retry on the same port.
        let _ = child.kill();
        let _ = child.wait();
        assert!(Instant::now() < deadline, "could not rebind worker on {addr}");
        std::thread::sleep(Duration::from_millis(200));
    }
}

fn fast_opts() -> RemoteOpts {
    RemoteOpts {
        connect_timeout: Duration::from_secs(1),
        read_timeout: Duration::from_secs(30),
        retries: 3,
        backoff: Duration::from_millis(25),
        health_interval: Duration::from_millis(50),
        min_replicas: 0,
    }
}

fn logits_bits(m: &Mat) -> Vec<u32> {
    m.as_slice().iter().map(|v| v.to_bits()).collect()
}

/// The whole lifecycle in one fleet (workers are expensive to train, so the
/// phases share them): handshake + bit-identity, kill-one-mid-load
/// conservation, restart + re-admission.
#[test]
fn coordinator_over_worker_processes_serves_identically_and_survives_a_kill() {
    // --- Fleet up: three real worker processes, ephemeral ports. ---
    let w0 = spawn_worker("127.0.0.1:0");
    let w1 = spawn_worker("127.0.0.1:0");
    let w2 = spawn_worker("127.0.0.1:0");
    assert_eq!(w0.fingerprint, w1.fingerprint);
    assert_eq!(w0.fingerprint, w2.fingerprint);
    assert_eq!(w0.fingerprint, "mlp:784-64-48-32-10");
    let w1_addr = w1.addr.clone();

    let remote = Arc::new(
        RemoteBackend::connect(
            &[w0.addr.clone(), w1.addr.clone(), w2.addr.clone()],
            &w0.fingerprint,
            fast_opts(),
        )
        .expect("all three workers handshake"),
    );
    assert_eq!(remote.healthy_replicas(), vec![true, true, true]);
    assert_eq!(remote.input_dim(), 784);

    let server = Server::start(
        remote.clone() as Arc<dyn Backend>,
        ServerConfig { shards: 2, ..ServerConfig::default() },
    )
    .expect("coordinator start");
    remote.attach_metrics(server.metrics.clone());
    let addr = server.local_addr;

    // --- Phase 1: bit-identity, 1 process vs 3 processes over TCP. ---
    // The direct client talks to worker 0 alone; the coordinator fans the
    // same inputs across all three. Same deterministic model + bit-exact
    // kernel class ⇒ identical bits wherever a batch lands.
    let w0_sock: std::net::SocketAddr = w0.addr.parse().unwrap();
    let mut direct = Client::connect(&w0_sock).unwrap();
    let mut coord = Client::connect(&addr).unwrap();
    let mut rng = Pcg32::seeded(0x9E91);
    for mode in [Mode::Control, Mode::ConditionalAe] {
        for req in 0..6 {
            let x = Mat::randn(1 + (req % 2), 784, 0.5, &mut rng);
            let a = direct.predict(x.clone(), mode).unwrap();
            let b = coord.predict(x, mode).unwrap();
            assert!(a.ok && b.ok, "{:?} / {:?}", a.error, b.error);
            assert_eq!(a.classes, b.classes, "mode {mode:?} req {req}: classes drifted");
            let wa = a.logits.as_ref().expect("direct logits");
            let wb = b.logits.as_ref().expect("coordinator logits");
            assert_eq!(
                logits_bits(wa),
                logits_bits(wb),
                "mode {mode:?} req {req}: N-process logits differ from 1-process"
            );
        }
    }

    // --- Phase 2: kill worker 1 mid-load; conservation must hold. ---
    let ok = Arc::new(AtomicU64::new(0));
    let overloaded = Arc::new(AtomicU64::new(0));
    let failed = Arc::new(AtomicU64::new(0));
    const CLIENTS: usize = 4;
    const REQS: usize = 40;
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let (ok, overloaded, failed) = (ok.clone(), overloaded.clone(), failed.clone());
            std::thread::spawn(move || {
                // A bounded read timeout turns a dropped reply (the bug this
                // guards against) into a counted failure, not a hung test.
                let opts = ConnectOpts {
                    read_timeout: Some(Duration::from_secs(60)),
                    ..ConnectOpts::default()
                };
                let mut client = Client::connect_with(&addr, &opts).unwrap();
                let mut rng = Pcg32::new(0xC11E ^ c as u64, 3);
                for i in 0..REQS {
                    let mode = if i % 2 == 0 { Mode::ConditionalAe } else { Mode::Control };
                    let x = Mat::randn(1, 784, 0.5, &mut rng);
                    match client.predict(x, mode) {
                        Ok(resp) if resp.ok => ok.fetch_add(1, Ordering::Relaxed),
                        Ok(resp) if resp.overloaded => {
                            overloaded.fetch_add(1, Ordering::Relaxed)
                        }
                        _ => failed.fetch_add(1, Ordering::Relaxed),
                    };
                    std::thread::sleep(Duration::from_millis(2));
                }
            })
        })
        .collect();
    // Let traffic flow, then take worker 1 down hard.
    std::thread::sleep(Duration::from_millis(60));
    w1.kill();
    for h in handles {
        h.join().unwrap();
    }
    let (ok, overloaded, failed) = (
        ok.load(Ordering::Relaxed),
        overloaded.load(Ordering::Relaxed),
        failed.load(Ordering::Relaxed),
    );
    assert_eq!(failed, 0, "requests lost or errored around the worker death");
    assert_eq!(
        ok + overloaded,
        (CLIENTS * REQS) as u64,
        "exactly one reply per request (ok {ok} + overloaded {overloaded})"
    );
    // With two healthy survivors, failover should serve everything.
    assert!(ok > 0, "no request succeeded after the kill");
    assert_eq!(server.metrics.counter("errors"), 0, "worker death surfaced as hard errors");

    // The health thread notices the death (if the predict path has not
    // already marked it down).
    let deadline = Instant::now() + Duration::from_secs(10);
    while remote.healthy_replicas()[1] {
        assert!(Instant::now() < deadline, "dead worker never marked unhealthy");
        std::thread::sleep(Duration::from_millis(20));
    }
    let deadline = Instant::now() + Duration::from_secs(5);
    while server.metrics.replica_gauge(1, "healthy") != Some(0.0) {
        assert!(Instant::now() < deadline, "replica1_healthy gauge never dropped to 0");
        std::thread::sleep(Duration::from_millis(20));
    }

    // --- Phase 3: restart on the same port; health thread re-admits. ---
    let revived = spawn_worker_at_port(&w1_addr);
    let deadline = Instant::now() + Duration::from_secs(60);
    while !remote.healthy_replicas()[1] {
        assert!(Instant::now() < deadline, "restarted worker never re-admitted");
        std::thread::sleep(Duration::from_millis(50));
    }
    let deadline = Instant::now() + Duration::from_secs(5);
    while server.metrics.replica_gauge(1, "healthy") != Some(1.0) {
        assert!(Instant::now() < deadline, "replica1_healthy gauge never recovered");
        std::thread::sleep(Duration::from_millis(20));
    }
    // The recovered fleet still answers bit-identically to worker 0.
    for req in 0..4 {
        let x = Mat::randn(1, 784, 0.5, &mut rng);
        let a = direct.predict(x.clone(), Mode::ConditionalAe).unwrap();
        let b = coord.predict(x, Mode::ConditionalAe).unwrap();
        assert!(a.ok && b.ok);
        assert_eq!(
            logits_bits(a.logits.as_ref().unwrap()),
            logits_bits(b.logits.as_ref().unwrap()),
            "req {req}: post-recovery logits drifted"
        );
    }

    // Per-replica counters flowed through the coordinator's registry.
    let routed: u64 = (0..3).map(|i| server.metrics.replica_counter(i, "batches_routed")).sum();
    assert!(routed > 0, "no batch was accounted to any replica");
    assert_eq!(server.metrics.gauge("replicas"), Some(3.0));
    assert_eq!(server.metrics.gauge("replicas_healthy"), Some(3.0));

    server.shutdown();
    drop(remote);
    revived.kill();
    w0.kill();
    w2.kill();
}
