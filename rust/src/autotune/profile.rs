//! Persistent machine profiles: the per-layer dispatch thresholds fitted by
//! the autotune harness, serialized as JSON so a machine measures once and
//! every later `condcomp serve` start just loads the file.
//!
//! A profile is bound to a *model shape* (the fingerprint — calibration
//! depends only on the per-layer `d × h` shapes, not the weight values) and
//! annotated with a *hardware descriptor* (arch/OS/thread count) so a file
//! copied between machines is at least visibly foreign. Loading rejects a
//! fingerprint mismatch outright; unknown JSON fields — including cost
//! columns for kernels this binary has never heard of — are tolerated, so
//! newer writers stay readable by older binaries.
//!
//! Since the kernel registry landed, each layer carries one **cost column
//! per registered kernel** (`kernel_costs`: kernel id → per-FLOP cost
//! relative to the dense baseline), and the profile records which kernel-id
//! set it measured (`kernels`). A profile missing a column for a kernel the
//! running binary has registered is not rejected — the loader reports the
//! gap ([`MachineProfile::missing_kernel_columns`]) and serve recalibrates
//! **just that column**, keeping the measured ones. The legacy
//! `cost_ratio`/`alpha_star` fields are still written (they are the masked
//! column in the old clothes), so pre-registry readers stay compatible.

use crate::condcomp::{DispatchPolicy, KernelId, PolicyTable};
use crate::io::json::Json;
use anyhow::Result;
use std::path::Path;

/// Schema version written into every profile; readers accept this version
/// only (the format is young — no compatibility shims yet).
pub const PROFILE_SCHEMA_VERSION: u64 = 1;

/// Canonical ordering for persisted cost columns: known kernels in registry
/// priority order, unknown (newer-writer) columns after them, lexicographic.
fn column_rank(name: &str) -> (u8, String) {
    match KernelId::parse(name) {
        Some(k) => (k.priority().0, name.to_string()),
        None => (u8::MAX, name.to_string()),
    }
}

/// One hidden layer's fitted calibration result.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerThreshold {
    /// Hidden-layer index (weight-matrix index; the output layer is never
    /// dispatched conditionally).
    pub layer: usize,
    /// Layer input width `d`.
    pub d: usize,
    /// Layer output width `h`.
    pub h: usize,
    /// Fitted masked-vs-dense per-FLOP cost ratio on the serving pool (the
    /// masked cost column in the legacy clothes — kept for pre-registry
    /// readers).
    pub cost_ratio: f64,
    /// The same ratio fitted single-threaded (recorded for diagnosis — the
    /// dispatch threshold uses `cost_ratio`).
    pub cost_ratio_serial: f64,
    /// The flip point derived from the cost table: cheapest dense-work
    /// per-FLOP cost over the masked per-FLOP cost; masked wins below.
    pub alpha_star: f64,
    /// Per-kernel per-FLOP cost columns relative to the dense baseline,
    /// canonical order. Unknown kernel ids (from a newer writer) are
    /// preserved through round-trips but ignored by [`Self::policy`].
    pub kernel_costs: Vec<(String, f64)>,
}

impl LayerThreshold {
    /// Construct from fitted per-kernel columns (the registry-era writer);
    /// derives the legacy `cost_ratio`/`alpha_star` fields from the table.
    pub fn from_kernel_costs(
        layer: usize,
        d: usize,
        h: usize,
        mut kernel_costs: Vec<(String, f64)>,
        cost_ratio_serial: Option<f64>,
    ) -> LayerThreshold {
        kernel_costs.sort_by_key(|(name, _)| column_rank(name));
        kernel_costs.dedup_by(|a, b| a.0 == b.0);
        let mut lt = LayerThreshold {
            layer,
            d,
            h,
            cost_ratio: DispatchPolicy::DEFAULT_COST_RATIO,
            cost_ratio_serial: 0.0,
            alpha_star: 0.0,
            kernel_costs,
        };
        let policy = lt.policy();
        lt.cost_ratio = policy.cost_ratio();
        lt.cost_ratio_serial = cost_ratio_serial.unwrap_or(lt.cost_ratio);
        lt.alpha_star = policy.density_threshold();
        lt
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("layer", Json::Num(self.layer as f64)),
            ("d", Json::Num(self.d as f64)),
            ("h", Json::Num(self.h as f64)),
            ("cost_ratio", Json::Num(self.cost_ratio)),
            ("cost_ratio_serial", Json::Num(self.cost_ratio_serial)),
            ("alpha_star", Json::Num(self.alpha_star)),
            (
                "kernel_costs",
                Json::Obj(
                    self.kernel_costs
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v)))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<LayerThreshold, String> {
        let need_usize = |key: &str| {
            v.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("layer entry missing integer '{key}'"))
        };
        let need_f64 = |key: &str| {
            v.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("layer entry missing number '{key}'"))
        };
        let cost_ratio = need_f64("cost_ratio")?;
        if !cost_ratio.is_finite() || cost_ratio <= 0.0 {
            return Err(format!("layer entry has invalid cost_ratio {cost_ratio}"));
        }
        // Writers that skip the serial arm record only the pooled ratio;
        // default to it (keeps summaries and equality NaN-free).
        let cost_ratio_serial = match v.get("cost_ratio_serial").and_then(Json::as_f64) {
            Some(r) if r.is_finite() && r > 0.0 => r,
            Some(r) => return Err(format!("layer entry has invalid cost_ratio_serial {r}")),
            None => cost_ratio,
        };
        // Per-kernel columns: unknown kernel ids are *tolerated* (kept for
        // round-trips, skipped by `policy()`); invalid numbers are errors.
        // A pre-registry profile without the field derives the binary table.
        let kernel_costs = match v.get("kernel_costs").and_then(Json::as_obj) {
            Some(map) => {
                let mut costs = Vec::with_capacity(map.len());
                for (name, val) in map {
                    let c = val
                        .as_f64()
                        .ok_or_else(|| format!("kernel_costs['{name}'] is not a number"))?;
                    if !c.is_finite() || c <= 0.0 {
                        return Err(format!("kernel_costs['{name}'] has invalid cost {c}"));
                    }
                    costs.push((name.clone(), c));
                }
                costs
            }
            None => vec![
                (KernelId::DENSE.as_str().to_string(), 1.0),
                (KernelId::MASKED.as_str().to_string(), cost_ratio),
            ],
        };
        // α* (and the reported ratio) are derivable state: recompute from
        // the columns so a hand-edited file cannot make the displayed
        // threshold disagree with the one dispatch actually uses.
        let mut lt = LayerThreshold::from_kernel_costs(
            need_usize("layer")?,
            need_usize("d")?,
            need_usize("h")?,
            kernel_costs,
            Some(cost_ratio_serial),
        );
        // When the columns lack a masked entry (partial newer-writer file),
        // keep the explicit legacy ratio rather than the default, and
        // re-derive the threshold from it.
        if !lt.has_column(KernelId::MASKED) {
            lt.cost_ratio = cost_ratio;
            lt.alpha_star = lt.policy().density_threshold();
        }
        Ok(lt)
    }

    /// Whether this layer has a measured cost column for `kernel`.
    pub fn has_column(&self, kernel: KernelId) -> bool {
        self.kernel_costs.iter().any(|(name, _)| name == kernel.as_str())
    }

    /// The dispatch policy this fit implies: one cost column per known
    /// kernel id (unknown columns are tolerated and skipped), with the
    /// legacy `cost_ratio` standing in for a missing masked column.
    pub fn policy(&self) -> DispatchPolicy {
        let mut columns = Vec::with_capacity(self.kernel_costs.len());
        for (name, cost) in &self.kernel_costs {
            if let Some(id) = KernelId::parse(name) {
                columns.push((id, *cost));
            }
        }
        let mut policy = DispatchPolicy::from_columns(columns);
        if policy.per_flop(KernelId::DENSE).is_none() {
            policy.set_column(KernelId::DENSE, 1.0);
        }
        if policy.per_flop(KernelId::MASKED).is_none() {
            policy.set_column(KernelId::MASKED, self.cost_ratio);
        }
        policy
    }
}

/// A persisted machine profile: which model (fingerprint), which machine
/// (hardware descriptor + pool size), and the per-layer thresholds.
#[derive(Clone, Debug, PartialEq)]
pub struct MachineProfile {
    pub version: u64,
    /// Model-shape fingerprint, e.g. `mlp:784-256-128-64-10`.
    pub fingerprint: String,
    /// Hardware descriptor, e.g. `x86_64-linux`.
    pub hardware: String,
    /// Pool threads the pooled ratios were measured on.
    pub threads: usize,
    /// Wall-clock budget the calibration ran under (ms).
    pub budget_ms: u64,
    /// The kernel-id set this profile carries cost columns for — the
    /// registry fingerprint. A running binary whose registry has more
    /// kernels recalibrates just the missing columns
    /// ([`Self::missing_kernel_columns`]); extra columns for kernels the
    /// binary lacks are tolerated.
    pub kernels: Vec<String>,
    pub layers: Vec<LayerThreshold>,
}

/// Fingerprint a model by its layer widths — the only thing calibration
/// depends on.
pub fn model_fingerprint(layer_sizes: &[usize]) -> String {
    let widths: Vec<String> = layer_sizes.iter().map(|w| w.to_string()).collect();
    format!("mlp:{}", widths.join("-"))
}

/// Describe the machine the measurement ran on.
pub fn hardware_descriptor() -> String {
    format!("{}-{}", std::env::consts::ARCH, std::env::consts::OS)
}

impl MachineProfile {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::Num(self.version as f64)),
            ("fingerprint", Json::Str(self.fingerprint.clone())),
            ("hardware", Json::Str(self.hardware.clone())),
            ("threads", Json::Num(self.threads as f64)),
            ("budget_ms", Json::Num(self.budget_ms as f64)),
            (
                "kernels",
                Json::Arr(self.kernels.iter().map(|k| Json::Str(k.clone())).collect()),
            ),
            (
                "layers",
                Json::Arr(self.layers.iter().map(LayerThreshold::to_json).collect()),
            ),
        ])
    }

    /// Parse from JSON text. Unknown fields are ignored; missing required
    /// fields and a wrong schema version are errors.
    pub fn parse(text: &str) -> Result<MachineProfile, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        let version = v
            .get("version")
            .and_then(Json::as_usize)
            .ok_or("profile missing 'version'")? as u64;
        if version != PROFILE_SCHEMA_VERSION {
            return Err(format!(
                "profile schema version {version} != supported {PROFILE_SCHEMA_VERSION}"
            ));
        }
        let fingerprint = v
            .get("fingerprint")
            .and_then(Json::as_str)
            .ok_or("profile missing 'fingerprint'")?
            .to_string();
        let hardware = v
            .get("hardware")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();
        let threads = v.get("threads").and_then(Json::as_usize).unwrap_or(0);
        let budget_ms = v.get("budget_ms").and_then(Json::as_usize).unwrap_or(0) as u64;
        let layers = v
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or("profile missing 'layers'")?
            .iter()
            .map(LayerThreshold::from_json)
            .collect::<Result<Vec<_>, String>>()?;
        // The measured kernel-id set: explicit when the writer recorded it;
        // a pre-registry profile derives it from the columns actually
        // present (the layers' derived dense+masked pair).
        let kernels = match v.get("kernels").and_then(Json::as_arr) {
            Some(arr) => arr
                .iter()
                .filter_map(|k| k.as_str().map(str::to_string))
                .collect(),
            None => {
                let mut union: Vec<String> = Vec::new();
                for lt in &layers {
                    for (name, _) in &lt.kernel_costs {
                        if !union.contains(name) {
                            union.push(name.clone());
                        }
                    }
                }
                union.sort_by_key(|name| column_rank(name));
                union
            }
        };
        Ok(MachineProfile { version, fingerprint, hardware, threads, budget_ms, kernels, layers })
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<MachineProfile> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        MachineProfile::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))
    }

    /// Load and verify the profile describes this model's shapes; a
    /// fingerprint mismatch is rejected (the thresholds would be for the
    /// wrong `d × h` grid).
    pub fn load_for_model(path: &Path, layer_sizes: &[usize]) -> Result<MachineProfile> {
        let profile = MachineProfile::load(path)?;
        profile.ensure_matches_model(layer_sizes)?;
        Ok(profile)
    }

    /// The fingerprint check as an error (shared by [`Self::load_for_model`]
    /// and the backend's `apply_profile`, so the rule and its message live
    /// in one place).
    pub fn ensure_matches_model(&self, layer_sizes: &[usize]) -> Result<()> {
        if !self.matches_model(layer_sizes) {
            return Err(anyhow::anyhow!(
                "machine profile fingerprint '{}' does not match model '{}'",
                self.fingerprint,
                model_fingerprint(layer_sizes)
            ));
        }
        Ok(())
    }

    /// Write to a file (pretty enough: one JSON document, trailing newline).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut text = self.to_json().to_string();
        text.push('\n');
        std::fs::write(path, text)
            .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
        Ok(())
    }

    /// Whether this profile describes a model with these layer widths.
    pub fn matches_model(&self, layer_sizes: &[usize]) -> bool {
        self.fingerprint == model_fingerprint(layer_sizes)
    }

    /// Registered kernels this profile has no cost column for, in at least
    /// one layer. A non-empty result does not reject the profile — serve
    /// keeps the measured columns and recalibrates only these (the columns
    /// are independent measurements, so partial reuse is sound).
    pub fn missing_kernel_columns(&self, required: &[KernelId]) -> Vec<KernelId> {
        required
            .iter()
            .copied()
            .filter(|k| self.layers.iter().any(|lt| !lt.has_column(*k)))
            .collect()
    }

    /// Build the runtime [`PolicyTable`] for a model with `num_layers`
    /// hidden layers; `source` is remembered for the fallback warning.
    pub fn policy_table(&self, num_layers: usize, source: &str) -> PolicyTable {
        let mut table = PolicyTable::uncalibrated(num_layers).with_profile_path(source);
        for lt in &self.layers {
            table.set_layer(lt.layer, lt.policy());
        }
        table
    }

    /// Human-readable per-layer report (the `calibrate` CLI prints this).
    pub fn summary_lines(&self) -> Vec<String> {
        let mut lines = vec![
            format!(
                "machine profile: {} on {} ({} threads, budget {} ms, kernels [{}])",
                self.fingerprint,
                self.hardware,
                self.threads,
                self.budget_ms,
                self.kernels.join(", ")
            ),
            format!(
                "{:<7} {:>11} {:>12} {:>14} {:>10}  {}",
                "layer", "shape", "cost-ratio", "ratio-serial", "α*", "kernel per-FLOP costs"
            ),
        ];
        for lt in &self.layers {
            let cols: Vec<String> = lt
                .kernel_costs
                .iter()
                .map(|(k, v)| format!("{k}:{v:.3}"))
                .collect();
            lines.push(format!(
                "{:<7} {:>11} {:>12.3} {:>14.3} {:>10.4}  {}",
                lt.layer,
                format!("{}×{}", lt.d, lt.h),
                lt.cost_ratio,
                lt.cost_ratio_serial,
                lt.alpha_star,
                cols.join(" ")
            ));
        }
        lines
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::condcomp::{KernelId, BUILTIN_KERNELS};

    fn sample() -> MachineProfile {
        MachineProfile {
            version: PROFILE_SCHEMA_VERSION,
            fingerprint: model_fingerprint(&[784, 256, 128, 10]),
            hardware: hardware_descriptor(),
            threads: 4,
            budget_ms: 500,
            kernels: vec!["dense".into(), "dense_packed".into(), "masked".into()],
            layers: vec![
                LayerThreshold::from_kernel_costs(
                    0,
                    784,
                    256,
                    vec![
                        ("dense".into(), 1.0),
                        ("dense_packed".into(), 0.9),
                        ("masked".into(), 2.5),
                    ],
                    Some(3.25),
                ),
                LayerThreshold::from_kernel_costs(
                    1,
                    256,
                    128,
                    vec![
                        ("dense".into(), 1.0),
                        ("dense_packed".into(), 1.1),
                        ("masked".into(), 5.0),
                    ],
                    Some(4.0),
                ),
            ],
        }
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let p = sample();
        let text = p.to_json().to_string();
        let back = MachineProfile::parse(&text).unwrap();
        assert_eq!(back, p);
        // The registry-era fields survived.
        assert_eq!(back.kernels.len(), 3);
        assert!(back.layers[0].has_column(KernelId::DENSE_PACKED));
    }

    #[test]
    fn derived_fields_come_from_the_cost_table() {
        let p = sample();
        // Layer 0: masked 2.5 over dense 1.0 → legacy ratio 2.5; the packed
        // column at 0.9 moves the threshold to 0.9/2.5 = 0.36.
        assert!((p.layers[0].cost_ratio - 2.5).abs() < 1e-12);
        assert!((p.layers[0].alpha_star - 0.36).abs() < 1e-12);
        assert_eq!(p.layers[0].policy().preferred_dense(), KernelId::DENSE_PACKED);
        // Layer 1: packed slower than dense → plain dense keeps the GEMM,
        // threshold is the classic 1/5.
        assert!((p.layers[1].alpha_star - 0.2).abs() < 1e-12);
        assert_eq!(p.layers[1].policy().preferred_dense(), KernelId::DENSE);
    }

    #[test]
    fn unknown_fields_are_tolerated() {
        // A future writer adds fields at both the profile and layer level;
        // this reader must still load the parts it understands.
        let text = r#"{
            "version": 1,
            "fingerprint": "mlp:8-4-2",
            "hardware": "x86_64-linux",
            "threads": 2,
            "budget_ms": 100,
            "future_backend_costs": {"pjrt": [1.0, 2.0]},
            "layers": [
                {"layer": 0, "d": 8, "h": 4,
                 "cost_ratio": 3.0, "cost_ratio_serial": 3.5,
                 "alpha_star": 0.3333, "pjrt_cost_ratio": 1.5}
            ]
        }"#;
        let p = MachineProfile::parse(text).unwrap();
        assert_eq!(p.fingerprint, "mlp:8-4-2");
        assert_eq!(p.layers.len(), 1);
        assert_eq!(p.layers[0].cost_ratio, 3.0);
        // Pre-registry file: the binary dense+masked table is derived.
        assert_eq!(p.kernels, vec!["dense".to_string(), "masked".to_string()]);
        assert!(p.layers[0].has_column(KernelId::MASKED));
    }

    /// Satellite: a cost column for a kernel this binary has never heard of
    /// is tolerated — preserved through a round-trip, skipped by `policy()`.
    #[test]
    fn unknown_kernel_column_is_tolerated_and_round_trips() {
        let text = r#"{
            "version": 1,
            "fingerprint": "mlp:8-4-2",
            "hardware": "x86_64-linux",
            "threads": 2,
            "budget_ms": 100,
            "kernels": ["dense", "masked", "quantized_int8"],
            "layers": [
                {"layer": 0, "d": 8, "h": 4,
                 "cost_ratio": 3.0, "cost_ratio_serial": 3.5, "alpha_star": 0.3333,
                 "kernel_costs": {"dense": 1.0, "masked": 3.0, "quantized_int8": 0.4}}
            ]
        }"#;
        let p = MachineProfile::parse(text).unwrap();
        assert!(p.kernels.contains(&"quantized_int8".to_string()));
        let lt = &p.layers[0];
        assert!(lt.kernel_costs.iter().any(|(k, v)| k == "quantized_int8" && *v == 0.4));
        // The unknown column cannot influence routing in this binary…
        let policy = lt.policy();
        assert_eq!(policy.columns().len(), 2, "{:?}", policy.columns());
        assert!((policy.cost_ratio() - 3.0).abs() < 1e-12);
        // …but survives the round-trip for the newer binary that wrote it.
        let back = MachineProfile::parse(&p.to_json().to_string()).unwrap();
        assert_eq!(back, p);
        // And this binary's registry flags nothing missing for its own set
        // minus what the file lacks.
        assert_eq!(
            p.missing_kernel_columns(&[KernelId::DENSE, KernelId::MASKED]),
            Vec::<KernelId>::new()
        );
    }

    /// Satellite: a profile missing a registered kernel's column is *not*
    /// rejected — the gap is reported so serve recalibrates just that
    /// column.
    #[test]
    fn missing_kernel_column_is_reported_for_recalibration() {
        // A pre-registry profile: no kernel_costs at all → dense+masked
        // derived, every later kernel (packed, SIMD, int8) missing.
        let text = r#"{
            "version": 1,
            "fingerprint": "mlp:8-4-2",
            "hardware": "x86_64-linux",
            "threads": 2,
            "budget_ms": 100,
            "layers": [
                {"layer": 0, "d": 8, "h": 4, "cost_ratio": 3.0}
            ]
        }"#;
        let p = MachineProfile::parse(text).unwrap();
        assert_eq!(
            p.missing_kernel_columns(BUILTIN_KERNELS),
            vec![
                KernelId::DENSE_PACKED,
                KernelId::DENSE_SIMD,
                KernelId::DENSE_I8,
                KernelId::MASKED_SIMD,
                KernelId::MASKED_I8,
            ]
        );
        // A partially-columned registry profile: one layer lacks masked.
        let text = r#"{
            "version": 1,
            "fingerprint": "mlp:8-4-2",
            "hardware": "x86_64-linux",
            "threads": 2,
            "budget_ms": 100,
            "layers": [
                {"layer": 0, "d": 8, "h": 4, "cost_ratio": 3.0,
                 "kernel_costs": {"dense": 1.0, "dense_packed": 0.95}}
            ]
        }"#;
        let p = MachineProfile::parse(text).unwrap();
        assert_eq!(
            p.missing_kernel_columns(BUILTIN_KERNELS),
            vec![
                KernelId::DENSE_SIMD,
                KernelId::DENSE_I8,
                KernelId::MASKED,
                KernelId::MASKED_SIMD,
                KernelId::MASKED_I8,
            ]
        );
        // The legacy ratio still anchors the masked fallback column.
        assert!((p.layers[0].cost_ratio - 3.0).abs() < 1e-12);
        assert_eq!(p.layers[0].policy().per_flop(KernelId::MASKED), Some(3.0));
        // An empty profile has nothing missing (nothing to serve either).
        let empty = MachineProfile { layers: vec![], ..p };
        assert!(empty.missing_kernel_columns(BUILTIN_KERNELS).is_empty());
    }

    #[test]
    fn missing_required_fields_and_bad_version_are_rejected() {
        assert!(MachineProfile::parse(r#"{"fingerprint": "mlp:1", "layers": []}"#).is_err());
        assert!(MachineProfile::parse(r#"{"version": 1, "layers": []}"#).is_err());
        assert!(MachineProfile::parse(r#"{"version": 99, "fingerprint": "m", "layers": []}"#)
            .is_err());
        assert!(MachineProfile::parse(
            r#"{"version": 1, "fingerprint": "m", "layers": [{"layer": 0}]}"#
        )
        .is_err());
    }

    #[test]
    fn file_roundtrip_and_fingerprint_check() {
        let p = sample();
        let path = std::env::temp_dir().join(format!(
            "condcomp-profile-test-{}.json",
            std::process::id()
        ));
        p.save(&path).unwrap();
        // Matching model loads…
        let loaded = MachineProfile::load_for_model(&path, &[784, 256, 128, 10]).unwrap();
        assert_eq!(loaded, p);
        // …a different architecture is rejected outright.
        let err = MachineProfile::load_for_model(&path, &[784, 300, 128, 10]).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn policy_table_carries_per_layer_thresholds() {
        let p = sample();
        let table = p.policy_table(2, "profile.json");
        assert_eq!(table.calibrated_layers(), 2);
        let t = table.thresholds();
        assert!((t[0] - 0.36).abs() < 1e-12, "α*₀ {t:?}");
        assert!((t[1] - 0.2).abs() < 1e-12, "α*₁ {t:?}");
        // At α = 0.3 the two layers disagree — the whole point of the table
        // (and layer 0's dense regime routes to the cheaper packed kernel).
        // Float-class allow-list: the int8 ids are opt-in and their
        // optimistic uncalibrated defaults would otherwise win the argmin.
        let float_kernels = [
            KernelId::DENSE,
            KernelId::DENSE_PACKED,
            KernelId::DENSE_SIMD,
            KernelId::MASKED,
            KernelId::MASKED_SIMD,
        ];
        assert_eq!(
            table.policy_for(0).decide(64, 784, 256, 0.3, &float_kernels),
            KernelId::MASKED
        );
        assert_eq!(
            table.policy_for(1).decide(64, 256, 128, 0.3, &float_kernels),
            KernelId::DENSE
        );
        assert_eq!(
            table.policy_for(0).decide(64, 784, 256, 0.9, &float_kernels),
            KernelId::DENSE_PACKED
        );
    }

    #[test]
    fn fingerprints_are_shape_sensitive() {
        assert_eq!(model_fingerprint(&[784, 256, 10]), "mlp:784-256-10");
        assert_ne!(model_fingerprint(&[784, 256, 10]), model_fingerprint(&[784, 255, 10]));
    }
}
