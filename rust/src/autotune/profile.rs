//! Persistent machine profiles: the per-layer dispatch thresholds fitted by
//! the autotune harness, serialized as JSON so a machine measures once and
//! every later `condcomp serve` start just loads the file.
//!
//! A profile is bound to a *model shape* (the fingerprint — calibration
//! depends only on the per-layer `d × h` shapes, not the weight values) and
//! annotated with a *hardware descriptor* (arch/OS/thread count) so a file
//! copied between machines is at least visibly foreign. Loading rejects a
//! fingerprint mismatch outright; unknown JSON fields are tolerated, so
//! newer writers (e.g. a future multi-backend router adding another cost
//! column) stay readable by older binaries.

use crate::condcomp::{DispatchPolicy, PolicyTable};
use crate::io::json::Json;
use anyhow::Result;
use std::path::Path;

/// Schema version written into every profile; readers accept this version
/// only (the format is young — no compatibility shims yet).
pub const PROFILE_SCHEMA_VERSION: u64 = 1;

/// One hidden layer's fitted calibration result.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerThreshold {
    /// Hidden-layer index (weight-matrix index; the output layer is never
    /// dispatched conditionally).
    pub layer: usize,
    /// Layer input width `d`.
    pub d: usize,
    /// Layer output width `h`.
    pub h: usize,
    /// Fitted masked-vs-dense per-FLOP cost ratio on the serving pool.
    pub cost_ratio: f64,
    /// The same ratio fitted single-threaded (recorded for diagnosis — the
    /// dispatch threshold uses `cost_ratio`).
    pub cost_ratio_serial: f64,
    /// The flip point `α* = clamp(1/cost_ratio, 0, 1)`: masked wins below.
    pub alpha_star: f64,
}

impl LayerThreshold {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("layer", Json::Num(self.layer as f64)),
            ("d", Json::Num(self.d as f64)),
            ("h", Json::Num(self.h as f64)),
            ("cost_ratio", Json::Num(self.cost_ratio)),
            ("cost_ratio_serial", Json::Num(self.cost_ratio_serial)),
            ("alpha_star", Json::Num(self.alpha_star)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<LayerThreshold, String> {
        let need_usize = |key: &str| {
            v.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("layer entry missing integer '{key}'"))
        };
        let need_f64 = |key: &str| {
            v.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("layer entry missing number '{key}'"))
        };
        let cost_ratio = need_f64("cost_ratio")?;
        if !cost_ratio.is_finite() || cost_ratio <= 0.0 {
            return Err(format!("layer entry has invalid cost_ratio {cost_ratio}"));
        }
        // Writers that skip the serial arm record only the pooled ratio;
        // default to it (keeps summaries and equality NaN-free).
        let cost_ratio_serial = match v.get("cost_ratio_serial").and_then(Json::as_f64) {
            Some(r) if r.is_finite() && r > 0.0 => r,
            Some(r) => return Err(format!("layer entry has invalid cost_ratio_serial {r}")),
            None => cost_ratio,
        };
        Ok(LayerThreshold {
            layer: need_usize("layer")?,
            d: need_usize("d")?,
            h: need_usize("h")?,
            cost_ratio,
            cost_ratio_serial,
            // α* is derivable state: recompute from the ratio so a
            // hand-edited file cannot make the displayed threshold disagree
            // with the one dispatch actually uses.
            alpha_star: DispatchPolicy::with_cost_ratio(cost_ratio).density_threshold(),
        })
    }

    /// The dispatch policy this fit implies.
    pub fn policy(&self) -> DispatchPolicy {
        DispatchPolicy::with_cost_ratio(self.cost_ratio)
    }
}

/// A persisted machine profile: which model (fingerprint), which machine
/// (hardware descriptor + pool size), and the per-layer thresholds.
#[derive(Clone, Debug, PartialEq)]
pub struct MachineProfile {
    pub version: u64,
    /// Model-shape fingerprint, e.g. `mlp:784-256-128-64-10`.
    pub fingerprint: String,
    /// Hardware descriptor, e.g. `x86_64-linux`.
    pub hardware: String,
    /// Pool threads the pooled ratios were measured on.
    pub threads: usize,
    /// Wall-clock budget the calibration ran under (ms).
    pub budget_ms: u64,
    pub layers: Vec<LayerThreshold>,
}

/// Fingerprint a model by its layer widths — the only thing calibration
/// depends on.
pub fn model_fingerprint(layer_sizes: &[usize]) -> String {
    let widths: Vec<String> = layer_sizes.iter().map(|w| w.to_string()).collect();
    format!("mlp:{}", widths.join("-"))
}

/// Describe the machine the measurement ran on.
pub fn hardware_descriptor() -> String {
    format!("{}-{}", std::env::consts::ARCH, std::env::consts::OS)
}

impl MachineProfile {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::Num(self.version as f64)),
            ("fingerprint", Json::Str(self.fingerprint.clone())),
            ("hardware", Json::Str(self.hardware.clone())),
            ("threads", Json::Num(self.threads as f64)),
            ("budget_ms", Json::Num(self.budget_ms as f64)),
            (
                "layers",
                Json::Arr(self.layers.iter().map(LayerThreshold::to_json).collect()),
            ),
        ])
    }

    /// Parse from JSON text. Unknown fields are ignored; missing required
    /// fields and a wrong schema version are errors.
    pub fn parse(text: &str) -> Result<MachineProfile, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        let version = v
            .get("version")
            .and_then(Json::as_usize)
            .ok_or("profile missing 'version'")? as u64;
        if version != PROFILE_SCHEMA_VERSION {
            return Err(format!(
                "profile schema version {version} != supported {PROFILE_SCHEMA_VERSION}"
            ));
        }
        let fingerprint = v
            .get("fingerprint")
            .and_then(Json::as_str)
            .ok_or("profile missing 'fingerprint'")?
            .to_string();
        let hardware = v
            .get("hardware")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();
        let threads = v.get("threads").and_then(Json::as_usize).unwrap_or(0);
        let budget_ms = v.get("budget_ms").and_then(Json::as_usize).unwrap_or(0) as u64;
        let layers = v
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or("profile missing 'layers'")?
            .iter()
            .map(LayerThreshold::from_json)
            .collect::<Result<Vec<_>, String>>()?;
        Ok(MachineProfile { version, fingerprint, hardware, threads, budget_ms, layers })
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<MachineProfile> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        MachineProfile::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))
    }

    /// Load and verify the profile describes this model's shapes; a
    /// fingerprint mismatch is rejected (the thresholds would be for the
    /// wrong `d × h` grid).
    pub fn load_for_model(path: &Path, layer_sizes: &[usize]) -> Result<MachineProfile> {
        let profile = MachineProfile::load(path)?;
        profile.ensure_matches_model(layer_sizes)?;
        Ok(profile)
    }

    /// The fingerprint check as an error (shared by [`Self::load_for_model`]
    /// and the backend's `apply_profile`, so the rule and its message live
    /// in one place).
    pub fn ensure_matches_model(&self, layer_sizes: &[usize]) -> Result<()> {
        if !self.matches_model(layer_sizes) {
            return Err(anyhow::anyhow!(
                "machine profile fingerprint '{}' does not match model '{}'",
                self.fingerprint,
                model_fingerprint(layer_sizes)
            ));
        }
        Ok(())
    }

    /// Write to a file (pretty enough: one JSON document, trailing newline).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut text = self.to_json().to_string();
        text.push('\n');
        std::fs::write(path, text)
            .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
        Ok(())
    }

    /// Whether this profile describes a model with these layer widths.
    pub fn matches_model(&self, layer_sizes: &[usize]) -> bool {
        self.fingerprint == model_fingerprint(layer_sizes)
    }

    /// Build the runtime [`PolicyTable`] for a model with `num_layers`
    /// hidden layers; `source` is remembered for the fallback warning.
    pub fn policy_table(&self, num_layers: usize, source: &str) -> PolicyTable {
        let mut table = PolicyTable::uncalibrated(num_layers).with_profile_path(source);
        for lt in &self.layers {
            table.set_layer(lt.layer, lt.policy());
        }
        table
    }

    /// Human-readable per-layer report (the `calibrate` CLI prints this).
    pub fn summary_lines(&self) -> Vec<String> {
        let mut lines = vec![
            format!(
                "machine profile: {} on {} ({} threads, budget {} ms)",
                self.fingerprint, self.hardware, self.threads, self.budget_ms
            ),
            format!(
                "{:<7} {:>11} {:>12} {:>14} {:>10}",
                "layer", "shape", "cost-ratio", "ratio-serial", "α*"
            ),
        ];
        for lt in &self.layers {
            lines.push(format!(
                "{:<7} {:>11} {:>12.3} {:>14.3} {:>10.4}",
                lt.layer,
                format!("{}×{}", lt.d, lt.h),
                lt.cost_ratio,
                lt.cost_ratio_serial,
                lt.alpha_star
            ));
        }
        lines
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::condcomp::Kernel;

    fn sample() -> MachineProfile {
        MachineProfile {
            version: PROFILE_SCHEMA_VERSION,
            fingerprint: model_fingerprint(&[784, 256, 128, 10]),
            hardware: hardware_descriptor(),
            threads: 4,
            budget_ms: 500,
            layers: vec![
                LayerThreshold {
                    layer: 0,
                    d: 784,
                    h: 256,
                    cost_ratio: 2.5,
                    cost_ratio_serial: 3.25,
                    alpha_star: 0.4,
                },
                LayerThreshold {
                    layer: 1,
                    d: 256,
                    h: 128,
                    cost_ratio: 5.0,
                    cost_ratio_serial: 4.0,
                    alpha_star: 0.2,
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let p = sample();
        let text = p.to_json().to_string();
        let back = MachineProfile::parse(&text).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn unknown_fields_are_tolerated() {
        // A future writer adds fields at both the profile and layer level;
        // this reader must still load the parts it understands.
        let text = r#"{
            "version": 1,
            "fingerprint": "mlp:8-4-2",
            "hardware": "x86_64-linux",
            "threads": 2,
            "budget_ms": 100,
            "future_backend_costs": {"pjrt": [1.0, 2.0]},
            "layers": [
                {"layer": 0, "d": 8, "h": 4,
                 "cost_ratio": 3.0, "cost_ratio_serial": 3.5,
                 "alpha_star": 0.3333, "pjrt_cost_ratio": 1.5}
            ]
        }"#;
        let p = MachineProfile::parse(text).unwrap();
        assert_eq!(p.fingerprint, "mlp:8-4-2");
        assert_eq!(p.layers.len(), 1);
        assert_eq!(p.layers[0].cost_ratio, 3.0);
    }

    #[test]
    fn missing_required_fields_and_bad_version_are_rejected() {
        assert!(MachineProfile::parse(r#"{"fingerprint": "mlp:1", "layers": []}"#).is_err());
        assert!(MachineProfile::parse(r#"{"version": 1, "layers": []}"#).is_err());
        assert!(MachineProfile::parse(r#"{"version": 99, "fingerprint": "m", "layers": []}"#)
            .is_err());
        assert!(MachineProfile::parse(
            r#"{"version": 1, "fingerprint": "m", "layers": [{"layer": 0}]}"#
        )
        .is_err());
    }

    #[test]
    fn file_roundtrip_and_fingerprint_check() {
        let p = sample();
        let path = std::env::temp_dir().join(format!(
            "condcomp-profile-test-{}.json",
            std::process::id()
        ));
        p.save(&path).unwrap();
        // Matching model loads…
        let loaded = MachineProfile::load_for_model(&path, &[784, 256, 128, 10]).unwrap();
        assert_eq!(loaded, p);
        // …a different architecture is rejected outright.
        let err = MachineProfile::load_for_model(&path, &[784, 300, 128, 10]).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn policy_table_carries_per_layer_thresholds() {
        let p = sample();
        let table = p.policy_table(2, "profile.json");
        assert_eq!(table.calibrated_layers(), 2);
        let t = table.thresholds();
        assert!((t[0] - 0.4).abs() < 1e-12, "α*₀ {t:?}");
        assert!((t[1] - 0.2).abs() < 1e-12, "α*₁ {t:?}");
        // At α = 0.3 the two layers disagree — the whole point of the table.
        assert_eq!(table.policy_for(0).decide(64, 784, 256, 0.3), Kernel::MaskedParallel);
        assert_eq!(table.policy_for(1).decide(64, 256, 128, 0.3), Kernel::DenseParallel);
    }

    #[test]
    fn fingerprints_are_shape_sensitive() {
        assert_eq!(model_fingerprint(&[784, 256, 10]), "mlp:784-256-10");
        assert_ne!(model_fingerprint(&[784, 256, 10]), model_fingerprint(&[784, 255, 10]));
    }
}
