//! Autotune: per-layer dispatch calibration with persistent machine
//! profiles.
//!
//! The paper's speedup claim holds only below a masked-vs-dense flip
//! density `α*`, and that flip point is a property of the *machine* and the
//! *layer shape* — the original single global cost ratio ignored that
//! different `d × h` shapes have different cache behaviour. This subsystem
//! measures the flip point per layer and persists it:
//!
//! - [`harness`] — the microbenchmark harness ([`Autotuner`]): times
//!   **every registered compute kernel** per layer shape (dense-work kernels
//!   once, α-scaled kernels across a density grid) under a wall-clock
//!   budget, and fits one per-FLOP cost column each relative to the dense
//!   baseline (timing is abstracted behind [`CostModel`] so tests inject
//!   synthetic cost surfaces).
//! - [`profile`] — [`MachineProfile`]: model fingerprint + hardware
//!   descriptor + measured kernel-id set + per-layer [`LayerThreshold`]s
//!   (one `kernel_costs` column per kernel), serialized via `io::json`.
//!   `condcomp calibrate` writes it; `condcomp serve` loads it at startup
//!   (falling back to online calibration, then to the per-kernel defaults)
//!   and installs it as the backend's [`crate::condcomp::PolicyTable`]. A
//!   profile missing a column for a newly registered kernel triggers
//!   recalibration of **just that column**.
//!
//! Config keys: `autotune.profile_path` (where the profile lives) and
//! `autotune.budget_ms` (calibration wall-clock budget). The profile format
//! tolerates unknown fields — including cost columns for kernels this
//! binary has never heard of — so newer writers stay readable.

pub mod harness;
pub mod profile;

pub use harness::{Autotuner, CostModel, MeasuredCost};
pub use profile::{
    hardware_descriptor, model_fingerprint, LayerThreshold, MachineProfile,
    PROFILE_SCHEMA_VERSION,
};
