//! Autotune: per-layer dispatch calibration with persistent machine
//! profiles.
//!
//! The paper's speedup claim holds only below a masked-vs-dense flip
//! density `α*`, and that flip point is a property of the *machine* and the
//! *layer shape* — the original single global cost ratio ignored that
//! different `d × h` shapes have different cache behaviour. This subsystem
//! measures the flip point per layer and persists it:
//!
//! - [`harness`] — the microbenchmark harness ([`Autotuner`]): times
//!   dense-parallel vs masked-parallel per layer shape across a density
//!   grid and thread counts under a wall-clock budget, and fits a per-layer
//!   cost ratio (timing is abstracted behind [`CostModel`] so tests inject
//!   synthetic cost surfaces).
//! - [`profile`] — [`MachineProfile`]: model fingerprint + hardware
//!   descriptor + per-layer [`LayerThreshold`]s, serialized via `io::json`.
//!   `condcomp calibrate` writes it; `condcomp serve` loads it at startup
//!   (falling back to online calibration, then to the global default) and
//!   installs it as the backend's
//!   [`crate::condcomp::PolicyTable`].
//!
//! Config keys: `autotune.profile_path` (where the profile lives) and
//! `autotune.budget_ms` (calibration wall-clock budget). The profile format
//! tolerates unknown fields, so future backends (the multi-backend router)
//! can contribute additional cost columns to the same file without breaking
//! older readers.

pub mod harness;
pub mod profile;

pub use harness::{Autotuner, CostModel, MeasuredCost};
pub use profile::{
    hardware_descriptor, model_fingerprint, LayerThreshold, MachineProfile,
    PROFILE_SCHEMA_VERSION,
};
