//! The calibration microbenchmark harness.
//!
//! For each hidden-layer shape `d × h` of a model, the harness times **every
//! registered compute kernel** (see [`crate::condcomp::KernelRegistry`]) and
//! fits one per-FLOP cost column each, relative to the plain dense axpy
//! baseline:
//!
//! - dense-work kernels (`dense`, `dense_packed`, …) are α-independent: one
//!   best-of timing per shape; the column is `t_kernel / t_dense`.
//! - α-scaled kernels (`masked`) are timed across a density grid and fitted
//!   by least squares through the origin (masked time is linear in α:
//!   `t(α) ≈ c · α · 2ndh`); the column is the fitted per-FLOP cost over the
//!   dense per-FLOP cost — the classic `cost_ratio`.
//!
//! The whole run is bounded by a wall-clock budget (`autotune.budget_ms`),
//! split evenly across measurement points; each point takes the best of as
//! many repetitions as fit its slice (at least one).
//!
//! Timing lives behind the [`CostModel`] trait so tests inject a synthetic
//! cost surface and exercise the fitting math deterministically;
//! [`MeasuredCost`] is the real implementation: it runs each kernel through
//! the **registry** and an [`ExecCtx`] (full-pool lease by default), so
//! calibration exercises exactly the dispatch path the serving executors
//! run — what gets tuned is what gets served.

use super::profile::{
    hardware_descriptor, model_fingerprint, LayerThreshold, MachineProfile,
    PROFILE_SCHEMA_VERSION,
};
use crate::condcomp::registry::LayerOperands;
use crate::condcomp::{DispatchPolicy, KernelId, KernelRegistry, MaskedLayer};
use crate::exec::ExecCtx;
use crate::linalg::{Mat, QuantizedLayer};
use crate::parallel::ThreadPool;
use crate::util::{Pcg32, Timer};

/// Where a kernel's timing numbers come from: the real registry kernels
/// ([`MeasuredCost`]) or a synthetic surface injected by tests.
pub trait CostModel {
    /// Seconds for one forward of `kernel` on an `n × d → h` layer at mask
    /// density `alpha` (dense-work kernels ignore `alpha`). Non-finite or
    /// non-positive returns make the fit fall back to the kernel's default
    /// cost.
    fn seconds(&mut self, kernel: KernelId, n: usize, d: usize, h: usize, alpha: f64) -> f64;
}

/// Runs the real kernels through the registry and an [`ExecCtx`],
/// best-of-reps within a per-point budget. Measuring through the ctx — not a
/// raw pool — means calibration exercises exactly the code path dispatch
/// will later take on the serving executors (same lease-width chunking, same
/// kernel entry points).
pub struct MeasuredCost<'a> {
    ctx: ExecCtx<'a>,
    registry: KernelRegistry,
    /// Wall-clock allowance per measurement point (seconds).
    point_budget_s: f64,
    /// Repetitions guaranteed even when the budget is tiny.
    min_reps: usize,
    seed: u64,
}

/// Hard per-point repetition cap: the budget is the intended bound; this is
/// the backstop against sub-microsecond kernels spinning thousands of reps.
const MAX_REPS: usize = 64;

/// Best-of timing: repeat `f` until the point budget is spent (but at
/// least `min_reps` and at most [`MAX_REPS`] times), return the minimum.
fn best_of(point_budget_s: f64, min_reps: usize, mut f: impl FnMut()) -> f64 {
    let window = Timer::start();
    let mut best = f64::INFINITY;
    let mut reps = 0usize;
    loop {
        let t = Timer::start();
        f();
        best = best.min(t.elapsed_s());
        reps += 1;
        if reps >= MAX_REPS || (reps >= min_reps && window.elapsed_s() >= point_budget_s) {
            return best;
        }
    }
}

impl<'a> MeasuredCost<'a> {
    /// Measure over a full-pool lease on `pool` (the `condcomp calibrate` /
    /// serve-startup warm-up path).
    pub fn new(pool: &'a ThreadPool, point_budget_s: f64, min_reps: usize, seed: u64) -> Self {
        MeasuredCost::over(ExecCtx::full(pool), point_budget_s, min_reps, seed)
    }

    /// Measure through a caller-supplied ctx (e.g. a specific lease width).
    pub fn over(ctx: ExecCtx<'a>, point_budget_s: f64, min_reps: usize, seed: u64) -> Self {
        MeasuredCost {
            ctx,
            registry: KernelRegistry::builtin(),
            point_budget_s,
            min_reps: min_reps.max(1),
            seed,
        }
    }

    /// Replace the registry (e.g. to measure an embedder's custom kernel).
    pub fn with_registry(mut self, registry: KernelRegistry) -> Self {
        self.registry = registry;
        self
    }

    fn rng_for(&self, n: usize, d: usize, h: usize) -> Pcg32 {
        // Deterministic per shape, so every kernel arm of one layer times
        // the same operand values.
        Pcg32::new(self.seed, (n as u64) << 42 ^ (d as u64) << 21 ^ h as u64)
    }
}

impl CostModel for MeasuredCost<'_> {
    fn seconds(&mut self, kernel: KernelId, n: usize, d: usize, h: usize, alpha: f64) -> f64 {
        // The fit's dense baseline must stay measurable even when the
        // configured registry is an allow-list view that excludes it
        // (`--kernels dense_packed,masked`): fall back to the builtin set
        // for in-tree ids. A kernel registered nowhere is unmeasurable —
        // the fit then uses its work-model default.
        let builtin;
        let kernel = match self.registry.get(kernel) {
            Some(k) => k,
            None => {
                builtin = KernelRegistry::builtin();
                match builtin.get(kernel) {
                    Some(k) => k,
                    None => return f64::INFINITY,
                }
            }
        };
        let mut rng = self.rng_for(n, d, h);
        let a = Mat::randn(n, d, 0.5, &mut rng);
        let w = Mat::randn(d, h, 0.05, &mut rng);
        let bias = vec![0.0f32; h];
        let layer = MaskedLayer::new(&w, &bias);
        // Quantize once, outside the timed region — mirroring serving, where
        // the backend prepares the int8 form at model load, so the i8
        // columns measure the forward, not the (amortized-away) quantize.
        let quant = QuantizedLayer::new(&layer.wt, &layer.bias);
        // Dense-work kernels compute every cell regardless of the mask; the
        // full mask keeps their gating pass honest without starving it.
        let mask = if kernel.id().work().scales_with_alpha() {
            Mat::from_fn(n, h, |_, _| if rng.bernoulli(alpha as f32) { 1.0 } else { 0.0 })
        } else {
            Mat::full(n, h, 1.0)
        };
        let ops = LayerOperands::new(&w, &layer).with_quant(&quant);
        let mut out = Mat::zeros(n, h);
        let (budget, reps) = (self.point_budget_s, self.min_reps);
        // One span per measurement point, tagged with the kernel id — so a
        // traced calibration shows up in the same observability plane as
        // serving (`span_autotune_measure_<id>` series).
        let sp = self
            .ctx
            .metrics()
            .span_with("autotune_measure", Some(kernel.id().as_str()));
        let ctx = &mut self.ctx;
        let best = best_of(budget, reps, || {
            let _ = kernel.run(&ops, &a, &mask, &mut *ctx, &mut out);
        });
        drop(sp);
        best
    }
}

/// The harness configuration + entry points.
#[derive(Clone, Debug)]
pub struct Autotuner {
    /// Total wall-clock budget for one whole-model calibration (ms).
    pub budget_ms: u64,
    /// Densities measured per α-scaled kernel per layer (the fit's sample
    /// points).
    pub alpha_grid: Vec<f64>,
    /// Batch rows used by the microbenchmarks (a typical serving batch).
    pub batch: usize,
    /// Repetitions guaranteed per point even when the budget is tiny.
    pub min_reps: usize,
    /// Also fit the single-threaded arm (`cost_ratio_serial`, a persisted
    /// diagnostic). Dispatch only consumes the pooled numbers, so callers
    /// that discard the profile — serve's online calibration — turn this off
    /// and spend the whole budget on the numbers that matter.
    pub fit_serial: bool,
    /// Kernel-id set to fit one cost column each for. Defaults to the
    /// builtin registry; `condcomp calibrate --kernels` and the targeted
    /// missing-column recalibration narrow it. [`KernelId::DENSE`] is always
    /// measured — it is the baseline every column is relative to.
    pub kernels: Vec<KernelId>,
}

impl Default for Autotuner {
    fn default() -> Autotuner {
        Autotuner {
            budget_ms: 2000,
            alpha_grid: vec![0.05, 0.25, 0.5, 1.0],
            batch: 64,
            min_reps: 2,
            fit_serial: true,
            kernels: KernelRegistry::builtin().ids(),
        }
    }
}

impl Autotuner {
    /// Default grid/batch under an explicit budget.
    pub fn with_budget_ms(budget_ms: u64) -> Autotuner {
        Autotuner { budget_ms, ..Autotuner::default() }
    }

    /// The kernel set actually fitted: the configured set with the dense
    /// baseline forced in, canonical order.
    fn fit_set(&self) -> Vec<KernelId> {
        let mut set = self.kernels.clone();
        if !set.contains(&KernelId::DENSE) {
            set.push(KernelId::DENSE);
        }
        set.sort_by_key(|k| k.priority());
        set.dedup();
        set
    }

    /// Whether the serial diagnostic arm runs: it fits the masked-vs-dense
    /// ratio, so it only makes sense (and only costs budget) when the
    /// masked kernel is in the configured set.
    fn serial_arm(&self) -> bool {
        self.fit_serial && self.fit_set().contains(&KernelId::MASKED)
    }

    /// Measurement points one layer costs under this configuration (the
    /// budget is split evenly across all points of all layers).
    fn points_per_layer(&self) -> usize {
        self.fit_set()
            .iter()
            .map(|k| {
                if k.work().scales_with_alpha() {
                    self.alpha_grid.len()
                } else {
                    1
                }
            })
            .sum()
    }

    /// Fit one shape's per-kernel per-FLOP cost columns from a cost model.
    /// Pure arithmetic over the model's numbers: dense-work kernels get
    /// `t_kernel / t_dense`; α-scaled kernels get the least-squares slope of
    /// `t(α) ≈ c · α · F` over the grid (`c = Σ tᵢαᵢ / (F · Σ αᵢ²)`) divided
    /// by the dense per-FLOP cost. Degenerate timings fall back to the
    /// kernel's work-model default.
    pub fn fit_kernel_costs(
        &self,
        model: &mut dyn CostModel,
        n: usize,
        d: usize,
        h: usize,
    ) -> Vec<(KernelId, f64)> {
        let set = self.fit_set();
        let flops = 2.0 * (n as f64) * (d as f64) * (h as f64);
        let t_dense = model.seconds(KernelId::DENSE, n, d, h, 1.0);
        let dense_ok = t_dense.is_finite() && t_dense > 0.0 && flops > 0.0;
        let dense_per_flop = if dense_ok { t_dense / flops } else { 0.0 };
        let mut columns = Vec::with_capacity(set.len());
        for k in set {
            let rel = if !dense_ok {
                k.work().default_per_flop()
            } else if !k.work().scales_with_alpha() {
                // α-independent kernels (float and int8 dense classes): one
                // best-of timing, column = t_kernel / t_dense.
                if k == KernelId::DENSE {
                    1.0
                } else {
                    let t = model.seconds(k, n, d, h, 1.0);
                    if t.is_finite() && t > 0.0 {
                        t / t_dense
                    } else {
                        k.work().default_per_flop()
                    }
                }
            } else {
                // α-scaled kernels (float and int8 masked classes):
                // least-squares slope over the density grid.
                let (mut num, mut den) = (0.0f64, 0.0f64);
                for &alpha in &self.alpha_grid {
                    let t = model.seconds(k, n, d, h, alpha);
                    if t.is_finite() && t > 0.0 && alpha > 0.0 {
                        num += t * alpha;
                        den += alpha * alpha;
                    }
                }
                if num <= 0.0 || den <= 0.0 {
                    k.work().default_per_flop()
                } else {
                    ((num / (den * flops)) / dense_per_flop).max(1e-6)
                }
            };
            columns.push((k, rel));
        }
        columns
    }

    /// Fit one shape's masked-vs-dense per-FLOP cost ratio (the legacy
    /// binary form — what `cost_ratio_serial` and old callers consume).
    pub fn fit_cost_ratio(
        &self,
        model: &mut dyn CostModel,
        n: usize,
        d: usize,
        h: usize,
    ) -> f64 {
        let masked_only = Autotuner {
            kernels: vec![KernelId::DENSE, KernelId::MASKED],
            ..self.clone()
        };
        let columns = masked_only.fit_kernel_costs(model, n, d, h);
        columns
            .iter()
            .find(|(k, _)| *k == KernelId::MASKED)
            .map(|(_, c)| *c)
            .unwrap_or(DispatchPolicy::DEFAULT_COST_RATIO)
    }

    /// Fit one hidden layer from injected cost models (`par` at the serving
    /// thread count, `serial` single-threaded; `None` skips the serial arm
    /// and records the pooled masked ratio in its place).
    pub fn fit_layer(
        &self,
        layer: usize,
        d: usize,
        h: usize,
        par: &mut dyn CostModel,
        serial: Option<&mut dyn CostModel>,
    ) -> LayerThreshold {
        let n = self.batch.max(1);
        let columns = self.fit_kernel_costs(par, n, d, h);
        // The serial arm diagnoses the masked ratio only — skip it (and its
        // measurement cost) when the masked kernel is not being fitted.
        let cost_ratio_serial = match serial {
            Some(model) if self.serial_arm() => Some(self.fit_cost_ratio(model, n, d, h)),
            _ => None,
        };
        LayerThreshold::from_kernel_costs(
            layer,
            d,
            h,
            columns
                .into_iter()
                .map(|(k, c)| (k.as_str().to_string(), c))
                .collect(),
            cost_ratio_serial,
        )
    }

    /// Fit every shape with injected cost models (tests, synthetic sweeps).
    pub fn fit_shapes(
        &self,
        shapes: &[(usize, usize)],
        par: &mut dyn CostModel,
        mut serial: Option<&mut dyn CostModel>,
    ) -> Vec<LayerThreshold> {
        let mut fitted = Vec::with_capacity(shapes.len());
        for (l, &(d, h)) in shapes.iter().enumerate() {
            fitted.push(self.fit_layer(l, d, h, &mut *par, serial.as_deref_mut()));
        }
        fitted
    }

    /// The hidden-layer shapes of a model given its layer widths: weight
    /// layers `0..len-2` run the conditional path (the output layer never
    /// does).
    pub fn hidden_shapes(layer_sizes: &[usize]) -> Vec<(usize, usize)> {
        (0..layer_sizes.len().saturating_sub(2))
            .map(|l| (layer_sizes[l], layer_sizes[l + 1]))
            .collect()
    }

    /// Measure and fit every hidden layer of a model on this machine,
    /// producing a persistable [`MachineProfile`] with one cost column per
    /// configured kernel. The budget is split evenly over all measurement
    /// points (per layer: one timing per dense-work kernel, one per α per
    /// α-scaled kernel, plus the serial arm's dense + masked-grid points
    /// when it runs). Kernels are looked up in the builtin registry; use
    /// [`Self::calibrate_model_on`] to measure an embedder's custom set.
    pub fn calibrate_model(&self, layer_sizes: &[usize], pool: &ThreadPool) -> MachineProfile {
        self.calibrate_model_on(layer_sizes, pool, &KernelRegistry::builtin())
    }

    /// [`Self::calibrate_model`] measuring through an explicit registry —
    /// what [`crate::coordinator::NativeBackend`] passes so custom
    /// registrants get *measured* columns, not work-model defaults.
    pub fn calibrate_model_on(
        &self,
        layer_sizes: &[usize],
        pool: &ThreadPool,
        registry: &KernelRegistry,
    ) -> MachineProfile {
        let shapes = Autotuner::hidden_shapes(layer_sizes);
        // The serial arm costs one dense + one-per-α masked timing per
        // layer, independent of the kernel set (it fits the masked ratio),
        // and only runs when masked is being fitted.
        let serial_points = if self.serial_arm() { 1 + self.alpha_grid.len() } else { 0 };
        let total_points = (shapes.len() * (self.points_per_layer() + serial_points)).max(1);
        let point_budget_s = (self.budget_ms as f64 / 1e3) / total_points as f64;

        let mut par = MeasuredCost::new(pool, point_budget_s, self.min_reps, 0xA7_70_7E)
            .with_registry(registry.clone());
        let serial_pool = if self.serial_arm() { Some(ThreadPool::new(1)) } else { None };
        let mut serial = serial_pool
            .as_ref()
            .map(|p| {
                MeasuredCost::new(p, point_budget_s, self.min_reps, 0xA7_70_7E)
                    .with_registry(registry.clone())
            });
        let layers = self.fit_shapes(
            &shapes,
            &mut par,
            serial.as_mut().map(|m| m as &mut dyn CostModel),
        );

        MachineProfile {
            version: PROFILE_SCHEMA_VERSION,
            fingerprint: model_fingerprint(layer_sizes),
            hardware: hardware_descriptor(),
            threads: pool.threads(),
            budget_ms: self.budget_ms,
            kernels: self
                .fit_set()
                .iter()
                .map(|k| k.as_str().to_string())
                .collect(),
            layers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::condcomp::BUILTIN_KERNELS;

    /// A synthetic cost surface where the masked kernel's per-FLOP penalty
    /// depends on the layer shape (wide-input layers pay 8×, square ones 2×)
    /// and the packed GEMM runs 10% faster per FLOP everywhere. Exactly
    /// linear in α, so the fit must recover the ratios precisely.
    struct SyntheticCost;

    fn ratio_for(d: usize, h: usize) -> f64 {
        if d > h { 8.0 } else { 2.0 }
    }

    impl CostModel for SyntheticCost {
        fn seconds(&mut self, kernel: KernelId, n: usize, d: usize, h: usize, alpha: f64) -> f64 {
            let dense = 2.0 * (n * d * h) as f64 * 1e-10;
            if kernel == KernelId::MASKED {
                alpha * ratio_for(d, h) * dense
            } else if kernel == KernelId::DENSE_PACKED {
                0.9 * dense
            } else {
                dense
            }
        }
    }

    #[test]
    fn fit_recovers_a_linear_cost_surface_exactly() {
        let tuner = Autotuner::default();
        let r = tuner.fit_cost_ratio(&mut SyntheticCost, 64, 512, 512);
        assert!((r - 2.0).abs() < 1e-9, "square-shape ratio {r}");
        let r = tuner.fit_cost_ratio(&mut SyntheticCost, 64, 1024, 256);
        assert!((r - 8.0).abs() < 1e-9, "wide-input ratio {r}");
    }

    /// The registry-era fit: one column per kernel, the packed column
    /// recovered relative to dense, and the derived threshold moved by it.
    #[test]
    fn fit_emits_one_column_per_registered_kernel() {
        let tuner = Autotuner::default();
        let columns = tuner.fit_kernel_costs(&mut SyntheticCost, 64, 512, 512);
        assert_eq!(
            columns.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
            KernelRegistry::builtin().ids()
        );
        assert!(columns.len() >= BUILTIN_KERNELS.len());
        let get = |id: KernelId| columns.iter().find(|(k, _)| *k == id).unwrap().1;
        assert!((get(KernelId::DENSE) - 1.0).abs() < 1e-9);
        assert!((get(KernelId::DENSE_PACKED) - 0.9).abs() < 1e-9);
        assert!((get(KernelId::MASKED) - 2.0).abs() < 1e-9);
        let lt = tuner.fit_layer(0, 512, 512, &mut SyntheticCost, None);
        // α* = cheapest dense per-FLOP (0.9, packed) / masked (2.0).
        assert!((lt.alpha_star - 0.45).abs() < 1e-9, "{lt:?}");
        assert_eq!(lt.policy().preferred_dense(), KernelId::DENSE_PACKED);
    }

    /// The acceptance criterion: with an injected synthetic cost model, two
    /// layers with different shapes get different α* values, and dispatch
    /// decisions at the same density differ between them.
    #[test]
    fn two_shapes_yield_two_thresholds_and_different_decisions() {
        // Restrict to the binary kernel pair so the classic thresholds
        // (1/2, 1/8) come out exactly.
        let tuner = Autotuner {
            kernels: vec![KernelId::DENSE, KernelId::MASKED],
            ..Autotuner::default()
        };
        let shapes = [(256usize, 256usize), (1024, 128)]; // square vs wide
        let fitted = tuner.fit_shapes(&shapes, &mut SyntheticCost, Some(&mut SyntheticCost));
        assert_eq!(fitted.len(), 2);
        assert!((fitted[0].alpha_star - 0.5).abs() < 1e-9, "{:?}", fitted[0]);
        assert!((fitted[1].alpha_star - 0.125).abs() < 1e-9, "{:?}", fitted[1]);

        let profile = MachineProfile {
            version: PROFILE_SCHEMA_VERSION,
            fingerprint: model_fingerprint(&[256, 256, 1024, 128]),
            hardware: hardware_descriptor(),
            threads: 1,
            budget_ms: 0,
            kernels: vec!["dense".into(), "masked".into()],
            layers: fitted,
        };
        let table = profile.policy_table(2, "synthetic");
        // α between the two thresholds: layer 0 stays masked, layer 1 goes
        // dense — per-layer dispatch in action.
        // Allow-list only the calibrated pair: the uncalibrated int8 class
        // runs on optimistic defaults and would (by design — it is opt-in)
        // undercut these measured columns if allowed in.
        let allowed = [KernelId::DENSE, KernelId::MASKED];
        let alpha = 0.3;
        assert_eq!(
            table.policy_for(0).decide(64, 256, 256, alpha, &allowed),
            KernelId::MASKED
        );
        assert_eq!(
            table.policy_for(1).decide(64, 1024, 128, alpha, &allowed),
            KernelId::DENSE
        );
        assert_ne!(table.thresholds()[0], table.thresholds()[1]);
    }

    #[test]
    fn skipping_the_serial_arm_records_the_pooled_ratio() {
        let tuner = Autotuner::default();
        let lt = tuner.fit_layer(0, 256, 256, &mut SyntheticCost, None);
        assert_eq!(lt.cost_ratio_serial, lt.cost_ratio);
        assert!((lt.cost_ratio - 2.0).abs() < 1e-9);
    }

    #[test]
    fn degenerate_models_fall_back_to_the_default_costs() {
        struct ZeroCost;
        impl CostModel for ZeroCost {
            fn seconds(&mut self, _: KernelId, _: usize, _: usize, _: usize, _: f64) -> f64 {
                0.0
            }
        }
        let tuner = Autotuner::default();
        let r = tuner.fit_cost_ratio(&mut ZeroCost, 8, 8, 8);
        assert_eq!(r, DispatchPolicy::DEFAULT_COST_RATIO);
        // Every column degrades to its work model's default.
        let columns = tuner.fit_kernel_costs(&mut ZeroCost, 8, 8, 8);
        for (k, c) in columns {
            assert_eq!(c, k.work().default_per_flop(), "{k}");
        }
    }

    #[test]
    fn hidden_shapes_exclude_the_output_layer() {
        assert_eq!(
            Autotuner::hidden_shapes(&[784, 256, 128, 10]),
            vec![(784, 256), (256, 128)]
        );
        assert!(Autotuner::hidden_shapes(&[784, 10]).is_empty());
        assert!(Autotuner::hidden_shapes(&[]).is_empty());
    }

    /// Real-kernel smoke: tiny shapes, tiny budget; checks structure and
    /// sanity, not performance.
    #[test]
    fn measured_calibration_produces_a_complete_profile() {
        let tuner = Autotuner {
            budget_ms: 40,
            alpha_grid: vec![0.25, 1.0],
            batch: 8,
            min_reps: 1,
            fit_serial: true,
            kernels: KernelRegistry::builtin().ids(),
        };
        let pool = ThreadPool::new(2);
        let layer_sizes = [24usize, 20, 16, 6];
        let profile = tuner.calibrate_model(&layer_sizes, &pool);
        assert_eq!(profile.fingerprint, model_fingerprint(&layer_sizes));
        assert_eq!(profile.threads, 2);
        assert_eq!(profile.layers.len(), 2);
        // One cost column per registered kernel, per layer — the CI smoke's
        // in-crate counterpart.
        let want_kernels: Vec<String> = KernelRegistry::builtin()
            .ids()
            .iter()
            .map(|k| k.as_str().to_string())
            .collect();
        assert_eq!(profile.kernels, want_kernels);
        assert!(profile.missing_kernel_columns(&KernelRegistry::builtin().ids()).is_empty());
        for (l, lt) in profile.layers.iter().enumerate() {
            assert_eq!(lt.layer, l);
            assert_eq!((lt.d, lt.h), (layer_sizes[l], layer_sizes[l + 1]));
            assert!(lt.cost_ratio.is_finite() && lt.cost_ratio > 0.0);
            assert!(lt.cost_ratio_serial.is_finite() && lt.cost_ratio_serial > 0.0);
            assert!((0.0..=1.0).contains(&lt.alpha_star));
            assert_eq!(lt.kernel_costs.len(), want_kernels.len());
            for (name, cost) in &lt.kernel_costs {
                assert!(cost.is_finite() && *cost > 0.0, "{name}: {cost}");
            }
        }
        // And it round-trips through the persistence layer.
        let back = MachineProfile::parse(&profile.to_json().to_string()).unwrap();
        assert_eq!(back, profile);
    }

    /// Regression: with an allow-list registry that excludes `dense`
    /// (`--kernels dense_packed,masked`), the fit's dense baseline must
    /// still be *measured* (builtin fallback), not degrade every column to
    /// its work-model default.
    #[test]
    fn measured_cost_measures_the_dense_baseline_through_a_restricted_registry() {
        let pool = ThreadPool::new(1);
        let restricted = KernelRegistry::builtin()
            .restricted(&[KernelId::DENSE_PACKED, KernelId::MASKED])
            .unwrap();
        let mut model = MeasuredCost::new(&pool, 0.0, 1, 7).with_registry(restricted);
        let t = model.seconds(KernelId::DENSE, 8, 8, 8, 1.0);
        assert!(t.is_finite() && t > 0.0, "dense baseline measurable: {t}");
        // A kernel registered nowhere stays unmeasurable (→ fit defaults).
        let t = model.seconds(KernelId::new("quantum"), 8, 8, 8, 1.0);
        assert!(t.is_infinite());
    }

    /// Targeted recalibration input: a subset fit measures only the named
    /// kernels (plus the dense baseline) — what serve runs when a profile
    /// is missing one column.
    #[test]
    fn subset_fit_measures_only_the_requested_kernels() {
        let tuner = Autotuner {
            kernels: vec![KernelId::DENSE_PACKED],
            ..Autotuner::default()
        };
        let columns = tuner.fit_kernel_costs(&mut SyntheticCost, 32, 64, 64);
        assert_eq!(
            columns.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
            vec![KernelId::DENSE, KernelId::DENSE_PACKED],
            "dense baseline forced in, nothing else"
        );
        assert!((columns[1].1 - 0.9).abs() < 1e-9);
    }
}
