//! The calibration microbenchmark harness.
//!
//! For each hidden-layer shape `d × h` of a model, the harness times the
//! dense-parallel GEMM against the masked-parallel kernel across a density
//! grid and up to two thread counts (the serving pool's size, plus a
//! single-threaded diagnostic arm when `fit_serial` is on), fits the
//! masked kernel's per-FLOP cost by least squares through the origin
//! (masked time is linear in α: `t(α) ≈ c · α · 2ndh`), and derives the
//! per-layer flip threshold `α* = 1/cost_ratio`. The whole run is bounded
//! by a wall-clock budget (`autotune.budget_ms`), split evenly across
//! measurement points; each point takes the best of as many repetitions as
//! fit its slice (at least one).
//!
//! Timing lives behind the [`CostModel`] trait so tests (and the
//! acceptance criterion's "two shapes → two thresholds" assertion) can
//! inject a synthetic cost surface and exercise the fitting math
//! deterministically; [`MeasuredCost`] is the real-kernel implementation,
//! and it measures through an [`ExecCtx`] (full-pool lease by default) so
//! calibration exercises exactly the leased code path the serving
//! executors run — what gets tuned is what gets served.

use super::profile::{
    hardware_descriptor, model_fingerprint, LayerThreshold, MachineProfile,
    PROFILE_SCHEMA_VERSION,
};
use crate::condcomp::{DispatchPolicy, MaskedLayer};
use crate::exec::ExecCtx;
use crate::linalg::{matmul_into_ctx, Mat};
use crate::parallel::ThreadPool;
use crate::util::{Pcg32, Timer};

/// Where a layer's timing numbers come from: the real kernels
/// ([`MeasuredCost`]) or a synthetic model injected by tests.
pub trait CostModel {
    /// Seconds for one dense-parallel forward of an `n × d → h` layer.
    fn dense_seconds(&mut self, n: usize, d: usize, h: usize) -> f64;
    /// Seconds for one masked-parallel forward at mask density `alpha`.
    fn masked_seconds(&mut self, n: usize, d: usize, h: usize, alpha: f64) -> f64;
}

/// Runs the real kernels through an [`ExecCtx`], best-of-reps within a
/// per-point budget. Measuring through the ctx — not a raw pool — means
/// calibration exercises exactly the code path dispatch will later take on
/// the serving executors (same lease-width chunking, same kernel entry
/// points).
pub struct MeasuredCost<'a> {
    ctx: ExecCtx<'a>,
    /// Wall-clock allowance per measurement point (seconds).
    point_budget_s: f64,
    /// Repetitions guaranteed even when the budget is tiny.
    min_reps: usize,
    seed: u64,
}

/// Hard per-point repetition cap: the budget is the intended bound; this is
/// the backstop against sub-microsecond kernels spinning thousands of reps.
const MAX_REPS: usize = 64;

/// Best-of timing: repeat `f` until the point budget is spent (but at
/// least `min_reps` and at most [`MAX_REPS`] times), return the minimum.
fn best_of(point_budget_s: f64, min_reps: usize, mut f: impl FnMut()) -> f64 {
    let window = Timer::start();
    let mut best = f64::INFINITY;
    let mut reps = 0usize;
    loop {
        let t = Timer::start();
        f();
        best = best.min(t.elapsed_s());
        reps += 1;
        if reps >= MAX_REPS || (reps >= min_reps && window.elapsed_s() >= point_budget_s) {
            return best;
        }
    }
}

impl<'a> MeasuredCost<'a> {
    /// Measure over a full-pool lease on `pool` (the `condcomp calibrate` /
    /// serve-startup warm-up path).
    pub fn new(pool: &'a ThreadPool, point_budget_s: f64, min_reps: usize, seed: u64) -> Self {
        MeasuredCost::over(ExecCtx::full(pool), point_budget_s, min_reps, seed)
    }

    /// Measure through a caller-supplied ctx (e.g. a specific lease width).
    pub fn over(ctx: ExecCtx<'a>, point_budget_s: f64, min_reps: usize, seed: u64) -> Self {
        MeasuredCost { ctx, point_budget_s, min_reps: min_reps.max(1), seed }
    }

    fn rng_for(&self, n: usize, d: usize, h: usize) -> Pcg32 {
        // Deterministic per shape, so dense and masked arms of one layer
        // time the same operand values.
        Pcg32::new(self.seed, (n as u64) << 42 ^ (d as u64) << 21 ^ h as u64)
    }
}

impl CostModel for MeasuredCost<'_> {
    fn dense_seconds(&mut self, n: usize, d: usize, h: usize) -> f64 {
        let mut rng = self.rng_for(n, d, h);
        let a = Mat::randn(n, d, 0.5, &mut rng);
        let w = Mat::randn(d, h, 0.05, &mut rng);
        let mut out = Mat::zeros(n, h);
        let (budget, reps) = (self.point_budget_s, self.min_reps);
        let ctx = &mut self.ctx;
        best_of(budget, reps, || matmul_into_ctx(&a, &w, &mut out, &mut *ctx))
    }

    fn masked_seconds(&mut self, n: usize, d: usize, h: usize, alpha: f64) -> f64 {
        let mut rng = self.rng_for(n, d, h);
        let a = Mat::randn(n, d, 0.5, &mut rng);
        let w = Mat::randn(d, h, 0.05, &mut rng);
        let bias = vec![0.0f32; h];
        let layer = MaskedLayer::new(&w, &bias);
        let mask = Mat::from_fn(n, h, |_, _| {
            if rng.bernoulli(alpha as f32) { 1.0 } else { 0.0 }
        });
        let mut out = Mat::zeros(n, h);
        let (budget, reps) = (self.point_budget_s, self.min_reps);
        let ctx = &mut self.ctx;
        best_of(budget, reps, || {
            let _ = layer.forward_masked_ctx(&a, &mask, &mut out, &mut *ctx);
        })
    }
}

/// The harness configuration + entry points.
#[derive(Clone, Debug)]
pub struct Autotuner {
    /// Total wall-clock budget for one whole-model calibration (ms).
    pub budget_ms: u64,
    /// Densities measured per layer (the fit's sample points).
    pub alpha_grid: Vec<f64>,
    /// Batch rows used by the microbenchmarks (a typical serving batch).
    pub batch: usize,
    /// Repetitions guaranteed per point even when the budget is tiny.
    pub min_reps: usize,
    /// Also fit the single-threaded arm (`cost_ratio_serial`, a persisted
    /// diagnostic). Dispatch only consumes the pooled ratio, so callers that
    /// discard the profile — serve's online calibration — turn this off and
    /// spend the whole budget on the numbers that matter.
    pub fit_serial: bool,
}

impl Default for Autotuner {
    fn default() -> Autotuner {
        Autotuner {
            budget_ms: 2000,
            alpha_grid: vec![0.05, 0.25, 0.5, 1.0],
            batch: 64,
            min_reps: 2,
            fit_serial: true,
        }
    }
}

impl Autotuner {
    /// Default grid/batch under an explicit budget.
    pub fn with_budget_ms(budget_ms: u64) -> Autotuner {
        Autotuner { budget_ms, ..Autotuner::default() }
    }

    /// Fit one shape's masked-vs-dense per-FLOP cost ratio from a cost
    /// model. Pure arithmetic over the model's numbers: the dense per-FLOP
    /// cost comes from one α-independent timing; the masked per-FLOP cost is
    /// the least-squares slope of `t(α) ≈ c · α · F` over the grid
    /// (`c = Σ tᵢαᵢ / (F · Σ αᵢ²)`).
    pub fn fit_cost_ratio(
        &self,
        model: &mut dyn CostModel,
        n: usize,
        d: usize,
        h: usize,
    ) -> f64 {
        let flops = 2.0 * (n as f64) * (d as f64) * (h as f64);
        let t_dense = model.dense_seconds(n, d, h);
        if !t_dense.is_finite() || t_dense <= 0.0 || flops <= 0.0 {
            return DispatchPolicy::DEFAULT_COST_RATIO;
        }
        let dense_per_flop = t_dense / flops;
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for &alpha in &self.alpha_grid {
            let t = model.masked_seconds(n, d, h, alpha);
            if t.is_finite() && alpha > 0.0 {
                num += t * alpha;
                den += alpha * alpha;
            }
        }
        if num <= 0.0 || den <= 0.0 {
            return DispatchPolicy::DEFAULT_COST_RATIO;
        }
        let masked_per_flop = num / (den * flops);
        (masked_per_flop / dense_per_flop).max(1e-6)
    }

    /// Fit one hidden layer from injected cost models (`par` at the serving
    /// thread count, `serial` single-threaded; `None` skips the serial arm
    /// and records the pooled ratio in its place).
    pub fn fit_layer(
        &self,
        layer: usize,
        d: usize,
        h: usize,
        par: &mut dyn CostModel,
        serial: Option<&mut dyn CostModel>,
    ) -> LayerThreshold {
        let n = self.batch.max(1);
        let cost_ratio = self.fit_cost_ratio(par, n, d, h);
        let cost_ratio_serial = match serial {
            Some(model) => self.fit_cost_ratio(model, n, d, h),
            None => cost_ratio,
        };
        LayerThreshold {
            layer,
            d,
            h,
            cost_ratio,
            cost_ratio_serial,
            alpha_star: DispatchPolicy::with_cost_ratio(cost_ratio).density_threshold(),
        }
    }

    /// Fit every shape with injected cost models (tests, synthetic sweeps).
    pub fn fit_shapes(
        &self,
        shapes: &[(usize, usize)],
        par: &mut dyn CostModel,
        mut serial: Option<&mut dyn CostModel>,
    ) -> Vec<LayerThreshold> {
        let mut fitted = Vec::with_capacity(shapes.len());
        for (l, &(d, h)) in shapes.iter().enumerate() {
            fitted.push(self.fit_layer(l, d, h, &mut *par, serial.as_deref_mut()));
        }
        fitted
    }

    /// The hidden-layer shapes of a model given its layer widths: weight
    /// layers `0..len-2` run the conditional path (the output layer never
    /// does).
    pub fn hidden_shapes(layer_sizes: &[usize]) -> Vec<(usize, usize)> {
        (0..layer_sizes.len().saturating_sub(2))
            .map(|l| (layer_sizes[l], layer_sizes[l + 1]))
            .collect()
    }

    /// Measure and fit every hidden layer of a model on this machine,
    /// producing a persistable [`MachineProfile`]. The budget is split
    /// evenly over all measurement points (per layer: one dense + one
    /// masked-per-α timing, per thread arm — the serial arm only when
    /// `fit_serial` is on).
    pub fn calibrate_model(&self, layer_sizes: &[usize], pool: &ThreadPool) -> MachineProfile {
        let shapes = Autotuner::hidden_shapes(layer_sizes);
        let arms = if self.fit_serial { 2 } else { 1 };
        let points_per_layer = arms * (1 + self.alpha_grid.len());
        let total_points = (shapes.len() * points_per_layer).max(1);
        let point_budget_s = (self.budget_ms as f64 / 1e3) / total_points as f64;

        let mut par = MeasuredCost::new(pool, point_budget_s, self.min_reps, 0xA7_70_7E);
        let serial_pool = if self.fit_serial { Some(ThreadPool::new(1)) } else { None };
        let mut serial = serial_pool
            .as_ref()
            .map(|p| MeasuredCost::new(p, point_budget_s, self.min_reps, 0xA7_70_7E));
        let layers = self.fit_shapes(
            &shapes,
            &mut par,
            serial.as_mut().map(|m| m as &mut dyn CostModel),
        );

        MachineProfile {
            version: PROFILE_SCHEMA_VERSION,
            fingerprint: model_fingerprint(layer_sizes),
            hardware: hardware_descriptor(),
            threads: pool.threads(),
            budget_ms: self.budget_ms,
            layers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::condcomp::Kernel;

    /// A synthetic cost surface where the masked kernel's per-FLOP penalty
    /// depends on the layer shape: wide-input layers pay 8×, square ones 2×.
    /// Exactly linear in α, so the fit must recover the ratios precisely.
    struct SyntheticCost;

    fn ratio_for(d: usize, h: usize) -> f64 {
        if d > h { 8.0 } else { 2.0 }
    }

    impl CostModel for SyntheticCost {
        fn dense_seconds(&mut self, n: usize, d: usize, h: usize) -> f64 {
            2.0 * (n * d * h) as f64 * 1e-10
        }

        fn masked_seconds(&mut self, n: usize, d: usize, h: usize, alpha: f64) -> f64 {
            alpha * ratio_for(d, h) * 2.0 * (n * d * h) as f64 * 1e-10
        }
    }

    #[test]
    fn fit_recovers_a_linear_cost_surface_exactly() {
        let tuner = Autotuner::default();
        let r = tuner.fit_cost_ratio(&mut SyntheticCost, 64, 512, 512);
        assert!((r - 2.0).abs() < 1e-9, "square-shape ratio {r}");
        let r = tuner.fit_cost_ratio(&mut SyntheticCost, 64, 1024, 256);
        assert!((r - 8.0).abs() < 1e-9, "wide-input ratio {r}");
    }

    /// The acceptance criterion: with an injected synthetic cost model, two
    /// layers with different shapes get different α* values, and dispatch
    /// decisions at the same density differ between them.
    #[test]
    fn two_shapes_yield_two_thresholds_and_different_decisions() {
        let tuner = Autotuner::default();
        let shapes = [(256usize, 256usize), (1024, 128)]; // square vs wide
        let fitted = tuner.fit_shapes(&shapes, &mut SyntheticCost, Some(&mut SyntheticCost));
        assert_eq!(fitted.len(), 2);
        assert!((fitted[0].alpha_star - 0.5).abs() < 1e-9, "{:?}", fitted[0]);
        assert!((fitted[1].alpha_star - 0.125).abs() < 1e-9, "{:?}", fitted[1]);

        let profile = MachineProfile {
            version: PROFILE_SCHEMA_VERSION,
            fingerprint: model_fingerprint(&[256, 256, 1024, 128]),
            hardware: hardware_descriptor(),
            threads: 1,
            budget_ms: 0,
            layers: fitted,
        };
        let table = profile.policy_table(2, "synthetic");
        // α between the two thresholds: layer 0 stays masked, layer 1 goes
        // dense — per-layer dispatch in action.
        let alpha = 0.3;
        assert_eq!(
            table.policy_for(0).decide(64, 256, 256, alpha),
            Kernel::MaskedParallel
        );
        assert_eq!(
            table.policy_for(1).decide(64, 1024, 128, alpha),
            Kernel::DenseParallel
        );
        assert_ne!(table.thresholds()[0], table.thresholds()[1]);
    }

    #[test]
    fn skipping_the_serial_arm_records_the_pooled_ratio() {
        let tuner = Autotuner::default();
        let lt = tuner.fit_layer(0, 256, 256, &mut SyntheticCost, None);
        assert_eq!(lt.cost_ratio_serial, lt.cost_ratio);
        assert!((lt.cost_ratio - 2.0).abs() < 1e-9);
    }

    #[test]
    fn degenerate_models_fall_back_to_the_default_ratio() {
        struct ZeroCost;
        impl CostModel for ZeroCost {
            fn dense_seconds(&mut self, _: usize, _: usize, _: usize) -> f64 {
                0.0
            }
            fn masked_seconds(&mut self, _: usize, _: usize, _: usize, _: f64) -> f64 {
                0.0
            }
        }
        let tuner = Autotuner::default();
        let r = tuner.fit_cost_ratio(&mut ZeroCost, 8, 8, 8);
        assert_eq!(r, DispatchPolicy::DEFAULT_COST_RATIO);
    }

    #[test]
    fn hidden_shapes_exclude_the_output_layer() {
        assert_eq!(
            Autotuner::hidden_shapes(&[784, 256, 128, 10]),
            vec![(784, 256), (256, 128)]
        );
        assert!(Autotuner::hidden_shapes(&[784, 10]).is_empty());
        assert!(Autotuner::hidden_shapes(&[]).is_empty());
    }

    /// Real-kernel smoke: tiny shapes, tiny budget; checks structure and
    /// sanity, not performance.
    #[test]
    fn measured_calibration_produces_a_complete_profile() {
        let tuner = Autotuner {
            budget_ms: 40,
            alpha_grid: vec![0.25, 1.0],
            batch: 8,
            min_reps: 1,
            fit_serial: true,
        };
        let pool = ThreadPool::new(2);
        let layer_sizes = [24usize, 20, 16, 6];
        let profile = tuner.calibrate_model(&layer_sizes, &pool);
        assert_eq!(profile.fingerprint, model_fingerprint(&layer_sizes));
        assert_eq!(profile.threads, 2);
        assert_eq!(profile.layers.len(), 2);
        for (l, lt) in profile.layers.iter().enumerate() {
            assert_eq!(lt.layer, l);
            assert_eq!((lt.d, lt.h), (layer_sizes[l], layer_sizes[l + 1]));
            assert!(lt.cost_ratio.is_finite() && lt.cost_ratio > 0.0);
            assert!(lt.cost_ratio_serial.is_finite() && lt.cost_ratio_serial > 0.0);
            assert!((0.0..=1.0).contains(&lt.alpha_star));
        }
        // And it round-trips through the persistence layer.
        let back = MachineProfile::parse(&profile.to_json().to_string()).unwrap();
        assert_eq!(back, profile);
    }
}
