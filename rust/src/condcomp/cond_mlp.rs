//! The estimator-augmented network forward: estimator mask → masked GEMM per
//! hidden layer, dense output layer — the deployable version of the paper's
//! system, with exact FLOP accounting.

use super::flops::{FlopBreakdown, LayerFlops};
use super::masked_gemm::MaskedLayer;
use crate::estimator::SignEstimatorSet;
use crate::linalg::Mat;
use crate::nn::activations::argmax_rows;
use crate::nn::mlp::{add_bias, Mlp};

/// An MLP compiled for conditional execution: transposed weight copies for
/// the masked GEMM plus a reference to the estimator set.
pub struct CondMlp<'a> {
    pub layers: Vec<MaskedLayer>,
    pub estimators: &'a SignEstimatorSet,
    /// Scratch: rank per layer, for FLOP accounting.
    ranks: Vec<usize>,
}

impl<'a> CondMlp<'a> {
    /// Prepare from a trained network and a fitted estimator set.
    pub fn compile(net: &Mlp, estimators: &'a SignEstimatorSet) -> CondMlp<'a> {
        assert_eq!(
            estimators.layers.len(),
            net.depth() - 1,
            "estimator set does not cover every hidden layer"
        );
        CondMlp {
            layers: (0..net.depth())
                .map(|l| MaskedLayer::new(&net.weights[l], &net.biases[l]))
                .collect(),
            estimators,
            ranks: estimators.ranks(),
        }
    }

    /// Conditional forward. Returns logits and the per-layer FLOP breakdown
    /// (hidden layers conditional, output layer dense — §4.1).
    pub fn forward(&self, x: &Mat) -> (Mat, FlopBreakdown) {
        let mut flops = FlopBreakdown::default();
        let depth = self.layers.len();
        let mut a = x.clone();
        for l in 0..depth - 1 {
            let est = &self.estimators.layers[l];
            let mask = est.mask(&a);
            let layer = &self.layers[l];
            let (out, computed) = layer.forward_masked(&a, &mask);
            flops.push(LayerFlops::from_counts(
                a.rows(),
                layer.in_dim(),
                layer.out_dim(),
                self.ranks[l],
                computed,
            ));
            a = out;
        }
        // Output layer: dense (never estimated).
        let last = &self.layers[depth - 1];
        let n = a.rows();
        let mut logits = crate::linalg::matmul(&a, &self.layers[depth - 1].wt.transpose());
        add_bias(&mut logits, &last.bias);
        flops.push(LayerFlops::from_counts(
            n,
            last.in_dim(),
            last.out_dim(),
            0,
            n * last.out_dim(),
        ));
        (logits, flops)
    }

    /// Predicted classes via the conditional path.
    pub fn predict(&self, x: &Mat) -> Vec<usize> {
        argmax_rows(&self.forward(x).0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EstimatorConfig, NetConfig};
    use crate::util::Pcg32;

    fn setup(rank: &[usize]) -> (Mlp, SignEstimatorSet, Mat) {
        let mut rng = Pcg32::seeded(3);
        let net = Mlp::init(
            &NetConfig { layers: vec![12, 16, 14, 5], weight_sigma: 0.4, bias_init: 0.1 },
            &mut rng,
        );
        let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(rank), 7);
        let x = Mat::randn(9, 12, 1.0, &mut rng);
        (net, est, x)
    }

    /// The conditional engine must produce *identical* logits to the dense
    /// forward gated by the same estimator (they are two implementations of
    /// the same function: one skips the work, one masks it afterwards).
    #[test]
    fn conditional_equals_gated_dense() {
        for ranks in [&[3usize, 3][..], &[8, 8][..], &[16, 14][..]] {
            let (net, est, x) = setup(ranks);
            let cond = CondMlp::compile(&net, &est);
            let (logits, _) = cond.forward(&x);
            let dense_gated = net.logits(&x, &est);
            assert!(
                logits.max_abs_diff(&dense_gated) < 1e-4,
                "ranks {ranks:?}: conditional and gated-dense disagree by {}",
                logits.max_abs_diff(&dense_gated)
            );
        }
    }

    #[test]
    fn full_rank_conditional_matches_control_output() {
        let (net, est, x) = setup(&[16, 14]);
        let cond = CondMlp::compile(&net, &est);
        let control = net.logits(&x, &crate::nn::mlp::NoGater);
        let (logits, _) = cond.forward(&x);
        assert!(logits.max_abs_diff(&control) < 1e-3);
    }

    #[test]
    fn flops_reflect_sparsity() {
        let (net, est, x) = setup(&[4, 4]);
        let cond = CondMlp::compile(&net, &est);
        let (_, flops) = cond.forward(&x);
        assert_eq!(flops.layers.len(), 3);
        // Hidden layers: conditional < dense (since some units are gated).
        for l in &flops.layers[..2] {
            assert!(l.conditional <= l.dense);
            assert!(l.density() <= 1.0);
        }
        // Output layer is dense: computed == total.
        let out = &flops.layers[2];
        assert_eq!(out.computed_units, out.total_units);
        assert_eq!(out.estimator, 0);
    }

    #[test]
    fn predictions_agree_with_gated_dense_path() {
        let (net, est, x) = setup(&[8, 8]);
        let cond = CondMlp::compile(&net, &est);
        assert_eq!(cond.predict(&x), crate::nn::activations::argmax_rows(&net.logits(&x, &est)));
    }
}
