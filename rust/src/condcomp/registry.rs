//! The kernel registry: an open set of compute kernels behind stable ids,
//! routed per layer per batch by the cost table in [`super::dispatch`].
//!
//! Before this module, the dispatch choice was a hard-coded binary — masked
//! vs dense — and every new compute path (packed GEMM, PJRT, quantized)
//! would have needed its own if-ladder in the backend. Now a kernel is an
//! object-safe [`ComputeKernel`]: it computes one hidden layer's
//! `σ(x·W + b) ⊙ S` through a caller-owned [`ExecCtx`] and reports how many
//! dot products it evaluated (the §3.4 FLOP accounting input). The
//! [`KernelRegistry`] maps [`KernelId`]s to implementations; the
//! [`crate::autotune::Autotuner`] measures every registered kernel per layer
//! shape and emits one machine-profile cost column each; the
//! [`super::DispatchPolicy`] argmin routes each batch to the cheapest
//! registered-and-allowed kernel.
//!
//! In-tree registrants ([`KernelRegistry::builtin`]):
//!
//! - `dense` — the axpy GEMM ([`crate::linalg::matmul_into_ctx`]), mask
//!   applied afterwards; every dot product computed.
//! - `dense_packed` — the A-panel-packing GEMM
//!   ([`crate::linalg::matmul_into_packed_ctx`]): same accumulation order,
//!   **bit-identical** to `dense`, different memory behaviour (faster on
//!   wide-input layers).
//! - `dense_simd` — the explicitly vectorized (AVX2/NEON, runtime-detected)
//!   fused-axpy GEMM ([`crate::linalg::matmul_into_simd_ctx`]):
//!   **tolerance-tier** against `dense` (fused accumulation), bit-identical
//!   across its own ISA paths and thread counts.
//! - `masked` — the dot-product kernel
//!   ([`MaskedLayer::forward_masked_ctx`]): computes only predicted-live
//!   entries.
//! - `masked_simd` — the masked kernel with vectorized dot products
//!   ([`MaskedLayer::forward_masked_simd_ctx`]): identical mask selection
//!   and counts, **tolerance-tier** values against `masked`.
//! - `dense_i8` / `masked_i8` — the int8 arithmetic class
//!   ([`crate::linalg::QuantizedLayer`]): per-row-scale quantized weights
//!   and activations, exact integer dots. **Sign-agreement tier** against
//!   the float oracles — the quantization error is bounded but real, so
//!   these kernels are *excluded from default routing* and selected only
//!   when an operator allow-lists them explicitly
//!   ([`KernelRegistry::default_routable`]).
//! - `pjrt` — a feature-gated slot (`--features pjrt`) that registers only
//!   when the real xla bindings replace `vendor/xla-stub`; until device
//!   execution lands it delegates to the dense path so the column is
//!   measurable end to end.
//!
//! Numeric contract — scoped by each kernel's declared [`EquivalenceTier`]:
//! a [`EquivalenceTier::BitExact`] kernel reproduces its serial oracle
//! bitwise (`dense`/`dense_packed` vs [`crate::linalg::matmul_into`],
//! `masked` vs [`MaskedLayer::forward_masked_into`]) for any thread count or
//! lease width; a [`EquivalenceTier::Tolerance`] kernel (the SIMD pair)
//! matches its oracle within the declared ULP bound, while remaining
//! bit-identical to *itself* across thread counts, lease widths and ISA
//! paths; a [`EquivalenceTier::SignAgree`] kernel (the int8 pair) promises
//! activation-pattern agreement with its oracle outside a near-zero band —
//! values drift by quantization error — and is still bit-identical to
//! itself everywhere (integer arithmetic is exact). Routing among
//! *default-routable* kernels changes wall-clock — and at most
//! tolerance-tier last bits — never correctness; routing onto the int8
//! class is an explicit operator opt-in to the sign-agreement contract.

use super::dispatch::KernelId;
use super::masked_gemm::{relu_gate, MaskedLayer};
use crate::exec::ExecCtx;
use crate::linalg::{
    matmul_into_ctx, matmul_into_packed_ctx, matmul_into_simd_ctx, Mat, QuantizedLayer, SimdCaps,
};
use crate::nn::mlp::add_bias;
use crate::util::ulp::{ulp_diff, within_tolerance};
use std::sync::Arc;

/// How closely a kernel's output is guaranteed to match its serial oracle —
/// the contract the equivalence test suites enforce per kernel, and the
/// scope of the serve e2e bit-identity invariant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EquivalenceTier {
    /// Bit-for-bit identical to the serial oracle at any thread count or
    /// lease width (same accumulation order).
    BitExact,
    /// Within the given ULP bound of the serial oracle (different
    /// accumulation order — e.g. fused multiply-adds or wider accumulator
    /// banks), with an absolute floor of `ulps · ε` near zero for
    /// ReLU-boundary sign flips. Still bit-identical to *itself* across
    /// thread counts, lease widths and ISA paths.
    Tolerance(u32),
    /// Aggregate, not elementwise: among oracle entries whose magnitude
    /// exceeds the near-zero band ([`QUANT_SIGN_BAND_REL`] × the oracle's
    /// max magnitude), the fraction whose *activation sign* (`> 0` after
    /// ReLU + mask) matches must be at least this many basis points (e.g.
    /// `9900` = 99%). Values are allowed to drift by quantization error —
    /// the int8 kernels' contract: the sign estimator only needs signs.
    /// Still bit-identical to *itself* across thread counts, lease widths
    /// and ISA paths (exact integer arithmetic).
    SignAgree(u32),
}

/// The near-zero band for [`EquivalenceTier::SignAgree`], relative to the
/// oracle output's max magnitude: entries this close to the ReLU boundary
/// may legitimately flip under quantization and are excluded from the
/// agreement count.
pub const QUANT_SIGN_BAND_REL: f32 = 0.02;

/// The agreement floor (basis points) the int8 kernels declare: ≥ 99% of
/// out-of-band activation signs must match the float oracle's.
pub const QUANT_TIER_AGREEMENT_BP: u32 = 9900;

/// The [`EquivalenceTier::SignAgree`] aggregate check (see the variant doc).
fn check_sign_agreement(floor_bp: u32, got: &[f32], want: &[f32]) -> Result<(), String> {
    let max_abs = want.iter().fold(0.0f32, |m, &w| m.max(w.abs()));
    let band = max_abs * QUANT_SIGN_BAND_REL;
    let mut eligible = 0usize;
    let mut agree = 0usize;
    for (&g, &w) in got.iter().zip(want) {
        if w.abs() <= band {
            continue;
        }
        eligible += 1;
        if (g > 0.0) == (w > 0.0) {
            agree += 1;
        }
    }
    if eligible == 0 {
        return Ok(());
    }
    let rate = agree as f64 / eligible as f64;
    let floor = floor_bp as f64 / 10_000.0;
    if rate + 1e-9 >= floor {
        Ok(())
    } else {
        Err(format!(
            "SignAgree({floor_bp}) violated: {agree}/{eligible} signs agree \
             ({rate:.4} < floor {floor:.4}) outside the ±{band:.3e} band"
        ))
    }
}

impl EquivalenceTier {
    /// Verify `got` against the oracle `want` under this tier. `Ok(())` or
    /// a message pinpointing the first violation (or, for the aggregate
    /// sign-agreement tier, the failing rate).
    pub fn check(&self, got: &[f32], want: &[f32]) -> Result<(), String> {
        if got.len() != want.len() {
            return Err(format!("length mismatch: {} vs {}", got.len(), want.len()));
        }
        if let EquivalenceTier::SignAgree(floor_bp) = self {
            return check_sign_agreement(*floor_bp, got, want);
        }
        for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
            let ok = match self {
                EquivalenceTier::BitExact => g.to_bits() == w.to_bits(),
                EquivalenceTier::Tolerance(ulps) => within_tolerance(g, w, *ulps),
                EquivalenceTier::SignAgree(_) => unreachable!("handled above"),
            };
            if !ok {
                return Err(format!(
                    "{self:?} violated at [{i}]: got {g} want {w} ({} ULPs apart)",
                    ulp_diff(g, w)
                ));
            }
        }
        Ok(())
    }

    /// The operator-facing tier label — what `--kernels` roster output and
    /// the serve startup log print next to each kernel id.
    pub fn label(&self) -> String {
        match self {
            EquivalenceTier::BitExact => "bit-exact".to_string(),
            EquivalenceTier::Tolerance(ulps) => format!("tolerance({ulps})"),
            EquivalenceTier::SignAgree(_) => "sign-agree".to_string(),
        }
    }

    /// Whether this tier preserves bit-identity with the serial oracle.
    pub fn is_bit_exact(&self) -> bool {
        matches!(self, EquivalenceTier::BitExact)
    }
}

/// Everything a kernel may read about one hidden layer: the untransposed
/// `d × h` weights (dense GEMM operand), the prepared [`MaskedLayer`]
/// (transposed weights + bias, the dot-product operand), and — when the
/// caller prepared one — the [`QuantizedLayer`] (int8 codes + per-row
/// scales, the `dense_i8`/`masked_i8` operand). All views describe the same
/// parameters.
pub struct LayerOperands<'a> {
    pub weights: &'a Mat,
    pub masked: &'a MaskedLayer,
    /// Quantized-once weights for the int8 kernels. `None` makes those
    /// kernels quantize on the fly (correct, but pays the quantization per
    /// batch — serving backends attach the prepared form).
    pub quant: Option<&'a QuantizedLayer>,
}

impl<'a> LayerOperands<'a> {
    pub fn new(weights: &'a Mat, masked: &'a MaskedLayer) -> LayerOperands<'a> {
        debug_assert_eq!(weights.shape(), (masked.in_dim(), masked.out_dim()));
        LayerOperands { weights, masked, quant: None }
    }

    /// Attach a prepared [`QuantizedLayer`] (quantize-once at model prep —
    /// the serving path; shapes must mirror the masked layer's).
    pub fn with_quant(mut self, quant: &'a QuantizedLayer) -> LayerOperands<'a> {
        debug_assert_eq!(
            (quant.in_dim(), quant.out_dim()),
            (self.masked.in_dim(), self.masked.out_dim())
        );
        self.quant = Some(quant);
        self
    }
}

/// An object-safe compute kernel: one way to evaluate a hidden layer's
/// `σ(x·W + b) ⊙ mask` for one batch.
pub trait ComputeKernel: Send + Sync {
    /// The stable id this kernel registers (and is costed) under.
    fn id(&self) -> KernelId;

    /// How closely this kernel's output matches its serial oracle. Defaults
    /// to [`EquivalenceTier::BitExact`] — a kernel with a different
    /// accumulation order must override this and declare its ULP bound.
    fn tier(&self) -> EquivalenceTier {
        EquivalenceTier::BitExact
    }

    /// Compute `σ(x·W + b) ⊙ mask` into `out` (overwritten — dirty reused
    /// buffers are fine), executing on the ctx's lease. Returns the number
    /// of dot products actually evaluated (the conditional-FLOP count).
    fn run(
        &self,
        layer: &LayerOperands<'_>,
        x: &Mat,
        mask: &Mat,
        ctx: &mut ExecCtx<'_>,
        out: &mut Mat,
    ) -> usize;
}

/// `dense`: axpy GEMM over row panels, then bias + ReLU + mask gate.
#[derive(Default)]
pub struct DenseKernel;

impl ComputeKernel for DenseKernel {
    fn id(&self) -> KernelId {
        KernelId::DENSE
    }

    fn run(
        &self,
        layer: &LayerOperands<'_>,
        x: &Mat,
        mask: &Mat,
        ctx: &mut ExecCtx<'_>,
        out: &mut Mat,
    ) -> usize {
        matmul_into_ctx(x, layer.weights, out, ctx);
        add_bias(out, &layer.masked.bias);
        relu_gate(out, mask);
        x.rows() * layer.masked.out_dim()
    }
}

/// `dense_packed`: the A-panel-packing GEMM — bit-identical to
/// [`DenseKernel`], different memory behaviour.
#[derive(Default)]
pub struct DensePackedKernel;

impl ComputeKernel for DensePackedKernel {
    fn id(&self) -> KernelId {
        KernelId::DENSE_PACKED
    }

    fn run(
        &self,
        layer: &LayerOperands<'_>,
        x: &Mat,
        mask: &Mat,
        ctx: &mut ExecCtx<'_>,
        out: &mut Mat,
    ) -> usize {
        matmul_into_packed_ctx(x, layer.weights, out, ctx);
        add_bias(out, &layer.masked.bias);
        relu_gate(out, mask);
        x.rows() * layer.masked.out_dim()
    }
}

/// The ULP bound both SIMD kernels declare: generous headroom over the
/// worst observed drift for the layer depths in play (each fused-vs-unfused
/// accumulation contributes at most ~1 ULP of divergence per term, so the
/// envelope scales with `d`; 4096 ULPs ≈ 2.4e-4 relative, with the
/// tolerance check's matching absolute floor near zero covering
/// ReLU-boundary sign flips).
pub const SIMD_TIER_ULPS: u32 = 4096;

/// `dense_simd`: the explicitly vectorized fused-axpy GEMM. Tolerance-tier
/// against [`DenseKernel`] (FMA rounds once where the oracle rounds twice);
/// bit-identical to itself across thread counts, lease widths and ISA paths.
pub struct DenseSimdKernel {
    caps: SimdCaps,
}

impl DenseSimdKernel {
    /// Pin an explicit capability set (tests exercising the scalar path
    /// in-process). [`Default`] probes the machine once.
    pub fn new(caps: SimdCaps) -> DenseSimdKernel {
        DenseSimdKernel { caps }
    }
}

impl Default for DenseSimdKernel {
    fn default() -> DenseSimdKernel {
        DenseSimdKernel::new(SimdCaps::get())
    }
}

impl ComputeKernel for DenseSimdKernel {
    fn id(&self) -> KernelId {
        KernelId::DENSE_SIMD
    }

    fn tier(&self) -> EquivalenceTier {
        EquivalenceTier::Tolerance(SIMD_TIER_ULPS)
    }

    fn run(
        &self,
        layer: &LayerOperands<'_>,
        x: &Mat,
        mask: &Mat,
        ctx: &mut ExecCtx<'_>,
        out: &mut Mat,
    ) -> usize {
        matmul_into_simd_ctx(self.caps, x, layer.weights, out, ctx);
        add_bias(out, &layer.masked.bias);
        relu_gate(out, mask);
        x.rows() * layer.masked.out_dim()
    }
}

/// `masked`: contiguous dot products for predicted-live entries only.
#[derive(Default)]
pub struct MaskedKernel;

impl ComputeKernel for MaskedKernel {
    fn id(&self) -> KernelId {
        KernelId::MASKED
    }

    fn run(
        &self,
        layer: &LayerOperands<'_>,
        x: &Mat,
        mask: &Mat,
        ctx: &mut ExecCtx<'_>,
        out: &mut Mat,
    ) -> usize {
        layer.masked.forward_masked_ctx(x, mask, out, ctx)
    }
}

/// `masked_simd`: the masked kernel with vectorized dot products. Identical
/// mask selection and count to [`MaskedKernel`]; computed values are
/// tolerance-tier (wider accumulator banks + fused ops in the dot).
pub struct MaskedSimdKernel {
    caps: SimdCaps,
}

impl MaskedSimdKernel {
    /// Pin an explicit capability set (tests exercising the scalar path
    /// in-process). [`Default`] probes the machine once.
    pub fn new(caps: SimdCaps) -> MaskedSimdKernel {
        MaskedSimdKernel { caps }
    }
}

impl Default for MaskedSimdKernel {
    fn default() -> MaskedSimdKernel {
        MaskedSimdKernel::new(SimdCaps::get())
    }
}

impl ComputeKernel for MaskedSimdKernel {
    fn id(&self) -> KernelId {
        KernelId::MASKED_SIMD
    }

    fn tier(&self) -> EquivalenceTier {
        EquivalenceTier::Tolerance(SIMD_TIER_ULPS)
    }

    fn run(
        &self,
        layer: &LayerOperands<'_>,
        x: &Mat,
        mask: &Mat,
        ctx: &mut ExecCtx<'_>,
        out: &mut Mat,
    ) -> usize {
        layer.masked.forward_masked_simd_ctx(self.caps, x, mask, out, ctx)
    }
}

/// Shared driver for the int8 kernels: use the caller's prepared
/// [`QuantizedLayer`] when the operands carry one, else quantize on the fly
/// (one-off callers, the autotune harness's first touch).
fn run_quant(
    caps: SimdCaps,
    layer: &LayerOperands<'_>,
    x: &Mat,
    mask: &Mat,
    ctx: &mut ExecCtx<'_>,
    out: &mut Mat,
    compute_all: bool,
) -> usize {
    let owned;
    let quant = match layer.quant {
        Some(q) => q,
        None => {
            owned = QuantizedLayer::new(&layer.masked.wt, &layer.masked.bias);
            &owned
        }
    };
    quant.forward_i8_ctx(caps, x, mask, out, compute_all, ctx)
}

/// `dense_i8`: every dot product computed in int8 (mask gates the output
/// only). Sign-agreement tier against [`DenseKernel`]; bit-identical to
/// itself across thread counts, lease widths and ISA paths (exact integer
/// accumulation).
pub struct QuantDenseKernel {
    caps: SimdCaps,
}

impl QuantDenseKernel {
    /// Pin an explicit capability set (tests exercising the scalar path
    /// in-process). [`Default`] probes the machine once.
    pub fn new(caps: SimdCaps) -> QuantDenseKernel {
        QuantDenseKernel { caps }
    }
}

impl Default for QuantDenseKernel {
    fn default() -> QuantDenseKernel {
        QuantDenseKernel::new(SimdCaps::get())
    }
}

impl ComputeKernel for QuantDenseKernel {
    fn id(&self) -> KernelId {
        KernelId::DENSE_I8
    }

    fn tier(&self) -> EquivalenceTier {
        EquivalenceTier::SignAgree(QUANT_TIER_AGREEMENT_BP)
    }

    fn run(
        &self,
        layer: &LayerOperands<'_>,
        x: &Mat,
        mask: &Mat,
        ctx: &mut ExecCtx<'_>,
        out: &mut Mat,
    ) -> usize {
        run_quant(self.caps, layer, x, mask, ctx, out, true)
    }
}

/// `masked_i8`: int8 dot products for predicted-live entries only —
/// identical mask selection and counts to [`MaskedKernel`], sign-agreement
/// tier values.
pub struct QuantMaskedKernel {
    caps: SimdCaps,
}

impl QuantMaskedKernel {
    /// Pin an explicit capability set (tests exercising the scalar path
    /// in-process). [`Default`] probes the machine once.
    pub fn new(caps: SimdCaps) -> QuantMaskedKernel {
        QuantMaskedKernel { caps }
    }
}

impl Default for QuantMaskedKernel {
    fn default() -> QuantMaskedKernel {
        QuantMaskedKernel::new(SimdCaps::get())
    }
}

impl ComputeKernel for QuantMaskedKernel {
    fn id(&self) -> KernelId {
        KernelId::MASKED_I8
    }

    fn tier(&self) -> EquivalenceTier {
        EquivalenceTier::SignAgree(QUANT_TIER_AGREEMENT_BP)
    }

    fn run(
        &self,
        layer: &LayerOperands<'_>,
        x: &Mat,
        mask: &Mat,
        ctx: &mut ExecCtx<'_>,
        out: &mut Mat,
    ) -> usize {
        run_quant(self.caps, layer, x, mask, ctx, out, false)
    }
}

/// `pjrt`: the feature-gated device slot. Until the real xla bindings
/// replace `vendor/xla-stub`, device execution is unavailable, so this
/// registrant delegates to the dense path — the registry seam, the config
/// allow-list, and the autotune cost column are all exercised end to end,
/// and swapping in device execution is a one-function change here.
#[cfg(feature = "pjrt")]
#[derive(Default)]
pub struct PjrtKernel {
    inner: DenseKernel,
}

#[cfg(feature = "pjrt")]
impl ComputeKernel for PjrtKernel {
    fn id(&self) -> KernelId {
        KernelId::PJRT
    }

    fn run(
        &self,
        layer: &LayerOperands<'_>,
        x: &Mat,
        mask: &Mat,
        ctx: &mut ExecCtx<'_>,
        out: &mut Mat,
    ) -> usize {
        self.inner.run(layer, x, mask, ctx, out)
    }
}

/// The kernel registry: stable ids → implementations, kept in the canonical
/// priority order so every iteration (routing candidates, calibration
/// columns, logs) is deterministic.
#[derive(Clone)]
pub struct KernelRegistry {
    kernels: Vec<Arc<dyn ComputeKernel>>,
}

impl KernelRegistry {
    /// An empty registry (embedders composing their own set).
    pub fn empty() -> KernelRegistry {
        KernelRegistry { kernels: Vec::new() }
    }

    /// The in-tree set: `dense`, `dense_packed`, `dense_simd`, `dense_i8`,
    /// `masked`, `masked_simd`, `masked_i8` — plus the `pjrt` slot when the
    /// feature is on. The SIMD and int8 kernels probe [`SimdCaps`] exactly
    /// once, here at construction.
    pub fn builtin() -> KernelRegistry {
        let mut reg = KernelRegistry::empty();
        reg.register(Arc::new(DenseKernel));
        reg.register(Arc::new(DensePackedKernel));
        reg.register(Arc::new(DenseSimdKernel::default()));
        reg.register(Arc::new(QuantDenseKernel::default()));
        reg.register(Arc::new(MaskedKernel));
        reg.register(Arc::new(MaskedSimdKernel::default()));
        reg.register(Arc::new(QuantMaskedKernel::default()));
        #[cfg(feature = "pjrt")]
        reg.register(Arc::new(PjrtKernel::default()));
        reg
    }

    /// Register a kernel (replacing any existing registrant with the same
    /// id). This is the extension point a new backend calls.
    pub fn register(&mut self, kernel: Arc<dyn ComputeKernel>) {
        let id = kernel.id();
        self.kernels.retain(|k| k.id() != id);
        self.kernels.push(kernel);
        self.kernels.sort_by_key(|k| k.id().priority());
    }

    pub fn get(&self, id: KernelId) -> Option<&dyn ComputeKernel> {
        self.kernels.iter().find(|k| k.id() == id).map(|k| k.as_ref())
    }

    pub fn contains(&self, id: KernelId) -> bool {
        self.get(id).is_some()
    }

    /// Registered ids, canonical order — the dispatch allow-list default and
    /// the calibration column set.
    pub fn ids(&self) -> Vec<KernelId> {
        self.kernels.iter().map(|k| k.id()).collect()
    }

    pub fn len(&self) -> usize {
        self.kernels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kernels.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Arc<dyn ComputeKernel>> {
        self.kernels.iter()
    }

    /// Every id this registry serves plus every in-tree id it doesn't —
    /// feature-gated or not-compiled-in slots marked `(unavailable)` — in
    /// canonical order. Registered ids carry their equivalence tier
    /// (`[bit-exact]`, `[tolerance(N)]`, `[sign-agree]`) so the operator can
    /// read the accuracy contract of every candidate off one line. What
    /// `--kernels` validation errors and the serve startup log enumerate.
    pub fn roster(&self) -> String {
        let mut entries: Vec<(KernelId, bool)> =
            self.ids().into_iter().map(|id| (id, true)).collect();
        for &id in KernelId::known() {
            if !self.contains(id) {
                entries.push((id, false));
            }
        }
        entries.sort_by_key(|(id, _)| id.priority());
        entries
            .iter()
            .map(|&(id, registered)| match self.get(id) {
                Some(kernel) if registered => {
                    format!("{id} [{}]", kernel.tier().label())
                }
                _ => format!("{id} (unavailable)"),
            })
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// The ids routed by default: every registered kernel whose tier
    /// preserves outputs (bit-exact or tolerance). Sign-agreement kernels
    /// change serve outputs, so they never enter the candidate set unless the
    /// operator names them in `dispatch.kernels` / `--kernels` — quantized
    /// routing is an explicit opt-in, not a cost-model accident.
    pub fn default_routable(&self) -> Vec<KernelId> {
        self.kernels
            .iter()
            .filter(|k| !matches!(k.tier(), EquivalenceTier::SignAgree(_)))
            .map(|k| k.id())
            .collect()
    }

    /// A registry restricted to `allow` (the `dispatch.kernels` config key /
    /// `--kernels` flag). Rejects unknown or unregistered ids and an empty
    /// result — a typo'd allow-list should fail loudly at startup, not route
    /// every batch to a silent default.
    pub fn restricted(&self, allow: &[KernelId]) -> Result<KernelRegistry, String> {
        for id in allow {
            if !self.contains(*id) {
                return Err(format!(
                    "kernel '{id}' is not registered (kernels: {})",
                    self.roster()
                ));
            }
        }
        let kernels: Vec<Arc<dyn ComputeKernel>> = self
            .kernels
            .iter()
            .filter(|k| allow.contains(&k.id()))
            .cloned()
            .collect();
        if kernels.is_empty() {
            return Err("kernel allow-list is empty".into());
        }
        Ok(KernelRegistry { kernels })
    }

    /// Parse already-tokenized allow-list names (the `dispatch.kernels`
    /// config key's `Vec<String>`) into kernel ids. Unknown tokens are an
    /// error naming the known set; duplicates collapse; empty is an error.
    pub fn parse_ids(names: &[String]) -> Result<Vec<KernelId>, String> {
        let mut ids = Vec::new();
        for tok in names.iter().map(|s| s.trim()).filter(|t| !t.is_empty()) {
            let id = KernelId::parse(tok).ok_or_else(|| {
                format!(
                    "unknown kernel '{tok}' (kernels: {})",
                    KernelRegistry::builtin().roster()
                )
            })?;
            if !ids.contains(&id) {
                ids.push(id);
            }
        }
        if ids.is_empty() {
            return Err("empty kernel allow-list".into());
        }
        Ok(ids)
    }

    /// Parse a comma-separated allow-list (`"dense_packed,masked"`, the
    /// `--kernels` flag) into kernel ids — one tokenization shared with
    /// [`Self::parse_ids`].
    pub fn parse_allowlist(s: &str) -> Result<Vec<KernelId>, String> {
        let names: Vec<String> = s.split(',').map(str::to_string).collect();
        KernelRegistry::parse_ids(&names)
    }
}

impl Default for KernelRegistry {
    fn default() -> KernelRegistry {
        KernelRegistry::builtin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::ThreadPool;
    use crate::util::proptest::property;
    use crate::util::Pcg32;

    fn operands(rng: &mut Pcg32, d: usize, h: usize) -> (Mat, Vec<f32>, MaskedLayer) {
        let w = Mat::randn(d, h, 0.4, rng);
        let bias: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.3, 0.3)).collect();
        let layer = MaskedLayer::new(&w, &bias);
        (w, bias, layer)
    }

    /// The serial oracle every registry kernel must agree with: blocked
    /// serial GEMM + bias + ReLU + mask gate for dense-work kernels, which
    /// equals the masked kernel's own serial oracle on the masked entries.
    fn dense_oracle(x: &Mat, w: &Mat, bias: &[f32], mask: &Mat) -> Mat {
        let mut out = Mat::zeros(x.rows(), w.cols());
        crate::linalg::matmul_into(x, w, &mut out);
        add_bias(&mut out, bias);
        relu_gate(&mut out, mask);
        out
    }

    #[test]
    fn builtin_registry_has_the_canonical_set() {
        let reg = KernelRegistry::builtin();
        let mut want = vec![
            KernelId::DENSE,
            KernelId::DENSE_PACKED,
            KernelId::DENSE_SIMD,
            KernelId::DENSE_I8,
            KernelId::MASKED,
            KernelId::MASKED_SIMD,
            KernelId::MASKED_I8,
        ];
        if cfg!(feature = "pjrt") {
            want.push(KernelId::PJRT);
        }
        assert_eq!(reg.ids(), want);
        assert!(reg.contains(KernelId::DENSE));
        assert!(reg.get(KernelId::MASKED).is_some());
        #[cfg(not(feature = "pjrt"))]
        assert!(
            !reg.contains(KernelId::PJRT),
            "the pjrt slot registers only behind the feature gate"
        );
    }

    /// Every registered kernel declares an equivalence tier (an acceptance
    /// criterion): the scalar kernels are bit-exact, the SIMD pair declares
    /// the shared ULP bound, the int8 pair the sign-agreement floor.
    #[test]
    fn every_registered_kernel_declares_a_tier() {
        for kernel in KernelRegistry::builtin().iter() {
            let tier = kernel.tier();
            match kernel.id() {
                KernelId::DENSE_SIMD | KernelId::MASKED_SIMD => {
                    assert_eq!(tier, EquivalenceTier::Tolerance(SIMD_TIER_ULPS))
                }
                KernelId::DENSE_I8 | KernelId::MASKED_I8 => {
                    assert_eq!(tier, EquivalenceTier::SignAgree(QUANT_TIER_AGREEMENT_BP))
                }
                _ => assert_eq!(tier, EquivalenceTier::BitExact, "{}", kernel.id()),
            }
        }
    }

    /// Default routing excludes the sign-agreement class: quantized kernels
    /// enter the candidate set only when the operator names them.
    #[test]
    fn default_routable_excludes_sign_agree_kernels() {
        let reg = KernelRegistry::builtin();
        let routable = reg.default_routable();
        assert!(!routable.contains(&KernelId::DENSE_I8));
        assert!(!routable.contains(&KernelId::MASKED_I8));
        assert!(routable.contains(&KernelId::DENSE));
        assert!(routable.contains(&KernelId::MASKED));
        assert!(routable.contains(&KernelId::DENSE_SIMD));
        // An explicit allow-list naming the int8 ids still restricts fine.
        let quant = reg
            .restricted(&[KernelId::DENSE, KernelId::DENSE_I8, KernelId::MASKED_I8])
            .unwrap();
        assert_eq!(
            quant.ids(),
            vec![KernelId::DENSE, KernelId::DENSE_I8, KernelId::MASKED_I8]
        );
    }

    /// The roster names every kernel's tier so one log line carries the
    /// accuracy contract of the full candidate set (satellite).
    #[test]
    fn roster_labels_each_kernel_with_its_tier() {
        let roster = KernelRegistry::builtin().roster();
        assert!(roster.contains("dense [bit-exact]"), "{roster}");
        assert!(
            roster.contains(&format!("dense_simd [tolerance({SIMD_TIER_ULPS})]")),
            "{roster}"
        );
        assert!(roster.contains("dense_i8 [sign-agree]"), "{roster}");
        assert!(roster.contains("masked_i8 [sign-agree]"), "{roster}");
        #[cfg(not(feature = "pjrt"))]
        assert!(roster.contains("pjrt (unavailable)"), "{roster}");
    }

    #[test]
    fn tier_check_enforces_its_contract() {
        let exact = EquivalenceTier::BitExact;
        assert!(exact.check(&[1.0, -0.5], &[1.0, -0.5]).is_ok());
        let one_up = f32::from_bits(1.0f32.to_bits() + 1);
        assert!(exact.check(&[one_up], &[1.0]).is_err(), "1 ULP breaks bit-exactness");
        assert!(exact.check(&[1.0, 2.0], &[1.0]).is_err(), "length mismatch");
        let tol = EquivalenceTier::Tolerance(4);
        assert!(tol.check(&[one_up], &[1.0]).is_ok());
        assert!(tol.check(&[1.001], &[1.0]).is_err(), "thousands of ULPs exceed the bound");
        let err = tol.check(&[1.001], &[1.0]).unwrap_err();
        assert!(err.contains("[0]"), "violation pinpoints the index: {err}");

        // The aggregate sign-agreement tier: values may drift, signs must
        // (mostly) hold outside the near-zero band.
        let sign = EquivalenceTier::SignAgree(9900);
        let want: Vec<f32> = (0..200).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect();
        let drifted: Vec<f32> = want.iter().map(|&w| w * 1.03).collect();
        assert!(sign.check(&drifted, &want).is_ok(), "pure magnitude drift passes");
        let mut flipped = want.clone();
        for v in flipped.iter_mut().take(8) {
            // 4 of the 100 out-of-band entries flip to zero: 96% < 99%.
            *v = 0.0;
        }
        assert!(sign.check(&flipped, &want).is_err(), ">1% out-of-band flips fail");
        // Flips confined to the near-zero band are ignored...
        let near: Vec<f32> = vec![1.0, 0.01, 0.015, 1.0];
        let near_flipped: Vec<f32> = vec![1.0, 0.0, 0.0, 1.0];
        assert!(sign.check(&near_flipped, &near).is_ok(), "in-band flips excluded");
        // ...and an all-in-band oracle has nothing to disagree with.
        assert!(sign.check(&[1.0, 2.0], &[0.0, 0.0]).is_ok(), "no eligible entries");
        assert!(sign.check(&[1.0, 2.0], &[1.0]).is_err(), "length mismatch still fails");
        assert_eq!(sign.label(), "sign-agree");
        assert!(!sign.is_bit_exact() && exact.is_bit_exact());
    }

    /// The roster (satellite): validation errors list the full candidate
    /// set, with feature-gated/unregistered ids marked unavailable, instead
    /// of only naming the rejected id.
    #[test]
    fn validation_errors_list_the_kernel_roster() {
        let reg = KernelRegistry::builtin();
        #[cfg(not(feature = "pjrt"))]
        {
            let err = reg.restricted(&[KernelId::PJRT]).unwrap_err();
            for id in ["dense", "dense_packed", "dense_simd", "masked", "masked_simd"] {
                assert!(err.contains(id), "roster missing '{id}': {err}");
            }
            assert!(err.contains("pjrt (unavailable)"), "gated slot marked: {err}");
        }
        let err = KernelRegistry::parse_allowlist("quantum").unwrap_err();
        assert!(err.contains("quantum") && err.contains("dense_simd"), "{err}");
        // A restricted registry's roster still shows what it excludes.
        let only = reg.restricted(&[KernelId::MASKED]).unwrap();
        let err = only.restricted(&[KernelId::DENSE]).unwrap_err();
        assert!(err.contains("dense (unavailable)") && err.contains("masked"), "{err}");
    }

    #[test]
    fn restricted_filters_and_rejects_unknown_or_empty() {
        let reg = KernelRegistry::builtin();
        let only = reg.restricted(&[KernelId::MASKED]).unwrap();
        assert_eq!(only.ids(), vec![KernelId::MASKED]);
        let two = reg
            .restricted(&[KernelId::MASKED, KernelId::DENSE_PACKED])
            .unwrap();
        assert_eq!(two.ids(), vec![KernelId::DENSE_PACKED, KernelId::MASKED]);
        assert!(reg.restricted(&[]).is_err());
        #[cfg(not(feature = "pjrt"))]
        assert!(reg.restricted(&[KernelId::PJRT]).is_err(), "unregistered id rejected");
    }

    #[test]
    fn allowlist_parsing() {
        assert_eq!(
            KernelRegistry::parse_allowlist("dense, masked").unwrap(),
            vec![KernelId::DENSE, KernelId::MASKED]
        );
        assert_eq!(
            KernelRegistry::parse_allowlist("dense_packed").unwrap(),
            vec![KernelId::DENSE_PACKED]
        );
        // Duplicates collapse; unknown ids and empty lists are errors.
        assert_eq!(
            KernelRegistry::parse_allowlist("dense,dense").unwrap().len(),
            1
        );
        assert!(KernelRegistry::parse_allowlist("quantum").is_err());
        assert!(KernelRegistry::parse_allowlist("").is_err());
        assert!(KernelRegistry::parse_allowlist(" , ").is_err());
    }

    #[test]
    fn register_replaces_by_id() {
        struct LoudDense;
        impl ComputeKernel for LoudDense {
            fn id(&self) -> KernelId {
                KernelId::DENSE
            }
            fn run(
                &self,
                layer: &LayerOperands<'_>,
                x: &Mat,
                mask: &Mat,
                ctx: &mut ExecCtx<'_>,
                out: &mut Mat,
            ) -> usize {
                DenseKernel.run(layer, x, mask, ctx, out)
            }
        }
        let mut reg = KernelRegistry::builtin();
        let before = reg.len();
        reg.register(Arc::new(LoudDense));
        assert_eq!(reg.len(), before, "same id replaces, never duplicates");
    }

    /// The satellite property test: every registered kernel matches its
    /// serial oracle *within its declared equivalence tier* at thread counts
    /// {1, 2, 7} and lease widths {1, N}. For the bit-exact kernels that is
    /// the same bitwise contract as before (so `--kernels` allow-list swaps
    /// stay output-preserving within a tier class); the SIMD kernels are
    /// held to their ULP bound — and to *exact* FLOP counts either way.
    #[test]
    fn every_registered_kernel_is_bit_identical_to_its_serial_oracle() {
        let reg = KernelRegistry::builtin();
        for threads in [1usize, 2, 7] {
            let pool = ThreadPool::new(threads);
            property("registry kernels == serial oracles", 8, |rng| {
                let n = rng.index(40) + 1;
                let d = rng.index(200) + 1;
                let h = rng.index(30) + 1;
                let x = Mat::randn(n, d, 0.6, rng);
                let (w, bias, layer) = operands(rng, d, h);
                let alpha = rng.uniform();
                let mask =
                    Mat::from_fn(n, h, |_, _| if rng.bernoulli(alpha) { 1.0 } else { 0.0 });
                let quant = QuantizedLayer::new(&layer.wt, &layer.bias);
                let ops = LayerOperands::new(&w, &layer).with_quant(&quant);
                let dense_want = dense_oracle(&x, &w, &bias, &mask);
                let (masked_want, masked_count) = layer.forward_masked(&x, &mask);
                // Serial int8 references: the i8 kernels must hit their
                // sign-agreement tier vs the float oracles AND stay bitwise
                // identical to the serial integer kernel at every thread
                // count / lease width (integer accumulation is exact).
                let mut i8_dense_want = Mat::zeros(n, h);
                let i8_dense_count =
                    quant.forward_i8_into(SimdCaps::get(), &x, &mask, &mut i8_dense_want, true);
                let mut i8_masked_want = Mat::zeros(n, h);
                let i8_masked_count =
                    quant.forward_i8_into(SimdCaps::get(), &x, &mask, &mut i8_masked_want, false);
                for lease_width in [1usize, threads] {
                    for kernel in reg.iter() {
                        let mut ctx = ExecCtx::over(pool.lease(lease_width));
                        let mut out = Mat::full(n, h, f32::NAN); // dirty buffer
                        let computed = kernel.run(&ops, &x, &mask, &mut ctx, &mut out);
                        use crate::condcomp::WorkModel;
                        let (want, want_count) = match kernel.id().work() {
                            WorkModel::Dense => (&dense_want, n * h),
                            WorkModel::AlphaScaled => (&masked_want, masked_count),
                            WorkModel::DenseI8 => (&dense_want, i8_dense_count),
                            WorkModel::AlphaScaledI8 => (&masked_want, i8_masked_count),
                        };
                        if let Err(msg) = kernel.tier().check(out.as_slice(), want.as_slice()) {
                            panic!(
                                "kernel {} threads {threads} lease {lease_width} \
                                 ({n}x{d}x{h}): {msg}",
                                kernel.id()
                            );
                        }
                        let i8_want = match kernel.id().work() {
                            WorkModel::DenseI8 => Some(&i8_dense_want),
                            WorkModel::AlphaScaledI8 => Some(&i8_masked_want),
                            _ => None,
                        };
                        if let Some(i8_want) = i8_want {
                            assert_eq!(
                                i8_want.max_abs_diff(&out),
                                0.0,
                                "kernel {} threads {threads} lease {lease_width}: int8 \
                                 output must be bitwise thread-invariant",
                                kernel.id()
                            );
                        }
                        assert_eq!(computed, want_count, "kernel {}", kernel.id());
                    }
                }
            });
            assert_eq!(pool.leased(), 0);
        }
    }
}
