//! The kernel registry: an open set of compute kernels behind stable ids,
//! routed per layer per batch by the cost table in [`super::dispatch`].
//!
//! Before this module, the dispatch choice was a hard-coded binary — masked
//! vs dense — and every new compute path (packed GEMM, PJRT, quantized)
//! would have needed its own if-ladder in the backend. Now a kernel is an
//! object-safe [`ComputeKernel`]: it computes one hidden layer's
//! `σ(x·W + b) ⊙ S` through a caller-owned [`ExecCtx`] and reports how many
//! dot products it evaluated (the §3.4 FLOP accounting input). The
//! [`KernelRegistry`] maps [`KernelId`]s to implementations; the
//! [`crate::autotune::Autotuner`] measures every registered kernel per layer
//! shape and emits one machine-profile cost column each; the
//! [`super::DispatchPolicy`] argmin routes each batch to the cheapest
//! registered-and-allowed kernel.
//!
//! In-tree registrants ([`KernelRegistry::builtin`]):
//!
//! - `dense` — the axpy GEMM ([`crate::linalg::matmul_into_ctx`]), mask
//!   applied afterwards; every dot product computed.
//! - `dense_packed` — the A-panel-packing GEMM
//!   ([`crate::linalg::matmul_into_packed_ctx`]): same accumulation order,
//!   **bit-identical** to `dense`, different memory behaviour (faster on
//!   wide-input layers).
//! - `masked` — the dot-product kernel
//!   ([`MaskedLayer::forward_masked_ctx`]): computes only predicted-live
//!   entries.
//! - `pjrt` — a feature-gated slot (`--features pjrt`) that registers only
//!   when the real xla bindings replace `vendor/xla-stub`; until device
//!   execution lands it delegates to the dense path so the column is
//!   measurable end to end.
//!
//! Numeric contract: `dense` and `dense_packed` are bit-identical to each
//! other (and to the serial [`crate::linalg::matmul_into`] oracle) for any
//! thread count or lease width; `masked` is bit-identical to its own serial
//! oracle [`MaskedLayer::forward_masked_into`]. Dense-work and masked-work
//! kernels compute the same function with different float accumulation
//! orders, so routing changes wall-clock, never correctness.

use super::dispatch::KernelId;
use super::masked_gemm::{relu_gate, MaskedLayer};
use crate::exec::ExecCtx;
use crate::linalg::{matmul_into_ctx, matmul_into_packed_ctx, Mat};
use crate::nn::mlp::add_bias;
use std::sync::Arc;

/// Everything a kernel may read about one hidden layer: the untransposed
/// `d × h` weights (dense GEMM operand) and the prepared [`MaskedLayer`]
/// (transposed weights + bias, the dot-product operand). Both views describe
/// the same parameters.
pub struct LayerOperands<'a> {
    pub weights: &'a Mat,
    pub masked: &'a MaskedLayer,
}

impl<'a> LayerOperands<'a> {
    pub fn new(weights: &'a Mat, masked: &'a MaskedLayer) -> LayerOperands<'a> {
        debug_assert_eq!(weights.shape(), (masked.in_dim(), masked.out_dim()));
        LayerOperands { weights, masked }
    }
}

/// An object-safe compute kernel: one way to evaluate a hidden layer's
/// `σ(x·W + b) ⊙ mask` for one batch.
pub trait ComputeKernel: Send + Sync {
    /// The stable id this kernel registers (and is costed) under.
    fn id(&self) -> KernelId;

    /// Compute `σ(x·W + b) ⊙ mask` into `out` (overwritten — dirty reused
    /// buffers are fine), executing on the ctx's lease. Returns the number
    /// of dot products actually evaluated (the conditional-FLOP count).
    fn run(
        &self,
        layer: &LayerOperands<'_>,
        x: &Mat,
        mask: &Mat,
        ctx: &mut ExecCtx<'_>,
        out: &mut Mat,
    ) -> usize;
}

/// `dense`: axpy GEMM over row panels, then bias + ReLU + mask gate.
#[derive(Default)]
pub struct DenseKernel;

impl ComputeKernel for DenseKernel {
    fn id(&self) -> KernelId {
        KernelId::DENSE
    }

    fn run(
        &self,
        layer: &LayerOperands<'_>,
        x: &Mat,
        mask: &Mat,
        ctx: &mut ExecCtx<'_>,
        out: &mut Mat,
    ) -> usize {
        matmul_into_ctx(x, layer.weights, out, ctx);
        add_bias(out, &layer.masked.bias);
        relu_gate(out, mask);
        x.rows() * layer.masked.out_dim()
    }
}

/// `dense_packed`: the A-panel-packing GEMM — bit-identical to
/// [`DenseKernel`], different memory behaviour.
#[derive(Default)]
pub struct DensePackedKernel;

impl ComputeKernel for DensePackedKernel {
    fn id(&self) -> KernelId {
        KernelId::DENSE_PACKED
    }

    fn run(
        &self,
        layer: &LayerOperands<'_>,
        x: &Mat,
        mask: &Mat,
        ctx: &mut ExecCtx<'_>,
        out: &mut Mat,
    ) -> usize {
        matmul_into_packed_ctx(x, layer.weights, out, ctx);
        add_bias(out, &layer.masked.bias);
        relu_gate(out, mask);
        x.rows() * layer.masked.out_dim()
    }
}

/// `masked`: contiguous dot products for predicted-live entries only.
#[derive(Default)]
pub struct MaskedKernel;

impl ComputeKernel for MaskedKernel {
    fn id(&self) -> KernelId {
        KernelId::MASKED
    }

    fn run(
        &self,
        layer: &LayerOperands<'_>,
        x: &Mat,
        mask: &Mat,
        ctx: &mut ExecCtx<'_>,
        out: &mut Mat,
    ) -> usize {
        layer.masked.forward_masked_ctx(x, mask, out, ctx)
    }
}

/// `pjrt`: the feature-gated device slot. Until the real xla bindings
/// replace `vendor/xla-stub`, device execution is unavailable, so this
/// registrant delegates to the dense path — the registry seam, the config
/// allow-list, and the autotune cost column are all exercised end to end,
/// and swapping in device execution is a one-function change here.
#[cfg(feature = "pjrt")]
#[derive(Default)]
pub struct PjrtKernel {
    inner: DenseKernel,
}

#[cfg(feature = "pjrt")]
impl ComputeKernel for PjrtKernel {
    fn id(&self) -> KernelId {
        KernelId::PJRT
    }

    fn run(
        &self,
        layer: &LayerOperands<'_>,
        x: &Mat,
        mask: &Mat,
        ctx: &mut ExecCtx<'_>,
        out: &mut Mat,
    ) -> usize {
        self.inner.run(layer, x, mask, ctx, out)
    }
}

/// The kernel registry: stable ids → implementations, kept in the canonical
/// priority order so every iteration (routing candidates, calibration
/// columns, logs) is deterministic.
#[derive(Clone)]
pub struct KernelRegistry {
    kernels: Vec<Arc<dyn ComputeKernel>>,
}

impl KernelRegistry {
    /// An empty registry (embedders composing their own set).
    pub fn empty() -> KernelRegistry {
        KernelRegistry { kernels: Vec::new() }
    }

    /// The in-tree set: `dense`, `dense_packed`, `masked` — plus the `pjrt`
    /// slot when the feature is on.
    pub fn builtin() -> KernelRegistry {
        let mut reg = KernelRegistry::empty();
        reg.register(Arc::new(DenseKernel));
        reg.register(Arc::new(DensePackedKernel));
        reg.register(Arc::new(MaskedKernel));
        #[cfg(feature = "pjrt")]
        reg.register(Arc::new(PjrtKernel::default()));
        reg
    }

    /// Register a kernel (replacing any existing registrant with the same
    /// id). This is the extension point a new backend calls.
    pub fn register(&mut self, kernel: Arc<dyn ComputeKernel>) {
        let id = kernel.id();
        self.kernels.retain(|k| k.id() != id);
        self.kernels.push(kernel);
        self.kernels.sort_by_key(|k| k.id().priority());
    }

    pub fn get(&self, id: KernelId) -> Option<&dyn ComputeKernel> {
        self.kernels.iter().find(|k| k.id() == id).map(|k| k.as_ref())
    }

    pub fn contains(&self, id: KernelId) -> bool {
        self.get(id).is_some()
    }

    /// Registered ids, canonical order — the dispatch allow-list default and
    /// the calibration column set.
    pub fn ids(&self) -> Vec<KernelId> {
        self.kernels.iter().map(|k| k.id()).collect()
    }

    pub fn len(&self) -> usize {
        self.kernels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kernels.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Arc<dyn ComputeKernel>> {
        self.kernels.iter()
    }

    /// A registry restricted to `allow` (the `dispatch.kernels` config key /
    /// `--kernels` flag). Rejects unknown or unregistered ids and an empty
    /// result — a typo'd allow-list should fail loudly at startup, not route
    /// every batch to a silent default.
    pub fn restricted(&self, allow: &[KernelId]) -> Result<KernelRegistry, String> {
        for id in allow {
            if !self.contains(*id) {
                return Err(format!(
                    "kernel '{id}' is not registered (registered: {})",
                    self.ids().iter().map(|k| k.as_str()).collect::<Vec<_>>().join(", ")
                ));
            }
        }
        let kernels: Vec<Arc<dyn ComputeKernel>> = self
            .kernels
            .iter()
            .filter(|k| allow.contains(&k.id()))
            .cloned()
            .collect();
        if kernels.is_empty() {
            return Err("kernel allow-list is empty".into());
        }
        Ok(KernelRegistry { kernels })
    }

    /// Parse already-tokenized allow-list names (the `dispatch.kernels`
    /// config key's `Vec<String>`) into kernel ids. Unknown tokens are an
    /// error naming the known set; duplicates collapse; empty is an error.
    pub fn parse_ids(names: &[String]) -> Result<Vec<KernelId>, String> {
        let mut ids = Vec::new();
        for tok in names.iter().map(|s| s.trim()).filter(|t| !t.is_empty()) {
            let id = KernelId::parse(tok).ok_or_else(|| {
                format!(
                    "unknown kernel '{tok}' (known: dense, dense_packed, masked, pjrt)"
                )
            })?;
            if !ids.contains(&id) {
                ids.push(id);
            }
        }
        if ids.is_empty() {
            return Err("empty kernel allow-list".into());
        }
        Ok(ids)
    }

    /// Parse a comma-separated allow-list (`"dense_packed,masked"`, the
    /// `--kernels` flag) into kernel ids — one tokenization shared with
    /// [`Self::parse_ids`].
    pub fn parse_allowlist(s: &str) -> Result<Vec<KernelId>, String> {
        let names: Vec<String> = s.split(',').map(str::to_string).collect();
        KernelRegistry::parse_ids(&names)
    }
}

impl Default for KernelRegistry {
    fn default() -> KernelRegistry {
        KernelRegistry::builtin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::ThreadPool;
    use crate::util::proptest::property;
    use crate::util::Pcg32;

    fn operands(rng: &mut Pcg32, d: usize, h: usize) -> (Mat, Vec<f32>, MaskedLayer) {
        let w = Mat::randn(d, h, 0.4, rng);
        let bias: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.3, 0.3)).collect();
        let layer = MaskedLayer::new(&w, &bias);
        (w, bias, layer)
    }

    /// The serial oracle every registry kernel must agree with: blocked
    /// serial GEMM + bias + ReLU + mask gate for dense-work kernels, which
    /// equals the masked kernel's own serial oracle on the masked entries.
    fn dense_oracle(x: &Mat, w: &Mat, bias: &[f32], mask: &Mat) -> Mat {
        let mut out = Mat::zeros(x.rows(), w.cols());
        crate::linalg::matmul_into(x, w, &mut out);
        add_bias(&mut out, bias);
        relu_gate(&mut out, mask);
        out
    }

    #[test]
    fn builtin_registry_has_the_canonical_set() {
        let reg = KernelRegistry::builtin();
        let mut want = vec![KernelId::DENSE, KernelId::DENSE_PACKED, KernelId::MASKED];
        if cfg!(feature = "pjrt") {
            want.push(KernelId::PJRT);
        }
        assert_eq!(reg.ids(), want);
        assert!(reg.contains(KernelId::DENSE));
        assert!(reg.get(KernelId::MASKED).is_some());
        #[cfg(not(feature = "pjrt"))]
        assert!(
            !reg.contains(KernelId::PJRT),
            "the pjrt slot registers only behind the feature gate"
        );
    }

    #[test]
    fn restricted_filters_and_rejects_unknown_or_empty() {
        let reg = KernelRegistry::builtin();
        let only = reg.restricted(&[KernelId::MASKED]).unwrap();
        assert_eq!(only.ids(), vec![KernelId::MASKED]);
        let two = reg
            .restricted(&[KernelId::MASKED, KernelId::DENSE_PACKED])
            .unwrap();
        assert_eq!(two.ids(), vec![KernelId::DENSE_PACKED, KernelId::MASKED]);
        assert!(reg.restricted(&[]).is_err());
        #[cfg(not(feature = "pjrt"))]
        assert!(reg.restricted(&[KernelId::PJRT]).is_err(), "unregistered id rejected");
    }

    #[test]
    fn allowlist_parsing() {
        assert_eq!(
            KernelRegistry::parse_allowlist("dense, masked").unwrap(),
            vec![KernelId::DENSE, KernelId::MASKED]
        );
        assert_eq!(
            KernelRegistry::parse_allowlist("dense_packed").unwrap(),
            vec![KernelId::DENSE_PACKED]
        );
        // Duplicates collapse; unknown ids and empty lists are errors.
        assert_eq!(
            KernelRegistry::parse_allowlist("dense,dense").unwrap().len(),
            1
        );
        assert!(KernelRegistry::parse_allowlist("quantum").is_err());
        assert!(KernelRegistry::parse_allowlist("").is_err());
        assert!(KernelRegistry::parse_allowlist(" , ").is_err());
    }

    #[test]
    fn register_replaces_by_id() {
        struct LoudDense;
        impl ComputeKernel for LoudDense {
            fn id(&self) -> KernelId {
                KernelId::DENSE
            }
            fn run(
                &self,
                layer: &LayerOperands<'_>,
                x: &Mat,
                mask: &Mat,
                ctx: &mut ExecCtx<'_>,
                out: &mut Mat,
            ) -> usize {
                DenseKernel.run(layer, x, mask, ctx, out)
            }
        }
        let mut reg = KernelRegistry::builtin();
        let before = reg.len();
        reg.register(Arc::new(LoudDense));
        assert_eq!(reg.len(), before, "same id replaces, never duplicates");
    }

    /// The satellite property test: every registered kernel is bit-identical
    /// to its serial oracle at thread counts {1, 2, 7} and lease widths
    /// {1, N} — and the two dense-work kernels are bit-identical to *each
    /// other* (that equivalence is what makes `--kernels` allow-list swaps
    /// output-preserving for the dense regime).
    #[test]
    fn every_registered_kernel_is_bit_identical_to_its_serial_oracle() {
        let reg = KernelRegistry::builtin();
        for threads in [1usize, 2, 7] {
            let pool = ThreadPool::new(threads);
            property("registry kernels == serial oracles", 8, |rng| {
                let n = rng.index(40) + 1;
                let d = rng.index(200) + 1;
                let h = rng.index(30) + 1;
                let x = Mat::randn(n, d, 0.6, rng);
                let (w, bias, layer) = operands(rng, d, h);
                let alpha = rng.uniform();
                let mask =
                    Mat::from_fn(n, h, |_, _| if rng.bernoulli(alpha) { 1.0 } else { 0.0 });
                let ops = LayerOperands::new(&w, &layer);
                let dense_want = dense_oracle(&x, &w, &bias, &mask);
                let (masked_want, masked_count) = layer.forward_masked(&x, &mask);
                for lease_width in [1usize, threads] {
                    for kernel in reg.iter() {
                        let mut ctx = ExecCtx::over(pool.lease(lease_width));
                        let mut out = Mat::full(n, h, f32::NAN); // dirty buffer
                        let computed = kernel.run(&ops, &x, &mask, &mut ctx, &mut out);
                        let (want, want_count) = match kernel.id().work() {
                            crate::condcomp::WorkModel::Dense => (&dense_want, n * h),
                            crate::condcomp::WorkModel::AlphaScaled => {
                                (&masked_want, masked_count)
                            }
                        };
                        assert_eq!(
                            out.as_slice(),
                            want.as_slice(),
                            "kernel {} threads {threads} lease {lease_width} ({n}x{d}x{h})",
                            kernel.id()
                        );
                        assert_eq!(computed, want_count, "kernel {}", kernel.id());
                    }
                }
            });
            assert_eq!(pool.leased(), 0);
        }
    }
}
