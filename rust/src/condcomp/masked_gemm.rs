//! Conditional (mask-driven) matrix multiplication.
//!
//! `forward_masked*` computes `σ(a·W + b) ⊙ S` touching only the `(i, j)`
//! dot products with `S[i,j] = 1`. With activation density α this performs
//! `α·N·(2d−1)·h` FLOPs versus the dense `N·(2d−1)·h` (paper §3.4) — the
//! source of the measured speedup in `benches/`.
//!
//! The weights are stored transposed (`Wᵀ`, row per output unit) so each
//! computed entry is a contiguous·contiguous dot product; the mask is
//! consumed row-major, matching its production order by the estimator.
//!
//! Entry points, hot path first:
//!
//! - [`MaskedLayer::forward_masked_ctx`] — the serving path: batch rows
//!   sharded across the caller's [`ExecCtx`] lease, writing into a
//!   caller-owned output buffer (nothing allocated per batch).
//! - [`MaskedLayer::forward_masked_par`] — the same kernel on an explicit
//!   execution target (pool or lease). Per-row work is exactly the serial
//!   code, and the per-shard `computed` counts are reduced in shard order,
//!   so the result — output *and* count — is bit-identical to the serial
//!   kernel for any thread count or lease width.
//! - [`MaskedLayer::forward_masked_simd_ctx`] (and its `_into`/`_par`
//!   forms) — the same kernel with explicitly vectorized dot products
//!   ([`crate::linalg::simd`]); identical mask selection and counts,
//!   tolerance-tier values (the `masked_simd` registry kernel).
//! - [`MaskedLayer::forward_masked_into`] — serial, buffer-reusing.
//! - [`MaskedLayer::forward_masked`] — serial, allocating (tests, one-off
//!   callers); the correctness oracle.
//! - [`MaskedLayer::forward_dense_par`] / [`MaskedLayer::forward_dense`] —
//!   the dense control path through the same data layout, used for timing
//!   comparisons (the bench sweep; [`super::DispatchPolicy`] ratios are
//!   fitted by the `crate::autotune` harness).

use crate::exec::ExecCtx;
use crate::linalg::gemm::dot;
use crate::linalg::simd::{dot_simd, SimdCaps};
use crate::linalg::Mat;
use crate::parallel::{chunk_rows, par_row_chunks, Parallelism};

/// Fuse ReLU with the estimator's gate over a dense pre-activation:
/// `out[i,j] = out[i,j]` where it is positive *and* the mask is live, else 0.
/// This is the post-pass every dense-work registry kernel applies so its
/// output matches the masked kernel's function (`σ(a·W + b) ⊙ S`) — the
/// dense kernels compute every dot product and zero the gated ones here.
pub fn relu_gate(out: &mut Mat, mask: &Mat) {
    debug_assert_eq!(out.shape(), mask.shape());
    for (o, &m) in out.as_mut_slice().iter_mut().zip(mask.as_slice()) {
        *o = if *o > 0.0 && m != 0.0 { *o } else { 0.0 };
    }
}

/// A layer prepared for conditional execution: transposed weights + bias.
#[derive(Clone, Debug)]
pub struct MaskedLayer {
    /// `Wᵀ`: `h × d`, row `j` is output unit `j`'s incoming weights.
    pub wt: Mat,
    pub bias: Vec<f32>,
}

impl MaskedLayer {
    /// Prepare from the standard `d × h` weight matrix.
    pub fn new(w: &Mat, bias: &[f32]) -> MaskedLayer {
        assert_eq!(w.cols(), bias.len());
        MaskedLayer { wt: w.transpose(), bias: bias.to_vec() }
    }

    pub fn in_dim(&self) -> usize {
        self.wt.cols()
    }

    pub fn out_dim(&self) -> usize {
        self.wt.rows()
    }

    /// One output row of `σ(a·W + b) ⊙ S`: computes the masked entries,
    /// zeroes the rest (so a dirty/reused output buffer is fine). Returns
    /// the number of dot products computed.
    #[inline]
    fn masked_row(&self, arow: &[f32], mrow: &[f32], orow: &mut [f32]) -> usize {
        let mut computed = 0usize;
        for (j, out) in orow.iter_mut().enumerate() {
            if mrow[j] != 0.0 {
                let z = dot(arow, self.wt.row(j)) + self.bias[j];
                *out = if z > 0.0 { z } else { 0.0 };
                computed += 1;
            } else {
                *out = 0.0;
            }
        }
        computed
    }

    /// [`Self::masked_row`] with the vectorized dot ([`dot_simd`]): same
    /// masked-entry selection and counting; only the dot's accumulation
    /// order differs — the `masked_simd` kernel's tolerance-tier delta.
    #[inline]
    fn masked_row_simd(
        &self,
        caps: SimdCaps,
        arow: &[f32],
        mrow: &[f32],
        orow: &mut [f32],
    ) -> usize {
        let mut computed = 0usize;
        for (j, out) in orow.iter_mut().enumerate() {
            if mrow[j] != 0.0 {
                let z = dot_simd(caps, arow, self.wt.row(j)) + self.bias[j];
                *out = if z > 0.0 { z } else { 0.0 };
                computed += 1;
            } else {
                *out = 0.0;
            }
        }
        computed
    }

    /// One output row of the dense path `σ(a·W + b)` (shared by the serial
    /// and parallel dense variants, mirroring [`Self::masked_row`]).
    #[inline]
    fn dense_row(&self, arow: &[f32], orow: &mut [f32]) {
        for (j, out) in orow.iter_mut().enumerate() {
            let z = dot(arow, self.wt.row(j)) + self.bias[j];
            *out = if z > 0.0 { z } else { 0.0 };
        }
    }

    fn check_shapes(&self, a: &Mat, mask: &Mat, out: &Mat) {
        let (n, d) = a.shape();
        let h = self.out_dim();
        assert_eq!(d, self.in_dim(), "input dim mismatch");
        assert_eq!(mask.shape(), (n, h), "mask shape mismatch");
        assert_eq!(out.shape(), (n, h), "output shape mismatch");
    }

    /// `σ(a·W + b) ⊙ S` into a caller-owned buffer (overwritten, not
    /// accumulated — reused buffers need no clearing). Returns the number of
    /// dot products actually computed.
    pub fn forward_masked_into(&self, a: &Mat, mask: &Mat, out: &mut Mat) -> usize {
        self.check_shapes(a, mask, out);
        let n = a.rows();
        let mut computed = 0usize;
        for i in 0..n {
            computed += self.masked_row(a.row(i), mask.row(i), out.row_mut(i));
        }
        computed
    }

    /// Parallel [`Self::forward_masked_into`] on an execution target (pool
    /// or lease slice): batch rows are sharded across workers; the
    /// per-shard counts are summed in shard order. Output and count are
    /// bit-identical to the serial kernel for any thread count or lease
    /// width.
    pub fn forward_masked_par<P: Parallelism>(
        &self,
        a: &Mat,
        mask: &Mat,
        out: &mut Mat,
        par: &P,
    ) -> usize {
        self.check_shapes(a, mask, out);
        let n = a.rows();
        let h = self.out_dim();
        if par.width() == 1 || n < 2 || h == 0 {
            return self.forward_masked_into(a, mask, out);
        }
        let rows_per = chunk_rows(n, par.width(), 1);
        let counts = par_row_chunks(par, out, rows_per, |row0, band| {
            let rows = band.len() / h;
            let mut computed = 0usize;
            for i in 0..rows {
                computed += self.masked_row(
                    a.row(row0 + i),
                    mask.row(row0 + i),
                    &mut band[i * h..(i + 1) * h],
                );
            }
            computed
        });
        counts.iter().sum()
    }

    /// [`Self::forward_masked_par`] through an execution context: chunked
    /// by the ctx's lease width — the serving backend's hot path.
    pub fn forward_masked_ctx(
        &self,
        a: &Mat,
        mask: &Mat,
        out: &mut Mat,
        ctx: &mut ExecCtx<'_>,
    ) -> usize {
        self.forward_masked_par(a, mask, out, ctx.lease())
    }

    /// Serial [`Self::forward_masked_into`] with vectorized dot products —
    /// the `masked_simd` kernel's oracle. Same mask selection and count;
    /// each computed entry is within the kernel's declared ULP tolerance of
    /// the scalar kernel's (all of `caps`' ISA paths are bit-identical to
    /// each other, so `CONDCOMP_FORCE_SCALAR` never changes results).
    pub fn forward_masked_simd_into(
        &self,
        caps: SimdCaps,
        a: &Mat,
        mask: &Mat,
        out: &mut Mat,
    ) -> usize {
        self.check_shapes(a, mask, out);
        let n = a.rows();
        let mut computed = 0usize;
        for i in 0..n {
            computed += self.masked_row_simd(caps, a.row(i), mask.row(i), out.row_mut(i));
        }
        computed
    }

    /// Parallel [`Self::forward_masked_simd_into`] on an execution target —
    /// same sharding and shard-order count reduction as
    /// [`Self::forward_masked_par`], so output and count are bit-identical
    /// to the serial SIMD kernel for any thread count or lease width.
    pub fn forward_masked_simd_par<P: Parallelism>(
        &self,
        caps: SimdCaps,
        a: &Mat,
        mask: &Mat,
        out: &mut Mat,
        par: &P,
    ) -> usize {
        self.check_shapes(a, mask, out);
        let n = a.rows();
        let h = self.out_dim();
        if par.width() == 1 || n < 2 || h == 0 {
            return self.forward_masked_simd_into(caps, a, mask, out);
        }
        let rows_per = chunk_rows(n, par.width(), 1);
        let counts = par_row_chunks(par, out, rows_per, |row0, band| {
            let rows = band.len() / h;
            let mut computed = 0usize;
            for i in 0..rows {
                computed += self.masked_row_simd(
                    caps,
                    a.row(row0 + i),
                    mask.row(row0 + i),
                    &mut band[i * h..(i + 1) * h],
                );
            }
            computed
        });
        counts.iter().sum()
    }

    /// [`Self::forward_masked_simd_par`] through an execution context —
    /// the `masked_simd` registry kernel's entry point.
    pub fn forward_masked_simd_ctx(
        &self,
        caps: SimdCaps,
        a: &Mat,
        mask: &Mat,
        out: &mut Mat,
        ctx: &mut ExecCtx<'_>,
    ) -> usize {
        self.forward_masked_simd_par(caps, a, mask, out, ctx.lease())
    }

    /// `σ(a·W + b) ⊙ S`, computing only where `S = 1`. Allocating wrapper
    /// over [`Self::forward_masked_into`] (tests and one-off callers; the
    /// serving path reuses buffers via the `_into`/`_par` variants).
    pub fn forward_masked(&self, a: &Mat, mask: &Mat) -> (Mat, usize) {
        let mut out = Mat::zeros(a.rows(), self.out_dim());
        let computed = self.forward_masked_into(a, mask, &mut out);
        (out, computed)
    }

    /// Dense reference: `σ(a·W + b)` with no mask (control path through the
    /// same data layout, used for timing comparisons).
    pub fn forward_dense(&self, a: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows(), self.out_dim());
        self.forward_dense_into(a, &mut out);
        out
    }

    /// Dense path into a caller-owned buffer.
    pub fn forward_dense_into(&self, a: &Mat, out: &mut Mat) {
        let (n, d) = a.shape();
        assert_eq!(d, self.in_dim());
        let h = self.out_dim();
        assert_eq!(out.shape(), (n, h), "output shape mismatch");
        for i in 0..n {
            self.dense_row(a.row(i), out.row_mut(i));
        }
    }

    /// Parallel dense path on an execution target (row-sharded;
    /// bit-identical to [`Self::forward_dense_into`] for any thread count
    /// or lease width).
    pub fn forward_dense_par<P: Parallelism>(&self, a: &Mat, out: &mut Mat, par: &P) {
        let (n, d) = a.shape();
        assert_eq!(d, self.in_dim());
        let h = self.out_dim();
        assert_eq!(out.shape(), (n, h), "output shape mismatch");
        if par.width() == 1 || n < 2 || h == 0 {
            self.forward_dense_into(a, out);
            return;
        }
        let rows_per = chunk_rows(n, par.width(), 1);
        par_row_chunks(par, out, rows_per, |row0, band| {
            let rows = band.len() / h;
            for i in 0..rows {
                self.dense_row(a.row(row0 + i), &mut band[i * h..(i + 1) * h]);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::nn::mlp::add_bias;
    use crate::parallel::ThreadPool;
    use crate::util::proptest::property;
    use crate::util::Pcg32;

    fn dense_ref(a: &Mat, w: &Mat, b: &[f32]) -> Mat {
        let mut z = matmul(a, w);
        add_bias(&mut z, b);
        z.map_inplace(|v| if v > 0.0 { v } else { 0.0 });
        z
    }

    #[test]
    fn all_ones_mask_matches_dense() {
        property("masked == dense under full mask", 16, |rng| {
            let n = rng.index(8) + 1;
            let d = rng.index(20) + 1;
            let h = rng.index(20) + 1;
            let a = Mat::randn(n, d, 1.0, rng);
            let w = Mat::randn(d, h, 1.0, rng);
            let b: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
            let layer = MaskedLayer::new(&w, &b);
            let (got, computed) = layer.forward_masked(&a, &Mat::full(n, h, 1.0));
            assert_eq!(computed, n * h);
            assert!(got.max_abs_diff(&dense_ref(&a, &w, &b)) < 1e-4);
            assert!(layer.forward_dense(&a).max_abs_diff(&got) < 1e-4);
        });
    }

    #[test]
    fn zero_mask_computes_nothing() {
        let mut rng = Pcg32::seeded(2);
        let a = Mat::randn(3, 5, 1.0, &mut rng);
        let w = Mat::randn(5, 4, 1.0, &mut rng);
        let layer = MaskedLayer::new(&w, &[0.0; 4]);
        let (out, computed) = layer.forward_masked(&a, &Mat::zeros(3, 4));
        assert_eq!(computed, 0);
        assert!(out.as_slice().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn partial_mask_selects_entries() {
        property("masked entries match dense, others zero", 16, |rng| {
            let n = rng.index(5) + 1;
            let d = rng.index(12) + 1;
            let h = rng.index(12) + 1;
            let a = Mat::randn(n, d, 1.0, rng);
            let w = Mat::randn(d, h, 1.0, rng);
            let b: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
            let mask = Mat::from_fn(n, h, |_, _| if rng.bernoulli(0.4) { 1.0 } else { 0.0 });
            let layer = MaskedLayer::new(&w, &b);
            let (got, computed) = layer.forward_masked(&a, &mask);
            let want = dense_ref(&a, &w, &b);
            let live = mask.as_slice().iter().filter(|&&m| m != 0.0).count();
            assert_eq!(computed, live);
            for i in 0..n {
                for j in 0..h {
                    if mask[(i, j)] != 0.0 {
                        assert!((got[(i, j)] - want[(i, j)]).abs() < 1e-4);
                    } else {
                        assert_eq!(got[(i, j)], 0.0);
                    }
                }
            }
        });
    }

    #[test]
    fn into_variant_overwrites_dirty_buffers() {
        let mut rng = Pcg32::seeded(5);
        let a = Mat::randn(4, 6, 1.0, &mut rng);
        let w = Mat::randn(6, 5, 1.0, &mut rng);
        let b: Vec<f32> = (0..5).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
        let mask = Mat::from_fn(4, 5, |_, _| if rng.bernoulli(0.5) { 1.0 } else { 0.0 });
        let layer = MaskedLayer::new(&w, &b);
        let (want, want_count) = layer.forward_masked(&a, &mask);
        let mut out = Mat::full(4, 5, f32::NAN); // simulate a reused buffer
        let count = layer.forward_masked_into(&a, &mask, &mut out);
        assert_eq!(count, want_count);
        assert_eq!(out.as_slice(), want.as_slice());
    }

    /// The determinism contract for the parallel kernel: output *and*
    /// computed count bit-identical to the serial oracle at thread counts
    /// 1, 2 and 7, over random shapes and masks.
    #[test]
    fn parallel_is_bit_identical_to_serial_for_any_thread_count() {
        for threads in [1usize, 2, 7] {
            let pool = ThreadPool::new(threads);
            property("parallel masked == serial masked", 12, |rng| {
                let n = rng.index(40) + 1;
                let d = rng.index(24) + 1;
                let h = rng.index(24) + 1;
                let a = Mat::randn(n, d, 1.0, rng);
                let w = Mat::randn(d, h, 1.0, rng);
                let b: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
                let alpha = rng.uniform();
                let mask =
                    Mat::from_fn(n, h, |_, _| if rng.bernoulli(alpha) { 1.0 } else { 0.0 });
                let layer = MaskedLayer::new(&w, &b);
                let (want, want_count) = layer.forward_masked(&a, &mask);
                let mut got = Mat::full(n, h, f32::NAN);
                let count = layer.forward_masked_par(&a, &mask, &mut got, &pool);
                assert_eq!(count, want_count, "threads={threads}");
                assert_eq!(got.as_slice(), want.as_slice(), "threads={threads}");
            });
        }
    }

    /// Lease widths are covered by the same determinism contract: any
    /// slice of a pool — including a zero-grant inline lease and the ctx
    /// entry point — reproduces the serial output and count bitwise.
    #[test]
    fn leased_masked_kernel_is_bit_identical_to_serial() {
        use crate::exec::ExecCtx;
        let mut rng = Pcg32::seeded(53);
        let (n, d, h) = (37, 22, 19);
        let a = Mat::randn(n, d, 1.0, &mut rng);
        let w = Mat::randn(d, h, 1.0, &mut rng);
        let b: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
        let mask = Mat::from_fn(n, h, |_, _| if rng.bernoulli(0.4) { 1.0 } else { 0.0 });
        let layer = MaskedLayer::new(&w, &b);
        let (want, want_count) = layer.forward_masked(&a, &mask);
        let pool = ThreadPool::new(4);
        for k in [0usize, 1, 3, 4] {
            let lease = pool.lease(k);
            let mut got = Mat::full(n, h, f32::NAN);
            let count = layer.forward_masked_par(&a, &mask, &mut got, &lease);
            assert_eq!(count, want_count, "lease {k}");
            assert_eq!(got.as_slice(), want.as_slice(), "lease {k}");
            drop(lease);
            let mut ctx = ExecCtx::over(pool.lease(k));
            let mut via_ctx = Mat::full(n, h, f32::NAN);
            let count = layer.forward_masked_ctx(&a, &mask, &mut via_ctx, &mut ctx);
            assert_eq!(count, want_count, "ctx lease {k}");
            assert_eq!(via_ctx.as_slice(), want.as_slice(), "ctx lease {k}");
        }
        assert_eq!(pool.leased(), 0);
    }

    #[test]
    fn parallel_dense_is_bit_identical_to_serial() {
        for threads in [1usize, 2, 7] {
            let pool = ThreadPool::new(threads);
            let mut rng = Pcg32::seeded(41);
            let a = Mat::randn(33, 20, 1.0, &mut rng);
            let w = Mat::randn(20, 15, 1.0, &mut rng);
            let b: Vec<f32> = (0..15).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
            let layer = MaskedLayer::new(&w, &b);
            let want = layer.forward_dense(&a);
            let mut got = Mat::full(33, 15, f32::NAN);
            layer.forward_dense_par(&a, &mut got, &pool);
            assert_eq!(got.as_slice(), want.as_slice(), "threads={threads}");
        }
    }

    /// The SIMD masked kernel against the scalar oracle: identical mask
    /// selection (exact count, exact zeros) and tolerance-tier values on
    /// the computed entries — under both the native and forced-scalar caps.
    #[test]
    fn simd_masked_matches_scalar_oracle_within_tolerance() {
        use crate::util::ulp::within_tolerance;
        for caps in [SimdCaps::get(), SimdCaps::scalar()] {
            property("forward_masked_simd ≈ forward_masked", 12, |rng| {
                let n = rng.index(20) + 1;
                let d = rng.index(60) + 1;
                let h = rng.index(20) + 1;
                let a = Mat::randn(n, d, 1.0, rng);
                let w = Mat::randn(d, h, 1.0, rng);
                let b: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
                let mask = Mat::from_fn(n, h, |_, _| if rng.bernoulli(0.4) { 1.0 } else { 0.0 });
                let layer = MaskedLayer::new(&w, &b);
                let (want, want_count) = layer.forward_masked(&a, &mask);
                let mut got = Mat::full(n, h, f32::NAN);
                let count = layer.forward_masked_simd_into(caps, &a, &mask, &mut got);
                assert_eq!(count, want_count, "SIMD mask selection must match exactly");
                for (i, (&g, &o)) in got.as_slice().iter().zip(want.as_slice()).enumerate() {
                    if mask.as_slice()[i] == 0.0 {
                        assert_eq!(g, 0.0, "dead entries stay exactly zero");
                    } else {
                        assert!(within_tolerance(g, o, 4096), "[{i}] got={g} want={o}");
                    }
                }
            });
        }
    }

    /// The SIMD kernel's own determinism contract: parallel and ctx runs
    /// (threads {1,2,7} × lease widths incl. zero-grant) are bit-identical
    /// to its serial form, and native vs forced-scalar caps agree bitwise.
    #[test]
    fn simd_masked_parallel_is_bit_identical_to_simd_serial() {
        use crate::exec::ExecCtx;
        let mut rng = Pcg32::seeded(77);
        let (n, d, h) = (37, 45, 19);
        let a = Mat::randn(n, d, 1.0, &mut rng);
        let w = Mat::randn(d, h, 1.0, &mut rng);
        let b: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
        let mask = Mat::from_fn(n, h, |_, _| if rng.bernoulli(0.4) { 1.0 } else { 0.0 });
        let layer = MaskedLayer::new(&w, &b);
        let native = SimdCaps::get();
        let mut want = Mat::full(n, h, f32::NAN);
        let want_count = layer.forward_masked_simd_into(native, &a, &mask, &mut want);
        // Cross-ISA: the forced-scalar path reproduces the native path bitwise.
        let mut scalar = Mat::full(n, h, f32::NAN);
        let scalar_count = layer.forward_masked_simd_into(SimdCaps::scalar(), &a, &mask, &mut scalar);
        assert_eq!(scalar_count, want_count);
        assert_eq!(scalar.as_slice(), want.as_slice(), "ISA paths must agree bitwise");
        for threads in [1usize, 2, 7] {
            let pool = ThreadPool::new(threads);
            let mut got = Mat::full(n, h, f32::NAN);
            let count = layer.forward_masked_simd_par(native, &a, &mask, &mut got, &pool);
            assert_eq!(count, want_count, "threads={threads}");
            assert_eq!(got.as_slice(), want.as_slice(), "threads={threads}");
            for k in [0usize, 1, threads] {
                let mut ctx = ExecCtx::over(pool.lease(k));
                let mut via_ctx = Mat::full(n, h, f32::NAN);
                let count = layer.forward_masked_simd_ctx(native, &a, &mask, &mut via_ctx, &mut ctx);
                assert_eq!(count, want_count, "ctx lease {k}");
                assert_eq!(via_ctx.as_slice(), want.as_slice(), "ctx lease {k}");
            }
            assert_eq!(pool.leased(), 0);
        }
    }

    #[test]
    #[should_panic(expected = "mask shape")]
    fn mask_shape_checked() {
        let a = Mat::zeros(2, 3);
        let layer = MaskedLayer::new(&Mat::zeros(3, 4), &[0.0; 4]);
        let _ = layer.forward_masked(&a, &Mat::zeros(2, 5));
    }
}
