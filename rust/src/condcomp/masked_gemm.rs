//! Conditional (mask-driven) matrix multiplication.
//!
//! `masked_matmul_bias_relu(a, S)` computes `σ(a·W + b) ⊙ S` touching only
//! the `(i, j)` dot products with `S[i,j] = 1`. With activation density α
//! this performs `α·N·(2d−1)·h` FLOPs versus the dense `N·(2d−1)·h`
//! (paper §3.4) — the source of the measured speedup in `benches/`.
//!
//! The weights are stored transposed (`Wᵀ`, row per output unit) so each
//! computed entry is a contiguous·contiguous dot product; the mask is
//! consumed row-major, matching its production order by the estimator.

use crate::linalg::gemm::dot;
use crate::linalg::Mat;

/// A layer prepared for conditional execution: transposed weights + bias.
#[derive(Clone, Debug)]
pub struct MaskedLayer {
    /// `Wᵀ`: `h × d`, row `j` is output unit `j`'s incoming weights.
    pub wt: Mat,
    pub bias: Vec<f32>,
}

impl MaskedLayer {
    /// Prepare from the standard `d × h` weight matrix.
    pub fn new(w: &Mat, bias: &[f32]) -> MaskedLayer {
        assert_eq!(w.cols(), bias.len());
        MaskedLayer { wt: w.transpose(), bias: bias.to_vec() }
    }

    pub fn in_dim(&self) -> usize {
        self.wt.cols()
    }

    pub fn out_dim(&self) -> usize {
        self.wt.rows()
    }

    /// `σ(a·W + b) ⊙ S`, computing only where `S = 1`. Returns the output and
    /// the number of dot products actually computed.
    pub fn forward_masked(&self, a: &Mat, mask: &Mat) -> (Mat, usize) {
        let (n, d) = a.shape();
        let h = self.out_dim();
        assert_eq!(d, self.in_dim(), "input dim mismatch");
        assert_eq!(mask.shape(), (n, h), "mask shape mismatch");
        let mut out = Mat::zeros(n, h);
        let mut computed = 0usize;
        for i in 0..n {
            let arow = a.row(i);
            let mrow = mask.row(i);
            let orow = out.row_mut(i);
            for j in 0..h {
                if mrow[j] != 0.0 {
                    let z = dot(arow, self.wt.row(j)) + self.bias[j];
                    orow[j] = if z > 0.0 { z } else { 0.0 };
                    computed += 1;
                }
            }
        }
        (out, computed)
    }

    /// Dense reference: `σ(a·W + b)` with no mask (control path through the
    /// same data layout, used for timing comparisons).
    pub fn forward_dense(&self, a: &Mat) -> Mat {
        let (n, d) = a.shape();
        assert_eq!(d, self.in_dim());
        let h = self.out_dim();
        let mut out = Mat::zeros(n, h);
        for i in 0..n {
            let arow = a.row(i);
            let orow = out.row_mut(i);
            for j in 0..h {
                let z = dot(arow, self.wt.row(j)) + self.bias[j];
                orow[j] = if z > 0.0 { z } else { 0.0 };
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::nn::mlp::add_bias;
    use crate::util::proptest::property;
    use crate::util::Pcg32;

    fn dense_ref(a: &Mat, w: &Mat, b: &[f32]) -> Mat {
        let mut z = matmul(a, w);
        add_bias(&mut z, b);
        z.map_inplace(|v| if v > 0.0 { v } else { 0.0 });
        z
    }

    #[test]
    fn all_ones_mask_matches_dense() {
        property("masked == dense under full mask", 16, |rng| {
            let n = rng.index(8) + 1;
            let d = rng.index(20) + 1;
            let h = rng.index(20) + 1;
            let a = Mat::randn(n, d, 1.0, rng);
            let w = Mat::randn(d, h, 1.0, rng);
            let b: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
            let layer = MaskedLayer::new(&w, &b);
            let (got, computed) = layer.forward_masked(&a, &Mat::full(n, h, 1.0));
            assert_eq!(computed, n * h);
            assert!(got.max_abs_diff(&dense_ref(&a, &w, &b)) < 1e-4);
            assert!(layer.forward_dense(&a).max_abs_diff(&got) < 1e-4);
        });
    }

    #[test]
    fn zero_mask_computes_nothing() {
        let mut rng = Pcg32::seeded(2);
        let a = Mat::randn(3, 5, 1.0, &mut rng);
        let w = Mat::randn(5, 4, 1.0, &mut rng);
        let layer = MaskedLayer::new(&w, &[0.0; 4]);
        let (out, computed) = layer.forward_masked(&a, &Mat::zeros(3, 4));
        assert_eq!(computed, 0);
        assert!(out.as_slice().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn partial_mask_selects_entries() {
        property("masked entries match dense, others zero", 16, |rng| {
            let n = rng.index(5) + 1;
            let d = rng.index(12) + 1;
            let h = rng.index(12) + 1;
            let a = Mat::randn(n, d, 1.0, rng);
            let w = Mat::randn(d, h, 1.0, rng);
            let b: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
            let mask = Mat::from_fn(n, h, |_, _| if rng.bernoulli(0.4) { 1.0 } else { 0.0 });
            let layer = MaskedLayer::new(&w, &b);
            let (got, computed) = layer.forward_masked(&a, &mask);
            let want = dense_ref(&a, &w, &b);
            let live = mask.as_slice().iter().filter(|&&m| m != 0.0).count();
            assert_eq!(computed, live);
            for i in 0..n {
                for j in 0..h {
                    if mask[(i, j)] != 0.0 {
                        assert!((got[(i, j)] - want[(i, j)]).abs() < 1e-4);
                    } else {
                        assert_eq!(got[(i, j)], 0.0);
                    }
                }
            }
        });
    }

    #[test]
    #[should_panic(expected = "mask shape")]
    fn mask_shape_checked() {
        let a = Mat::zeros(2, 3);
        let layer = MaskedLayer::new(&Mat::zeros(3, 4), &[0.0; 4]);
        let _ = layer.forward_masked(&a, &Mat::zeros(2, 5));
    }
}
