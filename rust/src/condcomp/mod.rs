//! The conditional forward path — where the paper's predicted speed gain is
//! actually realized.
//!
//! - [`masked_gemm`] — a GEMM that computes only the output entries the sign
//!   estimator predicts live ("we skip those dot products based on the
//!   prediction", §3.1). Works off a transposed weight copy so each computed
//!   dot product reads two contiguous strips; hot-path variants run batch
//!   rows on the shared worker pool and write into caller-owned buffers.
//! - [`dispatch`] — the density-adaptive kernel choice as an open cost
//!   table: [`DispatchPolicy`] holds one measured per-FLOP cost column per
//!   registered kernel and routes each batch to the argmin;
//!   [`PolicyTable`] holds one policy per hidden layer (fitted by
//!   [`crate::autotune`], persisted in a machine profile).
//! - [`registry`] — the open kernel set behind dispatch:
//!   [`KernelRegistry`] maps stable [`KernelId`]s (`dense`,
//!   `dense_packed`, `dense_simd`, `dense_i8`, `masked`, `masked_simd`,
//!   `masked_i8`, feature-gated `pjrt`) to object-safe [`ComputeKernel`]
//!   implementations running through an [`crate::exec::ExecCtx`]; each
//!   declares an [`EquivalenceTier`] (bit-exact vs ULP-bounded vs
//!   sign-agreement) scoping how closely it matches its serial oracle; the
//!   sign-agreement (int8) class is excluded from default routing and
//!   enters only via an explicit allow-list.
//! - [`cond_mlp`] — an estimator-augmented network forward built on the
//!   masked GEMM, with exact FLOP accounting per layer.
//! - [`flops`] — operation counters shared by the engine and the benches.

pub mod masked_gemm;
pub mod cond_mlp;
pub mod dispatch;
pub mod flops;
pub mod registry;

pub use cond_mlp::CondMlp;
pub use dispatch::{
    CostColumn, DispatchPolicy, ElasticConfig, KernelId, PolicyTable, WorkModel, BUILTIN_KERNELS,
};
pub use flops::{FlopBreakdown, LayerFlops};
pub use masked_gemm::{relu_gate, MaskedLayer};
pub use registry::{
    ComputeKernel, EquivalenceTier, KernelRegistry, LayerOperands, QUANT_SIGN_BAND_REL,
    QUANT_TIER_AGREEMENT_BP,
};
