//! The conditional forward path — where the paper's predicted speed gain is
//! actually realized.
//!
//! - [`masked_gemm`] — a GEMM that computes only the output entries the sign
//!   estimator predicts live ("we skip those dot products based on the
//!   prediction", §3.1). Works off a transposed weight copy so each computed
//!   dot product reads two contiguous strips.
//! - [`cond_mlp`] — an estimator-augmented network forward built on the
//!   masked GEMM, with exact FLOP accounting per layer.
//! - [`flops`] — operation counters shared by the engine and the benches.

pub mod masked_gemm;
pub mod cond_mlp;
pub mod flops;

pub use cond_mlp::CondMlp;
pub use flops::{FlopBreakdown, LayerFlops};
pub use masked_gemm::MaskedLayer;
