//! The conditional forward path — where the paper's predicted speed gain is
//! actually realized.
//!
//! - [`masked_gemm`] — a GEMM that computes only the output entries the sign
//!   estimator predicts live ("we skip those dot products based on the
//!   prediction", §3.1). Works off a transposed weight copy so each computed
//!   dot product reads two contiguous strips; hot-path variants run batch
//!   rows on the shared worker pool and write into caller-owned buffers.
//! - [`dispatch`] — the density-adaptive kernel choice: masked dot products
//!   beat the dense axpy GEMM only below a *measured*, *shape-dependent*
//!   density threshold; [`DispatchPolicy`] combines one measurement with
//!   the §3.4 cost model, and [`PolicyTable`] holds one per hidden layer
//!   (fitted by [`crate::autotune`], persisted in a machine profile).
//! - [`cond_mlp`] — an estimator-augmented network forward built on the
//!   masked GEMM, with exact FLOP accounting per layer.
//! - [`flops`] — operation counters shared by the engine and the benches.

pub mod masked_gemm;
pub mod cond_mlp;
pub mod dispatch;
pub mod flops;

pub use cond_mlp::CondMlp;
pub use dispatch::{DispatchPolicy, Kernel, PolicyTable};
pub use flops::{FlopBreakdown, LayerFlops};
pub use masked_gemm::MaskedLayer;
