//! FLOP accounting, matching the paper's §3.4 conventions exactly:
//! a dot product of length `d` costs `2d − 1` (d multiplies, d−1 adds), the
//! activation function costs 1 per element.

/// Exact operation counts for one layer's forward, one batch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LayerFlops {
    /// Dense path: `N·(2d−1)·h + N·h` (Eq. 8).
    pub dense: u64,
    /// Estimator overhead: `N·(2d−1)·k + N·(2k−1)·h + N·h` (low-rank product
    /// + sgn), Eq. 9's first three terms.
    pub estimator: u64,
    /// Conditional path: `(2d−1)·(computed) + (computed)` where `computed` is
    /// the number of dot products actually evaluated (α·N·h in expectation).
    pub conditional: u64,
    /// Dot products computed by the conditional path.
    pub computed_units: u64,
    /// Total output units (N·h).
    pub total_units: u64,
}

impl LayerFlops {
    /// Build from shapes and the measured live-unit count.
    pub fn from_counts(n: usize, d: usize, h: usize, k: usize, computed: usize) -> LayerFlops {
        let (n64, d64, h64, k64, c64) = (n as u64, d as u64, h as u64, k as u64, computed as u64);
        let dense = n64 * (2 * d64 - 1) * h64 + n64 * h64;
        let estimator = if k == 0 {
            0
        } else {
            n64 * (2 * d64 - 1) * k64 + n64 * (2 * k64 - 1) * h64 + n64 * h64
        };
        let conditional = c64 * (2 * d64 - 1) + c64;
        LayerFlops { dense, estimator, conditional, computed_units: c64, total_units: n64 * h64 }
    }

    /// Achieved density α̂ = computed / total.
    pub fn density(&self) -> f64 {
        if self.total_units == 0 {
            0.0
        } else {
            self.computed_units as f64 / self.total_units as f64
        }
    }

    /// Total FLOPs for the estimator-augmented path (excluding SVD refresh,
    /// which is amortized — see [`FlopBreakdown::with_svd`]).
    pub fn augmented(&self) -> u64 {
        self.estimator + self.conditional
    }
}

/// Whole-network accounting (Eq. 11): Σ F_nn / Σ F_ae.
#[derive(Clone, Debug, Default)]
pub struct FlopBreakdown {
    pub layers: Vec<LayerFlops>,
    /// Amortized SVD refresh cost per forward pass (β·O(d·h·min(d,h))).
    pub svd_amortized: f64,
}

impl FlopBreakdown {
    pub fn push(&mut self, layer: LayerFlops) {
        self.layers.push(layer);
    }

    /// Account the once-per-`period` SVD refresh: `beta = batch/period_examples`.
    pub fn with_svd(mut self, dims: &[(usize, usize)], beta: f64) -> FlopBreakdown {
        self.svd_amortized = dims
            .iter()
            .map(|&(d, h)| beta * (d as f64) * (h as f64) * (d.min(h) as f64))
            .sum();
        self
    }

    pub fn total_dense(&self) -> u64 {
        self.layers.iter().map(|l| l.dense).sum()
    }

    pub fn total_augmented(&self) -> f64 {
        self.layers.iter().map(|l| l.augmented()).sum::<u64>() as f64 + self.svd_amortized
    }

    /// The paper's relative speedup `Σ F_nn / Σ F_ae` (Eq. 11).
    pub fn speedup(&self) -> f64 {
        let denom = self.total_augmented();
        if denom == 0.0 {
            1.0
        } else {
            self.total_dense() as f64 / denom
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_formulas() {
        // N=1, d=784, h=1000, k=50, α=0.1 → computed = 100.
        let lf = LayerFlops::from_counts(1, 784, 1000, 50, 100);
        assert_eq!(lf.dense, (2 * 784 - 1) * 1000 + 1000);
        assert_eq!(lf.estimator, (2 * 784 - 1) * 50 + (2 * 50 - 1) * 1000 + 1000);
        assert_eq!(lf.conditional, 100 * (2 * 784 - 1) + 100);
        assert!((lf.density() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn zero_rank_means_no_estimator() {
        let lf = LayerFlops::from_counts(4, 100, 50, 0, 4 * 50);
        assert_eq!(lf.estimator, 0);
    }

    #[test]
    fn speedup_gt_one_when_sparse_and_lowrank() {
        // Strongly sparse (α = 0.05), small k: conditional must win big.
        let mut bd = FlopBreakdown::default();
        bd.push(LayerFlops::from_counts(1, 1000, 1000, 25, 50));
        assert!(bd.speedup() > 5.0, "speedup {}", bd.speedup());
    }

    #[test]
    fn speedup_lt_one_when_dense() {
        // α = 1: every unit computed, estimator is pure overhead.
        let mut bd = FlopBreakdown::default();
        bd.push(LayerFlops::from_counts(1, 1000, 1000, 100, 1000));
        assert!(bd.speedup() < 1.0, "speedup {}", bd.speedup());
    }

    #[test]
    fn svd_amortization_reduces_speedup() {
        let mut a = FlopBreakdown::default();
        a.push(LayerFlops::from_counts(1, 500, 500, 20, 25));
        let plain = a.speedup();
        // Per-example β for once-per-epoch refresh over 50k examples.
        let with = a.clone().with_svd(&[(500, 500)], 2e-5).speedup();
        assert!(with < plain);
        // The amortized SVD must be a small correction in this regime.
        assert!(with > plain * 0.5, "with {with} plain {plain}");
    }

    #[test]
    fn eq11_aggregates_layers() {
        let mut bd = FlopBreakdown::default();
        bd.push(LayerFlops::from_counts(1, 100, 100, 10, 10));
        bd.push(LayerFlops::from_counts(1, 100, 100, 10, 10));
        let one_dense = LayerFlops::from_counts(1, 100, 100, 10, 10).dense;
        assert_eq!(bd.total_dense(), 2 * one_dense);
    }
}
