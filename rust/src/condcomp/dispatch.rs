//! Density-adaptive kernel dispatch: dense-parallel vs masked-parallel, per
//! layer per batch.
//!
//! The masked kernel does `α·N·h` contiguous dot products; the dense axpy
//! GEMM does `N·h` output cells' worth of packed FMAs at a much higher
//! per-FLOP rate (dot accumulation chains defeat the vectorizer in a way
//! row-axpy does not — see the `linalg::gemm` module docs). So the masked
//! path wins only below a density threshold
//!
//! ```text
//! α* = (dense seconds) / (masked seconds at α = 1)
//!    = (dense per-FLOP cost) / (masked per-FLOP cost) = 1 / cost_ratio
//! ```
//!
//! The §3.4 cost model ([`LayerFlops`]) supplies the FLOP counts; the
//! per-FLOP cost ratio is **measured**, and it is *shape-dependent* — per-
//! layer `d × h` shapes have different cache behaviour, so each hidden
//! layer gets its own ratio. [`PolicyTable`] holds the per-layer policies;
//! they come from a persisted machine profile (`condcomp calibrate`, loaded
//! at `serve` startup), from online calibration via
//! [`crate::autotune::Autotuner`], or — per layer, as a last resort — from
//! [`DispatchPolicy::DEFAULT_COST_RATIO`], with a one-time warning naming
//! the profile path that was searched. The bench sweep records the fitted
//! per-layer thresholds in `BENCH_parallel.json`.

use super::flops::LayerFlops;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Which kernel executes a layer's forward for one batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Masked dot-product kernel, sharded over batch rows.
    MaskedParallel,
    /// Dense axpy GEMM, sharded over row panels (mask applied afterwards).
    DenseParallel,
}

/// Chooses the kernel from the batch's predicted mask density α and the
/// measured per-FLOP cost ratio of the two kernels.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DispatchPolicy {
    /// Masked-kernel seconds-per-FLOP divided by dense-kernel
    /// seconds-per-FLOP (> 1: a masked FLOP is slower than a dense FLOP).
    pub cost_ratio: f64,
}

impl DispatchPolicy {
    /// Fallback cost ratio for uncalibrated policies, from the rejected
    /// packed-dot experiment in the `linalg::gemm` docs (dot kernels ran a
    /// few× slower per FLOP than the axpy GEMM on the 1-core testbed). Run
    /// `condcomp calibrate` (the [`crate::autotune::Autotuner`] harness) or
    /// the bench sweep for per-layer measured values on the serving
    /// hardware.
    pub const DEFAULT_COST_RATIO: f64 = 3.0;

    /// Policy with an explicit (e.g. previously recorded) cost ratio.
    pub fn with_cost_ratio(cost_ratio: f64) -> DispatchPolicy {
        DispatchPolicy { cost_ratio: cost_ratio.max(1e-6) }
    }

    /// The α above which the dense kernel wins.
    pub fn density_threshold(&self) -> f64 {
        (1.0 / self.cost_ratio).clamp(0.0, 1.0)
    }

    /// Pick the kernel for one `n × d → h` layer at predicted density
    /// `alpha`, by comparing the §3.4 FLOP counts weighted by the measured
    /// per-FLOP costs.
    pub fn decide(&self, n: usize, d: usize, h: usize, alpha: f64) -> Kernel {
        let computed = (alpha.clamp(0.0, 1.0) * (n * h) as f64).round() as usize;
        let lf = LayerFlops::from_counts(n, d, h, 0, computed);
        if (lf.conditional as f64) * self.cost_ratio < lf.dense as f64 {
            Kernel::MaskedParallel
        } else {
            Kernel::DenseParallel
        }
    }
}

impl Default for DispatchPolicy {
    fn default() -> DispatchPolicy {
        DispatchPolicy { cost_ratio: DispatchPolicy::DEFAULT_COST_RATIO }
    }
}

/// Per-layer dispatch policies with a shared uncalibrated fallback.
///
/// A single global cost ratio ignores that different `d × h` layer shapes
/// have different cache behaviour, so their masked-vs-dense flip points
/// differ. The autotune subsystem ([`crate::autotune`]) measures each layer
/// shape separately and persists the result in a machine profile;
/// `PolicyTable` is the runtime form — one optional calibrated policy per
/// hidden layer, plus the fallback ([`DispatchPolicy::DEFAULT_COST_RATIO`])
/// for layers nothing has calibrated. The first fallback hit logs a
/// one-time warning naming the profile path that was searched, so a
/// silently-defaulting deployment is visible in the serve log.
#[derive(Clone, Debug)]
pub struct PolicyTable {
    /// `layers[l]` is hidden layer `l`'s calibrated policy; `None` falls
    /// back (and warns once).
    layers: Vec<Option<DispatchPolicy>>,
    fallback: DispatchPolicy,
    /// Where a machine profile was looked for — named by the warning.
    profile_path: Option<String>,
    /// One-time warning latch, shared across clones of this table.
    warned: Arc<AtomicBool>,
}

impl PolicyTable {
    /// A table with no calibrated layers: every lookup uses the fallback.
    pub fn uncalibrated(num_layers: usize) -> PolicyTable {
        PolicyTable {
            layers: vec![None; num_layers],
            fallback: DispatchPolicy::default(),
            profile_path: None,
            warned: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Every layer pinned to one explicit policy (tests; embedders with a
    /// single recorded global ratio). Counts as calibrated — no warning.
    pub fn uniform(policy: DispatchPolicy, num_layers: usize) -> PolicyTable {
        PolicyTable {
            layers: vec![Some(policy); num_layers],
            fallback: policy,
            profile_path: None,
            warned: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Record where a machine profile was (or would have been) looked for,
    /// so the fallback warning can name it.
    pub fn with_profile_path(mut self, path: impl Into<String>) -> PolicyTable {
        self.profile_path = Some(path.into());
        self
    }

    /// Number of hidden layers this table covers.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Install a calibrated policy for hidden layer `layer` (ignored if the
    /// index is out of range — profiles may describe a deeper model).
    pub fn set_layer(&mut self, layer: usize, policy: DispatchPolicy) {
        if let Some(slot) = self.layers.get_mut(layer) {
            *slot = Some(policy);
        }
    }

    /// Whether hidden layer `layer` has a calibrated (non-fallback) policy.
    pub fn is_calibrated(&self, layer: usize) -> bool {
        matches!(self.layers.get(layer), Some(Some(_)))
    }

    /// How many layers are calibrated.
    pub fn calibrated_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.is_some()).count()
    }

    /// The policy for hidden layer `layer`. Uncalibrated layers use the
    /// fallback and trigger the one-time warning.
    pub fn policy_for(&self, layer: usize) -> DispatchPolicy {
        match self.layers.get(layer).copied().flatten() {
            Some(p) => p,
            None => {
                self.warn_once(layer);
                self.fallback
            }
        }
    }

    fn warn_once(&self, layer: usize) {
        if !self.warned.swap(true, Ordering::Relaxed) {
            let looked = self
                .profile_path
                .as_deref()
                .unwrap_or("<autotune.profile_path not configured>");
            eprintln!(
                "warning: dispatch for layer {layer} is uncalibrated — no machine profile \
                 loaded (looked for {looked}); using DEFAULT_COST_RATIO = {}. \
                 Run `condcomp calibrate` to fit per-layer thresholds for this machine.",
                DispatchPolicy::DEFAULT_COST_RATIO
            );
        }
    }

    /// Per-layer α* values (fallback threshold where uncalibrated). Does not
    /// trigger the warning — this is the reporting path, not a decision.
    pub fn thresholds(&self) -> Vec<f64> {
        self.layers
            .iter()
            .map(|l| l.unwrap_or(self.fallback).density_threshold())
            .collect()
    }

    /// Human-readable per-layer table — the `serve` startup log.
    pub fn summary_lines(&self) -> Vec<String> {
        let mut lines = vec![format!(
            "{:<7} {:>12} {:>10} {:>12}",
            "layer", "cost-ratio", "α*", "source"
        )];
        for (l, slot) in self.layers.iter().enumerate() {
            let (p, source) = match slot {
                Some(p) => (*p, "calibrated"),
                None => (self.fallback, "fallback"),
            };
            lines.push(format!(
                "{:<7} {:>12.3} {:>10.4} {:>12}",
                l,
                p.cost_ratio,
                p.density_threshold(),
                source
            ));
        }
        lines
    }
}

/// Equality over the dispatch-relevant state (the warning latch and the
/// remembered profile path are diagnostics, not policy).
impl PartialEq for PolicyTable {
    fn eq(&self, other: &PolicyTable) -> bool {
        self.layers == other.layers && self.fallback == other.fallback
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_is_inverse_cost_ratio() {
        let p = DispatchPolicy::with_cost_ratio(4.0);
        assert!((p.density_threshold() - 0.25).abs() < 1e-12);
        // A faster-than-dense masked kernel would always win.
        let p = DispatchPolicy::with_cost_ratio(0.5);
        assert_eq!(p.density_threshold(), 1.0);
    }

    #[test]
    fn decide_flips_at_the_threshold() {
        let p = DispatchPolicy::with_cost_ratio(4.0); // α* = 0.25
        let (n, d, h) = (64, 512, 512);
        assert_eq!(p.decide(n, d, h, 0.05), Kernel::MaskedParallel);
        assert_eq!(p.decide(n, d, h, 0.20), Kernel::MaskedParallel);
        assert_eq!(p.decide(n, d, h, 0.30), Kernel::DenseParallel);
        assert_eq!(p.decide(n, d, h, 1.00), Kernel::DenseParallel);
    }

    #[test]
    fn extreme_densities_are_stable() {
        let p = DispatchPolicy::default();
        assert_eq!(p.decide(8, 100, 100, 0.0), Kernel::MaskedParallel);
        assert_eq!(p.decide(8, 100, 100, 1.0), Kernel::DenseParallel);
        // Out-of-range α is clamped, not UB.
        assert_eq!(p.decide(8, 100, 100, -3.0), Kernel::MaskedParallel);
        assert_eq!(p.decide(8, 100, 100, 7.0), Kernel::DenseParallel);
    }

    /// The point of the per-layer table: at the same batch density, two
    /// layers with different fitted ratios pick different kernels, each
    /// flipping just below/above its own α*.
    #[test]
    fn per_layer_policies_flip_at_their_own_thresholds() {
        let mut table = PolicyTable::uncalibrated(2);
        table.set_layer(0, DispatchPolicy::with_cost_ratio(2.0)); // α* = 0.5
        table.set_layer(1, DispatchPolicy::with_cost_ratio(10.0)); // α* = 0.1
        let (n, d, h) = (64, 512, 512);
        // Just below / above each layer's own threshold.
        assert_eq!(table.policy_for(0).decide(n, d, h, 0.45), Kernel::MaskedParallel);
        assert_eq!(table.policy_for(0).decide(n, d, h, 0.55), Kernel::DenseParallel);
        assert_eq!(table.policy_for(1).decide(n, d, h, 0.05), Kernel::MaskedParallel);
        assert_eq!(table.policy_for(1).decide(n, d, h, 0.15), Kernel::DenseParallel);
        // Same α, different layers → different kernels.
        assert_eq!(table.policy_for(0).decide(n, d, h, 0.3), Kernel::MaskedParallel);
        assert_eq!(table.policy_for(1).decide(n, d, h, 0.3), Kernel::DenseParallel);
        let t = table.thresholds();
        assert!((t[0] - 0.5).abs() < 1e-12 && (t[1] - 0.1).abs() < 1e-12);
    }

    #[test]
    fn uncalibrated_layers_fall_back_and_report() {
        let table = PolicyTable::uncalibrated(3).with_profile_path("/tmp/nope.json");
        assert_eq!(table.num_layers(), 3);
        assert_eq!(table.calibrated_layers(), 0);
        assert!(!table.is_calibrated(1));
        // Fallback policy is the global default; repeated lookups warn once
        // (the latch is per-table — asserted via the shared AtomicBool).
        assert_eq!(table.policy_for(0), DispatchPolicy::default());
        assert_eq!(table.policy_for(2), DispatchPolicy::default());
        // Out-of-range layers also fall back instead of panicking.
        assert_eq!(table.policy_for(99), DispatchPolicy::default());
        assert_eq!(table.summary_lines().len(), 4); // header + 3 layers
    }

    #[test]
    fn uniform_table_is_fully_calibrated() {
        let p = DispatchPolicy::with_cost_ratio(4.0);
        let table = PolicyTable::uniform(p, 2);
        assert_eq!(table.calibrated_layers(), 2);
        assert_eq!(table.policy_for(0), p);
        assert_eq!(table.policy_for(1), p);
        let mut expect = PolicyTable::uncalibrated(2);
        expect.set_layer(0, p);
        expect.set_layer(1, p);
        // PartialEq compares layers + fallback only; fallbacks differ here.
        assert_eq!(expect.thresholds(), table.thresholds());
    }
}
