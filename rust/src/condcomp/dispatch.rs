//! Density-adaptive kernel dispatch: dense-parallel vs masked-parallel, per
//! layer per batch.
//!
//! The masked kernel does `α·N·h` contiguous dot products; the dense axpy
//! GEMM does `N·h` output cells' worth of packed FMAs at a much higher
//! per-FLOP rate (dot accumulation chains defeat the vectorizer in a way
//! row-axpy does not — see the `linalg::gemm` module docs). So the masked
//! path wins only below a density threshold
//!
//! ```text
//! α* = (dense seconds) / (masked seconds at α = 1)
//!    = (dense per-FLOP cost) / (masked per-FLOP cost) = 1 / cost_ratio
//! ```
//!
//! The §3.4 cost model ([`LayerFlops`]) supplies the FLOP counts; the
//! per-FLOP cost ratio is **measured** — either at startup with
//! [`DispatchPolicy::calibrate`] (the `serve` command does this) or offline
//! by the bench sweep, which records the threshold in
//! `BENCH_parallel.json`. [`DispatchPolicy::DEFAULT_COST_RATIO`] is only the
//! fallback for callers that skip calibration.

use super::flops::LayerFlops;
use super::masked_gemm::MaskedLayer;
use crate::linalg::{matmul_into_par, Mat};
use crate::parallel::ThreadPool;
use crate::util::{Pcg32, Timer};

/// Which kernel executes a layer's forward for one batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Masked dot-product kernel, sharded over batch rows.
    MaskedParallel,
    /// Dense axpy GEMM, sharded over row panels (mask applied afterwards).
    DenseParallel,
}

/// Chooses the kernel from the batch's predicted mask density α and the
/// measured per-FLOP cost ratio of the two kernels.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DispatchPolicy {
    /// Masked-kernel seconds-per-FLOP divided by dense-kernel
    /// seconds-per-FLOP (> 1: a masked FLOP is slower than a dense FLOP).
    pub cost_ratio: f64,
}

impl DispatchPolicy {
    /// Fallback cost ratio for uncalibrated policies, from the rejected
    /// packed-dot experiment in the `linalg::gemm` docs (dot kernels ran a
    /// few× slower per FLOP than the axpy GEMM on the 1-core testbed). Run
    /// [`DispatchPolicy::calibrate`] or the bench sweep for a measured
    /// value on the serving hardware.
    pub const DEFAULT_COST_RATIO: f64 = 3.0;

    /// Policy with an explicit (e.g. previously recorded) cost ratio.
    pub fn with_cost_ratio(cost_ratio: f64) -> DispatchPolicy {
        DispatchPolicy { cost_ratio: cost_ratio.max(1e-6) }
    }

    /// The α above which the dense kernel wins.
    pub fn density_threshold(&self) -> f64 {
        (1.0 / self.cost_ratio).clamp(0.0, 1.0)
    }

    /// Pick the kernel for one `n × d → h` layer at predicted density
    /// `alpha`, by comparing the §3.4 FLOP counts weighted by the measured
    /// per-FLOP costs.
    pub fn decide(&self, n: usize, d: usize, h: usize, alpha: f64) -> Kernel {
        let computed = (alpha.clamp(0.0, 1.0) * (n * h) as f64).round() as usize;
        let lf = LayerFlops::from_counts(n, d, h, 0, computed);
        if (lf.conditional as f64) * self.cost_ratio < lf.dense as f64 {
            Kernel::MaskedParallel
        } else {
            Kernel::DenseParallel
        }
    }

    /// Measure the cost ratio on this machine/pool: times the dense-parallel
    /// GEMM against the masked-parallel kernel under a full (α = 1) mask on
    /// an `n × d → h` layer, taking the best of `reps` runs each. Costs a
    /// few milliseconds at the default sizes; `serve` runs it once at
    /// startup.
    pub fn calibrate(pool: &ThreadPool, n: usize, d: usize, h: usize, reps: usize) -> DispatchPolicy {
        let reps = reps.max(1);
        let mut rng = Pcg32::seeded(0xD15_7A7C);
        let a = Mat::randn(n, d, 0.5, &mut rng);
        let w = Mat::randn(d, h, 0.05, &mut rng);
        let bias = vec![0.0f32; h];
        let layer = MaskedLayer::new(&w, &bias);
        let full_mask = Mat::full(n, h, 1.0);
        let mut out = Mat::zeros(n, h);

        let mut t_dense = f64::INFINITY;
        let mut t_masked = f64::INFINITY;
        for _ in 0..reps {
            let t = Timer::start();
            matmul_into_par(&a, &w, &mut out, pool);
            t_dense = t_dense.min(t.elapsed_s());

            let t = Timer::start();
            let _ = layer.forward_masked_par(&a, &full_mask, &mut out, pool);
            t_masked = t_masked.min(t.elapsed_s());
        }
        if !(t_dense > 0.0) || !t_masked.is_finite() {
            return DispatchPolicy::default();
        }
        DispatchPolicy::with_cost_ratio(t_masked / t_dense)
    }
}

impl Default for DispatchPolicy {
    fn default() -> DispatchPolicy {
        DispatchPolicy { cost_ratio: DispatchPolicy::DEFAULT_COST_RATIO }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_is_inverse_cost_ratio() {
        let p = DispatchPolicy::with_cost_ratio(4.0);
        assert!((p.density_threshold() - 0.25).abs() < 1e-12);
        // A faster-than-dense masked kernel would always win.
        let p = DispatchPolicy::with_cost_ratio(0.5);
        assert_eq!(p.density_threshold(), 1.0);
    }

    #[test]
    fn decide_flips_at_the_threshold() {
        let p = DispatchPolicy::with_cost_ratio(4.0); // α* = 0.25
        let (n, d, h) = (64, 512, 512);
        assert_eq!(p.decide(n, d, h, 0.05), Kernel::MaskedParallel);
        assert_eq!(p.decide(n, d, h, 0.20), Kernel::MaskedParallel);
        assert_eq!(p.decide(n, d, h, 0.30), Kernel::DenseParallel);
        assert_eq!(p.decide(n, d, h, 1.00), Kernel::DenseParallel);
    }

    #[test]
    fn extreme_densities_are_stable() {
        let p = DispatchPolicy::default();
        assert_eq!(p.decide(8, 100, 100, 0.0), Kernel::MaskedParallel);
        assert_eq!(p.decide(8, 100, 100, 1.0), Kernel::DenseParallel);
        // Out-of-range α is clamped, not UB.
        assert_eq!(p.decide(8, 100, 100, -3.0), Kernel::MaskedParallel);
        assert_eq!(p.decide(8, 100, 100, 7.0), Kernel::DenseParallel);
    }

    #[test]
    fn calibrate_produces_a_finite_positive_ratio() {
        let pool = ThreadPool::new(2);
        let p = DispatchPolicy::calibrate(&pool, 16, 64, 64, 2);
        assert!(p.cost_ratio.is_finite() && p.cost_ratio > 0.0);
        let t = p.density_threshold();
        assert!((0.0..=1.0).contains(&t));
    }
}
