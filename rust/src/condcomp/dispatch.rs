//! Cost-routed kernel dispatch: pick the cheapest registered compute kernel
//! per layer per batch from its estimated activation density α.
//!
//! The original dispatch was a binary choice — masked dot products below a
//! density threshold, dense axpy GEMM above it (`α* = 1/cost_ratio`, §3.4).
//! That binary form is now a special case: a [`DispatchPolicy`] is a small
//! *cost table* with one column per kernel (see
//! [`crate::condcomp::registry::KernelRegistry`]), each column holding the
//! kernel's measured per-FLOP cost relative to the dense axpy baseline. The
//! routed cost of a kernel is
//!
//! ```text
//! cost(kernel, n, d, h, α) = per_flop(kernel) · work(kernel, n, d, h, α)
//! ```
//!
//! where `work` is the §3.4 FLOP count the kernel actually executes — the
//! full `N·(2d−1)·h` for dense-work kernels ([`WorkModel::Dense`]), the
//! density-proportional `α·N·(2d−1)·h` for masked ones
//! ([`WorkModel::AlphaScaled`]) — and the argmin over the allowed kernel set
//! picks the winner. The old threshold form is derived from the table
//! ([`DispatchPolicy::density_threshold`] = cheapest dense per-FLOP cost /
//! masked per-FLOP cost), so existing machine profiles keep loading; a
//! kernel without a measured column falls back to its work model's default
//! cost ([`DispatchPolicy::DEFAULT_COST_RATIO`] for masked work, parity with
//! dense for dense work) with the existing one-time warning — now latched
//! **once per process**, not once per table, so an N-shard server warns once.
//!
//! Per-FLOP costs are *shape-dependent* (cache behaviour differs per `d × h`),
//! so [`PolicyTable`] holds one policy per hidden layer, fitted by
//! [`crate::autotune`] and persisted in a machine profile with one cost
//! column per registered kernel.

use super::flops::LayerFlops;
use std::sync::atomic::{AtomicBool, Ordering};

/// Stable identifier of a compute kernel — the registry key, the profile
/// cost-column name, and the `--kernels` allow-list token.
///
/// The id set is open: a new backend defines its own
/// `KernelId::new("my_backend")`-style constant and registers under it; only
/// the ids below ship in-tree.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct KernelId(&'static str);

impl KernelId {
    /// Dense axpy GEMM over row panels (mask applied afterwards).
    pub const DENSE: KernelId = KernelId("dense");
    /// Dense GEMM with A's row panels packed into a contiguous scratch slab
    /// per KC block — bit-identical to [`KernelId::DENSE`].
    pub const DENSE_PACKED: KernelId = KernelId("dense_packed");
    /// Dense GEMM with explicitly vectorized (AVX2/NEON, runtime-detected)
    /// fused axpy rows — tolerance-tier against [`KernelId::DENSE`].
    pub const DENSE_SIMD: KernelId = KernelId("dense_simd");
    /// Dense-shaped kernel over int8-quantized weights and activations
    /// (per-row scales, exact integer dots) — sign-agreement tier against
    /// [`KernelId::DENSE`]; ~4× narrower arithmetic ([`WorkModel::DenseI8`]).
    pub const DENSE_I8: KernelId = KernelId("dense_i8");
    /// Masked dot-product kernel: computes only the `α·N·h` live entries.
    pub const MASKED: KernelId = KernelId("masked");
    /// Masked kernel with explicitly vectorized dot products —
    /// tolerance-tier against [`KernelId::MASKED`].
    pub const MASKED_SIMD: KernelId = KernelId("masked_simd");
    /// Masked kernel over int8-quantized weights and activations —
    /// sign-agreement tier against [`KernelId::MASKED`]
    /// ([`WorkModel::AlphaScaledI8`]).
    pub const MASKED_I8: KernelId = KernelId("masked_i8");
    /// Device execution through PJRT. The slot registers only when the real
    /// xla bindings replace `vendor/xla-stub` (`--features pjrt`).
    pub const PJRT: KernelId = KernelId("pjrt");

    /// Wrap a static id string (for out-of-tree registrants).
    pub const fn new(id: &'static str) -> KernelId {
        KernelId(id)
    }

    pub fn as_str(&self) -> &'static str {
        self.0
    }

    /// Parse a known id (config allow-lists, profile columns). Unknown ids
    /// return `None` — callers tolerate them (a newer writer's column) or
    /// reject them (a typo in `--kernels`), per context.
    pub fn parse(s: &str) -> Option<KernelId> {
        Self::known().iter().copied().find(|k| k.as_str() == s)
    }

    /// Every id defined in-tree, canonical order — the parse set, and what
    /// roster-style error messages enumerate (feature-gated slots included,
    /// marked unavailable by the registry when not compiled in).
    pub fn known() -> &'static [KernelId] {
        &[
            Self::DENSE,
            Self::DENSE_PACKED,
            Self::DENSE_SIMD,
            Self::DENSE_I8,
            Self::MASKED,
            Self::MASKED_SIMD,
            Self::MASKED_I8,
            Self::PJRT,
        ]
    }

    /// How this kernel's work scales with the mask density α (and which
    /// arithmetic class its per-FLOP costs live in: float and int8 columns
    /// are separate classes — an int8 "FLOP" is ~4× narrower).
    pub fn work(self) -> WorkModel {
        if self == Self::MASKED || self == Self::MASKED_SIMD {
            WorkModel::AlphaScaled
        } else if self == Self::DENSE_I8 {
            WorkModel::DenseI8
        } else if self == Self::MASKED_I8 {
            WorkModel::AlphaScaledI8
        } else {
            WorkModel::Dense
        }
    }

    /// Canonical ordering for deterministic argmin tie-breaks: the plain
    /// dense kernel wins ties against everything, bit-exact kernels against
    /// tolerance-tier SIMD ones, those against sign-agreement int8 ones,
    /// in-tree ids against foreign ones.
    pub(crate) fn priority(self) -> (u8, &'static str) {
        let rank = if self == Self::DENSE {
            0
        } else if self == Self::DENSE_PACKED {
            1
        } else if self == Self::DENSE_SIMD {
            2
        } else if self == Self::DENSE_I8 {
            3
        } else if self == Self::MASKED {
            4
        } else if self == Self::MASKED_SIMD {
            5
        } else if self == Self::MASKED_I8 {
            6
        } else if self == Self::PJRT {
            7
        } else {
            8
        };
        (rank, self.0)
    }
}

impl std::fmt::Display for KernelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.0)
    }
}

/// The in-tree kernel candidate set, canonical order (what
/// `KernelRegistry::builtin()` registers; the PJRT slot joins only behind
/// the `pjrt` feature).
pub const BUILTIN_KERNELS: &[KernelId] = &[
    KernelId::DENSE,
    KernelId::DENSE_PACKED,
    KernelId::DENSE_SIMD,
    KernelId::DENSE_I8,
    KernelId::MASKED,
    KernelId::MASKED_SIMD,
    KernelId::MASKED_I8,
];

/// How a kernel's executed FLOPs depend on the predicted mask density, and
/// which *arithmetic class* its per-FLOP costs belong to. The int8 variants
/// execute the same §3.4 op counts as their float counterparts, but each op
/// is ~4× narrower — so they form their own cost classes: an uncalibrated
/// int8 kernel must never inherit (or be floored by) a float column, and
/// vice versa.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkModel {
    /// Computes every output cell: `N·(2d−1)·h + N·h` (Eq. 8) regardless
    /// of α.
    Dense,
    /// Computes only the predicted-live cells: `α·N·h` dot products (Eq. 9's
    /// conditional term).
    AlphaScaled,
    /// [`WorkModel::Dense`] op counts in int8 arithmetic (per-row-scale
    /// quantized weights and activations).
    DenseI8,
    /// [`WorkModel::AlphaScaled`] op counts in int8 arithmetic.
    AlphaScaledI8,
}

impl WorkModel {
    /// The §3.4 op count a kernel with this work model executes for one
    /// `n × d → h` batch at density `alpha` (int8 classes count the same
    /// ops — the narrower cost per op lives in `default_per_flop` and the
    /// calibrated columns).
    pub fn flops(self, n: usize, d: usize, h: usize, alpha: f64) -> f64 {
        let computed = (alpha.clamp(0.0, 1.0) * (n * h) as f64).round() as usize;
        let lf = LayerFlops::from_counts(n, d, h, 0, computed);
        match self {
            WorkModel::Dense | WorkModel::DenseI8 => lf.dense as f64,
            WorkModel::AlphaScaled | WorkModel::AlphaScaledI8 => lf.conditional as f64,
        }
    }

    /// Whether this work model's executed ops shrink with the mask density
    /// (the masked kernel class, float or int8) — what elastic dispatch
    /// biases toward and what the autotune harness drives with partial
    /// masks.
    pub fn scales_with_alpha(self) -> bool {
        matches!(self, WorkModel::AlphaScaled | WorkModel::AlphaScaledI8)
    }

    /// Fallback per-FLOP cost (relative to the dense baseline) for a kernel
    /// nothing has calibrated: dense-work kernels assume parity (and lose
    /// argmin ties to the plain dense kernel), masked work assumes the
    /// conservative [`DispatchPolicy::DEFAULT_COST_RATIO`]. The int8
    /// classes reflect ~4× narrower arithmetic: dense-i8 ops default to a
    /// fraction of a dense FLOP, masked-i8 ops to a fraction of the masked
    /// default — optimistic on purpose, since int8 kernels are only
    /// routable when an operator allow-lists them explicitly (they are not
    /// bit-exact), and calibration replaces the guess at first serve.
    pub fn default_per_flop(self) -> f64 {
        match self {
            WorkModel::Dense => 1.0,
            WorkModel::AlphaScaled => DispatchPolicy::DEFAULT_COST_RATIO,
            WorkModel::DenseI8 => 0.3,
            WorkModel::AlphaScaledI8 => 1.0,
        }
    }
}

/// One kernel's measured per-FLOP cost relative to the dense axpy baseline
/// (`> 1`: this kernel's FLOP is slower than a dense FLOP).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostColumn {
    pub kernel: KernelId,
    pub per_flop: f64,
}

/// Per-layer cost table: one column per calibrated kernel; the argmin over
/// `cost(kernel, n, d, h, α)` picks the kernel for a batch.
#[derive(Clone, Debug, PartialEq)]
pub struct DispatchPolicy {
    /// Columns in canonical (priority) order, unique per kernel.
    columns: Vec<CostColumn>,
}

/// Process-wide latch for the uncalibrated-dispatch warning: under the
/// sharded server every shard executor snapshots its own table, so a
/// per-table latch fired once per shard. One process, one warning.
static FALLBACK_WARNED: AtomicBool = AtomicBool::new(false);

/// Claim the right to print the fallback warning. Returns `true` exactly
/// once per process.
fn claim_fallback_warning() -> bool {
    !FALLBACK_WARNED.swap(true, Ordering::Relaxed)
}

impl DispatchPolicy {
    /// Fallback masked-vs-dense cost ratio for uncalibrated policies, from
    /// the rejected packed-dot experiment in the `linalg::gemm` docs (dot
    /// kernels ran a few× slower per FLOP than the axpy GEMM on the 1-core
    /// testbed). Run `condcomp calibrate` (the [`crate::autotune::Autotuner`]
    /// harness) for per-layer per-kernel measured values.
    pub const DEFAULT_COST_RATIO: f64 = 3.0;

    /// The binary legacy form: dense at parity, masked at `cost_ratio` — the
    /// shape every pre-registry machine profile loads into.
    pub fn with_cost_ratio(cost_ratio: f64) -> DispatchPolicy {
        DispatchPolicy::from_columns(vec![
            (KernelId::DENSE, 1.0),
            (KernelId::MASKED, cost_ratio),
        ])
    }

    /// Build from explicit per-kernel columns (later duplicates win);
    /// per-FLOP costs are clamped positive.
    pub fn from_columns(columns: Vec<(KernelId, f64)>) -> DispatchPolicy {
        let mut policy = DispatchPolicy { columns: Vec::new() };
        for (kernel, per_flop) in columns {
            policy.set_column(kernel, per_flop);
        }
        policy
    }

    /// Insert or replace one kernel's cost column.
    pub fn set_column(&mut self, kernel: KernelId, per_flop: f64) {
        let per_flop = per_flop.max(1e-6);
        match self.columns.iter_mut().find(|c| c.kernel == kernel) {
            Some(c) => c.per_flop = per_flop,
            None => {
                self.columns.push(CostColumn { kernel, per_flop });
                self.columns.sort_by_key(|c| c.kernel.priority());
            }
        }
    }

    /// The calibrated columns, canonical order.
    pub fn columns(&self) -> &[CostColumn] {
        &self.columns
    }

    /// A kernel's measured per-FLOP cost, if calibrated.
    pub fn per_flop(&self, kernel: KernelId) -> Option<f64> {
        self.columns.iter().find(|c| c.kernel == kernel).map(|c| c.per_flop)
    }

    /// A kernel's per-FLOP cost, falling back for uncalibrated kernels to
    /// the *larger* of its work model's default and the most expensive
    /// calibrated column with the same work model. The floor matters once a
    /// table mixes calibrated and uncalibrated columns of one work model
    /// (e.g. a pre-SIMD profile measured `masked` at 8× but never saw
    /// `masked_simd`): an unmeasured kernel must never be assumed *cheaper*
    /// than a measured sibling, or a stale profile would route real traffic
    /// onto a kernel nothing has timed. Calibration replaces the guess.
    fn per_flop_or_default(&self, kernel: KernelId) -> f64 {
        if let Some(c) = self.per_flop(kernel) {
            return c;
        }
        let work = kernel.work();
        let floor = self
            .columns
            .iter()
            .filter(|c| c.kernel.work() == work)
            .map(|c| c.per_flop)
            .fold(f64::NEG_INFINITY, f64::max);
        work.default_per_flop().max(floor)
    }

    /// The masked-vs-dense ratio the legacy threshold form exposes (what
    /// machine profiles persist as `cost_ratio`).
    pub fn cost_ratio(&self) -> f64 {
        self.per_flop_or_default(KernelId::MASKED)
            / self.per_flop_or_default(KernelId::DENSE)
    }

    /// Estimated cost (arbitrary units: relative-per-FLOP × FLOPs) of running
    /// `kernel` on one `n × d → h` batch at density `alpha`.
    pub fn cost(&self, kernel: KernelId, n: usize, d: usize, h: usize, alpha: f64) -> f64 {
        self.per_flop_or_default(kernel) * kernel.work().flops(n, d, h, alpha)
    }

    /// The α above which every dense-work kernel beats the masked kernel —
    /// the legacy threshold, derived from the table (cheapest dense-work
    /// per-FLOP cost over the masked per-FLOP cost).
    pub fn density_threshold(&self) -> f64 {
        let dense = self
            .columns
            .iter()
            .filter(|c| c.kernel.work() == WorkModel::Dense)
            .map(|c| c.per_flop)
            .fold(f64::INFINITY, f64::min);
        let dense = if dense.is_finite() { dense } else { 1.0 };
        (dense / self.per_flop_or_default(KernelId::MASKED)).clamp(0.0, 1.0)
    }

    /// Drop cost columns for kernels outside `allowed` — the allow-list
    /// view a backend pins for the control path, so
    /// [`Self::preferred_dense`] can never pick an excluded kernel. (Plain
    /// dense remains the implicit baseline: the control path's GEMM is not
    /// conditional dispatch and always has the non-packed kernel to fall
    /// back on, like the output layer.)
    pub fn retain_kernels(&mut self, allowed: &[KernelId]) {
        self.columns.retain(|c| allowed.contains(&c.kernel));
    }

    /// The cheapest dense-work kernel in the table (plain dense when nothing
    /// is calibrated or tied) — what the control path's GEMM should run,
    /// since all dense-work kernels are bit-identical.
    pub fn preferred_dense(&self) -> KernelId {
        let mut best = (KernelId::DENSE, self.per_flop_or_default(KernelId::DENSE));
        for c in &self.columns {
            if c.kernel.work() == WorkModel::Dense && c.per_flop < best.1 {
                best = (c.kernel, c.per_flop);
            }
        }
        best.0
    }

    /// Pick the cheapest kernel among `allowed` for one `n × d → h` batch at
    /// predicted density `alpha`. Ties break toward the canonical order
    /// (dense first) regardless of the slice's order, and an empty
    /// allow-list degrades to plain dense. Allocation-free — this runs per
    /// layer per batch on the serving hot path.
    pub fn decide(
        &self,
        n: usize,
        d: usize,
        h: usize,
        alpha: f64,
        allowed: &[KernelId],
    ) -> KernelId {
        let mut best: Option<(f64, (u8, &'static str), KernelId)> = None;
        for &k in allowed {
            let c = self.cost(k, n, d, h, alpha);
            let key = (c, k.priority());
            if best.map_or(true, |(bc, bp, _)| key < (bc, bp)) {
                best = Some((c, k.priority(), k));
            }
        }
        best.map(|(_, _, k)| k).unwrap_or(KernelId::DENSE)
    }

    /// [`Self::decide`] with quality-elastic degradation: when `pressure`
    /// (the shard's queue fullness in `[0, 1]`) is at or above the
    /// configured threshold, every non-masked-work kernel's cost is
    /// multiplied by `elastic.dense_penalty`, biasing the argmin toward the
    /// cheaper masked class (`masked`/`masked_simd`/`masked_i8`) — conditional
    /// computation as a load-shedding mechanism. Below the threshold this
    /// is exactly `decide`. Returns the pick plus whether it differs from
    /// the unpressured choice (a *downgrade*, which callers log and meter).
    ///
    /// The elastic bias only reweights costs among `allowed`: it can never
    /// select a kernel outside the allow-list, and since every kernel
    /// computes the same function (within its declared equivalence tier),
    /// pressure changes *which* kernel runs, never the result.
    #[allow(clippy::too_many_arguments)]
    pub fn decide_elastic(
        &self,
        n: usize,
        d: usize,
        h: usize,
        alpha: f64,
        allowed: &[KernelId],
        elastic: &ElasticConfig,
        pressure: f64,
    ) -> (KernelId, bool) {
        let calm = self.decide(n, d, h, alpha, allowed);
        if !elastic.engaged(pressure) {
            return (calm, false);
        }
        let penalty = elastic.dense_penalty.max(1.0);
        let mut best: Option<(f64, (u8, &'static str), KernelId)> = None;
        for &k in allowed {
            let mut c = self.cost(k, n, d, h, alpha);
            if !k.work().scales_with_alpha() {
                c *= penalty;
            }
            let key = (c, k.priority());
            if best.map_or(true, |(bc, bp, _)| key < (bc, bp)) {
                best = Some((c, k.priority(), k));
            }
        }
        let pick = best.map(|(_, _, k)| k).unwrap_or(KernelId::DENSE);
        (pick, pick != calm)
    }
}

impl Default for DispatchPolicy {
    fn default() -> DispatchPolicy {
        DispatchPolicy::with_cost_ratio(DispatchPolicy::DEFAULT_COST_RATIO)
    }
}

/// Quality-elastic dispatch knobs (`server.elastic` turns the mechanism
/// on; these are the fixed degradation parameters). Under queue pressure
/// the server degrades *compute per request* — cheaper kernel class,
/// smaller estimator rank — never correctness or liveness: every elastic
/// decision is logged (flight recorder) and metered
/// (`elastic_downgrades`), and results stay within the chosen kernel's
/// declared equivalence tier.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ElasticConfig {
    /// Queue pressure in `[0, 1]` at or above which degradation engages
    /// (a step, not a ramp: hysteresis lives in the queue dynamics).
    pub pressure_threshold: f64,
    /// Multiplier applied to non-masked-work kernel costs while engaged —
    /// how hard the argmin is biased toward the masked class.
    pub dense_penalty: f64,
    /// Fraction of the estimator rank kept while engaged (ceil, floor 1).
    pub rank_frac: f64,
}

impl Default for ElasticConfig {
    fn default() -> ElasticConfig {
        ElasticConfig { pressure_threshold: 0.75, dense_penalty: 4.0, rank_frac: 0.5 }
    }
}

impl ElasticConfig {
    /// Whether degradation is active at this pressure.
    pub fn engaged(&self, pressure: f64) -> bool {
        pressure >= self.pressure_threshold
    }

    /// The estimator rank to use at this pressure: the full `rank` when
    /// calm, `ceil(rank × rank_frac)` (clamped to `[1, rank]`) while
    /// engaged. A smaller rank makes the sign estimate coarser — the mask
    /// may differ — but the masked kernels still compute exact dot
    /// products for every unit the mask keeps.
    pub fn effective_rank(&self, rank: usize, pressure: f64) -> usize {
        if rank == 0 || !self.engaged(pressure) {
            return rank;
        }
        ((rank as f64 * self.rank_frac).ceil() as usize).clamp(1, rank)
    }
}

/// Per-layer dispatch policies with a shared uncalibrated fallback.
///
/// A single global cost table ignores that different `d × h` layer shapes
/// have different cache behaviour, so their kernel flip points differ. The
/// autotune subsystem ([`crate::autotune`]) measures each layer shape ×
/// registered kernel separately and persists the result in a machine
/// profile; `PolicyTable` is the runtime form — one optional calibrated
/// policy per hidden layer, plus the fallback (default columns) for layers
/// nothing has calibrated. The first fallback hit logs a one-time (per
/// *process*) warning naming the profile path that was searched, so a
/// silently-defaulting deployment is visible in the serve log exactly once,
/// regardless of how many shard executors snapshot the table.
#[derive(Clone, Debug)]
pub struct PolicyTable {
    /// `layers[l]` is hidden layer `l`'s calibrated policy; `None` falls
    /// back (and warns once per process).
    layers: Vec<Option<DispatchPolicy>>,
    fallback: DispatchPolicy,
    /// Where a machine profile was looked for — named by the warning.
    profile_path: Option<String>,
}

impl PolicyTable {
    /// A table with no calibrated layers: every lookup uses the fallback.
    pub fn uncalibrated(num_layers: usize) -> PolicyTable {
        PolicyTable {
            layers: vec![None; num_layers],
            fallback: DispatchPolicy::default(),
            profile_path: None,
        }
    }

    /// Every layer pinned to one explicit policy (tests; embedders with a
    /// single recorded global table). Counts as calibrated — no warning.
    pub fn uniform(policy: DispatchPolicy, num_layers: usize) -> PolicyTable {
        PolicyTable {
            layers: vec![Some(policy.clone()); num_layers],
            fallback: policy,
            profile_path: None,
        }
    }

    /// Record where a machine profile was (or would have been) looked for,
    /// so the fallback warning can name it.
    pub fn with_profile_path(mut self, path: impl Into<String>) -> PolicyTable {
        self.profile_path = Some(path.into());
        self
    }

    /// Number of hidden layers this table covers.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Install a calibrated policy for hidden layer `layer` (ignored if the
    /// index is out of range — profiles may describe a deeper model).
    pub fn set_layer(&mut self, layer: usize, policy: DispatchPolicy) {
        if let Some(slot) = self.layers.get_mut(layer) {
            *slot = Some(policy);
        }
    }

    /// Insert or replace one kernel's cost column for one layer, preserving
    /// the layer's other columns (the targeted-recalibration path: a profile
    /// missing a kernel column gets just that column re-measured). An
    /// uncalibrated layer is promoted to calibrated with default columns
    /// plus the new one.
    pub fn set_layer_column(&mut self, layer: usize, kernel: KernelId, per_flop: f64) {
        if layer >= self.layers.len() {
            return;
        }
        let mut policy = self.layers[layer].clone().unwrap_or_else(|| self.fallback.clone());
        policy.set_column(kernel, per_flop);
        self.layers[layer] = Some(policy);
    }

    /// Drop every layer's cost columns for kernels outside `allowed`
    /// ([`DispatchPolicy::retain_kernels`] per layer + fallback) — applied
    /// to the snapshot a backend pins for the control path, so an
    /// allow-list-excluded kernel can never be preferred there either.
    pub fn retain_kernels(&mut self, allowed: &[KernelId]) {
        for slot in self.layers.iter_mut().flatten() {
            slot.retain_kernels(allowed);
        }
        self.fallback.retain_kernels(allowed);
    }

    /// Whether hidden layer `layer` has a calibrated (non-fallback) policy.
    pub fn is_calibrated(&self, layer: usize) -> bool {
        matches!(self.layers.get(layer), Some(Some(_)))
    }

    /// How many layers are calibrated.
    pub fn calibrated_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.is_some()).count()
    }

    /// The policy for hidden layer `layer`. Uncalibrated layers use the
    /// fallback and trigger the once-per-process warning.
    pub fn policy_for(&self, layer: usize) -> DispatchPolicy {
        match self.layers.get(layer).cloned().flatten() {
            Some(p) => p,
            None => {
                self.warn_once(layer);
                self.fallback.clone()
            }
        }
    }

    /// The policy for hidden layer `layer` without the fallback warning —
    /// the reporting path (summaries, kernel-choice logs), not a decision.
    pub fn policy_snapshot(&self, layer: usize) -> DispatchPolicy {
        self.layers
            .get(layer)
            .cloned()
            .flatten()
            .unwrap_or_else(|| self.fallback.clone())
    }

    /// The cheapest dense-work kernel for hidden layer `layer` (all
    /// dense-work kernels are bit-identical, so this choice can never change
    /// results). Does not trigger the fallback warning.
    pub fn dense_kernel_for(&self, layer: usize) -> KernelId {
        self.policy_snapshot(layer).preferred_dense()
    }

    fn warn_once(&self, layer: usize) {
        if claim_fallback_warning() {
            let looked = self
                .profile_path
                .as_deref()
                .unwrap_or("<autotune.profile_path not configured>");
            eprintln!(
                "warning: dispatch for layer {layer} is uncalibrated — no machine profile \
                 loaded (looked for {looked}); using DEFAULT_COST_RATIO = {}. \
                 Run `condcomp calibrate` to fit per-layer kernel costs for this machine.",
                DispatchPolicy::DEFAULT_COST_RATIO
            );
        }
    }

    /// Per-layer α* values (fallback threshold where uncalibrated). Does not
    /// trigger the warning — this is the reporting path, not a decision.
    pub fn thresholds(&self) -> Vec<f64> {
        self.layers
            .iter()
            .map(|l| l.as_ref().unwrap_or(&self.fallback).density_threshold())
            .collect()
    }

    /// Human-readable per-layer table — the `serve` startup log.
    pub fn summary_lines(&self) -> Vec<String> {
        let mut lines = vec![format!(
            "{:<7} {:>12} {:>10} {:>12}  {}",
            "layer", "cost-ratio", "α*", "source", "kernel per-FLOP costs"
        )];
        for (l, slot) in self.layers.iter().enumerate() {
            let (p, source) = match slot {
                Some(p) => (p, "calibrated"),
                None => (&self.fallback, "fallback"),
            };
            let cols: Vec<String> = p
                .columns()
                .iter()
                .map(|c| format!("{}:{:.3}", c.kernel, c.per_flop))
                .collect();
            lines.push(format!(
                "{:<7} {:>12.3} {:>10.4} {:>12}  {}",
                l,
                p.cost_ratio(),
                p.density_threshold(),
                source,
                cols.join(" ")
            ));
        }
        lines
    }
}

/// Equality over the dispatch-relevant state (the remembered profile path is
/// a diagnostic, not policy).
impl PartialEq for PolicyTable {
    fn eq(&self, other: &PolicyTable) -> bool {
        self.layers == other.layers && self.fallback == other.fallback
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DM: &[KernelId] = &[KernelId::DENSE, KernelId::MASKED];
    /// The float-arithmetic builtin set — what routing tests that predate
    /// the int8 class exercise (the int8 kernels' optimistic defaults are
    /// *supposed* to undercut float columns when allow-listed; see
    /// `int8_work_models_are_their_own_cost_class` for that contract).
    const FLOAT_KERNELS: &[KernelId] = &[
        KernelId::DENSE,
        KernelId::DENSE_PACKED,
        KernelId::DENSE_SIMD,
        KernelId::MASKED,
        KernelId::MASKED_SIMD,
    ];

    #[test]
    fn kernel_ids_parse_and_display() {
        for &k in KernelId::known() {
            assert_eq!(KernelId::parse(k.as_str()), Some(k));
            assert_eq!(format!("{k}"), k.as_str());
        }
        assert_eq!(KernelId::parse("quantum"), None);
        assert_eq!(KernelId::MASKED.work(), WorkModel::AlphaScaled);
        assert_eq!(KernelId::MASKED_SIMD.work(), WorkModel::AlphaScaled);
        assert_eq!(KernelId::DENSE_PACKED.work(), WorkModel::Dense);
        assert_eq!(KernelId::DENSE_SIMD.work(), WorkModel::Dense);
        assert_eq!(KernelId::DENSE_I8.work(), WorkModel::DenseI8);
        assert_eq!(KernelId::MASKED_I8.work(), WorkModel::AlphaScaledI8);
        assert!(KernelId::MASKED_I8.work().scales_with_alpha());
        assert!(!KernelId::DENSE_I8.work().scales_with_alpha());
        // Priorities are strictly ordered in the known() canonical order.
        let ranks: Vec<u8> = KernelId::known().iter().map(|k| k.priority().0).collect();
        assert!(ranks.windows(2).all(|w| w[0] < w[1]), "ranks {ranks:?}");
    }

    #[test]
    fn threshold_is_inverse_cost_ratio() {
        let p = DispatchPolicy::with_cost_ratio(4.0);
        assert!((p.density_threshold() - 0.25).abs() < 1e-12);
        assert!((p.cost_ratio() - 4.0).abs() < 1e-12);
        // A faster-than-dense masked kernel would always win.
        let p = DispatchPolicy::with_cost_ratio(0.5);
        assert_eq!(p.density_threshold(), 1.0);
    }

    #[test]
    fn decide_flips_at_the_threshold() {
        let p = DispatchPolicy::with_cost_ratio(4.0); // α* = 0.25
        let (n, d, h) = (64, 512, 512);
        assert_eq!(p.decide(n, d, h, 0.05, DM), KernelId::MASKED);
        assert_eq!(p.decide(n, d, h, 0.20, DM), KernelId::MASKED);
        assert_eq!(p.decide(n, d, h, 0.30, DM), KernelId::DENSE);
        assert_eq!(p.decide(n, d, h, 1.00, DM), KernelId::DENSE);
    }

    #[test]
    fn extreme_densities_are_stable() {
        let p = DispatchPolicy::default();
        assert_eq!(p.decide(8, 100, 100, 0.0, DM), KernelId::MASKED);
        assert_eq!(p.decide(8, 100, 100, 1.0, DM), KernelId::DENSE);
        // Out-of-range α is clamped, not UB.
        assert_eq!(p.decide(8, 100, 100, -3.0, DM), KernelId::MASKED);
        assert_eq!(p.decide(8, 100, 100, 7.0, DM), KernelId::DENSE);
    }

    /// The registry's open set in action: a cheaper packed column wins the
    /// dense regime, the masked column keeps the sparse regime, and the
    /// derived threshold moves with the cheapest dense kernel.
    #[test]
    fn packed_column_shifts_the_argmin_and_the_threshold() {
        let p = DispatchPolicy::from_columns(vec![
            (KernelId::DENSE, 1.0),
            (KernelId::DENSE_PACKED, 0.8),
            (KernelId::MASKED, 4.0),
        ]);
        let (n, d, h) = (64, 512, 512);
        // α* moved from 0.25 to 0.8/4 = 0.2.
        assert!((p.density_threshold() - 0.2).abs() < 1e-12);
        assert_eq!(p.preferred_dense(), KernelId::DENSE_PACKED);
        assert_eq!(p.decide(n, d, h, 0.1, FLOAT_KERNELS), KernelId::MASKED);
        assert_eq!(p.decide(n, d, h, 0.5, FLOAT_KERNELS), KernelId::DENSE_PACKED);
        // Restricting the allow-list removes the packed option.
        assert_eq!(p.decide(n, d, h, 0.5, DM), KernelId::DENSE);
        // A masked-only allow-list always routes masked.
        assert_eq!(p.decide(n, d, h, 1.0, &[KernelId::MASKED]), KernelId::MASKED);
        // An empty allow-list degrades to plain dense.
        assert_eq!(p.decide(n, d, h, 0.5, &[]), KernelId::DENSE);
    }

    /// Ties break toward the canonical order: an uncalibrated packed column
    /// defaults to parity and must lose to plain dense, deterministically.
    #[test]
    fn ties_prefer_the_canonical_order() {
        let p = DispatchPolicy::with_cost_ratio(4.0); // no packed column
        assert_eq!(p.decide(64, 512, 512, 1.0, FLOAT_KERNELS), KernelId::DENSE);
        assert_eq!(p.preferred_dense(), KernelId::DENSE);
        let mut q = p.clone();
        q.set_column(KernelId::DENSE_PACKED, 1.0); // explicit parity
        assert_eq!(q.decide(64, 512, 512, 1.0, FLOAT_KERNELS), KernelId::DENSE);
    }

    /// The uncalibrated floor: a kernel with no measured column is assumed
    /// no cheaper than any *measured* column of the same work model, so a
    /// pre-SIMD profile (which never timed `masked_simd`) cannot route
    /// traffic onto it just because the generic default (3×) undercuts the
    /// measured `masked` column.
    #[test]
    fn uncalibrated_kernels_never_undercut_calibrated_siblings() {
        let p = DispatchPolicy::from_columns(vec![
            (KernelId::DENSE, 1.0),
            (KernelId::MASKED, 8.0), // slower than the 3.0 default guess
        ]);
        let (n, d, h) = (64, 512, 512);
        let masked = p.cost(KernelId::MASKED, n, d, h, 0.3);
        let simd = p.cost(KernelId::MASKED_SIMD, n, d, h, 0.3);
        assert!(
            simd >= masked,
            "uncalibrated masked_simd ({simd}) undercut calibrated masked ({masked})"
        );
        // …so the argmin can pick it only via the canonical tie-break, which
        // masked wins — routing is unchanged until calibration says otherwise.
        assert_ne!(p.decide(n, d, h, 0.05, FLOAT_KERNELS), KernelId::MASKED_SIMD);
        // Dense-work floor likewise: an expensive calibrated packed column
        // lifts the uncalibrated dense_simd guess up to it.
        let q = DispatchPolicy::from_columns(vec![
            (KernelId::DENSE, 1.0),
            (KernelId::DENSE_PACKED, 2.5),
        ]);
        let packed = q.cost(KernelId::DENSE_PACKED, n, d, h, 1.0);
        assert_eq!(q.cost(KernelId::DENSE_SIMD, n, d, h, 1.0), packed);
        // A *measured* SIMD column beats the floor as usual.
        let mut r = p.clone();
        r.set_column(KernelId::MASKED_SIMD, 2.0);
        assert_eq!(r.decide(n, d, h, 0.05, FLOAT_KERNELS), KernelId::MASKED_SIMD);
    }

    /// Regression (satellite): the uncalibrated floor is *per arithmetic
    /// class*, not per α-scaling shape — a fresh `dense_i8` column must
    /// never inherit a float-class cost. With dense measured at 1.0 and
    /// packed at 2.5, the dense-work float floor is 2.5, but `dense_i8`
    /// keeps its own 0.3 default; likewise `masked_i8` ignores a measured
    /// 8.0 `masked` column. Once an i8 column *is* measured, the same-class
    /// floor applies within the i8 class.
    #[test]
    fn int8_work_models_are_their_own_cost_class() {
        let (n, d, h) = (64, 512, 512);
        let p = DispatchPolicy::from_columns(vec![
            (KernelId::DENSE, 1.0),
            (KernelId::DENSE_PACKED, 2.5),
            (KernelId::MASKED, 8.0),
        ]);
        let dense_flops = WorkModel::Dense.flops(n, d, h, 1.0);
        let cond_flops = WorkModel::AlphaScaled.flops(n, d, h, 0.3);
        // The float floors do not leak into the i8 classes…
        assert!((p.cost(KernelId::DENSE_I8, n, d, h, 1.0) - 0.3 * dense_flops).abs() < 1e-9);
        assert!((p.cost(KernelId::MASKED_I8, n, d, h, 0.3) - cond_flops).abs() < 1e-9);
        // …and the i8 defaults undercut the calibrated float columns, so an
        // operator who allow-lists the int8 class gets routed onto it.
        assert_eq!(p.decide(n, d, h, 1.0, BUILTIN_KERNELS), KernelId::DENSE_I8);
        assert_eq!(p.decide(n, d, h, 0.05, BUILTIN_KERNELS), KernelId::MASKED_I8);
        // A float-only allow-list is untouched by the i8 defaults.
        assert_eq!(p.decide(n, d, h, 1.0, FLOAT_KERNELS), KernelId::DENSE);
        // Measuring an i8 column replaces its default within its own class.
        let mut q = p.clone();
        q.set_column(KernelId::MASKED_I8, 5.0);
        assert!((q.cost(KernelId::MASKED_I8, n, d, h, 0.3) - 5.0 * cond_flops).abs() < 1e-9);
        // And the float masked column is still what cost_ratio reports.
        assert!((q.cost_ratio() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn cost_is_per_flop_times_work() {
        let p = DispatchPolicy::from_columns(vec![
            (KernelId::DENSE, 1.0),
            (KernelId::MASKED, 3.0),
        ]);
        let (n, d, h) = (4, 10, 10);
        let dense_flops = WorkModel::Dense.flops(n, d, h, 1.0);
        assert_eq!(p.cost(KernelId::DENSE, n, d, h, 0.3), dense_flops);
        let cond_flops = WorkModel::AlphaScaled.flops(n, d, h, 0.3);
        assert!((p.cost(KernelId::MASKED, n, d, h, 0.3) - 3.0 * cond_flops).abs() < 1e-9);
        // Uncalibrated kernels cost their work model's default.
        assert_eq!(p.cost(KernelId::DENSE_PACKED, n, d, h, 0.5), dense_flops);
    }

    /// The point of the per-layer table: at the same batch density, two
    /// layers with different fitted tables pick different kernels, each
    /// flipping just below/above its own α*.
    #[test]
    fn per_layer_policies_flip_at_their_own_thresholds() {
        let mut table = PolicyTable::uncalibrated(2);
        table.set_layer(0, DispatchPolicy::with_cost_ratio(2.0)); // α* = 0.5
        table.set_layer(1, DispatchPolicy::with_cost_ratio(10.0)); // α* = 0.1
        let (n, d, h) = (64, 512, 512);
        // Just below / above each layer's own threshold.
        assert_eq!(table.policy_for(0).decide(n, d, h, 0.45, DM), KernelId::MASKED);
        assert_eq!(table.policy_for(0).decide(n, d, h, 0.55, DM), KernelId::DENSE);
        assert_eq!(table.policy_for(1).decide(n, d, h, 0.05, DM), KernelId::MASKED);
        assert_eq!(table.policy_for(1).decide(n, d, h, 0.15, DM), KernelId::DENSE);
        // Same α, different layers → different kernels.
        assert_eq!(table.policy_for(0).decide(n, d, h, 0.3, DM), KernelId::MASKED);
        assert_eq!(table.policy_for(1).decide(n, d, h, 0.3, DM), KernelId::DENSE);
        let t = table.thresholds();
        assert!((t[0] - 0.5).abs() < 1e-12 && (t[1] - 0.1).abs() < 1e-12);
    }

    #[test]
    fn uncalibrated_layers_fall_back_and_report() {
        let table = PolicyTable::uncalibrated(3).with_profile_path("/tmp/nope.json");
        assert_eq!(table.num_layers(), 3);
        assert_eq!(table.calibrated_layers(), 0);
        assert!(!table.is_calibrated(1));
        assert_eq!(table.policy_for(0), DispatchPolicy::default());
        assert_eq!(table.policy_for(2), DispatchPolicy::default());
        // Out-of-range layers also fall back instead of panicking.
        assert_eq!(table.policy_for(99), DispatchPolicy::default());
        assert_eq!(table.summary_lines().len(), 4); // header + 3 layers
    }

    /// Regression (satellite): the fallback warning is latched once per
    /// *process*, not once per table — under the sharded server every shard
    /// executor snapshots its own table, and each snapshot used to re-warn.
    #[test]
    fn fallback_warning_is_once_per_process() {
        // Two tables standing in for two shard executors' snapshots.
        let shard0 = PolicyTable::uncalibrated(1).with_profile_path("shard0.json");
        let shard1 = PolicyTable::uncalibrated(1).with_profile_path("shard1.json");
        let _ = shard0.policy_for(0);
        // After any fallback lookup, the process-wide latch is set…
        assert!(FALLBACK_WARNED.load(Ordering::Relaxed));
        // …so no later table can claim the warning again.
        let _ = shard1.policy_for(0);
        assert!(!claim_fallback_warning(), "second shard's snapshot must not re-warn");
        // Reporting paths never touch the latch semantics either way.
        let _ = shard1.policy_snapshot(0);
        let _ = shard1.thresholds();
    }

    #[test]
    fn uniform_table_is_fully_calibrated() {
        let p = DispatchPolicy::with_cost_ratio(4.0);
        let table = PolicyTable::uniform(p.clone(), 2);
        assert_eq!(table.calibrated_layers(), 2);
        assert_eq!(table.policy_for(0), p);
        assert_eq!(table.policy_for(1), p);
        let mut expect = PolicyTable::uncalibrated(2);
        expect.set_layer(0, p.clone());
        expect.set_layer(1, p);
        // PartialEq compares layers + fallback only; fallbacks differ here.
        assert_eq!(expect.thresholds(), table.thresholds());
    }

    /// The allow-list view the control path pins: retaining only allowed
    /// kernels removes an excluded packed column from the preference, for
    /// every layer and the fallback alike.
    #[test]
    fn retain_kernels_strips_excluded_columns_from_the_preference() {
        let mut p = DispatchPolicy::from_columns(vec![
            (KernelId::DENSE, 1.0),
            (KernelId::DENSE_PACKED, 0.5),
            (KernelId::MASKED, 4.0),
        ]);
        assert_eq!(p.preferred_dense(), KernelId::DENSE_PACKED);
        p.retain_kernels(&[KernelId::DENSE, KernelId::MASKED]);
        assert_eq!(p.preferred_dense(), KernelId::DENSE, "excluded kernel never preferred");
        assert_eq!(p.per_flop(KernelId::DENSE_PACKED), None);
        assert_eq!(p.per_flop(KernelId::MASKED), Some(4.0), "allowed columns kept");

        let mut table = PolicyTable::uncalibrated(2);
        table.set_layer(
            0,
            DispatchPolicy::from_columns(vec![
                (KernelId::DENSE, 1.0),
                (KernelId::DENSE_PACKED, 0.5),
            ]),
        );
        table.retain_kernels(&[KernelId::DENSE, KernelId::MASKED]);
        assert_eq!(table.dense_kernel_for(0), KernelId::DENSE);
        assert_eq!(table.dense_kernel_for(1), KernelId::DENSE, "fallback stripped too");
    }

    /// Targeted recalibration: inserting one kernel's column preserves the
    /// layer's other columns, and promotes an uncalibrated layer.
    #[test]
    fn set_layer_column_merges_into_existing_policies() {
        let mut table = PolicyTable::uncalibrated(2);
        table.set_layer(0, DispatchPolicy::with_cost_ratio(5.0));
        table.set_layer_column(0, KernelId::DENSE_PACKED, 0.9);
        let p0 = table.policy_snapshot(0);
        assert_eq!(p0.per_flop(KernelId::MASKED), Some(5.0), "existing column preserved");
        assert_eq!(p0.per_flop(KernelId::DENSE_PACKED), Some(0.9));
        assert_eq!(p0.preferred_dense(), KernelId::DENSE_PACKED);
        // Layer 1 was uncalibrated: the column promotes it with defaults.
        table.set_layer_column(1, KernelId::DENSE_PACKED, 0.8);
        assert!(table.is_calibrated(1));
        let p1 = table.policy_snapshot(1);
        assert_eq!(p1.per_flop(KernelId::DENSE_PACKED), Some(0.8));
        assert!((p1.cost_ratio() - DispatchPolicy::DEFAULT_COST_RATIO).abs() < 1e-12);
        // Out of range is a no-op, not a panic.
        table.set_layer_column(99, KernelId::DENSE, 1.0);
    }

    /// Quality-elastic dispatch: synthetic pressure shifts the argmin to
    /// the masked class exactly at the configured thresholds, and reverts
    /// when pressure clears. With cost ratio R and dense penalty P, the
    /// masked kernel wins iff R·α < P — so the pressured flip point is
    /// α* = P/R instead of the calm 1/R.
    #[test]
    fn elastic_pressure_shifts_the_argmin_at_the_configured_threshold() {
        let (n, d, h) = (64, 512, 512);
        let p = DispatchPolicy::with_cost_ratio(4.0); // calm flip at α = 0.25
        let elastic = ElasticConfig {
            pressure_threshold: 0.5,
            dense_penalty: 2.0, // pressured flip at α = 2/4 = 0.5
            rank_frac: 0.5,
        };
        // Calm (pressure below the threshold): exactly `decide`, never a
        // downgrade.
        for alpha in [0.05, 0.30, 0.45, 1.0] {
            let (k, down) = p.decide_elastic(n, d, h, alpha, DM, &elastic, 0.49);
            assert_eq!(k, p.decide(n, d, h, alpha, DM), "α = {alpha}");
            assert!(!down, "no downgrade below the pressure threshold");
        }
        // Engaged (pressure at the threshold — the step is ≥): the flip
        // point moves from 0.25 to 0.5.
        let (k, down) = p.decide_elastic(n, d, h, 0.30, DM, &elastic, 0.5);
        assert_eq!(k, KernelId::MASKED, "α = 0.30 downgrades under pressure");
        assert!(down, "the pick differs from the calm argmin");
        let (k, down) = p.decide_elastic(n, d, h, 0.45, DM, &elastic, 1.0);
        assert_eq!(k, KernelId::MASKED);
        assert!(down);
        // Past the pressured flip point dense still wins — not a downgrade.
        let (k, down) = p.decide_elastic(n, d, h, 0.55, DM, &elastic, 1.0);
        assert_eq!(k, KernelId::DENSE);
        assert!(!down);
        // Already-masked regimes are not "downgrades" either.
        let (k, down) = p.decide_elastic(n, d, h, 0.05, DM, &elastic, 1.0);
        assert_eq!(k, KernelId::MASKED);
        assert!(!down, "masked was already the calm pick");
        // Pressure cleared: back to the calm argmin.
        let (k, down) = p.decide_elastic(n, d, h, 0.30, DM, &elastic, 0.0);
        assert_eq!(k, KernelId::DENSE);
        assert!(!down);
    }

    /// The elastic bias can never escape the allow-list: with only
    /// dense-work kernels allowed, any pressure and any penalty still pick
    /// from the allowed set (and report no downgrade — the calm argmin over
    /// the same set agrees).
    #[test]
    fn elastic_bias_never_selects_outside_the_allow_list() {
        let p = DispatchPolicy::with_cost_ratio(4.0);
        let elastic = ElasticConfig {
            pressure_threshold: 0.0,
            dense_penalty: 1e9,
            rank_frac: 0.5,
        };
        let dense_only = &[KernelId::DENSE, KernelId::DENSE_PACKED];
        for alpha in [0.05, 0.5, 1.0] {
            let (k, down) = p.decide_elastic(64, 512, 512, alpha, dense_only, &elastic, 1.0);
            assert!(dense_only.contains(&k), "picked {k} outside the allow-list");
            assert!(!down, "uniform penalty over one work model changes nothing");
        }
        // Empty allow-list degrades to dense, exactly like `decide`.
        let (k, _) = p.decide_elastic(64, 512, 512, 0.5, &[], &elastic, 1.0);
        assert_eq!(k, KernelId::DENSE);
        // Per-layer tables route elastic decisions through the same
        // policies `decide` uses (the pinned-view path the backend takes).
        let mut table = PolicyTable::uncalibrated(2);
        table.set_layer(0, DispatchPolicy::with_cost_ratio(4.0));
        let calm_elastic = ElasticConfig { pressure_threshold: 0.5, ..ElasticConfig::default() };
        let (k, down) =
            table.policy_for(0).decide_elastic(64, 512, 512, 0.30, DM, &calm_elastic, 1.0);
        assert_eq!((k, down), (KernelId::MASKED, true));
    }

    /// The rank-shrink half of elastic degradation: full rank while calm,
    /// `ceil(rank × frac)` clamped to `[1, rank]` while engaged.
    #[test]
    fn elastic_effective_rank_shrinks_only_under_pressure() {
        let e = ElasticConfig { pressure_threshold: 0.75, dense_penalty: 4.0, rank_frac: 0.5 };
        assert_eq!(e.effective_rank(8, 0.0), 8);
        assert_eq!(e.effective_rank(8, 0.74), 8, "below the step");
        assert_eq!(e.effective_rank(8, 0.75), 4, "the step is ≥");
        assert_eq!(e.effective_rank(7, 1.0), 4, "ceil(3.5) = 4");
        assert_eq!(e.effective_rank(1, 1.0), 1, "never below 1");
        assert_eq!(e.effective_rank(0, 1.0), 0, "rank 0 stays 0");
        let tiny = ElasticConfig { rank_frac: 0.01, ..e };
        assert_eq!(tiny.effective_rank(8, 1.0), 1, "floor at 1");
        let full = ElasticConfig { rank_frac: 1.0, ..e };
        assert_eq!(full.effective_rank(8, 1.0), 8, "frac 1.0 keeps the full rank");
    }
}
