//! When to recompute the estimator factorization from the live weights.
//!
//! The paper recomputes the SVD "once per epoch" (§3.5) and notes the
//! within-epoch drift this causes (Fig. 6). `EveryNBatches` and the
//! randomized factorization path implement the §5 future-work direction of
//! cheaper, more frequent refreshes.

/// Refresh cadence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefreshPolicy {
    /// Recompute at the first minibatch of every epoch (the paper's choice).
    OncePerEpoch,
    /// Recompute every `n` minibatches (counted across epochs).
    EveryNBatches(usize),
    /// Never refresh after the initial factorization (ablation baseline).
    Never,
}

impl RefreshPolicy {
    /// Should a refresh fire on this (epoch, batch) step? `steps_since` is
    /// the number of minibatches since the last refresh (including this one
    /// being the first → 0 means "just refreshed").
    pub fn due(&self, batch_index: usize, steps_since_refresh: usize, ever_refreshed: bool) -> bool {
        match self {
            RefreshPolicy::OncePerEpoch => batch_index == 0,
            RefreshPolicy::EveryNBatches(n) => {
                !ever_refreshed || steps_since_refresh >= *n
            }
            RefreshPolicy::Never => !ever_refreshed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn once_per_epoch_fires_on_batch_zero() {
        let p = RefreshPolicy::OncePerEpoch;
        assert!(p.due(0, 100, true));
        assert!(!p.due(1, 100, true));
        assert!(!p.due(57, 3, true));
    }

    #[test]
    fn every_n_counts_steps() {
        let p = RefreshPolicy::EveryNBatches(5);
        assert!(p.due(3, 0, false), "first ever refresh fires immediately");
        assert!(!p.due(4, 3, true));
        assert!(p.due(9, 5, true));
        assert!(p.due(2, 8, true));
    }

    #[test]
    fn never_fires_once() {
        let p = RefreshPolicy::Never;
        assert!(p.due(0, 0, false));
        assert!(!p.due(0, 1000, true));
    }
}
