//! Quality metrics for sign estimation — the quantities behind Figures 2, 4
//! and 6 of the paper.

use super::signest::SignEstimator;
use crate::linalg::{matmul, Mat};
use crate::nn::mlp::add_bias;

/// Confusion-style breakdown of one estimator against the exact layer.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SignQuality {
    /// P(predicted off | actually on): lost activations — these change the
    /// network output and drive the accuracy cost.
    pub false_negative_rate: f64,
    /// P(predicted on | actually off): wasted dot products — these only cost
    /// compute, not accuracy.
    pub false_positive_rate: f64,
    /// Overall sign disagreement.
    pub sign_error: f64,
    /// True activation density α (fraction of positive pre-activations).
    pub true_density: f64,
    /// Predicted density α̂ (fraction of units the estimator computes).
    pub predicted_density: f64,
    /// ‖σ(z) − σ(z)·S‖_F / ‖σ(z)‖_F — the *estimator path* error of Fig. 2.
    pub masked_rel_error: f64,
    /// ‖σ(z) − σ(ẑ)‖_F / ‖σ(z)‖_F where ẑ = a·U·V + b — the *low-rank value*
    /// error of Fig. 2 (the strawman the paper compares against).
    pub lowrank_rel_error: f64,
}

/// Evaluate an estimator against the exact layer `(w, b)` on inputs `a`.
pub fn evaluate(est: &SignEstimator, a: &Mat, w: &Mat, b: &[f32]) -> SignQuality {
    let mut z = matmul(a, w);
    add_bias(&mut z, b);
    let zhat = est.estimate_preact(a);
    let mask = est.mask(a);

    let n = z.as_slice().len();
    let (mut tp, mut fp, mut fn_, mut tn) = (0usize, 0usize, 0usize, 0usize);
    let mut lost_sq = 0.0f64;
    let mut lowrank_sq = 0.0f64;
    let mut act_sq = 0.0f64;
    for i in 0..n {
        let zv = z.as_slice()[i];
        let on = zv > 0.0;
        let pred_on = mask.as_slice()[i] > 0.0;
        match (on, pred_on) {
            (true, true) => tp += 1,
            (true, false) => fn_ += 1,
            (false, true) => fp += 1,
            (false, false) => tn += 1,
        }
        let act = zv.max(0.0) as f64;
        act_sq += act * act;
        // σ(z)·S keeps act where predicted on, zero otherwise.
        let kept = if pred_on { act } else { 0.0 };
        lost_sq += (act - kept) * (act - kept);
        let lr_act = zhat.as_slice()[i].max(0.0) as f64;
        lowrank_sq += (act - lr_act) * (act - lr_act);
    }
    let denom = act_sq.sqrt().max(1e-12);
    SignQuality {
        false_negative_rate: if tp + fn_ > 0 { fn_ as f64 / (tp + fn_) as f64 } else { 0.0 },
        false_positive_rate: if fp + tn > 0 { fp as f64 / (fp + tn) as f64 } else { 0.0 },
        sign_error: (fn_ + fp) as f64 / n as f64,
        true_density: (tp + fn_) as f64 / n as f64,
        predicted_density: (tp + fp) as f64 / n as f64,
        masked_rel_error: lost_sq.sqrt() / denom,
        lowrank_rel_error: lowrank_sq.sqrt() / denom,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn setup() -> (Mat, Mat, Vec<f32>) {
        let mut rng = Pcg32::seeded(1);
        let a = Mat::randn(50, 12, 1.0, &mut rng);
        let w = Mat::randn(12, 16, 0.5, &mut rng);
        let b = vec![0.1; 16];
        (a, w, b)
    }

    #[test]
    fn full_rank_estimator_is_perfect() {
        let (a, w, b) = setup();
        let est = SignEstimator::fit(&w, &b, 12, 0.0);
        let q = evaluate(&est, &a, &w, &b);
        assert!(q.sign_error < 1e-3, "sign error {}", q.sign_error);
        assert!(q.masked_rel_error < 1e-3);
        assert!(q.lowrank_rel_error < 1e-3);
        assert!((q.true_density - q.predicted_density).abs() < 1e-3);
    }

    #[test]
    fn fig2_shape_masked_error_beats_lowrank_error() {
        // The paper's Figure 2 claim: at moderate rank, the sign-masked path
        // has much lower error than using the low-rank *value*.
        let (a, w, b) = setup();
        let mut held = 0;
        for rank in [3, 4, 6, 8] {
            let est = SignEstimator::fit(&w, &b, rank, 0.0);
            let q = evaluate(&est, &a, &w, &b);
            if q.masked_rel_error < q.lowrank_rel_error {
                held += 1;
            }
        }
        assert!(held >= 3, "masked error should beat low-rank value error at most ranks");
    }

    #[test]
    fn error_monotone_in_rank() {
        let (a, w, b) = setup();
        let e_lo = evaluate(&SignEstimator::fit(&w, &b, 2, 0.0), &a, &w, &b);
        let e_hi = evaluate(&SignEstimator::fit(&w, &b, 10, 0.0), &a, &w, &b);
        assert!(e_hi.sign_error <= e_lo.sign_error + 1e-9);
        assert!(e_hi.masked_rel_error <= e_lo.masked_rel_error + 1e-9);
    }

    #[test]
    fn decision_bias_trades_fn_for_fp() {
        let (a, w, b) = setup();
        let neutral = evaluate(&SignEstimator::fit(&w, &b, 6, 0.0), &a, &w, &b);
        let aggressive = evaluate(&SignEstimator::fit(&w, &b, 6, 0.3), &a, &w, &b);
        let lenient = evaluate(&SignEstimator::fit(&w, &b, 6, -0.3), &a, &w, &b);
        assert!(aggressive.false_negative_rate >= neutral.false_negative_rate);
        assert!(aggressive.predicted_density <= neutral.predicted_density);
        assert!(lenient.false_negative_rate <= neutral.false_negative_rate);
        assert!(lenient.predicted_density >= neutral.predicted_density);
    }

    #[test]
    fn densities_are_probabilities() {
        let (a, w, b) = setup();
        let q = evaluate(&SignEstimator::fit(&w, &b, 4, 0.0), &a, &w, &b);
        for v in [
            q.false_negative_rate,
            q.false_positive_rate,
            q.sign_error,
            q.true_density,
            q.predicted_density,
        ] {
            assert!((0.0..=1.0).contains(&v), "{v} out of [0,1]");
        }
    }
}
