//! The paper's contribution: low-rank activation-sign estimators (§3).
//!
//! For each hidden layer `l` with weights `W_l` and bias `b_l`, maintain a
//! rank-`k` factorization `Ŵ_l = U_l·V_l` (from truncated SVD, §3.2). Before
//! computing the layer, estimate the pre-nonlinearity sign from the cheap
//! product `a_l·U_l·V_l + b_l`; units predicted negative are skipped — their
//! ReLU output would be zero anyway (Eq. 4–5).
//!
//! - [`signest`] — per-layer estimator + the set covering a whole network,
//!   implementing the trainer's gating hooks.
//! - [`refresh`] — refresh policies: once per epoch (the paper), every N
//!   minibatches, and randomized/adaptive variants (§5 future work).
//! - [`metrics`] — sign-estimation quality measures (drives Figs. 2, 4, 6).

pub mod signest;
pub mod refresh;
pub mod metrics;

pub use refresh::RefreshPolicy;
pub use signest::{SignEstimator, SignEstimatorSet};
