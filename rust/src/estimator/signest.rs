//! Per-layer sign estimators and the network-wide estimator set.

use super::refresh::RefreshPolicy;
use crate::config::EstimatorConfig;
use crate::linalg::{LowRank, Mat, QuantizedLowRank, SimdCaps, Svd};
use crate::exec::ExecCtx;
use crate::nn::mlp::{ActivationGater, Mlp};
use crate::nn::trainer::TrainGater;
use crate::parallel::{chunk_rows, par_row_chunks, Parallelism};
use crate::util::Pcg32;

/// A single layer's activation-sign estimator: `S = [a·U·V + b_layer − bias > 0]`.
///
/// The layer bias is carried alongside the factors (it costs nothing to add
/// and the layer's real pre-activation is `a·W + b`). `bias` is the paper's
/// §5 sparsity-tuning offset: raising it makes the estimator more aggressive
/// (more units predicted off).
#[derive(Clone, Debug)]
pub struct SignEstimator {
    pub factors: LowRank,
    /// Int8-quantized factors ([`Self::quantize_factors`]). When present,
    /// full-rank mask production runs both estimator stages on exact i8
    /// dots (the quantized estimator apply path); `None` keeps the float
    /// path. Rank-truncated elastic masks always stay float (see
    /// [`Self::mask_into_ctx_rank`]).
    pub qfactors: Option<QuantizedLowRank>,
    pub layer_bias: Vec<f32>,
    pub bias: f32,
}

impl SignEstimator {
    /// Fit from a weight matrix by exact truncated SVD (paper §3.2).
    pub fn fit(w: &Mat, layer_bias: &[f32], rank: usize, bias: f32) -> SignEstimator {
        SignEstimator {
            factors: LowRank::truncate(w, rank),
            qfactors: None,
            layer_bias: layer_bias.to_vec(),
            bias,
        }
    }

    /// Fit with the randomized range-finder (§5 online-refresh extension).
    pub fn fit_randomized(
        w: &Mat,
        layer_bias: &[f32],
        rank: usize,
        bias: f32,
        rng: &mut Pcg32,
    ) -> SignEstimator {
        SignEstimator {
            factors: LowRank::randomized(w, rank, 8, rng),
            qfactors: None,
            layer_bias: layer_bias.to_vec(),
            bias,
        }
    }

    /// Quantize the fitted factors (symmetric per-row int8). The estimator
    /// only needs the *sign* of `a·U·V + b`, so quantization error — bounded
    /// by the per-row step — costs almost no mask accuracy while the apply
    /// path drops to ~4× narrower arithmetic. Call again after each
    /// [`SignEstimatorSet::refresh`]-style refit; the set does this
    /// automatically when `estimator.quantized` is on.
    pub fn quantize_factors(&mut self) {
        self.qfactors = Some(QuantizedLowRank::quantize(&self.factors));
    }

    pub fn rank(&self) -> usize {
        self.factors.rank()
    }

    /// The estimated pre-activation `a·U·V + b_layer`. Always the float
    /// factors — the test oracle the quantized path is judged against.
    pub fn estimate_preact(&self, input: &Mat) -> Mat {
        let mut z = self.factors.apply(input);
        crate::nn::mlp::add_bias(&mut z, &self.layer_bias);
        z
    }

    /// The paper's `S` matrix (Eq. 5): 1 where the estimated pre-activation
    /// exceeds the decision bias, else 0. Allocating wrapper over
    /// [`Self::mask_into`], so float/quantized routing lives in one place.
    pub fn mask(&self, input: &Mat) -> Mat {
        let mut out = Mat::zeros(input.rows(), self.layer_bias.len());
        self.mask_into(input, &mut out);
        out
    }

    /// Quantized mask rows `row0..row0+rows` into `band` (a shard of the
    /// output matrix). Scratch is per call — i.e. per shard — and every row
    /// depends only on its own input data plus the shared quantized factors,
    /// so sharding never changes a bit of the result.
    fn mask_rows_quant(
        &self,
        q: &QuantizedLowRank,
        caps: SimdCaps,
        input: &Mat,
        row0: usize,
        band: &mut [f32],
    ) {
        let h = self.layer_bias.len();
        let rows = band.len() / h;
        let k = q.rank();
        let mut qx = vec![0i8; q.in_dim()];
        let mut tmp = vec![0.0f32; k];
        let mut qt = vec![0i8; k];
        let b = self.bias;
        for i in 0..rows {
            let zrow = &mut band[i * h..(i + 1) * h];
            q.preact_row_into(caps, input.row(row0 + i), &mut qx, &mut tmp, &mut qt, zrow);
            for (slot, &lb) in zrow.iter_mut().zip(&self.layer_bias) {
                *slot = if *slot + lb - b > 0.0 { 1.0 } else { 0.0 };
            }
        }
    }

    /// The serial mask into a caller-owned buffer (overwritten, not
    /// accumulated — dirty reused buffers need no clearing). The float path
    /// runs the low-rank product through the view GEMM, which keeps the
    /// serial kernel's accumulation order; when [`Self::quantize_factors`]
    /// has run, rows route through the exact-integer quantized apply
    /// instead. Either way this is the buffer-reusing serial oracle behind
    /// [`Self::mask_into_ctx`]: the serving backend recycles one mask buffer
    /// per layer per batch instead of allocating a fresh `Mat` each time.
    pub fn mask_into(&self, input: &Mat, out: &mut Mat) {
        let n = input.rows();
        let h = self.layer_bias.len();
        assert_eq!(out.shape(), (n, h), "mask output shape mismatch");
        if let Some(q) = &self.qfactors {
            self.mask_rows_quant(q, SimdCaps::get(), input, 0, out.as_mut_slice());
            return;
        }
        let rank = self.factors.rank();
        let mut tmp = vec![0.0f32; n * rank];
        self.factors.apply_view_into(input.view(), &mut tmp, out.as_mut_slice());
        let b = self.bias;
        for i in 0..n {
            let zrow = out.row_mut(i);
            for (slot, &lb) in zrow.iter_mut().zip(&self.layer_bias) {
                // Same expression as the serial path: add_bias then
                // `v - b > 0` — i.e. `(z + lb) - b`.
                *slot = if *slot + lb - b > 0.0 { 1.0 } else { 0.0 };
            }
        }
    }

    /// [`Self::mask_into`] on an execution target: row shards in parallel,
    /// bit-identical to the serial form for any thread count or lease width
    /// (same argument as [`Self::mask_par`]; the quantized path's rows are
    /// likewise shard-independent with exact integer accumulation).
    pub fn mask_into_par<P: Parallelism>(&self, input: &Mat, out: &mut Mat, par: &P) {
        let n = input.rows();
        let h = self.layer_bias.len();
        assert_eq!(out.shape(), (n, h), "mask output shape mismatch");
        // Below a few thousand estimated cells, shard setup dominates.
        if par.width() == 1 || n < 2 || n * h < 4096 {
            self.mask_into(input, out);
            return;
        }
        let rows_per = chunk_rows(n, par.width(), 1);
        if let Some(q) = &self.qfactors {
            let caps = SimdCaps::get();
            par_row_chunks(par, out, rows_per, |row0, band| {
                self.mask_rows_quant(q, caps, input, row0, band);
            });
            return;
        }
        let b = self.bias;
        let rank = self.factors.rank();
        par_row_chunks(par, out, rows_per, |row0, band| {
            let rows = band.len() / h;
            let mut tmp = vec![0.0f32; rows * rank];
            self.factors
                .apply_view_into(input.view_rows(row0, rows), &mut tmp, band);
            for i in 0..rows {
                let zrow = &mut band[i * h..(i + 1) * h];
                for (slot, &lb) in zrow.iter_mut().zip(&self.layer_bias) {
                    *slot = if *slot + lb - b > 0.0 { 1.0 } else { 0.0 };
                }
            }
        });
    }

    /// [`Self::mask_into_par`] through an execution context — the serving
    /// backend's estimator entry point (the mask buffer comes from, and
    /// returns to, the ctx's arena).
    pub fn mask_into_ctx(&self, input: &Mat, out: &mut Mat, ctx: &mut ExecCtx<'_>) {
        self.mask_into_par(input, out, ctx.lease());
    }

    /// [`Self::mask_into_ctx`] with an explicit estimator rank override —
    /// the quality-elastic serving path. At `rank >= self.rank()` this is
    /// the unmodified (bit-identical) full-rank path — including the
    /// quantized route when factors are quantized; below it the low-rank
    /// product uses only the leading `rank` SVD factors, trading sign
    /// accuracy for proportionally fewer estimator FLOPs while the server
    /// rides out an overload spike. Truncation always runs the *float*
    /// factors: the quantized form stores transposed whole-factor rows, so
    /// a leading-rank slice would need a re-quantization pass per width —
    /// not worth it for a transient degraded mode.
    pub fn mask_into_ctx_rank(
        &self,
        input: &Mat,
        out: &mut Mat,
        rank: usize,
        ctx: &mut ExecCtx<'_>,
    ) {
        if rank >= self.factors.rank() {
            self.mask_into_ctx(input, out, ctx);
            return;
        }
        let n = input.rows();
        let h = self.layer_bias.len();
        assert_eq!(out.shape(), (n, h), "mask output shape mismatch");
        let r = rank.max(1);
        let b = self.bias;
        let par = ctx.lease();
        if par.width() == 1 || n < 2 || n * h < 4096 {
            let mut tmp = vec![0.0f32; n * r];
            self.factors
                .apply_view_rank_into(input.view(), r, &mut tmp, out.as_mut_slice());
            for i in 0..n {
                let zrow = out.row_mut(i);
                for (slot, &lb) in zrow.iter_mut().zip(&self.layer_bias) {
                    *slot = if *slot + lb - b > 0.0 { 1.0 } else { 0.0 };
                }
            }
            return;
        }
        let rows_per = chunk_rows(n, par.width(), 1);
        par_row_chunks(par, out, rows_per, |row0, band| {
            let rows = band.len() / h;
            let mut tmp = vec![0.0f32; rows * r];
            self.factors
                .apply_view_rank_into(input.view_rows(row0, rows), r, &mut tmp, band);
            for i in 0..rows {
                let zrow = &mut band[i * h..(i + 1) * h];
                for (slot, &lb) in zrow.iter_mut().zip(&self.layer_bias) {
                    *slot = if *slot + lb - b > 0.0 { 1.0 } else { 0.0 };
                }
            }
        });
    }

    /// [`Self::mask`] with the low-rank prediction computed for row shards
    /// in parallel on an execution target (pool or lease slice). Each shard
    /// *borrows* its row range from the input ([`Mat::view_rows`] — no copy
    /// on the serving hot path) and runs the low-rank product through
    /// `LowRank::apply_view_into`, writing the `a·U·V` result straight into
    /// the shard's output band, which is then thresholded in place; the
    /// only per-shard allocation is the small `rows × rank` intermediate.
    /// The view GEMM keeps the serial kernel's accumulation order and every
    /// output row is independent of its neighbours, so the mask is
    /// bit-identical to the serial one for any thread count or lease width.
    pub fn mask_par<P: Parallelism>(&self, input: &Mat, par: &P) -> Mat {
        let mut out = Mat::zeros(input.rows(), self.layer_bias.len());
        self.mask_into_par(input, &mut out, par);
        out
    }

    /// [`Self::mask_par`] through an execution context: sharded by the
    /// ctx's lease width — the serving backend's estimator entry point.
    pub fn mask_ctx(&self, input: &Mat, ctx: &mut ExecCtx<'_>) -> Mat {
        self.mask_par(input, ctx.lease())
    }

    /// Fraction of units predicted live for this input (the achieved α̂).
    pub fn predicted_density(&self, input: &Mat) -> f32 {
        self.mask(input).density()
    }
}

/// Estimators for every hidden layer of a network, plus refresh policy state.
///
/// Implements [`ActivationGater`] (mask per layer during forward) and
/// [`TrainGater`] (policy-driven refresh from the live weights).
pub struct SignEstimatorSet {
    /// One estimator per hidden layer (layer index = weight-matrix index;
    /// the output layer is never estimated, §4.1).
    pub layers: Vec<SignEstimator>,
    pub cfg: EstimatorConfig,
    policy: RefreshPolicy,
    rng: Pcg32,
    steps_since_refresh: usize,
    ever_refreshed: bool,
    /// Total number of refreshes performed (exposed for tests/metrics).
    pub refresh_count: usize,
}

impl SignEstimatorSet {
    /// Build from a network and a config; performs the initial fit.
    pub fn fit(net: &Mlp, cfg: &EstimatorConfig, seed: u64) -> SignEstimatorSet {
        let policy = match cfg.refresh_every {
            Some(n) => RefreshPolicy::EveryNBatches(n),
            None => RefreshPolicy::OncePerEpoch,
        };
        let mut set = SignEstimatorSet {
            layers: Vec::new(),
            cfg: cfg.clone(),
            policy,
            rng: Pcg32::new(seed, 0xE57),
            steps_since_refresh: 0,
            ever_refreshed: false,
            refresh_count: 0,
        };
        set.refresh(net);
        set
    }

    /// Resolve the rank for hidden layer `l` (fixed list or adaptive).
    fn rank_for(&mut self, net: &Mlp, l: usize) -> usize {
        if let Some(energy) = self.cfg.adaptive_energy {
            let svd = Svd::compute(&net.weights[l]);
            return svd.rank_for_energy(energy).max(1);
        }
        self.cfg.ranks.get(l).copied().unwrap_or(1)
    }

    /// Recompute every layer's factorization from the live weights.
    pub fn refresh(&mut self, net: &Mlp) {
        let hidden_layers = net.depth() - 1;
        if !self.cfg.is_control() && self.cfg.adaptive_energy.is_none() {
            assert_eq!(
                self.cfg.ranks.len(),
                hidden_layers,
                "estimator config has {} ranks but the network has {} hidden layers",
                self.cfg.ranks.len(),
                hidden_layers
            );
        }
        let mut layers = Vec::with_capacity(hidden_layers);
        for l in 0..hidden_layers {
            let rank = self.rank_for(net, l);
            let mut est = if self.cfg.randomized {
                SignEstimator::fit_randomized(
                    &net.weights[l],
                    &net.biases[l],
                    rank,
                    self.cfg.bias,
                    &mut self.rng,
                )
            } else {
                SignEstimator::fit(&net.weights[l], &net.biases[l], rank, self.cfg.bias)
            };
            if self.cfg.quantized {
                // Re-quantize on every refresh so the int8 factors never go
                // stale relative to the float factors they mirror.
                est.quantize_factors();
            }
            layers.push(est);
        }
        self.layers = layers;
        self.steps_since_refresh = 0;
        self.ever_refreshed = true;
        self.refresh_count += 1;
    }

    /// Effective ranks per layer (after clamping/adaptive selection).
    pub fn ranks(&self) -> Vec<usize> {
        self.layers.iter().map(|e| e.rank()).collect()
    }
}

impl ActivationGater for SignEstimatorSet {
    fn gate(&self, layer: usize, input: &Mat) -> Option<Mat> {
        // Mask production rides the shared pool for large batches; the
        // parallel path is bit-identical to the serial one, so gated
        // training/eval stay reproducible for any thread count.
        self.layers
            .get(layer)
            .map(|est| est.mask_par(input, crate::parallel::global()))
    }
}

impl TrainGater for SignEstimatorSet {
    fn maybe_refresh(&mut self, net: &Mlp, _epoch: usize, batch_index: usize) {
        if self
            .policy
            .due(batch_index, self.steps_since_refresh, self.ever_refreshed)
        {
            self.refresh(net);
        }
        self.steps_since_refresh += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetConfig;
    use crate::nn::mlp::NoGater;

    fn net(rng: &mut Pcg32) -> Mlp {
        Mlp::init(
            &NetConfig { layers: vec![10, 14, 12, 4], weight_sigma: 0.4, bias_init: 0.1 },
            rng,
        )
    }

    #[test]
    fn full_rank_mask_matches_exact_sign() {
        let mut rng = Pcg32::seeded(1);
        let n = net(&mut rng);
        let x = Mat::randn(6, 10, 1.0, &mut rng);
        // Full-rank estimator for layer 0: UV == W exactly.
        let est = SignEstimator::fit(&n.weights[0], &n.biases[0], 10, 0.0);
        let mask = est.mask(&x);
        // Exact pre-activation sign:
        let mut z = crate::linalg::matmul(&x, &n.weights[0]);
        crate::nn::mlp::add_bias(&mut z, &n.biases[0]);
        for i in 0..6 {
            for j in 0..14 {
                let want = if z[(i, j)] > 0.0 { 1.0 } else { 0.0 };
                // Tolerate boundary flips where |z| is tiny (f32 SVD noise).
                if z[(i, j)].abs() > 1e-4 {
                    assert_eq!(mask[(i, j)], want, "mask mismatch at ({i},{j}) z={}", z[(i, j)]);
                }
            }
        }
    }

    #[test]
    fn sign_error_decreases_with_rank() {
        let mut rng = Pcg32::seeded(2);
        let n = net(&mut rng);
        let x = Mat::randn(40, 10, 1.0, &mut rng);
        let mut z = crate::linalg::matmul(&x, &n.weights[0]);
        crate::nn::mlp::add_bias(&mut z, &n.biases[0]);
        let exact: Vec<f32> = z.as_slice().iter().map(|&v| if v > 0.0 { 1.0 } else { 0.0 }).collect();
        let mut errs = Vec::new();
        for rank in [1, 2, 4, 8, 10] {
            let est = SignEstimator::fit(&n.weights[0], &n.biases[0], rank, 0.0);
            let mask = est.mask(&x);
            let err = mask
                .as_slice()
                .iter()
                .zip(&exact)
                .filter(|(a, b)| *a != *b)
                .count() as f32
                / exact.len() as f32;
            errs.push(err);
        }
        assert!(errs[4] <= 0.02, "full-rank sign error {}", errs[4]);
        assert!(errs[0] >= errs[4], "rank-1 should be no better than full rank");
    }

    #[test]
    fn mask_par_is_bit_identical_to_serial() {
        let mut rng = Pcg32::seeded(77);
        // Wide enough that n*h clears the mask_par serial cutoff (90*80=7200).
        let w = Mat::randn(30, 80, 0.3, &mut rng);
        let bias: Vec<f32> = (0..80).map(|_| rng.uniform_in(-0.2, 0.2)).collect();
        let est = SignEstimator::fit(&w, &bias, 6, 0.05);
        let x = Mat::randn(90, 30, 1.0, &mut rng);
        let want = est.mask(&x);
        for threads in [1usize, 2, 7] {
            let pool = crate::parallel::ThreadPool::new(threads);
            let got = est.mask_par(&x, &pool);
            assert_eq!(got.as_slice(), want.as_slice(), "threads={threads}");
        }
    }

    /// The buffer-reusing mask path (what the serving backend recycles
    /// through its arena) must be bit-identical to the allocating oracle —
    /// dirty buffers, any thread count, any lease width.
    #[test]
    fn mask_into_is_bit_identical_and_overwrites_dirty_buffers() {
        use crate::exec::ExecCtx;
        let mut rng = Pcg32::seeded(83);
        let w = Mat::randn(30, 80, 0.3, &mut rng);
        let bias: Vec<f32> = (0..80).map(|_| rng.uniform_in(-0.2, 0.2)).collect();
        let est = SignEstimator::fit(&w, &bias, 6, 0.05);
        let x = Mat::randn(90, 30, 1.0, &mut rng);
        let want = est.mask(&x);
        let mut out = Mat::full(90, 80, f32::NAN); // simulate a recycled buffer
        est.mask_into(&x, &mut out);
        assert_eq!(out.as_slice(), want.as_slice(), "serial mask_into");
        for threads in [1usize, 2, 7] {
            let pool = crate::parallel::ThreadPool::new(threads);
            for grant in [1usize, threads] {
                let mut out = Mat::full(90, 80, f32::NAN);
                let mut ctx = ExecCtx::over(pool.lease(grant));
                est.mask_into_ctx(&x, &mut out, &mut ctx);
                assert_eq!(
                    out.as_slice(),
                    want.as_slice(),
                    "threads={threads} lease={grant}"
                );
            }
            assert_eq!(pool.leased(), 0);
        }
    }

    /// The elastic rank-override entry point: at (or above) the fitted rank
    /// it must stay bit-identical to the normal path; below it the mask is
    /// the leading-factor truncation — still a valid 0/1 mask, typically a
    /// worse sign predictor — for any thread count or lease width.
    #[test]
    fn mask_into_ctx_rank_full_rank_is_bit_identical_and_truncation_is_deterministic() {
        use crate::exec::ExecCtx;
        let mut rng = Pcg32::seeded(101);
        let w = Mat::randn(30, 80, 0.3, &mut rng);
        let bias: Vec<f32> = (0..80).map(|_| rng.uniform_in(-0.2, 0.2)).collect();
        let est = SignEstimator::fit(&w, &bias, 6, 0.05);
        let x = Mat::randn(90, 30, 1.0, &mut rng);
        let want = est.mask(&x);
        // rank >= fitted rank → the unmodified path, bit-identical.
        for r in [6usize, 100] {
            let pool = crate::parallel::ThreadPool::new(2);
            let mut ctx = ExecCtx::over(pool.lease(2));
            let mut out = Mat::full(90, 80, f32::NAN);
            est.mask_into_ctx_rank(&x, &mut out, r, &mut ctx);
            assert_eq!(out.as_slice(), want.as_slice(), "rank={r}");
        }
        // Truncated rank: deterministic across thread counts and lease
        // widths, all entries 0/1, and distinct from full rank here.
        let mut reference: Option<Mat> = None;
        for threads in [1usize, 2, 7] {
            let pool = crate::parallel::ThreadPool::new(threads);
            for grant in [1usize, threads] {
                let mut ctx = ExecCtx::over(pool.lease(grant));
                let mut out = Mat::full(90, 80, f32::NAN);
                est.mask_into_ctx_rank(&x, &mut out, 2, &mut ctx);
                assert!(out.as_slice().iter().all(|&v| v == 0.0 || v == 1.0));
                match &reference {
                    None => reference = Some(out),
                    Some(want) => assert_eq!(
                        out.as_slice(),
                        want.as_slice(),
                        "threads={threads} lease={grant}"
                    ),
                }
            }
            assert_eq!(pool.leased(), 0);
        }
        let truncated = reference.unwrap();
        assert_ne!(
            truncated.as_slice(),
            want.as_slice(),
            "rank-2 truncation should change at least one decision here"
        );
    }

    /// The quantized estimator apply: bit-identical to its own serial form
    /// at every thread count and lease width (exact integer arithmetic,
    /// row-independent shards), and in high sign-agreement with the float
    /// mask it mirrors.
    #[test]
    fn quantized_masks_are_thread_invariant_and_agree_with_float() {
        use crate::exec::ExecCtx;
        let mut rng = Pcg32::seeded(91);
        let w = Mat::randn(30, 80, 0.3, &mut rng);
        let bias: Vec<f32> = (0..80).map(|_| rng.uniform_in(-0.2, 0.2)).collect();
        let mut est = SignEstimator::fit(&w, &bias, 6, 0.05);
        let x = Mat::randn(90, 30, 1.0, &mut rng);
        let float_mask = est.mask(&x);
        est.quantize_factors();
        let qmask = est.mask(&x);
        assert!(qmask.as_slice().iter().all(|&v| v == 0.0 || v == 1.0));
        let agree = float_mask
            .as_slice()
            .iter()
            .zip(qmask.as_slice())
            .filter(|(a, b)| a == b)
            .count() as f32
            / qmask.as_slice().len() as f32;
        assert!(agree >= 0.95, "quantized mask agrees with float only {agree}");
        for threads in [1usize, 2, 7] {
            let pool = crate::parallel::ThreadPool::new(threads);
            for grant in [1usize, threads] {
                let mut out = Mat::full(90, 80, f32::NAN); // dirty buffer
                let mut ctx = ExecCtx::over(pool.lease(grant));
                est.mask_into_ctx(&x, &mut out, &mut ctx);
                assert_eq!(
                    out.as_slice(),
                    qmask.as_slice(),
                    "threads={threads} lease={grant}"
                );
            }
            assert_eq!(pool.leased(), 0);
        }
        // The elastic full-rank override routes quantized too; a truncated
        // rank falls back to the float factors by contract.
        let pool = crate::parallel::ThreadPool::new(2);
        let mut ctx = ExecCtx::over(pool.lease(2));
        let mut out = Mat::full(90, 80, f32::NAN);
        est.mask_into_ctx_rank(&x, &mut out, 6, &mut ctx);
        assert_eq!(out.as_slice(), qmask.as_slice(), "full-rank override");
    }

    #[test]
    fn estimator_set_quantizes_on_refresh_when_configured() {
        let mut rng = Pcg32::seeded(92);
        let n = net(&mut rng);
        let cfg = EstimatorConfig { quantized: true, ..EstimatorConfig::fixed(&[5, 4]) };
        let set = SignEstimatorSet::fit(&n, &cfg, 9);
        assert!(
            set.layers.iter().all(|e| e.qfactors.is_some()),
            "estimator.quantized must quantize every layer at refresh"
        );
        let float_set = SignEstimatorSet::fit(&n, &EstimatorConfig::fixed(&[5, 4]), 9);
        assert!(float_set.layers.iter().all(|e| e.qfactors.is_none()));
        let x = Mat::randn(6, 10, 1.0, &mut rng);
        let m = set.gate(0, &x).unwrap();
        assert!(m.as_slice().iter().all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn bias_increases_sparsity() {
        let mut rng = Pcg32::seeded(3);
        let n = net(&mut rng);
        let x = Mat::randn(20, 10, 1.0, &mut rng);
        let d0 = SignEstimator::fit(&n.weights[0], &n.biases[0], 6, 0.0).predicted_density(&x);
        let d1 = SignEstimator::fit(&n.weights[0], &n.biases[0], 6, 0.5).predicted_density(&x);
        assert!(d1 <= d0, "higher decision bias must not increase density ({d0} -> {d1})");
    }

    #[test]
    fn set_covers_hidden_layers_only() {
        let mut rng = Pcg32::seeded(4);
        let n = net(&mut rng);
        let set = SignEstimatorSet::fit(&n, &EstimatorConfig::fixed(&[5, 4]), 9);
        assert_eq!(set.layers.len(), 2);
        assert_eq!(set.ranks(), vec![5, 4]);
        let x = Mat::randn(3, 10, 1.0, &mut rng);
        assert!(set.gate(0, &x).is_some());
        assert!(set.gate(2, &x).is_none(), "output layer is never gated");
    }

    #[test]
    #[should_panic(expected = "ranks")]
    fn wrong_rank_count_panics() {
        let mut rng = Pcg32::seeded(5);
        let n = net(&mut rng);
        let _ = SignEstimatorSet::fit(&n, &EstimatorConfig::fixed(&[5]), 9);
    }

    #[test]
    fn refresh_policy_once_per_epoch() {
        let mut rng = Pcg32::seeded(6);
        let n = net(&mut rng);
        let mut set = SignEstimatorSet::fit(&n, &EstimatorConfig::fixed(&[5, 4]), 9);
        assert_eq!(set.refresh_count, 1);
        set.maybe_refresh(&n, 0, 0); // epoch 0 batch 0 → fires
        assert_eq!(set.refresh_count, 2);
        set.maybe_refresh(&n, 0, 1);
        set.maybe_refresh(&n, 0, 2);
        assert_eq!(set.refresh_count, 2);
        set.maybe_refresh(&n, 1, 0); // next epoch → fires
        assert_eq!(set.refresh_count, 3);
    }

    #[test]
    fn refresh_tracks_weight_changes() {
        let mut rng = Pcg32::seeded(7);
        let mut n = net(&mut rng);
        let mut set = SignEstimatorSet::fit(&n, &EstimatorConfig::fixed(&[14, 12]), 9);
        let x = Mat::randn(5, 10, 1.0, &mut rng);
        let before = set.gate(0, &x).unwrap();
        // Mutate weights drastically; stale estimator must differ from fresh.
        for w in n.weights[0].as_mut_slice() {
            *w = -*w;
        }
        let stale = set.gate(0, &x).unwrap();
        assert_eq!(before, stale, "no refresh yet → same mask");
        set.refresh(&n);
        let fresh = set.gate(0, &x).unwrap();
        // Sign flip of W flips nearly every decision (modulo the bias term).
        let changed = fresh
            .as_slice()
            .iter()
            .zip(stale.as_slice())
            .filter(|(a, b)| a != b)
            .count();
        assert!(changed > 0, "refresh must change the mask after weights flip");
    }

    #[test]
    fn adaptive_rank_selects_small_rank_for_lowrank_weights() {
        let mut rng = Pcg32::seeded(8);
        // Build a rank-2 weight matrix.
        let u = Mat::randn(10, 2, 1.0, &mut rng);
        let v = Mat::randn(2, 14, 1.0, &mut rng);
        let mut n = net(&mut rng);
        n.weights[0] = crate::linalg::matmul(&u, &v);
        let cfg = EstimatorConfig {
            adaptive_energy: Some(0.999),
            ..EstimatorConfig::control()
        };
        let set = SignEstimatorSet::fit(&n, &cfg, 3);
        assert!(set.ranks()[0] <= 3, "adaptive rank {} should be ≈2", set.ranks()[0]);
    }

    #[test]
    fn randomized_fit_produces_usable_masks() {
        let mut rng = Pcg32::seeded(9);
        let n = net(&mut rng);
        let x = Mat::randn(30, 10, 1.0, &mut rng);
        let exact = SignEstimator::fit(&n.weights[0], &n.biases[0], 8, 0.0);
        let cfgd = EstimatorConfig {
            randomized: true,
            ..EstimatorConfig::fixed(&[8, 8])
        };
        let set = SignEstimatorSet::fit(&n, &cfgd, 10);
        let m_exact = exact.mask(&x);
        let m_rand = set.gate(0, &x).unwrap();
        let agree = m_exact
            .as_slice()
            .iter()
            .zip(m_rand.as_slice())
            .filter(|(a, b)| a == b)
            .count() as f32
            / m_exact.as_slice().len() as f32;
        assert!(agree > 0.9, "randomized mask agrees only {agree}");
    }

    #[test]
    fn gating_composes_with_forward() {
        let mut rng = Pcg32::seeded(10);
        let n = net(&mut rng);
        let x = Mat::randn(4, 10, 1.0, &mut rng);
        let set = SignEstimatorSet::fit(&n, &EstimatorConfig::fixed(&[14, 12]), 9);
        // Full-rank estimator gating changes nothing except true negatives →
        // logits must match the ungated forward (masked units were zero).
        let gated = n.logits(&x, &set);
        let dense = n.logits(&x, &NoGater);
        assert!(
            gated.max_abs_diff(&dense) < 1e-3,
            "full-rank gating must be output-preserving, diff {}",
            gated.max_abs_diff(&dense)
        );
    }
}
