//! # condcomp — conditional feedforward computation via low-rank sign estimation
//!
//! A three-layer (Rust coordinator / JAX model / Pallas kernel) reproduction of
//! *Davis & Arel, “Low-Rank Approximations for Conditional Feedforward
//! Computation in Deep Neural Networks”, ICLR 2014*.
//!
//! The crate is organized bottom-up:
//!
//! - [`util`] — PRNG, statistics, timing, property-test helpers (offline
//!   substitutes for `rand`/`proptest`).
//! - [`parallel`] — the shared worker pool, pool-slice leasing
//!   (`ThreadPool::lease`), and deterministic partitioning primitives every
//!   compute kernel runs on (dense GEMM, masked GEMM, estimator, serving
//!   backend).
//! - [`exec`] — the execution context: [`exec::ExecCtx`] bundles a pool
//!   lease, a scratch arena, a dispatch-policy view and a metrics scope
//!   behind one handle threaded through backends, kernels and the autotune
//!   harness.
//! - [`trace`] — the serving observability plane's tracing core:
//!   zero-cost-when-disabled span guards (issued through the `ExecCtx`
//!   metrics scope) and the batch flight recorder dumped by the `trace`
//!   protocol op.
//! - [`linalg`] — dense matrices, cache-blocked GEMM (serial oracle +
//!   row-panel-parallel variant), one-sided Jacobi SVD, truncated low-rank
//!   factorization (paper §3.2).
//! - [`io`] — `.npy`/`.npz` and JSON, for weight interchange with the
//!   build-time Python path and for the serving protocol.
//! - [`config`] — TOML-lite parser + typed experiment configuration.
//! - [`cli`] — declarative argument parser for the `condcomp` binary.
//! - [`data`] — synthetic MNIST/SVHN-like corpora, the paper's preprocessing
//!   pipeline (YUV → LCN → histogram equalization → standardize), batching.
//! - [`nn`] — the reference trainer (DeepLearnToolbox-equivalent, paper §3.5).
//! - [`estimator`] — the paper's contribution: SVD-derived activation-sign
//!   estimators with refresh policies and quality metrics (§3.1–§3.3).
//! - [`condcomp`] — conditional forward path: column-skipping masked GEMM
//!   (serial oracle + pool-parallel hot path), the density-adaptive
//!   dense-vs-masked dispatch policy, and the estimator-augmented MLP, with
//!   FLOP accounting.
//! - [`autotune`] — per-layer dispatch calibration: a budgeted
//!   microbenchmark harness fitting each layer shape's masked-vs-dense cost
//!   ratio, persisted as a machine profile (`condcomp calibrate` /
//!   `autotune.profile_path`).
//! - [`cost`] — the analytical FLOP model of §3.4 (Eqs. 8–11).
//! - [`runtime`] — PJRT client + HLO-text artifact store (the AOT bridge).
//! - [`coordinator`] — L3 serving/training orchestration: TCP server, dynamic
//!   batcher, router, SVD-refresh scheduler, metrics registry.
//! - [`bench`] — criterion-lite measurement harness used by `benches/`.
//! - [`experiments`] — one driver per paper table/figure.

// CI denies clippy warnings (`cargo clippy --workspace -- -D warnings`); the
// gate is aimed at the correctness/suspicious/perf/complexity lints. Style
// lints are opted out crate-wide: the numeric kernels' explicit index loops
// and long argument lists mirror the paper's notation and the serial
// oracles, and rewriting them for lint appeasement would hurt reviewability
// against the reference implementations.
#![allow(
    clippy::style,
    clippy::type_complexity,
    clippy::too_many_arguments,
    clippy::needless_range_loop
)]

pub mod util;
pub mod parallel;
pub mod exec;
pub mod trace;
pub mod linalg;
pub mod io;
pub mod config;
pub mod cli;
pub mod data;
pub mod nn;
pub mod estimator;
pub mod condcomp;
pub mod autotune;
pub mod cost;
pub mod runtime;
pub mod coordinator;
pub mod bench;
pub mod experiments;

/// Crate version string reported by the CLI and the serving protocol.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
