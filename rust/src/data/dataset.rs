//! In-memory labeled dataset with train/valid/test splits.

use crate::linalg::Mat;
use crate::util::Pcg32;

/// A labeled split: `x` is `n × d` (one example per row), `y[i] ∈ [0, 10)`.
#[derive(Clone, Debug)]
pub struct Split {
    pub x: Mat,
    pub y: Vec<usize>,
}

impl Split {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// Gather a sub-split by example indices.
    pub fn gather(&self, idx: &[usize]) -> Split {
        let d = self.dim();
        let mut x = Mat::zeros(idx.len(), d);
        let mut y = Vec::with_capacity(idx.len());
        for (row, &i) in idx.iter().enumerate() {
            x.row_mut(row).copy_from_slice(self.x.row(i));
            y.push(self.y[i]);
        }
        Split { x, y }
    }

    /// First `n` examples (used to cap experiment cost).
    pub fn head(&self, n: usize) -> Split {
        let n = n.min(self.len());
        Split { x: self.x.rows_slice(0, n), y: self.y[..n].to_vec() }
    }

    /// Class histogram over the labels.
    pub fn class_counts(&self, num_classes: usize) -> Vec<usize> {
        let mut counts = vec![0usize; num_classes];
        for &y in &self.y {
            counts[y] += 1;
        }
        counts
    }
}

/// A full dataset: named splits plus provenance metadata.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub train: Split,
    pub valid: Split,
    pub test: Split,
    pub num_classes: usize,
}

impl Dataset {
    pub fn input_dim(&self) -> usize {
        self.train.dim()
    }

    /// Shuffle the training split in place (epoch boundary).
    pub fn shuffle_train(&mut self, rng: &mut Pcg32) {
        let n = self.train.len();
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        self.train = self.train.gather(&idx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_split() -> Split {
        Split {
            x: Mat::from_fn(4, 3, |r, c| (r * 3 + c) as f32),
            y: vec![0, 1, 0, 2],
        }
    }

    #[test]
    fn gather_reorders() {
        let s = toy_split();
        let g = s.gather(&[2, 0]);
        assert_eq!(g.len(), 2);
        assert_eq!(g.y, vec![0, 0]);
        assert_eq!(g.x.row(0), s.x.row(2));
        assert_eq!(g.x.row(1), s.x.row(0));
    }

    #[test]
    fn head_truncates() {
        let s = toy_split();
        let h = s.head(2);
        assert_eq!(h.len(), 2);
        assert_eq!(h.y, vec![0, 1]);
        assert_eq!(s.head(100).len(), 4);
    }

    #[test]
    fn class_counts() {
        let s = toy_split();
        assert_eq!(s.class_counts(3), vec![2, 1, 1]);
    }

    #[test]
    fn shuffle_preserves_pairing() {
        let mut ds = Dataset {
            name: "toy".into(),
            train: Split {
                // Row i is constant vector of value i; label = i % 3.
                x: Mat::from_fn(30, 2, |r, _| r as f32),
                y: (0..30).map(|i| i % 3).collect(),
            },
            valid: toy_split(),
            test: toy_split(),
            num_classes: 3,
        };
        let mut rng = Pcg32::seeded(2);
        ds.shuffle_train(&mut rng);
        for i in 0..30 {
            let v = ds.train.x[(i, 0)] as usize;
            assert_eq!(ds.train.y[i], v % 3, "label must follow its row");
        }
    }
}
