//! Data substrate: corpora, preprocessing, batching.
//!
//! The evaluation datasets (MNIST, SVHN) are unavailable in this offline
//! container, so [`synth`] provides procedurally generated stand-ins that
//! preserve the properties the paper's experiments exercise: a 10-class image
//! manifold learnable by an MLP, with enough intra-class variation that
//! trained weight matrices are redundant (decaying singular spectrum). Real
//! MNIST IDX files are used instead when `MNIST_DIR` is set ([`mnist_idx`]).
//!
//! [`preprocess`] implements the paper's §4.1/§4.2 pipelines: RGB→YUV, local
//! contrast normalization, histogram equalization, and per-feature
//! standardization.

pub mod dataset;
pub mod synth;
pub mod mnist_idx;
pub mod preprocess;
pub mod batcher;

pub use batcher::Batcher;
pub use dataset::{Dataset, Split};
