//! Loader for the original MNIST IDX files (`train-images-idx3-ubyte` etc.).
//!
//! Used automatically when the `MNIST_DIR` environment variable points at a
//! directory containing the four standard files; otherwise the synthetic
//! corpus ([`super::synth`]) is used. Gzipped variants (`.gz`) are also
//! accepted via `flate2`.

use super::dataset::{Dataset, Split};
use super::preprocess;
use crate::config::ExperimentProfile;
use crate::linalg::Mat;
use std::io::Read;
use std::path::{Path, PathBuf};

/// Errors from IDX parsing.
#[derive(Debug)]
pub enum IdxError {
    Io(std::io::Error),
    Format(String),
}

impl std::fmt::Display for IdxError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IdxError::Io(e) => write!(f, "idx io error: {e}"),
            IdxError::Format(m) => write!(f, "idx format error: {m}"),
        }
    }
}

impl std::error::Error for IdxError {}

impl From<std::io::Error> for IdxError {
    fn from(e: std::io::Error) -> Self {
        IdxError::Io(e)
    }
}

fn open_maybe_gz(base: &Path) -> Result<Vec<u8>, IdxError> {
    let gz = PathBuf::from(format!("{}.gz", base.display()));
    let mut raw = Vec::new();
    if base.exists() {
        std::fs::File::open(base)?.read_to_end(&mut raw)?;
    } else if gz.exists() {
        let f = std::fs::File::open(&gz)?;
        flate2::read::GzDecoder::new(f).read_to_end(&mut raw)?;
    } else {
        return Err(IdxError::Format(format!("{} (or .gz) not found", base.display())));
    }
    Ok(raw)
}

fn be_u32(b: &[u8], at: usize) -> u32 {
    u32::from_be_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

/// Parse an IDX3 (images) buffer into `n × (rows·cols)` rows scaled to [0,1].
pub fn parse_images(raw: &[u8]) -> Result<Mat, IdxError> {
    if raw.len() < 16 {
        return Err(IdxError::Format("truncated header".into()));
    }
    if be_u32(raw, 0) != 0x0000_0803 {
        return Err(IdxError::Format(format!("bad images magic {:#x}", be_u32(raw, 0))));
    }
    let n = be_u32(raw, 4) as usize;
    let rows = be_u32(raw, 8) as usize;
    let cols = be_u32(raw, 12) as usize;
    let need = 16 + n * rows * cols;
    if raw.len() < need {
        return Err(IdxError::Format(format!("expected {need} bytes, got {}", raw.len())));
    }
    let data: Vec<f32> = raw[16..need].iter().map(|&b| b as f32 / 255.0).collect();
    Ok(Mat::from_vec(n, rows * cols, data))
}

/// Parse an IDX1 (labels) buffer.
pub fn parse_labels(raw: &[u8]) -> Result<Vec<usize>, IdxError> {
    if raw.len() < 8 {
        return Err(IdxError::Format("truncated header".into()));
    }
    if be_u32(raw, 0) != 0x0000_0801 {
        return Err(IdxError::Format(format!("bad labels magic {:#x}", be_u32(raw, 0))));
    }
    let n = be_u32(raw, 4) as usize;
    if raw.len() < 8 + n {
        return Err(IdxError::Format("truncated label payload".into()));
    }
    Ok(raw[8..8 + n].iter().map(|&b| b as usize).collect())
}

/// Load real MNIST from `dir`, splitting train into train/valid per the
/// profile's counts (paper §4.2: 50k/10k) and applying the §4.2 scaling.
pub fn load_mnist(dir: &Path, profile: &ExperimentProfile) -> Result<Dataset, IdxError> {
    let tr_x = parse_images(&open_maybe_gz(&dir.join("train-images-idx3-ubyte"))?)?;
    let tr_y = parse_labels(&open_maybe_gz(&dir.join("train-labels-idx1-ubyte"))?)?;
    let te_x = parse_images(&open_maybe_gz(&dir.join("t10k-images-idx3-ubyte"))?)?;
    let te_y = parse_labels(&open_maybe_gz(&dir.join("t10k-labels-idx1-ubyte"))?)?;
    if tr_x.rows() != tr_y.len() || te_x.rows() != te_y.len() {
        return Err(IdxError::Format("image/label count mismatch".into()));
    }
    let n_train = profile.n_train.min(tr_x.rows());
    let n_valid = profile.n_valid.min(tr_x.rows() - n_train);
    let n_test = profile.n_test.min(te_x.rows());

    let mut train = Split { x: tr_x.rows_slice(0, n_train), y: tr_y[..n_train].to_vec() };
    let mut valid = Split {
        x: tr_x.rows_slice(n_train, n_valid),
        y: tr_y[n_train..n_train + n_valid].to_vec(),
    };
    let mut test = Split { x: te_x.rows_slice(0, n_test), y: te_y[..n_test].to_vec() };

    let scale = preprocess::mnist_scale(&train.x);
    preprocess::apply_mnist_scale(&mut train.x, scale);
    preprocess::apply_mnist_scale(&mut valid.x, scale);
    preprocess::apply_mnist_scale(&mut test.x, scale);
    Ok(Dataset { name: "mnist".into(), train, valid, test, num_classes: 10 })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_images(n: usize, rows: usize, cols: usize) -> Vec<u8> {
        let mut raw = Vec::new();
        raw.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        raw.extend_from_slice(&(n as u32).to_be_bytes());
        raw.extend_from_slice(&(rows as u32).to_be_bytes());
        raw.extend_from_slice(&(cols as u32).to_be_bytes());
        raw.extend((0..n * rows * cols).map(|i| (i % 256) as u8));
        raw
    }

    fn fake_labels(n: usize) -> Vec<u8> {
        let mut raw = Vec::new();
        raw.extend_from_slice(&0x0000_0801u32.to_be_bytes());
        raw.extend_from_slice(&(n as u32).to_be_bytes());
        raw.extend((0..n).map(|i| (i % 10) as u8));
        raw
    }

    #[test]
    fn parses_images() {
        let m = parse_images(&fake_images(3, 4, 5)).unwrap();
        assert_eq!(m.shape(), (3, 20));
        assert!((m[(0, 1)] - 1.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn parses_labels() {
        let y = parse_labels(&fake_labels(12)).unwrap();
        assert_eq!(y.len(), 12);
        assert_eq!(y[11], 1);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(parse_images(&fake_labels(4)).is_err());
        assert!(parse_labels(&fake_images(1, 2, 2)).is_err());
        let mut img = fake_images(2, 3, 3);
        img.truncate(20);
        assert!(parse_images(&img).is_err());
    }

    #[test]
    fn load_mnist_end_to_end_from_fixture_dir() {
        let dir = std::env::temp_dir().join("condcomp-idx-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("train-images-idx3-ubyte"), fake_images(30, 28, 28)).unwrap();
        std::fs::write(dir.join("train-labels-idx1-ubyte"), fake_labels(30)).unwrap();
        std::fs::write(dir.join("t10k-images-idx3-ubyte"), fake_images(10, 28, 28)).unwrap();
        std::fs::write(dir.join("t10k-labels-idx1-ubyte"), fake_labels(10)).unwrap();
        let mut profile = ExperimentProfile::mnist_tiny();
        profile.n_train = 20;
        profile.n_valid = 10;
        profile.n_test = 10;
        let ds = load_mnist(&dir, &profile).unwrap();
        assert_eq!(ds.train.len(), 20);
        assert_eq!(ds.valid.len(), 10);
        assert_eq!(ds.test.len(), 10);
        assert_eq!(ds.input_dim(), 784);
    }
}
