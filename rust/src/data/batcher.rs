//! Minibatch iteration over a split, with per-epoch shuffling.

use super::dataset::Split;
use crate::linalg::Mat;
use crate::util::Pcg32;

/// One minibatch: `x` is `b × d`, `y` the matching labels.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Mat,
    pub y: Vec<usize>,
    /// Index of this batch within the epoch (drives Fig. 6's drift plot).
    pub index: usize,
}

/// Shuffled minibatch source. Produces every example exactly once per epoch;
/// the final batch may be smaller than `batch_size` (never padded here — the
/// serving-side batcher pads, the training-side one does not, matching the
/// reference toolbox).
pub struct Batcher {
    order: Vec<usize>,
    batch_size: usize,
}

impl Batcher {
    pub fn new(n: usize, batch_size: usize) -> Batcher {
        assert!(batch_size > 0, "batch_size must be positive");
        Batcher { order: (0..n).collect(), batch_size }
    }

    /// Number of batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.order.len().div_ceil(self.batch_size)
    }

    /// Reshuffle for a new epoch.
    pub fn shuffle(&mut self, rng: &mut Pcg32) {
        rng.shuffle(&mut self.order);
    }

    /// Iterate batches of the given split for one epoch.
    pub fn epoch<'a>(&'a self, split: &'a Split) -> impl Iterator<Item = Batch> + 'a {
        assert_eq!(split.len(), self.order.len(), "batcher built for a different split size");
        (0..self.batches_per_epoch()).map(move |bi| {
            let lo = bi * self.batch_size;
            let hi = (lo + self.batch_size).min(self.order.len());
            let idx = &self.order[lo..hi];
            let sub = split.gather(idx);
            Batch { x: sub.x, y: sub.y, index: bi }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn split(n: usize) -> Split {
        Split {
            x: Mat::from_fn(n, 2, |r, _| r as f32),
            y: (0..n).map(|i| i % 10).collect(),
        }
    }

    #[test]
    fn covers_every_example_once() {
        let s = split(23);
        let mut b = Batcher::new(23, 5);
        let mut rng = Pcg32::seeded(1);
        b.shuffle(&mut rng);
        let mut seen = vec![0usize; 23];
        let mut batches = 0;
        for batch in b.epoch(&s) {
            batches += 1;
            for i in 0..batch.y.len() {
                let orig = batch.x[(i, 0)] as usize;
                seen[orig] += 1;
                assert_eq!(batch.y[i], orig % 10, "labels track rows");
            }
        }
        assert_eq!(batches, 5);
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn last_batch_is_remainder() {
        let s = split(10);
        let b = Batcher::new(10, 4);
        let sizes: Vec<usize> = b.epoch(&s).map(|bt| bt.y.len()).collect();
        assert_eq!(sizes, vec![4, 4, 2]);
    }

    #[test]
    fn batch_indices_sequential() {
        let s = split(9);
        let b = Batcher::new(9, 3);
        let idx: Vec<usize> = b.epoch(&s).map(|bt| bt.index).collect();
        assert_eq!(idx, vec![0, 1, 2]);
    }

    #[test]
    fn exact_division() {
        let b = Batcher::new(12, 4);
        assert_eq!(b.batches_per_epoch(), 3);
    }
}
