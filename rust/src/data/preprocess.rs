//! The paper's preprocessing pipelines (§4.1 SVHN, §4.2 MNIST).
//!
//! SVHN: RGB → YUV (keep Y) → local contrast normalization (Jarrett et al.
//! 2009) → histogram equalization → per-feature standardization.
//! MNIST: `x / sqrt(max feature variance) − 0.5`.

use crate::linalg::Mat;

/// BT.601 luma from an interleaved RGB buffer (`len = w*h*3`), output `w*h`.
pub fn rgb_to_y(rgb: &[f32], w: usize, h: usize) -> Vec<f32> {
    assert_eq!(rgb.len(), w * h * 3, "rgb buffer size mismatch");
    let mut y = Vec::with_capacity(w * h);
    for px in 0..w * h {
        let r = rgb[px * 3];
        let g = rgb[px * 3 + 1];
        let b = rgb[px * 3 + 2];
        y.push(0.299 * r + 0.587 * g + 0.114 * b);
    }
    y
}

/// Separable Gaussian blur with reflective borders.
fn gaussian_blur(img: &[f32], w: usize, h: usize, sigma: f32, radius: usize) -> Vec<f32> {
    assert_eq!(img.len(), w * h);
    let mut kernel = Vec::with_capacity(2 * radius + 1);
    let denom = 2.0 * sigma * sigma;
    for i in -(radius as i32)..=(radius as i32) {
        kernel.push((-((i * i) as f32) / denom).exp());
    }
    let sum: f32 = kernel.iter().sum();
    for k in kernel.iter_mut() {
        *k /= sum;
    }

    let reflect = |i: i32, n: usize| -> usize {
        let n = n as i32;
        let mut i = i;
        if i < 0 {
            i = -i - 1;
        }
        if i >= n {
            i = 2 * n - 1 - i;
        }
        i.clamp(0, n - 1) as usize
    };

    // Horizontal pass.
    let mut tmp = vec![0.0f32; w * h];
    for y in 0..h {
        for x in 0..w {
            let mut acc = 0.0;
            for (ki, &kv) in kernel.iter().enumerate() {
                let sx = reflect(x as i32 + ki as i32 - radius as i32, w);
                acc += kv * img[y * w + sx];
            }
            tmp[y * w + x] = acc;
        }
    }
    // Vertical pass.
    let mut out = vec![0.0f32; w * h];
    for y in 0..h {
        for x in 0..w {
            let mut acc = 0.0;
            for (ki, &kv) in kernel.iter().enumerate() {
                let sy = reflect(y as i32 + ki as i32 - radius as i32, h);
                acc += kv * tmp[sy * w + x];
            }
            out[y * w + x] = acc;
        }
    }
    out
}

/// Local contrast normalization: subtract a Gaussian-weighted local mean,
/// then divide by the local standard deviation floored at its image mean
/// (the Jarrett et al. divisive-normalization variant the paper cites).
pub fn local_contrast_normalize(img: &[f32], w: usize, h: usize, sigma: f32, radius: usize) -> Vec<f32> {
    let mean = gaussian_blur(img, w, h, sigma, radius);
    let centered: Vec<f32> = img.iter().zip(&mean).map(|(&x, &m)| x - m).collect();
    let sq: Vec<f32> = centered.iter().map(|&x| x * x).collect();
    let var = gaussian_blur(&sq, w, h, sigma, radius);
    let std: Vec<f32> = var.iter().map(|&v| v.max(0.0).sqrt()).collect();
    let mean_std = std.iter().sum::<f32>() / std.len() as f32;
    let floor = mean_std.max(1e-4);
    centered
        .iter()
        .zip(&std)
        .map(|(&c, &s)| c / s.max(floor))
        .collect()
}

/// Histogram equalization over `bins` levels; output in `[0, 1]`.
pub fn histogram_equalize(img: &[f32], bins: usize) -> Vec<f32> {
    assert!(bins >= 2);
    let lo = img.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = img.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !(hi > lo) {
        return vec![0.5; img.len()];
    }
    let scale = (bins - 1) as f32 / (hi - lo);
    let mut hist = vec![0usize; bins];
    for &v in img {
        hist[((v - lo) * scale) as usize] += 1;
    }
    // CDF normalized to [0, 1].
    let mut cdf = vec![0.0f32; bins];
    let mut acc = 0usize;
    for (i, &c) in hist.iter().enumerate() {
        acc += c;
        cdf[i] = acc as f32 / img.len() as f32;
    }
    img.iter().map(|&v| cdf[((v - lo) * scale) as usize]).collect()
}

/// Per-feature standardizer (fit on train, apply anywhere) — §4.1's
/// "subtracting out the mean and dividing by the square root of the variance
/// for each variable".
#[derive(Clone, Debug)]
pub struct Standardizer {
    pub mean: Vec<f32>,
    pub inv_std: Vec<f32>,
}

impl Standardizer {
    pub fn fit(x: &Mat) -> Standardizer {
        let (n, d) = x.shape();
        assert!(n > 0);
        let mut mean = vec![0.0f64; d];
        for i in 0..n {
            for (j, &v) in x.row(i).iter().enumerate() {
                mean[j] += v as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= n as f64;
        }
        let mut var = vec![0.0f64; d];
        for i in 0..n {
            for (j, &v) in x.row(i).iter().enumerate() {
                let dlt = v as f64 - mean[j];
                var[j] += dlt * dlt;
            }
        }
        let inv_std = var
            .iter()
            .map(|&v| {
                let s = (v / n as f64).sqrt();
                if s < 1e-8 { 0.0 } else { (1.0 / s) as f32 }
            })
            .collect();
        Standardizer { mean: mean.into_iter().map(|m| m as f32).collect(), inv_std }
    }

    pub fn apply(&self, x: &mut Mat) {
        let d = x.cols();
        assert_eq!(d, self.mean.len());
        for i in 0..x.rows() {
            let row = x.row_mut(i);
            for j in 0..d {
                row[j] = (row[j] - self.mean[j]) * self.inv_std[j];
            }
        }
    }
}

/// §4.2 MNIST scaling: the single scale factor `1/sqrt(max feature variance)`.
pub fn mnist_scale(x: &Mat) -> f32 {
    let (n, d) = x.shape();
    let mut max_var = 0.0f64;
    for j in 0..d {
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for i in 0..n {
            let v = x[(i, j)] as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = (sq / n as f64 - mean * mean).max(0.0);
        max_var = max_var.max(var);
    }
    if max_var <= 0.0 { 1.0 } else { (1.0 / max_var.sqrt()) as f32 }
}

/// Apply `x ← x·scale − 0.5` in place.
pub fn apply_mnist_scale(x: &mut Mat, scale: f32) {
    x.map_inplace(|v| v * scale - 0.5);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::Pcg32;

    #[test]
    fn luma_weights() {
        // Pure white → 1; pure red → 0.299.
        let y = rgb_to_y(&[1.0, 1.0, 1.0, 1.0, 0.0, 0.0], 2, 1);
        assert!((y[0] - 1.0).abs() < 1e-6);
        assert!((y[1] - 0.299).abs() < 1e-6);
    }

    #[test]
    fn blur_preserves_constant_images() {
        let img = vec![0.7f32; 16 * 16];
        let out = gaussian_blur(&img, 16, 16, 2.0, 4);
        for v in out {
            assert!((v - 0.7).abs() < 1e-5);
        }
    }

    #[test]
    fn blur_reduces_variance() {
        let mut rng = Pcg32::seeded(4);
        let img: Vec<f32> = (0..24 * 24).map(|_| rng.uniform()).collect();
        let out = gaussian_blur(&img, 24, 24, 2.0, 4);
        let var = |xs: &[f32]| {
            let m = xs.iter().sum::<f32>() / xs.len() as f32;
            xs.iter().map(|&x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32
        };
        assert!(var(&out) < var(&img) * 0.5);
    }

    #[test]
    fn lcn_centers_locally() {
        let mut rng = Pcg32::seeded(8);
        // Image with strong global gradient + texture.
        let img: Vec<f32> = (0..32 * 32)
            .map(|i| (i % 32) as f32 / 32.0 + rng.uniform() * 0.1)
            .collect();
        let out = local_contrast_normalize(&img, 32, 32, 2.0, 4);
        let mean = out.iter().sum::<f32>() / out.len() as f32;
        assert!(mean.abs() < 0.05, "LCN output should be near zero-mean, got {mean}");
    }

    #[test]
    fn histeq_flattens_distribution() {
        let mut rng = Pcg32::seeded(2);
        // Heavily skewed values.
        let img: Vec<f32> = (0..4096).map(|_| rng.uniform().powi(4)).collect();
        let out = histogram_equalize(&img, 256);
        // Quartiles of the output should be near 0.25/0.5/0.75.
        let mut sorted = out.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |f: f64| sorted[(f * (sorted.len() - 1) as f64) as usize];
        assert!((q(0.5) - 0.5).abs() < 0.05, "median {}", q(0.5));
        assert!((q(0.25) - 0.25).abs() < 0.05);
    }

    #[test]
    fn histeq_constant_image() {
        let out = histogram_equalize(&[0.3; 100], 64);
        assert!(out.iter().all(|&v| v == 0.5));
    }

    #[test]
    fn standardizer_zero_mean_unit_var() {
        property("standardize normalizes train", 8, |rng| {
            let n = rng.index(40) + 10;
            let d = rng.index(8) + 2;
            let mut x = Mat::from_fn(n, d, |_, j| rng.normal() * (j as f32 + 1.0) + j as f32);
            let s = Standardizer::fit(&x);
            s.apply(&mut x);
            for j in 0..d {
                let col = x.col(j);
                let m = col.iter().sum::<f32>() / n as f32;
                let v = col.iter().map(|&c| (c - m) * (c - m)).sum::<f32>() / n as f32;
                assert!(m.abs() < 1e-3, "col {j} mean {m}");
                assert!((v - 1.0).abs() < 1e-2, "col {j} var {v}");
            }
        });
    }

    #[test]
    fn standardizer_handles_constant_features() {
        let mut x = Mat::from_fn(10, 2, |i, j| if j == 0 { 5.0 } else { i as f32 });
        let s = Standardizer::fit(&x);
        s.apply(&mut x);
        for i in 0..10 {
            assert_eq!(x[(i, 0)], 0.0, "constant feature maps to 0");
        }
    }

    #[test]
    fn mnist_scale_shifts_range() {
        let mut rng = Pcg32::seeded(6);
        let mut x = Mat::from_fn(50, 3, |_, _| rng.uniform());
        let s = mnist_scale(&x);
        assert!(s > 0.0);
        apply_mnist_scale(&mut x, s);
        let lo = x.as_slice().iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(lo >= -0.5 - 1e-6);
    }
}
