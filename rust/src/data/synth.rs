//! Procedural digit corpora — the offline stand-ins for MNIST and SVHN.
//!
//! Each digit class is a fixed set of strokes (polylines in the unit square).
//! An example is rendered by applying a random affine perturbation (rotation,
//! anisotropic scale, shear, translation), drawing the strokes with a random
//! thickness via a signed-distance falloff, and adding pixel noise. The
//! resulting manifold is (a) learnable by an MLP to a few % error, and
//! (b) varied enough that trained weight matrices exhibit the decaying
//! singular spectrum the paper's low-rank argument depends on (§2.1).
//!
//! The SVHN-like generator composites the digit over a colored background
//! with distractor strokes and returns 32×32 RGB, which then flows through
//! the paper's preprocessing pipeline ([`super::preprocess`]).

use super::dataset::{Dataset, Split};
use super::preprocess;
use crate::config::{DatasetKind, ExperimentProfile};
use crate::linalg::Mat;
use crate::util::Pcg32;

/// A stroke: sequence of points in the unit square (y grows downward).
type Stroke = &'static [(f32, f32)];

/// Stroke geometry for digits 0–9.
const DIGIT_STROKES: [&[Stroke]; 10] = [
    // 0: closed loop
    &[&[(0.35, 0.20), (0.62, 0.18), (0.70, 0.45), (0.64, 0.80), (0.38, 0.82), (0.30, 0.50), (0.35, 0.20)]],
    // 1: vertical bar with a flag
    &[&[(0.40, 0.25), (0.52, 0.12), (0.52, 0.88)]],
    // 2: top curve, diagonal, base
    &[&[(0.32, 0.28), (0.45, 0.13), (0.63, 0.17), (0.68, 0.35), (0.50, 0.55), (0.32, 0.84), (0.70, 0.84)]],
    // 3: two right-facing bumps
    &[&[(0.33, 0.16), (0.62, 0.14), (0.66, 0.32), (0.46, 0.48)], &[(0.46, 0.48), (0.68, 0.56), (0.66, 0.80), (0.34, 0.86)]],
    // 4: diagonal + crossbar + vertical
    &[&[(0.60, 0.12), (0.30, 0.58), (0.78, 0.58)], &[(0.62, 0.34), (0.62, 0.88)]],
    // 5: top bar, left drop, bowl
    &[&[(0.68, 0.14), (0.36, 0.14), (0.34, 0.46), (0.58, 0.44), (0.68, 0.60), (0.64, 0.80), (0.34, 0.86)]],
    // 6: sweep down into a lower loop
    &[&[(0.64, 0.14), (0.42, 0.32), (0.34, 0.58), (0.38, 0.80), (0.58, 0.86), (0.66, 0.68), (0.56, 0.54), (0.36, 0.58)]],
    // 7: top bar + steep diagonal
    &[&[(0.30, 0.15), (0.70, 0.15), (0.46, 0.86)]],
    // 8: stacked loops
    &[
        &[(0.50, 0.13), (0.65, 0.26), (0.50, 0.46), (0.35, 0.26), (0.50, 0.13)],
        &[(0.50, 0.50), (0.68, 0.66), (0.50, 0.87), (0.32, 0.66), (0.50, 0.50)],
    ],
    // 9: upper loop + tail
    &[&[(0.64, 0.30), (0.50, 0.12), (0.35, 0.28), (0.48, 0.46), (0.64, 0.30)], &[(0.64, 0.30), (0.58, 0.86)]],
];

/// Random affine perturbation parameters for one example.
#[derive(Clone, Copy, Debug)]
struct Jitter {
    cos: f32,
    sin: f32,
    sx: f32,
    sy: f32,
    shear: f32,
    dx: f32,
    dy: f32,
    thickness: f32,
}

impl Jitter {
    fn sample(rng: &mut Pcg32, strength: f32) -> Jitter {
        let angle = rng.uniform_in(-0.26, 0.26) * strength; // ±15° at strength 1
        Jitter {
            cos: angle.cos(),
            sin: angle.sin(),
            sx: 1.0 + rng.uniform_in(-0.18, 0.18) * strength,
            sy: 1.0 + rng.uniform_in(-0.18, 0.18) * strength,
            shear: rng.uniform_in(-0.18, 0.18) * strength,
            dx: rng.uniform_in(-0.07, 0.07) * strength,
            dy: rng.uniform_in(-0.07, 0.07) * strength,
            thickness: 0.050 + rng.uniform_in(-0.012, 0.022) * strength,
        }
    }

    /// Apply to a unit-square point, around the center (0.5, 0.5).
    fn apply(&self, (x, y): (f32, f32)) -> (f32, f32) {
        let (cx, cy) = (x - 0.5, y - 0.5);
        let (cx, cy) = (cx + self.shear * cy, cy);
        let (cx, cy) = (cx * self.sx, cy * self.sy);
        let (rx, ry) = (self.cos * cx - self.sin * cy, self.sin * cx + self.cos * cy);
        (rx + 0.5 + self.dx, ry + 0.5 + self.dy)
    }
}

/// Squared distance from point `p` to segment `ab`.
fn dist2_to_segment(p: (f32, f32), a: (f32, f32), b: (f32, f32)) -> f32 {
    let (apx, apy) = (p.0 - a.0, p.1 - a.1);
    let (abx, aby) = (b.0 - a.0, b.1 - a.1);
    let ab2 = abx * abx + aby * aby;
    let t = if ab2 <= 1e-12 { 0.0 } else { ((apx * abx + apy * aby) / ab2).clamp(0.0, 1.0) };
    let (dx, dy) = (p.0 - (a.0 + t * abx), p.1 - (a.1 + t * aby));
    dx * dx + dy * dy
}

/// Render digit `class` into a `side × side` grayscale buffer in `[0, 1]`.
pub fn render_digit(class: usize, side: usize, rng: &mut Pcg32, strength: f32) -> Vec<f32> {
    let jit = Jitter::sample(rng, strength);
    // Pre-transform stroke points.
    let strokes: Vec<Vec<(f32, f32)>> = DIGIT_STROKES[class]
        .iter()
        .map(|s| s.iter().map(|&p| jit.apply(p)).collect())
        .collect();
    let mut img = vec![0.0f32; side * side];
    let inv = 1.0 / side as f32;
    let th = jit.thickness;
    let feather = 0.025f32;
    for py in 0..side {
        for px in 0..side {
            let p = ((px as f32 + 0.5) * inv, (py as f32 + 0.5) * inv);
            let mut d2min = f32::INFINITY;
            for stroke in &strokes {
                for w in stroke.windows(2) {
                    d2min = d2min.min(dist2_to_segment(p, w[0], w[1]));
                }
            }
            let d = d2min.sqrt();
            // Smooth falloff from the stroke spine.
            let v = if d <= th {
                1.0
            } else if d < th + feather {
                1.0 - (d - th) / feather
            } else {
                0.0
            };
            img[py * side + px] = v;
        }
    }
    img
}

/// Generate an MNIST-like split: 28×28 grayscale, mild noise, values [0,1].
pub fn mnist_like_split(n: usize, rng: &mut Pcg32) -> Split {
    let side = 28;
    let d = side * side;
    let mut x = Mat::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let class = rng.index(10);
        let mut img = render_digit(class, side, rng, 1.0);
        for v in img.iter_mut() {
            *v = (*v + rng.normal() * 0.05).clamp(0.0, 1.0);
        }
        x.row_mut(i).copy_from_slice(&img);
        y.push(class);
    }
    Split { x, y }
}

/// Generate an SVHN-like split: 32×32 RGB composites reduced to the 1024-d
/// preprocessed Y channel per the paper's §4.1 pipeline.
pub fn svhn_like_split(n: usize, rng: &mut Pcg32) -> Split {
    let side = 32;
    let d = side * side;
    let mut x = Mat::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let class = rng.index(10);
        let rgb = render_svhn_rgb(class, side, rng);
        let yuv_y = preprocess::rgb_to_y(&rgb, side, side);
        let lcn = preprocess::local_contrast_normalize(&yuv_y, side, side, 2.0, 4);
        let eq = preprocess::histogram_equalize(&lcn, 256);
        x.row_mut(i).copy_from_slice(&eq);
        y.push(class);
    }
    Split { x, y }
}

/// Render one SVHN-like RGB image (flat `[r g b]` per pixel, values [0,1]).
pub fn render_svhn_rgb(class: usize, side: usize, rng: &mut Pcg32) -> Vec<f32> {
    // Background: linear gradient between two random colors.
    let c0 = [rng.uniform(), rng.uniform(), rng.uniform()];
    let c1 = [rng.uniform(), rng.uniform(), rng.uniform()];
    let gx = rng.uniform_in(-1.0, 1.0);
    let gy = rng.uniform_in(-1.0, 1.0);
    let digit = render_digit(class, side, rng, 1.2);
    // Digit color must contrast with the mean background.
    let bg_mean: f32 = (c0.iter().sum::<f32>() + c1.iter().sum::<f32>()) / 6.0;
    let fg = if bg_mean > 0.5 {
        [rng.uniform_in(0.0, 0.3), rng.uniform_in(0.0, 0.3), rng.uniform_in(0.0, 0.3)]
    } else {
        [rng.uniform_in(0.7, 1.0), rng.uniform_in(0.7, 1.0), rng.uniform_in(0.7, 1.0)]
    };
    // Distractor: a partial neighboring digit at the border (SVHN crops often
    // contain digit fragments).
    let distractor = render_digit(rng.index(10), side, rng, 1.5);
    let dshift = if rng.bernoulli(0.5) { side as i32 * 2 / 3 } else { -(side as i32 * 2 / 3) };

    let mut out = vec![0.0f32; side * side * 3];
    for py in 0..side {
        for px in 0..side {
            let t = ((px as f32 / side as f32 - 0.5) * gx + (py as f32 / side as f32 - 0.5) * gy + 0.5)
                .clamp(0.0, 1.0);
            let mut pix = [
                c0[0] * (1.0 - t) + c1[0] * t,
                c0[1] * (1.0 - t) + c1[1] * t,
                c0[2] * (1.0 - t) + c1[2] * t,
            ];
            // Distractor fragment, faded.
            let dx = px as i32 + dshift;
            if (0..side as i32).contains(&dx) {
                let a = distractor[py * side + dx as usize] * 0.5;
                for (ch, p) in pix.iter_mut().enumerate() {
                    *p = *p * (1.0 - a) + fg[ch] * a;
                }
            }
            let a = digit[py * side + px];
            for (ch, p) in pix.iter_mut().enumerate() {
                *p = *p * (1.0 - a) + fg[ch] * a;
                // Sensor noise.
                *p = (*p + rng.normal() * 0.03).clamp(0.0, 1.0);
            }
            let base = (py * side + px) * 3;
            out[base] = pix[0];
            out[base + 1] = pix[1];
            out[base + 2] = pix[2];
        }
    }
    out
}

/// Build the full dataset for a profile: generates splits, then applies the
/// paper's normalization (fit on train, applied everywhere).
pub fn build_dataset(profile: &ExperimentProfile, seed: u64) -> Dataset {
    match profile.dataset {
        DatasetKind::Mnist => {
            // Real MNIST when available, synthetic otherwise.
            if let Ok(dir) = std::env::var("MNIST_DIR") {
                if let Ok(ds) = super::mnist_idx::load_mnist(std::path::Path::new(&dir), profile) {
                    return ds;
                }
            }
            let mut rng = Pcg32::new(seed, 100);
            let mut train = mnist_like_split(profile.n_train, &mut rng);
            let mut valid = mnist_like_split(profile.n_valid, &mut rng);
            let mut test = mnist_like_split(profile.n_test, &mut rng);
            // Paper §4.2: x / sqrt(max feature variance) − 0.5.
            let scale = preprocess::mnist_scale(&train.x);
            preprocess::apply_mnist_scale(&mut train.x, scale);
            preprocess::apply_mnist_scale(&mut valid.x, scale);
            preprocess::apply_mnist_scale(&mut test.x, scale);
            Dataset { name: "mnist-like".into(), train, valid, test, num_classes: 10 }
        }
        DatasetKind::Svhn => {
            let mut rng = Pcg32::new(seed, 200);
            let mut train = svhn_like_split(profile.n_train, &mut rng);
            let mut valid = svhn_like_split(profile.n_valid, &mut rng);
            let mut test = svhn_like_split(profile.n_test, &mut rng);
            // Paper §4.1: per-feature standardization fit on train.
            let stats = preprocess::Standardizer::fit(&train.x);
            stats.apply(&mut train.x);
            stats.apply(&mut valid.x);
            stats.apply(&mut test.x);
            Dataset { name: "svhn-like".into(), train, valid, test, num_classes: 10 }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    #[test]
    fn digits_render_nonempty_and_distinct() {
        let mut rng = Pcg32::seeded(1);
        let mut means = Vec::new();
        for class in 0..10 {
            let img = render_digit(class, 28, &mut rng, 0.0);
            let on = img.iter().filter(|&&v| v > 0.5).count();
            assert!(on > 20, "class {class} renders only {on} lit pixels");
            assert!(on < 28 * 28 / 2, "class {class} renders too many pixels");
            means.push(img);
        }
        // Unjittered classes must be pairwise distinguishable.
        for a in 0..10 {
            for b in (a + 1)..10 {
                let diff: f32 = means[a]
                    .iter()
                    .zip(&means[b])
                    .map(|(x, y)| (x - y).abs())
                    .sum();
                assert!(diff > 10.0, "classes {a} and {b} overlap (diff {diff})");
            }
        }
    }

    #[test]
    fn jitter_varies_but_class_is_stable() {
        let mut rng = Pcg32::seeded(3);
        let base = render_digit(7, 28, &mut rng, 0.0);
        let jit = render_digit(7, 28, &mut rng, 1.0);
        let diff: f32 = base.iter().zip(&jit).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1.0, "jitter should move pixels");
        // The jittered 7 must still be closer to the clean 7 than to a clean 0
        // on average across draws (weak but meaningful invariant).
        let clean0 = render_digit(0, 28, &mut rng, 0.0);
        let mut closer = 0;
        for _ in 0..20 {
            let j = render_digit(7, 28, &mut rng, 1.0);
            let d7: f32 = base.iter().zip(&j).map(|(x, y)| (x - y).abs()).sum();
            let d0: f32 = clean0.iter().zip(&j).map(|(x, y)| (x - y).abs()).sum();
            if d7 < d0 {
                closer += 1;
            }
        }
        assert!(closer >= 15, "jittered 7 close to clean 7 only {closer}/20 times");
    }

    #[test]
    fn mnist_split_shapes_and_ranges() {
        let mut rng = Pcg32::seeded(5);
        let s = mnist_like_split(32, &mut rng);
        assert_eq!(s.x.shape(), (32, 784));
        assert_eq!(s.y.len(), 32);
        assert!(s.y.iter().all(|&y| y < 10));
        for v in s.x.as_slice() {
            assert!((0.0..=1.0).contains(v));
        }
    }

    #[test]
    fn svhn_split_shapes() {
        let mut rng = Pcg32::seeded(6);
        let s = svhn_like_split(8, &mut rng);
        assert_eq!(s.x.shape(), (8, 1024));
        assert!(s.y.iter().all(|&y| y < 10));
    }

    #[test]
    fn generation_is_deterministic() {
        property("same seed same corpus", 4, |rng| {
            let seed = rng.next_u64();
            let a = mnist_like_split(4, &mut Pcg32::new(seed, 9));
            let b = mnist_like_split(4, &mut Pcg32::new(seed, 9));
            assert_eq!(a.y, b.y);
            assert_eq!(a.x, b.x);
        });
    }

    #[test]
    fn build_dataset_standardizes() {
        let mut profile = ExperimentProfile::mnist_tiny();
        profile.n_train = 64;
        profile.n_valid = 16;
        profile.n_test = 16;
        let ds = build_dataset(&profile, 42);
        assert_eq!(ds.train.len(), 64);
        assert_eq!(ds.input_dim(), 784);
        // After MNIST scaling, values live in roughly [-0.5, 0.5+].
        let lo = ds.train.x.as_slice().iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(lo >= -0.51, "min {lo}");
    }

    #[test]
    fn svhn_rgb_in_range() {
        let mut rng = Pcg32::seeded(11);
        let img = render_svhn_rgb(3, 32, &mut rng);
        assert_eq!(img.len(), 32 * 32 * 3);
        for v in &img {
            assert!((0.0..=1.0).contains(v));
        }
    }
}
