//! Figure 4: a too-coarse (very low rank) estimator tracks the true sign
//! pattern early in training — while activations are mostly positive thanks
//! to the b=1 bias init — then collapses as the sign pattern diversifies.
//! We train a control network and, at each epoch boundary, fit a low-rank
//! and a higher-rank estimator to the live weights and measure their sign
//! error on a fixed probe batch.

use super::common::dataset_for;
use super::report::{markdown_table, write_markdown, Csv};
use crate::config::{EstimatorConfig, ExperimentProfile};
use crate::estimator::metrics::evaluate;
use crate::estimator::SignEstimator;
use crate::nn::mlp::NoGater;
use crate::nn::Trainer;
use crate::nn::Mlp;
use crate::util::Pcg32;
use anyhow::Result;
use std::path::Path;

pub fn run(profile: &ExperimentProfile, out_dir: &Path) -> Result<()> {
    let mut data = dataset_for(profile);
    let mut rng = Pcg32::new(profile.train.seed, 1);
    let mut net = Mlp::init(&profile.net, &mut rng);
    let probe = data.valid.head(128.min(data.valid.len())).x;

    // Low-rank ≈ the paper's 25-25-25 scaled; high-rank ≈ 4× that.
    let paper = crate::config::ExperimentProfile::mnist_paper();
    let lo_ranks = if profile.net.layers == paper.net.layers {
        vec![25, 25, 25]
    } else {
        profile.scale_ranks(&[25, 25, 25], &paper)
    };
    let hi_ranks: Vec<usize> = lo_ranks
        .iter()
        .enumerate()
        .map(|(l, &r)| (r * 4).min(profile.net.layers[l].min(profile.net.layers[l + 1])))
        .collect();
    let _ = EstimatorConfig::control(); // referenced for doc parity

    let mut csv = Csv::create(
        &out_dir.join("fig4.csv"),
        &["epoch", "low_rank_sign_error", "high_rank_sign_error", "true_density"],
    )?;
    let mut rows = Vec::new();

    // Train epoch by epoch so we can snapshot weights at each boundary. We
    // drive a fresh single-epoch Trainer per step but keep the *same* network,
    // and carry the schedules by overriding lr/momentum to the epoch's value.
    let total_epochs = profile.train.epochs;
    for epoch in 0..total_epochs {
        // Measure the estimators against the *current* weights (epoch start).
        let est_lo = SignEstimator::fit(&net.weights[0], &net.biases[0], lo_ranks[0], 0.0);
        let est_hi = SignEstimator::fit(&net.weights[0], &net.biases[0], hi_ranks[0], 0.0);
        let q_lo = evaluate(&est_lo, &probe, &net.weights[0], &net.biases[0]);
        let q_hi = evaluate(&est_hi, &probe, &net.weights[0], &net.biases[0]);
        csv.row_f64(&[epoch as f64, q_lo.sign_error, q_hi.sign_error, q_lo.true_density])?;
        rows.push(vec![
            epoch.to_string(),
            format!("{:.4}", q_lo.sign_error),
            format!("{:.4}", q_hi.sign_error),
            format!("{:.3}", q_lo.true_density),
        ]);
        eprintln!(
            "[fig4] epoch {epoch:>3}: low-rank {:.4}  high-rank {:.4}  α {:.3}",
            q_lo.sign_error, q_hi.sign_error, q_lo.true_density
        );

        // Advance one epoch of training with the epoch-correct schedules.
        let mut cfg = profile.train.clone();
        cfg.epochs = 1;
        cfg.lr = profile.train.lr * profile.train.lr_decay.powi(epoch as i32);
        cfg.momentum = (profile.train.momentum * profile.train.momentum_growth.powi(epoch as i32))
            .min(profile.train.max_momentum);
        cfg.seed = profile.train.seed ^ (epoch as u64 + 1);
        let trainer = Trainer::new(cfg);
        let _ = trainer.train(&mut net, &mut data, &mut NoGater);
    }

    write_markdown(
        out_dir,
        "fig4",
        "Figure 4 — coarse vs fine estimator sign error during training (layer 1)",
        &markdown_table(&["epoch", "low-rank err", "high-rank err", "α"], &rows),
    )?;
    Ok(())
}
