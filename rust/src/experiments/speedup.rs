//! §3.4 (Eqs. 8–11): the FLOP cost model, analytically and as measured
//! wall-clock on this machine's dense vs. conditional GEMM.
//!
//! For each layer of the profile's architecture and a sweep of (rank k,
//! density α), report:
//!   - the analytic `F_nn / F_ae` ratio (Eq. 10),
//!   - the measured dense / (estimator + masked) wall-clock ratio, using a
//!     random mask at the target density — same code path the server runs.

use super::report::{markdown_table, write_markdown, Csv};
use crate::bench::{bench_with_units, quick};
use crate::condcomp::MaskedLayer;
use crate::config::ExperimentProfile;
use crate::cost::LayerCost;
use crate::linalg::{LowRank, Mat};
use crate::util::Pcg32;
use anyhow::Result;
use std::path::Path;

pub fn run(profile: &ExperimentProfile, out_dir: &Path) -> Result<()> {
    let layers = &profile.net.layers;
    let alphas = [0.05, 0.10, 0.25, 0.50, 1.00];
    let rank_fracs = [0.02, 0.05, 0.10, 0.25];
    let batch = 8usize;
    let mut rng = Pcg32::seeded(99);
    let cfg = quick();

    let mut csv = Csv::create(
        &out_dir.join("speedup.csv"),
        &["layer", "d", "h", "k", "alpha", "analytic_speedup", "measured_speedup"],
    )?;
    let mut md_rows = Vec::new();

    for l in 0..layers.len() - 2 {
        let (d, h) = (layers[l], layers[l + 1]);
        let w = Mat::randn(d, h, 0.05, &mut rng);
        let bias = vec![0.0f32; h];
        let layer = MaskedLayer::new(&w, &bias);
        let x = Mat::randn(batch, d, 1.0, &mut rng);

        // Dense baseline time.
        let dense = bench_with_units(&format!("dense d{d} h{h}"), &cfg, (batch * d * h) as f64, || {
            layer.forward_dense(&x)
        });
        let t_dense = dense.time.median;

        for &rf in &rank_fracs {
            let k = ((d.min(h) as f64 * rf) as usize).max(1);
            let lr = LowRank::truncate(&w, k);
            for &alpha in &alphas {
                // Random mask at target density (the measured path is mask-
                // driven; where the mask comes from doesn't change its cost).
                let mask = Mat::from_fn(batch, h, |_, _| {
                    if rng.bernoulli(alpha as f32) { 1.0 } else { 0.0 }
                });
                let mut tmp = Mat::zeros(batch, k);
                let mut est_out = Mat::zeros(batch, h);
                let cond = bench_with_units(
                    &format!("cond d{d} h{h} k{k} a{alpha}"),
                    &cfg,
                    (batch * d * h) as f64,
                    || {
                        // Estimator cost (low-rank product) + masked GEMM.
                        lr.apply_into(&x, &mut tmp, &mut est_out);
                        layer.forward_masked(&x, &mask)
                    },
                );
                let measured = t_dense / cond.time.median;
                let analytic = LayerCost::new(d, h, k, alpha).speedup();
                csv.row_f64(&[
                    l as f64,
                    d as f64,
                    h as f64,
                    k as f64,
                    alpha,
                    analytic,
                    measured,
                ])?;
                md_rows.push(vec![
                    format!("{l}"),
                    format!("{d}×{h}"),
                    k.to_string(),
                    format!("{alpha:.2}"),
                    format!("{analytic:.2}×"),
                    format!("{measured:.2}×"),
                ]);
            }
        }
        eprintln!("[speedup] layer {l} ({d}×{h}) swept");
    }

    // Whole-network Eq. 11 at the paper's canonical α = 0.1, k = 5% of width.
    let net_layers: Vec<LayerCost> = (0..layers.len() - 2)
        .map(|l| {
            let (d, h) = (layers[l], layers[l + 1]);
            LayerCost::new(d, h, (d.min(h) / 20).max(1), 0.1)
        })
        .collect();
    let eq11 = crate::cost::network_speedup(&net_layers);
    eprintln!("[speedup] Eq.11 whole-network speedup @ α=0.1, k=5%: {eq11:.2}×");

    write_markdown(
        out_dir,
        "speedup",
        &format!(
            "§3.4 speedup model — {} (Eq.11 @ α=0.1, k=5%: {eq11:.2}×)",
            profile.name
        ),
        &markdown_table(
            &["layer", "shape", "k", "α", "analytic (Eq.10)", "measured"],
            &md_rows,
        ),
    )?;
    Ok(())
}
