//! Figure 6: within-epoch drift. The SVD is computed at the start of an
//! epoch; each gradient update moves the weights away from the stale
//! factorization, so the estimator's sign error grows through the epoch and
//! resets at the next refresh — per layer, at different rates.

use super::common::dataset_for;
use super::report::{markdown_table, write_markdown, Csv};
use crate::config::ExperimentProfile;
use crate::data::Batcher;
use crate::estimator::metrics::evaluate;
use crate::estimator::SignEstimator;
use crate::nn::activations::{nll_grad, softmax_rows};
use crate::nn::mlp::NoGater;
use crate::nn::optimizer::SgdMomentum;
use crate::nn::Mlp;
use crate::util::Pcg32;
use anyhow::Result;
use std::path::Path;

pub fn run(profile: &ExperimentProfile, out_dir: &Path) -> Result<()> {
    let mut data = dataset_for(profile);
    let mut rng = Pcg32::new(profile.train.seed, 1);
    let mut net = Mlp::init(&profile.net, &mut rng);

    // Warm up for one epoch so the weights are in a realistic regime.
    let mut warm_cfg = profile.train.clone();
    warm_cfg.epochs = 1;
    let trainer = crate::nn::Trainer::new(warm_cfg);
    let _ = trainer.train(&mut net, &mut data, &mut NoGater);

    let hidden_layers = net.depth() - 1;
    let paper = ExperimentProfile::mnist_paper();
    let ranks = if profile.net.layers == paper.net.layers {
        vec![50, 35, 25]
    } else {
        let base: Vec<usize> = vec![50, 35, 25, 20, 15][..hidden_layers].to_vec();
        profile.scale_ranks(&base, &paper)
    };

    // Freeze estimators at the refresh point (epoch start).
    let frozen: Vec<SignEstimator> = (0..hidden_layers)
        .map(|l| SignEstimator::fit(&net.weights[l], &net.biases[l], ranks[l], 0.0))
        .collect();

    // Now run minibatches for two epochs WITHOUT refreshing, measuring each
    // estimator against the live weights as they drift; refresh at the start
    // of the second epoch to show the reset.
    let mut header: Vec<String> = vec!["batch".into()];
    header.extend((0..hidden_layers).map(|l| format!("layer{}_sign_error", l + 1)));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut csv = Csv::create(&out_dir.join("fig6.csv"), &header_refs)?;

    let mut opt = SgdMomentum::new(&net, profile.train.clone());
    let mut batcher = Batcher::new(data.train.len(), profile.train.batch_size);
    let probe = data.valid.head(128.min(data.valid.len()));
    let mut estimators = frozen;
    let mut global_batch = 0usize;
    let mut rows_md = Vec::new();
    for epoch in 0..2usize {
        if epoch == 1 {
            // The paper's once-per-epoch refresh: error resets here.
            for (l, est) in estimators.iter_mut().enumerate() {
                *est = SignEstimator::fit(&net.weights[l], &net.biases[l], ranks[l], 0.0);
            }
        }
        batcher.shuffle(&mut rng);
        for batch in batcher.epoch(&data.train) {
            // Measure drift (estimator vs live weights) on the probe inputs,
            // layer 1 probes raw features; deeper layers probe the live
            // hidden activations.
            let trace = net.forward(&probe.x, &NoGater, None);
            let mut row = vec![global_batch as f64];
            let mut md_row = vec![global_batch.to_string()];
            for l in 0..hidden_layers {
                let input = if l == 0 { &probe.x } else { &trace.inputs[l] };
                let q = evaluate(&estimators[l], input, &net.weights[l], &net.biases[l]);
                row.push(q.sign_error);
                md_row.push(format!("{:.4}", q.sign_error));
            }
            csv.row_f64(&row)?;
            if global_batch % 8 == 0 {
                rows_md.push(md_row);
            }

            // One training step.
            let mut drop_rng = rng.split();
            let trace = net.forward(
                &batch.x,
                &NoGater,
                Some((profile.train.dropout_p, &mut drop_rng)),
            );
            let probs = softmax_rows(&trace.logits);
            let dlogits = nll_grad(&probs, &batch.y);
            let (dws, dbs) = net.backward(&trace, &dlogits, profile.train.l1_activation);
            opt.step(&mut net, &dws, &dbs);
            global_batch += 1;
        }
        opt.next_epoch();
    }

    write_markdown(
        out_dir,
        "fig6",
        "Figure 6 — estimator sign error drift between per-epoch SVD refreshes",
        &markdown_table(&header_refs, &rows_md),
    )?;
    eprintln!("[fig6] wrote {} batch measurements across 2 epochs", global_batch);
    Ok(())
}
