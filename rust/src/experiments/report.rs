//! Report emitters: CSV series and aligned markdown tables.

use std::io::Write;
use std::path::Path;

/// A simple CSV writer with a fixed header.
pub struct Csv {
    file: std::io::BufWriter<std::fs::File>,
    pub columns: usize,
}

impl Csv {
    pub fn create(path: &Path, header: &[&str]) -> std::io::Result<Csv> {
        let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(file, "{}", header.join(","))?;
        Ok(Csv { file, columns: header.len() })
    }

    pub fn row(&mut self, cells: &[String]) -> std::io::Result<()> {
        assert_eq!(cells.len(), self.columns, "csv row width mismatch");
        writeln!(self.file, "{}", cells.join(","))
    }

    pub fn row_f64(&mut self, cells: &[f64]) -> std::io::Result<()> {
        self.row(&cells.iter().map(|x| format!("{x}")).collect::<Vec<_>>())
    }
}

/// Render a markdown table.
pub fn markdown_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push_str(&format!("| {} |\n", header.join(" | ")));
    s.push_str(&format!("|{}\n", "---|".repeat(header.len())));
    for row in rows {
        s.push_str(&format!("| {} |\n", row.join(" | ")));
    }
    s
}

/// Write a named markdown section to `<out>/<name>.md`.
pub fn write_markdown(out_dir: &Path, name: &str, title: &str, body: &str) -> std::io::Result<()> {
    let mut f = std::fs::File::create(out_dir.join(format!("{name}.md")))?;
    writeln!(f, "# {title}\n\n{body}")
}

/// Format an error rate as a percentage string.
pub fn pct(x: f32) -> String {
    format!("{:.2}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("condcomp-report-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        {
            let mut c = Csv::create(&path, &["a", "b"]).unwrap();
            c.row(&["1".into(), "x".into()]).unwrap();
            c.row_f64(&[2.5, 3.0]).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("a,b\n1,x\n"));
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn csv_checks_width() {
        let dir = std::env::temp_dir().join("condcomp-report-test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut c = Csv::create(&dir.join("w.csv"), &["a", "b"]).unwrap();
        let _ = c.row(&["only-one".into()]);
    }

    #[test]
    fn markdown_shape() {
        let md = markdown_table(&["x", "y"], &[vec!["1".into(), "2".into()]]);
        assert!(md.contains("| x | y |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.0931), "9.31%");
    }
}
