//! Figures 3/5 + Tables 2/3: validation-error-vs-epoch curves and final test
//! error for the paper's estimator configurations on the SVHN-like and
//! MNIST-like corpora.

use super::common::{scaled_configs, train_one};
use super::report::{markdown_table, pct, write_markdown, Csv};
use crate::config::{DatasetKind, ExperimentProfile};
use anyhow::Result;
use std::path::Path;

/// Paper Table 2 / Figure 3 rank lists (SVHN, 4 hidden layers).
pub const SVHN_RANKS: &[&[usize]] = &[
    &[200, 100, 75, 15],
    &[100, 75, 50, 25],
    &[100, 75, 50, 15],
    &[75, 50, 40, 30],
    &[50, 40, 40, 35],
    &[25, 25, 15, 15],
];

/// Paper Table 3 / Figure 5 rank lists (MNIST, 3 hidden layers).
pub const MNIST_RANKS: &[&[usize]] = &[&[50, 35, 25], &[25, 25, 25], &[15, 10, 5], &[10, 10, 5]];

pub fn run_mnist(profile: &ExperimentProfile, out_dir: &Path) -> Result<()> {
    assert_eq!(profile.dataset, DatasetKind::Mnist, "fig5/table3 are MNIST experiments");
    run_curves(
        profile,
        &ExperimentProfile::mnist_paper(),
        MNIST_RANKS,
        out_dir,
        "fig5",
        "table3",
        "Figure 5 / Table 3 — MNIST",
    )
}

pub fn run_svhn(profile: &ExperimentProfile, out_dir: &Path) -> Result<()> {
    assert_eq!(profile.dataset, DatasetKind::Svhn, "fig3/table2 are SVHN experiments");
    run_curves(
        profile,
        &ExperimentProfile::svhn_paper(),
        SVHN_RANKS,
        out_dir,
        "fig3",
        "table2",
        "Figure 3 / Table 2 — SVHN",
    )
}

fn run_curves(
    profile: &ExperimentProfile,
    paper_profile: &ExperimentProfile,
    rank_lists: &[&[usize]],
    out_dir: &Path,
    fig_name: &str,
    table_name: &str,
    title: &str,
) -> Result<()> {
    let configs = scaled_configs(profile, paper_profile, rank_lists);
    let mut outcomes = Vec::new();
    for cfg in &configs {
        eprintln!("[{fig_name}] training '{}' on {}…", cfg.label(), profile.name);
        let out = train_one(profile, cfg, true);
        eprintln!(
            "[{fig_name}]   final valid {:.2}%  test {:.2}%",
            out.history.last().map(|h| h.valid_error * 100.0).unwrap_or(f32::NAN),
            out.test_error * 100.0
        );
        outcomes.push(out);
    }

    // Figure: per-epoch validation error per config.
    let mut header = vec!["epoch".to_string()];
    header.extend(outcomes.iter().map(|o| o.label.clone()));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut csv = Csv::create(&out_dir.join(format!("{fig_name}.csv")), &header_refs)?;
    let epochs = outcomes.iter().map(|o| o.history.len()).max().unwrap_or(0);
    for e in 0..epochs {
        let mut row = vec![e.to_string()];
        for o in &outcomes {
            row.push(
                o.history
                    .get(e)
                    .map(|h| format!("{:.6}", h.valid_error))
                    .unwrap_or_default(),
            );
        }
        csv.row(&row)?;
    }

    // Table: final test error per config (the paper's Tables 2/3).
    let rows: Vec<Vec<String>> = outcomes
        .iter()
        .map(|o| vec![o.label.clone(), pct(o.test_error)])
        .collect();
    write_markdown(
        out_dir,
        table_name,
        &format!("{title} — test error"),
        &markdown_table(&["Network", "Error"], &rows),
    )?;
    let mut tcsv = Csv::create(&out_dir.join(format!("{table_name}.csv")), &["network", "test_error"])?;
    for o in &outcomes {
        tcsv.row(&[o.label.clone(), format!("{:.6}", o.test_error)])?;
    }

    // Acceptance-shape telemetry (DESIGN.md §6): control ≤ any estimator run
    // is the paper's qualitative ordering; surface it for EXPERIMENTS.md.
    let control_err = outcomes[0].test_error;
    let worst = outcomes
        .iter()
        .skip(1)
        .map(|o| o.test_error)
        .fold(0.0f32, f32::max);
    eprintln!(
        "[{table_name}] control {:.2}% vs worst estimator {:.2}% (paper shape: control best, \
         degradation grows as rank shrinks)",
        control_err * 100.0,
        worst * 100.0
    );
    Ok(())
}
