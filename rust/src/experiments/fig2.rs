//! Figure 2: error of the low-rank *value* path `σ(a·UV)` vs the
//! *sign-masked* path `σ(a·W)·S` as the rank sweeps 1 → full, measured on
//! layer 1 of a trained network. The paper's claim: the sign-masked error
//! decays far faster, so a very low rank suffices for the estimator.

use super::common::{dataset_for, train_one};
use super::report::{markdown_table, write_markdown, Csv};
use crate::config::{EstimatorConfig, ExperimentProfile};
use crate::estimator::metrics::evaluate;
use crate::estimator::SignEstimator;
use anyhow::Result;
use std::path::Path;

pub fn run(profile: &ExperimentProfile, out_dir: &Path) -> Result<()> {
    eprintln!("[fig2] training control network ({})…", profile.name);
    let outcome = train_one(profile, &EstimatorConfig::control(), true);
    let data = dataset_for(profile);
    let net = &outcome.net;

    // Probe batch: a slice of validation inputs (layer-1 sees raw features).
    let probe = data.valid.head(256.min(data.valid.len())).x;
    let w = &net.weights[0];
    let b = &net.biases[0];
    let full_rank = w.rows().min(w.cols());

    // Log-spaced ranks 1 → full.
    let mut ranks = vec![1usize];
    let mut r = 1usize;
    while r < full_rank {
        r = (r * 2).min(full_rank);
        ranks.push(r);
    }

    let mut csv = Csv::create(
        &out_dir.join("fig2.csv"),
        &["rank", "lowrank_rel_error", "masked_rel_error", "sign_error"],
    )?;
    let mut rows = Vec::new();
    for &rank in &ranks {
        let est = SignEstimator::fit(w, b, rank, 0.0);
        let q = evaluate(&est, &probe, w, b);
        csv.row_f64(&[rank as f64, q.lowrank_rel_error, q.masked_rel_error, q.sign_error])?;
        rows.push(vec![
            rank.to_string(),
            format!("{:.4}", q.lowrank_rel_error),
            format!("{:.4}", q.masked_rel_error),
            format!("{:.4}", q.sign_error),
        ]);
        eprintln!(
            "[fig2] rank {rank:>4}: lowrank {:.4}  masked {:.4}  sign {:.4}",
            q.lowrank_rel_error, q.masked_rel_error, q.sign_error
        );
    }
    write_markdown(
        out_dir,
        "fig2",
        "Figure 2 — low-rank value error vs sign-masked error (layer 1)",
        &markdown_table(&["rank", "‖σ(aW)−σ(aUV)‖ rel", "‖σ(aW)−σ(aW)·S‖ rel", "sign err"], &rows),
    )?;
    Ok(())
}
