//! Experiment drivers — one per table/figure in the paper's evaluation
//! (see DESIGN.md §6 for the index).
//!
//! Every driver takes an [`crate::config::ExperimentProfile`] (so the same
//! code runs at `paper`, `small`, or `tiny` scale) and writes CSV + markdown
//! into an output directory. `condcomp experiment <id>` is the CLI entry.

pub mod report;
pub mod common;
pub mod fig2;
pub mod curves;
pub mod fig4;
pub mod fig6;
pub mod speedup;

use crate::config::ExperimentProfile;
use anyhow::{anyhow, Result};
use std::path::Path;

/// Run an experiment by paper id. `fig3`/`table2` and `fig5`/`table3` share
/// one training sweep each (the table is the last row of the curves).
pub fn run(id: &str, profile: &ExperimentProfile, out_dir: &Path) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    match id {
        "fig2" => fig2::run(profile, out_dir),
        "fig3" | "table2" => curves::run_svhn(profile, out_dir),
        "fig4" => fig4::run(profile, out_dir),
        "fig5" | "table3" => curves::run_mnist(profile, out_dir),
        "fig6" => fig6::run(profile, out_dir),
        "speedup" | "eq10" => speedup::run(profile, out_dir),
        "all" => {
            fig2::run(profile, out_dir)?;
            fig4::run(profile, out_dir)?;
            fig6::run(profile, out_dir)?;
            speedup::run(profile, out_dir)?;
            curves::run_mnist(profile, out_dir)?;
            curves::run_svhn(profile, out_dir)
        }
        other => Err(anyhow!(
            "unknown experiment '{other}' (try fig2|fig3|fig4|fig5|fig6|table2|table3|speedup|all)"
        )),
    }
}

/// All experiment ids, for `--help` and the bench drivers.
pub const ALL_IDS: &[&str] =
    &["fig2", "fig3", "fig4", "fig5", "fig6", "table2", "table3", "speedup"];
