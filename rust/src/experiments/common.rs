//! Shared machinery for the experiment drivers.

use crate::config::{EstimatorConfig, ExperimentProfile};
use crate::data::synth::build_dataset;
use crate::data::Dataset;
use crate::estimator::SignEstimatorSet;
use crate::nn::mlp::NoGater;
use crate::nn::trainer::{evaluate_error, EpochStats, TrainGater, Trainer};
use crate::nn::Mlp;
use crate::util::Pcg32;

/// Outcome of one training run.
pub struct RunOutcome {
    pub label: String,
    pub history: Vec<EpochStats>,
    pub test_error: f32,
    pub net: Mlp,
}

/// Build the profile's dataset (deterministic in the profile seed).
pub fn dataset_for(profile: &ExperimentProfile) -> Dataset {
    build_dataset(profile, profile.train.seed ^ 0xDA7A)
}

/// Train one network under an estimator config (or control when the config
/// is `control()`), evaluating on the profile's validation split per epoch
/// and on the test split at the end.
pub fn train_one(profile: &ExperimentProfile, est_cfg: &EstimatorConfig, quiet: bool) -> RunOutcome {
    let mut data = dataset_for(profile);
    let mut rng = Pcg32::new(profile.train.seed, 1);
    let mut net = Mlp::init(&profile.net, &mut rng);
    let mut trainer = Trainer::new(profile.train.clone());
    trainer.options.quiet = quiet;

    let (history, test_error) = if est_cfg.is_control() {
        let mut gater = NoGater;
        let h = trainer.train(&mut net, &mut data, &mut gater);
        let e = evaluate_error(&net, &NoGater, &data.test);
        (h, e)
    } else {
        let mut gater = SignEstimatorSet::fit(&net, est_cfg, profile.train.seed ^ 0x5E7);
        let h = trainer.train(&mut net, &mut data, &mut gater);
        // Final refresh so the test-time estimator matches final weights.
        gater.refresh(&net);
        let e = evaluate_error(&net, &gater, &data.test);
        (h, e)
    };
    RunOutcome { label: est_cfg.label(), history, test_error, net }
}

/// The paper's estimator configurations, scaled to the active profile.
///
/// Paper rank lists are defined against the paper architectures; on scaled
/// profiles each rank is shrunk proportionally to the layer widths
/// ([`ExperimentProfile::scale_ranks`]), preserving the sweep's *shape*.
pub fn scaled_configs(
    profile: &ExperimentProfile,
    paper_profile: &ExperimentProfile,
    paper_rank_lists: &[&[usize]],
) -> Vec<EstimatorConfig> {
    let mut out = vec![EstimatorConfig::control()];
    for ranks in paper_rank_lists {
        let scaled = if profile.net.layers == paper_profile.net.layers {
            ranks.to_vec()
        } else {
            profile.scale_ranks(ranks, paper_profile)
        };
        out.push(EstimatorConfig::fixed(&scaled));
    }
    out
}

/// A gater that wraps a `SignEstimatorSet` so drivers can access refresh
/// internals while the trainer drives the policy.
pub struct ObservedGater<'a> {
    pub inner: &'a mut SignEstimatorSet,
}

impl crate::nn::mlp::ActivationGater for ObservedGater<'_> {
    fn gate(&self, layer: usize, input: &crate::linalg::Mat) -> Option<crate::linalg::Mat> {
        self.inner.gate(layer, input)
    }
}

impl TrainGater for ObservedGater<'_> {
    fn maybe_refresh(&mut self, net: &Mlp, epoch: usize, batch_index: usize) {
        self.inner.maybe_refresh(net, epoch, batch_index);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ExperimentProfile {
        let mut p = ExperimentProfile::mnist_tiny();
        p.net.layers = vec![784, 32, 24, 10];
        p.train.epochs = 2;
        p.n_train = 300;
        p.n_valid = 80;
        p.n_test = 80;
        p
    }

    #[test]
    fn control_run_trains() {
        let out = train_one(&tiny(), &EstimatorConfig::control(), true);
        assert_eq!(out.label, "control");
        assert_eq!(out.history.len(), 2);
        assert!(out.test_error < 0.9);
    }

    #[test]
    fn estimator_run_trains_and_refreshes() {
        let cfg = EstimatorConfig::fixed(&[16, 12]);
        let out = train_one(&tiny(), &cfg, true);
        assert_eq!(out.label, "16-12");
        assert!(out.test_error <= 1.0);
    }

    #[test]
    fn scaled_configs_include_control() {
        let paper = ExperimentProfile::mnist_paper();
        let cfgs = scaled_configs(&tiny(), &paper, &[&[50, 35], &[25, 25]]);
        assert_eq!(cfgs.len(), 3);
        assert!(cfgs[0].is_control());
        assert!(cfgs[1].ranks.iter().all(|&r| r >= 1));
    }
}
