//! Singular value decomposition, from scratch.
//!
//! [`Svd::compute`] is a one-sided Jacobi SVD (Hestenes rotations): numerically
//! robust, simple to verify, and accurate enough that sign-estimation error is
//! dominated by truncation, not by the factorization. Cost is
//! `O(m·n²·sweeps)`, which is acceptable for the paper's per-epoch refresh
//! (§3.2: "calculating the SVD is an expensive operation … we can opt to
//! calculate the SVD less frequently").
//!
//! The paper's future-work section asks for a cheaper online refresh; the
//! randomized range-finder variant lives in [`super::lowrank`] and reuses the
//! Jacobi core on a small projected matrix.

use super::matrix::Mat;

/// Thin SVD `A = U · diag(s) · Vᵀ` with `U: m×r`, `s: r`, `Vᵀ: r×n`,
/// `r = min(m, n)`, singular values sorted descending.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f32>,
    pub vt: Mat,
}

/// Convergence threshold on the normalized off-diagonal inner product.
const JACOBI_TOL: f64 = 1e-9;
/// Hard cap on Jacobi sweeps (each sweep is a full pass over column pairs).
const MAX_SWEEPS: usize = 30;

impl Svd {
    /// Compute the thin SVD of `a` by one-sided Jacobi.
    pub fn compute(a: &Mat) -> Svd {
        let (m, n) = a.shape();
        if m >= n {
            jacobi_tall(a)
        } else {
            // SVD(Aᵀ) = (V, s, Uᵀ); swap the factors back.
            let t = jacobi_tall(&a.transpose());
            Svd { u: t.vt.transpose(), s: t.s, vt: t.u.transpose() }
        }
    }

    /// Rank of the decomposition (number of retained singular values).
    pub fn rank(&self) -> usize {
        self.s.len()
    }

    /// Reconstruct `U · diag(s) · Vᵀ` (testing / diagnostics).
    pub fn reconstruct(&self) -> Mat {
        let r = self.rank();
        let (m, n) = (self.u.rows(), self.vt.cols());
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let urow = self.u.row(i);
            let orow = out.row_mut(i);
            for p in 0..r {
                let c = urow[p] * self.s[p];
                if c == 0.0 {
                    continue;
                }
                let vrow = self.vt.row(p);
                for j in 0..n {
                    orow[j] += c * vrow[j];
                }
            }
        }
        out
    }

    /// Energy captured by the top-`r` singular values:
    /// `Σ_{i<r} s_i² / Σ_i s_i²`. Drives the adaptive rank selector (§5).
    pub fn energy_at(&self, r: usize) -> f64 {
        let total: f64 = self.s.iter().map(|&x| (x as f64) * (x as f64)).sum();
        if total == 0.0 {
            return 1.0;
        }
        let head: f64 = self.s.iter().take(r).map(|&x| (x as f64) * (x as f64)).sum();
        head / total
    }

    /// Smallest rank whose captured energy reaches `fraction` of the total.
    pub fn rank_for_energy(&self, fraction: f64) -> usize {
        let total: f64 = self.s.iter().map(|&x| (x as f64) * (x as f64)).sum();
        if total == 0.0 {
            return 1;
        }
        let mut acc = 0.0;
        for (i, &s) in self.s.iter().enumerate() {
            acc += (s as f64) * (s as f64);
            if acc >= fraction * total {
                return i + 1;
            }
        }
        self.s.len()
    }
}

/// One-sided Jacobi on a tall (m ≥ n) matrix.
fn jacobi_tall(a: &Mat) -> Svd {
    let (m, n) = a.shape();
    debug_assert!(m >= n);
    // Work columns of G in column-major order so each rotation touches two
    // contiguous strips.
    let mut g = vec![0.0f64; m * n]; // g[j*m + i]
    for i in 0..m {
        let arow = a.row(i);
        for j in 0..n {
            g[j * m + i] = arow[j] as f64;
        }
    }
    let mut v = vec![0.0f64; n * n]; // v[j*n + i] column-major
    for j in 0..n {
        v[j * n + j] = 1.0;
    }

    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let (alpha, beta, gamma) = {
                    let gp = &g[p * m..p * m + m];
                    let gq = &g[q * m..q * m + m];
                    let mut alpha = 0.0;
                    let mut beta = 0.0;
                    let mut gamma = 0.0;
                    for i in 0..m {
                        alpha += gp[i] * gp[i];
                        beta += gq[i] * gq[i];
                        gamma += gp[i] * gq[i];
                    }
                    (alpha, beta, gamma)
                };
                if alpha == 0.0 || beta == 0.0 {
                    continue;
                }
                let norm_gamma = gamma.abs() / (alpha.sqrt() * beta.sqrt());
                off = off.max(norm_gamma);
                if norm_gamma <= JACOBI_TOL {
                    continue;
                }
                // Jacobi rotation zeroing the (p,q) off-diagonal of GᵀG.
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                rotate_cols(&mut g, m, p, q, c, s);
                rotate_cols(&mut v, n, p, q, c, s);
            }
        }
        if off <= JACOBI_TOL {
            break;
        }
    }

    // Extract singular values and left vectors; sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n)
        .map(|j| g[j * m..j * m + m].iter().map(|x| x * x).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&a, &b| norms[b].partial_cmp(&norms[a]).unwrap());

    let mut u = Mat::zeros(m, n);
    let mut s = Vec::with_capacity(n);
    let mut vt = Mat::zeros(n, n);
    for (slot, &j) in order.iter().enumerate() {
        let sigma = norms[j];
        s.push(sigma as f32);
        if sigma > 0.0 {
            for i in 0..m {
                u[(i, slot)] = (g[j * m + i] / sigma) as f32;
            }
        }
        for i in 0..n {
            vt[(slot, i)] = v[j * n + i] as f32;
        }
    }
    Svd { u, s, vt }
}

/// Apply the rotation `[c -s; s c]` to columns `p`, `q` of a column-major
/// buffer with leading dimension `ld`.
#[inline]
fn rotate_cols(buf: &mut [f64], ld: usize, p: usize, q: usize, c: f64, s: f64) {
    let (lo, hi) = if p < q { (p, q) } else { (q, p) };
    let (head, tail) = buf.split_at_mut(hi * ld);
    let colp = &mut head[lo * ld..lo * ld + ld];
    let colq = &mut tail[..ld];
    if p < q {
        for i in 0..ld {
            let gp = colp[i];
            let gq = colq[i];
            colp[i] = c * gp - s * gq;
            colq[i] = s * gp + c * gq;
        }
    } else {
        for i in 0..ld {
            let gq = colp[i];
            let gp = colq[i];
            colq[i] = c * gp - s * gq;
            colp[i] = s * gp + c * gq;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_naive;
    use crate::util::proptest::property;
    use crate::util::Pcg32;

    fn check_orthonormal_cols(m: &Mat, tol: f32) {
        let g = matmul_naive(&m.transpose(), m);
        let d = g.max_abs_diff(&Mat::eye(m.cols()));
        assert!(d < tol, "columns not orthonormal: max dev {d}");
    }

    #[test]
    fn reconstructs_random_matrices() {
        property("U S Vt == A", 12, |rng| {
            let m = rng.index(20) + 2;
            let n = rng.index(20) + 2;
            let a = Mat::randn(m, n, 1.0, rng);
            let svd = Svd::compute(&a);
            let err = svd.reconstruct().max_abs_diff(&a);
            assert!(err < 1e-3, "reconstruction error {err} for {m}x{n}");
        });
    }

    #[test]
    fn factors_are_orthonormal() {
        let mut rng = Pcg32::seeded(4);
        for &(m, n) in &[(12, 8), (8, 12), (10, 10)] {
            let a = Mat::randn(m, n, 1.0, &mut rng);
            let svd = Svd::compute(&a);
            check_orthonormal_cols(&svd.u, 1e-4);
            check_orthonormal_cols(&svd.vt.transpose(), 1e-4);
        }
    }

    #[test]
    fn singular_values_sorted_nonnegative() {
        property("sorted s", 16, |rng| {
            let a = Mat::randn(rng.index(15) + 2, rng.index(15) + 2, 1.0, rng);
            let svd = Svd::compute(&a);
            for w in svd.s.windows(2) {
                assert!(w[0] >= w[1]);
            }
            assert!(svd.s.iter().all(|&x| x >= 0.0));
        });
    }

    #[test]
    fn diagonal_matrix_exact() {
        let mut a = Mat::zeros(4, 4);
        for (i, &v) in [3.0f32, 1.0, 4.0, 2.0].iter().enumerate() {
            a[(i, i)] = v;
        }
        let svd = Svd::compute(&a);
        let want = [4.0, 3.0, 2.0, 1.0];
        for (got, want) in svd.s.iter().zip(want.iter()) {
            assert!((got - want).abs() < 1e-5);
        }
    }

    #[test]
    fn rank_deficient_matrix() {
        // Outer product => rank 1; second singular value ~ 0.
        let mut rng = Pcg32::seeded(8);
        let u: Vec<f32> = (0..10).map(|_| rng.normal()).collect();
        let v: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
        let a = Mat::from_fn(10, 6, |i, j| u[i] * v[j]);
        let svd = Svd::compute(&a);
        assert!(svd.s[0] > 0.1);
        assert!(svd.s[1] < 1e-4, "s1 = {}", svd.s[1]);
        assert!(svd.reconstruct().max_abs_diff(&a) < 1e-4);
    }

    #[test]
    fn energy_and_rank_selection() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 3.0; // energy 9
        a[(1, 1)] = 4.0; // energy 16
        let svd = Svd::compute(&a);
        assert!((svd.energy_at(1) - 16.0 / 25.0).abs() < 1e-6);
        assert_eq!(svd.rank_for_energy(0.6), 1);
        assert_eq!(svd.rank_for_energy(0.99), 2);
    }

    #[test]
    fn wide_matrix_matches_tall_transpose() {
        let mut rng = Pcg32::seeded(21);
        let a = Mat::randn(5, 9, 1.0, &mut rng);
        let svd = Svd::compute(&a);
        let svd_t = Svd::compute(&a.transpose());
        for (x, y) in svd.s.iter().zip(svd_t.s.iter()) {
            assert!((x - y).abs() < 1e-4);
        }
        assert!(svd.reconstruct().max_abs_diff(&a) < 1e-3);
    }
}
