//! Symmetric per-row int8 quantization (`dense_i8` / `masked_i8` backing).
//!
//! The quantization scheme is the standard symmetric per-row one: for each
//! row, `scale = max_abs / 127` and `q = round(x / scale)` clamped to
//! `[-127, 127]` (an all-zero row stores scale `0.0` and all-zero codes).
//! Weights are quantized **once** at model-prep time ([`QuantizedMat`] /
//! [`QuantizedLayer`]); activations are quantized per input row at run time,
//! amortized over the `h` output dot products that consume the row.
//!
//! Numeric contract — stronger than the f32 SIMD kernels':
//!
//! - **Integer accumulation is exact.** `i8 × i8` products are at most
//!   `127² = 16129`, so an `i32` accumulator is exact up to reduction
//!   lengths of ~133 000 elements — far beyond any layer in this crate.
//!   Exact integer addition is associative, so **every ISA path, thread
//!   count, lease width and accumulation order produces identical bits**
//!   with no mirrored-accumulator ceremony: `CONDCOMP_FORCE_SCALAR`,
//!   AVX2 and NEON all agree by construction.
//! - **Against the f32 oracles the kernels are sign-agreement tier.** The
//!   quantization error per dot product is bounded but not zero; the
//!   registry declares `EquivalenceTier::SignAgree` for the value contract
//!   (see `condcomp::registry`), and the property suites pin the
//!   round-trip error bound `|dequant(q) − x| ≤ scale / 2` per element.
//!
//! The AVX2 path sign-extends 16 codes to i16 (`_mm256_cvtepi8_epi16`) and
//! uses `_mm256_madd_epi16` — pairwise i16 products summed into i32 lanes;
//! products of sign-extended i8 can never saturate the i16 multiply. The
//! NEON path widens with `vmull_s8` and folds with `vpadalq_s16`.

use super::lowrank::LowRank;
use super::matrix::Mat;
use super::simd::SimdCaps;
use crate::exec::ExecCtx;
use crate::parallel::{chunk_rows, par_row_chunks, Parallelism};

/// Codes consumed per i8-dot loop iteration (one 128-bit lane of i8s).
const QDOT_STEP: usize = 16;

/// Quantize one row: `dst[i] = round(src[i] · 127 / max_abs)` clamped to
/// `[-127, 127]`; returns the per-row scale `max_abs / 127` (so
/// `src[i] ≈ dst[i] · scale`). An all-zero (or empty) row stores all-zero
/// codes and returns scale `0.0`.
pub fn quantize_row_into(src: &[f32], dst: &mut [i8]) -> f32 {
    debug_assert_eq!(src.len(), dst.len());
    let max_abs = src.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    if max_abs == 0.0 {
        dst.fill(0);
        return 0.0;
    }
    let inv = 127.0 / max_abs;
    for (d, &x) in dst.iter_mut().zip(src) {
        *d = (x * inv).round().clamp(-127.0, 127.0) as i8;
    }
    max_abs / 127.0
}

/// Scalar i8 dot product — exact, and therefore bit-identical to every
/// vector path below regardless of accumulation order.
fn dot_i8_scalar(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0i32;
    for (&x, &y) in a.iter().zip(b) {
        s += x as i32 * y as i32;
    }
    s
}

/// i8 dot with 16-code AVX2 steps: sign-extend to i16, `madd` pairs into
/// i32 lanes, reduce, scalar tail.
///
/// # Safety
/// Caller must ensure AVX2 is available on the running CPU.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2(a: &[i8], b: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let split = (n / QDOT_STEP) * QDOT_STEP;
    let mut acc = _mm256_setzero_si256();
    let mut i = 0;
    while i < split {
        let va = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
        let vb = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
        let wa = _mm256_cvtepi8_epi16(va);
        let wb = _mm256_cvtepi8_epi16(vb);
        // i16 products of sign-extended i8s are ≤ 127² — no saturation, and
        // each madd lane adds at most 2·16129 to an exact i32 accumulator.
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(wa, wb));
        i += QDOT_STEP;
    }
    let mut lanes = [0i32; 8];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
    let mut s: i32 = lanes.iter().sum();
    for (&x, &y) in a[split..].iter().zip(&b[split..]) {
        s += x as i32 * y as i32;
    }
    s
}

/// i8 dot with 16-code NEON steps: widen with `vmull_s8`, fold with
/// `vpadalq_s16`, reduce, scalar tail.
///
/// # Safety
/// Caller must ensure NEON is available on the running CPU.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_i8_neon(a: &[i8], b: &[i8]) -> i32 {
    use std::arch::aarch64::*;
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let split = (n / QDOT_STEP) * QDOT_STEP;
    let mut acc = vdupq_n_s32(0);
    let mut i = 0;
    while i < split {
        let va = vld1q_s8(a.as_ptr().add(i));
        let vb = vld1q_s8(b.as_ptr().add(i));
        let lo = vmull_s8(vget_low_s8(va), vget_low_s8(vb));
        let hi = vmull_s8(vget_high_s8(va), vget_high_s8(vb));
        acc = vpadalq_s16(acc, lo);
        acc = vpadalq_s16(acc, hi);
        i += QDOT_STEP;
    }
    let mut s = vaddvq_s32(acc);
    for (&x, &y) in a[split..].iter().zip(&b[split..]) {
        s += x as i32 * y as i32;
    }
    s
}

/// Exact i8 dot product — the `dense_i8` / `masked_i8` inner kernel. Every
/// ISA path computes the same integer (exact arithmetic is associative).
#[inline]
pub fn dot_i8(caps: SimdCaps, a: &[i8], b: &[i8]) -> i32 {
    #[cfg(target_arch = "x86_64")]
    if caps.use_avx2() {
        // SAFETY: use_avx2() gates on runtime AVX2 detection.
        return unsafe { dot_i8_avx2(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if caps.use_neon() {
        // SAFETY: use_neon() gates on runtime NEON detection.
        return unsafe { dot_i8_neon(a, b) };
    }
    let _ = caps;
    dot_i8_scalar(a, b)
}

/// A row-major matrix quantized to i8 with one f32 scale per row:
/// `original[r, c] ≈ q[r, c] · scale[r]`.
#[derive(Clone, Debug)]
pub struct QuantizedMat {
    rows: usize,
    cols: usize,
    q: Vec<i8>,
    scales: Vec<f32>,
}

impl QuantizedMat {
    /// Quantize a dense matrix row by row (symmetric, per-row scales).
    pub fn quantize(m: &Mat) -> QuantizedMat {
        let (rows, cols) = m.shape();
        let mut q = vec![0i8; rows * cols];
        let mut scales = vec![0.0f32; rows];
        if cols > 0 {
            for ((r, dst), scale) in q.chunks_exact_mut(cols).enumerate().zip(scales.iter_mut()) {
                *scale = quantize_row_into(m.row(r), dst);
            }
        }
        QuantizedMat { rows, cols, q, scales }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Borrow row `r`'s codes.
    #[inline]
    pub fn row(&self, r: usize) -> &[i8] {
        debug_assert!(r < self.rows);
        &self.q[r * self.cols..(r + 1) * self.cols]
    }

    /// Row `r`'s scale (`0.0` for an all-zero row).
    #[inline]
    pub fn scale(&self, r: usize) -> f32 {
        self.scales[r]
    }

    #[inline]
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Materialize `q[r, c] · scale[r]` (tests, diagnostics).
    pub fn dequantize(&self) -> Mat {
        Mat::from_fn(self.rows, self.cols, |r, c| {
            self.q[r * self.cols + c] as f32 * self.scales[r]
        })
    }
}

/// A layer prepared for int8 conditional execution: quantized transposed
/// weights (one scale per output unit) + f32 bias. The arithmetic mirror of
/// [`crate::condcomp::MaskedLayer`], built from its already-transposed
/// weight matrix.
#[derive(Clone, Debug)]
pub struct QuantizedLayer {
    /// Quantized `Wᵀ`: `h × d`, row `j` is output unit `j`'s weights.
    pub wt: QuantizedMat,
    pub bias: Vec<f32>,
}

impl QuantizedLayer {
    /// Quantize from the transposed weight matrix (`h × d`, as stored by
    /// `MaskedLayer::wt`) and its bias.
    pub fn new(wt: &Mat, bias: &[f32]) -> QuantizedLayer {
        assert_eq!(wt.rows(), bias.len(), "bias length != output dim");
        QuantizedLayer { wt: QuantizedMat::quantize(wt), bias: bias.to_vec() }
    }

    pub fn in_dim(&self) -> usize {
        self.wt.cols()
    }

    pub fn out_dim(&self) -> usize {
        self.wt.rows()
    }

    fn check_shapes(&self, a: &Mat, mask: &Mat, out: &Mat) {
        let (n, d) = a.shape();
        let h = self.out_dim();
        assert_eq!(d, self.in_dim(), "input dim mismatch");
        assert_eq!(mask.shape(), (n, h), "mask shape mismatch");
        assert_eq!(out.shape(), (n, h), "output shape mismatch");
    }

    /// One output row of the int8 path. `qx` must hold the row's quantized
    /// input (scale `sx`). With `compute_all` every dot product runs and the
    /// mask only gates the output (`dense_i8`: count is `h`); without it,
    /// dead entries skip the dot entirely (`masked_i8`: count is the live
    /// entries). Either way the output function is `σ(a·W + b) ⊙ S` in
    /// quantized arithmetic.
    #[inline]
    fn row_i8(
        &self,
        caps: SimdCaps,
        qx: &[i8],
        sx: f32,
        mrow: &[f32],
        orow: &mut [f32],
        compute_all: bool,
    ) -> usize {
        let mut computed = 0usize;
        for (j, out) in orow.iter_mut().enumerate() {
            let live = mrow[j] != 0.0;
            if compute_all || live {
                let acc = dot_i8(caps, qx, self.wt.row(j));
                let z = acc as f32 * (sx * self.wt.scale(j)) + self.bias[j];
                *out = if z > 0.0 && live { z } else { 0.0 };
                computed += 1;
            } else {
                *out = 0.0;
            }
        }
        computed
    }

    /// Serial int8 forward into a caller-owned buffer (overwritten, not
    /// accumulated). Each input row is quantized once, then consumed by all
    /// its dot products. Returns the number of dot products computed.
    pub fn forward_i8_into(
        &self,
        caps: SimdCaps,
        a: &Mat,
        mask: &Mat,
        out: &mut Mat,
        compute_all: bool,
    ) -> usize {
        self.check_shapes(a, mask, out);
        let n = a.rows();
        let mut qx = vec![0i8; self.in_dim()];
        let mut computed = 0usize;
        for i in 0..n {
            let sx = quantize_row_into(a.row(i), &mut qx);
            computed += self.row_i8(caps, &qx, sx, mask.row(i), out.row_mut(i), compute_all);
        }
        computed
    }

    /// Parallel [`Self::forward_i8_into`] on an execution target: batch rows
    /// sharded across workers, per-shard counts summed in shard order. Rows
    /// are quantized independently and integer accumulation is exact, so
    /// output and count are bit-identical to the serial kernel for any
    /// thread count or lease width.
    pub fn forward_i8_par<P: Parallelism>(
        &self,
        caps: SimdCaps,
        a: &Mat,
        mask: &Mat,
        out: &mut Mat,
        compute_all: bool,
        par: &P,
    ) -> usize {
        self.check_shapes(a, mask, out);
        let n = a.rows();
        let h = self.out_dim();
        if par.width() == 1 || n < 2 || h == 0 {
            return self.forward_i8_into(caps, a, mask, out, compute_all);
        }
        let rows_per = chunk_rows(n, par.width(), 1);
        let counts = par_row_chunks(par, out, rows_per, |row0, band| {
            let rows = band.len() / h;
            let mut qx = vec![0i8; self.in_dim()];
            let mut computed = 0usize;
            for i in 0..rows {
                let sx = quantize_row_into(a.row(row0 + i), &mut qx);
                computed += self.row_i8(
                    caps,
                    &qx,
                    sx,
                    mask.row(row0 + i),
                    &mut band[i * h..(i + 1) * h],
                    compute_all,
                );
            }
            computed
        });
        counts.iter().sum()
    }

    /// [`Self::forward_i8_par`] through an execution context: chunked by the
    /// ctx's lease width — the `dense_i8` / `masked_i8` registry entry point.
    pub fn forward_i8_ctx(
        &self,
        caps: SimdCaps,
        a: &Mat,
        mask: &Mat,
        out: &mut Mat,
        compute_all: bool,
        ctx: &mut ExecCtx<'_>,
    ) -> usize {
        self.forward_i8_par(caps, a, mask, out, compute_all, ctx.lease())
    }
}

/// Int8-quantized low-rank factors for the sign estimator: the estimator
/// only needs the **sign** of `a·U·V + b`, so aggressive quantization of
/// both stages costs almost no mask accuracy (the bet this module exists to
/// cash). Factors are stored transposed so each stage is contiguous dots.
#[derive(Clone, Debug)]
pub struct QuantizedLowRank {
    /// Quantized `Uᵀ`: `k × d`, row `p` is factor direction `p`.
    pub ut: QuantizedMat,
    /// Quantized `Vᵀ`: `h × k`, row `j` is output unit `j`'s mixing weights.
    pub vt: QuantizedMat,
}

impl QuantizedLowRank {
    /// Quantize an f32 factorization (both stages, per-row scales).
    pub fn quantize(lr: &LowRank) -> QuantizedLowRank {
        QuantizedLowRank {
            ut: QuantizedMat::quantize(&lr.u.transpose()),
            vt: QuantizedMat::quantize(&lr.v.transpose()),
        }
    }

    pub fn rank(&self) -> usize {
        self.ut.rows()
    }

    pub fn in_dim(&self) -> usize {
        self.ut.cols()
    }

    pub fn out_dim(&self) -> usize {
        self.vt.rows()
    }

    /// One row of the quantized pre-activation estimate `x·U·V` (no layer
    /// bias — the caller adds it before thresholding). Scratch: `qx` holds
    /// `in_dim` codes, `tmp`/`qt` hold `rank` f32s/codes; `out` receives
    /// `out_dim` values. The intermediate `x·U` is re-quantized per row
    /// (dynamic, like the activations), so both stages run on i8 dots.
    /// Deterministic: depends only on this row's data, never on sharding.
    pub fn preact_row_into(
        &self,
        caps: SimdCaps,
        x: &[f32],
        qx: &mut [i8],
        tmp: &mut [f32],
        qt: &mut [i8],
        out: &mut [f32],
    ) {
        let k = self.rank();
        debug_assert_eq!(x.len(), self.in_dim());
        debug_assert!(qx.len() == x.len() && tmp.len() >= k && qt.len() >= k);
        debug_assert_eq!(out.len(), self.out_dim());
        let sx = quantize_row_into(x, qx);
        for (p, t) in tmp[..k].iter_mut().enumerate() {
            *t = dot_i8(caps, qx, self.ut.row(p)) as f32 * (sx * self.ut.scale(p));
        }
        let st = quantize_row_into(&tmp[..k], &mut qt[..k]);
        for (j, o) in out.iter_mut().enumerate() {
            *o = dot_i8(caps, &qt[..k], self.vt.row(j)) as f32 * (st * self.vt.scale(j));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecCtx;
    use crate::parallel::ThreadPool;
    use crate::util::proptest::property;
    use crate::util::Pcg32;

    /// Round-trip bound: `|dequant − x| ≤ scale / 2` per element (half a
    /// quantization step), and the scale is exactly `max_abs / 127`.
    #[test]
    fn quantize_round_trip_error_is_bounded_by_half_a_step() {
        property("|dequant - x| <= scale/2", 48, |rng| {
            let n = rng.index(200) + 1;
            let src: Vec<f32> = (0..n).map(|_| rng.uniform_in(-3.0, 3.0)).collect();
            let mut q = vec![0i8; n];
            let scale = quantize_row_into(&src, &mut q);
            let max_abs = src.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            assert_eq!(scale, max_abs / 127.0, "scale is exactly max_abs/127");
            let bound = scale * 0.5 + 1e-6;
            for (&code, &x) in q.iter().zip(&src) {
                assert!((-127..=127).contains(&(code as i32)));
                let back = code as f32 * scale;
                assert!(
                    (back - x).abs() <= bound,
                    "x={x} code={code} back={back} scale={scale}"
                );
            }
        });
    }

    #[test]
    fn all_zero_rows_quantize_to_zero_scale_and_codes() {
        let mut q = vec![7i8; 5];
        assert_eq!(quantize_row_into(&[0.0; 5], &mut q), 0.0);
        assert!(q.iter().all(|&c| c == 0));
        // Empty rows are fine too.
        assert_eq!(quantize_row_into(&[], &mut []), 0.0);
        // And a QuantizedMat with an all-zero row dequantizes to zeros.
        let m = Mat::from_vec(2, 3, vec![0.0, 0.0, 0.0, 1.0, -2.0, 0.5]);
        let qm = QuantizedMat::quantize(&m);
        assert_eq!(qm.scale(0), 0.0);
        assert!(qm.row(0).iter().all(|&c| c == 0));
        assert!(qm.scale(1) > 0.0);
        assert!(qm.dequantize().row(0).iter().all(|&v| v == 0.0));
    }

    /// The i8 dot is exact: it equals a wide-integer reference on every ISA
    /// path, including tail-only and empty inputs.
    #[test]
    fn dot_i8_is_exact_on_every_isa_path() {
        let native = SimdCaps::get();
        let scalar = SimdCaps::scalar();
        property("dot_i8 == i64 reference", 64, |rng| {
            let n = rng.index(200);
            let a: Vec<i8> = (0..n).map(|_| (rng.index(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> = (0..n).map(|_| (rng.index(255) as i32 - 127) as i8).collect();
            let want: i64 = a.iter().zip(&b).map(|(&x, &y)| x as i64 * y as i64).sum();
            assert_eq!(dot_i8(native, &a, &b) as i64, want, "native n={n}");
            assert_eq!(dot_i8(scalar, &a, &b) as i64, want, "scalar n={n}");
        });
        assert_eq!(dot_i8(native, &[], &[]), 0);
        // 15 codes: below one QDOT_STEP, pure tail.
        let x = [3i8; 15];
        let y = [-2i8; 15];
        assert_eq!(dot_i8(native, &x, &y), -90);
        assert_eq!(dot_i8(scalar, &x, &y), -90);
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// Determinism contract: parallel/ctx runs of the i8 forward are
    /// bit-identical (output and count) to the serial kernel at threads
    /// {1, 2, 7} × lease widths, for both the dense and masked forms, under
    /// both the native and forced-scalar caps.
    #[test]
    fn forward_i8_parallel_is_bit_identical_to_serial() {
        let mut rng = Pcg32::seeded(0x18A);
        let (n, d, h) = (37, 45, 19);
        let a = Mat::randn(n, d, 1.0, &mut rng);
        let w = Mat::randn(d, h, 1.0, &mut rng);
        let wt = w.transpose();
        let b: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
        let mask = Mat::from_fn(n, h, |_, _| if rng.bernoulli(0.4) { 1.0 } else { 0.0 });
        let layer = QuantizedLayer::new(&wt, &b);
        for caps in [SimdCaps::get(), SimdCaps::scalar()] {
            for compute_all in [true, false] {
                let mut want = Mat::full(n, h, f32::NAN);
                let want_count = layer.forward_i8_into(caps, &a, &mask, &mut want, compute_all);
                if compute_all {
                    assert_eq!(want_count, n * h);
                } else {
                    let live = mask.as_slice().iter().filter(|&&m| m != 0.0).count();
                    assert_eq!(want_count, live);
                }
                for threads in [1usize, 2, 7] {
                    let pool = ThreadPool::new(threads);
                    let mut got = Mat::full(n, h, f32::NAN);
                    let count =
                        layer.forward_i8_par(caps, &a, &mask, &mut got, compute_all, &pool);
                    assert_eq!(count, want_count, "threads={threads}");
                    assert_eq!(bits(got.as_slice()), bits(want.as_slice()), "threads={threads}");
                    for grant in [0usize, 1, threads] {
                        let mut ctx = ExecCtx::over(pool.lease(grant));
                        let mut via_ctx = Mat::full(n, h, f32::NAN);
                        let count = layer
                            .forward_i8_ctx(caps, &a, &mask, &mut via_ctx, compute_all, &mut ctx);
                        assert_eq!(count, want_count, "ctx lease {grant}");
                        assert_eq!(bits(via_ctx.as_slice()), bits(want.as_slice()));
                    }
                    assert_eq!(pool.leased(), 0);
                }
            }
        }
        // Cross-ISA: native and forced-scalar paths agree bitwise (exact
        // integer arithmetic — no mirrored-accumulator caveats needed).
        let mut native_out = Mat::full(n, h, f32::NAN);
        let mut scalar_out = Mat::full(n, h, f32::NAN);
        layer.forward_i8_into(SimdCaps::get(), &a, &mask, &mut native_out, false);
        layer.forward_i8_into(SimdCaps::scalar(), &a, &mask, &mut scalar_out, false);
        assert_eq!(bits(native_out.as_slice()), bits(scalar_out.as_slice()));
    }

    /// The int8 forward tracks the f32 masked forward: identical gating
    /// pattern (dead entries exactly zero) and values within the combined
    /// activation+weight quantization error envelope.
    #[test]
    fn forward_i8_tracks_the_float_forward() {
        use crate::condcomp::MaskedLayer;
        property("i8 forward ≈ f32 forward", 12, |rng| {
            let n = rng.index(8) + 1;
            let d = rng.index(40) + 4;
            let h = rng.index(16) + 1;
            let a = Mat::randn(n, d, 1.0, rng);
            let w = Mat::randn(d, h, 1.0, rng);
            let b: Vec<f32> = (0..h).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
            let mask = Mat::from_fn(n, h, |_, _| if rng.bernoulli(0.6) { 1.0 } else { 0.0 });
            let float = MaskedLayer::new(&w, &b);
            let quant = QuantizedLayer::new(&float.wt, &b);
            let (want, _) = float.forward_masked(&a, &mask);
            let mut got = Mat::full(n, h, f32::NAN);
            quant.forward_i8_into(SimdCaps::get(), &a, &mask, &mut got, false);
            // Error envelope: each of d products carries ~scale_x·scale_w/2
            // of rounding; use a generous multiple to keep the test stable.
            for i in 0..n {
                let ax = a.row(i).iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                for j in 0..h {
                    if mask[(i, j)] == 0.0 {
                        assert_eq!(got[(i, j)], 0.0);
                        continue;
                    }
                    let wx = quant.wt.scale(j) * 127.0;
                    let tol = (d as f32).sqrt() * ax * wx / 127.0 + 1e-3;
                    let (g, o) = (got[(i, j)], want[(i, j)]);
                    // ReLU can zero one side near the boundary; the preacts
                    // still agree within the envelope then.
                    assert!(
                        (g - o).abs() <= tol || (g.max(o)) <= tol,
                        "({i},{j}) got={g} want={o} tol={tol}"
                    );
                }
            }
        });
    }

    /// The quantized low-rank pre-activation is deterministic across ISA
    /// paths and stays close to the float factorization's apply.
    #[test]
    fn quantized_lowrank_preact_is_deterministic_and_close() {
        let mut rng = Pcg32::seeded(0x0051);
        let (d, h, k) = (24, 18, 6);
        let w = Mat::randn(d, h, 1.0, &mut rng);
        let lr = LowRank::truncate(&w, k);
        let q = QuantizedLowRank::quantize(&lr);
        assert_eq!(q.rank(), lr.rank());
        assert_eq!((q.in_dim(), q.out_dim()), (d, h));
        let x: Vec<f32> = (0..d).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let xm = Mat::from_vec(1, d, x.clone());
        let want = lr.apply(&xm);
        let mut qx = vec![0i8; d];
        let mut tmp = vec![0.0f32; lr.rank()];
        let mut qt = vec![0i8; lr.rank()];
        let mut native_out = vec![f32::NAN; h];
        let mut scalar_out = vec![f32::NAN; h];
        q.preact_row_into(SimdCaps::get(), &x, &mut qx, &mut tmp, &mut qt, &mut native_out);
        q.preact_row_into(SimdCaps::scalar(), &x, &mut qx, &mut tmp, &mut qt, &mut scalar_out);
        assert_eq!(bits(&native_out), bits(&scalar_out), "ISA paths agree bitwise");
        let scale = want.as_slice().iter().fold(0.1f32, |m, &v| m.max(v.abs()));
        for (j, (&g, &o)) in native_out.iter().zip(want.as_slice()).enumerate() {
            assert!((g - o).abs() <= scale * 0.15, "[{j}] got={g} want={o}");
        }
    }
}
