//! Row-major dense matrix of `f32`.
//!
//! Deliberately minimal: the crate needs exactly the operations a multilayer
//! perceptron and an SVD need, with explicit shapes everywhere. All indexing
//! is `(row, col)`; storage is `row * cols + col`.

use crate::util::Pcg32;

/// A dense row-major `rows × cols` matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with a constant.
    pub fn full(rows: usize, cols: usize, value: f32) -> Mat {
        Mat { rows, cols, data: vec![value; rows * cols] }
    }

    /// Identity-like matrix (ones on the main diagonal).
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from an existing row-major buffer. Panics on length mismatch.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "buffer length != rows*cols");
        Mat { rows, cols, data }
    }

    /// Build from a function of `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// i.i.d. `N(0, sigma²)` entries — the paper's weight init (§3.5).
    pub fn randn(rows: usize, cols: usize, sigma: f32, rng: &mut Pcg32) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, sigma);
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the underlying row-major buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutably borrow row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of column `c`.
    pub fn col(&self, c: usize) -> Vec<f32> {
        debug_assert!(c < self.cols);
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    /// Transpose (materialized).
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t[(c, r)] = self[(r, c)];
            }
        }
        t
    }

    /// Element-wise map into a new matrix.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// In-place element-wise map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in self.data.iter_mut() {
            *x = f(*x);
        }
    }

    /// Element-wise binary op into a new matrix. Panics on shape mismatch.
    pub fn zip(&self, other: &Mat, f: impl Fn(f32, f32) -> f32) -> Mat {
        assert_eq!(self.shape(), other.shape(), "zip shape mismatch");
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    /// `self += alpha * other`, in place.
    pub fn axpy(&mut self, alpha: f32, other: &Mat) {
        assert_eq!(self.shape(), other.shape(), "axpy shape mismatch");
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scale all entries in place.
    pub fn scale(&mut self, alpha: f32) {
        for x in self.data.iter_mut() {
            *x *= alpha;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Sum of absolute values (ℓ1; used by the activation penalty, Eq. 7).
    pub fn l1_norm(&self) -> f32 {
        self.data.iter().map(|&x| x.abs() as f64).sum::<f64>() as f32
    }

    /// Fraction of entries strictly greater than zero — the paper's
    /// activation sparsity coefficient α (§3.4).
    pub fn density(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&x| x > 0.0).count() as f32 / self.data.len() as f32
    }

    /// Extract a contiguous block of rows `[start, start+len)` as an owned
    /// copy. Hot paths that only need to *read* a row range should use
    /// [`Mat::view_rows`] instead, which borrows without copying.
    pub fn rows_slice(&self, start: usize, len: usize) -> Mat {
        assert!(start + len <= self.rows, "row slice out of bounds");
        Mat {
            rows: len,
            cols: self.cols,
            data: self.data[start * self.cols..(start + len) * self.cols].to_vec(),
        }
    }

    /// Borrow the whole matrix as a [`MatView`].
    #[inline]
    pub fn view(&self) -> MatView<'_> {
        MatView { rows: self.rows, cols: self.cols, data: &self.data }
    }

    /// Borrow rows `[start, start+len)` as a [`MatView`] — no copy. This is
    /// what the parallel estimator shards through on the serving hot path.
    #[inline]
    pub fn view_rows(&self, start: usize, len: usize) -> MatView<'_> {
        assert!(start + len <= self.rows, "row view out of bounds");
        MatView {
            rows: len,
            cols: self.cols,
            data: &self.data[start * self.cols..(start + len) * self.cols],
        }
    }

    /// Vertically stack two matrices with equal column counts.
    pub fn vstack(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "vstack column mismatch");
        let mut data = Vec::with_capacity((self.rows + other.rows) * self.cols);
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Mat { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Maximum absolute element-wise difference to another matrix.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// A borrowed row-range view into a [`Mat`]: same row-major layout, no
/// ownership, no copy. Produced by [`Mat::view`] / [`Mat::view_rows`];
/// consumed by the view-aware GEMM entry point
/// ([`crate::linalg::matmul_view_into`]) and [`crate::linalg::LowRank`]'s
/// `apply_view_into`, so parallel kernels can shard a batch without
/// materializing each shard.
#[derive(Clone, Copy, Debug)]
pub struct MatView<'a> {
    rows: usize,
    cols: usize,
    data: &'a [f32],
}

impl<'a> MatView<'a> {
    /// Wrap a row-major buffer. Panics on length mismatch.
    #[inline]
    pub fn new(rows: usize, cols: usize, data: &'a [f32]) -> MatView<'a> {
        assert_eq!(data.len(), rows * cols, "view length != rows*cols");
        MatView { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn as_slice(&self) -> &'a [f32] {
        self.data
    }

    /// Borrow row `r` of the viewed range.
    #[inline]
    pub fn row(&self, r: usize) -> &'a [f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Materialize an owned copy (tests, cold paths).
    pub fn to_mat(&self) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.to_vec() }
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;

    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols, "index ({r},{c}) out of {:?}", self.shape());
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{arb_shape, property};

    #[test]
    fn construction_and_indexing() {
        let m = Mat::from_fn(2, 3, |r, c| (r * 3 + c) as f32);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(m.col(2), vec![2.0, 5.0]);
    }

    #[test]
    fn transpose_involution() {
        property("transpose twice is identity", 32, |rng| {
            let (r, c) = arb_shape(rng, 8);
            let m = Mat::randn(r, c, 1.0, rng);
            assert_eq!(m.transpose().transpose(), m);
        });
    }

    #[test]
    fn eye_diagonal() {
        let i = Mat::eye(4);
        for r in 0..4 {
            for c in 0..4 {
                assert_eq!(i[(r, c)], if r == c { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Mat::full(2, 2, 1.0);
        let b = Mat::full(2, 2, 3.0);
        a.axpy(2.0, &b);
        assert_eq!(a, Mat::full(2, 2, 7.0));
        a.scale(0.5);
        assert_eq!(a, Mat::full(2, 2, 3.5));
    }

    #[test]
    fn norms() {
        let m = Mat::from_vec(1, 3, vec![3.0, -4.0, 0.0]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-6);
        assert!((m.l1_norm() - 7.0).abs() < 1e-6);
    }

    #[test]
    fn density_counts_strictly_positive() {
        let m = Mat::from_vec(1, 4, vec![1.0, 0.0, -2.0, 3.0]);
        assert!((m.density() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn rows_slice_and_vstack_roundtrip() {
        property("vstack of split halves is identity", 32, |rng| {
            let (r, c) = arb_shape(rng, 8);
            let m = Mat::randn(r + 1, c, 1.0, rng);
            let top = m.rows_slice(0, 1);
            let bot = m.rows_slice(1, r);
            assert_eq!(top.vstack(&bot), m);
        });
    }

    #[test]
    #[should_panic(expected = "buffer length")]
    fn from_vec_checks_length() {
        let _ = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn view_rows_matches_owned_slice_without_copying() {
        property("view_rows == rows_slice", 32, |rng| {
            let (r, c) = arb_shape(rng, 8);
            let m = Mat::randn(r + 2, c, 1.0, rng);
            let start = rng.index(r + 1);
            let len = rng.index(r + 2 - start) + 1;
            let view = m.view_rows(start, len);
            assert_eq!(view.shape(), (len, c));
            assert_eq!(view.to_mat(), m.rows_slice(start, len));
            for i in 0..len {
                assert_eq!(view.row(i), m.row(start + i));
            }
            // The view borrows the parent's storage — same address, no copy.
            assert_eq!(view.as_slice().as_ptr(), m.row(start).as_ptr());
        });
    }

    #[test]
    #[should_panic(expected = "row view out of bounds")]
    fn view_rows_bounds_checked() {
        let m = Mat::zeros(3, 2);
        let _ = m.view_rows(2, 2);
    }
}
