//! Truncated low-rank factorization `W ≈ U·V` (paper §3.2).
//!
//! Convention follows the paper exactly: from the thin SVD
//! `W = U_full · diag(s) · Vᵀ_full`, the rank-`r` factors are
//! `U = U_r` (first r left vectors) and `V = Σ_r · V_rᵀ`, so the estimator
//! computes `a·U` first (`h1×r`), then `(a·U)·V` (`r×h2`), which is cheaper
//! than `a·W` whenever `r < h1·h2 / (h1 + h2)`.
//!
//! Two construction paths:
//! - [`LowRank::from_svd`] / [`LowRank::truncate`] — exact truncated SVD
//!   (Eckart–Young optimal), the paper's per-epoch refresh.
//! - [`LowRank::randomized`] — Halko-style randomized range finder, `O(m·n·r)`;
//!   implements the paper's future-work "online approach to the low-rank
//!   approximation" at a fraction of the refresh cost.

use super::gemm::{matmul, matmul_into, matmul_view_into};
use super::matrix::{Mat, MatView};
use super::svd::Svd;
use crate::util::Pcg32;

/// A rank-`k` factorization `W ≈ U·V`, `U: d×k`, `V: k×h`.
#[derive(Clone, Debug)]
pub struct LowRank {
    pub u: Mat,
    pub v: Mat,
}

impl LowRank {
    /// Truncate an existing SVD to rank `r` (clamped to the available rank).
    pub fn from_svd(svd: &Svd, r: usize) -> LowRank {
        let r = r.clamp(1, svd.rank());
        let (m, n) = (svd.u.rows(), svd.vt.cols());
        let mut u = Mat::zeros(m, r);
        for i in 0..m {
            let src = svd.u.row(i);
            u.row_mut(i).copy_from_slice(&src[..r]);
        }
        let mut v = Mat::zeros(r, n);
        for p in 0..r {
            let sp = svd.s[p];
            let src = svd.vt.row(p);
            let dst = v.row_mut(p);
            for j in 0..n {
                dst[j] = sp * src[j];
            }
        }
        LowRank { u, v }
    }

    /// Exact rank-`r` truncated SVD of `w`.
    pub fn truncate(w: &Mat, r: usize) -> LowRank {
        LowRank::from_svd(&Svd::compute(w), r)
    }

    /// Randomized rank-`r` approximation with `oversample` extra probe
    /// directions (Halko, Martinsson & Tropp 2011): `Y = W·Ω`, orthonormalize
    /// `Q = orth(Y)`, project `B = Qᵀ·W`, take the exact SVD of the small `B`,
    /// and lift: `W ≈ (Q·U_B)·(Σ_B·V_Bᵀ)`.
    pub fn randomized(w: &Mat, r: usize, oversample: usize, rng: &mut Pcg32) -> LowRank {
        let (m, n) = w.shape();
        let r = r.clamp(1, m.min(n));
        let l = (r + oversample).min(m.min(n));
        let omega = Mat::randn(n, l, 1.0, rng);
        let y = matmul(w, &omega); // m×l
        let q = orthonormalize_cols(&y); // m×l
        let b = matmul(&q.transpose(), w); // l×n
        let svd_b = Svd::compute(&b);
        let small = LowRank::from_svd(&svd_b, r);
        LowRank { u: matmul(&q, &small.u), v: small.v }
    }

    /// Rank of the factorization.
    pub fn rank(&self) -> usize {
        self.u.cols()
    }

    /// Materialize the product `U·V` (testing / diagnostics).
    pub fn to_dense(&self) -> Mat {
        matmul(&self.u, &self.v)
    }

    /// `a · U · V` computed in the cheap order (`a·U` first).
    pub fn apply(&self, a: &Mat) -> Mat {
        matmul(&matmul(a, &self.u), &self.v)
    }

    /// `apply` into preallocated intermediate and output buffers (serving hot
    /// path; `tmp` must be `a.rows × rank`, `out` must be `a.rows × h`).
    pub fn apply_into(&self, a: &Mat, tmp: &mut Mat, out: &mut Mat) {
        matmul_into(a, &self.u, tmp);
        matmul_into(tmp, &self.v, out);
    }

    /// [`Self::apply_into`] over a borrowed row-range view: reads the shard
    /// in place (no copy) and writes into caller scratch — `tmp` row-major
    /// `a.rows × rank`, `out` row-major `a.rows × h`. Row-sharded callers
    /// (the parallel estimator) get results bit-identical to [`Self::apply`]
    /// on the full input, because the view GEMM keeps the serial kernel's
    /// accumulation order and rows are independent.
    pub fn apply_view_into(&self, a: MatView<'_>, tmp: &mut [f32], out: &mut [f32]) {
        matmul_view_into(a, &self.u, tmp);
        matmul_view_into(MatView::new(a.rows(), self.rank(), tmp), &self.v, out);
    }

    /// [`Self::apply_view_into`] restricted to the leading `r` factor
    /// columns/rows: computes `a · U[:, :r] · V[:r, :]` — the best rank-`r`
    /// truncation of the stored factorization. At `r == rank()` this
    /// delegates to [`Self::apply_view_into`] and is bit-identical to it;
    /// below full rank it trades approximation quality for an `r/rank`
    /// reduction in estimator FLOPs (the quality-elastic serving path).
    /// `tmp` must hold `a.rows × r`, `out` must hold `a.rows × h`.
    pub fn apply_view_rank_into(&self, a: MatView<'_>, r: usize, tmp: &mut [f32], out: &mut [f32]) {
        let full = self.rank();
        let r = r.clamp(1, full);
        if r == full {
            self.apply_view_into(a, tmp, out);
            return;
        }
        let (rows, k) = (a.rows(), a.cols());
        let h = self.v.cols();
        assert_eq!(k, self.u.rows());
        assert!(tmp.len() >= rows * r && out.len() >= rows * h);
        // Stage 1: tmp = a · U[:, :r]. U's leading r columns are strided in
        // the row-major factor, so walk rows of U and accumulate.
        tmp[..rows * r].fill(0.0);
        for i in 0..rows {
            let arow = a.row(i);
            let trow = &mut tmp[i * r..(i + 1) * r];
            for (p, &aip) in arow.iter().enumerate() {
                if aip == 0.0 {
                    continue;
                }
                let urow = &self.u.row(p)[..r];
                for (t, &u) in trow.iter_mut().zip(urow) {
                    *t += aip * u;
                }
            }
        }
        // Stage 2: out = tmp · V[:r, :].
        out[..rows * h].fill(0.0);
        for i in 0..rows {
            let trow = &tmp[i * r..(i + 1) * r];
            let orow = &mut out[i * h..(i + 1) * h];
            for (p, &tip) in trow.iter().enumerate() {
                if tip == 0.0 {
                    continue;
                }
                let vrow = self.v.row(p);
                for (o, &v) in orow.iter_mut().zip(vrow) {
                    *o += tip * v;
                }
            }
        }
    }

    /// Approximation error `‖W − U·V‖_F / ‖W‖_F`.
    pub fn rel_error(&self, w: &Mat) -> f32 {
        let diff = w.zip(&self.to_dense(), |a, b| a - b);
        let denom = w.fro_norm();
        if denom == 0.0 { 0.0 } else { diff.fro_norm() / denom }
    }
}

/// Modified Gram–Schmidt with one re-orthogonalization pass; returns a matrix
/// with orthonormal columns spanning the input's column space. Zero columns
/// (to numerical tolerance) are replaced by zeros and do not contribute.
pub fn orthonormalize_cols(a: &Mat) -> Mat {
    let (m, l) = a.shape();
    let mut q = a.transpose(); // work row-major over columns: q.row(j) = col j
    for j in 0..l {
        // Re-orthogonalize twice against previous columns ("twice is enough").
        for _pass in 0..2 {
            for p in 0..j {
                let dot: f64 = {
                    let (qp, qj) = (q.row(p), q.row(j));
                    qp.iter().zip(qj).map(|(&x, &y)| x as f64 * y as f64).sum()
                };
                let proj = dot as f32;
                let qp = q.row(p).to_vec();
                let qj = q.row_mut(j);
                for i in 0..m {
                    qj[i] -= proj * qp[i];
                }
            }
        }
        let norm: f64 = q.row(j).iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
        let norm = norm.sqrt() as f32;
        if norm > 1e-7 {
            let inv = 1.0 / norm;
            for x in q.row_mut(j) {
                *x *= inv;
            }
        } else {
            q.row_mut(j).fill(0.0);
        }
    }
    q.transpose()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_naive;
    use crate::util::proptest::property;

    /// Build a matrix with an exponentially decaying spectrum — the shape the
    /// paper assumes for trained nets ("highly redundant" weights, §2.1).
    fn decaying_matrix(m: usize, n: usize, decay: f32, rng: &mut Pcg32) -> Mat {
        let r = m.min(n);
        let u = orthonormalize_cols(&Mat::randn(m, r, 1.0, rng));
        let v = orthonormalize_cols(&Mat::randn(n, r, 1.0, rng));
        let mut scaled = Mat::zeros(m, r);
        for i in 0..m {
            for p in 0..r {
                scaled[(i, p)] = u[(i, p)] * decay.powi(p as i32);
            }
        }
        matmul_naive(&scaled, &v.transpose())
    }

    #[test]
    fn full_rank_truncation_is_exact() {
        property("rank=min(m,n) reconstructs", 10, |rng| {
            let m = rng.index(12) + 2;
            let n = rng.index(12) + 2;
            let w = Mat::randn(m, n, 1.0, rng);
            let lr = LowRank::truncate(&w, m.min(n));
            assert!(lr.to_dense().max_abs_diff(&w) < 1e-3);
        });
    }

    #[test]
    fn truncation_error_decreases_with_rank() {
        let mut rng = Pcg32::seeded(31);
        let w = decaying_matrix(20, 16, 0.7, &mut rng);
        let mut last = f32::INFINITY;
        for r in [1, 2, 4, 8, 16] {
            let e = LowRank::truncate(&w, r).rel_error(&w);
            assert!(e <= last + 1e-5, "rank {r}: error {e} > previous {last}");
            last = e;
        }
        assert!(last < 1e-3, "full-rank error should vanish, got {last}");
    }

    #[test]
    fn eckart_young_beats_random_projection() {
        // The SVD truncation must be no worse than any same-rank baseline.
        let mut rng = Pcg32::seeded(7);
        let w = decaying_matrix(24, 18, 0.8, &mut rng);
        let r = 4;
        let svd_err = LowRank::truncate(&w, r).rel_error(&w);
        let rand_err = LowRank::randomized(&w, r, 0, &mut rng).rel_error(&w);
        assert!(svd_err <= rand_err + 1e-4, "svd {svd_err} vs randomized {rand_err}");
    }

    #[test]
    fn randomized_with_oversampling_is_close_to_optimal() {
        let mut rng = Pcg32::seeded(13);
        let w = decaying_matrix(30, 24, 0.6, &mut rng);
        let r = 5;
        let opt = LowRank::truncate(&w, r).rel_error(&w);
        let rnd = LowRank::randomized(&w, r, 8, &mut rng).rel_error(&w);
        assert!(rnd <= opt * 2.0 + 1e-3, "randomized {rnd} vs optimal {opt}");
    }

    #[test]
    fn apply_matches_dense_product_order() {
        property("a·(UV) == (a·U)·V", 16, |rng| {
            let d = rng.index(10) + 2;
            let h = rng.index(10) + 2;
            let w = Mat::randn(d, h, 1.0, rng);
            let a = Mat::randn(3, d, 1.0, rng);
            let lr = LowRank::truncate(&w, d.min(h));
            let got = lr.apply(&a);
            let want = matmul_naive(&a, &lr.to_dense());
            assert!(got.max_abs_diff(&want) < 1e-3);
        });
    }

    #[test]
    fn apply_into_matches_apply() {
        let mut rng = Pcg32::seeded(5);
        let w = Mat::randn(8, 6, 1.0, &mut rng);
        let a = Mat::randn(4, 8, 1.0, &mut rng);
        let lr = LowRank::truncate(&w, 3);
        let mut tmp = Mat::zeros(4, 3);
        let mut out = Mat::zeros(4, 6);
        lr.apply_into(&a, &mut tmp, &mut out);
        assert!(out.max_abs_diff(&lr.apply(&a)) < 1e-5);
    }

    #[test]
    fn apply_view_into_is_bit_identical_to_apply_rows() {
        let mut rng = Pcg32::seeded(19);
        let w = Mat::randn(12, 9, 1.0, &mut rng);
        let a = Mat::randn(10, 12, 1.0, &mut rng);
        let lr = LowRank::truncate(&w, 4);
        let full = lr.apply(&a);
        for (start, rows) in [(0usize, 10usize), (3, 4), (9, 1)] {
            let mut tmp = vec![f32::NAN; rows * lr.rank()];
            let mut out = vec![f32::NAN; rows * 9];
            lr.apply_view_into(a.view_rows(start, rows), &mut tmp, &mut out);
            assert_eq!(&out[..], &full.as_slice()[start * 9..(start + rows) * 9]);
        }
    }

    #[test]
    fn apply_view_rank_into_full_rank_is_bit_identical() {
        let mut rng = Pcg32::seeded(23);
        let w = Mat::randn(12, 9, 1.0, &mut rng);
        let a = Mat::randn(6, 12, 1.0, &mut rng);
        let lr = LowRank::truncate(&w, 5);
        let mut tmp = vec![f32::NAN; 6 * 5];
        let mut want = vec![f32::NAN; 6 * 9];
        lr.apply_view_into(a.view_rows(0, 6), &mut tmp, &mut want);
        let mut tmp2 = vec![f32::NAN; 6 * 5];
        let mut got = vec![f32::NAN; 6 * 9];
        lr.apply_view_rank_into(a.view_rows(0, 6), lr.rank(), &mut tmp2, &mut got);
        assert_eq!(got, want, "full-rank truncation must stay bit-identical");
        // Over-asking clamps to full rank and stays on the exact path.
        got.fill(f32::NAN);
        lr.apply_view_rank_into(a.view_rows(0, 6), 100, &mut tmp2, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn apply_view_rank_into_truncates_to_leading_factors() {
        let mut rng = Pcg32::seeded(29);
        let w = decaying_matrix(16, 10, 0.6, &mut rng);
        let a = Mat::randn(4, 16, 1.0, &mut rng);
        let lr = LowRank::truncate(&w, 8);
        for r in [1usize, 3, 6] {
            // Reference: materialize U[:, :r] · V[:r, :] and multiply densely.
            let mut ur = Mat::zeros(16, r);
            let mut vr = Mat::zeros(r, 10);
            for i in 0..16 {
                ur.row_mut(i).copy_from_slice(&lr.u.row(i)[..r]);
            }
            for p in 0..r {
                vr.row_mut(p).copy_from_slice(lr.v.row(p));
            }
            let want = matmul_naive(&a, &matmul_naive(&ur, &vr));
            let mut tmp = vec![f32::NAN; 4 * r];
            let mut got = vec![0.0f32; 4 * 10];
            lr.apply_view_rank_into(a.view_rows(0, 4), r, &mut tmp, &mut got);
            let mut max = 0.0f32;
            for (g, w) in got.iter().zip(want.as_slice()) {
                max = max.max((g - w).abs());
            }
            assert!(max < 1e-4, "rank {r}: max diff {max}");
        }
    }

    #[test]
    fn orthonormalize_produces_orthonormal_columns() {
        property("QtQ == I on full-rank input", 12, |rng| {
            let m = rng.index(10) + 5;
            let l = rng.index(4) + 1; // l <= 4 < 5 <= m keeps full column rank likely
            let a = Mat::randn(m, l, 1.0, rng);
            let q = orthonormalize_cols(&a);
            let g = matmul_naive(&q.transpose(), &q);
            assert!(g.max_abs_diff(&Mat::eye(l)) < 1e-4);
        });
    }

    #[test]
    fn rank_clamps() {
        let mut rng = Pcg32::seeded(3);
        let w = Mat::randn(6, 4, 1.0, &mut rng);
        let lr = LowRank::truncate(&w, 100);
        assert_eq!(lr.rank(), 4);
        let lr1 = LowRank::truncate(&w, 0);
        assert_eq!(lr1.rank(), 1);
    }
}
