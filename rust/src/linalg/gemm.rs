//! Dense matrix multiplication — the control network's hot path.
//!
//! Two implementations:
//!
//! - [`matmul_naive`] — unblocked i–k–j loop, kept as the correctness oracle.
//! - [`matmul`] / [`matmul_into`] — the same axpy loop order with K-panel
//!   blocking so a `KC × n` slab of B stays in L2 across A's rows (16 GF/s
//!   vs 11.9 GF/s unblocked, vs 1.75 GF/s for the rejected packed-dot
//!   variant on this 1-core testbed — see EXPERIMENTS.md §Perf).
//!
//! Correctness is pinned by property tests against the naive kernel.

use super::matrix::Mat;

/// Rows of A processed per block (fits a panel of A in L1/L2 alongside Bᵀ).
const MC: usize = 64;
/// Columns of B processed per block.
const NC: usize = 128;
/// Depth (shared dimension) processed per block.
const KC: usize = 256;

/// Reference triple-loop kernel. O(m·n·k); used by tests and tiny shapes.
pub fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch: {:?} x {:?}", a.shape(), b.shape());
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        for p in 0..k {
            let aip = a[(i, p)];
            if aip == 0.0 {
                continue;
            }
            let brow = b.row(p);
            let crow = c.row_mut(i);
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
    c
}

/// `C = A · B` with the blocked kernel.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// `C = A · B`, writing into a preallocated `C` (overwritten, not accumulated).
///
/// Loop order is i–k–j ("axpy" form): the inner loop walks a row of B and a
/// row of C contiguously, which LLVM auto-vectorizes into packed FMAs, and
/// zero entries of A (common under ReLU inputs) skip whole row updates.
/// K-blocking keeps a `KC × n` panel of B hot in L2 across the rows of A.
///
/// Perf note (EXPERIMENTS.md §Perf): an earlier packed-Bᵀ dot-product kernel
/// ran 3× slower on this machine — scalar dot accumulation defeats the
/// vectorizer; contiguous row FMA does not.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!(c.shape(), (a.rows(), b.cols()), "output shape mismatch");
    let (m, k) = a.shape();
    c.as_mut_slice().fill(0.0);
    let _ = (MC, NC); // block constants retained for the masked/packed paths

    let mut p0 = 0;
    while p0 < k {
        let kc = KC.min(k - p0);
        for i in 0..m {
            let arow = &a.row(i)[p0..p0 + kc];
            let crow = c.row_mut(i);
            for (pp, &aip) in arow.iter().enumerate() {
                if aip == 0.0 {
                    continue;
                }
                let brow = b.row(p0 + pp);
                axpy_row(crow, aip, brow);
            }
        }
        p0 += kc;
    }
}

/// `c += alpha * b` over contiguous slices (the vectorized inner kernel).
#[inline]
fn axpy_row(c: &mut [f32], alpha: f32, b: &[f32]) {
    debug_assert_eq!(c.len(), b.len());
    for (cj, &bj) in c.iter_mut().zip(b) {
        *cj += alpha * bj;
    }
}

/// Contiguous dot product with 4-way unrolled accumulators.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let b = i * 4;
        s0 += x[b] * y[b];
        s1 += x[b + 1] * y[b + 1];
        s2 += x[b + 2] * y[b + 2];
        s3 += x[b + 3] * y[b + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in chunks * 4..n {
        s += x[i] * y[i];
    }
    s
}

/// `y = x · W + bias` for a single row vector (serving fast path; avoids the
/// panel machinery for batch-of-one requests).
pub fn rowvec_matmul_bias(x: &[f32], w: &Mat, bias: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), w.rows(), "rowvec length mismatch");
    assert_eq!(bias.len(), w.cols(), "bias length mismatch");
    let mut y = bias.to_vec();
    for (p, &xp) in x.iter().enumerate() {
        if xp == 0.0 {
            continue;
        }
        let wrow = w.row(p);
        for (j, yj) in y.iter_mut().enumerate() {
            *yj += xp * wrow[j];
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::Pcg32;

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        let d = a.max_abs_diff(b);
        assert!(d <= tol, "max abs diff {d} > {tol}");
    }

    #[test]
    fn known_product() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = matmul(&a, &b);
        assert_eq!(c, Mat::from_vec(2, 2, vec![19.0, 22.0, 43.0, 50.0]));
    }

    #[test]
    fn blocked_matches_naive_random_shapes() {
        property("blocked == naive", 24, |rng| {
            let m = rng.index(40) + 1;
            let k = rng.index(40) + 1;
            let n = rng.index(40) + 1;
            let a = Mat::randn(m, k, 1.0, rng);
            let b = Mat::randn(k, n, 1.0, rng);
            assert_close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-4);
        });
    }

    #[test]
    fn blocked_matches_naive_block_boundary_shapes() {
        // Exercise shapes straddling the MC/NC/KC boundaries.
        let mut rng = Pcg32::seeded(17);
        for &(m, k, n) in &[(64, 256, 128), (65, 257, 129), (63, 255, 127), (1, 300, 1), (130, 1, 260)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            assert_close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-3);
        }
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Pcg32::seeded(2);
        let a = Mat::randn(7, 7, 1.0, &mut rng);
        assert_close(&matmul(&a, &Mat::eye(7)), &a, 1e-6);
        assert_close(&matmul(&Mat::eye(7), &a), &a, 1e-6);
    }

    #[test]
    fn dot_matches_reference() {
        property("unrolled dot == fold", 32, |rng| {
            let n = rng.index(100) + 1;
            let x: Vec<f32> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            let y: Vec<f32> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            let reference: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((dot(&x, &y) - reference).abs() < 1e-4);
        });
    }

    #[test]
    fn rowvec_matches_matmul() {
        property("rowvec fast path == matmul + bias", 24, |rng| {
            let d = rng.index(30) + 1;
            let h = rng.index(30) + 1;
            let x: Vec<f32> = (0..d).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            let w = Mat::randn(d, h, 1.0, rng);
            let bias: Vec<f32> = (0..h).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            let xm = Mat::from_vec(1, d, x.clone());
            let mut want = matmul(&xm, &w);
            for (j, v) in want.row_mut(0).iter_mut().enumerate() {
                *v += bias[j];
            }
            let got = rowvec_matmul_bias(&x, &w, &bias);
            for j in 0..h {
                assert!((got[j] - want[(0, j)]).abs() < 1e-4);
            }
        });
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(4, 2);
        let _ = matmul(&a, &b);
    }
}
