//! Dense matrix multiplication — the control network's hot path.
//!
//! Three implementations:
//!
//! - [`matmul_naive`] — unblocked i–k–j loop, kept as the correctness oracle.
//! - [`matmul`] / [`matmul_into`] — the same axpy loop order with K-panel
//!   blocking so a `KC × n` slab of B stays in L2 across A's rows (16 GF/s
//!   vs 11.9 GF/s unblocked, vs 1.75 GF/s for the rejected packed-dot
//!   variant on this 1-core testbed — see EXPERIMENTS.md §Perf).
//! - [`matmul_into_par`] — the blocked kernel with C's row panels (MC-row
//!   granularity, NC-column sub-blocks) sharded across an execution target
//!   (a worker pool or a [`crate::parallel::PoolLease`] slice of one).
//!   Each output row accumulates its K-contributions in exactly the serial
//!   order, so the result is bit-identical to [`matmul_into`] for any
//!   thread count or lease width.
//! - [`matmul_into_packed`] / [`matmul_into_packed_par`] /
//!   [`matmul_into_packed_ctx`] — the same kernel with each active A-block
//!   packed into a contiguous scratch slab (the `dense_packed` registry
//!   kernel). Packing is a memory-layout change only: bit-identical to
//!   [`matmul_into`] everywhere the unpacked kernel is.
//!
//! [`matmul_auto`] / [`matmul_into_auto`] pick serial vs pool-parallel from
//! the problem size; the `nn` forward/backward paths route through them.
//! [`matmul_into_ctx`] / [`matmul_into_auto_ctx`] are the
//! execution-context entry points: same kernels, chunked by the ctx's lease
//! width (the serving backends and the autotune harness route through
//! these).
//!
//! Correctness is pinned by property tests against the naive kernel, at
//! pool sizes 1, 2 and 7 for the parallel variant.

use super::matrix::{Mat, MatView};
use crate::exec::ExecCtx;
use crate::parallel::{chunk_rows, par_row_chunks, Parallelism};

/// Rows of A (and C) per parallel row panel: the unit of work sharding.
const MC: usize = 64;
/// Columns of B processed per sub-block inside a row panel (keeps a
/// `KC × NC` slab of B and an `MC × NC` slab of C resident together).
const NC: usize = 128;
/// Depth (shared dimension) processed per block.
const KC: usize = 256;

/// Below this many fused multiply-adds (`m·k·n`), pool dispatch overhead
/// beats the parallel win and the auto paths stay serial.
const PAR_MIN_MULADDS: usize = 1 << 20;

/// Reference triple-loop kernel. O(m·n·k); used by tests and tiny shapes.
pub fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch: {:?} x {:?}", a.shape(), b.shape());
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        for p in 0..k {
            let aip = a[(i, p)];
            if aip == 0.0 {
                continue;
            }
            let brow = b.row(p);
            let crow = c.row_mut(i);
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
    c
}

/// `C = A · B` with the blocked kernel.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// `C = A · B`, writing into a preallocated `C` (overwritten, not accumulated).
///
/// Loop order is i–k–j ("axpy" form): the inner loop walks a row of B and a
/// row of C contiguously, which LLVM auto-vectorizes into packed FMAs, and
/// zero entries of A (common under ReLU inputs) skip whole row updates.
/// K-blocking keeps a `KC × n` panel of B hot in L2 across the rows of A.
///
/// Perf note (EXPERIMENTS.md §Perf): an earlier packed-Bᵀ dot-product kernel
/// ran 3× slower on this machine — scalar dot accumulation defeats the
/// vectorizer; contiguous row FMA does not.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!(c.shape(), (a.rows(), b.cols()), "output shape mismatch");
    let (m, k) = a.shape();
    c.as_mut_slice().fill(0.0);

    let mut p0 = 0;
    while p0 < k {
        let kc = KC.min(k - p0);
        for i in 0..m {
            let arow = &a.row(i)[p0..p0 + kc];
            let crow = c.row_mut(i);
            for (pp, &aip) in arow.iter().enumerate() {
                if aip == 0.0 {
                    continue;
                }
                let brow = b.row(p0 + pp);
                axpy_row(crow, aip, brow);
            }
        }
        p0 += kc;
    }
}

/// `out = A · B` where `A` is a borrowed row-range [`MatView`] and `out` is
/// a row-major `a.rows × b.cols` slice (overwritten, not accumulated).
///
/// Same KC-blocked axpy loop — and therefore the same per-element
/// accumulation order — as [`matmul_into`], so computing a row range through
/// a view is bit-identical to computing the full product and reading the
/// corresponding rows. This is what lets the parallel estimator shard a
/// batch across pool workers without copying each shard
/// (`SignEstimator::mask_par`).
pub fn matmul_view_into(a: MatView<'_>, b: &Mat, out: &mut [f32]) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch: {:?} x {:?}", a.shape(), b.shape());
    let (m, k) = a.shape();
    let n = b.cols();
    assert_eq!(out.len(), m * n, "output slice length mismatch");
    out.fill(0.0);

    let mut p0 = 0;
    while p0 < k {
        let kc = KC.min(k - p0);
        for i in 0..m {
            let arow = &a.row(i)[p0..p0 + kc];
            let crow = &mut out[i * n..(i + 1) * n];
            for (pp, &aip) in arow.iter().enumerate() {
                if aip == 0.0 {
                    continue;
                }
                axpy_row(crow, aip, b.row(p0 + pp));
            }
        }
        p0 += kc;
    }
}

/// `C = A · B` on an execution target (pool or lease slice): C's rows are
/// split into MC-quantized panels, one pool job per panel. Bit-identical to
/// [`matmul_into`] — each `C[i, j]` accumulates its `K` contributions in
/// exactly the serial order (KC panels ascending, rows within a panel
/// independent), so the thread count, lease width and panel boundaries
/// cannot change a single bit of the result.
pub fn matmul_into_par<P: Parallelism>(a: &Mat, b: &Mat, c: &mut Mat, par: &P) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!(c.shape(), (a.rows(), b.cols()), "output shape mismatch");
    let (m, k) = a.shape();
    let n = b.cols();
    let width = par.width();
    if width == 1 || m < 2 || n == 0 || k == 0 {
        matmul_into(a, b, c);
        return;
    }
    // MC is the preferred row-panel quantum; when the batch is too short to
    // give every worker an MC panel (serving batches of 64–250 rows), degrade
    // to finer panels — row sharding is bit-identity-safe at any granularity,
    // and a mostly-idle pool is worse than thinner panels.
    let quantum = if m >= width * MC { MC } else { (MC / 8).max(1) };
    let rows_per = chunk_rows(m, width, quantum);
    par_row_chunks(par, c, rows_per, |row0, band| {
        gemm_row_panel(a, b, row0, band);
    });
}

/// [`matmul_into_par`] through an execution context: chunked by the ctx's
/// lease width, executed on its pool.
pub fn matmul_into_ctx(a: &Mat, b: &Mat, c: &mut Mat, ctx: &mut ExecCtx<'_>) {
    matmul_into_par(a, b, c, ctx.lease());
}

/// `C = A · B` with A's row panels **packed** into a contiguous scratch
/// slab — the `dense_packed` registry kernel's serial form.
///
/// The plain blocked kernel re-reads each row panel's `rows × KC` slice of A
/// once per NC sub-block, striding `a.cols()` floats between rows; for wide
/// inputs (`k` ≫ KC) those strides span many pages and the slice competes
/// with B's slab for cache. Packing copies the active `≤ MC × KC` A-block
/// into a contiguous slab first, so the re-reads walk one dense 64 KiB
/// region. Copying `f32`s preserves their bits and the accumulation order
/// over K is untouched (KC panels ascending, `pp` ascending inside), so the
/// result is **bit-identical** to [`matmul_into`] — packing is a memory
/// layout change, never a numeric one.
pub fn matmul_into_packed(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!(c.shape(), (a.rows(), b.cols()), "output shape mismatch");
    let mut slab = Vec::new();
    gemm_row_panel_packed(a, b, 0, c.as_mut_slice(), &mut slab);
}

/// [`matmul_into_packed`] on an execution target: the same MC-quantized row
/// sharding as [`matmul_into_par`], with each pool job packing its own A
/// blocks. Bit-identical to [`matmul_into`] for any thread count or lease
/// width, by the same argument as the unpacked kernel.
pub fn matmul_into_packed_par<P: Parallelism>(a: &Mat, b: &Mat, c: &mut Mat, par: &P) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!(c.shape(), (a.rows(), b.cols()), "output shape mismatch");
    let (m, k) = a.shape();
    let n = b.cols();
    let width = par.width();
    if width == 1 || m < 2 || n == 0 || k == 0 {
        matmul_into_packed(a, b, c);
        return;
    }
    let quantum = if m >= width * MC { MC } else { (MC / 8).max(1) };
    let rows_per = chunk_rows(m, width, quantum);
    par_row_chunks(par, c, rows_per, |row0, band| {
        // Per-job slab: pool jobs run concurrently, so the pack buffer
        // cannot be shared; its ≤ MC × KC size amortizes over the panel.
        let mut slab = Vec::new();
        gemm_row_panel_packed(a, b, row0, band, &mut slab);
    });
}

/// [`matmul_into_packed_par`] through an execution context: chunked by the
/// ctx's lease width. The serial fall-through draws its pack slab from the
/// ctx's [`crate::exec::ScratchArena`] so repeated batches reuse one buffer.
pub fn matmul_into_packed_ctx(a: &Mat, b: &Mat, c: &mut Mat, ctx: &mut ExecCtx<'_>) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!(c.shape(), (a.rows(), b.cols()), "output shape mismatch");
    let (m, k) = a.shape();
    let n = b.cols();
    if ctx.threads() == 1 || m < 2 || n == 0 || k == 0 {
        let mut slab = ctx.take_buf(MC.min(m) * KC.min(k.max(1)));
        gemm_row_panel_packed(a, b, 0, c.as_mut_slice(), &mut slab);
        ctx.put_buf(slab);
        return;
    }
    matmul_into_packed_par(a, b, c, ctx.lease());
}

/// Compute one row panel of `C = A · B` into `band`, packing each active
/// `≤ MC × kc` block of A into `slab` before streaming B over it. Iterates
/// MC-row sub-panels internally so the slab stays L2-resident however large
/// the caller's panel is. Per-element accumulation order over K is exactly
/// [`gemm_row_panel`]'s (p0 outer ascending, `pp` inner ascending), so the
/// result bits match the unpacked kernel's.
fn gemm_row_panel_packed(a: &Mat, b: &Mat, row0: usize, band: &mut [f32], slab: &mut Vec<f32>) {
    let k = a.cols();
    let n = b.cols();
    if n == 0 {
        return;
    }
    let rows = band.len() / n;
    band.fill(0.0);
    let mut p0 = 0;
    while p0 < k {
        let kc = KC.min(k - p0);
        let mut i0 = 0;
        while i0 < rows {
            let mc = MC.min(rows - i0);
            // Pack the mc × kc A-block: row i of the slab is
            // A[row0+i0+i, p0..p0+kc], bit-for-bit.
            slab.resize(mc * kc, 0.0);
            for i in 0..mc {
                slab[i * kc..(i + 1) * kc]
                    .copy_from_slice(&a.row(row0 + i0 + i)[p0..p0 + kc]);
            }
            let mut j0 = 0;
            while j0 < n {
                let nc = NC.min(n - j0);
                for i in 0..mc {
                    let arow = &slab[i * kc..(i + 1) * kc];
                    let ci = i0 + i;
                    let crow = &mut band[ci * n + j0..ci * n + j0 + nc];
                    for (pp, &aip) in arow.iter().enumerate() {
                        if aip == 0.0 {
                            continue;
                        }
                        let brow = &b.row(p0 + pp)[j0..j0 + nc];
                        axpy_row(crow, aip, brow);
                    }
                }
                j0 += nc;
            }
            i0 += mc;
        }
        p0 += kc;
    }
}

/// Compute one row panel of `C = A · B` into `band` (row-major rows of C
/// starting at `row0`). Shared by the pool jobs and the serial fallback.
fn gemm_row_panel(a: &Mat, b: &Mat, row0: usize, band: &mut [f32]) {
    let k = a.cols();
    let n = b.cols();
    let rows = band.len() / n;
    band.fill(0.0);
    let mut p0 = 0;
    while p0 < k {
        let kc = KC.min(k - p0);
        // NC-column sub-blocks keep the active B slab L2-resident while the
        // panel's rows stream over it. Per-element accumulation order over
        // the K dimension is unchanged (p0 outer, pp inner), so blocking is
        // invisible in the result bits.
        let mut j0 = 0;
        while j0 < n {
            let nc = NC.min(n - j0);
            for i in 0..rows {
                let arow = &a.row(row0 + i)[p0..p0 + kc];
                let crow = &mut band[i * n + j0..i * n + j0 + nc];
                for (pp, &aip) in arow.iter().enumerate() {
                    if aip == 0.0 {
                        continue;
                    }
                    let brow = &b.row(p0 + pp)[j0..j0 + nc];
                    axpy_row(crow, aip, brow);
                }
            }
            j0 += nc;
        }
        p0 += kc;
    }
}

/// `C = A · B` on an execution target, allocating the output.
pub fn matmul_par<P: Parallelism>(a: &Mat, b: &Mat, par: &P) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    matmul_into_par(a, b, &mut c, par);
    c
}

/// `C = A · B`, choosing serial vs global-pool parallel from the problem
/// size. This is the entry point the `nn` forward/backward paths use; small
/// products (where dispatch overhead dominates) stay serial.
pub fn matmul_into_auto(a: &Mat, b: &Mat, c: &mut Mat) {
    let work = a
        .rows()
        .saturating_mul(a.cols())
        .saturating_mul(b.cols());
    if work < PAR_MIN_MULADDS {
        matmul_into(a, b, c);
    } else {
        matmul_into_par(a, b, c, crate::parallel::global());
    }
}

/// Allocating wrapper over [`matmul_into_auto`].
pub fn matmul_auto(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    matmul_into_auto(a, b, &mut c);
    c
}

/// [`matmul_into_auto`] through an execution context: small products stay
/// serial (dispatch overhead dominates), large ones run on the ctx's lease.
pub fn matmul_into_auto_ctx(a: &Mat, b: &Mat, c: &mut Mat, ctx: &mut ExecCtx<'_>) {
    let work = a
        .rows()
        .saturating_mul(a.cols())
        .saturating_mul(b.cols());
    if work < PAR_MIN_MULADDS {
        matmul_into(a, b, c);
    } else {
        matmul_into_ctx(a, b, c, ctx);
    }
}

/// Allocating wrapper over [`matmul_into_auto_ctx`]; the output buffer comes
/// from (and should eventually return to) the ctx's arena.
pub fn matmul_auto_ctx(a: &Mat, b: &Mat, ctx: &mut ExecCtx<'_>) -> Mat {
    let mut c = Mat::from_vec(a.rows(), b.cols(), ctx.take_buf(a.rows() * b.cols()));
    matmul_into_auto_ctx(a, b, &mut c, ctx);
    c
}

/// `c += alpha * b` over contiguous slices (the vectorized inner kernel).
#[inline]
fn axpy_row(c: &mut [f32], alpha: f32, b: &[f32]) {
    debug_assert_eq!(c.len(), b.len());
    for (cj, &bj) in c.iter_mut().zip(b) {
        *cj += alpha * bj;
    }
}

/// Contiguous dot product with 4-way unrolled accumulators.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let b = i * 4;
        s0 += x[b] * y[b];
        s1 += x[b + 1] * y[b + 1];
        s2 += x[b + 2] * y[b + 2];
        s3 += x[b + 3] * y[b + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in chunks * 4..n {
        s += x[i] * y[i];
    }
    s
}

/// `y = x · W + bias` for a single row vector (serving fast path; avoids the
/// panel machinery for batch-of-one requests).
pub fn rowvec_matmul_bias(x: &[f32], w: &Mat, bias: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), w.rows(), "rowvec length mismatch");
    assert_eq!(bias.len(), w.cols(), "bias length mismatch");
    let mut y = bias.to_vec();
    for (p, &xp) in x.iter().enumerate() {
        if xp == 0.0 {
            continue;
        }
        let wrow = w.row(p);
        for (j, yj) in y.iter_mut().enumerate() {
            *yj += xp * wrow[j];
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::ThreadPool;
    use crate::util::proptest::property;
    use crate::util::Pcg32;

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        let d = a.max_abs_diff(b);
        assert!(d <= tol, "max abs diff {d} > {tol}");
    }

    #[test]
    fn known_product() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = matmul(&a, &b);
        assert_eq!(c, Mat::from_vec(2, 2, vec![19.0, 22.0, 43.0, 50.0]));
    }

    #[test]
    fn blocked_matches_naive_random_shapes() {
        property("blocked == naive", 24, |rng| {
            let m = rng.index(40) + 1;
            let k = rng.index(40) + 1;
            let n = rng.index(40) + 1;
            let a = Mat::randn(m, k, 1.0, rng);
            let b = Mat::randn(k, n, 1.0, rng);
            assert_close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-4);
        });
    }

    #[test]
    fn blocked_matches_naive_block_boundary_shapes() {
        // Exercise shapes straddling the MC/NC/KC boundaries.
        let mut rng = Pcg32::seeded(17);
        for &(m, k, n) in &[(64, 256, 128), (65, 257, 129), (63, 255, 127), (1, 300, 1), (130, 1, 260)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            assert_close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-3);
        }
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Pcg32::seeded(2);
        let a = Mat::randn(7, 7, 1.0, &mut rng);
        assert_close(&matmul(&a, &Mat::eye(7)), &a, 1e-6);
        assert_close(&matmul(&Mat::eye(7), &a), &a, 1e-6);
    }

    #[test]
    fn dot_matches_reference() {
        property("unrolled dot == fold", 32, |rng| {
            let n = rng.index(100) + 1;
            let x: Vec<f32> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            let y: Vec<f32> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            let reference: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((dot(&x, &y) - reference).abs() < 1e-4);
        });
    }

    #[test]
    fn rowvec_matches_matmul() {
        property("rowvec fast path == matmul + bias", 24, |rng| {
            let d = rng.index(30) + 1;
            let h = rng.index(30) + 1;
            let x: Vec<f32> = (0..d).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            let w = Mat::randn(d, h, 1.0, rng);
            let bias: Vec<f32> = (0..h).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            let xm = Mat::from_vec(1, d, x.clone());
            let mut want = matmul(&xm, &w);
            for (j, v) in want.row_mut(0).iter_mut().enumerate() {
                *v += bias[j];
            }
            let got = rowvec_matmul_bias(&x, &w, &bias);
            for j in 0..h {
                assert!((got[j] - want[(0, j)]).abs() < 1e-4);
            }
        });
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(4, 2);
        let _ = matmul(&a, &b);
    }

    #[test]
    fn parallel_matches_naive_random_shapes() {
        for threads in [1usize, 2, 7] {
            let pool = ThreadPool::new(threads);
            property("parallel == naive", 16, |rng| {
                let m = rng.index(50) + 1;
                let k = rng.index(40) + 1;
                let n = rng.index(40) + 1;
                let a = Mat::randn(m, k, 1.0, rng);
                let b = Mat::randn(k, n, 1.0, rng);
                assert_close(&matmul_par(&a, &b, &pool), &matmul_naive(&a, &b), 1e-4);
            });
        }
    }

    /// The determinism contract: the parallel kernel is *bit-identical* to
    /// the serial blocked kernel for any thread count and any shape,
    /// including ones straddling the MC/NC/KC panel boundaries.
    #[test]
    fn parallel_is_bit_identical_to_serial_for_any_thread_count() {
        let mut rng = Pcg32::seeded(23);
        let shapes = [
            (1usize, 1usize, 1usize),
            (64, 256, 128),
            (65, 257, 129),
            (63, 100, 127),
            (130, 30, 260),
            (200, 17, 3),
        ];
        for &(m, k, n) in &shapes {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let mut serial = Mat::zeros(m, n);
            matmul_into(&a, &b, &mut serial);
            for threads in [1usize, 2, 7] {
                let pool = ThreadPool::new(threads);
                let mut par = Mat::full(m, n, f32::NAN); // dirty output buffer
                matmul_into_par(&a, &b, &mut par, &pool);
                assert_eq!(
                    par.as_slice(),
                    serial.as_slice(),
                    "threads={threads} shape=({m},{k},{n}) not bit-identical"
                );
            }
        }
    }

    /// A row-range view must produce exactly the rows the full product
    /// would — bitwise, since rows are independent and the view kernel
    /// mirrors the serial accumulation order.
    #[test]
    fn view_kernel_is_bit_identical_to_full_product_rows() {
        property("view rows == full product rows", 24, |rng| {
            let m = rng.index(30) + 2;
            let k = rng.index(40) + 1;
            let n = rng.index(40) + 1;
            let a = Mat::randn(m, k, 1.0, rng);
            let b = Mat::randn(k, n, 1.0, rng);
            let mut full = Mat::zeros(m, n);
            matmul_into(&a, &b, &mut full);
            let start = rng.index(m - 1);
            let rows = rng.index(m - start) + 1;
            let mut out = vec![f32::NAN; rows * n]; // dirty buffer
            matmul_view_into(a.view_rows(start, rows), &b, &mut out);
            assert_eq!(&out[..], &full.as_slice()[start * n..(start + rows) * n]);
        });
    }

    #[test]
    #[should_panic(expected = "output slice length")]
    fn view_kernel_checks_output_length() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(3, 4);
        let mut out = vec![0.0; 7];
        matmul_view_into(a.view(), &b, &mut out);
    }

    /// Lease slices are just another execution target: any lease width over
    /// any pool computes the same bits as the serial oracle, including a
    /// zero-grant (inline) lease and the ctx entry point.
    #[test]
    fn lease_and_ctx_paths_are_bit_identical_to_serial() {
        use crate::exec::ExecCtx;
        let mut rng = Pcg32::seeded(47);
        let (m, k, n) = (65, 100, 33);
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let mut serial = Mat::zeros(m, n);
        matmul_into(&a, &b, &mut serial);
        let pool = ThreadPool::new(4);
        for want in [0usize, 1, 2, 4] {
            let lease = pool.lease(want);
            let mut par = Mat::full(m, n, f32::NAN);
            matmul_into_par(&a, &b, &mut par, &lease);
            assert_eq!(par.as_slice(), serial.as_slice(), "lease width {}", lease.threads());
            drop(lease);
            let mut ctx = ExecCtx::over(pool.lease(want));
            let mut via_ctx = Mat::full(m, n, f32::NAN);
            matmul_into_ctx(&a, &b, &mut via_ctx, &mut ctx);
            assert_eq!(via_ctx.as_slice(), serial.as_slice(), "ctx lease {want}");
        }
        assert_eq!(pool.leased(), 0);
    }

    /// The packed kernel's contract: packing A panels is a memory-layout
    /// change only — bit-identical to [`matmul_into`] for random shapes,
    /// panel-boundary shapes, any thread count, and any lease width.
    #[test]
    fn packed_kernel_is_bit_identical_to_unpacked_serial() {
        property("packed == serial, bitwise", 24, |rng| {
            let m = rng.index(80) + 1;
            let k = rng.index(300) + 1;
            let n = rng.index(60) + 1;
            let a = Mat::randn(m, k, 1.0, rng);
            let b = Mat::randn(k, n, 1.0, rng);
            let mut serial = Mat::zeros(m, n);
            matmul_into(&a, &b, &mut serial);
            let mut packed = Mat::full(m, n, f32::NAN); // dirty output buffer
            matmul_into_packed(&a, &b, &mut packed);
            assert_eq!(packed.as_slice(), serial.as_slice(), "shape ({m},{k},{n})");
        });
    }

    #[test]
    fn packed_parallel_is_bit_identical_for_any_thread_count_and_lease() {
        use crate::exec::ExecCtx;
        let mut rng = Pcg32::seeded(61);
        // Shapes straddling the MC/NC/KC boundaries, incl. k > KC so the
        // packing loop runs more than one block.
        let shapes = [
            (1usize, 1usize, 1usize),
            (64, 256, 128),
            (65, 257, 129),
            (130, 300, 60),
            (200, 17, 3),
        ];
        for &(m, k, n) in &shapes {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let mut serial = Mat::zeros(m, n);
            matmul_into(&a, &b, &mut serial);
            for threads in [1usize, 2, 7] {
                let pool = ThreadPool::new(threads);
                let mut par = Mat::full(m, n, f32::NAN);
                matmul_into_packed_par(&a, &b, &mut par, &pool);
                assert_eq!(
                    par.as_slice(),
                    serial.as_slice(),
                    "threads={threads} shape=({m},{k},{n})"
                );
                for grant in [1usize, threads] {
                    let mut ctx = ExecCtx::over(pool.lease(grant));
                    let mut via_ctx = Mat::full(m, n, f32::NAN);
                    matmul_into_packed_ctx(&a, &b, &mut via_ctx, &mut ctx);
                    assert_eq!(
                        via_ctx.as_slice(),
                        serial.as_slice(),
                        "ctx lease {grant} shape=({m},{k},{n})"
                    );
                }
            }
        }
    }

    #[test]
    fn auto_path_matches_serial_across_the_size_threshold() {
        let mut rng = Pcg32::seeded(29);
        // Small (serial branch) and large (parallel branch) products.
        for &(m, k, n) in &[(8usize, 8usize, 8usize), (160, 160, 160)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let auto = matmul_auto(&a, &b);
            let mut serial = Mat::zeros(m, n);
            matmul_into(&a, &b, &mut serial);
            assert_eq!(auto.as_slice(), serial.as_slice());
        }
    }

    /// The ctx-routed auto path must take the same serial-vs-parallel
    /// branches as [`matmul_into_auto`] and return its buffer through the
    /// ctx arena — on both sides of the size threshold.
    #[test]
    fn auto_ctx_path_matches_serial_and_recycles_the_arena() {
        use crate::exec::ExecCtx;
        let mut rng = Pcg32::seeded(31);
        let pool = ThreadPool::new(2);
        for &(m, k, n) in &[(8usize, 8usize, 8usize), (160, 160, 160)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let mut serial = Mat::zeros(m, n);
            matmul_into(&a, &b, &mut serial);
            let mut ctx = ExecCtx::over(pool.lease(2));
            let mut into_ctx = Mat::full(m, n, f32::NAN);
            matmul_into_auto_ctx(&a, &b, &mut into_ctx, &mut ctx);
            assert_eq!(into_ctx.as_slice(), serial.as_slice(), "{m}x{k}x{n}");
            let auto = matmul_auto_ctx(&a, &b, &mut ctx);
            assert_eq!(auto.as_slice(), serial.as_slice(), "{m}x{k}x{n}");
            ctx.put_buf(auto.into_vec());
            assert_eq!(ctx.arena().len(), 1, "buffer came back to the arena");
        }
    }
}
