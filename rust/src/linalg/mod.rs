//! Dense linear algebra, built from scratch for the offline environment.
//!
//! - [`matrix`] — row-major `Mat` with shape-checked ops.
//! - [`gemm`] — the dense hot path: naive reference kernel, a cache-blocked
//!   serial implementation (the correctness oracle), and a row-panel
//!   pool-parallel variant that is bit-identical to it (the "control"
//!   network's forward pass runs through the auto-dispatching entry point).
//! - [`simd`] — explicitly vectorized (AVX2/NEON, runtime-detected) variants
//!   of the dense axpy GEMM and the contiguous dot; tolerance-tier against
//!   the serial oracles, bit-identical across their own ISA paths.
//! - [`svd`] — one-sided Jacobi SVD (full and truncated); powers the paper's
//!   per-epoch estimator refresh (§3.2).
//! - [`lowrank`] — truncated factorization `W ≈ U·V` with the paper's
//!   convention `U = U_r`, `V = Σ_r V_rᵀ`.
//! - [`quant`] — symmetric per-row int8 quantization: exact i8 dot kernels
//!   (AVX2/NEON/scalar, bit-identical by integer exactness), quantized
//!   layers and low-rank factors; sign-agreement tier against the f32
//!   oracles.

pub mod matrix;
pub mod gemm;
pub mod simd;
pub mod svd;
pub mod lowrank;
pub mod quant;

pub use gemm::{
    matmul, matmul_auto, matmul_auto_ctx, matmul_into, matmul_into_auto, matmul_into_auto_ctx,
    matmul_into_ctx, matmul_into_packed, matmul_into_packed_ctx, matmul_into_packed_par,
    matmul_into_par, matmul_par, matmul_view_into,
};
pub use simd::{dot_simd, matmul_into_simd, matmul_into_simd_ctx, matmul_into_simd_par, SimdCaps};
pub use quant::{dot_i8, quantize_row_into, QuantizedLayer, QuantizedLowRank, QuantizedMat};
pub use lowrank::LowRank;
pub use matrix::{Mat, MatView};
pub use svd::Svd;
