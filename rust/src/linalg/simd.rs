//! Explicitly vectorized f32 microkernels (`dense_simd` / `masked_simd`).
//!
//! The portable kernels in [`super::gemm`] lean on LLVM's auto-vectorizer,
//! which on the default x86_64 target is limited to the SSE2 baseline and
//! never emits fused multiply-adds (fusing changes results, so the compiler
//! must not do it silently). This module opts in explicitly: 8-lane AVX2+FMA
//! on x86_64, paired 4-lane NEON FMA on aarch64, both behind *runtime*
//! feature detection ([`SimdCaps`]) with a scalar tail and a pure-scalar
//! fallback path for every entry point.
//!
//! Numeric contract (what makes the equivalence tiers checkable):
//!
//! - **All ISA paths of one kernel are bit-identical to each other.** The
//!   scalar fallback mirrors the vector code's exact accumulator structure —
//!   same lane count, same reduction tree, same fused ops via
//!   [`f32::mul_add`] (correctly rounded, like the hardware FMA the vector
//!   paths use) — so AVX2, NEON and forced-scalar runs of `dense_simd` /
//!   `masked_simd` produce the same bits. `CONDCOMP_FORCE_SCALAR=1` changes
//!   speed, never results, and the scalar tail is exercised on every machine.
//! - **Against the serial oracles the kernels are tolerance-tier, not
//!   bit-exact.** The dense kernel fuses each multiply-add the oracle rounds
//!   in two steps; the masked dot kernel accumulates in 16 lanes instead of
//!   the oracle's 4. Both stay within a small ULP envelope — declared per
//!   kernel as `EquivalenceTier::Tolerance(..)` in the registry and enforced
//!   by the property suites with the [`crate::util::ulp`] comparator.
//!
//! The axpy-form GEMM is element-independent (each output cell accumulates
//! its K contributions in serial order; one fused op per contribution), so —
//! exactly like the portable kernel — row sharding, KC/NC blocking, lane
//! boundaries and tail handling are all invisible in the result bits: any
//! thread count, lease width or ISA path computes the same output.

use super::matrix::Mat;
use crate::exec::ExecCtx;
use crate::parallel::{chunk_rows, par_row_chunks, Parallelism};
use std::sync::OnceLock;

/// Row-panel / column-block / depth-panel sizes, mirrored from
/// [`super::gemm`] so the SIMD kernel shards work identically.
const MC: usize = 64;
const NC: usize = 128;
const KC: usize = 256;

/// Vector lane count the kernels are written for (f32x8: one AVX2 register,
/// a pair of NEON registers, or an 8-slot scalar accumulator bank).
pub const LANES: usize = 8;
/// Elements consumed per dot-product loop iteration (two 8-lane accumulators).
const DOT_STEP: usize = 2 * LANES;

/// CPU SIMD capabilities, probed once (satellite: detection is cached at
/// registry construction, not re-queried per `run` call) and honored by
/// every kernel in this module.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SimdCaps {
    /// x86_64 AVX2 available.
    pub avx2: bool,
    /// x86_64 FMA available (the vector path requires `avx2 && fma`).
    pub fma: bool,
    /// aarch64 NEON available.
    pub neon: bool,
    /// `CONDCOMP_FORCE_SCALAR` was set: pin the scalar path regardless of
    /// hardware (the escape hatch that makes the fallback testable anywhere).
    pub forced_scalar: bool,
}

impl SimdCaps {
    /// Probe the running CPU and the `CONDCOMP_FORCE_SCALAR` environment
    /// knob. Prefer [`SimdCaps::get`] — it caches this probe process-wide.
    pub fn probe() -> SimdCaps {
        let forced_scalar = std::env::var("CONDCOMP_FORCE_SCALAR")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        #[cfg(target_arch = "x86_64")]
        {
            SimdCaps {
                avx2: std::arch::is_x86_feature_detected!("avx2"),
                fma: std::arch::is_x86_feature_detected!("fma"),
                neon: false,
                forced_scalar,
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            SimdCaps {
                avx2: false,
                fma: false,
                neon: std::arch::is_aarch64_feature_detected!("neon"),
                forced_scalar,
            }
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            SimdCaps { avx2: false, fma: false, neon: false, forced_scalar }
        }
    }

    /// The process-wide cached probe (env + cpuid read exactly once).
    pub fn get() -> SimdCaps {
        static CAPS: OnceLock<SimdCaps> = OnceLock::new();
        *CAPS.get_or_init(SimdCaps::probe)
    }

    /// A caps value that pins the scalar path — lets tests exercise the
    /// fallback in-process without touching the environment.
    pub fn scalar() -> SimdCaps {
        SimdCaps { avx2: false, fma: false, neon: false, forced_scalar: true }
    }

    /// Whether the AVX2 vector path runs (needs FMA too — the kernels fuse).
    #[inline]
    pub fn use_avx2(&self) -> bool {
        self.avx2 && self.fma && !self.forced_scalar
    }

    /// Whether the NEON vector path runs.
    #[inline]
    pub fn use_neon(&self) -> bool {
        self.neon && !self.forced_scalar
    }

    /// Human-readable ISA path label (exported via the `stats` op's gauges
    /// and the serve startup log).
    pub fn isa_label(&self) -> &'static str {
        if self.forced_scalar {
            "scalar (forced)"
        } else if self.use_avx2() {
            "avx2+fma"
        } else if self.use_neon() {
            "neon"
        } else {
            "scalar"
        }
    }
}

// --- inner kernels: one per ISA path, bit-identical to each other ---------

/// Scalar mirror of the vector axpy: one fused multiply-add per element.
/// Elements are independent, so this matches the AVX2/NEON paths bitwise.
fn axpy_row_scalar(c: &mut [f32], alpha: f32, b: &[f32]) {
    debug_assert_eq!(c.len(), b.len());
    for (cj, &bj) in c.iter_mut().zip(b) {
        *cj = alpha.mul_add(bj, *cj);
    }
}

/// `c += alpha · b` with 8-lane AVX2 FMA and a fused scalar tail.
///
/// # Safety
/// Caller must ensure AVX2 and FMA are available on the running CPU.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy_row_avx2(c: &mut [f32], alpha: f32, b: &[f32]) {
    use std::arch::x86_64::*;
    debug_assert_eq!(c.len(), b.len());
    let n = c.len();
    let va = _mm256_set1_ps(alpha);
    let mut j = 0;
    while j + LANES <= n {
        let vb = _mm256_loadu_ps(b.as_ptr().add(j));
        let vc = _mm256_loadu_ps(c.as_ptr().add(j));
        _mm256_storeu_ps(c.as_mut_ptr().add(j), _mm256_fmadd_ps(va, vb, vc));
        j += LANES;
    }
    axpy_row_scalar(&mut c[j..], alpha, &b[j..]);
}

/// `c += alpha · b` with paired 4-lane NEON FMA and a fused scalar tail.
///
/// # Safety
/// Caller must ensure NEON is available on the running CPU.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn axpy_row_neon(c: &mut [f32], alpha: f32, b: &[f32]) {
    use std::arch::aarch64::*;
    debug_assert_eq!(c.len(), b.len());
    let n = c.len();
    let va = vdupq_n_f32(alpha);
    let mut j = 0;
    while j + LANES <= n {
        let b0 = vld1q_f32(b.as_ptr().add(j));
        let b1 = vld1q_f32(b.as_ptr().add(j + 4));
        let c0 = vld1q_f32(c.as_ptr().add(j));
        let c1 = vld1q_f32(c.as_ptr().add(j + 4));
        vst1q_f32(c.as_mut_ptr().add(j), vfmaq_f32(c0, va, b0));
        vst1q_f32(c.as_mut_ptr().add(j + 4), vfmaq_f32(c1, va, b1));
        j += LANES;
    }
    axpy_row_scalar(&mut c[j..], alpha, &b[j..]);
}

/// `c += alpha · b` over contiguous slices — the `dense_simd` inner kernel.
/// Every ISA path computes the same bits (one fused op per element).
#[inline]
pub fn axpy_row_simd(caps: SimdCaps, c: &mut [f32], alpha: f32, b: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    if caps.use_avx2() {
        // SAFETY: use_avx2() gates on runtime AVX2+FMA detection.
        unsafe { axpy_row_avx2(c, alpha, b) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if caps.use_neon() {
        // SAFETY: use_neon() gates on runtime NEON detection.
        unsafe { axpy_row_neon(c, alpha, b) };
        return;
    }
    let _ = caps;
    axpy_row_scalar(c, alpha, b);
}

/// Fixed-order reduction of the 8 accumulator lanes — identical tree on
/// every ISA path, so the lane sum's bits never depend on the hardware.
#[inline]
fn reduce_lanes(v: [f32; LANES]) -> f32 {
    ((v[0] + v[1]) + (v[2] + v[3])) + ((v[4] + v[5]) + (v[6] + v[7]))
}

/// Fused scalar tail shared by every dot path: folds the remainder into the
/// lane sum in element order.
#[inline]
fn dot_tail(mut s: f32, x: &[f32], y: &[f32]) -> f32 {
    for (&xv, &yv) in x.iter().zip(y) {
        s = xv.mul_add(yv, s);
    }
    s
}

/// Scalar mirror of the vector dot: two 8-slot accumulator banks updated
/// with fused ops in the exact lane layout the AVX2/NEON paths use.
fn dot_scalar(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc0 = [0.0f32; LANES];
    let mut acc1 = [0.0f32; LANES];
    let blocks = x.len() / DOT_STEP;
    let split = blocks * DOT_STEP;
    for (xc, yc) in x[..split].chunks_exact(DOT_STEP).zip(y[..split].chunks_exact(DOT_STEP)) {
        for (l, (a0, a1)) in acc0.iter_mut().zip(acc1.iter_mut()).enumerate() {
            *a0 = xc[l].mul_add(yc[l], *a0);
            *a1 = xc[LANES + l].mul_add(yc[LANES + l], *a1);
        }
    }
    let mut v = [0.0f32; LANES];
    for (slot, (a0, a1)) in v.iter_mut().zip(acc0.iter().zip(&acc1)) {
        *slot = a0 + a1;
    }
    dot_tail(reduce_lanes(v), &x[split..], &y[split..])
}

/// Contiguous dot product with two 8-lane AVX2 FMA accumulators.
///
/// # Safety
/// Caller must ensure AVX2 and FMA are available on the running CPU.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_avx2(x: &[f32], y: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let blocks = n / DOT_STEP;
    let split = blocks * DOT_STEP;
    let mut i = 0;
    while i < split {
        let x0 = _mm256_loadu_ps(x.as_ptr().add(i));
        let y0 = _mm256_loadu_ps(y.as_ptr().add(i));
        acc0 = _mm256_fmadd_ps(x0, y0, acc0);
        let x1 = _mm256_loadu_ps(x.as_ptr().add(i + LANES));
        let y1 = _mm256_loadu_ps(y.as_ptr().add(i + LANES));
        acc1 = _mm256_fmadd_ps(x1, y1, acc1);
        i += DOT_STEP;
    }
    let mut v = [0.0f32; LANES];
    _mm256_storeu_ps(v.as_mut_ptr(), _mm256_add_ps(acc0, acc1));
    dot_tail(reduce_lanes(v), &x[split..], &y[split..])
}

/// Contiguous dot product with two (4+4)-lane NEON FMA accumulator pairs —
/// same 16-element step, lane layout and reduction tree as the AVX2 path.
///
/// # Safety
/// Caller must ensure NEON is available on the running CPU.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_neon(x: &[f32], y: &[f32]) -> f32 {
    use std::arch::aarch64::*;
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    // acc0 covers lanes 0..8 (as two q-registers), acc1 covers lanes 8..16.
    let mut a0lo = vdupq_n_f32(0.0);
    let mut a0hi = vdupq_n_f32(0.0);
    let mut a1lo = vdupq_n_f32(0.0);
    let mut a1hi = vdupq_n_f32(0.0);
    let blocks = n / DOT_STEP;
    let split = blocks * DOT_STEP;
    let mut i = 0;
    while i < split {
        a0lo = vfmaq_f32(a0lo, vld1q_f32(x.as_ptr().add(i)), vld1q_f32(y.as_ptr().add(i)));
        a0hi = vfmaq_f32(a0hi, vld1q_f32(x.as_ptr().add(i + 4)), vld1q_f32(y.as_ptr().add(i + 4)));
        a1lo = vfmaq_f32(a1lo, vld1q_f32(x.as_ptr().add(i + 8)), vld1q_f32(y.as_ptr().add(i + 8)));
        a1hi =
            vfmaq_f32(a1hi, vld1q_f32(x.as_ptr().add(i + 12)), vld1q_f32(y.as_ptr().add(i + 12)));
        i += DOT_STEP;
    }
    let mut v = [0.0f32; LANES];
    vst1q_f32(v.as_mut_ptr(), vaddq_f32(a0lo, a1lo));
    vst1q_f32(v.as_mut_ptr().add(4), vaddq_f32(a0hi, a1hi));
    dot_tail(reduce_lanes(v), &x[split..], &y[split..])
}

/// Contiguous dot product — the `masked_simd` inner kernel. Every ISA path
/// computes the same bits (identical lane layout and reduction order).
#[inline]
pub fn dot_simd(caps: SimdCaps, x: &[f32], y: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if caps.use_avx2() {
        // SAFETY: use_avx2() gates on runtime AVX2+FMA detection.
        return unsafe { dot_avx2(x, y) };
    }
    #[cfg(target_arch = "aarch64")]
    if caps.use_neon() {
        // SAFETY: use_neon() gates on runtime NEON detection.
        return unsafe { dot_neon(x, y) };
    }
    let _ = caps;
    dot_scalar(x, y)
}

// --- the dense_simd GEMM ---------------------------------------------------

/// Compute one row panel of `C = A · B` into `band` with the vectorized
/// axpy — the same KC/NC blocking and zero-skip as
/// [`super::gemm::matmul_into`]'s panel, with each row update fused.
fn simd_row_panel(caps: SimdCaps, a: &Mat, b: &Mat, row0: usize, band: &mut [f32]) {
    let k = a.cols();
    let n = b.cols();
    if n == 0 {
        return;
    }
    let rows = band.len() / n;
    band.fill(0.0);
    let mut p0 = 0;
    while p0 < k {
        let kc = KC.min(k - p0);
        let mut j0 = 0;
        while j0 < n {
            let nc = NC.min(n - j0);
            for i in 0..rows {
                let arow = &a.row(row0 + i)[p0..p0 + kc];
                let crow = &mut band[i * n + j0..i * n + j0 + nc];
                for (pp, &aip) in arow.iter().enumerate() {
                    if aip == 0.0 {
                        continue;
                    }
                    let brow = &b.row(p0 + pp)[j0..j0 + nc];
                    axpy_row_simd(caps, crow, aip, brow);
                }
            }
            j0 += nc;
        }
        p0 += kc;
    }
}

/// `C = A · B` with the vectorized axpy GEMM (serial). Differs from the
/// portable [`super::gemm::matmul_into`] only by fusing each multiply-add —
/// the tolerance-tier delta; every structural choice (loop order, blocking,
/// zero-skip) is mirrored.
pub fn matmul_into_simd(caps: SimdCaps, a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!(c.shape(), (a.rows(), b.cols()), "output shape mismatch");
    simd_row_panel(caps, a, b, 0, c.as_mut_slice());
}

/// [`matmul_into_simd`] on an execution target: MC-quantized row panels, one
/// pool job per panel — the same sharding as the portable parallel kernel.
/// Bit-identical to the serial SIMD kernel for any thread count or lease
/// width (axpy elements are independent; each accumulates in serial K order).
pub fn matmul_into_simd_par<P: Parallelism>(
    caps: SimdCaps,
    a: &Mat,
    b: &Mat,
    c: &mut Mat,
    par: &P,
) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!(c.shape(), (a.rows(), b.cols()), "output shape mismatch");
    let (m, k) = a.shape();
    let n = b.cols();
    let width = par.width();
    if width == 1 || m < 2 || n == 0 || k == 0 {
        simd_row_panel(caps, a, b, 0, c.as_mut_slice());
        return;
    }
    let quantum = if m >= width * MC { MC } else { (MC / 8).max(1) };
    let rows_per = chunk_rows(m, width, quantum);
    par_row_chunks(par, c, rows_per, |row0, band| {
        simd_row_panel(caps, a, b, row0, band);
    });
}

/// [`matmul_into_simd_par`] through an execution context: chunked by the
/// ctx's lease width — the registry kernel's entry point.
pub fn matmul_into_simd_ctx(caps: SimdCaps, a: &Mat, b: &Mat, c: &mut Mat, ctx: &mut ExecCtx<'_>) {
    matmul_into_simd_par(caps, a, b, c, ctx.lease());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{dot, matmul_into, matmul_naive};
    use crate::parallel::ThreadPool;
    use crate::util::proptest::{arb_buf, property};
    use crate::util::ulp::within_tolerance;
    use crate::util::Pcg32;

    /// The probe never reports vector paths the architecture can't have,
    /// and the forced-scalar constructor pins the scalar label.
    #[test]
    fn caps_probe_is_arch_consistent() {
        let caps = SimdCaps::get();
        assert_eq!(caps, SimdCaps::get(), "cached probe is stable");
        #[cfg(target_arch = "x86_64")]
        assert!(!caps.neon);
        #[cfg(target_arch = "aarch64")]
        assert!(!caps.avx2 && !caps.fma);
        let forced = SimdCaps::scalar();
        assert!(!forced.use_avx2() && !forced.use_neon());
        assert_eq!(forced.isa_label(), "scalar (forced)");
        assert!(["avx2+fma", "neon", "scalar", "scalar (forced)"].contains(&caps.isa_label()));
    }

    /// The cross-ISA identity: on hardware with a vector path, the vector
    /// and forced-scalar paths must agree bit-for-bit — for the axpy, the
    /// dot, and whole GEMMs. (On scalar-only hardware both sides take the
    /// same path and the test is a tautology, which is fine: CI's
    /// `CONDCOMP_FORCE_SCALAR=1` arm covers the other leg.)
    #[test]
    fn vector_and_scalar_paths_are_bit_identical() {
        let native = SimdCaps::get();
        let scalar = SimdCaps::scalar();
        property("simd native path == forced-scalar path", 32, |rng| {
            let n = rng.index(70) + 1;
            let alpha = rng.uniform_in(-2.0, 2.0);
            let b = arb_buf(rng, n);
            let base = arb_buf(rng, n);
            let mut c_native = base.clone();
            let mut c_scalar = base;
            axpy_row_simd(native, &mut c_native, alpha, &b);
            axpy_row_simd(scalar, &mut c_scalar, alpha, &b);
            assert_eq!(bits(&c_native), bits(&c_scalar), "axpy n={n}");

            let x = arb_buf(rng, n);
            let y = arb_buf(rng, n);
            assert_eq!(
                dot_simd(native, &x, &y).to_bits(),
                dot_simd(scalar, &x, &y).to_bits(),
                "dot n={n}"
            );
        });
        let mut rng = Pcg32::seeded(0x51D);
        for &(m, k, n) in &[(5usize, 33usize, 17usize), (64, 256, 128), (65, 257, 129)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let mut via_native = Mat::full(m, n, f32::NAN);
            let mut via_scalar = Mat::full(m, n, f32::NAN);
            matmul_into_simd(native, &a, &b, &mut via_native);
            matmul_into_simd(scalar, &a, &b, &mut via_scalar);
            assert_eq!(bits(via_native.as_slice()), bits(via_scalar.as_slice()), "({m},{k},{n})");
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// ULP bound the SIMD kernels must satisfy against the portable serial
    /// oracles (the registry declares the same bound for their tiers).
    const TIER_ULPS: u32 = 4096;

    #[test]
    fn simd_dot_matches_portable_dot_within_tolerance() {
        for caps in [SimdCaps::get(), SimdCaps::scalar()] {
            property("dot_simd ≈ dot", 48, |rng| {
                let n = rng.index(300) + 1;
                let x = arb_buf(rng, n);
                let y = arb_buf(rng, n);
                let got = dot_simd(caps, &x, &y);
                let want = dot(&x, &y);
                assert!(
                    within_tolerance(got, want, TIER_ULPS),
                    "n={n} got={got} want={want}"
                );
            });
        }
    }

    #[test]
    fn simd_gemm_matches_oracles_within_tolerance() {
        for caps in [SimdCaps::get(), SimdCaps::scalar()] {
            property("matmul_into_simd ≈ matmul_into", 16, |rng| {
                let m = rng.index(40) + 1;
                let k = rng.index(120) + 1;
                let n = rng.index(40) + 1;
                let a = Mat::randn(m, k, 1.0, rng);
                let b = Mat::randn(k, n, 1.0, rng);
                let mut got = Mat::full(m, n, f32::NAN);
                matmul_into_simd(caps, &a, &b, &mut got);
                let mut oracle = Mat::zeros(m, n);
                matmul_into(&a, &b, &mut oracle);
                let naive = matmul_naive(&a, &b);
                for (j, (&g, (&o, &nv))) in got
                    .as_slice()
                    .iter()
                    .zip(oracle.as_slice().iter().zip(naive.as_slice()))
                    .enumerate()
                {
                    assert!(
                        within_tolerance(g, o, TIER_ULPS),
                        "vs blocked oracle: ({m},{k},{n})[{j}] got={g} want={o}"
                    );
                    assert!(
                        within_tolerance(g, nv, TIER_ULPS),
                        "vs naive: ({m},{k},{n})[{j}] got={g} want={nv}"
                    );
                }
            });
        }
    }

    /// The SIMD GEMM's own determinism contract: parallel/lease/ctx runs are
    /// bit-identical to the serial SIMD kernel (elements are independent, so
    /// sharding cannot move a single bit) — under both ISA paths.
    #[test]
    fn simd_parallel_is_bit_identical_to_simd_serial() {
        use crate::exec::ExecCtx;
        let mut rng = Pcg32::seeded(0x51AD);
        let shapes = [(1usize, 1usize, 1usize), (64, 256, 128), (65, 257, 129), (200, 17, 3)];
        for caps in [SimdCaps::get(), SimdCaps::scalar()] {
            for &(m, k, n) in &shapes {
                let a = Mat::randn(m, k, 1.0, &mut rng);
                let b = Mat::randn(k, n, 1.0, &mut rng);
                let mut serial = Mat::full(m, n, f32::NAN);
                matmul_into_simd(caps, &a, &b, &mut serial);
                for threads in [1usize, 2, 7] {
                    let pool = ThreadPool::new(threads);
                    let mut par = Mat::full(m, n, f32::NAN);
                    matmul_into_simd_par(caps, &a, &b, &mut par, &pool);
                    assert_eq!(
                        bits(par.as_slice()),
                        bits(serial.as_slice()),
                        "threads={threads} shape=({m},{k},{n})"
                    );
                    for grant in [1usize, threads] {
                        let mut ctx = ExecCtx::over(pool.lease(grant));
                        let mut via_ctx = Mat::full(m, n, f32::NAN);
                        matmul_into_simd_ctx(caps, &a, &b, &mut via_ctx, &mut ctx);
                        assert_eq!(
                            bits(via_ctx.as_slice()),
                            bits(serial.as_slice()),
                            "ctx lease {grant} shape=({m},{k},{n})"
                        );
                    }
                    assert_eq!(pool.leased(), 0);
                }
            }
        }
    }

    #[test]
    fn dot_simd_handles_tail_only_and_empty_inputs() {
        for caps in [SimdCaps::get(), SimdCaps::scalar()] {
            assert_eq!(dot_simd(caps, &[], &[]), 0.0);
            // Below one DOT_STEP the main loop never runs: pure tail.
            let x: Vec<f32> = (1..=15).map(|i| i as f32).collect();
            let y = vec![2.0f32; 15];
            assert_eq!(dot_simd(caps, &x, &y), 240.0);
        }
    }
}
