//! Layer-3 coordination: the serving/training orchestration around the
//! conditional-computation engine.
//!
//! The paper's mechanism needs system-side bookkeeping that lives here, not
//! in the kernels:
//!
//! - a **request router** that dispatches each inference request to the
//!   control (dense) or conditional (estimator-augmented) backend,
//! - a **dynamic batcher** that coalesces single-example requests into the
//!   fixed-shape batches the AOT-compiled PJRT executables expect
//!   (max-batch / max-wait, pad-to-shape),
//! - the **estimator refresh scheduler** that recomputes the per-layer SVD
//!   factors from the live weights (once per epoch during training, §3.5;
//!   on demand while serving),
//! - a **metrics registry** (request latency, achieved sparsity, FLOPs
//!   saved, estimator quality) exported as JSON,
//! - a line-oriented **TCP JSON protocol** so external clients (and the
//!   bundled load generator) can drive the server.
//!
//! Threads + channels (no async runtime offline): one acceptor, one
//! executor worker per batcher shard around the shared engine.
//!
//! The batching front-end is **sharded** ([`ShardedBatcher`]): requests are
//! routed (round-robin or least-depth) to one of `server.shards`
//! independent queues, each drained by a dedicated executor that owns an
//! execution context ([`crate::exec::ExecCtx`]) — a leased slice of the
//! shared compute pool, a recycled scratch arena, and a per-shard metrics
//! scope — so heavy concurrent traffic stops serializing through a single
//! queue lock, an N-shard server occupies exactly the configured thread
//! budget, and per-request results stay bit-identical to the single-queue
//! path.

pub mod protocol;
pub mod metrics;
pub mod batcher;
pub mod sharded;
pub mod backend;
pub mod server;
pub mod remote;
pub mod scheduler;

pub use backend::{Backend, BackendKind, NativeBackend, ScratchArena};
pub use batcher::{BatchItem, DynamicBatcher, PushRejection};
pub use metrics::MetricsRegistry;
pub use protocol::{Request, Response};
pub use remote::{RemoteBackend, RemoteOpts};
pub use server::{Client, ConnectOpts, PoolMode, Server, ServerConfig};
pub use sharded::{RouterKind, ShardRouter, ShardedBatcher, WeightedDepthRouter};
pub use scheduler::TrainingScheduler;
