//! Remote worker replicas behind the coordinator: a [`RemoteBackend`] that
//! implements the [`Backend`] trait by speaking the line-oriented TCP JSON
//! protocol to `condcomp worker` processes.
//!
//! Topology: the coordinator runs the usual sharded server front door
//! (acceptors, dynamic batching, metrics), but its backend forwards each
//! drained batch to one of N worker processes over the wire instead of
//! running kernels locally. Logits round-trip bit-exactly through the
//! protocol, so 1-process and N-worker serving are bit-identical for the
//! bit-exact kernel tiers (pinned end-to-end in `tests/replica_e2e.rs`).
//!
//! Replica lifecycle:
//!
//! - **Handshake.** Every connection starts with the `hello` op. The worker
//!   answers with its protocol version, model fingerprint, batch limits,
//!   and its calibrated [`MachineProfile`]; the coordinator refuses a
//!   mismatched worker with a clear error instead of silently serving
//!   wrong-model logits.
//! - **Cost-aware routing.** Each replica's profile yields a relative cost
//!   scalar (mean best per-FLOP kernel cost across layers); a
//!   [`WeightedDepthRouter`] picks the replica minimizing
//!   `(inflight + reported depth + 1) × cost`, so heterogeneous workers
//!   absorb load in proportion to their speed.
//! - **Health.** A background thread polls healthy replicas' `stats` for
//!   queue depth, reconnects unhealthy ones with bounded retry + backoff
//!   (re-running the handshake each time), and exports `replica<i>_`
//!   metrics through the coordinator's registry.
//! - **Failure.** An IO error marks the replica unhealthy and the same
//!   batch retries on the next healthy replica; when every candidate is
//!   dead or overloaded the predict fails with a "request shed" error that
//!   the server maps to the explicit `overloaded` reply — exactly-one-reply
//!   conservation survives a worker death.

use super::backend::{Backend, BackendKind};
use super::metrics::MetricsRegistry;
use super::protocol::{Mode, Response, PROTOCOL_VERSION};
use super::server::{Client, ConnectOpts};
use super::sharded::WeightedDepthRouter;
use crate::autotune::MachineProfile;
use crate::linalg::Mat;
use anyhow::Result;
use std::net::{SocketAddr, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Connection/health knobs for the coordinator's worker links (fed from
/// `server.connect_timeout_ms` / `server.retry_max` / `server.retry_backoff_ms`
/// / `server.health_interval_ms` / `server.replicas`).
#[derive(Clone, Debug)]
pub struct RemoteOpts {
    /// Per-attempt TCP connect timeout.
    pub connect_timeout: Duration,
    /// Read timeout on worker connections — a hung worker turns into a
    /// bounded failure instead of a wedged executor.
    pub read_timeout: Duration,
    /// Connect retries (after the first attempt) at startup.
    pub retries: usize,
    /// Initial retry backoff (doubles per attempt).
    pub backoff: Duration,
    /// Health-check / reconnect cadence.
    pub health_interval: Duration,
    /// Minimum workers that must complete the handshake at startup
    /// (0 = at least one).
    pub min_replicas: usize,
}

impl Default for RemoteOpts {
    fn default() -> Self {
        RemoteOpts {
            connect_timeout: Duration::from_secs(1),
            read_timeout: Duration::from_secs(30),
            retries: 5,
            backoff: Duration::from_millis(50),
            health_interval: Duration::from_millis(500),
            min_replicas: 0,
        }
    }
}

impl RemoteOpts {
    fn connect_opts(&self, retries: usize) -> ConnectOpts {
        ConnectOpts {
            connect_timeout: self.connect_timeout,
            read_timeout: Some(self.read_timeout),
            retries,
            backoff: self.backoff,
        }
    }
}

/// A worker's parsed `hello` payload.
#[derive(Clone, Debug)]
pub struct HelloInfo {
    pub proto: u64,
    pub version: String,
    pub fingerprint: String,
    pub input_dim: usize,
    pub max_batch: usize,
    pub profile: Option<MachineProfile>,
}

/// Parse a `hello` response into a [`HelloInfo`]. A worker that rejects the
/// op (an old binary answering "unknown op") or answers without the
/// handshake fields is a handshake failure, reported loudly.
pub fn parse_hello(resp: &Response) -> Result<HelloInfo, String> {
    if !resp.ok {
        return Err(format!(
            "worker rejected hello: {}",
            resp.error.as_deref().unwrap_or("no error reported")
        ));
    }
    let payload = resp.payload.as_ref().ok_or("hello reply carried no payload")?;
    let proto = payload
        .get("proto")
        .and_then(|v| v.as_f64())
        .ok_or("hello payload missing 'proto'")? as u64;
    let fingerprint = payload
        .get("fingerprint")
        .and_then(|v| v.as_str())
        .ok_or("hello payload missing 'fingerprint'")?
        .to_string();
    let input_dim = payload
        .get("input_dim")
        .and_then(|v| v.as_usize())
        .ok_or("hello payload missing 'input_dim'")?;
    let max_batch = payload
        .get("max_batch")
        .and_then(|v| v.as_usize())
        .ok_or("hello payload missing 'max_batch'")?;
    let version =
        payload.get("version").and_then(|v| v.as_str()).unwrap_or("unknown").to_string();
    // The profile is optional (a worker may serve uncalibrated); a present
    // but unparseable profile is an error — silently dropping it would turn
    // cost-aware routing off without anyone noticing.
    let profile = match payload.get("profile") {
        Some(p) => Some(
            MachineProfile::parse(&p.to_string())
                .map_err(|e| format!("hello payload carried a bad profile: {e}"))?,
        ),
        None => None,
    };
    Ok(HelloInfo { proto, version, fingerprint, input_dim, max_batch, profile })
}

/// Verify a worker's handshake against this coordinator: protocol version
/// must match exactly, and (when the coordinator knows its model) the
/// fingerprint must match — a worker serving a different model would return
/// wrong-model logits.
pub fn verify_hello(info: &HelloInfo, expected_fingerprint: &str) -> Result<(), String> {
    if info.proto != PROTOCOL_VERSION {
        return Err(format!(
            "protocol version mismatch: worker speaks v{}, coordinator v{PROTOCOL_VERSION}",
            info.proto
        ));
    }
    if !expected_fingerprint.is_empty() && info.fingerprint != expected_fingerprint {
        return Err(format!(
            "model fingerprint mismatch: worker serves '{}', coordinator expects \
             '{expected_fingerprint}' — refusing to route (wrong-model logits)",
            info.fingerprint
        ));
    }
    Ok(())
}

/// Relative cost scalar for a replica from its machine profile: the mean
/// over layers of the best (lowest) per-FLOP kernel cost — "how fast this
/// machine runs its cheapest kernel". Lower is faster; 1.0 when the profile
/// carries no usable columns (uniform routing).
pub fn replica_cost(profile: &MachineProfile) -> f64 {
    let mut sum = 0.0;
    let mut n = 0usize;
    for layer in &profile.layers {
        let best = layer
            .kernel_costs
            .iter()
            .map(|(_, c)| *c)
            .filter(|c| c.is_finite() && *c > 0.0)
            .fold(f64::INFINITY, f64::min);
        if best.is_finite() {
            sum += best;
            n += 1;
        }
    }
    if n > 0 {
        sum / n as f64
    } else {
        1.0
    }
}

/// One worker link: address, current connection, health flag, and the load/
/// cost state the router reads.
struct Replica {
    addr: SocketAddr,
    conn: Mutex<Option<Client>>,
    healthy: AtomicBool,
    /// Batches this coordinator currently has in flight on this worker.
    inflight: AtomicUsize,
    /// The worker's own queue depth, from its last `stats` poll.
    depth: AtomicUsize,
    /// Relative cost scalar (bits of an f64; lower = faster).
    cost_bits: AtomicU64,
    routed: AtomicU64,
    failures: AtomicU64,
    reconnects: AtomicU64,
    overloaded_replies: AtomicU64,
    profile: Mutex<Option<MachineProfile>>,
}

impl Replica {
    fn new(addr: SocketAddr) -> Replica {
        Replica {
            addr,
            conn: Mutex::new(None),
            healthy: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            depth: AtomicUsize::new(0),
            cost_bits: AtomicU64::new(1.0f64.to_bits()),
            routed: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            overloaded_replies: AtomicU64::new(0),
            profile: Mutex::new(None),
        }
    }

    fn cost(&self) -> f64 {
        f64::from_bits(self.cost_bits.load(Ordering::Relaxed))
    }

    /// Install a verified handshake: connection, profile, cost.
    fn install(&self, client: Client, info: &HelloInfo) {
        self.cost_bits.store(
            info.profile.as_ref().map(replica_cost).unwrap_or(1.0).to_bits(),
            Ordering::Relaxed,
        );
        *self.profile.lock().unwrap() = info.profile.clone();
        *self.conn.lock().unwrap() = Some(client);
        self.depth.store(0, Ordering::Relaxed);
        self.healthy.store(true, Ordering::Relaxed);
    }

    /// Drop the connection and mark unhealthy (the health thread retries).
    fn mark_down(&self) {
        self.healthy.store(false, Ordering::Relaxed);
        *self.conn.lock().unwrap() = None;
        self.failures.fetch_add(1, Ordering::Relaxed);
    }
}

/// State shared between the backend handle and the health thread.
struct RemoteShared {
    replicas: Vec<Arc<Replica>>,
    router: WeightedDepthRouter,
    expected_fingerprint: String,
    input_dim: usize,
    max_batch: usize,
    opts: RemoteOpts,
    metrics: Mutex<Option<Arc<MetricsRegistry>>>,
    stop: AtomicBool,
}

/// Outcome of one predict attempt against one replica.
enum Attempt {
    Ok(Mat),
    /// The worker shed the batch; try the next replica.
    Overloaded,
    /// IO failure; the replica was marked down — retry elsewhere.
    Failed,
    /// The worker answered with a real (non-shed) error; do not retry.
    Hard(String),
}

impl RemoteShared {
    /// Connect + handshake one address. `retries` bounds connect attempts;
    /// a completed-but-unacceptable handshake (protocol/fingerprint
    /// mismatch, bad payload) is a hard error that no retry can fix.
    fn handshake(&self, addr: &SocketAddr, retries: usize) -> Result<(Client, HelloInfo)> {
        let mut client = Client::connect_with(addr, &self.opts.connect_opts(retries))?;
        let resp = client.hello()?;
        let info = parse_hello(&resp).map_err(|e| anyhow::anyhow!("worker {addr}: {e}"))?;
        verify_hello(&info, &self.expected_fingerprint)
            .map_err(|e| anyhow::anyhow!("worker {addr}: {e}"))?;
        Ok((client, info))
    }

    fn publish<F: FnOnce(&MetricsRegistry)>(&self, f: F) {
        if let Some(m) = self.metrics.lock().unwrap().as_ref() {
            f(m);
        }
    }

    /// One predict attempt on replica `i`. Holds the replica's connection
    /// lock for the round-trip (one outstanding batch per worker link; the
    /// health poller uses `try_lock` so it never queues behind us).
    fn try_replica(&self, i: usize, x: &Mat, mode: Mode) -> Attempt {
        let replica = &self.replicas[i];
        replica.inflight.fetch_add(1, Ordering::Relaxed);
        let out = {
            let mut conn = replica.conn.lock().unwrap();
            match conn.as_mut() {
                None => Attempt::Failed,
                Some(client) => match client.predict(x.clone(), mode) {
                    Err(_) => Attempt::Failed,
                    Ok(resp) if resp.overloaded => Attempt::Overloaded,
                    Ok(resp) if !resp.ok => Attempt::Hard(
                        resp.error.unwrap_or_else(|| "worker error".into()),
                    ),
                    Ok(resp) => match resp.logits {
                        Some(logits) => Attempt::Ok(logits),
                        None => Attempt::Hard("worker reply carried no logits".into()),
                    },
                },
            }
        };
        replica.inflight.fetch_sub(1, Ordering::Relaxed);
        match &out {
            Attempt::Ok(_) => {
                replica.routed.fetch_add(1, Ordering::Relaxed);
                self.publish(|m| m.incr_replica(i, "batches_routed"));
            }
            Attempt::Overloaded => {
                replica.overloaded_replies.fetch_add(1, Ordering::Relaxed);
                self.publish(|m| m.incr_replica(i, "overloaded_replies"));
            }
            Attempt::Failed => {
                replica.mark_down();
                self.publish(|m| {
                    m.incr_replica(i, "failures");
                    m.set_replica_gauge(i, "healthy", 0.0);
                });
                eprintln!(
                    "remote: worker {} failed mid-request; re-routing the batch",
                    replica.addr
                );
            }
            Attempt::Hard(_) => {}
        }
        out
    }

    /// Current router costs, replica-indexed.
    fn costs(&self) -> Vec<f64> {
        self.replicas.iter().map(|r| r.cost()).collect()
    }

    /// One health tick: reconnect unhealthy replicas (single bounded
    /// attempt — the loop cadence is the retry schedule), poll healthy ones
    /// for queue depth, refresh router costs, export metrics.
    fn health_tick(&self) {
        for (i, replica) in self.replicas.iter().enumerate() {
            if !replica.healthy.load(Ordering::Relaxed) {
                match self.handshake(&replica.addr, 0) {
                    Ok((client, info)) => {
                        // Re-verify serving limits too: a worker that came
                        // back smaller than the coordinator's batch contract
                        // would reject batches we already promised to accept.
                        if info.input_dim != self.input_dim || info.max_batch < self.max_batch {
                            eprintln!(
                                "remote: worker {} rejoined with incompatible limits \
                                 (input_dim {} vs {}, max_batch {} < {}); keeping it out",
                                replica.addr,
                                info.input_dim,
                                self.input_dim,
                                info.max_batch,
                                self.max_batch
                            );
                        } else {
                            replica.install(client, &info);
                            replica.reconnects.fetch_add(1, Ordering::Relaxed);
                            self.publish(|m| m.incr_replica(i, "reconnects"));
                            eprintln!("remote: worker {} reconnected", replica.addr);
                        }
                    }
                    Err(_) => {} // still down; next tick retries
                }
            } else {
                // Depth poll: skip rather than queue behind an in-flight
                // batch (the connection is serial; depth is advisory).
                if let Ok(mut conn) = replica.conn.try_lock() {
                    let poll = conn.as_mut().map(|c| c.stats());
                    match poll {
                        Some(Ok(resp)) if resp.ok => {
                            replica
                                .depth
                                .store(reported_depth(&resp), Ordering::Relaxed);
                        }
                        Some(Err(_)) => {
                            drop(conn);
                            replica.mark_down();
                            self.publish(|m| m.incr_replica(i, "failures"));
                            eprintln!(
                                "remote: worker {} failed a health check",
                                replica.addr
                            );
                        }
                        _ => {}
                    }
                }
            }
        }
        self.router.set_costs(self.costs());
        self.export_metrics();
    }

    fn export_metrics(&self) {
        self.publish(|m| {
            let mut healthy = 0usize;
            for (i, r) in self.replicas.iter().enumerate() {
                let up = r.healthy.load(Ordering::Relaxed);
                healthy += usize::from(up);
                m.set_replica_gauge(i, "healthy", if up { 1.0 } else { 0.0 });
                m.set_replica_gauge(i, "depth", r.depth.load(Ordering::Relaxed) as f64);
                m.set_replica_gauge(i, "cost", r.cost());
            }
            m.set_gauge("replicas", self.replicas.len() as f64);
            m.set_gauge("replicas_healthy", healthy as f64);
        });
    }
}

/// Sum of the worker's per-shard `shard<i>_depth` gauges from a `stats`
/// payload (the worker's own queue pressure plane, read over the wire).
fn reported_depth(resp: &Response) -> usize {
    let Some(gauges) = resp.payload.as_ref().and_then(|p| p.get("gauges")).and_then(|g| g.as_obj())
    else {
        return 0;
    };
    let mut total = 0.0f64;
    for (key, value) in gauges {
        let Some(rest) = key.strip_prefix("shard") else { continue };
        let Some(idx) = rest.strip_suffix("_depth") else { continue };
        if !idx.is_empty() && idx.bytes().all(|b| b.is_ascii_digit()) {
            total += value.as_f64().unwrap_or(0.0).max(0.0);
        }
    }
    total as usize
}

/// Sentinel depth for replicas the router must not pick this round.
const UNAVAILABLE: usize = usize::MAX / 4;

/// A [`Backend`] that forwards batches to remote worker replicas over the
/// serving protocol. See the module docs for the lifecycle.
pub struct RemoteBackend {
    shared: Arc<RemoteShared>,
    health: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl RemoteBackend {
    /// Connect to `addrs`, handshake each worker, and start the health
    /// thread. A worker that completes the handshake with the wrong
    /// protocol version or model fingerprint fails the whole startup (the
    /// operator pointed the coordinator at the wrong fleet); a worker that
    /// is merely unreachable starts unhealthy and is retried in the
    /// background. Requires at least `max(1, min_replicas)` verified
    /// workers.
    pub fn connect(
        addrs: &[String],
        expected_fingerprint: &str,
        opts: RemoteOpts,
    ) -> Result<RemoteBackend> {
        if addrs.is_empty() {
            return Err(anyhow::anyhow!("no worker addresses given"));
        }
        let mut replicas = Vec::with_capacity(addrs.len());
        for a in addrs {
            let addr = a
                .to_socket_addrs()
                .map_err(|e| anyhow::anyhow!("bad worker address '{a}': {e}"))?
                .next()
                .ok_or_else(|| anyhow::anyhow!("worker address '{a}' resolved to nothing"))?;
            replicas.push(Arc::new(Replica::new(addr)));
        }
        let mut shared = RemoteShared {
            replicas,
            router: WeightedDepthRouter::new(),
            expected_fingerprint: expected_fingerprint.to_string(),
            input_dim: 0,
            max_batch: 0,
            opts,
            metrics: Mutex::new(None),
            stop: AtomicBool::new(false),
        };

        // Handshake every address; collect verified links. Mismatches are
        // hard errors, connect failures are retried by the health thread.
        let mut infos: Vec<Option<HelloInfo>> = Vec::new();
        let mut down: Vec<String> = Vec::new();
        for replica in &shared.replicas {
            match shared.handshake(&replica.addr, shared.opts.retries) {
                Ok((client, info)) => {
                    replica.install(client, &info);
                    infos.push(Some(info));
                }
                Err(e) => {
                    let msg = e.to_string();
                    // A completed-but-rejected handshake is fatal; a socket
                    // that never answered is just "not up yet".
                    if msg.contains("mismatch") || msg.contains("hello") {
                        return Err(e);
                    }
                    eprintln!("remote: worker {} unreachable at startup: {msg}", replica.addr);
                    down.push(replica.addr.to_string());
                    infos.push(None);
                }
            }
        }
        let up: Vec<&HelloInfo> = infos.iter().flatten().collect();
        let need = shared.opts.min_replicas.max(1);
        if up.len() < need {
            return Err(anyhow::anyhow!(
                "only {}/{} workers completed the handshake (need {need}; unreachable: [{}])",
                up.len(),
                shared.replicas.len(),
                down.join(", ")
            ));
        }
        let input_dim = up[0].input_dim;
        if up.iter().any(|i| i.input_dim != input_dim) {
            return Err(anyhow::anyhow!(
                "workers disagree on input_dim: {:?}",
                up.iter().map(|i| i.input_dim).collect::<Vec<_>>()
            ));
        }
        // The fleet's batch contract is the smallest worker's.
        let max_batch = up.iter().map(|i| i.max_batch).min().unwrap_or(1).max(1);
        shared.input_dim = input_dim;
        shared.max_batch = max_batch;
        shared.router.set_costs(shared.costs());
        let shared = Arc::new(shared);

        let health = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("condcomp-replica-health".into())
                .spawn(move || {
                    let step = Duration::from_millis(20);
                    let mut since_tick = Duration::ZERO;
                    while !shared.stop.load(Ordering::Relaxed) {
                        std::thread::sleep(step);
                        since_tick += step;
                        if since_tick >= shared.opts.health_interval {
                            since_tick = Duration::ZERO;
                            shared.health_tick();
                        }
                    }
                })
                .expect("spawn replica health thread")
        };
        Ok(RemoteBackend { shared, health: Mutex::new(Some(health)) })
    }

    /// Wire the coordinator's metrics registry in (after `Server::start`,
    /// which owns the registry): per-replica gauges and counters flow to
    /// `replica<i>_` stripes from here on.
    pub fn attach_metrics(&self, metrics: Arc<MetricsRegistry>) {
        *self.shared.metrics.lock().unwrap() = Some(metrics);
        self.shared.export_metrics();
    }

    /// Replica health snapshot (tests; diagnostics).
    pub fn healthy_replicas(&self) -> Vec<bool> {
        self.shared
            .replicas
            .iter()
            .map(|r| r.healthy.load(Ordering::Relaxed))
            .collect()
    }

    pub fn num_replicas(&self) -> usize {
        self.shared.replicas.len()
    }
}

impl Drop for RemoteBackend {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.health.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Backend for RemoteBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Remote
    }

    fn input_dim(&self) -> usize {
        self.shared.input_dim
    }

    fn max_batch(&self) -> usize {
        self.shared.max_batch
    }

    fn predict(&self, x: &Mat, mode: Mode) -> Result<(Mat, Option<f64>)> {
        let shared = &self.shared;
        let n = shared.replicas.len();
        let mut tried = vec![false; n];
        let mut saw_overload = false;
        for _ in 0..n {
            // Router input: the synthetic depth of each *available* replica
            // is our in-flight count plus its self-reported queue depth;
            // tried/unhealthy replicas get a sentinel the argmin can only
            // pick when nothing real is left.
            let depths: Vec<usize> = shared
                .replicas
                .iter()
                .enumerate()
                .map(|(i, r)| {
                    if tried[i] || !r.healthy.load(Ordering::Relaxed) {
                        UNAVAILABLE
                    } else {
                        r.inflight.load(Ordering::Relaxed) + r.depth.load(Ordering::Relaxed)
                    }
                })
                .collect();
            let pick = shared.router.pick(&depths);
            if depths.get(pick).copied().unwrap_or(UNAVAILABLE) >= UNAVAILABLE {
                break; // no healthy untried replica left
            }
            tried[pick] = true;
            match shared.try_replica(pick, x, mode) {
                Attempt::Ok(logits) => return Ok((logits, None)),
                Attempt::Hard(e) => {
                    return Err(anyhow::anyhow!(
                        "worker {}: {e}",
                        shared.replicas[pick].addr
                    ))
                }
                Attempt::Overloaded => saw_overload = true,
                Attempt::Failed => {}
            }
        }
        // Every candidate was down or shedding: report the batch as shed so
        // the server answers with the explicit `overloaded` reply (clients
        // retry later) instead of a hard error.
        if saw_overload {
            Err(anyhow::anyhow!("all worker replicas overloaded: request shed"))
        } else {
            Err(anyhow::anyhow!("no healthy worker replica: request shed"))
        }
    }

    fn refresh(&self) -> Result<()> {
        let mut ok = 0usize;
        let mut last_err: Option<String> = None;
        for (i, replica) in self.shared.replicas.iter().enumerate() {
            if !replica.healthy.load(Ordering::Relaxed) {
                continue;
            }
            let mut conn = replica.conn.lock().unwrap();
            match conn.as_mut().map(|c| c.refresh()) {
                Some(Ok(resp)) if resp.ok => ok += 1,
                Some(Ok(resp)) => {
                    last_err = resp.error.clone().or(Some("refresh rejected".into()))
                }
                Some(Err(e)) => {
                    drop(conn);
                    replica.mark_down();
                    self.shared.publish(|m| m.incr_replica(i, "failures"));
                    last_err = Some(e.to_string());
                }
                None => {}
            }
        }
        if ok > 0 {
            Ok(())
        } else {
            Err(anyhow::anyhow!(
                "refresh reached no worker: {}",
                last_err.unwrap_or_else(|| "no healthy replicas".into())
            ))
        }
    }

    fn model_fingerprint(&self) -> Option<String> {
        (!self.shared.expected_fingerprint.is_empty())
            .then(|| self.shared.expected_fingerprint.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::{model_fingerprint, LayerThreshold, PROFILE_SCHEMA_VERSION};
    use crate::config::{EstimatorConfig, NetConfig};
    use crate::coordinator::backend::NativeBackend;
    use crate::coordinator::server::{Server, ServerConfig};
    use crate::estimator::SignEstimatorSet;
    use crate::nn::Mlp;
    use crate::util::Pcg32;

    fn hello_resp(proto: u64, fingerprint: &str) -> Response {
        use crate::io::json::Json;
        let mut r = Response::ok(1);
        r.payload = Some(Json::obj(vec![
            ("proto", Json::Num(proto as f64)),
            ("version", Json::Str("t".into())),
            ("fingerprint", Json::Str(fingerprint.into())),
            ("input_dim", Json::Num(6.0)),
            ("max_batch", Json::Num(16.0)),
        ]));
        r
    }

    /// Satellite: the handshake verifies both directions — a good hello is
    /// accepted, version and fingerprint mismatches are refused with errors
    /// naming the mismatch.
    #[test]
    fn hello_verification_accepts_matches_and_rejects_mismatches() {
        let good = parse_hello(&hello_resp(PROTOCOL_VERSION, "mlp:6-10-3")).unwrap();
        assert_eq!(good.input_dim, 6);
        assert_eq!(good.max_batch, 16);
        assert!(good.profile.is_none());
        verify_hello(&good, "mlp:6-10-3").unwrap();
        verify_hello(&good, "").unwrap(); // no expectation → accept

        let old = parse_hello(&hello_resp(PROTOCOL_VERSION + 1, "mlp:6-10-3")).unwrap();
        let err = verify_hello(&old, "mlp:6-10-3").unwrap_err();
        assert!(err.contains("protocol version"), "{err}");

        let wrong = parse_hello(&hello_resp(PROTOCOL_VERSION, "mlp:9-9-9")).unwrap();
        let err = verify_hello(&wrong, "mlp:6-10-3").unwrap_err();
        assert!(err.contains("fingerprint"), "{err}");
        assert!(err.contains("mlp:9-9-9") && err.contains("mlp:6-10-3"), "{err}");
    }

    #[test]
    fn hello_parse_rejects_malformed_replies() {
        // An old worker that does not know the op answers with an error.
        let rejected = Response::err(1, "parse: unknown op 'hello'");
        let err = parse_hello(&rejected).unwrap_err();
        assert!(err.contains("unknown op"), "{err}");
        // A reply with no payload is not a handshake.
        let empty = Response::ok(1);
        assert!(parse_hello(&empty).is_err());
        // Missing fields are named.
        let mut partial = Response::ok(1);
        partial.payload = Some(crate::io::json::Json::obj(vec![(
            "proto",
            crate::io::json::Json::Num(1.0),
        )]));
        let err = parse_hello(&partial).unwrap_err();
        assert!(err.contains("fingerprint"), "{err}");
    }

    #[test]
    fn replica_cost_averages_best_kernel_columns() {
        let profile = MachineProfile {
            version: PROFILE_SCHEMA_VERSION,
            fingerprint: model_fingerprint(&[6, 10, 3]),
            hardware: "test".into(),
            threads: 1,
            budget_ms: 0,
            kernels: vec!["dense".into(), "masked".into()],
            layers: vec![
                LayerThreshold::from_kernel_costs(
                    0,
                    6,
                    10,
                    vec![("dense".into(), 2.0), ("masked".into(), 4.0)],
                    None,
                ),
                LayerThreshold::from_kernel_costs(
                    1,
                    10,
                    3,
                    vec![("dense".into(), 6.0), ("masked".into(), 4.0)],
                    None,
                ),
            ],
        };
        // Best per layer: 2.0 and 4.0 → mean 3.0.
        assert!((replica_cost(&profile) - 3.0).abs() < 1e-12);
        // No usable columns → uniform cost.
        let empty = MachineProfile { layers: vec![], ..profile };
        assert_eq!(replica_cost(&empty), 1.0);
    }

    fn worker(layers: Vec<usize>, ranks: &[usize]) -> (Server, String, String) {
        let mut rng = Pcg32::seeded(7);
        let net = Mlp::init(
            &NetConfig { layers, weight_sigma: 0.4, bias_init: 0.1 },
            &mut rng,
        );
        let fp = model_fingerprint(&net.layer_sizes());
        let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(ranks), 3);
        let backend = Arc::new(NativeBackend::new(net, est, 16));
        let server = Server::start(backend, ServerConfig::default()).unwrap();
        let addr = server.local_addr.to_string();
        (server, addr, fp)
    }

    fn fast_opts() -> RemoteOpts {
        RemoteOpts {
            connect_timeout: Duration::from_millis(500),
            read_timeout: Duration::from_secs(10),
            retries: 1,
            backoff: Duration::from_millis(10),
            health_interval: Duration::from_millis(50),
            min_replicas: 0,
        }
    }

    /// Satellite, over real TCP: the hello op round-trips the version and
    /// fingerprint, and a coordinator expecting a different model refuses
    /// the worker instead of serving its logits.
    #[test]
    fn coordinator_rejects_a_wrong_model_worker_over_tcp() {
        let (server, addr, fp) = worker(vec![6, 10, 8, 3], &[5, 4]);
        // Direct hello sees the protocol version and fingerprint.
        let mut client = Client::connect(&server.local_addr).unwrap();
        let info = parse_hello(&client.hello().unwrap()).unwrap();
        assert_eq!(info.proto, PROTOCOL_VERSION);
        assert_eq!(info.fingerprint, fp);
        assert_eq!(info.input_dim, 6);

        // Wrong expectation → hard startup error naming the fingerprints.
        let err = RemoteBackend::connect(
            &[addr.clone()],
            "mlp:784-1000-10",
            fast_opts(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("fingerprint mismatch"), "{err}");

        // Right expectation → verified link, bit-identical logits to a
        // direct client predict (lossless wire round-trip, same worker).
        let remote = RemoteBackend::connect(&[addr], &fp, fast_opts()).unwrap();
        assert_eq!(remote.kind(), BackendKind::Remote);
        assert_eq!(remote.input_dim(), 6);
        assert_eq!(remote.max_batch(), 16);
        assert_eq!(remote.healthy_replicas(), vec![true]);
        let mut rng = Pcg32::seeded(11);
        let x = Mat::randn(3, 6, 1.0, &mut rng);
        let direct = client.predict(x.clone(), Mode::ConditionalAe).unwrap();
        let (logits, _) = remote.predict(&x, Mode::ConditionalAe).unwrap();
        let want = direct.logits.unwrap();
        assert_eq!(logits.shape(), want.shape());
        for (a, b) in logits.as_slice().iter().zip(want.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        drop(remote);
        server.shutdown();
    }

    /// Every replica dead → predicts fail as "request shed" (the server
    /// turns that into explicit overloaded replies), and a worker that
    /// comes back is re-admitted by the health thread after a fresh
    /// handshake.
    #[test]
    fn dead_fleet_sheds_and_recovers() {
        let (server, addr, fp) = worker(vec![6, 10, 8, 3], &[5, 4]);
        let remote = RemoteBackend::connect(&[addr.clone()], &fp, fast_opts()).unwrap();
        server.shutdown();
        // The TCP connection is gone; the first predict fails over to
        // nothing and reports a shed.
        let mut rng = Pcg32::seeded(13);
        let x = Mat::randn(1, 6, 1.0, &mut rng);
        let mut last = None;
        for _ in 0..10 {
            match remote.predict(&x, Mode::ConditionalAe) {
                Err(e) => {
                    last = Some(e.to_string());
                    if last.as_deref().unwrap_or("").contains("request shed") {
                        break;
                    }
                }
                Ok(_) => std::thread::sleep(Duration::from_millis(20)),
            }
        }
        assert!(
            last.as_deref().unwrap_or("").contains("request shed"),
            "expected shed, got {last:?}"
        );
        assert_eq!(remote.healthy_replicas(), vec![false]);

        // Restart a compatible worker on the same port; the health thread
        // re-handshakes and the fleet serves again.
        let port: u16 = addr.rsplit(':').next().unwrap().parse().unwrap();
        let mut rng2 = Pcg32::seeded(7);
        let net = Mlp::init(
            &NetConfig { layers: vec![6, 10, 8, 3], weight_sigma: 0.4, bias_init: 0.1 },
            &mut rng2,
        );
        let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&[5, 4]), 3);
        let backend = Arc::new(NativeBackend::new(net, est, 16));
        let cfg = ServerConfig { addr: format!("127.0.0.1:{port}"), ..ServerConfig::default() };
        let revived = Server::start(backend, cfg).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while !remote.healthy_replicas()[0] {
            assert!(std::time::Instant::now() < deadline, "worker never re-admitted");
            std::thread::sleep(Duration::from_millis(20));
        }
        let (logits, _) = remote.predict(&x, Mode::ConditionalAe).unwrap();
        assert_eq!(logits.rows(), 1);
        drop(remote);
        revived.shutdown();
    }
}
