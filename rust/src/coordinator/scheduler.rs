//! Training orchestration over the PJRT path: Rust owns the epoch loop, the
//! data pipeline, the learning-rate/momentum schedules, and the per-epoch
//! SVD refresh (paper §3.5); the gradient step itself executes inside the
//! AOT-compiled `train_step` artifact. This is the three-layer story end to
//! end: L3 (this file) → L2 (jax train_step) → L1 (Pallas kernels).

use crate::config::TrainConfig;
use crate::data::{Batcher, Dataset};
use crate::nn::activations::{argmax_rows, error_rate};
use crate::runtime::ModelRuntime;
use crate::util::{Pcg32, Timer};
use anyhow::Result;

/// Per-epoch record from the PJRT training path.
#[derive(Clone, Debug)]
pub struct PjrtEpochStats {
    pub epoch: usize,
    pub train_loss: f32,
    pub valid_error: f32,
    /// Validation error through the estimator-augmented artifact.
    pub valid_error_ae: f32,
    pub lr: f32,
    pub momentum: f32,
    pub seconds: f64,
}

/// Drives training of a [`ModelRuntime`] with the paper's schedules.
pub struct TrainingScheduler {
    pub cfg: TrainConfig,
    pub quiet: bool,
}

impl TrainingScheduler {
    pub fn new(cfg: TrainConfig) -> TrainingScheduler {
        TrainingScheduler { cfg, quiet: true }
    }

    fn lr_at(&self, epoch: usize) -> f32 {
        self.cfg.lr * self.cfg.lr_decay.powi(epoch as i32)
    }

    fn momentum_at(&self, epoch: usize) -> f32 {
        (self.cfg.momentum * self.cfg.momentum_growth.powi(epoch as i32))
            .min(self.cfg.max_momentum)
    }

    /// Run `epochs` of training; refreshes estimator factors at every epoch
    /// boundary and evaluates both forward paths on the validation split.
    pub fn train(&self, rt: &mut ModelRuntime, data: &mut Dataset) -> Result<Vec<PjrtEpochStats>> {
        let mut rng = Pcg32::new(self.cfg.seed, 21);
        let batch = rt.batch;
        let mut batcher = Batcher::new(data.train.len(), batch);
        let mut history = Vec::new();
        for epoch in 0..self.cfg.epochs {
            let mut timer = Timer::start();
            // The paper's once-per-epoch SVD refresh, computed in Rust.
            rt.refresh_factors()?;
            batcher.shuffle(&mut rng);
            let (lr, momentum) = (self.lr_at(epoch), self.momentum_at(epoch));
            let mut loss_sum = 0.0f64;
            let mut steps = 0usize;
            for b in batcher.epoch(&data.train) {
                if b.x.rows() != batch {
                    continue; // artifact shape is fixed; drop the remainder
                }
                let loss = rt.train_step(&b.x, &b.y, lr, momentum)?;
                loss_sum += loss as f64;
                steps += 1;
            }
            // Refresh factors from the *post-epoch* weights for evaluation.
            rt.refresh_factors()?;
            let valid_error = self.evaluate(rt, data, false)?;
            let valid_error_ae = self.evaluate(rt, data, true)?;
            let stats = PjrtEpochStats {
                epoch,
                train_loss: if steps > 0 { (loss_sum / steps as f64) as f32 } else { f32::NAN },
                valid_error,
                valid_error_ae,
                lr,
                momentum,
                seconds: timer.lap_s(),
            };
            if !self.quiet {
                eprintln!(
                    "[pjrt] epoch {:>3}  loss {:.4}  valid {:.2}%  valid-ae {:.2}%  ({:.1}s)",
                    stats.epoch,
                    stats.train_loss,
                    stats.valid_error * 100.0,
                    stats.valid_error_ae * 100.0,
                    stats.seconds
                );
            }
            history.push(stats);
        }
        Ok(history)
    }

    /// Validation error through either artifact path.
    pub fn evaluate(&self, rt: &ModelRuntime, data: &Dataset, ae: bool) -> Result<f32> {
        let split = &data.valid;
        if split.is_empty() {
            return Ok(0.0);
        }
        let mut wrong = 0usize;
        let mut seen = 0usize;
        let mut at = 0usize;
        while at < split.len() {
            let n = rt.batch.min(split.len() - at);
            let x = split.x.rows_slice(at, n);
            let logits = if ae { rt.forward_ae(&x)? } else { rt.forward(&x)? };
            let pred = argmax_rows(&logits);
            wrong += pred
                .iter()
                .zip(&split.y[at..at + n])
                .filter(|(p, y)| p != y)
                .count();
            seen += n;
            at += n;
        }
        let _ = error_rate(&[], &[]); // keep the helper linked for doc parity
        Ok(wrong as f32 / seen as f32)
    }
}

// PJRT-dependent integration tests live in rust/tests/.
