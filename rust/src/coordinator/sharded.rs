//! Sharded dynamic batching: N independent [`DynamicBatcher`] queues, each
//! drained by its own executor worker, with a pluggable router in front.
//!
//! The single-queue batcher serializes every request through one
//! mutex+condvar before it ever reaches a parallel kernel; under heavy
//! concurrent traffic the queue lock — not the GEMM — gates tail latency.
//! Sharding splits the front door: requests are routed to one of
//! `server.shards` independent queues (round-robin by default, least-depth
//! as an option), so producers contend on 1/N of the locking and each shard
//! worker drains without waking the others.
//!
//! Invariants (property-tested in `tests/batcher_props.rs`):
//!
//! - **No request is lost or duplicated.** Every accepted item is drained by
//!   exactly one shard; after [`ShardedBatcher::close`] a push hands the
//!   item back ([`DynamicBatcher::push`]'s rejection contract) instead of
//!   stranding it on a queue nobody drains.
//! - **Per-shard batching semantics are unchanged.** Each shard is a plain
//!   `DynamicBatcher`: `max_batch`/`max_wait` hold per shard, items are
//!   never reordered within a shard and modes are never mixed in a batch.
//! - **Results do not depend on the shard count.** Batches execute the same
//!   kernels with the same serial accumulation order wherever they land, so
//!   per-request outputs are bit-identical between 1 and N shards (asserted
//!   end-to-end in `tests/serve_e2e.rs`).

use super::batcher::{BatchItem, DynamicBatcher, PushRejection};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

/// Which routing discipline places requests onto shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterKind {
    /// Rotate through shards in order: uniform load, zero coordination.
    RoundRobin,
    /// Send each request to the currently shallowest queue: better tail
    /// latency when request costs are skewed, at the price of reading every
    /// shard's depth on the push path.
    LeastDepth,
}

impl RouterKind {
    pub fn parse(s: &str) -> Option<RouterKind> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "roundrobin" | "rr" => Some(RouterKind::RoundRobin),
            "least-depth" | "leastdepth" | "ld" => Some(RouterKind::LeastDepth),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            RouterKind::RoundRobin => "round-robin",
            RouterKind::LeastDepth => "least-depth",
        }
    }
}

impl std::fmt::Display for RouterKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Pluggable shard-selection policy. Implementations must be cheap: `route`
/// runs on the connection-handler thread for every predict request.
pub trait ShardRouter: Send + Sync {
    /// Pick a shard in `0..num_shards` for one incoming item.
    /// `depths[i]` is shard `i`'s current queue depth — populated only when
    /// [`ShardRouter::needs_depths`] returns true (reading depths touches
    /// every shard's queue lock, which depth-blind policies must not pay).
    /// Out-of-range returns are clamped by the caller.
    fn route(&self, item: &BatchItem, num_shards: usize, depths: &[usize]) -> usize;
    /// Whether `route` wants the depth snapshot (default: yes).
    fn needs_depths(&self) -> bool {
        true
    }
    fn name(&self) -> &'static str;
}

/// Rotating counter; depth-blind, so a push touches exactly one shard lock.
pub struct RoundRobinRouter {
    next: AtomicUsize,
}

impl RoundRobinRouter {
    pub fn new() -> RoundRobinRouter {
        RoundRobinRouter { next: AtomicUsize::new(0) }
    }
}

impl Default for RoundRobinRouter {
    fn default() -> Self {
        Self::new()
    }
}

impl ShardRouter for RoundRobinRouter {
    fn route(&self, _item: &BatchItem, num_shards: usize, _depths: &[usize]) -> usize {
        self.next.fetch_add(1, Ordering::Relaxed) % num_shards
    }

    fn needs_depths(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        RouterKind::RoundRobin.as_str()
    }
}

/// Shallowest queue wins; ties go to the lowest shard index so the choice is
/// deterministic under equal load.
pub struct LeastDepthRouter;

impl ShardRouter for LeastDepthRouter {
    fn route(&self, _item: &BatchItem, _num_shards: usize, depths: &[usize]) -> usize {
        depths
            .iter()
            .enumerate()
            .min_by_key(|&(_, &d)| d)
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    fn name(&self) -> &'static str {
        RouterKind::LeastDepth.as_str()
    }
}

/// Cost-weighted least-depth: pick the target minimizing
/// `(depth + 1) × cost`, where `cost[i]` is a per-target relative cost
/// scalar (lower = faster). With uniform costs this is exactly
/// [`LeastDepthRouter`]; with heterogeneous costs a fast target absorbs
/// proportionally more work before a slow one is preferred. Built for the
/// replica-aware coordinator ([`crate::coordinator::remote::RemoteBackend`]
/// routes batches across worker replicas with per-replica costs from their
/// published machine profiles), but works as a shard router too.
pub struct WeightedDepthRouter {
    costs: std::sync::RwLock<Vec<f64>>,
}

impl WeightedDepthRouter {
    /// Uniform costs (pure least-depth) until [`Self::set_costs`] is called.
    pub fn new() -> WeightedDepthRouter {
        WeightedDepthRouter { costs: std::sync::RwLock::new(Vec::new()) }
    }

    pub fn with_costs(costs: Vec<f64>) -> WeightedDepthRouter {
        let r = WeightedDepthRouter::new();
        r.set_costs(costs);
        r
    }

    /// Install per-target relative costs; non-finite or non-positive entries
    /// fall back to 1.0. Targets beyond the vector also cost 1.0.
    pub fn set_costs(&self, costs: Vec<f64>) {
        let sane: Vec<f64> = costs
            .into_iter()
            .map(|c| if c.is_finite() && c > 0.0 { c } else { 1.0 })
            .collect();
        *self.costs.write().unwrap() = sane;
    }

    /// Argmin of `(depth + 1) × cost` over the depth snapshot; ties break to
    /// the lowest index so the choice is deterministic under equal load.
    pub fn pick(&self, depths: &[usize]) -> usize {
        let costs = self.costs.read().unwrap();
        let mut best = 0usize;
        let mut best_score = f64::INFINITY;
        for (i, &d) in depths.iter().enumerate() {
            let cost = costs.get(i).copied().unwrap_or(1.0);
            let score = (d as f64 + 1.0) * cost;
            if score < best_score {
                best_score = score;
                best = i;
            }
        }
        best
    }
}

impl Default for WeightedDepthRouter {
    fn default() -> Self {
        Self::new()
    }
}

impl ShardRouter for WeightedDepthRouter {
    fn route(&self, _item: &BatchItem, _num_shards: usize, depths: &[usize]) -> usize {
        self.pick(depths)
    }

    fn name(&self) -> &'static str {
        "weighted-depth"
    }
}

fn router_for(kind: RouterKind) -> Box<dyn ShardRouter> {
    match kind {
        RouterKind::RoundRobin => Box::new(RoundRobinRouter::new()),
        RouterKind::LeastDepth => Box::new(LeastDepthRouter),
    }
}

/// N independent batching queues behind one router.
pub struct ShardedBatcher {
    shards: Vec<DynamicBatcher>,
    router: Box<dyn ShardRouter>,
}

impl ShardedBatcher {
    /// `num_shards` queues (clamped to ≥ 1), each with the given
    /// `max_batch`/`max_wait`, routed by `kind`. Unbounded, no deadline.
    pub fn new(
        num_shards: usize,
        max_batch: usize,
        max_wait: Duration,
        kind: RouterKind,
    ) -> ShardedBatcher {
        ShardedBatcher::with_limits(num_shards, max_batch, max_wait, 0, None, kind)
    }

    /// As [`ShardedBatcher::new`] with a caller-supplied routing policy.
    pub fn with_router(
        num_shards: usize,
        max_batch: usize,
        max_wait: Duration,
        router: Box<dyn ShardRouter>,
    ) -> ShardedBatcher {
        ShardedBatcher::with_limits_router(num_shards, max_batch, max_wait, 0, None, router)
    }

    /// Fully-specified constructor: per-shard admission bound
    /// (`max_queue_depth` items per shard, 0 = unbounded) and optional
    /// per-request drain deadline, threaded to every shard's
    /// [`DynamicBatcher::with_limits`].
    pub fn with_limits(
        num_shards: usize,
        max_batch: usize,
        max_wait: Duration,
        max_queue_depth: usize,
        deadline: Option<Duration>,
        kind: RouterKind,
    ) -> ShardedBatcher {
        ShardedBatcher::with_limits_router(
            num_shards,
            max_batch,
            max_wait,
            max_queue_depth,
            deadline,
            router_for(kind),
        )
    }

    /// As [`ShardedBatcher::with_limits`] with a caller-supplied routing
    /// policy.
    pub fn with_limits_router(
        num_shards: usize,
        max_batch: usize,
        max_wait: Duration,
        max_queue_depth: usize,
        deadline: Option<Duration>,
        router: Box<dyn ShardRouter>,
    ) -> ShardedBatcher {
        let num_shards = num_shards.max(1);
        ShardedBatcher {
            shards: (0..num_shards)
                .map(|_| DynamicBatcher::with_limits(max_batch, max_wait, max_queue_depth, deadline))
                .collect(),
            router,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn router_name(&self) -> &'static str {
        self.router.name()
    }

    /// One shard's queue (executor workers drain their own shard directly).
    pub fn shard(&self, i: usize) -> &DynamicBatcher {
        &self.shards[i]
    }

    /// Route and enqueue one item. On success returns the shard index the
    /// item landed on; after [`ShardedBatcher::close`] (or when the target
    /// shard's bounded queue is full) the item is handed back inside a
    /// [`PushRejection`] (same contract as [`DynamicBatcher::push`]).
    ///
    /// The routing decision uses a snapshot of queue depths; depths may move
    /// between the snapshot and the enqueue, which can cost least-depth
    /// optimality but never correctness — the target shard accepts the item
    /// or (if the batcher closed or filled in between) rejects it back to
    /// the caller.
    pub fn push(&self, item: BatchItem) -> Result<usize, PushRejection> {
        let depths = if self.router.needs_depths() { self.depths() } else { Vec::new() };
        let shard = self
            .router
            .route(&item, self.shards.len(), &depths)
            .min(self.shards.len() - 1);
        self.shards[shard].push(item).map(|()| shard)
    }

    /// Queue depth per shard (router input; exported as gauges).
    pub fn depths(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.depth()).collect()
    }

    /// Total pushes shed at admission across shards (monotonic).
    pub fn shed_count(&self) -> u64 {
        self.shards.iter().map(|s| s.shed_count()).sum()
    }

    /// Total deadline-expired replies across shards (monotonic).
    pub fn expired_count(&self) -> u64 {
        self.shards.iter().map(|s| s.expired_count()).sum()
    }

    /// Total queued items across shards.
    pub fn depth(&self) -> usize {
        self.shards.iter().map(|s| s.depth()).sum()
    }

    /// Blocking: next batch from shard `i`. `None` once the batcher is
    /// closed *and* shard `i` has drained.
    pub fn next_batch(&self, i: usize) -> Option<Vec<BatchItem>> {
        self.shards[i].next_batch()
    }

    /// Close every shard. Already-queued items still drain (each shard's
    /// `next_batch` ships its remainder before returning `None`); new pushes
    /// are rejected back to the caller.
    pub fn close(&self) {
        for s in &self.shards {
            s.close();
        }
    }

    pub fn is_closed(&self) -> bool {
        self.shards.iter().all(|s| s.is_closed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{Mode, Response};
    use crate::linalg::Mat;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn item(id: u64) -> (BatchItem, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel();
        (
            BatchItem {
                id,
                mode: Mode::Control,
                x: Mat::zeros(1, 4),
                enqueued: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn round_robin_spreads_items_evenly() {
        let b = ShardedBatcher::new(3, 8, Duration::from_millis(5), RouterKind::RoundRobin);
        let mut placed = vec![0usize; 3];
        for i in 0..9 {
            let (it, _rx) = item(i);
            placed[b.push(it).unwrap()] += 1;
        }
        assert_eq!(placed, vec![3, 3, 3]);
        assert_eq!(b.depth(), 9);
        assert_eq!(b.depths(), vec![3, 3, 3]);
    }

    #[test]
    fn least_depth_targets_the_shallowest_shard() {
        let b = ShardedBatcher::new(3, 8, Duration::from_millis(5), RouterKind::LeastDepth);
        // Preload shards 0 and 1 by draining nothing: depths [1, 1, 0] after
        // two pushes (both go to the then-shallowest shard in index order).
        let (a, _r1) = item(1);
        assert_eq!(b.push(a).unwrap(), 0, "all-empty tie breaks to shard 0");
        let (c, _r2) = item(2);
        assert_eq!(b.push(c).unwrap(), 1);
        let (d, _r3) = item(3);
        assert_eq!(b.push(d).unwrap(), 2);
        let (e, _r4) = item(4);
        assert_eq!(b.push(e).unwrap(), 0, "equal depths tie back to shard 0");
    }

    #[test]
    fn shard_count_clamps_to_one() {
        let b = ShardedBatcher::new(0, 4, Duration::from_millis(1), RouterKind::RoundRobin);
        assert_eq!(b.num_shards(), 1);
        let (it, _rx) = item(7);
        assert_eq!(b.push(it).unwrap(), 0);
        assert_eq!(b.next_batch(0).unwrap().len(), 1);
    }

    #[test]
    fn close_rejects_new_and_drains_old_on_every_shard() {
        let b = ShardedBatcher::new(2, 4, Duration::from_millis(1), RouterKind::RoundRobin);
        let (a, _r1) = item(1);
        let (c, _r2) = item(2);
        b.push(a).unwrap();
        b.push(c).unwrap();
        b.close();
        assert!(b.is_closed());
        let (d, _r3) = item(3);
        let back = b.push(d).expect_err("closed batcher must hand the item back");
        assert!(!back.is_overloaded(), "close rejection, not a shed");
        assert_eq!(back.into_item().id, 3);
        // Both shards drain their pre-close item, then report done.
        let drained: usize = (0..2)
            .map(|i| {
                let n = b.next_batch(i).map(|batch| batch.len()).unwrap_or(0);
                assert!(b.next_batch(i).is_none());
                n
            })
            .sum();
        assert_eq!(drained, 2);
    }

    #[test]
    fn bounded_shards_shed_independently() {
        let b = ShardedBatcher::with_limits(
            2,
            8,
            Duration::from_millis(5),
            2,
            None,
            RouterKind::RoundRobin,
        );
        // Fill both shards (round-robin: 2 per shard).
        for i in 0..4u64 {
            let (it, _rx) = item(i);
            b.push(it).unwrap();
        }
        assert_eq!(b.depths(), vec![2, 2]);
        let (it, _rx) = item(9);
        let back = b.push(it).expect_err("full shard must shed");
        assert!(back.is_overloaded());
        assert_eq!(back.into_item().id, 9);
        assert_eq!(b.shed_count(), 1);
        assert_eq!(b.shard(0).pressure(), 1.0);
        // Shed pushes never changed any queue.
        assert_eq!(b.depth(), 4);
    }

    #[test]
    fn weighted_depth_defaults_to_least_depth() {
        let r = WeightedDepthRouter::new();
        assert_eq!(r.pick(&[2, 0, 1]), 1);
        assert_eq!(r.pick(&[1, 1, 1]), 0, "ties break to the lowest index");
        assert_eq!(r.pick(&[]), 0, "empty snapshot clamps to 0");
    }

    #[test]
    fn weighted_depth_prefers_cheap_targets_under_load() {
        // Target 0 is 4x faster: at equal depth it wins, and it keeps
        // winning until its queue is ~4x deeper than target 1's.
        let r = WeightedDepthRouter::with_costs(vec![0.25, 1.0]);
        assert_eq!(r.pick(&[0, 0]), 0);
        assert_eq!(r.pick(&[2, 0]), 0, "(2+1)*0.25 < (0+1)*1.0");
        assert_eq!(r.pick(&[4, 0]), 0, "(4+1)*0.25 still ahead");
        assert_eq!(r.pick(&[7, 1]), 0, "2.0 == 2.0 ties to lower index");
        assert_eq!(r.pick(&[8, 1]), 1, "finally saturated");
        // Bad costs degrade to 1.0 instead of poisoning the argmin; targets
        // beyond the cost vector also default to 1.0.
        r.set_costs(vec![f64::NAN, -3.0]);
        assert_eq!(r.pick(&[1, 0, 0]), 1);
        // And it routes through the ShardRouter trait like any other policy.
        let b = ShardedBatcher::with_limits_router(
            2,
            8,
            Duration::from_millis(5),
            0,
            None,
            Box::new(WeightedDepthRouter::with_costs(vec![1.0, 0.1])),
        );
        let (it, _rx) = item(1);
        assert_eq!(b.push(it).unwrap(), 1, "cheap shard wins the empty tie");
        assert_eq!(b.router_name(), "weighted-depth");
    }

    #[test]
    fn router_kind_parses_aliases() {
        assert_eq!(RouterKind::parse("round-robin"), Some(RouterKind::RoundRobin));
        assert_eq!(RouterKind::parse("RR"), Some(RouterKind::RoundRobin));
        assert_eq!(RouterKind::parse("least-depth"), Some(RouterKind::LeastDepth));
        assert_eq!(RouterKind::parse("LeastDepth"), Some(RouterKind::LeastDepth));
        assert_eq!(RouterKind::parse("nope"), None);
        assert_eq!(RouterKind::RoundRobin.to_string(), "round-robin");
    }
}
