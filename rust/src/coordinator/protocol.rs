//! Line-oriented JSON protocol between clients and the serving coordinator.
//!
//! Request (one JSON object per line):
//! `{"id": 7, "op": "predict", "mode": "ae", "x": [[...784 floats...], ...]}`
//! `{"id": 8, "op": "stats"}` · `{"id": 9, "op": "refresh"}` ·
//! `{"id": 0, "op": "ping"}` · `{"id": 10, "op": "trace"}` (flight-recorder
//! dump)
//!
//! Response: `{"id": 7, "ok": true, "classes": [3], "logits": [[...]],
//!             "latency_us": 812}` or `{"id": 7, "ok": false, "error": "..."}`.
//! Load-shed responses carry an explicit marker so clients can tell a shed
//! from a failure: `{"id": 7, "ok": false, "overloaded": true, "error":
//! "server overloaded: request shed"}` — retry later, nothing is wrong with
//! the request.

use crate::io::json::Json;
use crate::linalg::Mat;

/// Wire protocol version, carried in the `hello` handshake. Bump on any
/// incompatible change to the request/response shapes; the coordinator
/// refuses workers that answer with a different version.
pub const PROTOCOL_VERSION: u64 = 1;

/// Which forward path a predict request wants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Dense control network.
    Control,
    /// Estimator-augmented conditional network.
    ConditionalAe,
}

impl Mode {
    pub fn parse(s: &str) -> Option<Mode> {
        match s {
            "control" | "dense" => Some(Mode::Control),
            "ae" | "conditional" | "condcomp" => Some(Mode::ConditionalAe),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Mode::Control => "control",
            Mode::ConditionalAe => "ae",
        }
    }
}

/// A parsed client request.
#[derive(Clone, Debug)]
pub enum Request {
    Ping { id: u64 },
    /// Handshake: the reply payload carries the protocol version, the
    /// backend's model fingerprint, and (for workers) the calibrated
    /// `MachineProfile` — the coordinator verifies both before routing.
    Hello { id: u64 },
    Stats { id: u64 },
    /// Force an estimator-factor refresh from the current weights.
    Refresh { id: u64 },
    Predict { id: u64, mode: Mode, x: Mat },
    /// Dump the flight recorder (last N batch records with span timings).
    Trace { id: u64 },
    Shutdown { id: u64 },
}

impl Request {
    pub fn id(&self) -> u64 {
        match self {
            Request::Ping { id }
            | Request::Hello { id }
            | Request::Stats { id }
            | Request::Refresh { id }
            | Request::Predict { id, .. }
            | Request::Trace { id }
            | Request::Shutdown { id } => *id,
        }
    }

    /// Parse one protocol line.
    pub fn parse(line: &str) -> Result<Request, String> {
        let v = Json::parse(line).map_err(|e| e.to_string())?;
        let id = v.get("id").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
        let op = v
            .get("op")
            .and_then(|x| x.as_str())
            .ok_or_else(|| "missing 'op'".to_string())?;
        match op {
            "ping" => Ok(Request::Ping { id }),
            "hello" => Ok(Request::Hello { id }),
            "stats" => Ok(Request::Stats { id }),
            "refresh" => Ok(Request::Refresh { id }),
            "trace" => Ok(Request::Trace { id }),
            "shutdown" => Ok(Request::Shutdown { id }),
            "predict" => {
                let mode = v
                    .get("mode")
                    .and_then(|m| m.as_str())
                    .map(|m| Mode::parse(m).ok_or_else(|| format!("bad mode '{m}'")))
                    .transpose()?
                    .unwrap_or(Mode::ConditionalAe);
                let rows = v
                    .get("x")
                    .and_then(|x| x.as_arr())
                    .ok_or_else(|| "missing 'x'".to_string())?;
                if rows.is_empty() {
                    return Err("empty 'x'".into());
                }
                let first = rows[0]
                    .to_f32_vec()
                    .ok_or_else(|| "x rows must be float arrays".to_string())?;
                let d = first.len();
                let mut data = Vec::with_capacity(rows.len() * d);
                data.extend_from_slice(&first);
                for row in &rows[1..] {
                    let r = row
                        .to_f32_vec()
                        .ok_or_else(|| "x rows must be float arrays".to_string())?;
                    if r.len() != d {
                        return Err(format!("ragged x: {} vs {d}", r.len()));
                    }
                    data.extend_from_slice(&r);
                }
                Ok(Request::Predict { id, mode, x: Mat::from_vec(rows.len(), d, data) })
            }
            other => Err(format!("unknown op '{other}'")),
        }
    }

    /// Serialize (used by the bundled client/load generator).
    pub fn to_json_line(&self) -> String {
        match self {
            Request::Ping { id } => {
                Json::obj(vec![("id", Json::Num(*id as f64)), ("op", Json::Str("ping".into()))])
                    .to_string()
            }
            Request::Hello { id } => {
                Json::obj(vec![("id", Json::Num(*id as f64)), ("op", Json::Str("hello".into()))])
                    .to_string()
            }
            Request::Stats { id } => {
                Json::obj(vec![("id", Json::Num(*id as f64)), ("op", Json::Str("stats".into()))])
                    .to_string()
            }
            Request::Refresh { id } => {
                Json::obj(vec![("id", Json::Num(*id as f64)), ("op", Json::Str("refresh".into()))])
                    .to_string()
            }
            Request::Trace { id } => {
                Json::obj(vec![("id", Json::Num(*id as f64)), ("op", Json::Str("trace".into()))])
                    .to_string()
            }
            Request::Shutdown { id } => Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                ("op", Json::Str("shutdown".into())),
            ])
            .to_string(),
            Request::Predict { id, mode, x } => {
                let rows: Vec<Json> = (0..x.rows()).map(|i| Json::num_arr(x.row(i))).collect();
                Json::obj(vec![
                    ("id", Json::Num(*id as f64)),
                    ("op", Json::Str("predict".into())),
                    ("mode", Json::Str(mode.as_str().into())),
                    ("x", Json::Arr(rows)),
                ])
                .to_string()
            }
        }
    }
}

/// A server response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub ok: bool,
    pub error: Option<String>,
    pub classes: Vec<usize>,
    pub logits: Option<Mat>,
    pub latency_us: u64,
    /// Arbitrary payload for stats responses.
    pub payload: Option<Json>,
    /// Load-shed marker: the server rejected this request under overload
    /// (queue full or deadline expired). Always paired with `ok: false`;
    /// distinguishes "retry later" from a genuinely failed request.
    pub overloaded: bool,
}

impl Response {
    pub fn ok(id: u64) -> Response {
        Response {
            id,
            ok: true,
            error: None,
            classes: Vec::new(),
            logits: None,
            latency_us: 0,
            payload: None,
            overloaded: false,
        }
    }

    pub fn err(id: u64, msg: impl Into<String>) -> Response {
        Response { id, ok: false, error: Some(msg.into()), ..Response::ok(id) }
    }

    /// Explicit load-shed reply: the request was not executed because the
    /// server is saturated (bounded queue full, or the item outlived its
    /// deadline before a worker reached it).
    pub fn overloaded(id: u64) -> Response {
        Response {
            overloaded: true,
            ..Response::err(id, "server overloaded: request shed")
        }
    }

    pub fn to_json_line(&self) -> String {
        let mut fields: Vec<(&str, Json)> = vec![
            ("id", Json::Num(self.id as f64)),
            ("ok", Json::Bool(self.ok)),
            ("latency_us", Json::Num(self.latency_us as f64)),
        ];
        if self.overloaded {
            fields.push(("overloaded", Json::Bool(true)));
        }
        if let Some(e) = &self.error {
            fields.push(("error", Json::Str(e.clone())));
        }
        if !self.classes.is_empty() {
            fields.push((
                "classes",
                Json::Arr(self.classes.iter().map(|&c| Json::Num(c as f64)).collect()),
            ));
        }
        if let Some(l) = &self.logits {
            let rows: Vec<Json> = (0..l.rows()).map(|i| Json::num_arr(l.row(i))).collect();
            fields.push(("logits", Json::Arr(rows)));
        }
        if let Some(p) = &self.payload {
            fields.push(("stats", p.clone()));
        }
        Json::obj(fields).to_string()
    }

    /// Parse a response line (client side). Logits round-trip losslessly:
    /// the serializer prints each f32 (widened exactly to f64) with Rust's
    /// shortest-roundtrip formatting, so parse-back recovers the bits — the
    /// e2e suite leans on this to assert cross-shard bit-identity through
    /// the wire.
    pub fn parse(line: &str) -> Result<Response, String> {
        let v = Json::parse(line).map_err(|e| e.to_string())?;
        let id = v.get("id").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
        let ok = v.get("ok").and_then(|x| x.as_bool()).unwrap_or(false);
        let classes = v
            .get("classes")
            .and_then(|c| c.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default();
        let logits = v.get("logits").and_then(|l| l.as_arr()).and_then(parse_logits);
        Ok(Response {
            id,
            ok,
            error: v.get("error").and_then(|e| e.as_str()).map(String::from),
            classes,
            logits,
            latency_us: v.get("latency_us").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64,
            payload: v.get("stats").cloned(),
            overloaded: v.get("overloaded").and_then(|x| x.as_bool()).unwrap_or(false),
        })
    }
}

/// Rectangular rows-of-floats → `Mat`; `None` on ragged or non-numeric rows
/// (tolerated: logits are an optional response field).
fn parse_logits(rows: &[Json]) -> Option<Mat> {
    let first = rows.first()?.to_f32_vec()?;
    let d = first.len();
    let mut data = Vec::with_capacity(rows.len() * d);
    data.extend_from_slice(&first);
    for row in &rows[1..] {
        let r = row.to_f32_vec()?;
        if r.len() != d {
            return None;
        }
        data.extend_from_slice(&r);
    }
    Some(Mat::from_vec(rows.len(), d, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predict_roundtrip() {
        let x = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let req = Request::Predict { id: 42, mode: Mode::ConditionalAe, x };
        let line = req.to_json_line();
        match Request::parse(&line).unwrap() {
            Request::Predict { id, mode, x } => {
                assert_eq!(id, 42);
                assert_eq!(mode, Mode::ConditionalAe);
                assert_eq!(x.shape(), (2, 3));
                assert_eq!(x[(1, 2)], 6.0);
            }
            other => panic!("wrong parse: {other:?}"),
        }
    }

    #[test]
    fn control_ops_roundtrip() {
        for (req, want) in [
            (Request::Ping { id: 1 }, "ping"),
            (Request::Hello { id: 6 }, "hello"),
            (Request::Stats { id: 2 }, "stats"),
            (Request::Refresh { id: 3 }, "refresh"),
            (Request::Trace { id: 5 }, "trace"),
            (Request::Shutdown { id: 4 }, "shutdown"),
        ] {
            let line = req.to_json_line();
            assert!(line.contains(want));
            let back = Request::parse(&line).unwrap();
            assert_eq!(back.id(), req.id());
        }
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Request::parse("{}").is_err());
        assert!(Request::parse(r#"{"op":"predict","id":1}"#).is_err());
        assert!(Request::parse(r#"{"op":"predict","id":1,"x":[[1],[1,2]]}"#).is_err());
        assert!(Request::parse(r#"{"op":"predict","id":1,"x":[],"mode":"ae"}"#).is_err());
        assert!(Request::parse(r#"{"op":"nope","id":1}"#).is_err());
        assert!(Request::parse(r#"{"op":"predict","id":1,"x":[[1]],"mode":"zzz"}"#).is_err());
    }

    #[test]
    fn default_mode_is_ae() {
        let req = Request::parse(r#"{"op":"predict","id":1,"x":[[1,2]]}"#).unwrap();
        match req {
            Request::Predict { mode, .. } => assert_eq!(mode, Mode::ConditionalAe),
            _ => panic!(),
        }
    }

    #[test]
    fn response_roundtrip() {
        let mut r = Response::ok(9);
        r.classes = vec![3, 1];
        r.latency_us = 812;
        let line = r.to_json_line();
        let back = Response::parse(&line).unwrap();
        assert!(back.ok);
        assert_eq!(back.id, 9);
        assert_eq!(back.classes, vec![3, 1]);
        assert_eq!(back.latency_us, 812);
        let e = Response::err(4, "boom");
        let back = Response::parse(&e.to_json_line()).unwrap();
        assert!(!back.ok);
        assert_eq!(back.error.as_deref(), Some("boom"));
        assert!(!back.overloaded, "plain errors are not sheds");
    }

    /// The load-shed marker survives the wire in both directions, so
    /// clients can tell "retry later" from a failed request.
    #[test]
    fn overloaded_marker_roundtrips() {
        let shed = Response::overloaded(7);
        assert!(!shed.ok && shed.overloaded);
        let line = shed.to_json_line();
        assert!(line.contains("\"overloaded\":true"), "{line}");
        let back = Response::parse(&line).unwrap();
        assert!(back.overloaded && !back.ok);
        assert_eq!(back.id, 7);
        assert!(back.error.as_deref().unwrap_or("").contains("overloaded"));
        // Non-shed responses never carry the marker.
        let ok_line = Response::ok(8).to_json_line();
        assert!(!ok_line.contains("overloaded"), "{ok_line}");
        assert!(!Response::parse(&ok_line).unwrap().overloaded);
    }

    /// Logits must survive the wire bit-exactly — awkward f32s included —
    /// so loopback tests can assert cross-shard bit-identity on parsed
    /// responses.
    #[test]
    fn logits_roundtrip_bit_exactly() {
        let mut r = Response::ok(5);
        let vals = vec![
            0.1f32,
            -1.0 / 3.0,
            f32::MIN_POSITIVE,
            1.000_000_1,
            -2.5e-7,
            123_456.79,
        ];
        r.logits = Some(Mat::from_vec(2, 3, vals.clone()));
        let back = Response::parse(&r.to_json_line()).unwrap();
        let logits = back.logits.expect("logits parsed");
        assert_eq!(logits.shape(), (2, 3));
        for (got, want) in logits.as_slice().iter().zip(&vals) {
            assert_eq!(got.to_bits(), want.to_bits(), "{got} vs {want}");
        }
        // Absent logits stay absent; ragged logits are dropped, not fatal.
        assert!(Response::parse(&Response::ok(6).to_json_line()).unwrap().logits.is_none());
        let ragged = r#"{"id":1,"ok":true,"latency_us":0,"logits":[[1,2],[3]]}"#;
        assert!(Response::parse(ragged).unwrap().logits.is_none());
    }
}
