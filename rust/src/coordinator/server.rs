//! The serving coordinator: TCP acceptor, per-connection readers/writers,
//! a sharded dynamic batcher with one executor worker per shard, metrics.
//!
//! Execution model: the acceptor hands each connection to a reader thread;
//! predict requests are routed by the [`ShardedBatcher`] onto one of N
//! independent queues; each queue is drained by a dedicated executor that
//! owns an [`ExecCtx`] — a [`crate::parallel::PoolLease`] carving its
//! [`crate::parallel::partition_threads`] slice out of the **shared** pool,
//! a recycled [`crate::exec::ScratchArena`], and a per-shard
//! [`MetricsScope`]. The
//! leases together hold exactly the configured thread budget: an N-shard
//! server no longer spawns private pools beside a parked global one
//! (`threads_total` / `threads_leased` in the `stats` op make this
//! checkable from the wire). Per-request outputs are bit-identical for any
//! shard count and any lease width: batches run the same kernels in the
//! same serial accumulation order wherever they land.

use super::backend::Backend;
use super::batcher::{BatchItem, PushRejection};
use super::metrics::MetricsRegistry;
use super::protocol::{Mode, Request, Response};
use super::sharded::{RouterKind, ShardedBatcher};
use crate::condcomp::ElasticConfig;
use crate::exec::{ExecCtx, MetricsScope};
use crate::linalg::Mat;
use crate::parallel::{PoolLease, ThreadPool};
use crate::trace::{FlightRecord, FlightRecorder, SpanCollector};
use anyhow::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How shard executors get their compute slice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolMode {
    /// Lease each shard's slice from the shared pool (the default): total
    /// worker threads == the configured budget.
    Lease,
    /// Spawn a private [`ThreadPool`] per shard (the PR-3 baseline, kept so
    /// the bench sweep can record `serve_lease_vs_private`): budget threads
    /// in private pools *plus* the parked shared pool.
    PrivatePools,
}

/// Server knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address, e.g. "127.0.0.1:0" (0 = ephemeral port).
    pub addr: String,
    /// Dynamic-batching window (per shard).
    pub max_wait: Duration,
    /// Batcher shards, each with its own queue + executor worker
    /// (`server.shards` / `--shards`). 0 = derive from the compute-thread
    /// budget: one shard per two pool threads, capped at 8 — enough queues
    /// that the front door stops serializing, while each executor still
    /// gets a multi-thread pool slice.
    pub shards: usize,
    /// How requests are placed onto shards (`server.router` / `--router`).
    pub router: RouterKind,
    /// Compute-thread budget (0 = auto: available parallelism). Sizes the
    /// process-wide pool via `parallel::configure_global` (a no-op if the
    /// pool already exists — the `condcomp serve` CLI sizes it earlier,
    /// before dispatch calibration); the shard executors lease their
    /// slices from that pool.
    pub threads: usize,
    /// Leased slices of the shared pool (default) vs private per-shard
    /// pools (bench baseline).
    pub pool_mode: PoolMode,
    /// Enable span tracing at startup (`server.trace` / `--trace`; the
    /// `CONDCOMP_TRACE` env knob also enables it without a config change).
    /// Tracing changes observability only — span guards are inert when off.
    pub trace: bool,
    /// Flight-recorder capacity: the last N drained-batch records kept for
    /// the `trace` op (`server.trace_ring` / `--trace-ring`).
    pub trace_ring: usize,
    /// Bounded admission: per-shard queue depth at which new predict
    /// requests are shed with an explicit `overloaded` reply instead of
    /// being enqueued (`server.max_queue_depth` / `--max-queue-depth`;
    /// 0 = unbounded, the historical behavior).
    pub max_queue_depth: usize,
    /// Per-request deadline: enqueued items older than this at drain time
    /// are replied to as `overloaded` instead of being executed
    /// dead-on-arrival (`server.deadline_ms` / `--deadline-ms`; `None` =
    /// no deadline).
    pub deadline: Option<Duration>,
    /// Quality-elastic dispatch: when a shard's queue pressure crosses the
    /// elastic threshold, bias the kernel cost argmin toward the cheap
    /// masked class and truncate the estimator rank
    /// (`server.elastic` / `--elastic`). Off by default — pressure then
    /// affects admission only, never kernel choice.
    pub elastic: bool,
    /// Ceiling on the connection-acceptor pool: acceptors are spawned on
    /// demand (one more whenever every live acceptor is busy inside a
    /// connection) up to this many. Not CLI-exposed; the default is far
    /// above any realistic concurrent-connection count for this server.
    pub max_acceptors: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_wait: Duration::from_millis(2),
            shards: 0,
            router: RouterKind::RoundRobin,
            threads: 0,
            pool_mode: PoolMode::Lease,
            trace: false,
            trace_ring: 64,
            max_queue_depth: 0,
            deadline: None,
            elastic: false,
            max_acceptors: 64,
        }
    }
}

/// Shard count for a compute budget of `threads` when the operator passes 0.
pub fn derive_shards(threads: usize) -> usize {
    (threads / 2).clamp(1, 8)
}

/// A running server; dropping it (or calling [`Server::shutdown`]) stops the
/// threads.
pub struct Server {
    pub local_addr: std::net::SocketAddr,
    pub metrics: Arc<MetricsRegistry>,
    /// The batch flight recorder (dumped by the `trace` op; only written
    /// while tracing is enabled).
    pub recorder: Arc<FlightRecorder>,
    batcher: Arc<ShardedBatcher>,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start accepting connections on the process-wide shared pool; returns
    /// once the listener is bound.
    pub fn start(backend: Arc<dyn Backend>, cfg: ServerConfig) -> Result<Server> {
        if cfg.threads > 0 {
            crate::parallel::configure_global(cfg.threads);
        }
        Server::start_on(backend, cfg, crate::parallel::global())
    }

    /// [`Server::start`] on an explicit compute pool (tests lease-account
    /// against a pool they own; embedders can isolate servers the same
    /// way). The pool must be `'static` because executor threads hold
    /// leases on it for the server's lifetime.
    pub fn start_on(
        backend: Arc<dyn Backend>,
        cfg: ServerConfig,
        pool: &'static ThreadPool,
    ) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let metrics = Arc::new(MetricsRegistry::new());
        // `--trace` turns the process-wide flag on; it never turns it *off*,
        // so the `CONDCOMP_TRACE` env knob (or an embedder's earlier
        // `trace::set_enabled`) survives a config that doesn't mention it.
        if cfg.trace {
            crate::trace::set_enabled(true);
        }
        let recorder = Arc::new(FlightRecorder::new(cfg.trace_ring));
        metrics.set_gauge("trace_enabled", u8::from(crate::trace::enabled()).into());
        metrics.set_gauge("trace_ring", recorder.capacity() as f64);
        let budget = pool.threads();
        metrics.set_gauge("pool_threads", budget as f64);
        metrics.set_gauge("threads_total", budget as f64);
        // Which ISA path the SIMD kernels run on this machine — the cached
        // probe the registry's kernels were constructed with, surfaced via
        // the `stats` op so operators can see it (and spot a forced-scalar
        // escape hatch or a missing feature) without shell access.
        let caps = crate::linalg::SimdCaps::get();
        metrics.set_gauge("simd_avx2", u8::from(caps.avx2).into());
        metrics.set_gauge("simd_fma", u8::from(caps.fma).into());
        metrics.set_gauge("simd_neon", u8::from(caps.neon).into());
        metrics.set_gauge("simd_forced_scalar", u8::from(caps.forced_scalar).into());
        eprintln!("serve: simd path = {}", caps.isa_label());
        // Export the backend's per-layer dispatch thresholds so operators
        // can see which α* table a deployment is actually running.
        if let Some(thresholds) = backend.dispatch_thresholds() {
            metrics.set_gauge("dispatch_layers", thresholds.len() as f64);
            for (l, t) in thresholds.iter().enumerate() {
                metrics.set_gauge(&format!("dispatch_alpha_star_l{l}"), *t);
            }
        }
        // Log the per-layer kernel-choice table: which registered kernel the
        // cost router picks at each grid density — the deployment's routing
        // decisions, visible before the first request lands.
        if let Some(lines) = backend.kernel_choice_lines() {
            for line in &lines {
                eprintln!("dispatch: {line}");
            }
        }
        let num_shards = if cfg.shards == 0 { derive_shards(budget) } else { cfg.shards };
        let slices = crate::parallel::partition_threads(budget, num_shards);
        let batcher = Arc::new(ShardedBatcher::with_limits(
            num_shards,
            backend.max_batch(),
            cfg.max_wait,
            cfg.max_queue_depth,
            cfg.deadline,
            cfg.router,
        ));
        metrics.set_gauge("shards", num_shards as f64);
        metrics.set_gauge("max_queue_depth", cfg.max_queue_depth as f64);
        metrics.set_gauge("elastic_enabled", u8::from(cfg.elastic).into());
        let stop = Arc::new(AtomicBool::new(false));
        let elastic = cfg.elastic;
        let mut threads = Vec::new();

        // One executor per shard: drain the shard's queue, run batches
        // through this shard's ExecCtx — its leased slice of the shared
        // thread budget, its recycled scratch arena, its metrics scope —
        // and fan results back out. Leases are taken here, before the
        // executors spawn, so the gauges are deterministic by the time
        // `start` returns and the slices cover the budget exactly
        // (`partition_threads` grants never race each other).
        for (shard, &slice) in slices.iter().enumerate() {
            // In the default Lease mode each executor carves its slice out
            // of the shared pool: no new threads. PrivatePools is the PR-3
            // baseline (private pool per shard, shared pool parked), kept
            // only so the bench sweep can measure lease-vs-private; a
            // single-shard "private" server always used the shared pool.
            let leased: Option<PoolLease<'static>> =
                if cfg.pool_mode == PoolMode::Lease || num_shards == 1 {
                    Some(pool.lease(slice))
                } else {
                    None
                };
            let (width, granted) = match &leased {
                Some(l) => (l.threads(), l.granted()),
                None => (slice, 0),
            };
            metrics.set_shard_gauge(shard, "pool_threads", width as f64);
            metrics.set_shard_gauge(shard, "lease_threads", granted as f64);
            let batcher = batcher.clone();
            let backend = backend.clone();
            let metrics = metrics.clone();
            let recorder = recorder.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("condcomp-shard-{shard}"))
                    .spawn(move || {
                        // If this executor panics, dump the flight recorder
                        // to stderr on the way down — the last N batches are
                        // exactly the post-mortem an operator wants.
                        let _panic_dump =
                            PanicFlightDump { shard, recorder: recorder.clone() };
                        let scope = MetricsScope::for_shard(metrics.clone(), shard);
                        // The lease span covers executor setup (private-pool
                        // construction / lease acquisition); it is recorded
                        // before the span collector attaches so it never
                        // pollutes the first batch's flight record.
                        let sp = scope.span("lease");
                        let private = if leased.is_none() {
                            Some(ThreadPool::new(slice))
                        } else {
                            None
                        };
                        let lease = match leased {
                            Some(l) => l,
                            // Private-pool baseline: a full lease on the
                            // executor's own pool.
                            None => private.as_ref().expect("private pool").lease(slice),
                        };
                        drop(sp);
                        let scope = scope.with_spans(Arc::new(SpanCollector::default()));
                        let mut ctx = ExecCtx::over(lease).with_metrics(scope);
                        if elastic {
                            ctx = ctx.with_elastic(ElasticConfig::default());
                        }
                        // Deadline sheds happen inside the batcher (it owns
                        // the reply channels); the executor exports them as
                        // per-shard counter deltas after each drain.
                        let mut seen_expired = 0u64;
                        while let Some(batch) = batcher.next_batch(shard) {
                            let queue = batcher.shard(shard);
                            let depth = queue.depth();
                            let pressure = queue.pressure();
                            ctx.set_pressure(pressure);
                            execute_batch(
                                shard,
                                batch,
                                backend.as_ref(),
                                &mut ctx,
                                depth,
                                pressure,
                                &recorder,
                            );
                            metrics.set_shard_gauge(shard, "depth", depth as f64);
                            metrics.set_shard_gauge(shard, "queue_pressure", pressure);
                            let expired = queue.expired_count();
                            if expired > seen_expired {
                                let delta = expired - seen_expired;
                                seen_expired = expired;
                                let sink = metrics.shard_sink(shard);
                                sink.add("deadline_expired", delta);
                                sink.add("shed_total", delta);
                            }
                        }
                    })
                    .expect("spawn shard executor"),
            );
        }
        metrics.set_gauge("threads_leased", pool.leased() as f64);

        // Acceptor pool: connection readers used to be spawned as one
        // detached thread per connection — unbounded and unaccounted. Now a
        // pool of acceptor threads shares the non-blocking listener; each
        // acceptor serves the accepted connection *inline* and another
        // acceptor is spawned on demand when the last free one goes busy,
        // up to `max_acceptors`. Live/free counts are exported as gauges so
        // saturation of the front door is visible from the `stats` op.
        {
            let acceptors = Arc::new(AcceptorPool {
                listener,
                max: cfg.max_acceptors.max(1),
                live: AtomicUsize::new(0),
                free: AtomicUsize::new(0),
                batcher: batcher.clone(),
                backend,
                metrics: metrics.clone(),
                stop: stop.clone(),
                pool,
                recorder: recorder.clone(),
            });
            AcceptorPool::spawn_acceptor(&acceptors);
        }

        Ok(Server { local_addr, metrics, recorder, batcher, stop, threads })
    }

    /// Number of batcher shards actually running (after 0 = auto
    /// derivation).
    pub fn num_shards(&self) -> usize {
        self.batcher.num_shards()
    }

    /// True once a shutdown has been requested (protocol `shutdown` op or
    /// [`Server::shutdown`]). The `condcomp serve` main loop polls this so
    /// a client-driven shutdown lets the process exit instead of sleeping
    /// forever.
    pub fn is_stopped(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Stop accepting, close every shard, and wait for the executors —
    /// which drain their queues first ([`ShardedBatcher::close`] ships
    /// already-accepted items before `next_batch` reports done), so no
    /// in-flight request loses its response.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.batcher.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.batcher.close();
    }
}

/// The connection front door: a pool of acceptor threads sharing one
/// non-blocking listener. Each acceptor serves its accepted connection
/// inline (reader loop + per-connection writer thread); when the last free
/// acceptor goes busy another one is spawned, up to `max` — so concurrent
/// connections are bounded and accounted (`acceptors_live` /
/// `acceptors_free` gauges) instead of each connection spawning an
/// untracked thread. Acceptors are detached: they observe the stop flag
/// between polls and exit on their own, so shutdown never blocks behind a
/// client that is still connected.
struct AcceptorPool {
    listener: TcpListener,
    max: usize,
    /// Acceptor threads currently running.
    live: AtomicUsize,
    /// Acceptors currently polling the listener (not serving a connection).
    free: AtomicUsize,
    batcher: Arc<ShardedBatcher>,
    backend: Arc<dyn Backend>,
    metrics: Arc<MetricsRegistry>,
    stop: Arc<AtomicBool>,
    pool: &'static ThreadPool,
    recorder: Arc<FlightRecorder>,
}

impl AcceptorPool {
    /// Spawn one more acceptor if the ceiling allows; a no-op at `max`.
    fn spawn_acceptor(this: &Arc<AcceptorPool>) {
        if this.live.fetch_add(1, Ordering::AcqRel) >= this.max {
            this.live.fetch_sub(1, Ordering::AcqRel);
            return;
        }
        this.free.fetch_add(1, Ordering::AcqRel);
        this.export_gauges();
        let me = this.clone();
        let n = this.live.load(Ordering::Relaxed);
        let _ = std::thread::Builder::new()
            .name(format!("condcomp-acceptor-{n}"))
            .spawn(move || me.run())
            .expect("spawn acceptor");
    }

    fn run(self: Arc<AcceptorPool>) {
        while !self.stop.load(Ordering::Relaxed) {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    // Going busy: if that empties the free set and there is
                    // headroom, add an acceptor so the next connection does
                    // not wait behind this one.
                    if self.free.fetch_sub(1, Ordering::AcqRel) == 1 {
                        AcceptorPool::spawn_acceptor(&self);
                    }
                    self.export_gauges();
                    self.metrics.incr("connections");
                    let _ = handle_connection(
                        stream,
                        &self.batcher,
                        self.backend.as_ref(),
                        &self.metrics,
                        &self.stop,
                        self.pool,
                        &self.recorder,
                    );
                    self.free.fetch_add(1, Ordering::AcqRel);
                    self.export_gauges();
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
        // Exiting from the polling state: leave both counts consistent.
        self.live.fetch_sub(1, Ordering::AcqRel);
        self.free.fetch_sub(1, Ordering::AcqRel);
        self.export_gauges();
    }

    fn export_gauges(&self) {
        self.metrics
            .set_gauge("acceptors_live", self.live.load(Ordering::Relaxed) as f64);
        self.metrics
            .set_gauge("acceptors_free", self.free.load(Ordering::Relaxed) as f64);
    }
}

/// Dumps the flight recorder to stderr if the owning executor thread
/// unwinds — the last N batch records are the post-mortem.
struct PanicFlightDump {
    shard: usize,
    recorder: Arc<FlightRecorder>,
}

impl Drop for PanicFlightDump {
    fn drop(&mut self) {
        if std::thread::panicking() && crate::trace::enabled() {
            let dump = self.recorder.dump().to_string();
            eprintln!("shard {} executor panicked; flight-recorder dump: {dump}", self.shard);
        }
    }
}

/// Run one drained batch through a shard's [`ExecCtx`] (leased pool slice +
/// recycled arena + per-shard metrics scope) and fan the responses back
/// out. One request increments `predictions` exactly once, whichever shard
/// executed it. Every metric lands in the shard's striped sink (plain
/// names; the snapshot materializes fleet totals and `shard<i>_` views).
/// When tracing is on, the batch additionally emits `queue`/`prep`/
/// `predict`/`reply` spans (the backend adds `estimator`/`kernel` inside
/// `predict`) and pushes one [`FlightRecord`] with the span breakdown.
fn execute_batch(
    shard: usize,
    batch: Vec<BatchItem>,
    backend: &dyn Backend,
    ctx: &mut ExecCtx<'_>,
    queue_depth: usize,
    pressure: f64,
    recorder: &FlightRecorder,
) {
    let t_batch = Instant::now();
    let traced = crate::trace::enabled();
    let mode = batch[0].mode;
    let n_items = batch.len();
    let total_rows: usize = batch.iter().map(|i| i.x.rows()).sum();
    ctx.metrics().incr("batches");
    ctx.metrics().add("batched_rows", total_rows as u64);
    ctx.metrics().set_gauge("last_batch_rows", total_rows as f64);
    // Queue wait: how long the oldest item in this batch sat between enqueue
    // and drain. Only measured when traced (it reads the clock per item).
    let queue_wait = if traced {
        let wait =
            batch.iter().map(|i| i.enqueued.elapsed().as_secs_f64()).fold(0.0, f64::max);
        ctx.metrics().observe_latency("span_queue", wait);
        wait
    } else {
        0.0
    };

    // Concatenate the batch.
    let d = batch[0].x.cols();
    let sp = ctx.metrics().span("prep");
    let mut x = Mat::zeros(total_rows, d);
    let mut at = 0usize;
    let mut ok_shapes = true;
    for item in &batch {
        if item.x.cols() != d {
            ok_shapes = false;
            break;
        }
        for r in 0..item.x.rows() {
            x.row_mut(at).copy_from_slice(item.x.row(r));
            at += 1;
        }
    }
    drop(sp);
    if !ok_shapes {
        for item in batch {
            let _ = item
                .reply
                .send(Response::err(item.id, "inconsistent input dims in batch"));
        }
        // Discard any spans so they can't leak into the next batch's record.
        ctx.metrics().drain_spans();
        return;
    }

    let t0 = Instant::now();
    let sp = ctx.metrics().span("predict");
    let result = backend.predict_ctx(&x, mode, ctx);
    drop(sp);
    let dt = t0.elapsed().as_secs_f64();
    ctx.metrics().observe_latency(&format!("predict_{}", mode.as_str()), dt);
    ctx.metrics().observe_latency("predict", dt);

    match result {
        Ok((logits, speedup)) => {
            if let Some(s) = speedup {
                ctx.metrics().set_gauge("flop_speedup", s);
            }
            let sp = ctx.metrics().span("reply");
            let mut row = 0usize;
            for item in batch {
                let n = item.x.rows();
                let slice = logits.rows_slice(row, n);
                row += n;
                let mut resp = Response::ok(item.id);
                resp.classes = crate::nn::activations::argmax_rows(&slice);
                resp.logits = Some(slice);
                resp.latency_us = item.enqueued.elapsed().as_micros() as u64;
                let _ = item.reply.send(resp);
            }
            drop(sp);
            // One counter update per batch, not per item.
            ctx.metrics().add("predictions", n_items as u64);
            // The logits buffer came from the ctx's arena; park it for the
            // next batch on this shard.
            ctx.put_buf(logits.into_vec());
        }
        Err(e) => {
            // A remote backend that tried every replica and got shed (or
            // found none healthy) reports a "request shed" error — forward
            // it as the explicit overloaded reply so clients see "retry
            // later", and exactly-one-reply conservation survives a worker
            // death behind the coordinator. Anything else is a real error.
            if e.to_string().contains("request shed") {
                ctx.metrics().add("shed_total", n_items as u64);
                for item in batch {
                    let _ = item.reply.send(Response::overloaded(item.id));
                }
            } else {
                ctx.metrics().incr("errors");
                for item in batch {
                    let _ = item.reply.send(Response::err(item.id, format!("backend: {e}")));
                }
            }
        }
    }

    if traced {
        let spans = ctx.metrics().drain_spans();
        // The kernels the cost router picked, in layer order (deduped: one
        // entry per distinct kernel).
        let mut kernels: Vec<String> = Vec::new();
        for s in &spans {
            if s.name == "kernel" {
                if let Some(k) = s.detail {
                    if !kernels.iter().any(|have| have == k) {
                        kernels.push(k.to_string());
                    }
                }
            }
        }
        recorder.record(FlightRecord {
            seq: recorder.next_seq(),
            shard,
            rows: total_rows,
            items: n_items,
            mode: mode.as_str(),
            kernels,
            queue_depth,
            pressure,
            queue_wait_us: queue_wait * 1e6,
            total_us: t_batch.elapsed().as_secs_f64() * 1e6,
            spans,
        });
    }
}

fn handle_connection(
    stream: TcpStream,
    batcher: &ShardedBatcher,
    backend: &dyn Backend,
    metrics: &MetricsRegistry,
    stop: &AtomicBool,
    pool: &'static ThreadPool,
    recorder: &FlightRecorder,
) -> Result<()> {
    stream.set_nodelay(true)?;
    let reader = BufReader::new(stream.try_clone()?);
    let write_stream = stream;
    // Writer thread: serializes responses (batching workers reply through the
    // channel, so ordering across pipelined requests is by completion).
    let (tx, rx) = channel::<Response>();
    let writer = std::thread::spawn(move || {
        let mut out = write_stream;
        while let Ok(resp) = rx.recv() {
            let line = resp.to_json_line();
            if out.write_all(line.as_bytes()).is_err() || out.write_all(b"\n").is_err() {
                break;
            }
            let _ = out.flush();
        }
    });

    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        metrics.incr("requests");
        // recv span: wire-line → parsed request. Connection readers have no
        // shard scope, so traced timings go straight to the global sink —
        // only while tracing is on, so the hot path stays stripe-only.
        let t_recv = crate::trace::enabled().then(Instant::now);
        let parsed = Request::parse(&line);
        if let Some(t) = t_recv {
            metrics.observe_latency("span_recv", t.elapsed().as_secs_f64());
        }
        match parsed {
            Err(e) => {
                let _ = tx.send(Response::err(0, format!("parse: {e}")));
            }
            Ok(Request::Ping { id }) => {
                let mut r = Response::ok(id);
                r.payload = Some(crate::io::json::Json::obj(vec![(
                    "version",
                    crate::io::json::Json::Str(crate::VERSION.into()),
                )]));
                let _ = tx.send(r);
            }
            Ok(Request::Hello { id }) => {
                // Handshake: protocol version + model fingerprint (+ the
                // calibrated machine profile, when the backend has one) so a
                // coordinator can verify this worker serves the same model
                // before routing any traffic, and hold its cost columns.
                use crate::io::json::Json;
                metrics.incr("hellos");
                let mut fields: Vec<(&str, Json)> = vec![
                    ("proto", Json::Num(super::protocol::PROTOCOL_VERSION as f64)),
                    ("version", Json::Str(crate::VERSION.into())),
                    (
                        "fingerprint",
                        Json::Str(backend.model_fingerprint().unwrap_or_default()),
                    ),
                    ("input_dim", Json::Num(backend.input_dim() as f64)),
                    ("max_batch", Json::Num(backend.max_batch() as f64)),
                ];
                if let Some(profile) = backend.machine_profile() {
                    fields.push(("profile", profile.to_json()));
                }
                let mut r = Response::ok(id);
                r.payload = Some(Json::obj(fields));
                let _ = tx.send(r);
            }
            Ok(Request::Stats { id }) => {
                // Refresh the thread-accounting gauges right before the
                // snapshot so the wire always reports live lease state —
                // the idle-pool claim is checkable from a `stats` call.
                metrics.set_gauge("threads_total", pool.threads() as f64);
                metrics.set_gauge("threads_leased", pool.leased() as f64);
                let mut r = Response::ok(id);
                r.payload = Some(metrics.snapshot());
                let _ = tx.send(r);
            }
            Ok(Request::Refresh { id }) => {
                metrics.incr("refreshes");
                let resp = match backend.refresh() {
                    Ok(()) => Response::ok(id),
                    Err(e) => Response::err(id, format!("refresh: {e}")),
                };
                let _ = tx.send(resp);
            }
            Ok(Request::Trace { id }) => {
                metrics.incr("trace_dumps");
                let mut r = Response::ok(id);
                r.payload = Some(recorder.dump());
                let _ = tx.send(r);
            }
            Ok(Request::Shutdown { id }) => {
                let _ = tx.send(Response::ok(id));
                stop.store(true, Ordering::Relaxed);
                batcher.close();
                break;
            }
            Ok(Request::Predict { id, mode, x }) => {
                if x.cols() != backend.input_dim() {
                    let _ = tx.send(Response::err(
                        id,
                        format!("input dim {} != model {}", x.cols(), backend.input_dim()),
                    ));
                    continue;
                }
                if x.rows() > backend.max_batch() {
                    let _ = tx.send(Response::err(
                        id,
                        format!("request rows {} > max batch {}", x.rows(), backend.max_batch()),
                    ));
                    continue;
                }
                let item = BatchItem { id, mode, x, enqueued: Instant::now(), reply: tx.clone() };
                // No metrics write on the accept path: the shard executor
                // already publishes its depth gauge after every drained
                // batch, and touching the (global) metrics mutex per request
                // would re-serialize the connection threads this split
                // exists to decouple. (The route span below only fires while
                // tracing is on.)
                let t_route = crate::trace::enabled().then(Instant::now);
                let pushed = batcher.push(item);
                if let Some(t) = t_route {
                    metrics.observe_latency("span_route", t.elapsed().as_secs_f64());
                }
                match pushed {
                    Ok(_shard) => {}
                    // Bounded admission: the shard's queue is at its depth
                    // limit — shed with an explicit overloaded reply (the
                    // client can back off and retry) instead of queueing
                    // work that would miss its deadline anyway.
                    Err(PushRejection::Overloaded(it)) => {
                        metrics.incr("shed_total");
                        let _ = tx.send(Response::overloaded(it.id));
                    }
                    // Batcher closed (shutdown in progress): the item is
                    // handed back, so the client still gets an answer
                    // instead of a silently dropped request.
                    Err(PushRejection::Closed(it)) => {
                        metrics.incr("rejected");
                        let _ = tx.send(Response::err(
                            it.id,
                            "server shutting down: request rejected",
                        ));
                    }
                }
            }
        }
    }
    drop(tx);
    let _ = writer.join();
    Ok(())
}

/// A minimal blocking client for the line protocol (tests, examples,
/// load generator).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

/// Bounded connection behavior for [`Client::connect_with`]: connect
/// timeout, optional read timeout, and retry-with-backoff — so a client
/// never blocks forever on a dead or still-starting address. Reused by the
/// coordinator's worker (re)connection path.
#[derive(Clone, Debug)]
pub struct ConnectOpts {
    /// Per-attempt TCP connect timeout.
    pub connect_timeout: Duration,
    /// Read timeout installed on the connected stream (`None` = block).
    pub read_timeout: Option<Duration>,
    /// Additional attempts after the first failed connect.
    pub retries: usize,
    /// Initial backoff between attempts (doubles each retry).
    pub backoff: Duration,
}

impl Default for ConnectOpts {
    fn default() -> Self {
        ConnectOpts {
            connect_timeout: Duration::from_secs(1),
            read_timeout: None,
            retries: 3,
            backoff: Duration::from_millis(100),
        }
    }
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        Client::connect_with(addr, &ConnectOpts::default())
    }

    /// Connect with bounded timeouts and retry-with-backoff (see
    /// [`ConnectOpts`]). Each failed attempt sleeps the current backoff and
    /// doubles it; the last error is returned once attempts are exhausted.
    pub fn connect_with(addr: &std::net::SocketAddr, opts: &ConnectOpts) -> Result<Client> {
        let mut backoff = opts.backoff;
        let mut last_err = None;
        for attempt in 0..=opts.retries {
            if attempt > 0 {
                std::thread::sleep(backoff);
                backoff = backoff.saturating_mul(2);
            }
            match TcpStream::connect_timeout(addr, opts.connect_timeout) {
                Ok(stream) => {
                    stream.set_nodelay(true)?;
                    stream.set_read_timeout(opts.read_timeout)?;
                    return Ok(Client {
                        reader: BufReader::new(stream.try_clone()?),
                        writer: stream,
                        next_id: 1,
                    });
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(anyhow::anyhow!(
            "connect to {addr} failed after {} attempts: {}",
            opts.retries + 1,
            last_err.expect("at least one attempt")
        ))
    }

    fn roundtrip(&mut self, req: &Request) -> Result<Response> {
        let line = req.to_json_line();
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut resp_line = String::new();
        self.reader.read_line(&mut resp_line)?;
        Response::parse(&resp_line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }

    pub fn ping(&mut self) -> Result<Response> {
        let id = self.bump();
        self.roundtrip(&Request::Ping { id })
    }

    /// Handshake: the payload carries the server's protocol version, model
    /// fingerprint, input/batch limits, and (for calibrated workers) the
    /// machine profile.
    pub fn hello(&mut self) -> Result<Response> {
        let id = self.bump();
        self.roundtrip(&Request::Hello { id })
    }

    pub fn stats(&mut self) -> Result<Response> {
        let id = self.bump();
        self.roundtrip(&Request::Stats { id })
    }

    pub fn refresh(&mut self) -> Result<Response> {
        let id = self.bump();
        self.roundtrip(&Request::Refresh { id })
    }

    /// Fetch the flight-recorder dump (the `trace` op); the payload is the
    /// ring's JSON (`ring_capacity` / `recorded` / `records`).
    pub fn trace(&mut self) -> Result<Response> {
        let id = self.bump();
        self.roundtrip(&Request::Trace { id })
    }

    pub fn shutdown(&mut self) -> Result<Response> {
        let id = self.bump();
        self.roundtrip(&Request::Shutdown { id })
    }

    pub fn predict(&mut self, x: Mat, mode: Mode) -> Result<Response> {
        let id = self.bump();
        self.roundtrip(&Request::Predict { id, mode, x })
    }

    fn bump(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EstimatorConfig, NetConfig};
    use crate::coordinator::backend::NativeBackend;
    use crate::estimator::SignEstimatorSet;
    use crate::nn::Mlp;
    use crate::util::Pcg32;

    fn start_server() -> (Server, std::net::SocketAddr) {
        let mut rng = Pcg32::seeded(7);
        let net = Mlp::init(
            &NetConfig { layers: vec![6, 10, 8, 3], weight_sigma: 0.4, bias_init: 0.1 },
            &mut rng,
        );
        let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&[5, 4]), 3);
        let backend = Arc::new(NativeBackend::new(net, est, 16));
        let server = Server::start(backend, ServerConfig::default()).unwrap();
        let addr = server.local_addr;
        (server, addr)
    }

    #[test]
    fn ping_stats_predict_roundtrip() {
        let (server, addr) = start_server();
        let mut client = Client::connect(&addr).unwrap();

        let pong = client.ping().unwrap();
        assert!(pong.ok);

        let mut rng = Pcg32::seeded(1);
        let x = Mat::randn(2, 6, 1.0, &mut rng);
        let resp = client.predict(x.clone(), Mode::ConditionalAe).unwrap();
        assert!(resp.ok, "predict failed: {:?}", resp.error);
        assert_eq!(resp.classes.len(), 2);
        assert!(resp.classes.iter().all(|&c| c < 3));

        let dense = client.predict(x, Mode::Control).unwrap();
        assert!(dense.ok);

        let stats = client.stats().unwrap();
        assert!(stats.ok);
        let counters = stats.payload.unwrap();
        let preds = counters
            .get("counters")
            .and_then(|c| c.get("predictions"))
            .and_then(|p| p.as_f64())
            .unwrap();
        // One increment per request item: two predict calls so far.
        assert!(preds >= 2.0, "predictions counter {preds}");

        server.shutdown();
    }

    #[test]
    fn bad_requests_are_rejected_not_fatal() {
        let (server, addr) = start_server();
        let mut client = Client::connect(&addr).unwrap();
        // Wrong input dim.
        let x = Mat::zeros(1, 5);
        let resp = client.predict(x, Mode::Control).unwrap();
        assert!(!resp.ok);
        // Oversized batch.
        let x = Mat::zeros(17, 6);
        let resp = client.predict(x, Mode::Control).unwrap();
        assert!(!resp.ok);
        // Server still alive.
        assert!(client.ping().unwrap().ok);
        server.shutdown();
    }

    #[test]
    fn concurrent_clients_are_batched() {
        let (server, addr) = start_server();
        let handles: Vec<_> = (0..6)
            .map(|_| {
                let addr = addr;
                std::thread::spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    let mut rng = Pcg32::seeded(3);
                    for _ in 0..5 {
                        let x = Mat::randn(1, 6, 1.0, &mut rng);
                        let resp = client.predict(x, Mode::ConditionalAe).unwrap();
                        assert!(resp.ok);
                        assert_eq!(resp.classes.len(), 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.metrics.counter("predictions"), 30);
        // With 6 concurrent clients and a 2ms window, at least some batches
        // must have coalesced multiple requests.
        let batches = server.metrics.counter("batches");
        assert!(batches <= 30, "batches {batches}");
        server.shutdown();
    }

    #[test]
    fn dispatch_threshold_gauges_exported_at_startup() {
        let (server, _addr) = start_server();
        // Native backend: two hidden layers → two α* gauges + the count.
        assert_eq!(server.metrics.gauge("dispatch_layers"), Some(2.0));
        assert!(server.metrics.gauge("dispatch_alpha_star_l0").is_some());
        assert!(server.metrics.gauge("dispatch_alpha_star_l1").is_some());
        server.shutdown();
    }

    #[test]
    fn sharded_server_exports_per_shard_gauges() {
        let mut rng = Pcg32::seeded(7);
        let net = Mlp::init(
            &NetConfig { layers: vec![6, 10, 8, 3], weight_sigma: 0.4, bias_init: 0.1 },
            &mut rng,
        );
        let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&[5, 4]), 3);
        let backend = Arc::new(NativeBackend::new(net, est, 16));
        // A pool this test owns: lease accounting is deterministic (the
        // process-global pool is shared with concurrently running tests).
        let pool: &'static ThreadPool = Box::leak(Box::new(ThreadPool::new(7)));
        let server = Server::start_on(
            backend,
            ServerConfig { shards: 3, ..ServerConfig::default() },
            pool,
        )
        .unwrap();
        assert_eq!(server.num_shards(), 3);
        assert_eq!(server.metrics.gauge("shards"), Some(3.0));
        // Every shard advertises its leased slice; together the leases
        // cover the whole budget — no private pools, no parked threads.
        assert_eq!(server.metrics.gauge("threads_total"), Some(7.0));
        assert_eq!(server.metrics.gauge("threads_leased"), Some(7.0));
        let widths: Vec<usize> = (0..3)
            .map(|s| server.metrics.shard_gauge(s, "pool_threads").expect("slice gauge") as usize)
            .collect();
        assert_eq!(widths, vec![3, 2, 2], "partition_threads(7, 3)");
        let granted: f64 = (0..3)
            .map(|s| server.metrics.shard_gauge(s, "lease_threads").expect("lease gauge"))
            .sum();
        assert_eq!(granted as usize, 7, "leases cover the budget exactly");
        assert_eq!(pool.leased(), 7);

        // Requests flow and are answered with shards > 1.
        let mut client = Client::connect(&server.local_addr).unwrap();
        for _ in 0..6 {
            let x = Mat::randn(1, 6, 1.0, &mut rng);
            assert!(client.predict(x, Mode::ConditionalAe).unwrap().ok);
        }
        assert_eq!(server.metrics.counter("predictions"), 6);
        server.shutdown();
        assert_eq!(pool.leased(), 0, "shutdown returns every shard lease");
    }

    #[test]
    fn derive_shards_tracks_the_thread_budget() {
        assert_eq!(derive_shards(1), 1);
        assert_eq!(derive_shards(2), 1);
        assert_eq!(derive_shards(4), 2);
        assert_eq!(derive_shards(8), 4);
        assert_eq!(derive_shards(64), 8, "capped");
    }

    #[test]
    fn refresh_over_protocol() {
        let (server, addr) = start_server();
        let mut client = Client::connect(&addr).unwrap();
        assert!(client.refresh().unwrap().ok);
        assert_eq!(server.metrics.counter("refreshes"), 1);
        server.shutdown();
    }
}
