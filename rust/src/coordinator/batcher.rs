//! Dynamic request batching: coalesce single-example predict requests into
//! the fixed-shape batches the AOT-compiled executables want.
//!
//! Policy: a worker blocks until at least one item is queued, then waits up
//! to `max_wait` for more, closing the batch early once `max_batch` items of
//! the same mode are available. Items are never reordered within a mode and
//! never dropped: accepted items always drain (including through shutdown),
//! and a closed batcher hands new items back to the caller instead of
//! accepting them into a queue nothing will drain.
//!
//! Overload behavior (admission control): with a bound configured
//! ([`DynamicBatcher::with_limits`]), a push onto a full queue is rejected
//! as [`PushRejection::Overloaded`] — the caller owns the item and must
//! reply (the server sends an explicit `overloaded` response, never a
//! silent drop). With a per-request deadline configured, items that are
//! dead on arrival at drain time (older than the deadline) are replied to
//! with the same overloaded response *before* they cost any compute; they
//! are never dropped without an answer. Queue fullness is also exported as
//! a [`DynamicBatcher::pressure`] signal in `[0, 1]` that the executors
//! feed to quality-elastic dispatch.
//!
//! The serving coordinator runs N of these behind a router
//! ([`super::sharded::ShardedBatcher`]); this type stays the single-queue
//! primitive.

use super::protocol::{Mode, Response};
use crate::linalg::Mat;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queued predict item (a single request, possibly multi-row).
#[derive(Debug)]
pub struct BatchItem {
    pub id: u64,
    pub mode: Mode,
    pub x: Mat,
    pub enqueued: Instant,
    /// Where the worker sends the finished response.
    pub reply: Sender<super::protocol::Response>,
}

/// Why a push handed its item back. Either way the caller owns the item
/// again and must reply to it — the batcher never strands a request.
#[derive(Debug)]
pub enum PushRejection {
    /// The batcher is closed (server shutting down).
    Closed(BatchItem),
    /// The queue is at `max_queue_depth` (load shed — reply `overloaded`).
    Overloaded(BatchItem),
}

impl PushRejection {
    /// The rejected item, whichever way it bounced.
    pub fn into_item(self) -> BatchItem {
        match self {
            PushRejection::Closed(it) | PushRejection::Overloaded(it) => it,
        }
    }

    pub fn item(&self) -> &BatchItem {
        match self {
            PushRejection::Closed(it) | PushRejection::Overloaded(it) => it,
        }
    }

    pub fn is_overloaded(&self) -> bool {
        matches!(self, PushRejection::Overloaded(_))
    }
}

/// Thread-safe batching queue.
pub struct DynamicBatcher {
    queue: Mutex<VecDeque<BatchItem>>,
    available: Condvar,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Admission bound: pushes beyond this depth are shed (0 = unbounded).
    max_queue_depth: usize,
    /// Per-request deadline: items older than this at drain time are
    /// replied to as overloaded instead of executed (`None` = no deadline).
    deadline: Option<Duration>,
    /// Pushes shed at admission (queue full). Monotonic.
    shed: AtomicU64,
    /// Items replied to as dead-on-arrival at drain time. Monotonic.
    expired: AtomicU64,
    /// Monotonic (false → true once). Checked under the queue lock where
    /// the push/drain invariant needs it, so a plain atomic suffices — no
    /// second mutex on the per-request hot path.
    closed: AtomicBool,
}

impl DynamicBatcher {
    /// Unbounded queue, no deadline — the pre-overload-control behavior.
    pub fn new(max_batch: usize, max_wait: Duration) -> DynamicBatcher {
        DynamicBatcher::with_limits(max_batch, max_wait, 0, None)
    }

    /// Bounded queue (`max_queue_depth` items, 0 = unbounded) with an
    /// optional per-request drain deadline.
    pub fn with_limits(
        max_batch: usize,
        max_wait: Duration,
        max_queue_depth: usize,
        deadline: Option<Duration>,
    ) -> DynamicBatcher {
        assert!(max_batch > 0);
        DynamicBatcher {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            max_batch,
            max_wait,
            max_queue_depth,
            deadline,
            shed: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            closed: AtomicBool::new(false),
        }
    }

    /// Enqueue a request. After [`DynamicBatcher::close`] the item is handed
    /// back instead of being queued — a closed batcher's queue is only ever
    /// drained (shutdown ships what is already in flight), so silently
    /// accepting the item would strand it with no worker to answer it. A
    /// push onto a full bounded queue is handed back as
    /// [`PushRejection::Overloaded`]. Either way the caller owns the
    /// rejected item and must reply to it.
    pub fn push(&self, item: BatchItem) -> Result<(), PushRejection> {
        // The closed check happens under the queue lock so it serializes
        // against the drain's final empty-and-closed check (also under the
        // queue lock): either this item is enqueued before the drain's last
        // look at the queue (and ships), or the drain already saw
        // closed=true — in which case queue-lock ordering plus the flag's
        // monotonicity guarantees this load sees true too and the item is
        // rejected. Never queued-after-drain and lost. The depth bound is
        // checked under the same lock, so depth can never exceed
        // `max_queue_depth` even under racing pushers.
        let mut q = self.queue.lock().unwrap();
        if self.closed.load(Ordering::Relaxed) {
            return Err(PushRejection::Closed(item));
        }
        if self.max_queue_depth > 0 && q.len() >= self.max_queue_depth {
            self.shed.fetch_add(1, Ordering::Relaxed);
            return Err(PushRejection::Overloaded(item));
        }
        q.push_back(item);
        drop(q);
        self.available.notify_one();
        Ok(())
    }

    /// Number of queued items (diagnostics).
    pub fn depth(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Queue fullness in `[0, 1]`: depth over `max_queue_depth`, or `0.0`
    /// when unbounded. This is the per-shard `queue_pressure` signal the
    /// executors export and quality-elastic dispatch keys off.
    pub fn pressure(&self) -> f64 {
        if self.max_queue_depth == 0 {
            return 0.0;
        }
        (self.depth() as f64 / self.max_queue_depth as f64).clamp(0.0, 1.0)
    }

    /// The configured admission bound (0 = unbounded).
    pub fn max_queue_depth(&self) -> usize {
        self.max_queue_depth
    }

    /// Pushes shed at admission so far (monotonic).
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Items replied to as deadline-expired at drain time so far (monotonic).
    pub fn expired_count(&self) -> u64 {
        self.expired.load(Ordering::Relaxed)
    }

    /// Mark the batcher closed and wake all waiters (server shutdown).
    pub fn close(&self) {
        self.closed.store(true, Ordering::Relaxed);
        self.available.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Relaxed)
    }

    /// Reply `overloaded` to front items that outlived the deadline — work
    /// that is dead on arrival must get an answer, not a silent drop, and
    /// must not cost a forward pass. FIFO order plus a uniform deadline
    /// means expiry is monotone from the front, so popping from the head
    /// catches every expired item.
    fn reply_expired(&self, q: &mut VecDeque<BatchItem>) {
        let Some(deadline) = self.deadline else { return };
        while let Some(front) = q.front() {
            if front.enqueued.elapsed() <= deadline {
                break;
            }
            let it = q.pop_front().expect("front was Some under the same lock");
            self.expired.fetch_add(1, Ordering::Relaxed);
            // A gone client (hung-up receiver) is fine; the reply is dropped
            // exactly like any other response to a closed connection.
            let _ = it.reply.send(Response::overloaded(it.id));
        }
    }

    /// Blocking: wait for the next batch. Returns `None` on shutdown.
    ///
    /// The batch contains consecutive items of one mode (the head's), with
    /// total row count ≤ `max_batch`. Deadline-expired items are replied to
    /// (and skipped) here, at drain time.
    pub fn next_batch(&self) -> Option<Vec<BatchItem>> {
        let mut q = self.queue.lock().unwrap();
        loop {
            // Answer dead-on-arrival work first: it must not ride into a
            // batch, and expiring the head may empty the queue entirely —
            // which is why everything below re-checks `front` instead of
            // assuming the queue it woke up to is still non-empty.
            self.reply_expired(&mut q);
            let Some(front) = q.front() else {
                if self.is_closed() {
                    return None;
                }
                let (guard, _timeout) = self
                    .available
                    .wait_timeout(q, Duration::from_millis(50))
                    .unwrap();
                q = guard;
                continue;
            };
            // Give latecomers a window to fill the batch, anchored at the
            // current head (recomputed every wakeup: another consumer or an
            // expiry may have changed which item is at the front).
            let mode = front.mode;
            let batch_deadline = front.enqueued + self.max_wait;
            let rows: usize = q
                .iter()
                .take_while(|i| i.mode == mode)
                .map(|i| i.x.rows())
                .scan(0usize, |acc, r| {
                    *acc += r;
                    Some(*acc)
                })
                .take_while(|&acc| acc <= self.max_batch)
                .count();
            let full = rows > 0 && {
                let filled: usize = q
                    .iter()
                    .take(rows)
                    .map(|i| i.x.rows())
                    .sum();
                filled >= self.max_batch
            };
            let now = Instant::now();
            if full || now >= batch_deadline || self.is_closed() {
                let take = rows.max(1).min(q.len()); // an oversized head still ships
                let batch: Vec<BatchItem> = q.drain(..take).collect();
                return Some(batch);
            }
            let wait = batch_deadline.saturating_duration_since(now);
            let (guard, _timeout) = self.available.wait_timeout(q, wait).unwrap();
            q = guard;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::Response;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn item(id: u64, mode: Mode, rows: usize) -> (BatchItem, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel();
        (
            BatchItem {
                id,
                mode,
                x: Mat::zeros(rows, 4),
                enqueued: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn batches_fill_to_max() {
        let b = DynamicBatcher::new(4, Duration::from_millis(200));
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (it, rx) = item(i, Mode::Control, 1);
            b.push(it).unwrap();
            rxs.push(rx);
        }
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        // Full batch must ship immediately, well before max_wait.
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn partial_batch_ships_after_max_wait() {
        let b = DynamicBatcher::new(8, Duration::from_millis(50));
        let (it, _rx) = item(1, Mode::Control, 1);
        b.push(it).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(40));
    }

    #[test]
    fn modes_are_not_mixed() {
        let b = DynamicBatcher::new(8, Duration::from_millis(10));
        let (a, _r1) = item(1, Mode::Control, 1);
        let (c, _r2) = item(2, Mode::ConditionalAe, 1);
        let (d, _r3) = item(3, Mode::Control, 1);
        b.push(a).unwrap();
        b.push(c).unwrap();
        b.push(d).unwrap();
        let first = b.next_batch().unwrap();
        assert_eq!(first.len(), 1, "head is control; next item is ae → batch breaks");
        assert_eq!(first[0].mode, Mode::Control);
        let second = b.next_batch().unwrap();
        assert_eq!(second[0].mode, Mode::ConditionalAe);
    }

    #[test]
    fn preserves_fifo_order() {
        let b = DynamicBatcher::new(16, Duration::from_millis(10));
        for i in 0..5 {
            let (it, _rx) = item(i, Mode::ConditionalAe, 1);
            b.push(it).unwrap();
        }
        let batch = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.iter().map(|i| i.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn push_after_close_is_rejected_and_queued_items_still_drain() {
        let b = DynamicBatcher::new(4, Duration::from_millis(10));
        let (before, _r1) = item(1, Mode::Control, 1);
        b.push(before).unwrap();
        b.close();
        // Queued-before-close item still ships (shutdown drains)…
        let (after, _r2) = item(2, Mode::Control, 1);
        let rejected = b.push(after).expect_err("push after close must reject");
        assert!(!rejected.is_overloaded(), "close rejection, not a shed");
        assert_eq!(rejected.into_item().id, 2, "rejected item handed back to the caller");
        let batch = b.next_batch().expect("pre-close item drains");
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 1);
        // …and once drained, the closed batcher yields None.
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn close_unblocks_waiters() {
        let b = Arc::new(DynamicBatcher::new(4, Duration::from_millis(10)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(30));
        b.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn multirow_items_count_toward_capacity() {
        let b = DynamicBatcher::new(4, Duration::from_millis(300));
        let (a, _r1) = item(1, Mode::Control, 3);
        let (c, _r2) = item(2, Mode::Control, 3);
        b.push(a).unwrap();
        b.push(c).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        // Only the first item fits within max_batch=4 rows... but since 3 < 4
        // and adding the second would exceed, the batch ships once the wait
        // expires or immediately if full. 3 rows < 4 → waits, then ships 1.
        assert_eq!(batch.len(), 1);
        let _ = t0;
    }

    #[test]
    fn bounded_queue_sheds_at_the_depth_limit() {
        let b = DynamicBatcher::with_limits(4, Duration::from_millis(200), 3, None);
        assert_eq!(b.pressure(), 0.0);
        for i in 0..3 {
            let (it, _rx) = item(i, Mode::Control, 1);
            b.push(it).unwrap();
        }
        assert_eq!(b.depth(), 3);
        assert_eq!(b.pressure(), 1.0);
        let (it, _rx) = item(9, Mode::Control, 1);
        let back = b.push(it).expect_err("4th push must shed");
        assert!(back.is_overloaded());
        assert_eq!(back.into_item().id, 9);
        assert_eq!(b.shed_count(), 1);
        assert_eq!(b.depth(), 3, "shed pushes never enter the queue");
        // Draining frees capacity again.
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pressure(), 0.0);
        let (it, _rx) = item(10, Mode::Control, 1);
        b.push(it).expect("capacity freed by the drain");
    }

    #[test]
    fn unbounded_queue_reports_zero_pressure() {
        let b = DynamicBatcher::new(2, Duration::from_millis(1));
        for i in 0..50 {
            let (it, _rx) = item(i, Mode::Control, 1);
            b.push(it).unwrap();
        }
        assert_eq!(b.pressure(), 0.0, "no bound → no pressure signal");
        assert_eq!(b.max_queue_depth(), 0);
        assert_eq!(b.shed_count(), 0);
    }

    #[test]
    fn deadline_expired_items_are_replied_to_not_dropped() {
        let b = DynamicBatcher::with_limits(
            8,
            Duration::from_millis(1),
            0,
            Some(Duration::from_millis(20)),
        );
        let (dead, dead_rx) = item(1, Mode::Control, 1);
        let (dead2, dead2_rx) = item(2, Mode::Control, 1);
        b.push(dead).unwrap();
        b.push(dead2).unwrap();
        std::thread::sleep(Duration::from_millis(40));
        let (live, _live_rx) = item(3, Mode::Control, 1);
        b.push(live).unwrap();
        let batch = b.next_batch().expect("live item still ships");
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 3, "only the non-expired item drains");
        assert_eq!(b.expired_count(), 2);
        // Both expired items got an explicit overloaded reply.
        for rx in [dead_rx, dead2_rx] {
            let resp = rx.try_recv().expect("expired item was replied to");
            assert!(resp.overloaded, "{resp:?}");
            assert!(!resp.ok);
        }
    }

    /// Regression: a wakeup that observes an emptied queue must not panic.
    /// With two consumers on one batcher, `close` wakes both; the first
    /// drains the only item and the second re-evaluates on an empty queue —
    /// the old code computed its wait deadline from `q.front().unwrap()`
    /// once and then dereferenced the front again inside the loop, so the
    /// second consumer (or any spurious wakeup after a concurrent drain)
    /// panicked instead of returning.
    #[test]
    fn concurrent_consumers_survive_wakeups_on_an_emptied_queue() {
        let b = Arc::new(DynamicBatcher::new(4, Duration::from_millis(200)));
        let (it, _rx) = item(1, Mode::Control, 1);
        b.push(it).unwrap();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let b = b.clone();
                std::thread::spawn(move || b.next_batch().map(|batch| batch.len()))
            })
            .collect();
        // Let both consumers reach their waits (one holds the item and is
        // inside the batching window; the other waits for a first item),
        // then close: both wake, exactly one gets the batch.
        std::thread::sleep(Duration::from_millis(50));
        b.close();
        let results: Vec<_> = consumers
            .into_iter()
            .map(|h| h.join().expect("consumer must not panic"))
            .collect();
        let mut got: Vec<_> = results.into_iter().flatten().collect();
        got.sort_unstable();
        assert_eq!(got, vec![1], "exactly one consumer drained the single item");
    }
}
