//! Dynamic request batching: coalesce single-example predict requests into
//! the fixed-shape batches the AOT-compiled executables want.
//!
//! Policy: a worker blocks until at least one item is queued, then waits up
//! to `max_wait` for more, closing the batch early once `max_batch` items of
//! the same mode are available. Items are never reordered within a mode and
//! never dropped: accepted items always drain (including through shutdown),
//! and a closed batcher hands new items back to the caller instead of
//! accepting them into a queue nothing will drain.
//!
//! The serving coordinator runs N of these behind a router
//! ([`super::sharded::ShardedBatcher`]); this type stays the single-queue
//! primitive.

use super::protocol::Mode;
use crate::linalg::Mat;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queued predict item (a single request, possibly multi-row).
#[derive(Debug)]
pub struct BatchItem {
    pub id: u64,
    pub mode: Mode,
    pub x: Mat,
    pub enqueued: Instant,
    /// Where the worker sends the finished response.
    pub reply: Sender<super::protocol::Response>,
}

/// Thread-safe batching queue.
pub struct DynamicBatcher {
    queue: Mutex<VecDeque<BatchItem>>,
    available: Condvar,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Monotonic (false → true once). Checked under the queue lock where
    /// the push/drain invariant needs it, so a plain atomic suffices — no
    /// second mutex on the per-request hot path.
    closed: AtomicBool,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> DynamicBatcher {
        assert!(max_batch > 0);
        DynamicBatcher {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            max_batch,
            max_wait,
            closed: AtomicBool::new(false),
        }
    }

    /// Enqueue a request. After [`DynamicBatcher::close`] the item is handed
    /// back instead of being queued — a closed batcher's queue is only ever
    /// drained (shutdown ships what is already in flight), so silently
    /// accepting the item would strand it with no worker to answer it. The
    /// caller owns the rejected item and must reply to it.
    pub fn push(&self, item: BatchItem) -> Result<(), BatchItem> {
        // The closed check happens under the queue lock so it serializes
        // against the drain's final empty-and-closed check (also under the
        // queue lock): either this item is enqueued before the drain's last
        // look at the queue (and ships), or the drain already saw
        // closed=true — in which case queue-lock ordering plus the flag's
        // monotonicity guarantees this load sees true too and the item is
        // rejected. Never queued-after-drain and lost.
        let mut q = self.queue.lock().unwrap();
        if self.closed.load(Ordering::Relaxed) {
            return Err(item);
        }
        q.push_back(item);
        drop(q);
        self.available.notify_one();
        Ok(())
    }

    /// Number of queued items (diagnostics).
    pub fn depth(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Mark the batcher closed and wake all waiters (server shutdown).
    pub fn close(&self) {
        self.closed.store(true, Ordering::Relaxed);
        self.available.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Relaxed)
    }

    /// Blocking: wait for the next batch. Returns `None` on shutdown.
    ///
    /// The batch contains consecutive items of one mode (the head's), with
    /// total row count ≤ `max_batch`.
    pub fn next_batch(&self) -> Option<Vec<BatchItem>> {
        let mut q = self.queue.lock().unwrap();
        // Wait for a first item.
        loop {
            if !q.is_empty() {
                break;
            }
            if self.is_closed() {
                return None;
            }
            let (guard, _timeout) = self
                .available
                .wait_timeout(q, Duration::from_millis(50))
                .unwrap();
            q = guard;
        }
        // Give latecomers a window to fill the batch.
        let deadline = q.front().map(|i| i.enqueued + self.max_wait).unwrap();
        loop {
            let mode = q.front().unwrap().mode;
            let rows: usize = q
                .iter()
                .take_while(|i| i.mode == mode)
                .map(|i| i.x.rows())
                .scan(0usize, |acc, r| {
                    *acc += r;
                    Some(*acc)
                })
                .take_while(|&acc| acc <= self.max_batch)
                .count();
            let full = rows > 0 && {
                let filled: usize = q
                    .iter()
                    .take(rows)
                    .map(|i| i.x.rows())
                    .sum();
                filled >= self.max_batch
            };
            let now = Instant::now();
            if full || now >= deadline || self.is_closed() {
                let take = rows.max(1).min(q.len()); // an oversized head still ships
                let batch: Vec<BatchItem> = q.drain(..take).collect();
                return Some(batch);
            }
            let wait = deadline.saturating_duration_since(now);
            let (guard, _timeout) = self.available.wait_timeout(q, wait).unwrap();
            q = guard;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::Response;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn item(id: u64, mode: Mode, rows: usize) -> (BatchItem, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel();
        (
            BatchItem {
                id,
                mode,
                x: Mat::zeros(rows, 4),
                enqueued: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn batches_fill_to_max() {
        let b = DynamicBatcher::new(4, Duration::from_millis(200));
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (it, rx) = item(i, Mode::Control, 1);
            b.push(it).unwrap();
            rxs.push(rx);
        }
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        // Full batch must ship immediately, well before max_wait.
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn partial_batch_ships_after_max_wait() {
        let b = DynamicBatcher::new(8, Duration::from_millis(50));
        let (it, _rx) = item(1, Mode::Control, 1);
        b.push(it).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(40));
    }

    #[test]
    fn modes_are_not_mixed() {
        let b = DynamicBatcher::new(8, Duration::from_millis(10));
        let (a, _r1) = item(1, Mode::Control, 1);
        let (c, _r2) = item(2, Mode::ConditionalAe, 1);
        let (d, _r3) = item(3, Mode::Control, 1);
        b.push(a).unwrap();
        b.push(c).unwrap();
        b.push(d).unwrap();
        let first = b.next_batch().unwrap();
        assert_eq!(first.len(), 1, "head is control; next item is ae → batch breaks");
        assert_eq!(first[0].mode, Mode::Control);
        let second = b.next_batch().unwrap();
        assert_eq!(second[0].mode, Mode::ConditionalAe);
    }

    #[test]
    fn preserves_fifo_order() {
        let b = DynamicBatcher::new(16, Duration::from_millis(10));
        for i in 0..5 {
            let (it, _rx) = item(i, Mode::ConditionalAe, 1);
            b.push(it).unwrap();
        }
        let batch = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.iter().map(|i| i.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn push_after_close_is_rejected_and_queued_items_still_drain() {
        let b = DynamicBatcher::new(4, Duration::from_millis(10));
        let (before, _r1) = item(1, Mode::Control, 1);
        b.push(before).unwrap();
        b.close();
        // Queued-before-close item still ships (shutdown drains)…
        let (after, _r2) = item(2, Mode::Control, 1);
        let rejected = b.push(after).expect_err("push after close must reject");
        assert_eq!(rejected.id, 2, "rejected item handed back to the caller");
        let batch = b.next_batch().expect("pre-close item drains");
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 1);
        // …and once drained, the closed batcher yields None.
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn close_unblocks_waiters() {
        let b = Arc::new(DynamicBatcher::new(4, Duration::from_millis(10)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(30));
        b.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn multirow_items_count_toward_capacity() {
        let b = DynamicBatcher::new(4, Duration::from_millis(300));
        let (a, _r1) = item(1, Mode::Control, 3);
        let (c, _r2) = item(2, Mode::Control, 3);
        b.push(a).unwrap();
        b.push(c).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        // Only the first item fits within max_batch=4 rows... but since 3 < 4
        // and adding the second would exceed, the batch ships once the wait
        // expires or immediately if full. 3 rows < 4 → waits, then ships 1.
        assert_eq!(batch.len(), 1);
        let _ = t0;
    }
}
