//! Serving metrics: counters, latency distributions, sparsity/FLOP gauges.

use crate::io::json::Json;
use crate::util::stats::Welford;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Thread-safe metrics registry shared by the server's workers.
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    latencies: BTreeMap<String, Welford>,
    gauges: BTreeMap<String, f64>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Record a latency observation in seconds.
    pub fn observe_latency(&self, name: &str, seconds: f64) {
        let mut g = self.inner.lock().unwrap();
        g.latencies
            .entry(name.to_string())
            .or_insert_with(Welford::new)
            .push(seconds);
    }

    /// Set a point-in-time gauge (achieved α, current speedup estimate, …).
    pub fn set_gauge(&self, name: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.gauges.insert(name.to_string(), value);
    }

    /// Canonical key for a per-shard metric (`shard3_depth`, …). One naming
    /// scheme shared by writers (shard executors) and readers (tests,
    /// dashboards scraping the stats snapshot).
    pub fn shard_key(shard: usize, name: &str) -> String {
        format!("shard{shard}_{name}")
    }

    /// Per-shard gauge (queue depth after each drained batch, last batch
    /// rows, …).
    pub fn set_shard_gauge(&self, shard: usize, name: &str, value: f64) {
        self.set_gauge(&MetricsRegistry::shard_key(shard, name), value);
    }

    pub fn shard_gauge(&self, shard: usize, name: &str) -> Option<f64> {
        self.gauge(&MetricsRegistry::shard_key(shard, name))
    }

    /// Per-shard latency distribution (batch execution seconds).
    pub fn observe_shard_latency(&self, shard: usize, name: &str, seconds: f64) {
        self.observe_latency(&MetricsRegistry::shard_key(shard, name), seconds);
    }

    /// Per-shard counter (batches drained, rows executed, …).
    pub fn incr_shard(&self, shard: usize, name: &str) {
        self.add(&MetricsRegistry::shard_key(shard, name), 1);
    }

    pub fn shard_counter(&self, shard: usize, name: &str) -> u64 {
        self.counter(&MetricsRegistry::shard_key(shard, name))
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    /// Mean latency in seconds, if observed.
    pub fn mean_latency(&self, name: &str) -> Option<f64> {
        let g = self.inner.lock().unwrap();
        g.latencies.get(name).filter(|w| w.count() > 0).map(|w| w.mean())
    }

    /// Export everything as a JSON object.
    pub fn snapshot(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let counters =
            Json::Obj(g.counters.iter().map(|(k, &v)| (k.clone(), Json::Num(v as f64))).collect());
        let gauges =
            Json::Obj(g.gauges.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect());
        let lat = Json::Obj(
            g.latencies
                .iter()
                .map(|(k, w)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::Num(w.count() as f64)),
                            ("mean_us", Json::Num(w.mean() * 1e6)),
                            ("std_us", Json::Num(w.std() * 1e6)),
                            ("min_us", Json::Num(if w.count() > 0 { w.min() * 1e6 } else { 0.0 })),
                            ("max_us", Json::Num(if w.count() > 0 { w.max() * 1e6 } else { 0.0 })),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![("counters", counters), ("gauges", gauges), ("latency", lat)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = MetricsRegistry::new();
        m.incr("req");
        m.add("req", 4);
        assert_eq!(m.counter("req"), 5);
        assert_eq!(m.counter("other"), 0);
    }

    #[test]
    fn latency_stats() {
        let m = MetricsRegistry::new();
        for x in [0.001, 0.002, 0.003] {
            m.observe_latency("predict", x);
        }
        assert!((m.mean_latency("predict").unwrap() - 0.002).abs() < 1e-9);
        assert!(m.mean_latency("none").is_none());
    }

    #[test]
    fn gauges_overwrite() {
        let m = MetricsRegistry::new();
        m.set_gauge("alpha", 0.2);
        m.set_gauge("alpha", 0.1);
        assert_eq!(m.gauge("alpha"), Some(0.1));
    }

    #[test]
    fn snapshot_is_valid_json() {
        let m = MetricsRegistry::new();
        m.incr("a");
        m.observe_latency("p", 0.5);
        m.set_gauge("g", 1.5);
        let s = m.snapshot().to_string();
        let parsed = Json::parse(&s).unwrap();
        assert_eq!(parsed.get("counters").unwrap().get("a").unwrap().as_f64(), Some(1.0));
        assert!(parsed.get("latency").unwrap().get("p").is_some());
    }

    #[test]
    fn per_shard_metrics_share_one_key_scheme() {
        let m = MetricsRegistry::new();
        m.set_shard_gauge(0, "depth", 3.0);
        m.set_shard_gauge(2, "depth", 7.0);
        m.incr_shard(2, "batches");
        m.incr_shard(2, "batches");
        m.observe_shard_latency(1, "predict", 0.004);
        assert_eq!(m.shard_gauge(0, "depth"), Some(3.0));
        assert_eq!(m.shard_gauge(2, "depth"), Some(7.0));
        assert_eq!(m.shard_gauge(1, "depth"), None);
        assert_eq!(m.shard_counter(2, "batches"), 2);
        assert_eq!(m.gauge("shard2_depth"), Some(7.0), "writers and readers agree on keys");
        assert!((m.mean_latency("shard1_predict").unwrap() - 0.004).abs() < 1e-12);
        // Snapshot carries the per-shard keys.
        let s = m.snapshot().to_string();
        assert!(s.contains("shard2_depth") && s.contains("shard1_predict"), "{s}");
    }

    #[test]
    fn registry_is_shareable_across_threads() {
        let m = std::sync::Arc::new(MetricsRegistry::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.incr("n");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 400);
    }
}
