//! Serving metrics: counters, log-bucketed latency histograms, and
//! sparsity/FLOP gauges, striped per shard.
//!
//! Layout: one **global sink** (connection counters, CLI one-shots, pool
//! gauges) plus one **[`ShardSink`] per shard executor**. Executors write
//! their per-batch metrics to their own sink under *plain* names —
//! uncontended lock, no key formatting on the hot path — and
//! [`MetricsRegistry::snapshot`] materializes both views at read time: the
//! merged fleet total under the plain key and the per-shard breakdown under
//! the canonical `shard<i>_` key ([`MetricsRegistry::shard_key`]). Readers
//! (tests, dashboards) keep addressing either key; accessors parse the
//! prefix and route to the right sink.
//!
//! Latency series are [`LogHistogram`]s (8 buckets per octave, ≈9% relative
//! error), so the snapshot exports tail percentiles (`p50_us`/`p95_us`/
//! `p99_us`) alongside the exact mean/std/min/max — the queue-pressure and
//! tail signals the ROADMAP's admission-control work reads.

use crate::io::json::Json;
use crate::util::stats::LogHistogram;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// One striped store of counters/gauges/histograms. Writes avoid the
/// alloc-per-call trap: the key is only cloned the first time a series
/// appears in this sink.
#[derive(Default)]
struct Sink {
    counters: BTreeMap<String, u64>,
    latencies: BTreeMap<String, LogHistogram>,
    gauges: BTreeMap<String, f64>,
}

impl Sink {
    fn add(&mut self, name: &str, by: u64) {
        match self.counters.get_mut(name) {
            Some(c) => *c += by,
            None => {
                self.counters.insert(name.to_string(), by);
            }
        }
    }

    fn observe(&mut self, name: &str, seconds: f64) {
        match self.latencies.get_mut(name) {
            Some(h) => h.push(seconds),
            None => {
                let mut h = LogHistogram::new();
                h.push(seconds);
                self.latencies.insert(name.to_string(), h);
            }
        }
    }

    fn set_gauge(&mut self, name: &str, value: f64) {
        match self.gauges.get_mut(name) {
            Some(g) => *g = value,
            None => {
                self.gauges.insert(name.to_string(), value);
            }
        }
    }
}

/// A shard executor's private metrics stripe. Handed out once at executor
/// spawn ([`MetricsRegistry::shard_sink`]) and cached in the executor's
/// `MetricsScope`, so hot-path writes take an uncontended per-shard lock
/// and never format a `shard<i>_` key — prefixing happens at snapshot.
pub struct ShardSink {
    shard: usize,
    inner: Mutex<Sink>,
}

impl ShardSink {
    fn new(shard: usize) -> ShardSink {
        ShardSink { shard, inner: Mutex::new(Sink::default()) }
    }

    /// The shard this stripe belongs to.
    pub fn shard(&self) -> usize {
        self.shard
    }

    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, by: u64) {
        self.inner.lock().unwrap().add(name, by);
    }

    pub fn observe(&self, name: &str, seconds: f64) {
        self.inner.lock().unwrap().observe(name, seconds);
    }

    pub fn set_gauge(&self, name: &str, value: f64) {
        self.inner.lock().unwrap().set_gauge(name, value);
    }
}

/// Thread-safe metrics registry shared by the server's workers: the global
/// sink plus the per-shard stripes, merged on read.
#[derive(Default)]
pub struct MetricsRegistry {
    global: Mutex<Sink>,
    shards: Mutex<Vec<Arc<ShardSink>>>,
    /// Per-replica stripes (the coordinator's remote-worker view): same
    /// machinery as the shard stripes under a `replica<i>_` key scheme. The
    /// two prefixes can never alias — each strict parser rejects the other's
    /// keys at the first character.
    replicas: Mutex<Vec<Arc<ShardSink>>>,
}

/// `<prefix><i>_<name>` → `(i, name)`; `None` for plain/global keys. Strict
/// on purpose: `shards_total` / `shard_` / `replicas` must not alias a
/// stripe.
fn parse_prefixed_key<'a>(prefix: &str, name: &'a str) -> Option<(usize, &'a str)> {
    let rest = name.strip_prefix(prefix)?;
    let digits_end = rest.find(|c: char| !c.is_ascii_digit())?;
    if digits_end == 0 {
        return None;
    }
    let (digits, tail) = rest.split_at(digits_end);
    Some((digits.parse().ok()?, tail.strip_prefix('_')?))
}

/// `shard<i>_<name>` → `(i, name)`; `None` for plain/global keys.
fn parse_shard_key(name: &str) -> Option<(usize, &str)> {
    parse_prefixed_key("shard", name)
}

/// `replica<i>_<name>` → `(i, name)`; `None` for plain/global keys.
fn parse_replica_key(name: &str) -> Option<(usize, &str)> {
    parse_prefixed_key("replica", name)
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// The stripe for `shard`, created on first request. Executors call
    /// this once at spawn and keep the `Arc`.
    pub fn shard_sink(&self, shard: usize) -> Arc<ShardSink> {
        let mut shards = self.shards.lock().unwrap();
        while shards.len() <= shard {
            let next = shards.len();
            shards.push(Arc::new(ShardSink::new(next)));
        }
        shards[shard].clone()
    }

    fn sinks(&self) -> Vec<Arc<ShardSink>> {
        self.shards.lock().unwrap().clone()
    }

    /// The stripe for `replica`, created on first request. The coordinator's
    /// remote backend calls this once per worker and keeps the `Arc`.
    pub fn replica_sink(&self, replica: usize) -> Arc<ShardSink> {
        let mut replicas = self.replicas.lock().unwrap();
        while replicas.len() <= replica {
            let next = replicas.len();
            replicas.push(Arc::new(ShardSink::new(next)));
        }
        replicas[replica].clone()
    }

    fn replica_sinks(&self) -> Vec<Arc<ShardSink>> {
        self.replicas.lock().unwrap().clone()
    }

    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, by: u64) {
        match parse_shard_key(name) {
            Some((shard, plain)) => self.shard_sink(shard).add(plain, by),
            None => match parse_replica_key(name) {
                Some((replica, plain)) => self.replica_sink(replica).add(plain, by),
                None => self.global.lock().unwrap().add(name, by),
            },
        }
    }

    /// Record a latency observation in seconds.
    pub fn observe_latency(&self, name: &str, seconds: f64) {
        match parse_shard_key(name) {
            Some((shard, plain)) => self.shard_sink(shard).observe(plain, seconds),
            None => match parse_replica_key(name) {
                Some((replica, plain)) => self.replica_sink(replica).observe(plain, seconds),
                None => self.global.lock().unwrap().observe(name, seconds),
            },
        }
    }

    /// Set a point-in-time gauge (achieved α, current speedup estimate, …).
    pub fn set_gauge(&self, name: &str, value: f64) {
        match parse_shard_key(name) {
            Some((shard, plain)) => self.shard_sink(shard).set_gauge(plain, value),
            None => match parse_replica_key(name) {
                Some((replica, plain)) => self.replica_sink(replica).set_gauge(plain, value),
                None => self.global.lock().unwrap().set_gauge(name, value),
            },
        }
    }

    /// Canonical key for a per-shard metric (`shard3_depth`, …). One naming
    /// scheme shared by writers (shard executors) and readers (tests,
    /// dashboards scraping the stats snapshot). Since the striped rework
    /// this is a *read-side* scheme: writers record plain names into their
    /// stripe and the snapshot emits the prefixed aliases.
    pub fn shard_key(shard: usize, name: &str) -> String {
        format!("shard{shard}_{name}")
    }

    /// Per-shard gauge (queue depth after each drained batch, last batch
    /// rows, …).
    pub fn set_shard_gauge(&self, shard: usize, name: &str, value: f64) {
        self.shard_sink(shard).set_gauge(name, value);
    }

    pub fn shard_gauge(&self, shard: usize, name: &str) -> Option<f64> {
        self.sinks().get(shard).and_then(|s| s.inner.lock().unwrap().gauges.get(name).copied())
    }

    /// Per-shard latency distribution (batch execution seconds).
    pub fn observe_shard_latency(&self, shard: usize, name: &str, seconds: f64) {
        self.shard_sink(shard).observe(name, seconds);
    }

    /// Per-shard counter (batches drained, rows executed, …).
    pub fn incr_shard(&self, shard: usize, name: &str) {
        self.shard_sink(shard).incr(name);
    }

    pub fn shard_counter(&self, shard: usize, name: &str) -> u64 {
        self.sinks()
            .get(shard)
            .and_then(|s| s.inner.lock().unwrap().counters.get(name).copied())
            .unwrap_or(0)
    }

    /// Canonical key for a per-replica metric (`replica2_depth`, …) —
    /// the read-side scheme mirroring [`MetricsRegistry::shard_key`].
    pub fn replica_key(replica: usize, name: &str) -> String {
        format!("replica{replica}_{name}")
    }

    /// Per-replica gauge (health, reported queue depth, routing cost, …).
    pub fn set_replica_gauge(&self, replica: usize, name: &str, value: f64) {
        self.replica_sink(replica).set_gauge(name, value);
    }

    pub fn replica_gauge(&self, replica: usize, name: &str) -> Option<f64> {
        self.replica_sinks()
            .get(replica)
            .and_then(|s| s.inner.lock().unwrap().gauges.get(name).copied())
    }

    /// Per-replica counter (batches routed, failures, reconnects, …).
    pub fn incr_replica(&self, replica: usize, name: &str) {
        self.replica_sink(replica).incr(name);
    }

    pub fn add_replica(&self, replica: usize, name: &str, by: u64) {
        self.replica_sink(replica).add(name, by);
    }

    pub fn replica_counter(&self, replica: usize, name: &str) -> u64 {
        self.replica_sinks()
            .get(replica)
            .and_then(|s| s.inner.lock().unwrap().counters.get(name).copied())
            .unwrap_or(0)
    }

    /// Merged counter: a plain name sums the global sink and every stripe
    /// (shard and replica); a `shard<i>_`/`replica<i>_` name reads that
    /// stripe alone.
    pub fn counter(&self, name: &str) -> u64 {
        if let Some((shard, plain)) = parse_shard_key(name) {
            return self.shard_counter(shard, plain);
        }
        if let Some((replica, plain)) = parse_replica_key(name) {
            return self.replica_counter(replica, plain);
        }
        let mut total = self.global.lock().unwrap().counters.get(name).copied().unwrap_or(0);
        for sink in self.sinks().iter().chain(self.replica_sinks().iter()) {
            total += sink.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0);
        }
        total
    }

    /// A plain name prefers the global sink, then the lowest shard (then
    /// replica) that set it; a prefixed name reads that stripe alone.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        if let Some((shard, plain)) = parse_shard_key(name) {
            return self.shard_gauge(shard, plain);
        }
        if let Some((replica, plain)) = parse_replica_key(name) {
            return self.replica_gauge(replica, plain);
        }
        if let Some(v) = self.global.lock().unwrap().gauges.get(name).copied() {
            return Some(v);
        }
        self.sinks()
            .iter()
            .chain(self.replica_sinks().iter())
            .find_map(|s| s.inner.lock().unwrap().gauges.get(name).copied())
    }

    /// The merged histogram behind `name` (global + stripes for a plain
    /// name, one stripe for a `shard<i>_` name), if any observation landed.
    fn merged_latency(&self, name: &str) -> Option<LogHistogram> {
        let mut merged = LogHistogram::new();
        if let Some((shard, plain)) = parse_shard_key(name) {
            if let Some(sink) = self.sinks().get(shard) {
                if let Some(h) = sink.inner.lock().unwrap().latencies.get(plain) {
                    merged.merge(h);
                }
            }
        } else if let Some((replica, plain)) = parse_replica_key(name) {
            if let Some(sink) = self.replica_sinks().get(replica) {
                if let Some(h) = sink.inner.lock().unwrap().latencies.get(plain) {
                    merged.merge(h);
                }
            }
        } else {
            if let Some(h) = self.global.lock().unwrap().latencies.get(name) {
                merged.merge(h);
            }
            for sink in self.sinks().iter().chain(self.replica_sinks().iter()) {
                if let Some(h) = sink.inner.lock().unwrap().latencies.get(name) {
                    merged.merge(h);
                }
            }
        }
        (merged.count() > 0).then_some(merged)
    }

    /// Mean latency in seconds, if observed.
    pub fn mean_latency(&self, name: &str) -> Option<f64> {
        self.merged_latency(name).map(|h| h.mean())
    }

    /// Bucketed latency quantile in seconds (`q` in `[0, 1]`), if observed.
    pub fn latency_quantile(&self, name: &str, q: f64) -> Option<f64> {
        self.merged_latency(name).and_then(|h| h.quantile(q))
    }

    fn latency_json(h: &LogHistogram) -> Json {
        Json::obj(vec![
            ("count", Json::Num(h.count() as f64)),
            ("mean_us", Json::Num(h.mean() * 1e6)),
            ("std_us", Json::Num(h.std() * 1e6)),
            ("min_us", Json::Num(h.min().unwrap_or(0.0) * 1e6)),
            ("max_us", Json::Num(h.max().unwrap_or(0.0) * 1e6)),
            ("p50_us", Json::Num(h.quantile(0.50).unwrap_or(0.0) * 1e6)),
            ("p95_us", Json::Num(h.quantile(0.95).unwrap_or(0.0) * 1e6)),
            ("p99_us", Json::Num(h.quantile(0.99).unwrap_or(0.0) * 1e6)),
        ])
    }

    /// Export everything as a JSON object: plain keys carry the fleet-wide
    /// merge (counters summed, histograms merged, global gauges winning
    /// over stripe gauges), `shard<i>_` keys carry each stripe verbatim.
    pub fn snapshot(&self) -> Json {
        let mut counters: BTreeMap<String, u64>;
        let mut gauges: BTreeMap<String, f64>;
        let mut latencies: BTreeMap<String, LogHistogram>;
        {
            let g = self.global.lock().unwrap();
            counters = g.counters.clone();
            gauges = g.gauges.clone();
            latencies = g.latencies.clone();
        }
        // Shard stripes first, then replica stripes — same merge semantics,
        // different read-side key prefix.
        for (sinks, key_for) in [
            (self.sinks(), MetricsRegistry::shard_key as fn(usize, &str) -> String),
            (self.replica_sinks(), MetricsRegistry::replica_key as fn(usize, &str) -> String),
        ] {
            for sink in sinks {
                let stripe = sink.inner.lock().unwrap();
                for (k, &v) in &stripe.counters {
                    *counters.entry(k.clone()).or_insert(0) += v;
                    counters.insert(key_for(sink.shard, k), v);
                }
                for (k, &v) in &stripe.gauges {
                    // Global (and lower-stripe) values win the plain key; the
                    // prefixed key is always this stripe's own.
                    gauges.entry(k.clone()).or_insert(v);
                    gauges.insert(key_for(sink.shard, k), v);
                }
                for (k, h) in &stripe.latencies {
                    latencies
                        .entry(k.clone())
                        .or_insert_with(LogHistogram::new)
                        .merge(h);
                    latencies.insert(key_for(sink.shard, k), h.clone());
                }
            }
        }
        Json::obj(vec![
            (
                "counters",
                Json::Obj(counters.into_iter().map(|(k, v)| (k, Json::Num(v as f64))).collect()),
            ),
            ("gauges", Json::Obj(gauges.into_iter().map(|(k, v)| (k, Json::Num(v))).collect())),
            (
                "latency",
                Json::Obj(
                    latencies
                        .iter()
                        .map(|(k, h)| (k.clone(), MetricsRegistry::latency_json(h)))
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = MetricsRegistry::new();
        m.incr("req");
        m.add("req", 4);
        assert_eq!(m.counter("req"), 5);
        assert_eq!(m.counter("other"), 0);
    }

    #[test]
    fn latency_stats() {
        let m = MetricsRegistry::new();
        for x in [0.001, 0.002, 0.003] {
            m.observe_latency("predict", x);
        }
        assert!((m.mean_latency("predict").unwrap() - 0.002).abs() < 1e-9);
        assert!(m.mean_latency("none").is_none());
        // Percentiles come from the log buckets: within one bucket (~9%).
        let p50 = m.latency_quantile("predict", 0.5).unwrap();
        assert!((p50 / 0.002 - 1.0).abs() < 0.10, "p50 {p50}");
    }

    #[test]
    fn gauges_overwrite() {
        let m = MetricsRegistry::new();
        m.set_gauge("alpha", 0.2);
        m.set_gauge("alpha", 0.1);
        assert_eq!(m.gauge("alpha"), Some(0.1));
    }

    #[test]
    fn snapshot_is_valid_json() {
        let m = MetricsRegistry::new();
        m.incr("a");
        m.observe_latency("p", 0.5);
        m.set_gauge("g", 1.5);
        let s = m.snapshot().to_string();
        let parsed = Json::parse(&s).unwrap();
        assert_eq!(parsed.get("counters").unwrap().get("a").unwrap().as_f64(), Some(1.0));
        assert!(parsed.get("latency").unwrap().get("p").is_some());
    }

    #[test]
    fn snapshot_latency_exports_percentiles() {
        let m = MetricsRegistry::new();
        for i in 1..=100 {
            m.observe_latency("p", i as f64 * 1e-3);
        }
        let snap = m.snapshot();
        let p = snap.get("latency").unwrap().get("p").unwrap();
        for key in ["count", "mean_us", "std_us", "min_us", "max_us", "p50_us", "p95_us", "p99_us"]
        {
            assert!(p.get(key).is_some(), "latency entry missing {key}");
        }
        let p50 = p.get("p50_us").unwrap().as_f64().unwrap();
        let p99 = p.get("p99_us").unwrap().as_f64().unwrap();
        let max = p.get("max_us").unwrap().as_f64().unwrap();
        assert!((p50 / 50_000.0 - 1.0).abs() < 0.10, "p50 {p50}");
        assert!((p99 / 99_000.0 - 1.0).abs() < 0.10, "p99 {p99}");
        assert!(p50 < p99 && p99 <= max, "ordering: {p50} {p99} {max}");
    }

    #[test]
    fn per_shard_metrics_share_one_key_scheme() {
        let m = MetricsRegistry::new();
        m.set_shard_gauge(0, "depth", 3.0);
        m.set_shard_gauge(2, "depth", 7.0);
        m.incr_shard(2, "batches");
        m.incr_shard(2, "batches");
        m.observe_shard_latency(1, "predict", 0.004);
        assert_eq!(m.shard_gauge(0, "depth"), Some(3.0));
        assert_eq!(m.shard_gauge(2, "depth"), Some(7.0));
        assert_eq!(m.shard_gauge(1, "depth"), None);
        assert_eq!(m.shard_counter(2, "batches"), 2);
        assert_eq!(m.gauge("shard2_depth"), Some(7.0), "writers and readers agree on keys");
        assert!((m.mean_latency("shard1_predict").unwrap() - 0.004).abs() < 1e-12);
        // Snapshot carries the per-shard keys.
        let s = m.snapshot().to_string();
        assert!(s.contains("shard2_depth") && s.contains("shard1_predict"), "{s}");
        // Plain keys carry the merge: counters sum, gauges fall back to the
        // lowest stripe, histograms merge.
        assert_eq!(m.counter("batches"), 2);
        assert_eq!(m.gauge("depth"), Some(3.0));
        assert!(m.mean_latency("predict").is_some());
    }

    #[test]
    fn shard_prefix_parsing_is_strict() {
        let m = MetricsRegistry::new();
        m.add("shards_total", 2);
        m.add("shard_less", 1);
        m.add("shard7_rows", 5);
        // The first two are global names, the third lands in stripe 7.
        assert_eq!(m.counter("shards_total"), 2);
        assert_eq!(m.counter("shard_less"), 1);
        assert_eq!(m.shard_counter(7, "rows"), 5);
        assert_eq!(m.counter("rows"), 5, "plain read merges the stripe");
    }

    /// The `replica<i>_` key scheme mirrors `shard<i>_` exactly: strict
    /// prefix parsing, stripe-verbatim prefixed keys, merged plain keys —
    /// and the two namespaces can never collide.
    #[test]
    fn per_replica_metrics_mirror_the_shard_key_scheme() {
        let m = MetricsRegistry::new();
        m.set_replica_gauge(0, "depth", 2.0);
        m.set_replica_gauge(1, "healthy", 1.0);
        m.incr_replica(1, "batches_routed");
        m.add("replica1_batches_routed", 2);
        m.observe_latency("replica0_predict", 0.003);
        assert_eq!(m.replica_gauge(0, "depth"), Some(2.0));
        assert_eq!(m.gauge("replica1_healthy"), Some(1.0));
        assert_eq!(m.replica_counter(1, "batches_routed"), 3);
        assert_eq!(m.counter("replica1_batches_routed"), 3);
        assert!((m.mean_latency("replica0_predict").unwrap() - 0.003).abs() < 1e-12);
        // Plain keys merge across replica stripes too.
        assert_eq!(m.counter("batches_routed"), 3);
        assert_eq!(m.gauge("depth"), Some(2.0));
        let s = m.snapshot().to_string();
        assert!(s.contains("replica0_depth") && s.contains("replica1_healthy"), "{s}");
        assert!(s.contains("replica0_predict"), "{s}");
        // A replica stripe never aliases a shard stripe of the same index.
        m.set_shard_gauge(0, "depth", 9.0);
        assert_eq!(m.gauge("replica0_depth"), Some(2.0));
        assert_eq!(m.gauge("shard0_depth"), Some(9.0));
    }

    #[test]
    fn replica_prefix_parsing_is_strict() {
        let m = MetricsRegistry::new();
        m.add("replicas", 3);
        m.add("replica_less", 1);
        m.add("replica4_routed", 5);
        assert_eq!(m.counter("replicas"), 3);
        assert_eq!(m.counter("replica_less"), 1);
        assert_eq!(m.replica_counter(4, "routed"), 5);
        assert_eq!(m.counter("routed"), 5, "plain read merges the stripe");
        // Neither parser claims the other's keys.
        assert_eq!(m.shard_counter(4, "routed"), 0);
        m.add("shard2_routed", 7);
        assert_eq!(m.replica_counter(2, "routed"), 0);
        assert_eq!(m.counter("routed"), 12, "plain read merges both families");
    }

    #[test]
    fn registry_is_shareable_across_threads() {
        let m = std::sync::Arc::new(MetricsRegistry::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.incr("n");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 400);
    }

    /// Satellite property: concurrent writers through per-shard stripes
    /// must merge to exactly what one sequential sink would hold.
    #[test]
    fn striped_merge_equals_single_sink_reference() {
        crate::util::proptest::property("striped_merge_matches_reference", 8, |rng| {
            let threads = 2 + (rng.next_u32() as usize % 3); // 2..=4 stripes
            let per = 50 + (rng.next_u32() as usize % 100); // 50..=149 obs each
            let seed = rng.next_u32() as u64;
            let m = Arc::new(MetricsRegistry::new());
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    let m = m.clone();
                    std::thread::spawn(move || {
                        let sink = m.shard_sink(t);
                        for i in 0..per {
                            sink.add("rows", (t + 1) as u64);
                            sink.observe("predict", obs(seed, t, i));
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            // Sequential reference over the identical observation stream.
            let mut reference = LogHistogram::new();
            let mut rows = 0u64;
            for t in 0..threads {
                for i in 0..per {
                    reference.push(obs(seed, t, i));
                    rows += (t + 1) as u64;
                }
            }
            assert_eq!(m.counter("rows"), rows);
            for t in 0..threads {
                assert_eq!(m.shard_counter(t, "rows"), (t as u64 + 1) * per as u64);
            }
            let merged = m.merged_latency("predict").unwrap();
            assert_eq!(merged.count(), reference.count());
            assert!((merged.mean() - reference.mean()).abs() < 1e-12 * reference.mean().abs());
            for q in [0.5, 0.95, 0.99] {
                let a = merged.quantile(q).unwrap();
                let b = reference.quantile(q).unwrap();
                assert!((a - b).abs() <= 1e-12 * b.abs(), "q{q}: striped {a} vs single {b}");
            }
            assert_eq!(merged.min(), reference.min());
            assert_eq!(merged.max(), reference.max());
        });
    }

    /// Deterministic pseudo-latency stream: same (seed, shard, index) →
    /// same value on both the striped and reference sides.
    fn obs(seed: u64, t: usize, i: usize) -> f64 {
        let mix = seed
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add((t as u64) << 32)
            .wrapping_add(i as u64)
            .wrapping_mul(0xbf58_476d_1ce4_e5b9);
        1e-5 * (1.0 + (mix % 9973) as f64 / 100.0)
    }
}
