//! Inference backends behind the router: native (pure Rust engine) and PJRT
//! (AOT artifacts). Both serve the same two modes — control and conditional.

use super::protocol::Mode;
use crate::autotune::{Autotuner, MachineProfile};
use crate::condcomp::registry::LayerOperands;
use crate::condcomp::{
    DispatchPolicy, FlopBreakdown, KernelId, KernelRegistry, MaskedLayer, PolicyTable,
};
use crate::estimator::SignEstimatorSet;
use crate::exec::ExecCtx;
use crate::linalg::{matmul_into_ctx, Mat, QuantizedLayer};
use crate::nn::mlp::add_bias;
use crate::nn::Mlp;
use crate::parallel::ThreadPool;
use crate::runtime::ModelRuntime;
use anyhow::Result;
use std::sync::{Arc, Mutex, RwLock};

// The arena moved to `exec` (it was never serving-specific); re-exported
// here so `coordinator::ScratchArena` keeps working.
pub use crate::exec::ScratchArena;

/// Which implementation serves the request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust engine (masked GEMM).
    Native,
    /// PJRT-compiled artifacts (Pallas kernels inside the HLO).
    Pjrt,
    /// Remote worker replicas over the TCP protocol (coordinator side).
    Remote,
}

/// A serving backend: maps a batch of inputs to logits.
pub trait Backend: Send + Sync {
    fn kind(&self) -> BackendKind;
    fn input_dim(&self) -> usize;
    /// Largest batch accepted per call.
    fn max_batch(&self) -> usize;
    /// Forward `x` in the given mode; returns logits and, for the
    /// conditional mode, the achieved FLOP speedup vs dense (Eq. 11).
    fn predict(&self, x: &Mat, mode: Mode) -> Result<(Mat, Option<f64>)>;
    /// Forward `x` through a caller-owned [`ExecCtx`] — the shard-executor
    /// entry point: each shard worker brings a leased slice of the shared
    /// thread budget, its recycled buffer arena, and its metrics scope in
    /// one handle, so concurrent shards share neither locks nor buffers.
    /// Results must be bit-identical to [`Backend::predict`] for any lease
    /// width (the kernels are thread-count-invariant); the default ignores
    /// the context for backends without ctx-aware kernels.
    fn predict_ctx(
        &self,
        x: &Mat,
        mode: Mode,
        ctx: &mut ExecCtx<'_>,
    ) -> Result<(Mat, Option<f64>)> {
        let _ = ctx;
        self.predict(x, mode)
    }
    /// Recompute estimator factors from the current weights.
    fn refresh(&self) -> Result<()>;
    /// Per-layer dispatch thresholds (α*), if this backend dispatches
    /// conditionally. The server exports them as startup gauges.
    fn dispatch_thresholds(&self) -> Option<Vec<f64>> {
        None
    }
    /// Human-readable per-layer kernel-choice table (which registered
    /// kernel the cost router picks at each grid density), if this backend
    /// routes through a kernel registry. `serve` logs it at startup.
    fn kernel_choice_lines(&self) -> Option<Vec<String>> {
        None
    }
    /// Fingerprint of the model this backend serves (layer shapes), if
    /// known. The `hello` handshake publishes it so a coordinator can
    /// refuse a worker serving a different model.
    fn model_fingerprint(&self) -> Option<String> {
        None
    }
    /// The machine profile this backend calibrated (or loaded), if any.
    /// Workers publish it through `hello` so the coordinator holds
    /// per-replica cost columns and can route batches where they run
    /// cheapest.
    fn machine_profile(&self) -> Option<MachineProfile> {
        None
    }
}

/// Pure-Rust backend: the control path uses the dense layer kernels, the
/// conditional path runs estimator + masked GEMM — all on the process-wide
/// worker pool, so server workers queue compute on shared threads instead of
/// contending on serial kernels.
pub struct NativeBackend {
    net: Mlp,
    masked: Vec<MaskedLayer>,
    /// Int8-quantized weights, prepared once at construction (per-row
    /// scales) so the `dense_i8`/`masked_i8` kernels never pay quantization
    /// on the hot path. Tiny next to the f32 copies; always built.
    quants: Vec<QuantizedLayer>,
    estimators: RwLock<SignEstimatorSet>,
    max_batch: usize,
    /// Per-layer per-kernel cost tables — loaded from a machine profile
    /// ([`NativeBackend::apply_profile`]) or measured at startup
    /// ([`NativeBackend::calibrate_dispatch`]); uncalibrated layers fall
    /// back to the per-kernel defaults with a once-per-process warning.
    dispatch: RwLock<PolicyTable>,
    /// The compute kernels the cost router may pick from: `base` is the full
    /// registered set (builtin unless an embedder replaced it), `active` is
    /// the routing view after the `dispatch.kernels` allow-list
    /// ([`NativeBackend::set_allowed_kernels`] always restricts from
    /// `base`, so allow-lists replace rather than compound). With no
    /// allow-list, `active` is `base` minus the sign-agreement (int8)
    /// kernels — quantized routing is opt-in; naming `dense_i8`/`masked_i8`
    /// in the allow-list enables them. A ctx-pinned registry view overrides
    /// `active` per call.
    kernels: RwLock<(Arc<KernelRegistry>, Arc<KernelRegistry>)>,
    /// Recycled activation buffers for pool-less callers
    /// ([`Backend::predict`]); shard executors bypass this entirely by
    /// bringing their own arena inside the [`ExecCtx`] they hand to
    /// [`Backend::predict_ctx`].
    scratch: Mutex<ScratchArena>,
    /// The machine profile this backend last calibrated or loaded —
    /// published by the worker `hello` handshake so a coordinator can route
    /// to cheap replicas. `None` until calibration/apply_profile runs.
    profile: RwLock<Option<MachineProfile>>,
}

impl NativeBackend {
    pub fn new(net: Mlp, estimators: SignEstimatorSet, max_batch: usize) -> NativeBackend {
        let masked: Vec<MaskedLayer> = (0..net.depth())
            .map(|l| MaskedLayer::new(&net.weights[l], &net.biases[l]))
            .collect();
        let quants: Vec<QuantizedLayer> = masked
            .iter()
            .map(|m| QuantizedLayer::new(&m.wt, &m.bias))
            .collect();
        let hidden = net.depth().saturating_sub(1);
        NativeBackend {
            net,
            masked,
            quants,
            estimators: RwLock::new(estimators),
            max_batch,
            dispatch: RwLock::new(PolicyTable::uncalibrated(hidden)),
            kernels: RwLock::new({
                let base = Arc::new(KernelRegistry::builtin());
                (base.clone(), Self::default_view(&base))
            }),
            scratch: Mutex::new(ScratchArena::new()),
            profile: RwLock::new(None),
        }
    }

    /// The default routing view over a registered set: everything except
    /// the sign-agreement (int8) kernels, which change outputs and so only
    /// route when an allow-list names them. Falls back to the full set if
    /// the filter would leave nothing (an all-quantized custom registry).
    fn default_view(base: &Arc<KernelRegistry>) -> Arc<KernelRegistry> {
        match base.restricted(&base.default_routable()) {
            Ok(view) => Arc::new(view),
            Err(_) => base.clone(),
        }
    }

    /// The shared compute pool every batch executes on.
    fn pool(&self) -> &'static ThreadPool {
        crate::parallel::global()
    }

    /// Number of conditionally-dispatched (hidden) layers.
    fn num_hidden(&self) -> usize {
        self.net.depth().saturating_sub(1)
    }

    /// Pin every layer to one explicit policy (tests; embedders with a
    /// single recorded global ratio).
    pub fn set_dispatch(&self, policy: DispatchPolicy) {
        *self.dispatch.write().unwrap() = PolicyTable::uniform(policy, self.num_hidden());
    }

    /// Install a full per-layer policy table.
    pub fn set_policy_table(&self, table: PolicyTable) {
        *self.dispatch.write().unwrap() = table;
    }

    /// The kernel registry view the cost router currently picks from.
    pub fn registry(&self) -> Arc<KernelRegistry> {
        self.kernels.read().unwrap().1.clone()
    }

    /// Replace the registry outright (embedders composing their own kernel
    /// set; they register before serving starts). Clears any allow-list —
    /// the active view resets to the default-routable subset (sign-agreement
    /// kernels excluded until allow-listed again). Rejects an empty registry
    /// — the router must always have a kernel to pick (the same invariant
    /// `restricted` enforces for allow-lists).
    pub fn set_registry(&self, registry: KernelRegistry) -> Result<()> {
        if registry.is_empty() {
            return Err(anyhow::anyhow!("kernel registry must not be empty"));
        }
        let base = Arc::new(registry);
        let active = Self::default_view(&base);
        *self.kernels.write().unwrap() = (base, active);
        Ok(())
    }

    /// Restrict routing to an allow-list of kernel ids (`dispatch.kernels` /
    /// `--kernels`), always relative to the full registered set — so naming
    /// `dense_i8`/`masked_i8` here is exactly how the sign-agreement class
    /// becomes routable. Rejects unknown or unregistered ids and an empty
    /// list.
    pub fn set_allowed_kernels(&self, allow: &[KernelId]) -> Result<()> {
        let mut guard = self.kernels.write().unwrap();
        let restricted = guard.0.restricted(allow).map_err(|e| anyhow::anyhow!("{e}"))?;
        guard.1 = Arc::new(restricted);
        Ok(())
    }

    /// Measure cost columns for just `kernels` (plus the dense baseline) on
    /// this machine and merge them into the live policy table, preserving
    /// every already-calibrated column — the targeted-recalibration path for
    /// a machine profile that predates a newly registered kernel. Returns
    /// the updated table.
    pub fn calibrate_kernel_columns(&self, kernels: &[KernelId], budget_ms: u64) -> PolicyTable {
        let mut tuner = Autotuner::with_budget_ms(budget_ms.max(1));
        tuner.batch = self.max_batch.clamp(8, 64);
        tuner.fit_serial = false;
        tuner.kernels = kernels.to_vec();
        let profile =
            tuner.calibrate_model_on(&self.net.layer_sizes(), self.pool(), &self.registry());
        let mut table = self.policy_table();
        for lt in &profile.layers {
            for (name, cost) in &lt.kernel_costs {
                if let Some(id) = KernelId::parse(name) {
                    if kernels.contains(&id) {
                        table.set_layer_column(lt.layer, id, *cost);
                    }
                }
            }
        }
        self.set_policy_table(table.clone());
        table
    }

    /// Which kernel the cost router would pick per hidden layer across the
    /// calibration α grid — the `serve` startup log's routing table.
    fn choice_lines(&self) -> Vec<String> {
        const GRID: [f64; 4] = [0.05, 0.25, 0.5, 1.0];
        let table = self.policy_table();
        let registry = self.registry();
        let allowed = registry.ids();
        let n = self.max_batch.max(1);
        let mut lines = vec![format!(
            "kernel routing (batch {n}, kernels [{}]):",
            allowed.iter().map(|k| k.as_str()).collect::<Vec<_>>().join(", ")
        )];
        for l in 0..self.num_hidden() {
            let (d, h) = (self.masked[l].in_dim(), self.masked[l].out_dim());
            let policy = table.policy_snapshot(l);
            let choices: Vec<String> = GRID
                .iter()
                .map(|&alpha| format!("α={alpha:.2}→{}", policy.decide(n, d, h, alpha, &allowed)))
                .collect();
            lines.push(format!("layer {l} ({d}×{h}): {}", choices.join("  ")));
        }
        lines
    }

    /// Install the per-layer thresholds from a persisted machine profile.
    /// Rejects a profile whose fingerprint does not match this model's
    /// shapes (its thresholds would be for the wrong `d × h` grid).
    pub fn apply_profile(&self, profile: &MachineProfile, source: &str) -> Result<PolicyTable> {
        profile.ensure_matches_model(&self.net.layer_sizes())?;
        // A shape match is required; a pool/hardware mismatch is only
        // suspicious (thresholds were fitted under different contention /
        // cache behaviour), so it installs with a warning.
        let live_threads = self.pool().threads();
        if profile.threads != 0 && profile.threads != live_threads {
            eprintln!(
                "warning: machine profile {source} was calibrated on {} pool threads; \
                 this pool has {live_threads} — thresholds may be off \
                 (re-run `condcomp calibrate` on this configuration)",
                profile.threads
            );
        }
        let live_hw = crate::autotune::hardware_descriptor();
        if profile.hardware != "unknown" && profile.hardware != live_hw {
            eprintln!(
                "warning: machine profile {source} describes hardware '{}'; \
                 this machine is '{live_hw}'",
                profile.hardware
            );
        }
        let table = profile.policy_table(self.num_hidden(), source);
        self.set_policy_table(table.clone());
        *self.profile.write().unwrap() = Some(profile.clone());
        Ok(table)
    }

    /// Measure per-layer masked-vs-dense cost ratios on this machine's pool
    /// (online calibration — the fallback when no machine profile is on
    /// disk) and install the resulting table; returns it so `serve` can log
    /// the per-layer thresholds at startup. Wall-clock bounded by
    /// `budget_ms`. The harness measures through an [`ExecCtx`] over a
    /// full-pool lease, so warm-up exercises exactly the leased code path
    /// the shard executors will run — one warm-up path, not two.
    pub fn calibrate_dispatch(&self, budget_ms: u64) -> PolicyTable {
        let mut tuner = Autotuner::with_budget_ms(budget_ms.max(1));
        tuner.batch = self.max_batch.clamp(8, 64);
        // Online calibration discards the profile, so skip the serial
        // diagnostic arm and spend the whole budget on the pooled numbers
        // dispatch actually consumes.
        tuner.fit_serial = false;
        // One cost column per kernel this backend may actually route to —
        // measured through this backend's registry, so custom registrants
        // get real columns, not work-model defaults.
        let registry = self.registry();
        tuner.kernels = registry.ids();
        let profile = tuner.calibrate_model_on(&self.net.layer_sizes(), self.pool(), &registry);
        let table = profile.policy_table(self.num_hidden(), "<online calibration>");
        self.set_policy_table(table.clone());
        *self.profile.write().unwrap() = Some(profile);
        table
    }

    /// Current dispatch policy table (cloned snapshot).
    pub fn policy_table(&self) -> PolicyTable {
        self.dispatch.read().unwrap().clone()
    }

    /// Conditional forward with flop accounting (shared with experiments),
    /// through a caller-owned execution context.
    ///
    /// Per hidden layer: predict the mask (row shards on the ctx's lease),
    /// read its density α, and let the cost table route the batch to the
    /// cheapest registered-and-allowed kernel — masked dot products in the
    /// sparse regime, a dense GEMM (plain or packed, with the mask applied
    /// afterwards) in the dense one. All kernels compute the same function
    /// (the two dense-work kernels are even bit-identical); routing only
    /// changes which one is faster. Every routing decision lands in the
    /// ctx's metrics as a `layer<l>_kernel_<id>_batches` counter.
    fn forward_cond(&self, x: &Mat, ctx: &mut ExecCtx<'_>) -> (Mat, FlopBreakdown) {
        let est = self.estimators.read().unwrap();
        // The ctx's pinned table/registry win (tests/calibration force a
        // kernel); otherwise snapshot the (small) live table instead of
        // holding the read guard across the whole forward — a concurrent
        // recalibration writer would otherwise stall every in-flight batch
        // behind it.
        let table = match ctx.policy() {
            Some(t) => t.clone(),
            None => self.policy_table(),
        };
        let registry = match ctx.registry() {
            Some(r) => r.clone(),
            None => self.registry(),
        };
        let allowed = registry.ids();
        // Quality-elastic serving: under queue pressure the executor sets a
        // pressure view on the ctx; when an elastic config is attached and
        // engaged, the estimator runs at a truncated rank and the cost-table
        // argmin is biased toward the cheap masked kernels. Pressure changes
        // *which* registered kernel runs, never what any kernel computes.
        let elastic = ctx.elastic().copied();
        let pressure = ctx.pressure();
        let mut flops = FlopBreakdown::default();
        let depth = self.masked.len();
        let mut a = x.clone();
        for l in 0..depth - 1 {
            let layer = &self.masked[l];
            let (n, h) = (a.rows(), layer.out_dim());
            // The mask buffer recycles through the arena like every other
            // per-batch activation (nothing allocated after warmup).
            let mut mask = Mat::from_vec(n, h, ctx.take_buf(n * h));
            let full_rank = est.layers[l].rank();
            let eff_rank = match &elastic {
                Some(e) => e.effective_rank(full_rank, pressure),
                None => full_rank,
            };
            let sp = ctx.metrics().span("estimator");
            if eff_rank < full_rank {
                est.layers[l].mask_into_ctx_rank(&a, &mut mask, eff_rank, ctx);
            } else {
                est.layers[l].mask_into_ctx(&a, &mut mask, ctx);
            }
            drop(sp);
            if eff_rank < full_rank {
                ctx.metrics().incr("elastic_rank_truncations");
            }
            let alpha = mask.density() as f64;
            let mut out = Mat::from_vec(n, h, ctx.take_buf(n * h));
            // Per-layer cost table: each layer's shape has its own fitted
            // per-kernel columns; the argmin picks the kernel.
            let (kid, downgraded) = match &elastic {
                Some(e) => table.policy_for(l).decide_elastic(
                    n,
                    layer.in_dim(),
                    h,
                    alpha,
                    &allowed,
                    e,
                    pressure,
                ),
                None => (
                    table.policy_for(l).decide(n, layer.in_dim(), h, alpha, &allowed),
                    false,
                ),
            };
            if downgraded {
                ctx.metrics().incr("elastic_downgrades");
                let sp = ctx.metrics().span_with("elastic", Some(kid.as_str()));
                drop(sp);
            }
            let kernel = registry
                .get(kid)
                .expect("decide() only returns registered kernels");
            let ops =
                LayerOperands::new(&self.net.weights[l], layer).with_quant(&self.quants[l]);
            let sp = ctx.metrics().span_with("kernel", Some(kid.as_str()));
            let computed = kernel.run(&ops, &a, &mask, ctx, &mut out);
            drop(sp);
            // Kernel outputs are post-ReLU masked activations, so the output
            // density is the *achieved* α: units the estimator predicted
            // positive that really were. predicted/achieved/agreement are
            // the paper's robustness observables (§3.3), exported per layer.
            let achieved = out.density() as f64;
            let agreement = if alpha > 0.0 { (achieved / alpha).min(1.0) } else { 1.0 };
            ctx.metrics().incr(&format!("layer{l}_kernel_{kid}_batches"));
            ctx.metrics().set_gauge(&format!("layer{l}_alpha"), alpha);
            ctx.metrics().set_gauge(&format!("layer{l}_alpha_predicted"), alpha);
            ctx.metrics().set_gauge(&format!("layer{l}_alpha_achieved"), achieved);
            ctx.metrics().set_gauge(&format!("layer{l}_sign_agreement"), agreement);
            flops.push(crate::condcomp::LayerFlops::from_counts(
                n,
                layer.in_dim(),
                h,
                eff_rank,
                computed,
            ));
            ctx.put_buf(mask.into_vec());
            let prev = std::mem::replace(&mut a, out);
            if l > 0 {
                // `prev` owns a scratch buffer (layer-0 input is the request).
                ctx.put_buf(prev.into_vec());
            }
        }
        let last = &self.masked[depth - 1];
        let mut logits = Mat::from_vec(
            a.rows(),
            last.out_dim(),
            ctx.take_buf(a.rows() * last.out_dim()),
        );
        matmul_into_ctx(&a, &self.net.weights[depth - 1], &mut logits, ctx);
        add_bias(&mut logits, &last.bias);
        flops.push(crate::condcomp::LayerFlops::from_counts(
            a.rows(),
            last.in_dim(),
            last.out_dim(),
            0,
            a.rows() * last.out_dim(),
        ));
        if depth > 1 {
            ctx.put_buf(a.into_vec());
        }
        (logits, flops)
    }
}

impl Backend for NativeBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Native
    }

    fn input_dim(&self) -> usize {
        self.net.layer_sizes()[0]
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn predict(&self, x: &Mat, mode: Mode) -> Result<(Mat, Option<f64>)> {
        // Borrow the shared arena by value (brief lock) and run through a
        // *shared* (non-reserving) ctx over the global pool: full machine
        // width without starving a concurrent server's shard leases, then
        // hand the buffers back — concurrent pool-less callers simply start
        // from an empty arena and allocate.
        let arena = std::mem::take(&mut *self.scratch.lock().unwrap());
        let mut ctx = ExecCtx::shared(crate::parallel::global()).with_arena(arena);
        let out = self.predict_ctx(x, mode, &mut ctx);
        self.scratch.lock().unwrap().absorb(ctx.into_arena());
        out
    }

    fn predict_ctx(
        &self,
        x: &Mat,
        mode: Mode,
        ctx: &mut ExecCtx<'_>,
    ) -> Result<(Mat, Option<f64>)> {
        match mode {
            Mode::Control => {
                // The dense forward also benefits from the cost table: when
                // a layer's `dense_packed` column beats `dense`, the packed
                // GEMM runs instead — bit-identical, just faster. Pin a
                // snapshot for the duration of the forward (unless the
                // caller pinned one), restricted to the allow-list so an
                // excluded kernel can never be preferred here either, then
                // restore so a long-lived shard ctx never freezes out
                // recalibration.
                let pinned = ctx.policy().is_some();
                if !pinned {
                    let mut table = self.policy_table();
                    table.retain_kernels(&self.registry().ids());
                    ctx.set_policy(Some(table));
                }
                let logits = self.net.logits_ctx(x, ctx);
                if !pinned {
                    ctx.set_policy(None);
                }
                Ok((logits, None))
            }
            Mode::ConditionalAe => {
                let (logits, flops) = self.forward_cond(x, ctx);
                let dense = flops.total_dense() as f64;
                if dense > 0.0 {
                    // Fraction of the dense FLOP budget the conditional path
                    // skipped (estimator overhead already charged against it).
                    let skipped = (1.0 - flops.total_augmented() / dense).max(0.0);
                    ctx.metrics().set_gauge("flops_skipped_frac", skipped);
                }
                Ok((logits, Some(flops.speedup())))
            }
        }
    }

    fn refresh(&self) -> Result<()> {
        let net = &self.net;
        self.estimators.write().unwrap().refresh(net);
        Ok(())
    }

    fn dispatch_thresholds(&self) -> Option<Vec<f64>> {
        Some(self.dispatch.read().unwrap().thresholds())
    }

    fn kernel_choice_lines(&self) -> Option<Vec<String>> {
        Some(self.choice_lines())
    }

    fn model_fingerprint(&self) -> Option<String> {
        Some(crate::autotune::model_fingerprint(&self.net.layer_sizes()))
    }

    fn machine_profile(&self) -> Option<MachineProfile> {
        self.profile.read().unwrap().clone()
    }
}

/// PJRT backend over the AOT artifacts; the runtime is mutex-guarded because
/// refresh mutates factor literals.
pub struct PjrtBackend {
    rt: Mutex<ModelRuntime>,
    input_dim: usize,
    batch: usize,
}

impl PjrtBackend {
    /// Wrap a runtime for serving.
    ///
    /// The `ModelRuntime` (and the `Arc<Engine>` inside it) must be the only
    /// live handle to its PJRT client — see the `Send`/`Sync` note below.
    pub fn new(rt: ModelRuntime) -> PjrtBackend {
        let input_dim = rt.layers[0];
        let batch = rt.batch;
        PjrtBackend { rt: Mutex::new(rt), input_dim, batch }
    }
}

// SAFETY: the `xla` crate's handles (PjRtClient: Rc<...>, Literal /
// PjRtLoadedExecutable: raw pointers) are not auto-Send/Sync, but the
// underlying PJRT CPU client is thread-safe and *every* access to the
// runtime goes through the `Mutex<ModelRuntime>` above — the Rc refcount and
// the raw handles are never touched from two threads at once as long as the
// constructor's single-handle requirement holds.
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {}

impl Backend for PjrtBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    fn input_dim(&self) -> usize {
        self.input_dim
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn predict(&self, x: &Mat, mode: Mode) -> Result<(Mat, Option<f64>)> {
        let rt = self.rt.lock().unwrap();
        match mode {
            Mode::Control => Ok((rt.forward(x)?, None)),
            Mode::ConditionalAe => Ok((rt.forward_ae(x)?, None)),
        }
    }

    fn refresh(&self) -> Result<()> {
        self.rt.lock().unwrap().refresh_factors()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EstimatorConfig, NetConfig};
    use crate::util::Pcg32;

    fn native() -> NativeBackend {
        let mut rng = Pcg32::seeded(5);
        let net = Mlp::init(
            &NetConfig { layers: vec![8, 12, 10, 4], weight_sigma: 0.4, bias_init: 0.1 },
            &mut rng,
        );
        let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&[6, 5]), 3);
        NativeBackend::new(net, est, 32)
    }

    #[test]
    fn native_modes_agree_at_full_rank() {
        let mut rng = Pcg32::seeded(9);
        let net = Mlp::init(
            &NetConfig { layers: vec![8, 12, 10, 4], weight_sigma: 0.4, bias_init: 0.1 },
            &mut rng,
        );
        let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&[12, 10]), 3);
        let be = NativeBackend::new(net, est, 32);
        let x = Mat::randn(5, 8, 1.0, &mut rng);
        let (dense, _) = be.predict(&x, Mode::Control).unwrap();
        let (cond, speedup) = be.predict(&x, Mode::ConditionalAe).unwrap();
        assert!(dense.max_abs_diff(&cond) < 1e-3);
        assert!(speedup.is_some());
    }

    #[test]
    fn conditional_speedup_reported() {
        let be = native();
        let mut rng = Pcg32::seeded(2);
        let x = Mat::randn(4, 8, 1.0, &mut rng);
        let (_, speedup) = be.predict(&x, Mode::ConditionalAe).unwrap();
        let s = speedup.unwrap();
        assert!(s > 0.0 && s.is_finite());
    }

    #[test]
    fn refresh_succeeds() {
        let be = native();
        be.refresh().unwrap();
        assert_eq!(be.kind(), BackendKind::Native);
        assert_eq!(be.input_dim(), 8);
        assert_eq!(be.max_batch(), 32);
    }

    /// Forcing the policy to either extreme must not change what the
    /// conditional path computes — dispatch picks a kernel, not a function.
    #[test]
    fn dispatch_choice_does_not_change_results() {
        let be = native();
        let mut rng = Pcg32::seeded(17);
        let x = Mat::randn(6, 8, 1.0, &mut rng);

        be.set_dispatch(DispatchPolicy::with_cost_ratio(1e9)); // α* ≈ 0 → always dense
        let (dense_logits, dense_speedup) = be.predict(&x, Mode::ConditionalAe).unwrap();
        be.set_dispatch(DispatchPolicy::with_cost_ratio(1e-9)); // α* = 1 → always masked
        let (masked_logits, masked_speedup) = be.predict(&x, Mode::ConditionalAe).unwrap();

        assert!(
            dense_logits.max_abs_diff(&masked_logits) < 1e-4,
            "kernels disagree by {}",
            dense_logits.max_abs_diff(&masked_logits)
        );
        // The dense fallback reports every dot product computed, so its
        // accounted speedup can only be lower.
        assert!(dense_speedup.unwrap() <= masked_speedup.unwrap() + 1e-9);
    }

    #[test]
    fn repeated_predicts_reuse_scratch_and_stay_deterministic() {
        let be = native();
        let mut rng = Pcg32::seeded(23);
        let x = Mat::randn(5, 8, 1.0, &mut rng);
        let (first, _) = be.predict(&x, Mode::ConditionalAe).unwrap();
        for _ in 0..4 {
            let (again, _) = be.predict(&x, Mode::ConditionalAe).unwrap();
            assert_eq!(again.as_slice(), first.as_slice(), "reused buffers leaked state");
        }
    }

    /// The shard-executor entry point must compute exactly what the
    /// pool-less path computes, for any pool size, any lease width, and a
    /// fresh or warm arena — this is the kernel-level half of the "outputs
    /// are bit-identical across shard counts" serving invariant.
    #[test]
    fn predict_ctx_is_bit_identical_for_any_pool_lease_and_arena() {
        let be = native();
        let mut rng = Pcg32::seeded(31);
        let x = Mat::randn(5, 8, 1.0, &mut rng);
        for mode in [Mode::Control, Mode::ConditionalAe] {
            let (want, _) = be.predict(&x, mode).unwrap();
            for threads in [1usize, 2, 7] {
                let pool = crate::parallel::ThreadPool::new(threads);
                for grant in [0usize, 1, 2, 7] {
                    let mut ctx = ExecCtx::over(pool.lease(grant));
                    // Twice per ctx: a cold arena and a warm (recycled) one.
                    for _ in 0..2 {
                        let (got, _) = be.predict_ctx(&x, mode, &mut ctx).unwrap();
                        assert_eq!(
                            got.as_slice(),
                            want.as_slice(),
                            "mode {:?} threads {threads} lease {grant} diverged",
                            mode
                        );
                        ctx.put_buf(got.into_vec());
                    }
                }
                assert_eq!(pool.leased(), 0, "ctx drop returns the lease");
            }
        }
    }

    /// A ctx-pinned policy table overrides the backend's live table — the
    /// read-view half of the ExecCtx contract (forcing either extreme must
    /// not change what is computed, only which kernel computes it).
    #[test]
    fn ctx_pinned_policy_overrides_the_live_table() {
        let be = native();
        let mut rng = Pcg32::seeded(37);
        let x = Mat::randn(6, 8, 1.0, &mut rng);
        let pool = crate::parallel::ThreadPool::new(2);
        // Live table says "always masked"; the ctx pins "always dense".
        be.set_dispatch(DispatchPolicy::with_cost_ratio(1e-9));
        let (want_logits, masked_speedup) = be.predict(&x, Mode::ConditionalAe).unwrap();
        let pinned = PolicyTable::uniform(DispatchPolicy::with_cost_ratio(1e9), 2);
        let mut ctx = ExecCtx::over(pool.lease(2)).with_policy(pinned);
        let (logits, dense_speedup) = be.predict_ctx(&x, Mode::ConditionalAe, &mut ctx).unwrap();
        assert!(
            logits.max_abs_diff(&want_logits) < 1e-4,
            "pinned policy changed the function, not just the kernel"
        );
        // The dense fallback accounts every dot product computed, so the
        // pinned-dense run must report a lower (or equal) FLOP speedup —
        // proof the pin actually flipped the kernel choice.
        assert!(dense_speedup.unwrap() <= masked_speedup.unwrap() + 1e-9);
    }

    #[test]
    fn calibration_installs_a_sane_per_layer_table() {
        let be = native();
        let table = be.calibrate_dispatch(60);
        // Three weight layers → two conditionally-dispatched hidden layers.
        assert_eq!(table.num_layers(), 2);
        assert_eq!(table.calibrated_layers(), 2);
        assert_eq!(be.policy_table(), table);
        for t in table.thresholds() {
            assert!((0.0..=1.0).contains(&t), "threshold {t}");
        }
        assert_eq!(be.dispatch_thresholds().unwrap().len(), 2);
    }

    #[test]
    fn profile_with_matching_fingerprint_installs_per_layer_thresholds() {
        use crate::autotune::{model_fingerprint, LayerThreshold, MachineProfile};
        let be = native();
        let profile = MachineProfile {
            version: crate::autotune::PROFILE_SCHEMA_VERSION,
            fingerprint: model_fingerprint(&[8, 12, 10, 4]),
            hardware: "test".into(),
            threads: 1,
            budget_ms: 0,
            kernels: vec!["dense".into(), "masked".into()],
            layers: vec![
                LayerThreshold::from_kernel_costs(
                    0,
                    8,
                    12,
                    vec![("dense".into(), 1.0), ("masked".into(), 2.0)],
                    Some(2.0),
                ),
                LayerThreshold::from_kernel_costs(
                    1,
                    12,
                    10,
                    vec![("dense".into(), 1.0), ("masked".into(), 8.0)],
                    Some(8.0),
                ),
            ],
        };
        let table = be.apply_profile(&profile, "test-profile.json").unwrap();
        let t = table.thresholds();
        assert!((t[0] - 0.5).abs() < 1e-12 && (t[1] - 0.125).abs() < 1e-12, "{t:?}");
        assert_eq!(be.dispatch_thresholds().unwrap(), t);
        // The two layers now dispatch differently at the same density.
        // Float-class allow-list: the int8 ids are opt-in and their
        // optimistic uncalibrated defaults would otherwise win the argmin.
        use crate::condcomp::KernelId;
        let float_kernels = [
            KernelId::DENSE,
            KernelId::DENSE_PACKED,
            KernelId::DENSE_SIMD,
            KernelId::MASKED,
            KernelId::MASKED_SIMD,
        ];
        assert_eq!(
            table.policy_for(0).decide(4, 8, 12, 0.3, &float_kernels),
            KernelId::MASKED
        );
        assert_eq!(
            table.policy_for(1).decide(4, 12, 10, 0.3, &float_kernels),
            KernelId::DENSE
        );
    }

    #[test]
    fn profile_with_wrong_fingerprint_is_rejected() {
        use crate::autotune::MachineProfile;
        let be = native();
        let profile = MachineProfile {
            version: crate::autotune::PROFILE_SCHEMA_VERSION,
            fingerprint: "mlp:999-999-999".into(),
            hardware: "test".into(),
            threads: 1,
            budget_ms: 0,
            kernels: vec![],
            layers: vec![],
        };
        let err = be.apply_profile(&profile, "wrong.json").unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        // The uncalibrated table is untouched.
        assert_eq!(be.policy_table().calibrated_layers(), 0);
    }

    /// Satellite: every routing decision is observable — the conditional
    /// forward increments one `layer<l>_kernel_<id>_batches` counter per
    /// hidden layer per batch, under both the global and the shard key.
    #[test]
    fn kernel_hit_counters_record_routing_decisions() {
        use crate::coordinator::metrics::MetricsRegistry;
        use crate::exec::MetricsScope;
        let be = native();
        let mut rng = Pcg32::seeded(71);
        let x = Mat::randn(4, 8, 1.0, &mut rng);
        let pool = crate::parallel::ThreadPool::new(2);
        let reg = std::sync::Arc::new(MetricsRegistry::new());

        // Force the masked kernel everywhere.
        be.set_dispatch(DispatchPolicy::with_cost_ratio(1e-9));
        let mut ctx = ExecCtx::over(pool.lease(2))
            .with_metrics(MetricsScope::for_shard(reg.clone(), 1));
        be.predict_ctx(&x, Mode::ConditionalAe, &mut ctx).unwrap();
        assert_eq!(reg.counter("layer0_kernel_masked_batches"), 1);
        assert_eq!(reg.counter("layer1_kernel_masked_batches"), 1);
        assert_eq!(reg.shard_counter(1, "layer0_kernel_masked_batches"), 1);
        assert_eq!(reg.counter("layer0_kernel_dense_batches"), 0);
        assert!(reg.gauge("layer0_alpha").is_some(), "α gauge exported per layer");

        // Force the dense kernel via the allow-list (deterministic for any
        // α, unlike a cost-ratio pin — at α = 0 the masked column costs
        // exactly zero): the counters move to the dense kernel.
        be.set_allowed_kernels(&[crate::condcomp::KernelId::DENSE]).unwrap();
        be.predict_ctx(&x, Mode::ConditionalAe, &mut ctx).unwrap();
        assert_eq!(reg.counter("layer0_kernel_dense_batches"), 1);
        assert_eq!(reg.counter("layer1_kernel_dense_batches"), 1);
        assert_eq!(reg.counter("layer0_kernel_masked_batches"), 1, "unchanged");
    }

    /// The allow-list restricts routing without changing the function: a
    /// masked-only backend and a packed-only backend still agree with the
    /// unrestricted one (numerically for masked-vs-dense, bitwise for
    /// packed-vs-dense).
    #[test]
    fn kernel_allow_list_restricts_routing_not_results() {
        use crate::condcomp::KernelId;
        let be = native();
        let mut rng = Pcg32::seeded(73);
        let x = Mat::randn(5, 8, 1.0, &mut rng);
        // Dense baseline, forced by allow-list (deterministic for any α).
        be.set_allowed_kernels(&[KernelId::DENSE]).unwrap();
        let (dense_logits, dense_speedup) = be.predict(&x, Mode::ConditionalAe).unwrap();

        // dense_packed-only: bit-identical to dense (packing is layout-only),
        // and the speedup accounting agrees exactly (same computed counts).
        be.set_allowed_kernels(&[KernelId::DENSE_PACKED]).unwrap();
        let (packed_logits, packed_speedup) = be.predict(&x, Mode::ConditionalAe).unwrap();
        assert_eq!(packed_logits.as_slice(), dense_logits.as_slice());
        assert_eq!(packed_speedup.unwrap().to_bits(), dense_speedup.unwrap().to_bits());

        // masked-only: same function, different accumulation order — and the
        // dense-regime policy cannot override the allow-list.
        be.set_allowed_kernels(&[KernelId::MASKED]).unwrap();
        let (masked_logits, masked_speedup) = be.predict(&x, Mode::ConditionalAe).unwrap();
        assert!(masked_logits.max_abs_diff(&dense_logits) < 1e-4);
        // Masked computes fewer dot products → strictly better accounted
        // speedup (proof the allow-list actually flipped the kernel).
        assert!(masked_speedup.unwrap() >= dense_speedup.unwrap() - 1e-9);

        // Unknown/unregistered ids and empty lists are rejected loudly.
        assert!(be.set_allowed_kernels(&[]).is_err());
        #[cfg(not(feature = "pjrt"))]
        assert!(be.set_allowed_kernels(&[KernelId::PJRT]).is_err());
    }

    /// Int8 kernels never route by default: the backend's active view
    /// excludes the sign-agreement class until an allow-list names it, and
    /// when it does, the quantized forward stays close to the float one
    /// (sign-agreement drift, not garbage).
    #[test]
    fn quantized_kernels_route_only_when_allow_listed() {
        use crate::condcomp::KernelId;
        let be = native();
        let default_ids = be.registry().ids();
        assert!(
            !default_ids.contains(&KernelId::DENSE_I8)
                && !default_ids.contains(&KernelId::MASKED_I8),
            "int8 class must be absent from default routing: {default_ids:?}"
        );
        assert!(default_ids.contains(&KernelId::DENSE));

        let mut rng = Pcg32::seeded(79);
        let x = Mat::randn(5, 8, 1.0, &mut rng);
        be.set_allowed_kernels(&[KernelId::DENSE]).unwrap();
        let (dense_logits, _) = be.predict(&x, Mode::ConditionalAe).unwrap();

        // Opt in: only the int8 pair allowed → every hidden layer runs
        // quantized, whichever of the two the cost table picks.
        be.set_allowed_kernels(&[KernelId::DENSE_I8, KernelId::MASKED_I8]).unwrap();
        assert_eq!(
            be.registry().ids(),
            vec![KernelId::DENSE_I8, KernelId::MASKED_I8]
        );
        let (q_logits, q_speedup) = be.predict(&x, Mode::ConditionalAe).unwrap();
        assert!(q_speedup.unwrap().is_finite());
        let scale = dense_logits
            .as_slice()
            .iter()
            .fold(0.0f32, |m, &v| m.max(v.abs()))
            .max(1e-6);
        let drift = q_logits.max_abs_diff(&dense_logits);
        assert!(
            drift <= 0.25 * scale,
            "quantized logits drifted {drift} vs float magnitude {scale}"
        );
        // And repeated quantized predicts are bit-stable (integer exactness).
        let (again, _) = be.predict(&x, Mode::ConditionalAe).unwrap();
        assert_eq!(again.as_slice(), q_logits.as_slice());

        // Clearing back to a float allow-list restores bit-identical output.
        be.set_allowed_kernels(&[KernelId::DENSE]).unwrap();
        let (back, _) = be.predict(&x, Mode::ConditionalAe).unwrap();
        assert_eq!(back.as_slice(), dense_logits.as_slice());
    }

    /// Targeted recalibration: a backend whose table came from a pre-registry
    /// profile (dense + masked only) gains just the missing columns —
    /// measured — while the profile's masked columns survive untouched.
    #[test]
    fn calibrate_kernel_columns_fills_only_the_missing_column() {
        use crate::autotune::{model_fingerprint, LayerThreshold, MachineProfile};
        use crate::condcomp::{KernelId, BUILTIN_KERNELS};
        let be = native();
        let profile = MachineProfile {
            version: crate::autotune::PROFILE_SCHEMA_VERSION,
            fingerprint: model_fingerprint(&[8, 12, 10, 4]),
            hardware: "test".into(),
            threads: 1,
            budget_ms: 0,
            kernels: vec!["dense".into(), "masked".into()],
            layers: vec![
                LayerThreshold::from_kernel_costs(
                    0,
                    8,
                    12,
                    vec![("dense".into(), 1.0), ("masked".into(), 2.0)],
                    None,
                ),
                LayerThreshold::from_kernel_costs(
                    1,
                    12,
                    10,
                    vec![("dense".into(), 1.0), ("masked".into(), 8.0)],
                    None,
                ),
            ],
        };
        let missing = profile.missing_kernel_columns(BUILTIN_KERNELS);
        assert_eq!(
            missing,
            vec![
                KernelId::DENSE_PACKED,
                KernelId::DENSE_SIMD,
                KernelId::DENSE_I8,
                KernelId::MASKED_SIMD,
                KernelId::MASKED_I8,
            ]
        );
        be.apply_profile(&profile, "partial.json").unwrap();
        let table = be.calibrate_kernel_columns(&missing, 40);
        for l in 0..2 {
            let p = table.policy_snapshot(l);
            assert!(
                p.per_flop(KernelId::DENSE_PACKED).is_some(),
                "layer {l} gained the packed column"
            );
        }
        // The profile's masked columns were preserved, not re-measured.
        assert_eq!(table.policy_snapshot(0).per_flop(KernelId::MASKED), Some(2.0));
        assert_eq!(table.policy_snapshot(1).per_flop(KernelId::MASKED), Some(8.0));
        assert_eq!(be.policy_table(), table, "merged table installed");
    }

    #[test]
    fn kernel_choice_lines_cover_every_hidden_layer() {
        let be = native();
        let lines = be.kernel_choice_lines().expect("native backend routes via registry");
        assert_eq!(lines.len(), 3, "header + 2 hidden layers: {lines:?}");
        assert!(lines[0].contains("dense_packed"), "{}", lines[0]);
        assert!(lines[1].starts_with("layer 0") && lines[2].starts_with("layer 1"));
        for line in &lines[1..] {
            assert!(line.contains("α=0.05→") && line.contains("α=1.00→"), "{line}");
        }
    }
}
