//! Inference backends behind the router: native (pure Rust engine) and PJRT
//! (AOT artifacts). Both serve the same two modes — control and conditional.

use super::protocol::Mode;
use crate::condcomp::{FlopBreakdown, MaskedLayer};
use crate::estimator::SignEstimatorSet;
use crate::linalg::Mat;
use crate::nn::mlp::{add_bias, NoGater};
use crate::nn::Mlp;
use crate::runtime::ModelRuntime;
use anyhow::Result;
use std::sync::{Mutex, RwLock};

/// Which implementation serves the request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust engine (masked GEMM).
    Native,
    /// PJRT-compiled artifacts (Pallas kernels inside the HLO).
    Pjrt,
}

/// A serving backend: maps a batch of inputs to logits.
pub trait Backend: Send + Sync {
    fn kind(&self) -> BackendKind;
    fn input_dim(&self) -> usize;
    /// Largest batch accepted per call.
    fn max_batch(&self) -> usize;
    /// Forward `x` in the given mode; returns logits and, for the
    /// conditional mode, the achieved FLOP speedup vs dense (Eq. 11).
    fn predict(&self, x: &Mat, mode: Mode) -> Result<(Mat, Option<f64>)>;
    /// Recompute estimator factors from the current weights.
    fn refresh(&self) -> Result<()>;
}

/// Pure-Rust backend: the control path uses the dense layer kernels, the
/// conditional path runs estimator + masked GEMM.
pub struct NativeBackend {
    net: Mlp,
    masked: Vec<MaskedLayer>,
    estimators: RwLock<SignEstimatorSet>,
    max_batch: usize,
}

impl NativeBackend {
    pub fn new(net: Mlp, estimators: SignEstimatorSet, max_batch: usize) -> NativeBackend {
        let masked = (0..net.depth())
            .map(|l| MaskedLayer::new(&net.weights[l], &net.biases[l]))
            .collect();
        NativeBackend { net, masked, estimators: RwLock::new(estimators), max_batch }
    }

    /// Conditional forward with flop accounting (shared with experiments).
    fn forward_cond(&self, x: &Mat) -> (Mat, FlopBreakdown) {
        let est = self.estimators.read().unwrap();
        let mut flops = FlopBreakdown::default();
        let depth = self.masked.len();
        let mut a = x.clone();
        for l in 0..depth - 1 {
            let mask = est.layers[l].mask(&a);
            let layer = &self.masked[l];
            let (out, computed) = layer.forward_masked(&a, &mask);
            flops.push(crate::condcomp::LayerFlops::from_counts(
                a.rows(),
                layer.in_dim(),
                layer.out_dim(),
                est.layers[l].rank(),
                computed,
            ));
            a = out;
        }
        let last = &self.masked[depth - 1];
        let mut logits = crate::linalg::matmul(&a, &last.wt.transpose());
        add_bias(&mut logits, &last.bias);
        flops.push(crate::condcomp::LayerFlops::from_counts(
            a.rows(),
            last.in_dim(),
            last.out_dim(),
            0,
            a.rows() * last.out_dim(),
        ));
        (logits, flops)
    }
}

impl Backend for NativeBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Native
    }

    fn input_dim(&self) -> usize {
        self.net.layer_sizes()[0]
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn predict(&self, x: &Mat, mode: Mode) -> Result<(Mat, Option<f64>)> {
        match mode {
            Mode::Control => Ok((self.net.logits(x, &NoGater), None)),
            Mode::ConditionalAe => {
                let (logits, flops) = self.forward_cond(x);
                Ok((logits, Some(flops.speedup())))
            }
        }
    }

    fn refresh(&self) -> Result<()> {
        let net = &self.net;
        self.estimators.write().unwrap().refresh(net);
        Ok(())
    }
}

/// PJRT backend over the AOT artifacts; the runtime is mutex-guarded because
/// refresh mutates factor literals.
pub struct PjrtBackend {
    rt: Mutex<ModelRuntime>,
    input_dim: usize,
    batch: usize,
}

impl PjrtBackend {
    /// Wrap a runtime for serving.
    ///
    /// The `ModelRuntime` (and the `Arc<Engine>` inside it) must be the only
    /// live handle to its PJRT client — see the `Send`/`Sync` note below.
    pub fn new(rt: ModelRuntime) -> PjrtBackend {
        let input_dim = rt.layers[0];
        let batch = rt.batch;
        PjrtBackend { rt: Mutex::new(rt), input_dim, batch }
    }
}

// SAFETY: the `xla` crate's handles (PjRtClient: Rc<...>, Literal /
// PjRtLoadedExecutable: raw pointers) are not auto-Send/Sync, but the
// underlying PJRT CPU client is thread-safe and *every* access to the
// runtime goes through the `Mutex<ModelRuntime>` above — the Rc refcount and
// the raw handles are never touched from two threads at once as long as the
// constructor's single-handle requirement holds.
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {}

impl Backend for PjrtBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    fn input_dim(&self) -> usize {
        self.input_dim
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn predict(&self, x: &Mat, mode: Mode) -> Result<(Mat, Option<f64>)> {
        let rt = self.rt.lock().unwrap();
        match mode {
            Mode::Control => Ok((rt.forward(x)?, None)),
            Mode::ConditionalAe => Ok((rt.forward_ae(x)?, None)),
        }
    }

    fn refresh(&self) -> Result<()> {
        self.rt.lock().unwrap().refresh_factors()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EstimatorConfig, NetConfig};
    use crate::util::Pcg32;

    fn native() -> NativeBackend {
        let mut rng = Pcg32::seeded(5);
        let net = Mlp::init(
            &NetConfig { layers: vec![8, 12, 10, 4], weight_sigma: 0.4, bias_init: 0.1 },
            &mut rng,
        );
        let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&[6, 5]), 3);
        NativeBackend::new(net, est, 32)
    }

    #[test]
    fn native_modes_agree_at_full_rank() {
        let mut rng = Pcg32::seeded(9);
        let net = Mlp::init(
            &NetConfig { layers: vec![8, 12, 10, 4], weight_sigma: 0.4, bias_init: 0.1 },
            &mut rng,
        );
        let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&[12, 10]), 3);
        let be = NativeBackend::new(net, est, 32);
        let x = Mat::randn(5, 8, 1.0, &mut rng);
        let (dense, _) = be.predict(&x, Mode::Control).unwrap();
        let (cond, speedup) = be.predict(&x, Mode::ConditionalAe).unwrap();
        assert!(dense.max_abs_diff(&cond) < 1e-3);
        assert!(speedup.is_some());
    }

    #[test]
    fn conditional_speedup_reported() {
        let be = native();
        let mut rng = Pcg32::seeded(2);
        let x = Mat::randn(4, 8, 1.0, &mut rng);
        let (_, speedup) = be.predict(&x, Mode::ConditionalAe).unwrap();
        let s = speedup.unwrap();
        assert!(s > 0.0 && s.is_finite());
    }

    #[test]
    fn refresh_succeeds() {
        let be = native();
        be.refresh().unwrap();
        assert_eq!(be.kind(), BackendKind::Native);
        assert_eq!(be.input_dim(), 8);
        assert_eq!(be.max_batch(), 32);
    }
}
