//! A small declarative CLI argument parser (offline substitute for `clap`).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, repeated
//! options, and positional arguments, with generated `--help` text.
//!
//! # `condcomp calibrate` usage
//!
//! The autotune subcommand fits per-layer dispatch thresholds and persists
//! them, so serving hosts measure once instead of at every startup:
//!
//! ```text
//! # Fit thresholds for a profile's architecture on this machine
//! # (~2 s default budget; writes condcomp-profile.json):
//! condcomp calibrate --profile mnist-small
//!
//! # CI smoke / constrained budget, explicit output path:
//! condcomp calibrate --budget-ms 500 --out profiles/ci.json
//!
//! # Serve with the persisted profile (also settable via the
//! # autotune.profile_path config key):
//! condcomp serve --autotune-profile profiles/ci.json
//! ```
//!
//! `serve` verifies the profile's model fingerprint, logs the per-layer
//! α* table it loaded, and falls back to online calibration
//! (`autotune.budget_ms`) when the file is missing or rejected.
//!
//! # `condcomp serve` usage
//!
//! The serving coordinator batches requests through a **sharded** front-end:
//! `--shards N` runs N independent queues, each drained by a dedicated
//! executor worker on its own slice of the compute-thread budget, so heavy
//! concurrent traffic does not serialize through one queue lock:
//!
//! ```text
//! # Two batcher shards, round-robin routing (the default policy):
//! condcomp serve --shards 2
//!
//! # Derive the shard count from the thread budget (one shard per two pool
//! # threads, capped at 8) and route to the shallowest queue:
//! condcomp serve --shards 0 --router least-depth
//!
//! # Config-file equivalents ([server] section / --set overrides):
//! condcomp serve --set server.shards=4 --set server.router=round-robin
//! ```
//!
//! Per-request outputs are bit-identical for any `--shards` value (batches
//! run the same kernels in the same accumulation order wherever they land);
//! the knob trades queueing contention against per-shard batching
//! opportunity. Per-shard queue depth, batch counts and predict latency are
//! exported through the `stats` op as `shard<i>_*` metrics. Shard
//! executors *lease* their slices from the shared worker pool, so the
//! server's worker threads equal the `--threads` budget for any shard
//! count; `stats` reports the accounting as `threads_total`,
//! `threads_leased` and `shard<i>_lease_threads`.
//!
//! # Kernel allow-lists (`--kernels`)
//!
//! `serve`, `bench` and `calibrate` accept `--kernels` (config key
//! `dispatch.kernels`): a comma-separated allow-list of registered compute
//! kernel ids the cost router may pick from — `dense`, `dense_packed`,
//! `masked` (and `pjrt` once the real bindings land):
//!
//! ```text
//! # Route only between the packed GEMM and the masked kernel:
//! condcomp serve --kernels dense_packed,masked
//!
//! # Calibrate cost columns for a restricted set (dense is always measured
//! # as the baseline), or bench the kernels against each other:
//! condcomp calibrate --kernels dense_packed,masked
//! condcomp bench --kernels dense,dense_packed
//! ```
//!
//! Every routing decision is observable in production: the `stats` op
//! exports one `layer<i>_kernel_<id>_batches` counter per hidden layer per
//! kernel, and `serve` logs the per-layer kernel-choice table at startup.
//!
//! # Observability (`--trace` / `condcomp trace`)
//!
//! `serve --trace` (config key `server.trace`, env `CONDCOMP_TRACE=1`)
//! enables span tracing through the request path and a fixed-size flight
//! recorder of the last N executed batches (`--trace-ring` /
//! `server.trace_ring`, default 64). `condcomp trace --addr host:port`
//! fetches the ring from a running server as JSON:
//!
//! ```text
//! condcomp serve --trace --trace-ring 128 &
//! condcomp trace --addr 127.0.0.1:7878 > trace-dump.json
//! ```
//!
//! The `stats` op additionally exports p50/p95/p99 for every latency
//! series and per-layer `alpha_predicted` / `alpha_achieved` /
//! `sign_agreement` gauges; see the README "Observability" section.

use std::collections::BTreeMap;

/// Declaration of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// Takes a value (`--key v`) vs boolean flag (`--key`).
    pub takes_value: bool,
    /// May appear multiple times.
    pub repeated: bool,
    pub default: Option<&'static str>,
}

impl OptSpec {
    pub fn value(name: &'static str, help: &'static str) -> OptSpec {
        OptSpec { name, help, takes_value: true, repeated: false, default: None }
    }

    pub fn flag(name: &'static str, help: &'static str) -> OptSpec {
        OptSpec { name, help, takes_value: false, repeated: false, default: None }
    }

    pub fn with_default(mut self, d: &'static str) -> OptSpec {
        self.default = Some(d);
        self
    }

    pub fn multi(mut self) -> OptSpec {
        self.repeated = true;
        self
    }
}

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, Vec<String>>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> &[String] {
        self.values.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|_| CliError(format!("--{name}: expected an integer, got '{s}'"))),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|_| CliError(format!("--{name}: expected a number, got '{s}'"))),
        }
    }

    /// Parse a rank list like "75-50-40-30" or "control".
    pub fn get_ranks(&self, name: &str) -> Result<Option<Vec<usize>>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some("control") => Ok(Some(Vec::new())),
            Some(s) => s
                .split('-')
                .map(|p| {
                    p.parse::<usize>()
                        .map_err(|_| CliError(format!("--{name}: bad rank list '{s}'")))
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }
}

/// CLI error (message already formatted for the user).
#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// A command with named options.
#[derive(Clone, Debug)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Command {
        Command { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, spec: OptSpec) -> Command {
        self.opts.push(spec);
        self
    }

    /// Parse raw args (not including the command name itself).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, CliError> {
        let mut out = Parsed::default();
        for spec in &self.opts {
            if let Some(d) = spec.default {
                out.values.insert(spec.name.to_string(), vec![d.to_string()]);
            }
        }
        let mut i = 0;
        while i < args.len() {
            let arg = &args[i];
            if let Some(name) = arg.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError(format!("unknown option --{name}\n\n{}", self.help())))?;
                if spec.takes_value {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{name} requires a value")))?
                        }
                    };
                    let slot = out.values.entry(name.to_string()).or_default();
                    if !spec.repeated {
                        slot.clear();
                    }
                    slot.push(value);
                } else {
                    if inline.is_some() {
                        return Err(CliError(format!("--{name} does not take a value")));
                    }
                    out.flags.insert(name.to_string(), true);
                }
            } else {
                out.positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// Generated help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let arg = if o.takes_value { format!("--{} <v>", o.name) } else { format!("--{}", o.name) };
            let dflt = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  {arg:<24} {}{dflt}\n", o.help));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("train", "train a network")
            .opt(OptSpec::value("profile", "experiment profile").with_default("mnist-small"))
            .opt(OptSpec::value("ranks", "estimator ranks, e.g. 50-35-25"))
            .opt(OptSpec::flag("quiet", "suppress progress"))
            .opt(OptSpec::value("set", "config override key=value").multi())
    }

    #[test]
    fn defaults_and_values() {
        let p = cmd().parse(&[]).unwrap();
        assert_eq!(p.get("profile"), Some("mnist-small"));
        let p = cmd()
            .parse(&["--profile".into(), "svhn-paper".into(), "--quiet".into()])
            .unwrap();
        assert_eq!(p.get("profile"), Some("svhn-paper"));
        assert!(p.flag("quiet"));
    }

    #[test]
    fn equals_form_and_positional() {
        let p = cmd().parse(&["--profile=x".into(), "fig2".into()]).unwrap();
        assert_eq!(p.get("profile"), Some("x"));
        assert_eq!(p.positional, vec!["fig2"]);
    }

    #[test]
    fn repeated_options_accumulate() {
        let p = cmd()
            .parse(&["--set".into(), "a=1".into(), "--set".into(), "b=2".into()])
            .unwrap();
        assert_eq!(p.get_all("set"), &["a=1".to_string(), "b=2".to_string()]);
    }

    #[test]
    fn rank_parsing() {
        let p = cmd().parse(&["--ranks".into(), "75-50-40-30".into()]).unwrap();
        assert_eq!(p.get_ranks("ranks").unwrap(), Some(vec![75, 50, 40, 30]));
        let p = cmd().parse(&["--ranks".into(), "control".into()]).unwrap();
        assert_eq!(p.get_ranks("ranks").unwrap(), Some(vec![]));
        let p = cmd().parse(&["--ranks".into(), "75-x".into()]).unwrap();
        assert!(p.get_ranks("ranks").is_err());
    }

    #[test]
    fn errors_are_reported() {
        assert!(cmd().parse(&["--nope".into()]).is_err());
        assert!(cmd().parse(&["--profile".into()]).is_err());
        assert!(cmd().parse(&["--quiet=yes".into()]).is_err());
    }

    #[test]
    fn typed_getters() {
        let c = Command::new("t", "t").opt(OptSpec::value("n", "count").with_default("5"));
        let p = c.parse(&[]).unwrap();
        assert_eq!(p.get_usize("n").unwrap(), Some(5));
        let p = c.parse(&["--n".into(), "abc".into()]).unwrap();
        assert!(p.get_usize("n").is_err());
    }
}
