//! The paper's analytical cost model (§3.4, Eqs. 8–11), in closed form.
//!
//! All counts follow the paper's conventions: a length-`d` dot product costs
//! `2d − 1` FLOPs, activation functions cost 1 FLOP per element, and the SVD
//! refresh is amortized with the feed-forwards-per-refresh ratio β.

/// Parameters of one layer's cost comparison.
#[derive(Clone, Copy, Debug)]
pub struct LayerCost {
    /// N in the paper: 1 for fully-connected, #patches for convolutional.
    pub n: f64,
    /// Input dimension d.
    pub d: f64,
    /// Output dimension h.
    pub h: f64,
    /// Estimator rank k.
    pub k: f64,
    /// Activation density α ∈ [0, 1].
    pub alpha: f64,
    /// Amortized SVD share per unit of feed-forward work. The paper quotes
    /// β = 250/50000 = 0.005 *per minibatch* (batch 250, SVD once per 50k
    /// examples); per example that is β = 0.005/250 = 2·10⁻⁵. Use the
    /// per-example value here, matching `n = 1` feed-forward costs.
    pub beta: f64,
}

impl LayerCost {
    pub fn new(d: usize, h: usize, k: usize, alpha: f64) -> LayerCost {
        LayerCost { n: 1.0, d: d as f64, h: h as f64, k: k as f64, alpha, beta: 0.0 }
    }

    pub fn with_beta(mut self, beta: f64) -> LayerCost {
        self.beta = beta;
        self
    }

    pub fn with_n(mut self, n: f64) -> LayerCost {
        self.n = n;
        self
    }

    /// Eq. 8: `F_nn = N(2d−1)h + Nh`.
    pub fn f_nn(&self) -> f64 {
        self.n * (2.0 * self.d - 1.0) * self.h + self.n * self.h
    }

    /// The SVD refresh term `β·O(d·h·min(d,h))` (unit constant).
    pub fn svd_term(&self) -> f64 {
        self.beta * self.d * self.h * self.d.min(self.h)
    }

    /// Eq. 9: estimator + conditional + amortized SVD.
    pub fn f_ae(&self) -> f64 {
        let est = self.n * (2.0 * self.d - 1.0) * self.k
            + self.n * (2.0 * self.k - 1.0) * self.h
            + self.n * self.h;
        let cond = self.alpha * (self.n * (2.0 * self.d - 1.0) * self.h + self.n * self.h);
        est + cond + self.svd_term()
    }

    /// Eq. 10: relative FLOP reduction `F_nn / F_ae`.
    pub fn speedup(&self) -> f64 {
        self.f_nn() / self.f_ae()
    }

    /// Largest rank k for which the estimator still pays off (speedup > 1) at
    /// this α; `None` if no rank ≥ 1 does.
    pub fn max_profitable_rank(&self) -> Option<usize> {
        // F_ae is increasing in k; binary search the crossover.
        let probe = |k: f64| LayerCost { k, ..*self }.speedup();
        if probe(1.0) <= 1.0 {
            return None;
        }
        let (mut lo, mut hi) = (1.0f64, self.d.min(self.h));
        if probe(hi) > 1.0 {
            return Some(hi as usize);
        }
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if probe(mid) > 1.0 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        Some(lo.floor().max(1.0) as usize)
    }

    /// Largest density α at which the estimator pays off for this rank.
    pub fn max_profitable_alpha(&self) -> Option<f64> {
        let probe = |alpha: f64| LayerCost { alpha, ..*self }.speedup();
        if probe(0.0) <= 1.0 {
            return None;
        }
        let (mut lo, mut hi) = (0.0f64, 1.0f64);
        if probe(1.0) > 1.0 {
            return Some(1.0);
        }
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if probe(mid) > 1.0 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        Some(lo)
    }
}

/// Eq. 11: whole-network relative speedup `Σ F_nn / Σ F_ae`.
pub fn network_speedup(layers: &[LayerCost]) -> f64 {
    let nn: f64 = layers.iter().map(|l| l.f_nn()).sum();
    let ae: f64 = layers.iter().map(|l| l.f_ae()).sum();
    nn / ae
}

/// The rank bound below which the low-rank product is cheaper than the dense
/// one: `k < d·h / (d + h)` (§3.1).
pub fn break_even_rank(d: usize, h: usize) -> f64 {
    (d as f64 * h as f64) / (d as f64 + h as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq8_eq9_hand_computed() {
        let c = LayerCost::new(784, 1000, 50, 0.1);
        assert_eq!(c.f_nn(), (2.0 * 784.0 - 1.0) * 1000.0 + 1000.0);
        let est = (2.0 * 784.0 - 1.0) * 50.0 + (2.0 * 50.0 - 1.0) * 1000.0 + 1000.0;
        let cond = 0.1 * ((2.0 * 784.0 - 1.0) * 1000.0 + 1000.0);
        assert!((c.f_ae() - (est + cond)).abs() < 1e-9);
    }

    #[test]
    fn paper_beta_example() {
        // §3.4: minibatch 250, train set 50,000 → β = 0.005 per minibatch,
        // i.e. 0.005/250 = 2e-5 per example (our F counts are per example).
        let beta_minibatch: f64 = 250.0 / 50_000.0;
        assert!((beta_minibatch - 0.005).abs() < 1e-12);
        let beta = beta_minibatch / 250.0;
        let c = LayerCost::new(784, 1000, 50, 0.1).with_beta(beta);
        assert!(c.svd_term() > 0.0);
        assert!(c.speedup() > 1.0, "paper's canonical regime must profit: {}", c.speedup());
    }

    #[test]
    fn speedup_decreases_with_alpha_and_k() {
        let base = LayerCost::new(1000, 1000, 50, 0.1);
        let denser = LayerCost { alpha: 0.5, ..base };
        let bigger_k = LayerCost { k: 200.0, ..base };
        assert!(base.speedup() > denser.speedup());
        assert!(base.speedup() > bigger_k.speedup());
    }

    #[test]
    fn fully_dense_never_profits() {
        let c = LayerCost::new(1000, 1000, 50, 1.0);
        assert!(c.speedup() < 1.0);
        assert!(c.max_profitable_rank().is_none() || c.speedup() < 1.0);
    }

    #[test]
    fn crossover_rank_is_consistent() {
        let c = LayerCost::new(784, 1000, 1, 0.1);
        let kmax = c.max_profitable_rank().expect("sparse regime must profit at k=1");
        let at = LayerCost { k: kmax as f64, ..c };
        let above = LayerCost { k: (kmax + 2) as f64, ..c };
        assert!(at.speedup() > 1.0, "speedup at kmax {}", at.speedup());
        assert!(above.speedup() <= 1.0 + 1e-6, "speedup above kmax {}", above.speedup());
    }

    #[test]
    fn crossover_alpha_is_consistent() {
        let c = LayerCost::new(784, 1000, 50, 0.0);
        let amax = c.max_profitable_alpha().expect("k=50 must profit at α=0");
        assert!(amax > 0.0 && amax < 1.0);
        let at = LayerCost { alpha: amax - 0.01, ..c };
        let above = LayerCost { alpha: amax + 0.01, ..c };
        assert!(at.speedup() > 1.0);
        assert!(above.speedup() < 1.0);
    }

    #[test]
    fn break_even_rank_matches_flops() {
        // At k slightly below d·h/(d+h), low-rank multiply is cheaper.
        let (d, h) = (300, 500);
        let kb = break_even_rank(d, h);
        let lowrank_flops = |k: f64| (2.0 * d as f64 - 1.0) * k + (2.0 * k - 1.0) * h as f64;
        let dense = (2.0 * d as f64 - 1.0) * h as f64;
        assert!(lowrank_flops(kb * 0.95) < dense);
        assert!(lowrank_flops(kb * 1.10) > dense);
    }

    #[test]
    fn network_speedup_aggregates() {
        let layers = vec![
            LayerCost::new(784, 1000, 50, 0.1),
            LayerCost::new(1000, 600, 35, 0.1),
            LayerCost::new(600, 400, 25, 0.1),
        ];
        let s = network_speedup(&layers);
        let lo = layers.iter().map(|l| l.speedup()).fold(f64::INFINITY, f64::min);
        let hi = layers.iter().map(|l| l.speedup()).fold(0.0, f64::max);
        assert!(s >= lo && s <= hi, "aggregate {s} outside [{lo}, {hi}]");
    }
}
