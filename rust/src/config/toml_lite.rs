//! A TOML-subset parser sufficient for this crate's config files.
//!
//! Supported: `[section.subsection]` tables, `key = value` with string /
//! integer / float / boolean / homogeneous-array values, `#` comments, and
//! dotted lookup (`doc.get("train.lr")`). Unsupported (rejected, not silently
//! mangled): inline tables, array-of-tables, multi-line strings, datetimes.

use std::collections::BTreeMap;

/// A scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        match self {
            TomlValue::Arr(items) => items.iter().map(|v| v.as_usize()).collect(),
            _ => None,
        }
    }

    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        match self {
            TomlValue::Arr(items) => items.iter().map(|v| v.as_f64()).collect(),
            _ => None,
        }
    }
}

/// A parsed document: flat map from dotted path to value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    entries: BTreeMap<String, TomlValue>,
}

/// Parse error with line context.
#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    /// Parse a document from text.
    pub fn parse(src: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |m: &str| TomlError { line: lineno + 1, message: m.to_string() };
            if let Some(rest) = line.strip_prefix('[') {
                if line.starts_with("[[") {
                    return Err(err("array-of-tables is not supported"));
                }
                let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated section"))?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(err("empty section name"));
                }
                section = name.to_string();
            } else if let Some(eq) = find_top_level_eq(line) {
                let key = line[..eq].trim();
                if key.is_empty() {
                    return Err(err("empty key"));
                }
                let value = parse_value(line[eq + 1..].trim())
                    .map_err(|m| err(&format!("bad value for '{key}': {m}")))?;
                let path = if section.is_empty() {
                    key.to_string()
                } else {
                    format!("{section}.{key}")
                };
                if doc.entries.insert(path.clone(), value).is_some() {
                    return Err(err(&format!("duplicate key '{path}'")));
                }
            } else {
                return Err(err("expected 'key = value' or '[section]'"));
            }
        }
        Ok(doc)
    }

    /// Load and parse a file.
    pub fn load(path: &std::path::Path) -> Result<TomlDoc, TomlError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| TomlError { line: 0, message: format!("cannot read {path:?}: {e}") })?;
        TomlDoc::parse(&text)
    }

    /// Lookup by dotted path.
    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    /// Insert/override a value (CLI `--set key=value`; value re-parsed with
    /// TOML scalar rules, falling back to a string).
    pub fn set(&mut self, path: &str, raw: &str) {
        let v = parse_value(raw).unwrap_or_else(|_| TomlValue::Str(raw.to_string()));
        self.entries.insert(path.to_string(), v);
    }

    pub fn get_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(|v| v.as_str())
    }

    pub fn get_f64(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(|v| v.as_f64())
    }

    pub fn get_f32(&self, path: &str) -> Option<f32> {
        self.get_f64(path).map(|x| x as f32)
    }

    pub fn get_usize(&self, path: &str) -> Option<usize> {
        self.get(path).and_then(|v| v.as_usize())
    }

    pub fn get_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(|v| v.as_bool())
    }

    pub fn get_usize_vec(&self, path: &str) -> Option<Vec<usize>> {
        self.get(path).and_then(|v| v.as_usize_vec())
    }

    /// All keys under a section prefix.
    pub fn keys_under(&self, prefix: &str) -> Vec<&str> {
        let want = format!("{prefix}.");
        self.entries.keys().filter(|k| k.starts_with(&want)).map(|k| k.as_str()).collect()
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn find_top_level_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn parse_value(raw: &str) -> Result<TomlValue, String> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = raw.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        // Basic escapes only.
        let mut s = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => s.push('\n'),
                    Some('t') => s.push('\t'),
                    Some('"') => s.push('"'),
                    Some('\\') => s.push('\\'),
                    other => return Err(format!("bad escape '\\{other:?}'")),
                }
            } else {
                s.push(c);
            }
        }
        return Ok(TomlValue::Str(s));
    }
    if raw == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if raw == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = raw.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    let clean = raw.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(x) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(x));
    }
    Err(format!("cannot parse '{raw}'"))
}

/// Split an array body on commas not inside strings or nested brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# Paper Table 1, MNIST column.
profile = "paper"

[net]
layers = [784, 1000, 600, 400, 10]
weight_sigma = 0.05
bias_init = 1.0

[train]
lr = 0.25
lr_decay = 0.99          # per-epoch scaling
max_momentum = 0.8
l1_activation = 1e-5
use_dropout = true
name = "mnist # not a comment"
"#;

    #[test]
    fn parses_sample() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.get_str("profile"), Some("paper"));
        assert_eq!(doc.get_usize_vec("net.layers"), Some(vec![784, 1000, 600, 400, 10]));
        assert_eq!(doc.get_f64("net.weight_sigma"), Some(0.05));
        assert_eq!(doc.get_f64("train.lr"), Some(0.25));
        assert_eq!(doc.get_f64("train.l1_activation"), Some(1e-5));
        assert_eq!(doc.get_bool("train.use_dropout"), Some(true));
        assert_eq!(doc.get_str("train.name"), Some("mnist # not a comment"));
    }

    #[test]
    fn int_float_coercion() {
        let doc = TomlDoc::parse("x = 3\ny = 2.5").unwrap();
        assert_eq!(doc.get_f64("x"), Some(3.0));
        assert_eq!(doc.get_usize("x"), Some(3));
        assert_eq!(doc.get_usize("y"), None);
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("= 3").is_err());
        assert!(TomlDoc::parse("x = ").is_err());
        assert!(TomlDoc::parse("x = 1\nx = 2").is_err());
        assert!(TomlDoc::parse("just some words").is_err());
        assert!(TomlDoc::parse("[[tables]]\n").is_err());
    }

    #[test]
    fn nested_arrays() {
        let doc = TomlDoc::parse("m = [[1, 2], [3, 4]]").unwrap();
        match doc.get("m").unwrap() {
            TomlValue::Arr(rows) => {
                assert_eq!(rows.len(), 2);
                assert_eq!(rows[1].as_usize_vec(), Some(vec![3, 4]));
            }
            _ => panic!("expected array"),
        }
    }

    #[test]
    fn set_override() {
        let mut doc = TomlDoc::parse("x = 1").unwrap();
        doc.set("x", "2.5");
        assert_eq!(doc.get_f64("x"), Some(2.5));
        doc.set("name", "hello");
        assert_eq!(doc.get_str("name"), Some("hello"));
    }

    #[test]
    fn underscored_numbers() {
        let doc = TomlDoc::parse("n = 600_000").unwrap();
        assert_eq!(doc.get_usize("n"), Some(600000));
    }
}
