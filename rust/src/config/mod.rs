//! Configuration: a TOML-lite parser plus the typed experiment schema.
//!
//! Shipped configs under `configs/` encode the paper's Table 1
//! hyperparameters; every experiment driver and the serving binary load one
//! of these (or accept `--set key=value` overrides from the CLI).

pub mod toml_lite;
pub mod schema;

pub use schema::{
    AutotuneConfig, DatasetKind, DispatchSettings, EstimatorConfig, EstimatorSettings,
    ExperimentProfile, NetConfig, ServerSettings, TrainConfig,
};
pub use toml_lite::TomlDoc;
