//! Typed experiment configuration (paper Table 1 + scaled profiles).

use super::toml_lite::TomlDoc;

/// Which corpus an experiment runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// 28×28 grayscale digits (784-d), MNIST-like.
    Mnist,
    /// 32×32 RGB street-number crops reduced to a 1024-d Y channel, SVHN-like.
    Svhn,
}

impl DatasetKind {
    pub fn input_dim(self) -> usize {
        match self {
            DatasetKind::Mnist => 784,
            DatasetKind::Svhn => 1024,
        }
    }

    pub fn parse(s: &str) -> Option<DatasetKind> {
        match s.to_ascii_lowercase().as_str() {
            "mnist" => Some(DatasetKind::Mnist),
            "svhn" => Some(DatasetKind::Svhn),
            _ => None,
        }
    }
}

impl std::fmt::Display for DatasetKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DatasetKind::Mnist => write!(f, "mnist"),
            DatasetKind::Svhn => write!(f, "svhn"),
        }
    }
}

/// Network architecture + init (Table 1 rows "Architecture" / "Weight Init").
#[derive(Clone, Debug, PartialEq)]
pub struct NetConfig {
    /// Layer widths, input to output, e.g. `[784, 1000, 600, 400, 10]`.
    pub layers: Vec<usize>,
    /// Std-dev of the `N(0, σ²)` weight init.
    pub weight_sigma: f32,
    /// Constant bias init (the paper uses 1.0 to start ReLUs unsaturated).
    pub bias_init: f32,
}

impl NetConfig {
    pub fn num_weight_layers(&self) -> usize {
        self.layers.len() - 1
    }

    /// Number of hidden (non-output) weight matrices — the layers that get an
    /// activation estimator (the output layer never does, §4.1).
    pub fn num_estimated_layers(&self) -> usize {
        self.num_weight_layers().saturating_sub(1)
    }
}

/// Optimization hyperparameters (Table 1 + §3.5 schedules).
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub epochs: usize,
    pub batch_size: usize,
    /// γ₀ — initial learning rate.
    pub lr: f32,
    /// λ — per-epoch learning-rate scaling (γₙ = γ₀·λⁿ).
    pub lr_decay: f32,
    /// ν₀ — initial momentum.
    pub momentum: f32,
    /// ν_max — momentum ceiling.
    pub max_momentum: f32,
    /// β — per-epoch momentum growth (νₙ = min(ν_max, ν₀·βⁿ)).
    pub momentum_growth: f32,
    /// Dropout keep is `1 - p`; the paper fixes p = 0.5 on hidden layers.
    pub dropout_p: f32,
    /// λ in Eq. 7 — ℓ1 penalty on hidden activations.
    pub l1_activation: f32,
    /// ℓ2 weight penalty.
    pub l2_weight: f32,
    /// Max-norm constraint on incoming weight vectors (Table 1 "Maximum Norm").
    pub max_norm: f32,
    /// RNG seed for init, shuffling and dropout.
    pub seed: u64,
    /// Worker threads for the shared compute pool (0 = auto: the machine's
    /// available parallelism). Every parallel kernel is bit-identical to
    /// its serial oracle, so training trajectories and eval results do not
    /// depend on this knob — it changes wall-clock only.
    pub threads: usize,
}

/// Autotune-subsystem knobs: where the persisted machine profile lives and
/// how long calibration may take.
#[derive(Clone, Debug, PartialEq)]
pub struct AutotuneConfig {
    /// Path to the persisted `MachineProfile` JSON (`condcomp calibrate`
    /// writes it; `condcomp serve` loads it at startup). `None` = not
    /// configured — serve falls back to online calibration, then to the
    /// global default ratio.
    pub profile_path: Option<String>,
    /// Wall-clock budget for a whole-model calibration, in milliseconds
    /// (split evenly over all per-layer measurement points).
    pub budget_ms: u64,
}

impl Default for AutotuneConfig {
    fn default() -> AutotuneConfig {
        AutotuneConfig { profile_path: None, budget_ms: 2000 }
    }
}

/// Kernel-dispatch knobs (the `[dispatch]` config section).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct DispatchSettings {
    /// Kernel allow-list (`dispatch.kernels`, comma-separated, e.g.
    /// `"dense_packed,masked"` / CLI `--kernels`): which registered compute
    /// kernels the cost router may pick from. Empty = every registered
    /// kernel. Kept as strings here so the config layer stays independent of
    /// the condcomp registry; `serve`/`bench`/`calibrate` validate the ids
    /// via `KernelRegistry::parse_allowlist`.
    pub kernels: Vec<String>,
}

/// Serving-coordinator knobs (the `[server]` config section).
#[derive(Clone, Debug, PartialEq)]
pub struct ServerSettings {
    /// Batcher shards (`server.shards` / CLI `--shards`): independent
    /// request queues, each drained by a dedicated executor worker on its
    /// own slice of the compute-thread budget. 0 = derive from the budget
    /// (one shard per two pool threads, capped at 8).
    pub shards: usize,
    /// Shard routing policy (`server.router` / CLI `--router`):
    /// "round-robin" (default) or "least-depth". Kept as a string here so
    /// the config layer stays independent of the coordinator; `serve`
    /// validates it via `RouterKind::parse`.
    pub router: String,
    /// Span tracing + flight recorder (`server.trace` / CLI `--trace`):
    /// when true the server enables process-wide span tracing at startup.
    /// Default false; the `CONDCOMP_TRACE` env var can also turn it on.
    pub trace: bool,
    /// Flight-recorder ring capacity in batch records (`server.trace_ring` /
    /// CLI `--trace-ring`). The ring always exists (the `trace` protocol op
    /// dumps it); only recording is gated on tracing being enabled.
    pub trace_ring: usize,
    /// Bounded admission (`server.max_queue_depth` / CLI
    /// `--max-queue-depth`): per-shard queue depth at which new predict
    /// requests are shed with an explicit overloaded reply. 0 = unbounded.
    pub max_queue_depth: usize,
    /// Per-request deadline in milliseconds (`server.deadline_ms` / CLI
    /// `--deadline-ms`): enqueued items older than this at drain time get
    /// an overloaded reply instead of being executed dead-on-arrival.
    /// 0 = no deadline.
    pub deadline_ms: u64,
    /// Quality-elastic dispatch (`server.elastic` / CLI `--elastic`):
    /// under queue pressure, bias kernel routing toward the cheap masked
    /// class and truncate the estimator rank. Default false.
    pub elastic: bool,
    /// Worker replica addresses (`server.worker_addrs` / CLI
    /// `--worker-addrs`, CSV): when non-empty, `serve` runs as a
    /// coordinator forwarding batches to these `condcomp worker` processes
    /// over the TCP protocol instead of executing kernels in-process.
    pub worker_addrs: Vec<String>,
    /// Minimum workers that must complete the `hello` handshake at
    /// coordinator startup (`server.replicas` / CLI `--replicas`).
    /// 0 = at least one.
    pub replicas: usize,
    /// Per-attempt TCP connect timeout toward workers, milliseconds
    /// (`server.connect_timeout_ms`).
    pub connect_timeout_ms: u64,
    /// Connect retries after the first attempt (`server.retry_max`), with
    /// exponential backoff starting at `retry_backoff_ms`.
    pub retry_max: usize,
    /// Initial connect-retry backoff, milliseconds
    /// (`server.retry_backoff_ms`); doubles per attempt.
    pub retry_backoff_ms: u64,
    /// Replica health-check / reconnect cadence, milliseconds
    /// (`server.health_interval_ms`).
    pub health_interval_ms: u64,
}

impl Default for ServerSettings {
    fn default() -> ServerSettings {
        ServerSettings {
            shards: 0,
            router: "round-robin".into(),
            trace: false,
            trace_ring: 64,
            max_queue_depth: 0,
            deadline_ms: 0,
            elastic: false,
            worker_addrs: Vec::new(),
            replicas: 0,
            connect_timeout_ms: 1000,
            retry_max: 5,
            retry_backoff_ms: 50,
            health_interval_ms: 500,
        }
    }
}

/// Estimator knobs carried by a profile (the `[estimator]` config section).
/// Rank lists stay CLI-side (they name an experiment arm, not a profile);
/// this section holds the arm-independent estimator switches.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct EstimatorSettings {
    /// Quantize estimator factors to int8 after every refresh
    /// (`estimator.quantized` / CLI `--quantized-estimator`); see
    /// [`EstimatorConfig::quantized`].
    pub quantized: bool,
}

/// Per-layer activation-estimator configuration (§3.1–§3.2).
#[derive(Clone, Debug, PartialEq)]
pub struct EstimatorConfig {
    /// Rank of Ŵ_l per hidden layer, e.g. `[50, 35, 25]`. Empty = control
    /// network (no estimator).
    pub ranks: Vec<usize>,
    /// Refresh cadence in minibatches; `None` = once per epoch (paper §3.5).
    pub refresh_every: Option<usize>,
    /// Sign-decision bias `b` in `sgn(aUV − b)` (§5 extension; 0 = paper).
    pub bias: f32,
    /// Use the randomized range-finder instead of exact SVD for refresh
    /// (§5 "online approach" extension).
    pub randomized: bool,
    /// If set, choose each rank adaptively as the smallest rank capturing
    /// this fraction of spectral energy (§5 extension); overrides `ranks`.
    pub adaptive_energy: Option<f64>,
    /// Quantize the low-rank factors to int8 per-row scales after every
    /// (re)fit (`estimator.quantized`): full-rank mask production then runs
    /// both estimator stages on exact integer dots. Sign-agreement — not
    /// bit-identity — with the float estimator; off by default.
    pub quantized: bool,
}

impl EstimatorConfig {
    /// The control configuration: no estimator anywhere.
    pub fn control() -> EstimatorConfig {
        EstimatorConfig {
            ranks: Vec::new(),
            refresh_every: None,
            bias: 0.0,
            randomized: false,
            adaptive_energy: None,
            quantized: false,
        }
    }

    /// Paper-style fixed ranks, once-per-epoch exact SVD.
    pub fn fixed(ranks: &[usize]) -> EstimatorConfig {
        EstimatorConfig { ranks: ranks.to_vec(), ..EstimatorConfig::control() }
    }

    pub fn is_control(&self) -> bool {
        self.ranks.is_empty() && self.adaptive_energy.is_none()
    }

    /// Label like "75-50-40-30" (papers' config naming) or "control".
    pub fn label(&self) -> String {
        if self.is_control() {
            "control".to_string()
        } else if let Some(e) = self.adaptive_energy {
            format!("adaptive-{e:.2}")
        } else {
            self.ranks.iter().map(|r| r.to_string()).collect::<Vec<_>>().join("-")
        }
    }
}

/// A fully-resolved experiment profile: what to train, on what data, at what
/// scale. `paper` matches Table 1; `small`/`tiny` shrink corpus + epochs for
/// the 1-core testbed (EXPERIMENTS.md records which profile produced what).
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentProfile {
    pub name: String,
    pub dataset: DatasetKind,
    pub net: NetConfig,
    pub train: TrainConfig,
    /// Autotune subsystem knobs (profile path, calibration budget).
    pub autotune: AutotuneConfig,
    /// Serving-coordinator knobs (batcher shards, shard router).
    pub server: ServerSettings,
    /// Kernel-dispatch knobs (registry allow-list).
    pub dispatch: DispatchSettings,
    /// Estimator knobs (int8 factor quantization).
    pub estimator: EstimatorSettings,
    /// Training/validation/test example counts for the synthetic corpus.
    pub n_train: usize,
    pub n_valid: usize,
    pub n_test: usize,
}

impl ExperimentProfile {
    /// The paper's MNIST setup (Table 1, right column).
    pub fn mnist_paper() -> ExperimentProfile {
        ExperimentProfile {
            name: "mnist-paper".into(),
            dataset: DatasetKind::Mnist,
            net: NetConfig {
                layers: vec![784, 1000, 600, 400, 10],
                weight_sigma: 0.05,
                bias_init: 1.0,
            },
            train: TrainConfig {
                epochs: 50,
                batch_size: 100,
                lr: 0.25,
                lr_decay: 0.99,
                momentum: 0.5,
                max_momentum: 0.8,
                momentum_growth: 1.05,
                dropout_p: 0.5,
                l1_activation: 1e-5,
                l2_weight: 5e-5,
                max_norm: 25.0,
                seed: 1,
                threads: 0,
            },
            autotune: AutotuneConfig::default(),
            server: ServerSettings::default(),
            dispatch: DispatchSettings::default(),
            estimator: EstimatorSettings::default(),
            n_train: 50_000,
            n_valid: 10_000,
            n_test: 10_000,
        }
    }

    /// The paper's SVHN setup (Table 1, left column).
    pub fn svhn_paper() -> ExperimentProfile {
        ExperimentProfile {
            name: "svhn-paper".into(),
            dataset: DatasetKind::Svhn,
            net: NetConfig {
                layers: vec![1024, 1500, 700, 400, 200, 10],
                weight_sigma: 0.01,
                bias_init: 1.0,
            },
            train: TrainConfig {
                epochs: 50,
                batch_size: 250,
                lr: 0.15,
                lr_decay: 0.99,
                momentum: 0.5,
                max_momentum: 0.8,
                momentum_growth: 1.01,
                dropout_p: 0.5,
                l1_activation: 0.0,
                l2_weight: 0.0,
                max_norm: 25.0,
                seed: 1,
                threads: 0,
            },
            autotune: AutotuneConfig::default(),
            server: ServerSettings::default(),
            dispatch: DispatchSettings::default(),
            estimator: EstimatorSettings::default(),
            n_train: 590_000,
            n_valid: 14_388,
            n_test: 26_032,
        }
    }

    /// MNIST scaled for the 1-core container: same architecture family,
    /// ~10× smaller corpus, fewer epochs.
    pub fn mnist_small() -> ExperimentProfile {
        let mut p = ExperimentProfile::mnist_paper();
        p.name = "mnist-small".into();
        p.net.layers = vec![784, 256, 128, 64, 10];
        p.train.epochs = 12;
        p.n_train = 6_000;
        p.n_valid = 1_000;
        p.n_test = 1_000;
        p
    }

    /// SVHN-like scaled profile.
    ///
    /// Optimization knobs deviate from Table 1 deliberately: the paper's
    /// lr = 0.15 / dropout = 0.5 / σ = 0.01 were tuned for 590k examples ×
    /// many epochs; at 1/100 corpus scale they leave the 5-layer net stuck
    /// at chance (verified experimentally — see EXPERIMENTS.md). The scaled
    /// profile uses lr 0.3, σ 0.05, bias 0.1, dropout 0.25 so the sweep's
    /// *shape* (control vs estimator ranks) is measurable in minutes.
    pub fn svhn_small() -> ExperimentProfile {
        let mut p = ExperimentProfile::svhn_paper();
        p.name = "svhn-small".into();
        p.net.layers = vec![1024, 300, 180, 100, 60, 10];
        p.net.weight_sigma = 0.05;
        p.net.bias_init = 0.1;
        p.train.lr = 0.3;
        p.train.dropout_p = 0.25;
        p.train.epochs = 12;
        p.train.batch_size = 100;
        p.n_train = 8_000;
        p.n_valid = 1_000;
        p.n_test = 1_000;
        p
    }

    /// Minutes-scale profile used by integration tests.
    pub fn mnist_tiny() -> ExperimentProfile {
        let mut p = ExperimentProfile::mnist_small();
        p.name = "mnist-tiny".into();
        p.net.layers = vec![784, 64, 48, 32, 10];
        p.train.epochs = 3;
        p.n_train = 800;
        p.n_valid = 200;
        p.n_test = 200;
        p
    }

    /// Seconds-scale SVHN-like profile for integration tests.
    pub fn svhn_tiny() -> ExperimentProfile {
        let mut p = ExperimentProfile::svhn_small();
        p.name = "svhn-tiny".into();
        p.net.layers = vec![1024, 64, 48, 32, 24, 10];
        p.train.epochs = 2;
        p.n_train = 600;
        p.n_valid = 150;
        p.n_test = 150;
        p
    }

    /// Resolve a named profile.
    pub fn by_name(name: &str) -> Option<ExperimentProfile> {
        match name {
            "mnist-paper" => Some(Self::mnist_paper()),
            "svhn-paper" => Some(Self::svhn_paper()),
            "mnist-small" => Some(Self::mnist_small()),
            "svhn-small" => Some(Self::svhn_small()),
            "mnist-tiny" => Some(Self::mnist_tiny()),
            "svhn-tiny" => Some(Self::svhn_tiny()),
            _ => None,
        }
    }

    /// Scale the paper's per-layer estimator ranks to this profile's layer
    /// widths, so rank configs like `50-35-25` stay meaningful on shrunken
    /// architectures (each rank is scaled by the hidden-width ratio and
    /// clamped to `[1, min(fan_in, fan_out)]`).
    pub fn scale_ranks(&self, paper_ranks: &[usize], paper: &ExperimentProfile) -> Vec<usize> {
        paper_ranks
            .iter()
            .enumerate()
            .map(|(l, &r)| {
                let ours = self.net.layers[l + 1] as f64;
                let theirs = paper.net.layers[l + 1] as f64;
                let scaled = (r as f64 * ours / theirs).round() as usize;
                let cap = self.net.layers[l].min(self.net.layers[l + 1]);
                scaled.clamp(1, cap)
            })
            .collect()
    }

    /// Apply `key = value` overrides from a TOML doc (profile files or CLI).
    pub fn apply_overrides(&mut self, doc: &TomlDoc) {
        if let Some(v) = doc.get_usize_vec("net.layers") {
            self.net.layers = v;
        }
        if let Some(x) = doc.get_f32("net.weight_sigma") {
            self.net.weight_sigma = x;
        }
        if let Some(x) = doc.get_f32("net.bias_init") {
            self.net.bias_init = x;
        }
        if let Some(x) = doc.get_usize("train.epochs") {
            self.train.epochs = x;
        }
        if let Some(x) = doc.get_usize("train.batch_size") {
            self.train.batch_size = x;
        }
        if let Some(x) = doc.get_f32("train.lr") {
            self.train.lr = x;
        }
        if let Some(x) = doc.get_f32("train.lr_decay") {
            self.train.lr_decay = x;
        }
        if let Some(x) = doc.get_f32("train.momentum") {
            self.train.momentum = x;
        }
        if let Some(x) = doc.get_f32("train.max_momentum") {
            self.train.max_momentum = x;
        }
        if let Some(x) = doc.get_f32("train.momentum_growth") {
            self.train.momentum_growth = x;
        }
        if let Some(x) = doc.get_f32("train.dropout_p") {
            self.train.dropout_p = x;
        }
        if let Some(x) = doc.get_f32("train.l1_activation") {
            self.train.l1_activation = x;
        }
        if let Some(x) = doc.get_f32("train.l2_weight") {
            self.train.l2_weight = x;
        }
        if let Some(x) = doc.get_f32("train.max_norm") {
            self.train.max_norm = x;
        }
        if let Some(x) = doc.get_usize("train.seed") {
            self.train.seed = x as u64;
        }
        if let Some(x) = doc.get_usize("train.threads") {
            self.train.threads = x;
        }
        if let Some(s) = doc.get_str("autotune.profile_path") {
            self.autotune.profile_path = Some(s.to_string());
        }
        if let Some(x) = doc.get_usize("autotune.budget_ms") {
            self.autotune.budget_ms = x as u64;
        }
        if let Some(x) = doc.get_usize("server.shards") {
            self.server.shards = x;
        }
        if let Some(s) = doc.get_str("server.router") {
            self.server.router = s.to_string();
        }
        if let Some(b) = doc.get_bool("server.trace") {
            self.server.trace = b;
        }
        if let Some(x) = doc.get_usize("server.trace_ring") {
            self.server.trace_ring = x;
        }
        if let Some(x) = doc.get_usize("server.max_queue_depth") {
            self.server.max_queue_depth = x;
        }
        if let Some(x) = doc.get_usize("server.deadline_ms") {
            self.server.deadline_ms = x as u64;
        }
        if let Some(b) = doc.get_bool("server.elastic") {
            self.server.elastic = b;
        }
        if let Some(s) = doc.get_str("server.worker_addrs") {
            self.server.worker_addrs = s
                .split(',')
                .map(str::trim)
                .filter(|t| !t.is_empty())
                .map(str::to_string)
                .collect();
        }
        if let Some(x) = doc.get_usize("server.replicas") {
            self.server.replicas = x;
        }
        if let Some(x) = doc.get_usize("server.connect_timeout_ms") {
            self.server.connect_timeout_ms = x as u64;
        }
        if let Some(x) = doc.get_usize("server.retry_max") {
            self.server.retry_max = x;
        }
        if let Some(x) = doc.get_usize("server.retry_backoff_ms") {
            self.server.retry_backoff_ms = x as u64;
        }
        if let Some(x) = doc.get_usize("server.health_interval_ms") {
            self.server.health_interval_ms = x as u64;
        }
        if let Some(b) = doc.get_bool("estimator.quantized") {
            self.estimator.quantized = b;
        }
        if let Some(s) = doc.get_str("dispatch.kernels") {
            self.dispatch.kernels = s
                .split(',')
                .map(str::trim)
                .filter(|t| !t.is_empty())
                .map(str::to_string)
                .collect();
        }
        if let Some(x) = doc.get_usize("data.n_train") {
            self.n_train = x;
        }
        if let Some(x) = doc.get_usize("data.n_valid") {
            self.n_valid = x;
        }
        if let Some(x) = doc.get_usize("data.n_test") {
            self.n_test = x;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_profiles_match_table1() {
        let m = ExperimentProfile::mnist_paper();
        assert_eq!(m.net.layers, vec![784, 1000, 600, 400, 10]);
        assert_eq!(m.net.weight_sigma, 0.05);
        assert_eq!(m.train.lr, 0.25);
        assert_eq!(m.train.momentum_growth, 1.05);
        assert_eq!(m.train.l1_activation, 1e-5);
        assert_eq!(m.train.l2_weight, 5e-5);
        let s = ExperimentProfile::svhn_paper();
        assert_eq!(s.net.layers, vec![1024, 1500, 700, 400, 200, 10]);
        assert_eq!(s.net.weight_sigma, 0.01);
        assert_eq!(s.train.lr, 0.15);
        assert_eq!(s.train.momentum_growth, 1.01);
        assert_eq!(s.train.l1_activation, 0.0);
    }

    #[test]
    fn estimator_labels() {
        assert_eq!(EstimatorConfig::control().label(), "control");
        assert_eq!(EstimatorConfig::fixed(&[75, 50, 40, 30]).label(), "75-50-40-30");
    }

    #[test]
    fn estimated_layers_excludes_output() {
        let m = ExperimentProfile::mnist_paper();
        assert_eq!(m.net.num_weight_layers(), 4);
        assert_eq!(m.net.num_estimated_layers(), 3);
    }

    #[test]
    fn rank_scaling_tracks_width_ratio() {
        let paper = ExperimentProfile::mnist_paper();
        let small = ExperimentProfile::mnist_small();
        let scaled = small.scale_ranks(&[50, 35, 25], &paper);
        assert_eq!(scaled.len(), 3);
        // 50 * 256/1000 ≈ 13, 35 * 128/600 ≈ 7, 25 * 64/400 = 4.
        assert_eq!(scaled, vec![13, 7, 4]);
    }

    #[test]
    fn overrides_apply() {
        let mut p = ExperimentProfile::mnist_tiny();
        let doc = TomlDoc::parse("[train]\nepochs = 9\nlr = 0.5\nthreads = 4\n[data]\nn_train = 123")
            .unwrap();
        p.apply_overrides(&doc);
        assert_eq!(p.train.epochs, 9);
        assert_eq!(p.train.lr, 0.5);
        assert_eq!(p.train.threads, 4);
        assert_eq!(p.n_train, 123);
    }

    #[test]
    fn threads_defaults_to_auto() {
        assert_eq!(ExperimentProfile::mnist_paper().train.threads, 0);
        assert_eq!(ExperimentProfile::svhn_tiny().train.threads, 0);
    }

    #[test]
    fn autotune_defaults_and_overrides() {
        let mut p = ExperimentProfile::mnist_tiny();
        assert_eq!(p.autotune, AutotuneConfig::default());
        assert!(p.autotune.profile_path.is_none());
        assert_eq!(p.autotune.budget_ms, 2000);
        let doc = TomlDoc::parse(
            "[autotune]\nprofile_path = \"profiles/ci.json\"\nbudget_ms = 500",
        )
        .unwrap();
        p.apply_overrides(&doc);
        assert_eq!(p.autotune.profile_path.as_deref(), Some("profiles/ci.json"));
        assert_eq!(p.autotune.budget_ms, 500);
    }

    #[test]
    fn server_defaults_and_overrides() {
        let mut p = ExperimentProfile::mnist_tiny();
        assert_eq!(p.server, ServerSettings::default());
        assert_eq!(p.server.shards, 0, "0 = derive from the thread budget");
        assert_eq!(p.server.router, "round-robin");
        assert!(!p.server.trace, "tracing is opt-in");
        assert_eq!(p.server.trace_ring, 64);
        assert_eq!(p.server.max_queue_depth, 0, "unbounded admission by default");
        assert_eq!(p.server.deadline_ms, 0, "no deadline by default");
        assert!(!p.server.elastic, "elastic dispatch is opt-in");
        assert!(p.server.worker_addrs.is_empty(), "in-process serving by default");
        assert_eq!(p.server.replicas, 0, "0 = at least one worker must handshake");
        assert_eq!(p.server.connect_timeout_ms, 1000);
        assert_eq!(p.server.retry_max, 5);
        assert_eq!(p.server.retry_backoff_ms, 50);
        assert_eq!(p.server.health_interval_ms, 500);
        let doc = TomlDoc::parse(
            "[server]\nshards = 4\nrouter = \"least-depth\"\ntrace = true\ntrace_ring = 128\n\
             max_queue_depth = 256\ndeadline_ms = 50\nelastic = true\n\
             worker_addrs = \"127.0.0.1:7001, 127.0.0.1:7002\"\nreplicas = 2\n\
             connect_timeout_ms = 250\nretry_max = 7\nretry_backoff_ms = 20\n\
             health_interval_ms = 100",
        )
        .unwrap();
        p.apply_overrides(&doc);
        assert_eq!(p.server.shards, 4);
        assert_eq!(p.server.router, "least-depth");
        assert!(p.server.trace);
        assert_eq!(p.server.trace_ring, 128);
        assert_eq!(p.server.max_queue_depth, 256);
        assert_eq!(p.server.deadline_ms, 50);
        assert!(p.server.elastic);
        assert_eq!(
            p.server.worker_addrs,
            vec!["127.0.0.1:7001".to_string(), "127.0.0.1:7002".to_string()],
            "CSV worker list, whitespace-tolerant"
        );
        assert_eq!(p.server.replicas, 2);
        assert_eq!(p.server.connect_timeout_ms, 250);
        assert_eq!(p.server.retry_max, 7);
        assert_eq!(p.server.retry_backoff_ms, 20);
        assert_eq!(p.server.health_interval_ms, 100);
    }

    #[test]
    fn dispatch_defaults_and_overrides() {
        let mut p = ExperimentProfile::mnist_tiny();
        assert_eq!(p.dispatch, DispatchSettings::default());
        assert!(p.dispatch.kernels.is_empty(), "empty = every registered kernel");
        let doc = TomlDoc::parse("[dispatch]\nkernels = \"dense_packed, masked\"").unwrap();
        p.apply_overrides(&doc);
        assert_eq!(p.dispatch.kernels, vec!["dense_packed".to_string(), "masked".to_string()]);
    }

    #[test]
    fn estimator_settings_default_and_override() {
        let mut p = ExperimentProfile::mnist_tiny();
        assert_eq!(p.estimator, EstimatorSettings::default());
        assert!(!p.estimator.quantized, "int8 estimator factors are opt-in");
        assert!(!EstimatorConfig::control().quantized);
        let doc = TomlDoc::parse("[estimator]\nquantized = true").unwrap();
        p.apply_overrides(&doc);
        assert!(p.estimator.quantized);
    }

    #[test]
    fn by_name_roundtrip() {
        for name in ["mnist-paper", "svhn-paper", "mnist-small", "svhn-small", "mnist-tiny"] {
            assert_eq!(ExperimentProfile::by_name(name).unwrap().name, name);
        }
        assert!(ExperimentProfile::by_name("nope").is_none());
    }
}
