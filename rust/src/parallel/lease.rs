//! Pool slicing: lease `k` workers from a shared [`ThreadPool`].
//!
//! PR 3's sharded coordinator gave every shard executor a *private*
//! `ThreadPool` sized by [`super::partition_threads`], which meant an
//! N-shard server spawned `budget + global-pool` threads with the global
//! pool parked the whole time. A [`PoolLease`] removes that cost: it is a
//! **reservation** of `k` worker slots on the shared pool — no new threads,
//! just an atomic counter bounding how much of the pool each holder may
//! occupy at once.
//!
//! Semantics:
//!
//! - [`ThreadPool::lease`]`(k)` grants `min(k, threads − leased)` slots;
//!   concurrent grants can never sum past the pool size. The grant is
//!   returned when the lease drops (including during a panic unwind).
//! - A lease's **width** (`granted.max(1)`) is what the partition
//!   primitives size their chunking by; a zero-grant lease degrades to
//!   inline execution on the caller's thread, exactly like a one-thread
//!   pool. Nested requests (from inside a pool job) and `k == 0` degrade
//!   the same way, so leasing is always safe to call.
//! - [`PoolLease::scope`] mirrors [`ThreadPool::scope`]: jobs borrow from
//!   the caller's stack and the first job panic is re-raised when the scope
//!   closes. Jobs land on the shared queue — a lease bounds how many chunks
//!   a *well-behaved* caller enqueues (the partition primitives spawn at
//!   most `width` jobs per scope), it does not partition the physical
//!   workers, so the pool stays work-conserving.
//! - [`ThreadPool::share`] is the non-reserving variant: full pool width,
//!   nothing subtracted from the leasable capacity. It is the
//!   compatibility path for pool-less callers (`Backend::predict`) that
//!   should use whatever the machine has without starving the serving
//!   executors' reservations.
//!
//! Bit-identity: results never depend on the lease width (property-tested
//! per kernel and end-to-end in `tests/serve_e2e.rs`); the width only
//! changes wall-clock and how politely callers share the machine.

use super::pool::{on_pool_thread, Parallelism, Scope, ThreadPool};

/// A scoped slice of a shared [`ThreadPool`]: `granted` reserved worker
/// slots, returned on drop.
pub struct PoolLease<'p> {
    pool: &'p ThreadPool,
    /// Effective worker count for partitioning (`≥ 1`; `1` = inline).
    width: usize,
    /// Slots subtracted from the pool's leasable capacity (0 for shared and
    /// degraded leases).
    reserved: usize,
}

impl ThreadPool {
    /// Lease up to `k` workers from this pool. The grant is
    /// `min(k, threads − leased)` — possibly 0, in which case the lease
    /// degrades to inline execution. Requests from inside a pool job and
    /// `k == 0` degrade inline immediately (nested scopes must never queue
    /// behind their own worker).
    pub fn lease(&self, k: usize) -> PoolLease<'_> {
        if k == 0 || on_pool_thread() {
            return PoolLease { pool: self, width: 1, reserved: 0 };
        }
        let granted = self.try_reserve(k);
        PoolLease { pool: self, width: granted.max(1), reserved: granted }
    }

    /// A non-reserving lease over the whole pool: full width, nothing
    /// subtracted from the leasable capacity. Pool-less callers use this to
    /// ride the shared pool without starving concurrent reservations.
    pub fn share(&self) -> PoolLease<'_> {
        let width = if on_pool_thread() { 1 } else { self.threads() };
        PoolLease { pool: self, width, reserved: 0 }
    }
}

impl<'p> PoolLease<'p> {
    /// The pool this lease slices.
    pub fn pool(&self) -> &'p ThreadPool {
        self.pool
    }

    /// Worker slots actually reserved (0 for shared/degraded leases) — the
    /// number the serving `stats` op reports per shard.
    pub fn granted(&self) -> usize {
        self.reserved
    }

    /// Effective worker count for partitioning (`granted.max(1)` for
    /// reserving leases; the pool size for shared ones). `1` means work
    /// runs inline on the caller's thread.
    pub fn threads(&self) -> usize {
        self.width
    }

    /// True when this lease executes inline rather than on pool workers.
    pub fn is_inline(&self) -> bool {
        self.width <= 1
    }

    /// Run `f` with a [`Scope`], mirroring [`ThreadPool::scope`]: returns
    /// after every spawned job finished; the first job panic is re-raised
    /// here. Degrades to inline execution for zero-width leases and when
    /// called from a pool job.
    pub fn scope<'env, F, T>(&'env self, f: F) -> T
    where
        F: for<'scope> FnOnce(&'scope Scope<'scope, 'env>) -> T,
    {
        if self.width > 1 && !on_pool_thread() {
            self.pool.scope(f)
        } else {
            self.pool.scope_inline(f)
        }
    }
}

impl Parallelism for PoolLease<'_> {
    fn pool(&self) -> &ThreadPool {
        self.pool
    }

    fn width(&self) -> usize {
        self.width
    }
}

impl Drop for PoolLease<'_> {
    fn drop(&mut self) {
        self.pool.release_reserved(self.reserved);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn grants_clamp_to_available_capacity() {
        let pool = ThreadPool::new(4);
        let a = pool.lease(3);
        assert_eq!(a.granted(), 3);
        assert_eq!(a.threads(), 3);
        assert_eq!(pool.leased(), 3);
        let b = pool.lease(3);
        assert_eq!(b.granted(), 1, "only one slot left");
        let c = pool.lease(2);
        assert_eq!(c.granted(), 0, "exhausted pool grants nothing");
        assert_eq!(c.threads(), 1, "zero-grant lease degrades inline");
        assert!(c.is_inline());
        assert_eq!(pool.leased(), 4);
        drop(b);
        assert_eq!(pool.leased(), 3);
        drop(a);
        drop(c);
        assert_eq!(pool.leased(), 0);
    }

    #[test]
    fn zero_request_and_shared_leases_reserve_nothing() {
        let pool = ThreadPool::new(3);
        let z = pool.lease(0);
        assert_eq!((z.granted(), z.threads()), (0, 1));
        let s = pool.share();
        assert_eq!((s.granted(), s.threads()), (0, 3));
        assert!(!s.is_inline());
        assert_eq!(pool.leased(), 0, "neither touches the counter");
        // A shared lease does not block reservations.
        let r = pool.lease(3);
        assert_eq!(r.granted(), 3);
    }

    #[test]
    fn lease_scope_runs_jobs_and_releases_on_drop() {
        let pool = ThreadPool::new(4);
        let sum = AtomicU64::new(0);
        {
            let lease = pool.lease(2);
            lease.scope(|s| {
                for i in 1..=10u64 {
                    let sum = &sum;
                    s.spawn(move || {
                        sum.fetch_add(i, Ordering::Relaxed);
                    });
                }
            });
            assert_eq!(pool.leased(), 2, "held across the scope");
        }
        assert_eq!(sum.load(Ordering::Relaxed), 55);
        assert_eq!(pool.leased(), 0);
    }

    #[test]
    fn lease_releases_during_panic_unwind() {
        let pool = ThreadPool::new(4);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let lease = pool.lease(3);
            assert_eq!(pool.leased(), 3);
            lease.scope(|s| {
                s.spawn(|| panic!("boom in leased job"));
            });
        }));
        let payload = result.expect_err("scope re-raises the job panic");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .unwrap_or("");
        assert!(msg.contains("boom in leased job"), "payload lost: {msg:?}");
        assert_eq!(pool.leased(), 0, "reservation returned during unwind");
        // And the pool still works.
        assert_eq!(pool.lease(4).granted(), 4);
    }

    #[test]
    fn nested_lease_requests_degrade_inline() {
        let pool = ThreadPool::new(2);
        let outer = std::thread::current().id();
        let ok = std::sync::Mutex::new(false);
        pool.scope(|s| {
            let ok = &ok;
            let pool = &pool;
            s.spawn(move || {
                let worker = std::thread::current().id();
                assert_ne!(worker, outer, "job must be on a pool worker");
                let lease = pool.lease(2);
                assert_eq!(lease.granted(), 0, "nested lease grants nothing");
                assert!(lease.is_inline());
                let mut ran_on = None;
                lease.scope(|s2| {
                    let slot = &mut ran_on;
                    s2.spawn(move || *slot = Some(std::thread::current().id()));
                });
                assert_eq!(ran_on, Some(worker), "nested scope ran inline");
                *ok.lock().unwrap() = true;
            });
        });
        assert!(*ok.lock().unwrap());
        assert_eq!(pool.leased(), 0);
    }

    #[test]
    fn inline_scope_preserves_panic_payloads() {
        let pool = ThreadPool::new(2);
        let lease = pool.lease(0); // inline
        let result = catch_unwind(AssertUnwindSafe(|| {
            lease.scope(|s| s.spawn(|| panic!("inline boom")));
        }));
        let payload = result.expect_err("inline scope re-raises too");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("inline boom"), "payload lost: {msg:?}");
    }
}
