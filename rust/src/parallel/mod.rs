//! The parallel execution subsystem: a shared worker pool + deterministic
//! data-parallel primitives, threaded through the entire forward path.
//!
//! The paper's speedup claim (§3.4) is a FLOP-count argument; turning saved
//! FLOPs into saved *seconds* additionally requires that the kernels use the
//! machine. This module supplies that layer:
//!
//! - [`ThreadPool`] — a std-only pool of persistent workers with a
//!   `std::thread::scope`-style borrowing-jobs API ([`ThreadPool::scope`]).
//! - [`global`] — the process-wide shared pool (sized by
//!   [`configure_global`] / the `--threads` CLI knob / `CONDCOMP_THREADS`,
//!   defaulting to the machine's available parallelism). The GEMM kernels,
//!   the masked forward, the estimator and the serving backend all execute
//!   on this one pool, so concurrent server workers queue compute instead of
//!   oversubscribing cores.
//! - [`PoolLease`] — a scoped slice of the shared pool
//!   ([`ThreadPool::lease`]`(k)`): `k` worker slots reserved atomically
//!   (concurrent grants never sum past the pool size), returned on drop.
//!   The serving coordinator's shard executors each hold one, so an
//!   N-shard server occupies exactly the configured thread budget instead
//!   of spawning private pools beside a parked global one.
//! - [`par_chunks_mut`] / [`par_row_chunks`] / [`chunk_rows`] — contiguous
//!   disjoint-chunk partitioning, generic over [`Parallelism`] (a whole
//!   pool or a lease). Work inside a chunk runs exactly the code the serial
//!   kernel runs, so every parallel kernel in the crate is **bit-identical
//!   to its serial oracle and invariant to the thread count and lease
//!   width** (pinned by property tests at thread counts 1, 2 and 7).
//!
//! Rules of the road:
//!
//! - Pool jobs must not spawn nested scopes. The primitives enforce this
//!   automatically: calls made from a pool thread ([`on_pool_thread`])
//!   execute inline instead of enqueueing, so nesting degrades to serial
//!   execution rather than deadlocking.
//! - Serial kernels stay available and are the correctness oracles; the
//!   parallel entry points fall back to them for small inputs where
//!   dispatch overhead would dominate.
//!
//! Which kernel (dense-parallel vs masked-parallel) to run per layer per
//! batch is decided one level up, by
//! [`crate::condcomp::DispatchPolicy`], from the predicted mask density α
//! and the §3.4 cost model.

pub mod pool;
pub mod lease;
pub mod partition;

pub use lease::PoolLease;
pub use partition::{chunk_rows, par_chunks_mut, par_row_chunks, partition_threads};
pub use pool::{
    configure_global, configure_global_if_unset, default_threads, global, on_pool_thread,
    Parallelism, Scope, ThreadPool,
};
