//! A std-only scoped worker pool.
//!
//! Design constraints (see the module docs in `parallel/mod.rs`):
//!
//! - **Persistent workers.** Threads are spawned once per pool and reused;
//!   dispatching a scope costs two mutex/condvar handshakes per job, not a
//!   thread spawn. One process-wide pool ([`global`]) is shared by the GEMM
//!   kernels, the masked forward, the estimator, and the serving backend, so
//!   concurrent server workers queue compute on the same threads instead of
//!   oversubscribing the machine.
//! - **Scoped, borrowing jobs.** [`ThreadPool::scope`] mirrors
//!   `std::thread::scope`: jobs may borrow from the caller's stack because
//!   `scope` does not return (or unwind) until every spawned job has
//!   finished. This is the same soundness argument as `std::thread::scope`:
//!   the borrowed data cannot be observed by the caller while jobs still run,
//!   because control does not come back until they are done.
//! - **No nesting.** Pool jobs must never block on a nested scope — with all
//!   workers blocked waiting for sub-jobs behind them in the queue, the pool
//!   would deadlock. Workers mark themselves with a thread-local flag;
//!   [`on_pool_thread`] lets the partition primitives fall back to serial
//!   execution automatically, making accidental nesting safe (it degrades to
//!   inline execution instead of deadlocking).

use std::cell::Cell;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// True on threads owned by a [`ThreadPool`]. The partition primitives use
/// this to run serially instead of enqueueing nested jobs (deadlock guard).
pub fn on_pool_thread() -> bool {
    IN_POOL_WORKER.with(|c| c.get())
}

struct Queue {
    /// Pending jobs + the shutdown flag, under one lock.
    state: Mutex<(VecDeque<Job>, bool)>,
    available: Condvar,
}

/// A fixed-size pool of persistent worker threads with a scoped-spawn API.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
    /// Worker slots currently reserved by live [`super::PoolLease`]s. Grants
    /// are bounded so the sum never exceeds `threads`; the counter is what
    /// the serving `stats` op reports as `threads_leased`.
    leased: AtomicUsize,
}

impl ThreadPool {
    /// Spawn a pool with `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let queue = Arc::new(Queue {
            state: Mutex::new((VecDeque::new(), false)),
            available: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let queue = Arc::clone(&queue);
                std::thread::Builder::new()
                    .name(format!("condcomp-pool-{i}"))
                    .spawn(move || {
                        IN_POOL_WORKER.with(|c| c.set(true));
                        worker_loop(&queue);
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { queue, workers, threads, leased: AtomicUsize::new(0) }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Worker slots currently reserved by live leases (`0` when nothing is
    /// carved out — the whole pool is up for grabs).
    pub fn leased(&self) -> usize {
        self.leased.load(Ordering::Acquire)
    }

    /// Reserve up to `want` worker slots; returns how many were granted
    /// (`min(want, threads - leased)` at the moment of the reservation —
    /// concurrent grants can never sum past the pool size). The caller must
    /// pair every nonzero grant with one [`ThreadPool::release_reserved`];
    /// [`super::PoolLease`] does this in its `Drop`.
    pub(crate) fn try_reserve(&self, want: usize) -> usize {
        let mut cur = self.leased.load(Ordering::Acquire);
        loop {
            let avail = self.threads.saturating_sub(cur);
            let take = want.min(avail);
            if take == 0 {
                return 0;
            }
            match self.leased.compare_exchange_weak(
                cur,
                cur + take,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return take,
                Err(now) => cur = now,
            }
        }
    }

    /// Return `n` previously reserved slots to the pool.
    pub(crate) fn release_reserved(&self, n: usize) {
        if n > 0 {
            let before = self.leased.fetch_sub(n, Ordering::AcqRel);
            debug_assert!(before >= n, "lease release underflow: {before} - {n}");
        }
    }

    fn push(&self, job: Job) {
        let mut state = self.queue.state.lock().unwrap();
        state.0.push_back(job);
        drop(state);
        self.queue.available.notify_one();
    }

    /// Run `f` with a [`Scope`] on which borrowing jobs can be spawned.
    /// Returns only after every spawned job has completed; if any job
    /// panicked, the panic is re-raised here (after all jobs finished).
    pub fn scope<'env, F, T>(&'env self, f: F) -> T
    where
        F: for<'scope> FnOnce(&'scope Scope<'scope, 'env>) -> T,
    {
        self.scope_with(f, false)
    }

    /// [`ThreadPool::scope`] whose spawns run inline on the caller's thread
    /// (same panic semantics: the first job panic is re-raised when the
    /// scope closes). This is the degrade path [`super::PoolLease::scope`]
    /// takes for zero-width leases and nested calls.
    pub(crate) fn scope_inline<'env, F, T>(&'env self, f: F) -> T
    where
        F: for<'scope> FnOnce(&'scope Scope<'scope, 'env>) -> T,
    {
        self.scope_with(f, true)
    }

    fn scope_with<'env, F, T>(&'env self, f: F, inline: bool) -> T
    where
        F: for<'scope> FnOnce(&'scope Scope<'scope, 'env>) -> T,
    {
        let scope = Scope {
            pool: self,
            inline,
            state: Arc::new(ScopeState {
                pending: Mutex::new(0),
                done: Condvar::new(),
                panic: Mutex::new(None),
            }),
            _scope: PhantomData,
            _env: PhantomData,
        };
        // Even if `f` itself panics we must wait for already-spawned jobs
        // before unwinding past the borrowed stack frame.
        let result = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        scope.wait_all();
        match result {
            Err(payload) => resume_unwind(payload),
            Ok(value) => {
                // Re-raise the first job panic with its original payload so
                // assertion messages survive the pool boundary.
                if let Some(payload) = scope.state.panic.lock().unwrap().take() {
                    resume_unwind(payload);
                }
                value
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut state = self.queue.state.lock().unwrap();
            state.1 = true;
        }
        self.queue.available.notify_all();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(queue: &Queue) {
    loop {
        let job = {
            let mut state = queue.state.lock().unwrap();
            loop {
                if let Some(job) = state.0.pop_front() {
                    break Some(job);
                }
                if state.1 {
                    break None;
                }
                state = queue.available.wait(state).unwrap();
            }
        };
        match job {
            // Job bodies are panic-caught in `Scope::spawn`, so the queue
            // lock can never be poisoned by user code.
            Some(job) => job(),
            None => return,
        }
    }
}

struct ScopeState {
    pending: Mutex<usize>,
    done: Condvar,
    /// First panic payload from a job, re-raised when the scope closes.
    panic: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>,
}

/// Handle for spawning borrowing jobs inside [`ThreadPool::scope`].
///
/// The two invariant lifetimes mirror `std::thread::Scope`: `'scope` is the
/// duration of the scope itself, `'env` the environment it may borrow from.
pub struct Scope<'scope, 'env: 'scope> {
    pool: &'env ThreadPool,
    /// Inline scopes run every spawn on the caller's thread (lease degrade
    /// path); panic bookkeeping is identical to the queued path.
    inline: bool,
    state: Arc<ScopeState>,
    _scope: PhantomData<&'scope mut &'scope ()>,
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'scope, 'env> Scope<'scope, 'env> {
    /// Queue a job on the pool. The job may borrow anything that outlives
    /// the enclosing [`ThreadPool::scope`] call.
    pub fn spawn<F>(&'scope self, f: F)
    where
        F: FnOnce() + Send + 'scope,
    {
        if self.inline {
            if let Err(payload) = catch_unwind(AssertUnwindSafe(f)) {
                let mut slot = self.state.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            return;
        }
        *self.state.pending.lock().unwrap() += 1;
        let state = Arc::clone(&self.state);
        let job: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
            if let Err(payload) = catch_unwind(AssertUnwindSafe(f)) {
                let mut slot = state.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            let mut pending = state.pending.lock().unwrap();
            *pending -= 1;
            if *pending == 0 {
                state.done.notify_all();
            }
        });
        // SAFETY: the only thing transmuted away is the `'scope` lifetime
        // bound of the boxed closure (the fat-pointer layout is identical).
        // `ThreadPool::scope` blocks in `wait_all` until `pending` reaches
        // zero — on both the normal and the unwinding path — so the job can
        // never run after the borrows it captured have expired.
        let job: Job = unsafe { std::mem::transmute(job) };
        self.pool.push(job);
    }

    fn wait_all(&self) {
        let mut pending = self.state.pending.lock().unwrap();
        while *pending > 0 {
            pending = self.state.done.wait(pending).unwrap();
        }
    }
}

// ---------------------------------------------------------------------------
// Execution targets
// ---------------------------------------------------------------------------

/// An execution target for the data-parallel primitives: a whole
/// [`ThreadPool`] or a [`super::PoolLease`] slice of one.
///
/// The partition primitives size their chunking by [`Parallelism::width`]
/// and execute on [`Parallelism::pool`]. Because chunk boundaries never
/// change result bits (every kernel in the crate is bit-identical to its
/// serial oracle), running on a lease of any width computes exactly what the
/// full pool computes — a lease only bounds how much of the shared pool one
/// caller occupies at a time.
pub trait Parallelism {
    /// The pool that executes spawned jobs.
    fn pool(&self) -> &ThreadPool;
    /// Effective worker count used to size work partitions (`1` = run
    /// inline on the caller's thread).
    fn width(&self) -> usize;
}

impl Parallelism for ThreadPool {
    fn pool(&self) -> &ThreadPool {
        self
    }

    fn width(&self) -> usize {
        self.threads
    }
}

// ---------------------------------------------------------------------------
// The process-wide shared pool
// ---------------------------------------------------------------------------

static GLOBAL_POOL: OnceLock<ThreadPool> = OnceLock::new();
static REQUESTED_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Request a size for the global pool (`0` = auto). Takes effect only if the
/// pool has not been created yet; returns whether the request will be
/// honored. Call early (the CLI does, from `--threads`).
pub fn configure_global(threads: usize) -> bool {
    REQUESTED_THREADS.store(threads, Ordering::SeqCst);
    GLOBAL_POOL.get().is_none()
}

/// Like [`configure_global`], but only applies when no explicit size has
/// been requested yet — lower-precedence knobs (config-file `train.threads`
/// applied from library code) use this so they never override a CLI
/// `--threads` that was set first.
pub fn configure_global_if_unset(threads: usize) -> bool {
    if GLOBAL_POOL.get().is_some() {
        return false;
    }
    REQUESTED_THREADS
        .compare_exchange(0, threads, Ordering::SeqCst, Ordering::SeqCst)
        .is_ok()
}

/// Default worker count: `CONDCOMP_THREADS` env override, else the machine's
/// available parallelism.
pub fn default_threads() -> usize {
    std::env::var("CONDCOMP_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

/// The process-wide shared pool, created on first use with the configured
/// (or default) thread count.
pub fn global() -> &'static ThreadPool {
    GLOBAL_POOL.get_or_init(|| {
        let requested = REQUESTED_THREADS.load(Ordering::SeqCst);
        let threads = if requested == 0 { default_threads() } else { requested };
        ThreadPool::new(threads)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, AtomicU64};

    #[test]
    fn scope_runs_all_jobs_and_joins() {
        let pool = ThreadPool::new(4);
        let counter = AtomicU64::new(0);
        pool.scope(|s| {
            for i in 0..100u64 {
                let counter = &counter;
                s.spawn(move || {
                    counter.fetch_add(i, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn jobs_can_borrow_mutably_and_disjointly() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0usize; 10];
        pool.scope(|s| {
            for (i, slot) in data.iter_mut().enumerate() {
                s.spawn(move || *slot = i * i);
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn sequential_scopes_reuse_workers() {
        let pool = ThreadPool::new(2);
        let hits = AtomicU64::new(0);
        for _ in 0..50 {
            pool.scope(|s| {
                let hits = &hits;
                s.spawn(move || {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
            });
        }
        assert_eq!(hits.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn empty_scope_returns() {
        let pool = ThreadPool::new(2);
        let v = pool.scope(|_s| 42);
        assert_eq!(v, 42);
    }

    #[test]
    fn job_panic_propagates_with_its_payload_after_all_jobs_finish() {
        let pool = ThreadPool::new(2);
        let completed = AtomicU64::new(0);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| {
                s.spawn(|| panic!("boom at shard 3"));
                for _ in 0..8 {
                    let completed = &completed;
                    s.spawn(move || {
                        completed.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
        }));
        // The original payload (and thus the assertion message) survives.
        let payload = result.expect_err("scope must re-raise the job panic");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .or_else(|| payload.downcast_ref::<String>().map(|s| s.as_str()))
            .unwrap_or("");
        assert!(msg.contains("boom at shard 3"), "payload lost: {msg:?}");
        assert_eq!(completed.load(Ordering::Relaxed), 8, "other jobs still ran");
        // The pool survives a panicked job.
        let ok = pool.scope(|_| true);
        assert!(ok);
    }

    #[test]
    fn worker_flag_is_set_inside_jobs() {
        let pool = ThreadPool::new(1);
        assert!(!on_pool_thread());
        let seen = AtomicBool::new(false);
        pool.scope(|s| {
            let seen = &seen;
            s.spawn(move || seen.store(on_pool_thread(), Ordering::Release));
        });
        assert!(seen.load(Ordering::Acquire));
    }

    #[test]
    fn threads_clamped_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.threads(), 1);
    }

    #[test]
    fn global_pool_is_shared() {
        let a = global() as *const ThreadPool;
        let b = global() as *const ThreadPool;
        assert_eq!(a, b);
        assert!(global().threads() >= 1);
    }
}
