//! Data-parallel partitioning primitives on top of [`super::ThreadPool`].
//!
//! All primitives split work into **contiguous, disjoint chunks** and hand
//! each chunk to one pool job. Because every chunk is computed by exactly the
//! same code a serial loop would run — and floating-point accumulation order
//! inside a chunk never depends on the chunk boundaries — results are
//! **bit-identical to the serial path and invariant to the thread count**.
//! Per-chunk return values come back in chunk order, so reductions over them
//! (e.g. the masked GEMM's `computed` counts) are deterministic too.
//!
//! Serial fallbacks: a single chunk, a one-wide execution target, or being
//! called from inside a pool job ([`on_pool_thread`], the no-nesting guard)
//! all run the chunks inline on the caller's thread.
//!
//! Every primitive is generic over [`Parallelism`], so the same code path
//! serves a whole [`super::ThreadPool`] and a [`super::PoolLease`] slice of
//! one — chunking is sized by the target's *width*, execution lands on its
//! pool.

use super::pool::{on_pool_thread, Parallelism};
use crate::linalg::Mat;

#[inline]
fn div_up(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Rows per chunk so that `total` rows split into at most `threads` chunks,
/// with the chunk size rounded up to a multiple of `quantum` (the GEMM row
/// panel MC; 1 for row-granular work). Always returns at least `quantum`.
pub fn chunk_rows(total: usize, threads: usize, quantum: usize) -> usize {
    let quantum = quantum.max(1);
    let threads = threads.max(1);
    let per = div_up(total.max(1), threads);
    (div_up(per, quantum) * quantum).max(quantum)
}

/// Split a compute-thread budget of `total` threads into `shards` per-shard
/// slice sizes — the serving coordinator's "partitioned slice of the shared
/// pool": each shard executor leases a [`super::PoolLease`] of this size
/// from the shared pool, so the shards together use the configured budget
/// instead of each oversubscribing the whole machine.
///
/// Every shard gets at least 1 thread; when `total` does not divide evenly
/// the remainder goes to the lowest-indexed shards, so
/// `sum(partition_threads(t, s)) == max(t, s)`.
pub fn partition_threads(total: usize, shards: usize) -> Vec<usize> {
    let shards = shards.max(1);
    let total = total.max(1);
    let base = total / shards;
    let extra = total % shards;
    (0..shards)
        .map(|i| (base + usize::from(i < extra)).max(1))
        .collect()
}

/// Split `data` into chunks of `chunk_len` elements (last chunk may be
/// short) and run `f(chunk_index, element_offset, chunk)` for each, on the
/// target's pool when it pays and inline otherwise. Returns the per-chunk
/// results in chunk order.
pub fn par_chunks_mut<P, T, R, F>(
    par: &P,
    data: &mut [T],
    chunk_len: usize,
    f: F,
) -> Vec<R>
where
    P: Parallelism,
    T: Send,
    R: Send,
    F: Fn(usize, usize, &mut [T]) -> R + Sync,
{
    let chunk_len = chunk_len.max(1);
    let n_chunks = div_up(data.len(), chunk_len);
    if n_chunks <= 1 || par.width() == 1 || on_pool_thread() {
        return data
            .chunks_mut(chunk_len)
            .enumerate()
            .map(|(i, chunk)| f(i, i * chunk_len, chunk))
            .collect();
    }
    let mut results: Vec<Option<R>> = Vec::with_capacity(n_chunks);
    results.resize_with(n_chunks, || None);
    let f = &f;
    par.pool().scope(|s| {
        for (i, (slot, chunk)) in results.iter_mut().zip(data.chunks_mut(chunk_len)).enumerate() {
            s.spawn(move || {
                *slot = Some(f(i, i * chunk_len, chunk));
            });
        }
    });
    results
        .into_iter()
        .map(|r| r.expect("pool chunk did not run"))
        .collect()
}

/// Row-oriented variant over a matrix: splits `m` into bands of
/// `rows_per_chunk` whole rows and runs `f(first_row, band)` for each, where
/// `band` is the row-major storage of those rows. Results in band order.
pub fn par_row_chunks<P, R, F>(
    par: &P,
    m: &mut Mat,
    rows_per_chunk: usize,
    f: F,
) -> Vec<R>
where
    P: Parallelism,
    R: Send,
    F: Fn(usize, &mut [f32]) -> R + Sync,
{
    let cols = m.cols();
    if cols == 0 {
        return Vec::new();
    }
    let rows_per_chunk = rows_per_chunk.max(1);
    par_chunks_mut(par, m.as_mut_slice(), rows_per_chunk * cols, move |_, offset, band| {
        f(offset / cols, band)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::ThreadPool;
    use crate::util::proptest::property;

    #[test]
    fn chunk_rows_covers_and_quantizes() {
        // 512 rows on 4 threads with MC=64 → 128-row chunks.
        assert_eq!(chunk_rows(512, 4, 64), 128);
        // Quantum rounding: 100 rows / 3 threads, quantum 16 → ceil(34/16)*16 = 48.
        assert_eq!(chunk_rows(100, 3, 16), 48);
        // Degenerate inputs stay sane.
        assert_eq!(chunk_rows(0, 4, 8), 8);
        assert_eq!(chunk_rows(5, 0, 0), 5);
        // Chunks never exceed the thread count.
        for total in [1usize, 7, 64, 129, 1000] {
            for threads in [1usize, 2, 7, 16] {
                for quantum in [1usize, 8, 64] {
                    let per = chunk_rows(total, threads, quantum);
                    assert!(per >= 1);
                    assert!((total + per - 1) / per <= threads.max(1));
                }
            }
        }
    }

    #[test]
    fn partition_threads_covers_the_budget() {
        assert_eq!(partition_threads(8, 2), vec![4, 4]);
        assert_eq!(partition_threads(7, 2), vec![4, 3]);
        assert_eq!(partition_threads(2, 5), vec![1, 1, 1, 1, 1]);
        assert_eq!(partition_threads(0, 0), vec![1]);
        for total in [1usize, 2, 7, 16] {
            for shards in [1usize, 2, 3, 7] {
                let parts = partition_threads(total, shards);
                assert_eq!(parts.len(), shards);
                assert!(parts.iter().all(|&p| p >= 1));
                assert_eq!(parts.iter().sum::<usize>(), total.max(shards));
                // Lowest-indexed shards soak up the remainder.
                assert!(parts.windows(2).all(|w| w[0] >= w[1]));
            }
        }
    }

    #[test]
    fn par_chunks_mut_matches_serial_for_any_thread_count() {
        for threads in [1usize, 2, 7] {
            let pool = ThreadPool::new(threads);
            property("par_chunks_mut == serial map", 16, |rng| {
                let n = rng.index(200) + 1;
                let chunk = rng.index(32) + 1;
                let mut data: Vec<i64> = (0..n as i64).collect();
                let mut want = data.clone();
                for v in want.iter_mut() {
                    *v = *v * 3 + 1;
                }
                let sums = par_chunks_mut(&pool, &mut data, chunk, |_, offset, c| {
                    let mut s = 0i64;
                    for (j, v) in c.iter_mut().enumerate() {
                        assert_eq!(*v, (offset + j) as i64, "offset bookkeeping");
                        *v = *v * 3 + 1;
                        s += *v;
                    }
                    s
                });
                assert_eq!(data, want);
                assert_eq!(sums.iter().sum::<i64>(), want.iter().sum::<i64>());
                assert_eq!(sums.len(), (n + chunk - 1) / chunk);
            });
        }
    }

    #[test]
    fn par_row_chunks_sees_whole_rows() {
        let pool = ThreadPool::new(2);
        let mut m = Mat::from_fn(9, 4, |r, c| (r * 4 + c) as f32);
        let firsts = par_row_chunks(&pool, &mut m, 2, |row0, band| {
            assert_eq!(band.len() % 4, 0, "whole rows only");
            for v in band.iter_mut() {
                *v += 1.0;
            }
            row0
        });
        assert_eq!(firsts, vec![0, 2, 4, 6, 8]);
        assert_eq!(m[(3, 2)], (3 * 4 + 2) as f32 + 1.0);
    }

    #[test]
    fn empty_input_yields_no_chunks() {
        let pool = ThreadPool::new(2);
        let mut data: Vec<u8> = Vec::new();
        let out: Vec<usize> = par_chunks_mut(&pool, &mut data, 8, |i, _, _| i);
        assert!(out.is_empty());
        let mut m = Mat::zeros(0, 5);
        let out: Vec<usize> = par_row_chunks(&pool, &mut m, 2, |r, _| r);
        assert!(out.is_empty());
    }

    #[test]
    fn nested_calls_fall_back_to_serial() {
        // A job that itself calls par_chunks_mut must not deadlock: the
        // on_pool_thread guard degrades the inner call to inline execution.
        let pool = ThreadPool::new(2);
        let mut outer = vec![0u32; 4];
        par_chunks_mut(&pool, &mut outer, 1, |i, _, chunk| {
            let inner_pool = super::super::pool::global();
            let mut inner = vec![i as u32; 8];
            let _ = par_chunks_mut(inner_pool, &mut inner, 2, |_, _, c| {
                for v in c.iter_mut() {
                    *v += 1;
                }
            });
            chunk[0] = inner.iter().sum();
        });
        assert_eq!(outer, vec![8, 16, 24, 32]);
    }
}
