//! Small self-contained utilities: PRNG, statistics, timing, property testing.
//!
//! The build environment is fully offline, so the usual ecosystem crates
//! (`rand`, `proptest`, `criterion`) are unavailable; these modules provide
//! the small slices of their functionality the rest of the crate needs.

pub mod rng;
pub mod stats;
pub mod timer;
pub mod proptest;
pub mod ulp;

pub use rng::Pcg32;
pub use stats::Summary;
pub use timer::Timer;
