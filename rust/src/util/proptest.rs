//! A tiny property-testing harness (offline substitute for `proptest`).
//!
//! Runs a property over `cases` randomized inputs drawn from a seeded
//! [`Pcg32`]; on failure it reports the case index and the seed so the run
//! reproduces exactly. No shrinking — cases are kept small instead.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the libstdc++ rpath the xla crate needs)
//! use condcomp::util::proptest::property;
//! property("addition commutes", 64, |rng| {
//!     let (a, b) = (rng.uniform(), rng.uniform());
//!     assert!((a + b - (b + a)).abs() < 1e-6);
//! });
//! ```

use super::rng::Pcg32;

/// Seed used for property tests; override with `CONDCOMP_PROPTEST_SEED`.
pub fn base_seed() -> u64 {
    std::env::var("CONDCOMP_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0DE_CA5E)
}

/// Run `prop` over `cases` independent RNG streams. Panics (with the case
/// index and seed embedded in the message) if any case panics.
pub fn property(name: &str, cases: u32, mut prop: impl FnMut(&mut Pcg32)) {
    let seed = base_seed();
    for case in 0..cases {
        let mut rng = Pcg32::new(seed, case as u64 + 1);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| panic.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed on case {case}/{cases} \
                 (CONDCOMP_PROPTEST_SEED={seed}): {msg}"
            );
        }
    }
}

/// Draw a random shape `(rows, cols)` with each dim in `[1, max_dim]`.
pub fn arb_shape(rng: &mut Pcg32, max_dim: usize) -> (usize, usize) {
    (rng.index(max_dim) + 1, rng.index(max_dim) + 1)
}

/// Fill-and-return a random matrix buffer with entries in `[-1, 1)`.
pub fn arb_buf(rng: &mut Pcg32, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        property("tautology", 16, |rng| {
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn failing_property_reports() {
        property("must fail", 8, |rng| {
            assert!(rng.uniform() < -1.0, "impossible");
        });
    }

    #[test]
    fn arb_shape_in_bounds() {
        property("shape bounds", 32, |rng| {
            let (r, c) = arb_shape(rng, 10);
            assert!((1..=10).contains(&r) && (1..=10).contains(&c));
        });
    }
}
