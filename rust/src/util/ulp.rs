//! ULP (units-in-the-last-place) distance for `f32` — the comparator behind
//! the kernel registry's `Tolerance(..)` equivalence tier.
//!
//! The trick is the standard monotone reindexing of IEEE-754 bit patterns:
//! mapped through [`ulp_index`], the finite floats (plus ±∞) form a single
//! ascending integer sequence in numeric order, so the ULP distance between
//! two floats is just the difference of their indices. Both zeros map to
//! index 0, making `-0.0` and `+0.0` zero ULPs apart.

/// Map `x` onto the monotone integer line: adjacent representable floats
/// have adjacent indices, ordering matches numeric ordering, and ±0.0 both
/// map to 0. (NaNs land beyond the ±∞ indices; callers reject them first.)
pub fn ulp_index(x: f32) -> i64 {
    let i = x.to_bits() as i32;
    if i >= 0 {
        i64::from(i)
    } else {
        // Negative floats have sign-bit-set patterns that *increase* as the
        // value decreases; flip them below zero so ordering is restored.
        i64::from(i32::MIN) - i64::from(i)
    }
}

/// ULP distance between `a` and `b`. Equal values (including `+0.0` vs
/// `-0.0`, and infinities of the same sign) are 0 apart; any NaN on either
/// side yields `u64::MAX` so it can never satisfy a tolerance.
pub fn ulp_diff(a: f32, b: f32) -> u64 {
    if a == b {
        return 0;
    }
    if a.is_nan() || b.is_nan() {
        return u64::MAX;
    }
    (ulp_index(a) - ulp_index(b)).unsigned_abs()
}

/// Whether `a` and `b` are within `ulps` ULPs of each other.
pub fn ulp_within(a: f32, b: f32, ulps: u32) -> bool {
    ulp_diff(a, b) <= u64::from(ulps)
}

/// Tier check used for `Tolerance(ulps)` kernels: a relative ULP bound,
/// with an absolute floor of `ulps · ε` near zero. The floor matters at
/// ReLU boundaries — a fused and an unfused accumulation can land on
/// opposite sides of 0.0, where the values are ULP-far apart but both tiny.
pub fn within_tolerance(a: f32, b: f32, ulps: u32) -> bool {
    ulp_within(a, b, ulps) || (a - b).abs() <= ulps as f32 * f32::EPSILON
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{arb_buf, property};

    #[test]
    fn identical_values_are_zero_ulps_apart() {
        property("ulp self-distance is 0", 64, |rng| {
            let x = rng.uniform_in(-1e6, 1e6);
            assert_eq!(ulp_diff(x, x), 0);
        });
        assert_eq!(ulp_diff(0.0, -0.0), 0, "signed zeros compare equal");
        assert_eq!(ulp_diff(f32::INFINITY, f32::INFINITY), 0);
    }

    #[test]
    fn adjacent_floats_are_one_ulp_apart() {
        property("next_up is 1 ULP away", 64, |rng| {
            let x = rng.uniform_in(-1e4, 1e4);
            let next = f32::from_bits(if x >= 0.0 { x.to_bits() + 1 } else { x.to_bits() - 1 });
            assert_eq!(ulp_diff(x, next), 1, "x={x}");
        });
        // The famous boundary: smallest positive subnormal vs zero, and the
        // two subnormals straddling zero.
        assert_eq!(ulp_diff(0.0, f32::from_bits(1)), 1);
        assert_eq!(ulp_diff(-f32::from_bits(1), f32::from_bits(1)), 2);
    }

    #[test]
    fn diff_is_symmetric_and_monotone() {
        property("symmetry + monotonicity", 64, |rng| {
            let buf = arb_buf(rng, 3);
            let (a, b) = (buf[0] * 100.0, buf[1] * 100.0);
            assert_eq!(ulp_diff(a, b), ulp_diff(b, a));
            // Monotone: the index ordering matches numeric ordering.
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            assert!(ulp_index(lo) <= ulp_index(hi), "lo={lo} hi={hi}");
            // Triangle-ish: a midpoint is no farther than the endpoints.
            let mid = lo + (hi - lo) * 0.5;
            if mid.is_finite() {
                assert!(ulp_diff(lo, mid) <= ulp_diff(lo, hi));
            }
        });
    }

    #[test]
    fn nan_never_satisfies_a_tolerance() {
        assert_eq!(ulp_diff(f32::NAN, f32::NAN), u64::MAX);
        assert_eq!(ulp_diff(f32::NAN, 1.0), u64::MAX);
        assert!(!ulp_within(1.0, f32::NAN, u32::MAX));
        assert!(!within_tolerance(f32::NAN, f32::NAN, u32::MAX));
    }

    #[test]
    fn tolerance_has_an_absolute_floor_near_zero() {
        // 1e-5 and -1e-5 are millions of ULPs apart but within the absolute
        // floor at 4096 ULPs (4096 · ε ≈ 4.9e-4) — the ReLU-boundary case.
        assert!(!ulp_within(1e-5, -1e-5, 4096));
        assert!(within_tolerance(1e-5, -1e-5, 4096));
        // Far from zero the relative bound governs: 1.0 vs 1.0+2ulp passes
        // a 4-ULP tier, 1.0 vs 1.001 (≈ 8400 ULPs) fails it.
        let two_up = f32::from_bits(1.0f32.to_bits() + 2);
        assert!(within_tolerance(1.0, two_up, 4));
        assert!(!within_tolerance(1.0, 1.001, 4));
    }
}
